"""CI observability gate: validate a TRACE.json produced by `trivance trace`.

Usage: check_trace.py TRACE.json

Checks (schema trivance.trace.v1, the Chrome trace-event "JSON object
format" plus a `link_telemetry` extension):

- top level: schema tag, `traceEvents` array, `link_telemetry` array;
- every event has a name, a known phase (B/E/X/i), a pid inside the five
  Trivance lanes (1 packet, 2 flow, 3 online, 4 harness, 5 links), a
  non-negative integer tid, and a finite `ts`; `X` events carry a finite
  non-negative `dur`;
- export order is sorted by `ts` (Perfetto requirement for fast loads);
- `B`/`E` events pair up per `(pid, tid)` track: every `E` closes the
  innermost open `B` of the same name, and no span is left open;
- telemetry rows carry exactly the LinkSample fields, describe forward
  intervals, and never report achieved bandwidth above the pristine link
  capacity (relative tolerance 1e-9);
- the rows reconcile 1:1 with the `link_busy` X events on the links lane:
  same link, step, interval (µs vs seconds to 1e-9 relative), bytes,
  capacity, and queue depth. The packet engine emits both from the same
  busy-interval computation, so any divergence means the exporter broke.
"""

import json
import math
import sys

PH_KINDS = {"B", "E", "X", "i"}
PID_MIN, PID_MAX = 1, 5
PID_LINKS = 5
ROW_KEYS = {"link", "step", "start_s", "end_s", "bytes", "cap_bytes_per_s", "queue_len"}
REL_TOL = 1e-9


def close(a, b):
    """|a - b| within REL_TOL of the larger magnitude (floor 1.0)."""
    return abs(a - b) <= REL_TOL * max(abs(a), abs(b), 1.0)


def check_events(events):
    """Validate the traceEvents array; return a list of error strings."""
    errs = []
    last_ts = -math.inf
    stacks = {}
    for i, e in enumerate(events):
        name = e.get("name")
        ph = e.get("ph")
        pid = e.get("pid")
        tid = e.get("tid")
        ts = e.get("ts")
        if not isinstance(name, str) or not name:
            errs.append(f"event {i}: missing name")
            continue
        if ph not in PH_KINDS:
            errs.append(f"event {i} ({name}): unknown phase {ph!r}")
        if not isinstance(pid, int) or not PID_MIN <= pid <= PID_MAX:
            errs.append(f"event {i} ({name}): pid {pid!r} outside lanes {PID_MIN}..{PID_MAX}")
        if not isinstance(tid, int) or tid < 0:
            errs.append(f"event {i} ({name}): bad tid {tid!r}")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errs.append(f"event {i} ({name}): non-finite ts {ts!r}")
            continue
        if ts < last_ts:
            errs.append(f"event {i} ({name}): ts {ts} below predecessor {last_ts}")
        last_ts = ts
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or not math.isfinite(dur) or dur < 0:
                errs.append(f"event {i} ({name}): X with bad dur {dur!r}")
        elif ph == "B":
            stacks.setdefault((pid, tid), []).append(name)
        elif ph == "E":
            stack = stacks.setdefault((pid, tid), [])
            if not stack:
                errs.append(f"event {i}: E {name!r} with no open span on ({pid}, {tid})")
            elif stack[-1] != name:
                errs.append(f"event {i}: E {name!r} closes open span {stack[-1]!r}")
            else:
                stack.pop()
    for (pid, tid), stack in sorted(stacks.items()):
        if stack:
            errs.append(f"span {stack[-1]!r} left open on ({pid}, {tid})")
    return errs


def check_telemetry(rows):
    """Validate the link_telemetry rows; return a list of error strings."""
    errs = []
    for i, r in enumerate(rows):
        if set(r) != ROW_KEYS:
            errs.append(f"row {i}: keys {sorted(r)} != {sorted(ROW_KEYS)}")
            continue
        if r["end_s"] <= r["start_s"]:
            errs.append(f"row {i}: empty interval [{r['start_s']}, {r['end_s']}]")
            continue
        if r["bytes"] <= 0 or r["cap_bytes_per_s"] <= 0:
            errs.append(f"row {i}: non-positive bytes/capacity")
            continue
        achieved = r["bytes"] / (r["end_s"] - r["start_s"])
        if achieved > r["cap_bytes_per_s"] * (1 + REL_TOL):
            errs.append(
                f"row {i}: achieved {achieved:.6e} B/s above capacity "
                f"{r['cap_bytes_per_s']:.6e} on link {r['link']}"
            )
    return errs


def check_reconciliation(events, rows):
    """Every telemetry row must reconcile with one link_busy X event.

    Both are emitted by the packet engine from the same busy interval, the
    event in microseconds, the row in full-precision seconds. Matched by
    sorting both sides by (link, time, step) — intervals on one link are
    far wider than f64 rounding, so the order is unambiguous.
    """
    busy = [
        e
        for e in events
        if e.get("name") == "link_busy" and e.get("ph") == "X" and e.get("pid") == PID_LINKS
    ]
    if len(busy) != len(rows):
        return [f"{len(busy)} link_busy events vs {len(rows)} telemetry rows"]
    busy.sort(key=lambda e: (e["tid"], e["ts"], e["args"]["step"]))
    srows = sorted(rows, key=lambda r: (r["link"], r["start_s"] * 1e6, r["step"]))
    errs = []
    for i, (e, r) in enumerate(zip(busy, srows)):
        args = e.get("args", {})
        bad = []
        if e["tid"] != r["link"]:
            bad.append(f"tid {e['tid']} vs link {r['link']}")
        if args.get("step") != r["step"]:
            bad.append(f"step {args.get('step')} vs {r['step']}")
        if args.get("queue_len") != r["queue_len"]:
            bad.append(f"queue_len {args.get('queue_len')} vs {r['queue_len']}")
        if not close(e["ts"], r["start_s"] * 1e6):
            bad.append(f"ts {e['ts']} vs start {r['start_s'] * 1e6} µs")
        if not close(e["dur"], (r["end_s"] - r["start_s"]) * 1e6):
            bad.append(f"dur {e['dur']} vs {(r['end_s'] - r['start_s']) * 1e6} µs")
        if not close(args.get("bytes", math.nan), r["bytes"]):
            bad.append(f"bytes {args.get('bytes')} vs {r['bytes']}")
        if not close(args.get("cap_bytes_per_s", math.nan), r["cap_bytes_per_s"]):
            bad.append(f"cap {args.get('cap_bytes_per_s')} vs {r['cap_bytes_per_s']}")
        if bad:
            errs.append(f"pair {i} (link {r['link']}): " + "; ".join(bad))
    return errs


def check_trace(doc):
    """Full validation of a parsed TRACE.json; returns error strings."""
    if doc.get("schema") != "trivance.trace.v1":
        return [f"unexpected schema {doc.get('schema')!r}"]
    events = doc.get("traceEvents")
    rows = doc.get("link_telemetry")
    if not isinstance(events, list):
        return ["traceEvents is not an array"]
    if not isinstance(rows, list):
        return ["link_telemetry is not an array"]
    errs = check_events(events)
    errs += check_telemetry(rows)
    errs += check_reconciliation(events, rows)
    return errs


def main():
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} TRACE.json", file=sys.stderr)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"{path}: {e}", file=sys.stderr)
        return 2
    errs = check_trace(doc)
    if errs:
        for e in errs[:20]:
            print(f"FAIL: {e}", file=sys.stderr)
        if len(errs) > 20:
            print(f"... and {len(errs) - 20} more", file=sys.stderr)
        return 1
    n_events = len(doc["traceEvents"])
    n_rows = len(doc["link_telemetry"])
    print(f"{path}: valid trivance.trace.v1 — {n_events} events, {n_rows} telemetry rows")
    return 0


if __name__ == "__main__":
    sys.exit(main())
