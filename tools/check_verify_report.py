"""CI schema validator for VERIFY_report.json (schema trivance.verify.v2).

Usage: check_verify_report.py REPORT

Validates the report the `trivance verify --all` CI gate emits (and the
pysim mirror's report_v2, which is shape-identical):

- schema tag is "trivance.verify.v2";
- top-level "passes" lists every pass exactly once with a non-negative
  wall-clock "seconds" (the per-pass timing satellite: a slow pass must be
  visible in the artifact before it bloats the CI gate);
- "topos" is a non-empty list of {dims, certs} with non-empty certs;
- every cert carries every v1 field (the v2 bump preserves them) and every
  v2 pass field, with basic type/value sanity;
- cross-field consistency a released report must satisfy: barrier_free
  mirrors hazard_war_cells == 0, no WAW races, deadlock_ok true, the cost
  certificate's step count and serialization sum agree with the v1
  optimality/congestion fields, and bandwidth (B) variants are in-place
  (zero WAR cells).

Exit codes: 0 valid, 1 invalid, 2 usage/parse error.
"""

import json
import sys

PASS_NAMES = ["dataflow", "hazard", "deadlock", "memory", "ports",
              "congestion", "optimality", "cost"]

V1_FIELDS = {
    "collective": str, "algo": str, "variant": str, "padded": bool,
    "steps": int, "lat_bound3": int, "lat_bound2": int,
    "max_node_sent_rel": (int, float), "bw_lower_rel": (int, float),
    "port_budget": int, "max_port_msgs": int,
    "tx_delay_rel": (int, float), "max_link_rel": (int, float),
    "mean_link_rel": (int, float), "max_link_msgs": int,
    "bytes_on_wire_rel": (int, float), "messages": int, "max_atoms": int,
    "class": str,
}
V2_FIELDS = {
    "hazard_war_cells": int, "hazard_waw_conflicts": int,
    "barrier_free": bool, "deadlock_ok": bool,
    "mem_peak_rel": (int, float), "mem_in_rel_max": (int, float),
    "cost_steps": int, "cost_tx_rel": (int, float),
    "cost_hop_lat_rel": (int, float), "cost_hop_proc_rel": (int, float),
}
CLASSES = {"latency-optimal", "bandwidth-optimal", "neither"}


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def check_cert(where, c):
    for field, ty in {**V1_FIELDS, **V2_FIELDS}.items():
        if field not in c:
            return fail(f"{where}: missing field {field!r}")
        v = c[field]
        if isinstance(v, bool) and ty is not bool:
            return fail(f"{where}: field {field!r} is a bool, want {ty}")
        if not isinstance(v, ty):
            return fail(f"{where}: field {field!r} is {type(v).__name__}")
    if c["class"] not in CLASSES:
        return fail(f"{where}: unknown class {c['class']!r}")
    if c["steps"] < 1 or c["max_atoms"] < 1 or c["messages"] < 1:
        return fail(f"{where}: degenerate counts")
    if c["hazard_waw_conflicts"] != 0:
        return fail(f"{where}: released report carries a WAW race")
    if c["barrier_free"] != (c["hazard_war_cells"] == 0):
        return fail(f"{where}: barrier_free inconsistent with WAR count")
    if c["variant"] == "B" and c["hazard_war_cells"] != 0:
        return fail(f"{where}: bandwidth variant is not in-place")
    if not c["deadlock_ok"]:
        return fail(f"{where}: released report carries a deadlock finding")
    if c["cost_steps"] != c["steps"]:
        return fail(f"{where}: cost_steps {c['cost_steps']} != steps "
                    f"{c['steps']}")
    if abs(c["cost_tx_rel"] - c["tx_delay_rel"]) > 1e-9:
        return fail(f"{where}: cost_tx_rel {c['cost_tx_rel']} != "
                    f"tx_delay_rel {c['tx_delay_rel']}")
    if c["mem_peak_rel"] < 1.0:
        return fail(f"{where}: mem_peak_rel below one accumulator")
    return 0


def check_report(rep):
    if rep.get("schema") != "trivance.verify.v2":
        return fail(f"unexpected schema {rep.get('schema')!r}")
    passes = rep.get("passes")
    if not isinstance(passes, list):
        return fail("missing top-level 'passes' timing list")
    names = [p.get("name") for p in passes]
    if sorted(names) != sorted(PASS_NAMES):
        return fail(f"pass timing list {names} != {PASS_NAMES}")
    for p in passes:
        if not isinstance(p.get("seconds"), (int, float)) or p["seconds"] < 0:
            return fail(f"pass {p.get('name')!r}: bad seconds")
    topos = rep.get("topos")
    if not isinstance(topos, list) or not topos:
        return fail("missing or empty 'topos'")
    for t in topos:
        dims = t.get("dims")
        if (not isinstance(dims, list) or not dims
                or not all(isinstance(d, int) and d > 0 for d in dims)):
            return fail(f"bad dims {dims!r}")
        certs = t.get("certs")
        if not isinstance(certs, list) or not certs:
            return fail(f"{dims}: missing or empty certs")
        for c in certs:
            where = f"{dims}/{c.get('collective', '?')}"
            if check_cert(where, c):
                return 1
    return 0


def main():
    if len(sys.argv) != 2:
        print(f"usage: {sys.argv[0]} REPORT", file=sys.stderr)
        return 2
    try:
        with open(sys.argv[1]) as f:
            rep = json.load(f)
    except (OSError, ValueError) as e:
        print(f"{sys.argv[1]}: {e}", file=sys.stderr)
        return 2
    rc = check_report(rep)
    if rc == 0:
        n = sum(len(t["certs"]) for t in rep["topos"])
        print(f"{sys.argv[1]}: valid trivance.verify.v2 "
              f"({len(rep['topos'])} topologies, {n} certificates)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
