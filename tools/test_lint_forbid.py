"""Unit tests for tools/lint_forbid.py (the CI source-lint gate).

Run directly: `python3 tools/test_lint_forbid.py`. Each case shells out to
the real script against a synthetic repo tree so the exit codes tested
here are exactly the ones CI acts on: 0 clean, 1 violation/stale entry,
2 usage error.
"""

import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                      "lint_forbid.py")


class LintForbidTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()
        self.root = self.dir.name

    def tearDown(self):
        self.dir.cleanup()

    def write(self, rel, content):
        path = os.path.join(self.root, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            f.write(content)
        return path

    def run_lint(self, allow=None):
        cmd = [sys.executable, SCRIPT, "--root", self.root,
               "--allow", allow or os.path.join(self.root, "allow.txt")]
        return subprocess.run(cmd, capture_output=True, text=True)

    def test_clean_tree_passes(self):
        self.write("rust/src/sim/mod.rs", "pub fn ok() -> u32 { 1 }\n")
        self.write("rust/src/net/mod.rs", "pub fn ok() {}\n")
        r = self.run_lint()
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("clean", r.stdout)

    def test_unwrap_in_library_fails(self):
        self.write("rust/src/verify/mod.rs",
                   "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n")
        r = self.run_lint()
        self.assertEqual(r.returncode, 1)
        self.assertIn("verify/mod.rs:1", r.stderr)
        self.assertIn(".unwrap()", r.stderr)

    def test_expect_and_panic_fail(self):
        self.write("rust/src/schedule/mod.rs",
                   'fn f() { g().expect("boom"); }\n')
        self.write("rust/src/sim/plan.rs",
                   'fn g() { panic!("no"); }\n')
        r = self.run_lint()
        self.assertEqual(r.returncode, 1)
        self.assertIn("schedule/mod.rs", r.stderr)
        self.assertIn("sim/plan.rs", r.stderr)

    def test_cfg_test_tail_is_exempt(self):
        self.write("rust/src/sim/mod.rs",
                   "pub fn ok() {}\n"
                   "#[cfg(test)]\n"
                   "mod tests {\n"
                   "    #[test]\n"
                   '    fn t() { Some(1).unwrap(); panic!("fine here"); }\n'
                   "}\n")
        r = self.run_lint()
        self.assertEqual(r.returncode, 0, r.stderr)

    def test_paths_outside_library_dirs_are_ignored(self):
        self.write("rust/src/cli.rs", "fn f() { x.unwrap(); }\n")
        self.write("rust/src/sim/mod.rs", "pub fn ok() {}\n")
        r = self.run_lint()
        self.assertEqual(r.returncode, 0, r.stderr)

    def test_allowlist_excuses_exact_file_and_substring(self):
        self.write("rust/src/net/mod.rs",
                   'fn f() { q.expect("bfs invariant") }\n')
        allow = self.write(
            "allow.txt",
            'net/mod.rs :: q.expect("bfs invariant") :: queued nodes '
            "always have distances\n")
        r = self.run_lint(allow)
        self.assertEqual(r.returncode, 0, r.stderr)
        self.assertIn("1 justified exception", r.stdout)

    def test_allowlist_is_per_file(self):
        self.write("rust/src/sim/mod.rs",
                   'fn f() { q.expect("bfs invariant") }\n')
        allow = self.write(
            "allow.txt",
            'net/mod.rs :: q.expect("bfs invariant") :: wrong file\n')
        r = self.run_lint(allow)
        self.assertEqual(r.returncode, 1)

    def test_stale_allowlist_entry_fails(self):
        self.write("rust/src/sim/mod.rs", "pub fn ok() {}\n")
        allow = self.write("allow.txt",
                           "sim/mod.rs :: x.unwrap() :: long gone\n")
        r = self.run_lint(allow)
        self.assertEqual(r.returncode, 1)
        self.assertIn("stale allowlist entry", r.stderr)

    def test_malformed_allowlist_is_usage_error(self):
        self.write("rust/src/sim/mod.rs", "pub fn ok() {}\n")
        allow = self.write("allow.txt", "only two :: fields\n")
        r = self.run_lint(allow)
        self.assertEqual(r.returncode, 2)

    def test_missing_rust_src_is_usage_error(self):
        r = self.run_lint()
        self.assertEqual(r.returncode, 2)

    def test_repo_tree_is_clean(self):
        repo = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            os.pardir)
        r = subprocess.run([sys.executable, SCRIPT, "--root", repo],
                           capture_output=True, text=True)
        self.assertEqual(r.returncode, 0, r.stderr)


if __name__ == "__main__":
    unittest.main()
