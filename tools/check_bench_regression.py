"""CI perf-smoke gate: compare a freshly generated BENCH_core.json against
the checked-in baseline and fail on a >25% events/sec regression.

Usage: check_bench_regression.py BASELINE NEW

Rules (schema trivance.bench_core.v1):
- no baseline file -> skip (exit 0): first run bootstraps the trajectory;
- baseline engine != "rust" -> skip (exit 0): the initial checked-in
  baseline is generated through the pysim mirror (engine "pysim-mirror")
  and python wall clock is not comparable to release-mode rust. The gate
  arms itself once a rust-engine baseline is committed;
- otherwise every queue kind present in the baseline must stay within
  25% of its baseline events/sec in the new record, and the new record's
  queue kinds must agree on events (the bit-identity contract's shadow in
  the trajectory file — the real assert runs inside run_core_bench).
"""

import json
import os
import sys

THRESHOLD = 0.25


def main():
    if len(sys.argv) != 3:
        print(f"usage: {sys.argv[0]} BASELINE NEW", file=sys.stderr)
        return 2
    base_path, new_path = sys.argv[1], sys.argv[2]
    if not os.path.exists(base_path):
        print(f"no baseline at {base_path} — skipping (first run bootstraps)")
        return 0
    with open(base_path) as f:
        base = json.load(f)
    with open(new_path) as f:
        new = json.load(f)
    for rec, name in ((base, base_path), (new, new_path)):
        if rec.get("schema") != "trivance.bench_core.v1":
            print(f"{name}: unexpected schema {rec.get('schema')!r}", file=sys.stderr)
            return 2

    events = {q["events"] for q in new["event_queue"]}
    if len(events) > 1:
        print(f"FAIL: queue kinds disagree on event count in {new_path}: {events}", file=sys.stderr)
        return 1

    if base.get("engine") != "rust":
        print(
            f"baseline engine is {base.get('engine')!r} (not 'rust') — "
            "wall-clock not comparable, skipping the regression gate"
        )
        return 0

    base_eps = {q["kind"]: q["events_per_s"] for q in base["event_queue"]}
    new_eps = {q["kind"]: q["events_per_s"] for q in new["event_queue"]}
    failed = False
    for kind, b in sorted(base_eps.items()):
        n = new_eps.get(kind)
        if n is None:
            print(f"FAIL: queue kind {kind!r} missing from {new_path}", file=sys.stderr)
            failed = True
            continue
        delta = (n - b) / b
        mark = "FAIL" if delta < -THRESHOLD else "ok  "
        print(f"[{mark}] {kind}: {b:.3e} -> {n:.3e} events/s ({delta:+.1%})")
        if delta < -THRESHOLD:
            failed = True
    if failed:
        print(f"events/sec regressed by more than {THRESHOLD:.0%}", file=sys.stderr)
        return 1
    print("perf smoke: no events/sec regression beyond threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
