"""Unit tests for tools/check_bench_regression.py (the CI perf-smoke gate).

Run directly: `python3 tools/test_check_bench_regression.py`. Each case
shells out to the real script so the exit codes tested here are exactly
the ones CI acts on: 0 pass/skip, 1 regression, 2 usage/schema error.
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "check_bench_regression.py")


def record(engine="rust", eps=None, events=None):
    """A minimal trivance.bench_core.v1 record."""
    eps = eps if eps is not None else {"heap": 1e6, "calendar": 2e6}
    return {
        "schema": "trivance.bench_core.v1",
        "engine": engine,
        "event_queue": [
            {"kind": kind, "events": (events or {}).get(kind, 1000), "events_per_s": v}
            for kind, v in sorted(eps.items())
        ],
    }


class CheckBenchRegressionTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()

    def tearDown(self):
        self.dir.cleanup()

    def write(self, name, rec):
        path = os.path.join(self.dir.name, name)
        with open(path, "w") as f:
            json.dump(rec, f)
        return path

    def gate(self, *argv):
        return subprocess.run(
            [sys.executable, SCRIPT, *argv], capture_output=True, text=True
        ).returncode

    def test_wrong_argc_is_usage_error(self):
        self.assertEqual(self.gate(), 2)
        self.assertEqual(self.gate("only-one.json"), 2)

    def test_missing_baseline_bootstraps(self):
        new = self.write("new.json", record())
        self.assertEqual(self.gate(os.path.join(self.dir.name, "absent.json"), new), 0)

    def test_bad_schema_is_an_error(self):
        base = self.write("base.json", {"schema": "something.else"})
        new = self.write("new.json", record())
        self.assertEqual(self.gate(base, new), 2)

    def test_non_rust_baseline_skips_even_on_huge_regression(self):
        base = self.write("base.json", record(engine="pysim-mirror"))
        new = self.write("new.json", record(eps={"heap": 1.0, "calendar": 1.0}))
        self.assertEqual(self.gate(base, new), 0)

    def test_within_threshold_passes(self):
        base = self.write("base.json", record())
        new = self.write("new.json", record(eps={"heap": 0.8e6, "calendar": 1.6e6}))
        self.assertEqual(self.gate(base, new), 0)

    def test_improvement_passes(self):
        base = self.write("base.json", record())
        new = self.write("new.json", record(eps={"heap": 1.5e6, "calendar": 3e6}))
        self.assertEqual(self.gate(base, new), 0)

    def test_one_kind_regressing_past_threshold_fails(self):
        base = self.write("base.json", record())
        new = self.write("new.json", record(eps={"heap": 1e6, "calendar": 1.4e6}))
        self.assertEqual(self.gate(base, new), 1)

    def test_missing_kind_in_new_record_fails(self):
        base = self.write("base.json", record())
        new = self.write("new.json", record(eps={"heap": 1e6}))
        self.assertEqual(self.gate(base, new), 1)

    def test_queue_kinds_disagreeing_on_events_fails_any_engine(self):
        # The bit-identity shadow check runs before the engine gate, so it
        # bites even while the baseline is still pysim-generated.
        base = self.write("base.json", record(engine="pysim-mirror"))
        new = self.write(
            "new.json", record(events={"heap": 1000, "calendar": 999})
        )
        self.assertEqual(self.gate(base, new), 1)


if __name__ == "__main__":
    unittest.main()
