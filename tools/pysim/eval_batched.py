"""Measure the batched packet engine against the reference per-packet
engine and against the flow model on every topology the Rust tests assert:

1. batched == ref exactly when there is no partial-overlap contention
   (single-message closed forms);
2. batched-vs-ref drift across the registry (how far message-granular FIFO
   moves completions);
3. flow-vs-batched rel error on ring9 (Rust bound: 10%), the property set
   (0.25), and the new 8x8 / 4x4x4 acceptance matrix (target: 10%);
4. event-count reduction (the >=3x events/sec claim's basis) on ring-27 at
   1 MiB.
"""

import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
beta = 8.0 / P["bw"]
ph = per_hop(P)
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


# --- 1. closed forms with the batched engine (Rust packet.rs tests) ---
s1 = Schedule("one", 4, 4)
st = s1.push_step()
st[0].append(Send(1, [(frozenset(range(4)), "reduce", frozenset())], MIN))
k, _ = simulate_packet_batched(Plan(s1, Torus([4])), 64 * 1024, P, 4096)
exp = P["alpha"] + 64 * 1024 * beta + ph
chk("batched single hop", abs(k - exp) < 1e-12, f"{k} vs {exp}")

s3 = Schedule("hop3", 9, 9)
st = s3.push_step()
st[0].append(Send(3, [(frozenset(range(9)), "reduce", frozenset())], MIN))
k, _ = simulate_packet_batched(Plan(s3, Torus([9])), 256 * 1024, P, 4096)
exp = P["alpha"] + 256 * 1024 * beta + 2 * 4096 * beta + 3 * ph
chk("batched 3-hop pipeline", abs(k - exp) < exp * 1e-9, f"{k} vs {exp}")

# f64 regression shape: 1 MiB + 1 on a single hop
m = (1 << 20) + 1
k, _ = simulate_packet_batched(Plan(s1, Torus([4])), m, P, 4096)
exp = P["alpha"] + m * beta + ph
chk("batched 1MiB+1 closed form", abs(k - exp) < exp * 1e-12, f"{k} vs {exp}")

# MTU larger than message
k, _ = simulate_packet_batched(Plan(s1, Torus([4])), 100, P, 1 << 20)
exp = P["alpha"] + 100 * beta + ph
chk("batched MTU>message", abs(k - exp) < 1e-12, f"{k} vs {exp}")

# zero-byte collective
k, _ = simulate_packet_batched(Plan(s1, Torus([4])), 0, P, 4096)
exp = P["alpha"] + ph
chk("batched zero bytes", abs(k - exp) < 1e-15, f"{k} vs {exp}")

# lone fractional multi-packet message: batched's single total/cap division
# vs reference's per-packet rounded accumulation differ by a few ulps, never
# more (the Rust test asserts rel < 1e-12, not bit equality)
s_frac = Schedule("frac", 4, 3)
st = s_frac.push_step()
st[0].append(Send(1, [(frozenset([0]), "reduce", frozenset())], MIN))
pf = Plan(s_frac, Torus([4]))
a, _ = simulate_packet_batched(pf, (1 << 20) + 1, P, 4096)
b, _ = simulate_packet_ref(pf, (1 << 20) + 1, P, 4096)
chk(
    "batched vs ref lone fractional message",
    abs(a - b) / b < 1e-12,
    f"rel={abs(a - b) / b:.3e}",
)

# --- 2. batched vs ref drift across registry ---
print("\n== batched vs reference drift ==")
worst = (0.0, None)
for dims in [[8], [9], [27], [3, 3]]:
    for algo in ALGOS:
        for variant in VARIANTS:
            t = Torus(dims)
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t)
            for m in [4096, 256 << 10]:
                r, _ = simulate_packet_ref(plan, m, P, 4096)
                n, _ = simulate_packet_batched(plan, m, P, 4096)
                rel = abs(n - r) / r if r > 0 else 0.0
                if rel > worst[0]:
                    worst = (rel, (dims, algo, variant, m))
                if rel > 0.02:
                    print(f"  drift {rel:.4f}: {dims} {algo}-{variant} m={m}")
print(f"worst batched-vs-ref drift: {worst[0]:.4f} at {worst[1]}")

# --- 3a. flow vs batched, ring9 exhaustive (Rust bound 10%) ---
print("\n== flow vs batched: ring9 matrix (bound 0.10) ==")
for algo in ["trivance", "bruck", "bucket"]:
    for variant in VARIANTS:
        for m in [4096, 256 << 10]:
            r = crosscheck([9], algo, variant, m)
            chk(f"ring9 {algo}-{variant} m={m}", r[0] < 0.10, f"rel={r[0]:.4f}")

# trivance ring9 at packet.rs sizes incl 1 MiB
for m in [4096, 64 * 1024, 1 << 20]:
    r = crosscheck([9], "trivance", "L", m)
    chk(f"ring9 trivance-L m={m}", r[0] < 0.10, f"rel={r[0]:.4f}")

# --- 3b. property set (bound 0.25) ---
print("\n== flow vs batched: property topologies (bound 0.25) ==")
for dims in [[8], [9], [3, 3]]:
    for algo in ALGOS:
        for variant in VARIANTS:
            for m in [4096, 32 << 10, 256 << 10]:
                r = crosscheck(dims, algo, variant, m)
                if r is None:
                    continue
                chk(f"{dims} {algo}-{variant} m={m}", r[0] < 0.25, f"rel={r[0]:.4f}")

# --- 3c. acceptance matrix: 8x8 and 4x4x4, full registry ---
print("\n== flow vs batched: 8x8 / 4x4x4 acceptance (target 0.10) ==")
for dims in [[8, 8], [4, 4, 4]]:
    for algo in ALGOS:
        for variant in VARIANTS:
            for m in [4096, 256 << 10, 1 << 20]:
                r = crosscheck(dims, algo, variant, m)
                if r is None:
                    print(f"  (unsupported: {dims} {algo}-{variant})")
                    continue
                mark = "ok " if r[0] < 0.10 else "OVER"
                print(
                    f"[{mark}] {dims} {algo}-{variant} m={m}: rel={r[0]:.4f} "
                    f"(flow {r[1]:.3e} packet {r[2]:.3e})"
                )

# --- 4. event counts ring-27 at 1 MiB ---
print("\n== event counts: ring27 trivance-L, 1 MiB, mtu 4096 ==")
t = Torus([27])
b = build("trivance", "L", t)
plan = Plan(b.net, t)
r, re = simulate_packet_ref(plan, 1 << 20, P, 4096)
n, ne = simulate_packet_batched(plan, 1 << 20, P, 4096)
print(f"ref events={re} batched events={ne} ratio={re/ne:.1f}x  drift={(abs(n-r)/r):.5f}")

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("batched-engine eval: all asserted bounds hold")
