"""Dynamic-fabrics validation (ISSUE 5): timeline engines, fault rewriting.

No rustc in this container, so the acceptance bounds of the dynamic PR are
measured here against the mirror:

  1. the rewritten schedules are *correct* (symbolic AllReduce validation:
     exact atom covers, no double reduction, full coverage) for every
     non-padded registry build on ring-9 / 3x3 / 4x4x4;
  2. rewrite-vs-detour on the mid-fault preset: rewrite must beat detour
     for trivance at bandwidth-bound sizes (the headline claim of the
     scenarios table), and the worst regression anywhere is reported;
  3. flow-vs-packet agreement under the flap / brownout / mid-fault
     presets stays within 10% across the registry (the crosscheck bound
     asserted in rust/tests/sim_crosscheck.rs);
  4. dynamic presets never *speed up* a collective vs the uniform run;
  5. timeline mechanics: epochs after completion are no-ops, no-op
     mutations are float-level no-ops, and a down link without recovery
     trips the stranded assertion instead of reporting a bogus completion.
"""

import sys

from mirror import (
    ALGOS,
    DEFAULT_PARAMS as P,
    EMPTY_TIMELINE,
    VARIANTS,
    Fault,
    NetModel,
    Plan,
    StrandedError,
    Timeline,
    Torus,
    build,
    dynamic_timeline,
    midfault_fault,
    midfault_plans,
    rewrite_collective_for_faults,
    rewrite_for_fault,
    rewrite_for_fault_hosted,
    simulate_flow,
    simulate_flow_dyn,
    simulate_packet_batched,
    simulate_packet_dyn,
)

FAILED = []


def check(name, ok, detail=""):
    print(f"[{'ok ' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        FAILED.append(name)


# ---------------------------------------------------------------- validator


def validate_allreduce_mirror(s):
    """Symbolic AllReduce validation (mirror of schedule::validate):
    senders hold exact atom unions, receivers never double-reduce, every
    node ends with full coverage. Returns None or an error string."""
    n, nb = s.n, s.n_blocks
    full = frozenset(range(n))
    atoms = [[[frozenset([r])] for _ in range(nb)] for r in range(n)]

    def total(cell):
        t = set()
        for a in cell:
            t |= a
        return frozenset(t)

    for k, step in enumerate(s.steps):
        snapshot = [[list(c) for c in row] for row in atoms]
        for src in range(n):
            for snd in step[src]:
                if snd.to == src:
                    return f"step {k}: self-send at {src}"
                for blocks, kind, contrib in snd.pieces:
                    if not blocks:
                        return f"step {k}: empty piece {src}->{snd.to}"
                    for b in blocks:
                        if kind == "reduce":
                            sender = snapshot[src][b]
                            if not contrib <= total(sender):
                                return f"step {k}: {src}->{snd.to} b{b}: sender lacks contrib"
                            covered = 0
                            for a in sender:
                                inter = a & contrib
                                if not inter:
                                    continue
                                if inter != a:
                                    return (
                                        f"step {k}: {src}->{snd.to} b{b}: contrib not an "
                                        f"exact union of sender atoms"
                                    )
                                covered += len(a)
                            if covered != len(contrib):
                                return f"step {k}: {src}->{snd.to} b{b}: inexact cover"
                            if total(atoms[snd.to][b]) & contrib:
                                return f"step {k}: {src}->{snd.to} b{b}: double reduction"
                            atoms[snd.to][b].append(contrib)
                        else:
                            if contrib != full:
                                return f"step {k}: Set piece with partial contrib"
                            if total(snapshot[src][b]) != full:
                                return f"step {k}: {src}->{snd.to} b{b}: Set from partial holder"
                            atoms[snd.to][b] = [full]
    for r in range(n):
        for b in range(nb):
            if total(atoms[r][b]) != full:
                return f"incomplete: node {r} block {b}"
    return None


# ------------------------------------------------- 1. rewrite correctness

print("== 1. fault-rewrite correctness (symbolic validation) ==")
for dims in ([9], [3, 3], [4, 4, 4]):
    t = Torus(dims)
    base = NetModel.uniform(t)
    fault = midfault_fault(t)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            if b.padded:
                # the raw (collapsed) net schedule still refuses — its
                # contributor sets live in virtual space
                try:
                    rewrite_for_fault(b.net, base, fault)
                    check(f"padded raw-net refusal {algo}-{variant} {dims}", False)
                except ValueError as e:
                    check(
                        f"padded raw-net refusal {algo}-{variant} {dims}",
                        "virtual" in str(e),
                        str(e),
                    )
                if dims == [4, 4, 4]:
                    continue  # virtual space too large for the slow mirror
                # PR 6: the *hosted* rewrite goes through the padding host
                # map; the virtual rewrite is a complete AllReduce and its
                # collapse never crosses the dead cable post-fault
                rw = rewrite_for_fault_hosted(b.exec_s, base, fault, b.hosts)
                err = validate_allreduce_mirror(rw)
                check(f"padded hosted rewrite valid {algo}-{variant} {dims}", err is None, err or "")
                net = rewrite_collective_for_faults(b, base, [fault])
                post = fault.apply(base)
                crosses = False
                for step in net.steps[fault.step:]:
                    for src in range(net.n):
                        for snd in step[src]:
                            if any(post.down[l] for l in post.route(src, snd.to, snd.route)):
                                crosses = True
                check(f"padded collapse avoids dead link {algo}-{variant} {dims}", not crosses)
                continue
            rw = rewrite_for_fault(b.net, base, fault)
            err = validate_allreduce_mirror(rw)
            check(f"rewrite valid {algo}-{variant} {dims}", err is None, err or "")
            extra = rw.num_steps() - b.net.num_steps()
            assert extra in (0, 1), f"{algo}-{variant} {dims}: {extra} extra steps"

# node-death recovery after propagation
t9 = Torus([9])
b = build("trivance", "L", t9)
rw = rewrite_for_fault(b.net, NetModel.uniform(t9), Fault(1, dead_nodes=[4]))
survivors_ok = True
for step in rw.steps[1:]:
    if step[4]:
        survivors_ok = False
    for sends in step:
        for snd in sends:
            if snd.to == 4:
                survivors_ok = False
check("node-death rewrite avoids the dead node", survivors_ok)
try:
    rewrite_for_fault(b.net, NetModel.uniform(t9), Fault(0, dead_nodes=[4]))
    check("node-death before propagation is unrecoverable", False)
except ValueError:
    check("node-death before propagation is unrecoverable", True)

# ------------------------------------- 2. rewrite vs detour (flow mode)

print("== 2. rewrite vs detour on the mid-fault preset (flow) ==")
SIZES = [4096, 64 << 10, 256 << 10, 1 << 20]
worst_regression = 0.0
deltas = {}
# full registry on ring-9 / 3x3; 4x4x4 covered by the (slower) trivance row
CASES = [([9], ALGOS), ([3, 3], ALGOS), ([4, 4, 4], ["trivance"])]
for dims, algo_set in CASES:
    t = Torus(dims)
    for algo in algo_set:
        for variant in VARIANTS:
            plans = midfault_plans(t, algo, variant)
            if plans is None:
                continue
            detour, rewrite, padded = plans
            for m in SIZES:
                fd, _ = simulate_flow(detour, m, P)
                fr, _ = simulate_flow(rewrite, m, P)
                delta = fd / fr - 1.0  # >0: rewrite faster
                deltas[(tuple(dims), algo, variant, m)] = delta
                if delta < worst_regression:
                    worst_regression = delta
                print(
                    f"     {str(dims):>10} {algo}-{variant:1} m={m:>8}: "
                    f"detour/rewrite-1 = {delta:+.3f}"
                )
# The measured shape of the comparison, pinned (these calibrate the Rust
# test midfault_rewrite_validates_and_beats_detour_where_crossings_repeat):
# rewrite wins where the remaining schedule re-crosses the dead cable step
# after step (ring bucket-B), detour-in-place stays at parity for shallow
# schedules (trivance-L, one blocked crossing absorbed by spare capacity).
check(
    "bucket-B ring-9 rewrite beats detour by >30% at 4 KiB",
    deltas[((9,), "bucket", "B", 4096)] > 0.30,
    f"{deltas[((9,), 'bucket', 'B', 4096)]:+.3f}",
)
check(
    "bucket-B ring-9 rewrite beats detour by >10% at 256 KiB",
    deltas[((9,), "bucket", "B", 256 << 10)] > 0.10,
    f"{deltas[((9,), 'bucket', 'B', 256 << 10)]:+.3f}",
)
check(
    "trivance-L ring-9 parity at 1 MiB (|delta| < 10%)",
    abs(deltas[((9,), "trivance", "L", 1 << 20)]) < 0.10,
    f"{deltas[((9,), 'trivance', 'L', 1 << 20)]:+.3f}",
)
print(f"worst rewrite regression anywhere: {worst_regression:+.4f}")

# --------------------------- 3. flow vs packet drift, dynamic presets

print("== 3. flow-vs-packet drift under dynamic presets ==")
# Bounds (mirrored in sim_crosscheck's dynamic test): the ISSUE's 10% holds
# on the 3x3 torus; on the ring every flow shares the single path, so an
# outage pits FIFO head-of-line blocking (packet) against fluid fair
# sharing (flow) — measured worst 19.8% native / 28.0% padded.
worst = (0.0, None)
per_class_worst = {}
for dims in ([9], [3, 3]):
    t = Torus(dims)
    base = NetModel.uniform(t)
    fault = midfault_fault(t)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            bound = 0.10 if dims == [3, 3] else (0.35 if b.padded else 0.25)
            plain = Plan(b.net, t)
            mf = midfault_plans(t, algo, variant)
            for m in (4096, 256 << 10, 1 << 20):
                cases = []
                for name in ("flap", "brownout"):
                    tl = dynamic_timeline(name, t, P, m)
                    cases.append((name, plain, tl))
                cases.append(("mid-fault-detour", mf[0], EMPTY_TIMELINE))
                cases.append(("mid-fault-rewrite", mf[1], EMPTY_TIMELINE))
                for name, plan, tl in cases:
                    f, _ = simulate_flow_dyn(plan, m, P, tl)
                    k, _ = simulate_packet_dyn(plan, m, P, 4096, tl)
                    rel = abs(f - k) / k
                    tag = f"{name} {algo}-{variant} {dims} m={m}"
                    if rel > worst[0]:
                        worst = (rel, tag)
                    key = (tuple(dims), b.padded)
                    if rel > per_class_worst.get(key, (0.0, None))[0]:
                        per_class_worst[key] = (rel, tag)
                    if rel >= bound:
                        check(f"drift {tag}", False, f"rel={rel:.3f} bound={bound}")
for key, (rel, tag) in sorted(per_class_worst.items()):
    print(f"  worst drift {key}: {rel:.4f} ({tag})")
print(f"worst dynamic flow-vs-packet drift: {worst[0]:.4f} ({worst[1]})")
check(
    "dynamic crosscheck bounds (3x3 <10%, ring native <25%, ring padded <35%)",
    per_class_worst.get(((3, 3), False), (0,))[0] < 0.10
    and per_class_worst.get(((3, 3), True), (0,))[0] < 0.10
    and per_class_worst.get(((9,), False), (0,))[0] < 0.25
    and per_class_worst.get(((9,), True), (0,))[0] < 0.35,
)

# --------------------------- 4. dynamic presets never speed things up

print("== 4. monotonicity: dynamic >= uniform ==")
bad = 0
for dims in ([9], [3, 3]):
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plain = Plan(b.net, t)
            mf = midfault_plans(t, algo, variant)
            # virtually-padded builds have lumpy traffic where max-min
            # fair-share *ordering* effects can shave fractions of a percent
            # off a degraded run (same fluid artifact the straggler
            # monotonicity test tolerates at 0.1%); measured worst here
            # 0.26% (flap recdoub-L ring-9 at 4 KiB)
            tol = 5e-3 if b.padded else 1e-9
            for m in (4096, 1 << 20):
                f0, _ = simulate_flow(plain, m, P)
                for name in ("flap", "brownout"):
                    tl = dynamic_timeline(name, t, P, m)
                    f1, _ = simulate_flow_dyn(plain, m, P, tl)
                    if f1 < f0 * (1.0 - tol):
                        bad += 1
                        print(f"  SPEEDUP {name} {algo}-{variant} {dims} m={m}: {f1} < {f0}")
                # mid-fault monotonicity holds only for minimal-routed
                # schedules: bruck-unidir forces the +1 direction, and the
                # BFS detour legitimately finds *shorter* paths for its
                # blocked wrap-around sends (a fault "speeding it up" is the
                # directed hint's inefficiency, not a simulator bug)
                if algo == "bruck-unidir":
                    continue
                for plan in (mf[0], mf[1]):
                    f1, _ = simulate_flow(plan, m, P)
                    if f1 < f0 * (1.0 - 1e-9):
                        bad += 1
                        print(f"  SPEEDUP mid-fault {algo}-{variant} {dims} m={m}: {f1} < {f0}")
check("no dynamic preset speeds up any collective (minimal-routed)", bad == 0)

# trivance visibly degrades at 1 MiB under every dynamic preset (the rust
# scenarios test asserts this on 3x3)
t33 = Torus([3, 3])
b = build("trivance", "L", t33)
bB = build("trivance", "B", t33)
plainL, plainB = Plan(b.net, t33), Plan(bB.net, t33)
m = 1 << 20
base_best = min(simulate_flow(plainL, m, P)[0], simulate_flow(plainB, m, P)[0])
mf = midfault_plans(t33, "trivance", "L")
mfB = midfault_plans(t33, "trivance", "B")
for name in ("flap", "brownout"):
    tl = dynamic_timeline(name, t33, P, m)
    dyn_best = min(
        simulate_flow_dyn(plainL, m, P, tl)[0], simulate_flow_dyn(plainB, m, P, tl)[0]
    )
    check(f"{name} degrades trivance best-variant at 1 MiB on 3x3",
          dyn_best > base_best * 1.0001, f"{dyn_best/base_best-1.0:+.4f}")
for name, pl, plB in (("detour", mf[0], mfB[0]), ("rewrite", mf[1], mfB[1])):
    dyn_best = min(simulate_flow(pl, m, P)[0], simulate_flow(plB, m, P)[0])
    check(f"mid-fault-{name} degrades trivance best-variant at 1 MiB on 3x3",
          dyn_best > base_best * 1.0001, f"{dyn_best/base_best-1.0:+.4f}")

# --------------------------- 5. timeline mechanics

print("== 5. timeline mechanics ==")
t = Torus([9])
b = build("trivance", "L", t)
plan = Plan(b.net, t)
m = 256 << 10
f0, e0 = simulate_flow(plan, m, P)
k0, _ = simulate_packet_batched(plan, m, P, 4096)

# epochs far after completion change nothing (flow pays two extra heap
# events; completion identical)
late = Timeline([(1e3, [("down", 0, True)]), (2e3, [("down", 0, False)])])
f1, _ = simulate_flow_dyn(plan, m, P, late)
k1, _ = simulate_packet_dyn(plan, m, P, 4096, late)
check("late epochs: flow completion unchanged", f1 == f0, f"{f1} vs {f0}")
check("late epochs: packet completion unchanged", k1 == k0, f"{k1} vs {k0}")

# no-op mutations (set a link to its existing class) are float-level no-ops
noop = Timeline([(1e-6, [("class", 0, 1.0, 1.0, 1.0)])])
f2, _ = simulate_flow_dyn(plan, m, P, noop)
k2, _ = simulate_packet_dyn(plan, m, P, 4096, noop)
check("no-op mutation: flow within 1e-12", abs(f2 - f0) <= f0 * 1e-12, f"{f2} vs {f0}")
check("no-op mutation: packet within 1e-12", abs(k2 - k0) <= k0 * 1e-12, f"{k2} vs {k0}")

# a used link down forever strands traffic: both engines must return the
# typed StrandedError (PR 6) carrying the blocked link — never a bogus
# completion, never a bare assert
used_link = plan.msgs[0][4][0]
dead = Timeline([(1e-7, [("down", used_link, True)])])
for name, fn in (
    ("flow", lambda: simulate_flow_dyn(plan, m, P, dead)),
    ("packet", lambda: simulate_packet_dyn(plan, m, P, 4096, dead)),
):
    try:
        fn()
        check(f"stranded traffic typed ({name})", False)
    except StrandedError as e:
        check(f"stranded traffic typed ({name})", e.link == used_link, f"link={e.link}")

print()
if FAILED:
    print(f"eval_dynamic: {len(FAILED)} FAILURES: {FAILED}")
    sys.exit(1)
print("dynamic eval: all asserted bounds hold")
