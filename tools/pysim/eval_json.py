"""Mirror of the util::json hardening properties (ISSUE 5 satellite).

Python's float parsing/printing implements the same IEEE-754
shortest-round-trip contract as Rust's, so the bit-exactness properties
asserted by `rust/src/util/json.rs`'s property tests are validated here
without a Rust toolchain:

  * random finite f64 bit patterns survive format -> parse bit-exactly
    (both positional and exponent notation);
  * -0.0 keeps its sign bit, the extreme normals/subnormals round-trip;
  * bare NaN/Infinity tokens are *rejected* (Python's json module accepts
    them by default — `parse_constant` raising mirrors the Rust reader's
    strictness), and deep nesting is bounded.
"""

import json
import math
import struct
import sys

FAILED = []


def check(name, ok, detail=""):
    print(f"[{'ok ' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        FAILED.append(name)


def bits(x):
    return struct.unpack("<Q", struct.pack("<d", x))[0]


# SplitMix64 — same generator as the Rust property test (seed included).
MASK = (1 << 64) - 1


def splitmix(seed):
    state = seed
    while True:
        state = (state + 0x9E3779B97F4A7C15) & MASK
        z = state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK
        yield z ^ (z >> 31)


rng = splitmix(0x150B0001)
checked = 0
bad = 0
for _ in range(500):
    v = struct.unpack("<d", struct.pack("<Q", next(rng)))[0]
    if not math.isfinite(v):
        continue
    checked += 1
    # Python's repr is the shortest round-trip form (same contract as the
    # Rust writers' `{}` formatter); exponent-notation round-trips are a
    # Rust-formatter property (`{:e}` there is shortest too) covered by the
    # Rust-side property test — Python's f"{v:e}" is fixed-6-digit and
    # cannot mirror it.
    if bits(float(repr(v))) != bits(v):
        bad += 1
    # exponent notation with explicitly sufficient digits must also be
    # bit-exact through the parser (17 significant digits always round-trip)
    if bits(float(f"{v:.17e}")) != bits(v):
        bad += 1
check("random finite floats round-trip bit-exactly", bad == 0, f"{checked} checked")

z = float("-0.0")
check("-0.0 keeps its sign bit", bits(float(repr(z))) == bits(z))
for v in (1.7976931348623157e308, 5e-324, -5e-324, 2.2250738585072014e-308):
    check(f"extreme magnitude {v!r} round-trips", bits(float(repr(v))) == bits(v))

# NaN / Infinity rejection (mirroring the Rust reader's strictness)
def reject_constant(name):
    raise ValueError(f"bare {name} is not JSON")


for doc in ("NaN", "Infinity", "-Infinity", "[1, NaN]", '{"a": -Infinity}'):
    try:
        json.loads(doc, parse_constant=reject_constant)
        check(f"reject {doc!r}", False)
    except ValueError:
        check(f"reject {doc!r}", True)

# depth contract mirror: the Rust reader caps nesting at 64, and every
# artifact this repo writes stays within it — a 64-deep document must
# parse; the cap itself (65+ rejected) is a Rust-side property the Rust
# unit tests pin (Python's json has no such cap, so only the in-contract
# side can be mirrored here)
v = json.loads("[" * 64 + "]" * 64)
depth = 0
while isinstance(v, list) and v:
    v = v[0]
    depth += 1
check("64-deep documents (the Rust reader's cap) parse", depth == 63 and v == [])

# Exact boundary mirror (ISSUE 7 satellite): replicate the Rust reader's
# depth accounting — parse_value(depth) errors when depth > MAX_DEPTH and
# containers recurse at depth + 1 — so the boundary itself is pinned in
# lockstep with rust/src/util/json.rs's
# nesting_depth_boundary_is_exact_and_error_is_targeted test: a scalar
# wrapped in exactly 64 brackets parses, 65 must raise the targeted error.
MAX_DEPTH = 64


def mirror_parse(doc):
    pos = [0]

    def ws():
        while pos[0] < len(doc) and doc[pos[0]] in " \t\n":
            pos[0] += 1

    def value(depth):
        if depth > MAX_DEPTH:
            raise ValueError(f"nesting deeper than {MAX_DEPTH}")
        ws()
        c = doc[pos[0]]
        if c == "[":
            pos[0] += 1
            ws()
            items = []
            if doc[pos[0]] == "]":
                pos[0] += 1
                return items
            items.append(value(depth + 1))
            ws()
            while doc[pos[0]] == ",":
                pos[0] += 1
                items.append(value(depth + 1))
                ws()
            assert doc[pos[0]] == "]"
            pos[0] += 1
            return items
        if c == "{":
            pos[0] += 1
            ws()
            obj = {}
            if doc[pos[0]] == "}":
                pos[0] += 1
                return obj
            while True:
                ws()
                assert doc[pos[0]] == '"'
                end = doc.index('"', pos[0] + 1)
                key = doc[pos[0] + 1:end]
                pos[0] = end + 1
                ws()
                assert doc[pos[0]] == ":"
                pos[0] += 1
                obj[key] = value(depth + 1)
                ws()
                if doc[pos[0]] != ",":
                    break
                pos[0] += 1
            assert doc[pos[0]] == "}"
            pos[0] += 1
            return obj
        start = pos[0]
        while pos[0] < len(doc) and doc[pos[0]] in "0123456789.eE+-":
            pos[0] += 1
        return float(doc[start:pos[0]])

    return value(0)


ok_doc = "[" * MAX_DEPTH + "1" + "]" * MAX_DEPTH
v = mirror_parse(ok_doc)
inner = v
levels = 0
while isinstance(inner, list):
    inner = inner[0]
    levels += 1
check("scalar at exactly MAX_DEPTH brackets parses (mirror)",
      levels == MAX_DEPTH and inner == 1.0)
check("mirror agrees with the stdlib on the in-contract document",
      v == json.loads(ok_doc))
try:
    mirror_parse("[" * (MAX_DEPTH + 1) + "1" + "]" * (MAX_DEPTH + 1))
    check("MAX_DEPTH+1 brackets rejected (mirror)", False)
except ValueError as e:
    check("MAX_DEPTH+1 brackets rejected (mirror)", "nesting deeper than" in str(e))
obj_ok = '{"k": ' * (MAX_DEPTH // 2) + "1" + "}" * (MAX_DEPTH // 2)
check("object nesting within the limit parses (mirror)",
      mirror_parse(obj_ok) == json.loads(obj_ok))
try:
    mirror_parse('{"k": ' * (MAX_DEPTH + 1) + "1" + "}" * (MAX_DEPTH + 1))
    check("MAX_DEPTH+1 objects rejected (mirror)", False)
except ValueError as e:
    check("MAX_DEPTH+1 objects rejected (mirror)", "nesting deeper than" in str(e))

print()
if FAILED:
    print(f"eval_json: {len(FAILED)} FAILURES: {FAILED}")
    sys.exit(1)
print("json eval: all asserted properties hold")
