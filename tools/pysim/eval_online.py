"""Online fault-response validation (ISSUE 6): controller, selector, fuzzer.

No rustc in this container, so the acceptance bounds of the online PR are
measured here against the mirror:

  1. the seeded two-fault sequence (cable death mid-collective, then a
     node death across the cable on rings / a far cable on 2D+) completes
     under the online controller in BOTH engines on ring-9 and 3x3 for
     trivance and bruck (ring bandwidth variants are the measured
     boundary: the dead endpoint's contribution is still unspread that
     late, the rewrite refuses, and the failure is typed); on the ring the
     rewrite response completes where detour-in-place partitions — the
     completion-vs-failure margin recorded per size bucket calibrates the
     Rust test online_two_fault_sequence_completes_in_both_engines and
     the `scenarios --online` sweep's headline;
  2. flow-vs-packet drift for multi-fault sequences (two-fault, and a
     directed-link fault followed by a late node death) stays within the
     bounds asserted by sim_crosscheck's
     fault_sequences_keep_flow_and_packet_within_measured_bounds;
  3. the tuned nearest-scenario selector: descriptor separation of
     transient vs permanent presets, rewrite-on-cable / detour-on-flap /
     detour-on-unmatched decisions, dead-node observation coverage, and
     the policy-driven response: on the ring it completes where blanket
     detour partitions and matches the per-event oracle; on 3x3 it
     completes (blanket detour is at parity or better there — recorded);
  4. the seeded timeline fuzzer, replaying rust/tests/timeline_fuzz.rs
     (same SplitMix64 seed 0x0F5A_2206 and draw order): both engines
     complete within FUZZ_TOL or fail with the same typed error — the
     measured worst drift pins FUZZ_TOL;
  5. stranding returns the typed StrandedError carrying the blocked link
     in both engines (never a bogus completion).
"""

import sys

from mirror import (
    DEFAULT_PARAMS as P,
    FaultEvent,
    NetModel,
    Plan,
    SplitMix64,
    StrandedError,
    Timeline,
    Torus,
    UnreachableError,
    build,
    features_dist,
    features_of_obs,
    link_at,
    obs_of_event,
    preset_obs,
    ref_horizon,
    respond,
    select,
    selector_policy,
    selector_rows,
    simulate_flow,
    simulate_flow_dyn,
    simulate_packet_batched,
    simulate_packet_dyn,
    step_time_estimates,
    two_fault_events,
    CANONICAL_SIZE,
)

FAILED = []


def check(name, ok, detail=""):
    print(f"[{'ok ' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        FAILED.append(name)


ONLINE_ALGOS = ["trivance", "bruck"]
VARIANTS = ["L", "B"]
SIZES = [4096, 64 << 10, 256 << 10, 1 << 20]


def completions(plan, m):
    f, _ = simulate_flow(plan, m, P)
    k, _ = simulate_packet_batched(plan, m, P, 4096)
    return f, k


def run_strategy(b, base, events, m, action):
    """Completion (flow, packet) under a blanket policy, or None when the
    response's plan cannot route (detour across a partition)."""
    resp = respond(b, base, events, m, P, lambda ev, step: action)
    try:
        plan = resp.build_plan(base)
    except UnreachableError:
        return None, resp
    try:
        return completions(plan, m), resp
    except (StrandedError, AssertionError):
        return None, resp


# ---------------- 1. two-fault acceptance + rewrite-vs-detour margins

print("== 1. seeded two-fault sequence: controller completes, margins ==")
best_margin = {}
for dims in ([9], [3, 3]):
    t = Torus(dims)
    base = NetModel.uniform(t)
    ring = t.ndims() == 1
    for algo in ONLINE_ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            m0 = 256 << 10
            ends = step_time_estimates(b.net, base, m0, P)
            events = two_fault_events(t, ends)
            check(f"two events {algo}-{variant} {dims}", len(events) == 2)
            resp = respond(b, base, events, m0, P, lambda ev, step: "rewrite")
            if ring and variant == "B":
                # measured boundary: a Reduce-Scatter-style ring schedule
                # still holds the dying endpoint's contribution unspread
                # this late — the rewrite refuses, the fallback detour
                # cannot route around a dead node, and the plan build
                # fails *typed*, never with a panic
                check(
                    f"ring-B boundary degrades to detour {algo} {dims}",
                    len(resp.actions) == 2 and resp.actions[1][1] == "detour",
                    f"actions={resp.actions}",
                )
                try:
                    plan = resp.build_plan(base)
                    completions(plan, m0)
                    check(f"ring-B boundary is typed {algo} {dims}", False)
                except (UnreachableError, StrandedError) as e:
                    check(
                        f"ring-B boundary is typed {algo} {dims}",
                        True,
                        f"{type(e).__name__}: {e}",
                    )
                continue
            check(
                f"rewrite policy applied {algo}-{variant} {dims}",
                len(resp.actions) == 2
                and all(a == "rewrite" for _, a in resp.actions),
                f"actions={resp.actions}",
            )
            plan = resp.build_plan(base)
            f, k = completions(plan, m0)
            check(
                f"completes both engines {algo}-{variant} {dims}",
                f > 0.0 and k > 0.0,
                f"flow={f:.3e} packet={k:.3e}",
            )
            for m in SIZES:
                ends_m = step_time_estimates(b.net, base, m, P)
                ev_m = two_fault_events(t, ends_m)
                rw, _ = run_strategy(b, base, ev_m, m, "rewrite")
                dt, _ = run_strategy(b, base, ev_m, m, "detour")
                if rw is None:
                    check(f"rewrite survives {algo}-{variant} {dims} m={m}", False)
                    continue
                if dt is None:
                    margin = None  # detour partitioned: rewrite wins outright
                else:
                    margin = dt[0] / rw[0] - 1.0
                key = (tuple(dims), algo, variant)
                cur = best_margin.get(key)
                if margin is None:
                    best_margin[key] = ("partition", m)
                elif cur is None or (cur[0] != "partition" and margin > cur[0]):
                    best_margin[key] = (margin, m)
                mtxt = "detour-partitioned" if margin is None else f"{margin:+.3f}"
                print(f"     {str(dims):>7} {algo}-{variant} m={m:>8}: detour/rewrite-1 = {mtxt}")

for key, (margin, m) in sorted(best_margin.items()):
    print(f"  best margin {key}: {margin} at m={m}")
# the acceptance bucket: on the ring the dead node partitions every detour
# plan, so the rewrite response completes where detour-in-place cannot —
# the strongest completion-vs-failure form of "beats detour". On 3x3 both
# complete and detour-in-place stays at parity or better (recorded above);
# the single-fault rewrite wins live on ring bucket-B in eval_dynamic.
check(
    "ring-9: rewrite completes where detour-in-place partitions (every size)",
    all(v[0] == "partition" for k, v in best_margin.items() if k[0] == (9,))
    and any(k[0] == (9,) for k in best_margin),
)
check(
    "3x3: both strategies complete on every bucket",
    all(v[0] != "partition" for k, v in best_margin.items() if k[0] == (3, 3))
    and any(k[0] == (3, 3) for k in best_margin),
)

# ---------------- 2. fault-sequence flow-vs-packet drift

print("== 2. multi-fault sequence flow-vs-packet drift ==")
worst_seq = {}
for dims in ([9], [3, 3]):
    t = Torus(dims)
    base = NetModel.uniform(t)
    ring = t.ndims() == 1
    for algo in ONLINE_ALGOS:
        for variant in VARIANTS:
            if ring and variant == "B":
                continue  # measured boundary (section 1): rewrite refuses
            b = build(algo, variant, t)
            if b is None:
                continue
            m = 256 << 10
            ends = step_time_estimates(b.net, base, m, P)
            last = ends[-1]
            l0 = t.link_index(0, 0, 1)
            # on the ring only a victim adjacent to the rewired link keeps
            # the survivors' path connected; mid-torus victims are fine on 2D
            victim = 1 if ring else t.n // 2
            link_then_node = [
                FaultEvent.link(0.5 * (ends[0] + ends[min(len(ends), 2) - 1]), l0),
                FaultEvent.node(0.9 * last, victim),
            ]
            for tag, events in (
                ("two-fault", two_fault_events(t, ends)),
                ("link+node", link_then_node),
            ):
                resp = respond(b, base, events, m, P, lambda ev, step: "rewrite")
                plan = resp.build_plan(base)
                f, k = completions(plan, m)
                rel = abs(f - k) / k
                key = tuple(dims)
                if rel > worst_seq.get(key, (0.0, None))[0]:
                    worst_seq[key] = (rel, f"{tag} {algo}-{variant}")
                print(f"     {tag:>9} {str(dims):>7} {algo}-{variant}: rel={rel:.4f}")
for key, (rel, tag) in sorted(worst_seq.items()):
    print(f"  worst sequence drift {key}: {rel:.4f} ({tag})")
check(
    "sequence drift bound (<0.10 both topologies) as pinned in sim_crosscheck",
    worst_seq.get((3, 3), (0.0,))[0] < 0.10 and worst_seq.get((9,), (0.0,))[0] < 0.10,
)

# ---------------- 3. selector descriptors + policy

print("== 3. nearest-scenario selector ==")
t33 = Torus([3, 3])
feats = [
    features_of_obs(
        t33,
        preset_obs(name, t33, P, CANONICAL_SIZE),
        ref_horizon(P, CANONICAL_SIZE),
    )
    for name in ("flap", "brownout", "mid-fault-detour", "mid-fault-rewrite")
]
check("flap transient + hard down", feats[0][3] == 0.0 and feats[0][1] == 0.0)
check(
    "brownout transient, soft, wider",
    feats[1][3] == 0.0 and abs(feats[1][1] - 0.25) < 1e-12 and feats[1][0] > feats[0][0],
)
check("mid-fault permanent + hard down", all(f[3] == 1.0 and f[1] == 0.0 for f in feats[2:]))
check("flap vs cable death far apart", features_dist(feats[0], feats[2]) > 0.9)
check("mid-fault strategies share features", features_dist(feats[2], feats[3]) < 1e-12)

rows = selector_rows(t33, P)
m = 256 << 10
ev = FaultEvent.cable(P["alpha"], t33, 0)
name, d, matched, action = select(rows, t33, obs_of_event(ev, t33), m, P)
check(
    "cable death -> matched mid-fault, rewrite",
    matched and name.startswith("mid-fault") and action == "rewrite",
    f"{name} d={d:.3f}",
)
from mirror import pick_links, FLAP_SEED

lf = pick_links(t33, 1, FLAP_SEED, keep_connected=False)[0]
ser = m * 8.0 / P["bw"]
flap_obs = [
    (P["alpha"] + 0.25 * ser, lf, 0.0),
    (P["alpha"] + 2.25 * ser, lf, 1.0),
]
name, d, matched, action = select(rows, t33, flap_obs, m, P)
check("flap -> matched flap, detour", matched and name == "flap" and action == "detour",
      f"{name} d={d:.3f}")
name, d, matched, action = select(rows, t33, [], m, P)
check("pristine -> unmatched, detour", not matched and action == "detour", f"d={d:.3f}")

t9 = Torus([9])
obs = obs_of_event(FaultEvent.node(1.0, 4), t9)
links = sorted({o[1] for o in obs})
check(
    "dead node covers all incident directed links",
    len(links) == 4 and all(o[2] == 0.0 for o in obs),
)

# policy-driven response on the seeded two-fault timeline. The dead-node
# hard rule forces rewrite on the ring's second event (a dead node is never
# detourable); the cable events go through the nearest-fingerprint match.
# Measured: on ring-9 the policy (detour the cable, rewrite the node)
# completes where blanket detour partitions AND matches the per-event
# oracle — in particular it is no slower than blanket rewrite. On 3x3 the
# first cable matches the mid-fault fingerprint (rewrite) while the second
# lands at 98% of the reference horizon — outside the match threshold — so
# the selector conservatively detours the tail; the response completes.
# Blanket detour happens to be faster there (recorded, not asserted
# against).
for dims in ([9], [3, 3]):
    t = Torus(dims)
    base = NetModel.uniform(t)
    ring = t.ndims() == 1
    rows_t = selector_rows(t, P)
    b = build("trivance", "L", t)
    m0 = 256 << 10
    ends = step_time_estimates(b.net, base, m0, P)
    events = two_fault_events(t, ends)
    resp = respond(b, base, events, m0, P, selector_policy(rows_t, t, m0, P))
    if ring:
        check(
            "policy: dead-node hard rule forces rewrite on ring",
            len(resp.actions) == 2 and resp.actions[1][1] == "rewrite",
            f"actions={resp.actions}",
        )
    else:
        check(
            "policy on 3x3: rewrite matched cable, detour unmatched tail fault",
            len(resp.actions) == 2
            and resp.actions[0][1] == "rewrite"
            and resp.actions[1][1] == "detour",
            f"actions={resp.actions}",
        )
    pol_c = completions(resp.build_plan(base), m0)[0]
    check(f"policy completes {dims}", pol_c > 0.0, f"policy={pol_c:.3e}")
    dt, _ = run_strategy(b, base, events, m0, "detour")
    rw, _ = run_strategy(b, base, events, m0, "rewrite")
    if ring:
        check(
            "policy beats blanket detour on ring (completion vs partition)",
            dt is None,
        )
        check(
            "policy no slower than blanket rewrite on ring",
            rw is not None and pol_c <= rw[0] * (1.0 + 1e-9),
            f"policy={pol_c:.3e} rewrite={'partitioned' if rw is None else f'{rw[0]:.3e}'}",
        )
    else:
        dtxt = "partitioned" if dt is None else f"{dt[0]:.3e}"
        print(f"  3x3 policy={pol_c:.3e} vs blanket detour={dtxt} (informational)")

# ---------------- 4. seeded fuzz replication (lockstep with timeline_fuzz.rs)

print("== 4. fuzzed timelines (seed 0x0F5A_2206, 40 cases) ==")
FUZZ_ALGOS = ["trivance", "bruck", "bucket"]


def rng_range(rng, lo, hi):
    return lo + rng.below(hi - lo + 1)


def rng_f64(rng):
    return (rng.next_u64() >> 11) / float(1 << 53)


def rng_choose(rng, xs):
    return xs[rng.below(len(xs))]


rng = SplitMix64(0x0F5A_2206)
worst_fuzz = (0.0, None)
outcome_mismatch = 0
for case in range(40):
    dims = rng_choose(rng, [[9], [3, 3]])
    t = Torus(dims)
    algo = rng_choose(rng, FUZZ_ALGOS)
    variant = rng_choose(rng, VARIANTS)
    m = rng_choose(rng, [4096, 256 << 10])
    n_ev = rng_range(rng, 1, 3)
    evs = []
    for _ in range(n_ev):
        link = rng_range(rng, 0, t.num_links() - 1)
        kind = rng_range(rng, 0, 2)
        if kind == 0:
            evs.append(("down", link))
        elif kind == 1:
            at = 0.8 * rng_f64(rng)
            evs.append(("flap", link, at, at + 0.05 + 0.4 * rng_f64(rng)))
        else:
            evs.append(("brown", link, 0.8 * rng_f64(rng), 2.0 + 6.0 * rng_f64(rng)))
    b = build(algo, variant, t)
    if b is None:
        continue
    plan = Plan(b.net, t)
    horizon = simulate_flow(plan, m, P)[0]
    epochs = []
    for e in evs:
        if e[0] == "down":
            epochs.append((0.0, [("down", e[1], True)]))
        elif e[0] == "flap":
            epochs.append((e[2] * horizon, [("down", e[1], True)]))
            epochs.append((e[3] * horizon, [("down", e[1], False)]))
        else:
            epochs.append((e[2] * horizon, [("class", e[1], 1.0 / e[3], 1.0, 1.0)]))
    tl = Timeline(epochs)

    def run(engine):
        try:
            if engine == "flow":
                return ("ok", simulate_flow_dyn(plan, m, P, tl)[0])
            return ("ok", simulate_packet_dyn(plan, m, P, 4096, tl)[0])
        except StrandedError:
            return ("stranded", None)
        except UnreachableError:
            return ("unroutable", None)

    fo = run("flow")
    ko = run("packet")
    if fo[0] != ko[0]:
        outcome_mismatch += 1
        print(f"  OUTCOME MISMATCH case {case}: flow={fo[0]} packet={ko[0]} "
              f"({algo}-{variant} {dims} m={m} evs={evs})")
    elif fo[0] == "ok":
        rel = abs(fo[1] - ko[1]) / ko[1]
        if rel > worst_fuzz[0]:
            worst_fuzz = (rel, f"case {case}: {algo}-{variant} {dims} m={m} evs={evs}")
check("fuzz: engines always agree on outcome class", outcome_mismatch == 0)
print(f"  worst fuzz drift: {worst_fuzz[0]:.4f} ({worst_fuzz[1]})")
check("fuzz drift within FUZZ_TOL=0.20 (pinned in timeline_fuzz.rs)", worst_fuzz[0] < 0.20)

# ---------------- 5. stranding is typed in both engines

print("== 5. typed stranding ==")
t = Torus([9])
b = build("bucket", "B", t)
plan = Plan(b.net, t)
link = plan.msgs[0][4][0]
tl = Timeline([(0.0, [("down", link, True)])])
for name, fn in (
    ("flow", lambda: simulate_flow_dyn(plan, 4096, P, tl)),
    ("packet", lambda: simulate_packet_dyn(plan, 4096, P, 4096, tl)),
):
    try:
        fn()
        check(f"stranded typed ({name})", False)
    except StrandedError as e:
        check(f"stranded typed ({name})", e.link == link, f"link={e.link} step={e.step}")

print()
if FAILED:
    print(f"eval_online: {len(FAILED)} FAILURES: {FAILED}")
    sys.exit(1)
print("online eval: all asserted bounds hold")
