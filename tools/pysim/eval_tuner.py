"""Tuner (decision-table + workload replay) validation — the
toolchain-less protocol for the tuner PR, same role eval_netmodel.py
played for the NetModel PR. Runtime ~6 minutes (the 8x8 scenario sweeps
dominate; straggler/faulty fabrics disable the flow fast path).

Asserted bounds (measured 2026-07 in this container; the Rust tuner tests
pin the same semantics on the small topologies, and `trivance replay`
reports the same accounting):

1. `ladder_index` is the exact nearest-in-log-space index into the 32*2^k
   tune ladder (integer midpoint arithmetic, O(1)), and maps every ladder
   point to itself.
2. Trace generators are deterministic (SplitMix64, fixed per-trace seeds),
   clamp to the requested cap, and keep the distinct-size set small enough
   to replay exactly (<= 3 sizes per mix row).
3. Distilled winners at ladder sizes agree with a fresh per-size sweep
   (first-minimum tie-breaks, matching Rust's min_by).
4. Replay acceptance (ring-8, ring-9, and the replay default 8x8; every
   built-in trace x scenario preset): the table-driven policy lands within
   5% of the per-call oracle (measured worst 0.94%, ring-9
   tensor-parallel), and on the mixed trace it beats every fixed-algorithm
   policy strictly (worst margin on 8x8: bucket +14.3% vs table +0.0%,
   straggler).
"""

import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


# --- 1. ladder_index: exact nearest-in-log-space, O(1) ---
print("== ladder_index ==")
ladder = tune_ladder(128 << 20)
chk("ladder shape", ladder[0] == 32 and ladder[-1] == 128 << 20 and len(ladder) == 23)
chk(
    "ladder points map to themselves",
    all(ladder_index(m, len(ladder)) == i for i, m in enumerate(ladder)),
)
# geometric midpoints: 32*2^k*sqrt(2) — below rounds down, above rounds up
import math

ok = True
for k in range(len(ladder) - 1):
    mid = ladder[k] * math.sqrt(2.0)
    lo, hi = int(math.floor(mid)), int(math.ceil(mid))
    if ladder_index(lo, len(ladder)) != k or ladder_index(hi, len(ladder)) != k + 1:
        ok = False
chk("midpoint boundaries exact", ok)
chk("clamps", ladder_index(0, 5) == 0 and ladder_index(1 << 62, 5) == 4)

# --- 2. trace generators ---
print("== trace generators ==")
for name in TRACE_NAMES:
    a = gen_trace(name, 160, 128 << 20)
    b = gen_trace(name, 160, 128 << 20)
    chk(f"{name} deterministic", a == b)
    chk(f"{name} in range", all(1 <= s <= 128 << 20 for s in a))
    chk(
        f"{name} distinct bounded",
        len(set(a)) <= 3 * len(TRACE_MIX[name]),
        f"{len(set(a))} distinct",
    )
    capped = gen_trace(name, 160, 256 << 10)
    chk(f"{name} cap respected", max(capped) <= 256 << 10)
mixed = gen_trace("mixed", 160, 128 << 20)
chk("mixed spans both regimes", min(mixed) <= 1024 and max(mixed) >= 8 << 20)

# --- 3. distilled winners == fresh sweep winners ---
print("== distillation vs fresh sweep ==")
for dims in [[9], [3, 3]]:
    t = Torus(dims)
    lad = tune_ladder(4 << 20)
    for sc in SCENARIO_NAMES:
        model = scenario_model(sc, t)
        wins = distill_winners(t, model, lad, P)
        built = build_variant_plans(t, model)
        fresh = [winner_at(built, m, P)[:2] for m in lad]
        chk(f"winners {dims} {sc}", wins == fresh)

# --- 4. replay acceptance ---
print("== replay acceptance (<=5% regret; mixed beats every fixed) ==")
worst_regret = (0.0, "")
for dims in [[8], [9], [8, 8]]:
    t = Torus(dims)
    lad = tune_ladder(128 << 20)
    winners = {}
    for sc in SCENARIO_NAMES:
        winners[sc] = distill_winners(t, scenario_model(sc, t), lad, P)
    for trace in TRACE_NAMES:
        sizes = gen_trace(trace, 160, 128 << 20)
        for sc in SCENARIO_NAMES:
            totals = replay_totals(
                t, scenario_model(sc, t), sizes, winners[sc], lad, P
            )
            oracle = totals["oracle"]
            regret = totals["table"] / oracle - 1.0
            if regret > worst_regret[0]:
                worst_regret = (regret, f"{dims} {trace} {sc}")
            chk(
                f"regret {dims} {trace} {sc}",
                regret <= 0.05,
                f"table +{regret * 100:.2f}% vs oracle",
            )
            if trace == "mixed":
                fixed = {k[6:]: v for k, v in totals.items() if k.startswith("fixed:")}
                beaten = all(totals["table"] < v for v in fixed.values())
                margin = min(v / oracle - 1.0 for v in fixed.values())
                chk(
                    f"mixed strict-beat {dims} {sc}",
                    beaten,
                    f"best fixed +{margin * 100:.2f}%",
                )
print(f"worst table regret: +{worst_regret[0] * 100:.2f}% ({worst_regret[1]})")

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("tuner eval: all asserted bounds hold")
