"""Verify the symmetric-step fast path is bit-identical to generic
water-filling across the registry, and that batched-engine event counts are
message-size independent."""

import struct
import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
import mirror
from mirror import *  # noqa

P = DEFAULT_PARAMS
fails = []


def bits(x):
    return struct.pack("<d", x)


# --- monkey-patch a fast-path variant of the flow recompute ---
def simulate_flow_fast(plan, m_bytes, params):
    """Same as mirror.simulate_flow but with the closed-form uniform-split
    short-circuit (mirrors rust/src/sim/flow.rs WaterFill::recompute)."""
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0
    cap = params["bw"] / 8.0
    ph = per_hop(params)
    symmetric_ok = all(len(m[4]) > 0 for m in plan.msgs)

    import heapq

    received = [0] * (n * nsteps)
    entered = [-1] * n
    heap = []
    seq = 0

    def push(t, ev):
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, seq, ev))

    for r in range(n):
        push(params["alpha"], ("step", r, 0))

    active = []
    nactive = [0] * plan.num_links
    touched = []
    in_touched = [False] * plan.num_links
    residual = [0.0] * plan.num_links
    unfrozen = [0] * plan.num_links
    now = 0.0
    completion = 0.0
    events = 0
    need_recompute = False

    def wf_inject(route):
        for l in route:
            if not in_touched[l]:
                in_touched[l] = True
                touched.append(l)
            nactive[l] += 1

    def wf_drain(route):
        for l in route:
            nactive[l] -= 1

    def recompute():
        nonlocal touched
        keep = []
        for l in touched:
            if nactive[l] == 0:
                in_touched[l] = False
            else:
                residual[l] = cap
                unfrozen[l] = nactive[l]
                keep.append(l)
        touched = keep

        # fast path
        if symmetric_ok and touched:
            c = nactive[touched[0]]
            if all(nactive[l] == c for l in touched):
                share = cap / c
                for f in active:
                    f[2] = share
                return

        unfrozen_flows = list(range(len(active)))
        while unfrozen_flows:
            min_share = float("inf")
            for l in touched:
                if unfrozen[l] > 0:
                    share = residual[l] / unfrozen[l]
                    if share < min_share:
                        min_share = share
            if min_share == float("inf"):
                for fi in unfrozen_flows:
                    active[fi][2] = cap
                break
            freeze = []
            i = 0
            while i < len(unfrozen_flows):
                fi = unfrozen_flows[i]
                share = float("inf")
                for l in plan.msgs[active[fi][0]][4]:
                    s = residual[l] / max(unfrozen[l], 1)
                    if s < share:
                        share = s
                if share <= min_share * (1.0 + SHARE_EPS):
                    freeze.append(fi)
                    unfrozen_flows[i] = unfrozen_flows[-1]
                    unfrozen_flows.pop()
                else:
                    i += 1
            if not freeze:
                for fi in unfrozen_flows:
                    active[fi][2] = min_share
                break
            for fi in freeze:
                active[fi][2] = min_share
                for l in plan.msgs[active[fi][0]][4]:
                    residual[l] -= min_share
                    if residual[l] < 0.0:
                        residual[l] = 0.0
                    unfrozen[l] -= 1

    while True:
        t_event = heap[0][0] if heap else float("inf")
        t_drain = float("inf")
        for f in active:
            if f[2] > 0.0:
                t = now + f[1] / f[2]
                if t < t_drain:
                    t_drain = t
        t_next = min(t_event, t_drain)
        if t_next == float("inf"):
            break
        dt = t_next - now
        if dt > 0.0:
            for f in active:
                f[1] -= f[2] * dt
        now = t_next

        i = 0
        while i < len(active):
            f = active[i]
            if f[1] <= f[2] * TIME_EPS + 1e-9 * TIME_EPS or f[1] <= 1e-7:
                active[i] = active[-1]
                active.pop()
                src, dst, k, rel, route = plan.msgs[f[0]]
                wf_drain(route)
                push(now + len(route) * ph, ("deliv", dst, k))
                need_recompute = True
            else:
                i += 1

        while heap and heap[0][0] <= now + max(TIME_EPS, now * 1e-12):
            _, _, ev = heapq.heappop(heap)
            events += 1
            if ev[0] == "step":
                _, node, step = ev
                entered[node] = step
                for mi in plan.injections(node, step):
                    active.append([mi, plan.bytes(mi, m_bytes), 0.0])
                    wf_inject(plan.msgs[mi][4])
                    need_recompute = True
                if (
                    plan.expected_count(node, step) == received[node * nsteps + step]
                    and step + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, step + 1))
            else:
                _, node, k = ev
                completion = max(completion, now)
                received[node * nsteps + k] += 1
                if (
                    received[node * nsteps + k] == plan.expected_count(node, k)
                    and entered[node] == k
                    and k + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, k + 1))

        if need_recompute:
            recompute()
            need_recompute = False

    return completion, events


print("== fast path vs generic water-filling: bitwise comparison ==")
worst = None
for dims in [[8], [9], [27], [3, 3], [8, 8], [4, 4, 4]]:
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t)
            for m in [32, 4096, 256 << 10, 1 << 20]:
                a, ae = simulate_flow(plan, m, P)
                f, fe = simulate_flow_fast(plan, m, P)
                same = bits(a) == bits(f) and ae == fe
                if not same:
                    fails.append((dims, algo, variant, m))
                    print(f"[FAIL] {dims} {algo}-{variant} m={m}: {a} vs {f}")
print(f"checked; {len(fails)} mismatches")

print("\n== batched engine: event count is message-size independent ==")
for dims in [[9], [8, 8]]:
    t = Torus(dims)
    b = build("trivance", "L", t)
    plan = Plan(b.net, t)
    counts = set()
    for m in [4096, 1 << 20, 8 << 20]:
        _, e = simulate_packet_batched(plan, m, P, 4096)
        counts.add(e)
    print(f"{dims}: events {counts}")
    if len(counts) != 1:
        fails.append(("events", dims))

if fails:
    print(f"\n{len(fails)} FAILURES")
    sys.exit(1)
print("\nfast-path bit-identity and event invariance verified")
