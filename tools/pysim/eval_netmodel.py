"""NetModel (heterogeneous per-link network) validation — the
toolchain-less protocol for the NetModel PR, same role eval_batched.py
played for the packet-engine overhaul.

Asserted bounds (measured 2026-07 in this container; the Rust tests pin the
same semantics, so these are the numbers the Rust suite is expected to
reproduce):

1. A uniform NetModel is **bit-identical** to the model-less path for every
   engine (flow / batched packet / reference packet) across the registry.
2. Straggler monotonicity: slowing any used link x4 never decreases the
   flow completion on non-padded configurations (padded configurations are
   allowed a <0.1% fluid artifact — recdoub-B on ring-9 measures -0.074%).
3. Faulty-link reroute: with 1-2 down links ([3,3] k=1,2; [4,4] k=1), every
   route avoids the down links and flow-vs-batched-packet drift stays <10%
   (measured worst 0.069).
4. Hetero-dims: flow-vs-packet drift <6% on per-dimension bandwidth ratios
   (measured worst 0.035).
5. Batched-vs-reference drift under hetero models stays <15% (measured
   worst 0.113, swing-L ring-8 straggler; uniform bound remains the 6% of
   eval_batched.py).
"""

import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
STRAGGLER_SEED = 0x5EED0001
FAULTY_SEED = 0x5EED0002
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


# --- 1. uniform NetModel is bit-identical to the model-less path ---
print("== uniform NetModel bit-identity ==")
for dims in [[9], [3, 3]]:
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            base = Plan(b.net, t)
            um = Plan(b.net, t, NetModel.uniform(t))
            for m in [4096, 256 << 10]:
                for name, run in [
                    ("flow", lambda p: simulate_flow(p, m, P)),
                    ("batched", lambda p: simulate_packet_batched(p, m, P, 4096)),
                    ("ref", lambda p: simulate_packet_ref(p, m, P, 4096)),
                ]:
                    a, ae = run(base)
                    c, ce = run(um)
                    chk(
                        f"uniform {dims} {algo}-{variant} {name} m={m}",
                        a == c and ae == ce,
                        f"{a} vs {c}",
                    )

# --- 2. straggler monotonicity ---
print("== straggler monotonicity (each used link x4) ==")
for dims in [[9], [3, 3]]:
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            base_plan = Plan(b.net, t)
            used = sorted({l for msg in base_plan.msgs for l in msg[4]})
            tol = 1e-3 if b.padded else 1e-12
            for m in [4096, 256 << 10]:
                f0, _ = simulate_flow(base_plan, m, P)
                worst = 0.0
                for l in used:
                    mdl = NetModel.uniform(t)
                    mdl.bw_scale[l] = 0.25
                    f1, _ = simulate_flow(Plan(b.net, t, mdl), m, P)
                    worst = min(worst, (f1 - f0) / f0)
                chk(
                    f"monotone {dims} {algo}-{variant} m={m} (padded={b.padded})",
                    worst >= -tol,
                    f"worst decrease {worst:.2e}",
                )

# --- 3. faulty-link reroute ---
print("== faulty reroute: routes avoid down links, flow-vs-packet <10% ==")
for dims, ks in [([3, 3], [1, 2]), ([4, 4], [1])]:
    t = Torus(dims)
    for k in ks:
        mdl = NetModel.faulty(t, k, FAULTY_SEED)
        chk(f"faulty {dims} k={k} connected", strongly_connected(t, mdl.down))
        for algo in ALGOS:
            for variant in VARIANTS:
                b = build(algo, variant, t)
                if b is None:
                    continue
                plan = Plan(b.net, t, mdl)
                clean = not any(mdl.down[l] for msg in plan.msgs for l in msg[4])
                chk(f"faulty {dims} k={k} {algo}-{variant} routes clean", clean)
                for m in [4096, 256 << 10]:
                    f, _ = simulate_flow(plan, m, P)
                    p, _ = simulate_packet_batched(plan, m, P, 4096)
                    rel = abs(f - p) / p
                    chk(
                        f"faulty {dims} k={k} {algo}-{variant} m={m}",
                        rel < 0.10,
                        f"rel={rel:.4f}",
                    )

# --- 4. hetero-dims flow-vs-packet ---
print("== hetero-dims flow-vs-packet <6% ==")
for dims, scales in [([3, 3], [1.0, 0.5]), ([4, 4], [1.0, 0.5]), ([3, 3, 3], [1.0, 0.5, 0.25])]:
    t = Torus(dims)
    mdl = NetModel.hetero_dims(t, scales)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t, mdl)
            for m in [4096, 256 << 10]:
                f, _ = simulate_flow(plan, m, P)
                p, _ = simulate_packet_batched(plan, m, P, 4096)
                rel = abs(f - p) / p
                chk(
                    f"hetero {dims} {algo}-{variant} m={m}",
                    rel < 0.06,
                    f"rel={rel:.4f}",
                )

# --- 5. batched vs reference under hetero models ---
print("== batched-vs-reference hetero drift <15% ==")
worst = 0.0
for dims in [[9], [8], [3, 3]]:
    t = Torus(dims)
    models = [
        ("straggler1", NetModel.straggler(t, 1, 4.0, STRAGGLER_SEED)),
        ("faulty1", NetModel.faulty(t, 1, FAULTY_SEED)),
    ]
    for name, mdl in models:
        for algo in ALGOS:
            for variant in VARIANTS:
                b = build(algo, variant, t)
                if b is None:
                    continue
                plan = Plan(b.net, t, mdl)
                for m in [4096, 256 << 10]:
                    a, _ = simulate_packet_batched(plan, m, P, 4096)
                    r, _ = simulate_packet_ref(plan, m, P, 4096)
                    rel = abs(a - r) / r
                    worst = max(worst, rel)
                    chk(
                        f"drift {dims} {name} {algo}-{variant} m={m}",
                        rel < 0.15,
                        f"rel={rel:.4f}",
                    )
print(f"worst batched-vs-reference hetero drift: {worst:.4f}")

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("netmodel eval: all asserted bounds hold")
