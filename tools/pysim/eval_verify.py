"""Static-verification parity harness (ISSUE 7 satellite).

Replicates rust/src/verify/ through the mirror's dataflow lattice,
port-budget audit, congestion sums and mutation corruptors, and pins the
registry certificates the Rust test suite (rust/tests/verify_static.rs)
asserts — this container has no rustc, so these are the measurements the
Rust constants were pinned from:

  * full registry certification (dataflow proof on the exec schedule,
    port legality and congestion/optimality on the net schedule) on
    ring-8, ring-9, ring-27 and the 3x3 torus;
  * the pinned ring congestion table — Trivance-L tx_delay exactly one
    third of unidirectional Bruck (4/12, 4/12, 13/39) and below
    bidirectional Bruck (6, 6, 21);
  * latency classification: Trivance-L at exactly sum(ceil_log3(a_d))
    steps on every acceptance topology (congestion/optimality-only on
    8x8 and 4x4x4 — the padded 729-virtual-rank dataflow proof is
    covered by the Rust side, where it is cheap);
  * bandwidth classification: bucket-B meets 2(n-1)/n everywhere,
    trivance-B exactly on the power-of-three topologies;
  * the seeded mutation suite (drop/swap/dup/shift) kills 100% of
    mutants on ring-8, ring-9 and 3x3 native builds.
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from mirror import (Torus, build, ceil_log, certify_registry,  # noqa: E402
                    audit_congestion, audit_optimality, run_mutation_suite)

FAILED = []


def check(name, ok, detail=""):
    print(f"[{'ok ' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        FAILED.append(name)


# ── full registry certification + pinned ring congestion ─────────────────
PINNED_RING_TX = {8: (4.0, 6.0, 12.0), 9: (4.0, 6.0, 12.0),
                  27: (13.0, 21.0, 39.0)}

for dims in ([8], [9], [27], [3, 3]):
    t = Torus(dims)
    certs = certify_registry(t)  # raises on any defect / broken gate
    check(f"registry certifies on {dims}", len(certs) >= 8,
          f"{len(certs)} collectives")
    tri = certs[("trivance", "L")]
    lat3 = sum(ceil_log(3, a) for a in t.dims)
    check(f"{dims}: trivance-L steps == ceil_log3 bound",
          tri["optimality"]["steps"] == lat3,
          f"{tri['optimality']['steps']} vs {lat3}")
    check(f"{dims}: trivance-L one message per port",
          tri["max_port_msgs"] == 1)
    check(f"{dims}: trivance-L classified latency-optimal",
          tri["optimality"]["klass"] == "latency-optimal")
    if t.ndims() == 1:
        want_tri, want_bid, want_uni = PINNED_RING_TX[t.n]
        tx = tri["congestion"]["tx_delay_rel"]
        uni = certs[("bruck-unidir", "L")]["congestion"]["tx_delay_rel"]
        bid = certs[("bruck", "L")]["congestion"]["tx_delay_rel"]
        check(f"ring-{t.n}: pinned tx (tri {want_tri}, bruck {want_bid}, "
              f"uni {want_uni})",
              abs(tx - want_tri) < 1e-9 and abs(bid - want_bid) < 1e-9
              and abs(uni - want_uni) < 1e-9,
              f"got {tx}/{bid}/{uni}")
        check(f"ring-{t.n}: trivance-L exactly one third of "
              "unidirectional Bruck", abs(tx - uni / 3.0) < 1e-9)

# ── congestion/optimality-only sweep on the large acceptance topologies ──
for dims, lat3_want in ([[8, 8], 4], [[4, 4, 4], 6]):
    t = Torus(dims)
    b = build("trivance", "L", t)
    opt = audit_optimality(b.net, t)
    check(f"{dims}: trivance-L steps == ceil_log3 bound",
          opt["steps"] == lat3_want == opt["lat_bound3"],
          f"{opt['steps']} vs {lat3_want}")
    check(f"{dims}: trivance-L classified latency-optimal",
          opt["klass"] == "latency-optimal")
    cong = audit_congestion(b.net, t)
    check(f"{dims}: trivance-L congestion audit is finite and loaded",
          cong["tx_delay_rel"] > 0 and cong["messages"] > 0)

# ── bandwidth classification vs the paper tables ─────────────────────────
TRI_B_OPTIMAL = {(8,): False, (9,): True, (27,): True, (3, 3): True,
                 (8, 8): False, (4, 4, 4): False}
for dims, want in TRI_B_OPTIMAL.items():
    t = Torus(list(dims))
    bucket = build("bucket", "B", t)
    ob = audit_optimality(bucket.net, t)
    check(f"{list(dims)}: bucket-B bandwidth-optimal",
          ob["bandwidth_optimal"],
          f"sent {ob['max_node_sent_rel']:.4f} vs {ob['bw_lower_rel']:.4f}")
    tri = build("trivance", "B", t)
    ot = audit_optimality(tri.net, t)
    check(f"{list(dims)}: trivance-B bandwidth-optimal == {want}",
          ot["bandwidth_optimal"] == want,
          f"sent {ot['max_node_sent_rel']:.4f} vs {ot['bw_lower_rel']:.4f}")

# ── mutation suite: the verifier must kill every seeded corruption ───────
topos = [Torus([8]), Torus([9]), Torus([3, 3])]
total, killed, survivors = run_mutation_suite(topos, 0xC0FFEE07, 8)
check("mutation suite is large enough", total >= 100, f"{total} mutants")
check("mutation suite kills 100%", killed == total and not survivors,
      f"{killed}/{total}" + (f" survivors: {survivors[:3]}"
                             if survivors else ""))

print()
if FAILED:
    print(f"eval_verify: {len(FAILED)} FAILURES: {FAILED}")
    sys.exit(1)
print("verify eval: all pinned certificates and the mutation gate hold")
