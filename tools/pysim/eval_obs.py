"""Observability evals (ISSUE 9), mirrored from rust/src/obs/ and the
packet engine's telemetry hooks — the container has no rustc, so this is
the numeric validation of the same invariants rust/tests/obs.rs pins:

1. sink-off bit identity: running the packet engine with a telemetry sink
   attached returns byte-for-byte the same completion, event count, and
   queue stats as running without one (the NoopSink contract) — static and
   dynamic, both queue kinds;
2. telemetry physics: per-link busy intervals are forward, disjoint per
   link within a simulation, achieved bandwidth never exceeds the pristine
   capacity (1e-9 relative), and there is exactly one row per message-hop;
3. congestion signal: under the brownout preset the achieved/cap ratio —
   the tuner::online observation stream (obs_of_samples) — drops on the
   throttled links while every ratio stays in (0, 1];
4. schema parity: the mirror's telemetry rows carry exactly the LinkSample
   keys that rust exports into TRACE.json's `link_telemetry`, asserted
   against tools/check_trace.py's ROW_KEYS so the validator, the rust
   exporter, and the mirror can never drift apart silently.
"""

import importlib.util
import os
import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


def _load_check_trace():
    path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "check_trace.py")
    spec = importlib.util.spec_from_file_location("check_trace", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


check_trace = _load_check_trace()

# --- 1. sink-off bit identity ---
print("== telemetry sink is invisible to the engines (bit identity) ==")
mismatches = 0
cells = 0
for dims in [[9], [3, 3]]:
    t = Torus(dims)
    for algo in ("trivance", "bucket"):
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t)
            for m in [4096, 256 << 10]:
                for kind in ("heap", "calendar"):
                    rows = []
                    bare = simulate_packet_batched_stats(plan, m, P, 4096, kind)
                    sunk = simulate_packet_batched_stats(plan, m, P, 4096, kind, sink=rows)
                    cells += 1
                    if bare != sunk or not rows:
                        mismatches += 1
                        print(f"  MISMATCH static {dims} {algo}-{variant} m={m} {kind}")
                    for name in ("flap", "brownout"):
                        tl = dynamic_timeline(name, t, P, m)
                        rows_d = []
                        bare_d = simulate_packet_dyn_stats(plan, m, P, 4096, tl, kind)
                        sunk_d = simulate_packet_dyn_stats(plan, m, P, 4096, tl, kind, sink=rows_d)
                        cells += 1
                        if bare_d != sunk_d or not rows_d:
                            mismatches += 1
                            print(f"  MISMATCH {name} {dims} {algo}-{variant} m={m} {kind}")
chk(f"sink on/off bit-identical ({cells} cells)", mismatches == 0)

# --- 2. telemetry physics on one static simulation ---
print("\n== per-link busy-interval telemetry (static 3x3 trivance-L) ==")
t33 = Torus([3, 3])
b33 = build("trivance", "L", t33)
plan33 = Plan(b33.net, t33)
rows = []
simulate_packet_batched_stats(plan33, 64 << 10, P, 4096, "calendar", sink=rows)
chk("telemetry rows emitted", len(rows) > 0, f"{len(rows)} rows")
expected_rows = sum(len(msg[4]) for msg in plan33.msgs)
chk("exactly one row per message-hop", len(rows) == expected_rows, f"expect {expected_rows}")

REL_TOL = 1e-9
bad_phys = 0
for r in rows:
    achieved = r["bytes"] / (r["end_s"] - r["start_s"])
    if not (
        0 <= r["link"] < plan33.num_links
        and r["end_s"] > r["start_s"]
        and r["bytes"] > 0
        and achieved <= r["cap_bytes_per_s"] * (1 + REL_TOL)
        and r["queue_len"] >= 0
    ):
        bad_phys += 1
chk("rows are forward intervals with achieved <= cap (1e-9)", bad_phys == 0)

by_link = {}
for r in rows:
    by_link.setdefault(r["link"], []).append((r["start_s"], r["end_s"]))
overlaps = 0
for l, iv in by_link.items():
    iv.sort()
    for (s0, e0), (s1, e1) in zip(iv, iv[1:]):
        if s1 < e0 - 1e-12:
            overlaps += 1
chk("per-link busy intervals are disjoint within a simulation", overlaps == 0)

# --- 3. brownout shows up in the achieved/cap observation stream ---
print("\n== brownout congestion signal (tuner observation stream) ==")
tl = dynamic_timeline("brownout", t33, P, 64 << 10)
rows_b = []
simulate_packet_dyn_stats(plan33, 64 << 10, P, 4096, tl, "calendar", sink=rows_b)
# mirror of tuner::online::obs_of_samples: (t, link, achieved/cap clamped)
stream = [
    (r["start_s"], r["link"], min(max(r["bytes"] / (r["end_s"] - r["start_s"]) / r["cap_bytes_per_s"], 0.0), 1.0))
    for r in rows_b
    if r["end_s"] > r["start_s"] and r["cap_bytes_per_s"] > 0
]
chk("observation stream non-empty", len(stream) > 0, f"{len(stream)} observations")
chk("all cap ratios in (0, 1]", all(0.0 < ratio <= 1.0 for _, _, ratio in stream))
degraded = [ratio for _, _, ratio in stream if ratio < 0.9]
chk(
    "brownout degrades achieved/cap on throttled links",
    len(degraded) > 0,
    f"{len(degraded)}/{len(stream)} rows below 0.9, min {min(r for _, _, r in stream):.3f}",
)

# --- 4. schema parity with the rust exporter / trace validator ---
print("\n== telemetry schema parity with tools/check_trace.py ==")
keys = set(rows[0])
chk(
    "mirror rows carry exactly the LinkSample keys",
    keys == check_trace.ROW_KEYS,
    f"{sorted(keys)}",
)
chk(
    "check_trace validator accepts the mirror's telemetry rows",
    check_trace.check_telemetry(rows) == [],
)

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("obs eval: telemetry is invisible, physical, and schema-locked")
