"""Hot-path engine overhaul evals: the calendar event queue vs the binary
heap, mirrored from rust/src/sim/events.rs.

1. queue micro-checks mirroring the events.rs unit tests (interleaved
   push/pop agreement, day-rollover (t, seq) order, grow/shrink cycles,
   zero-span FIFO bursts);
2. heap-vs-calendar bit identity across the full registry, static plans
   (ring9/27, 3x3, 8x8, 4x4x4 at 4 KiB / 256 KiB / 1 MiB);
3. the same identity under dynamic timelines (flap / brownout presets,
   StrandedError symmetric);
4. op-count report for the BENCH_core workload (trivance-B 8x8, 1 MiB,
   mtu 4096): pushes/pops/peak and calendar resizes + entries scanned
   per pop (the O(1)-amortized claim's basis);
5. with --emit-baseline PATH: write the pysim-provenance BENCH_core.json
   (schema trivance.bench_core.v1, engine "pysim-mirror"). The CI
   perf-smoke gate only compares events/sec between same-engine records,
   so this baseline bootstraps the trajectory without gating on python
   wall clock; reducer-kernel GB/s is rust-only and left empty here.
"""

import heapq
import sys
import time

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


# --- 1. queue micro-checks (mirror of events.rs tests) ---
print("== calendar queue micro-checks ==")


def times_400():
    out = []
    for i in range(400):
        fi = float(i)
        m = i % 4
        if m == 0:
            out.append(1e-6 * fi)
        elif m == 1:
            out.append(1e-6 * (fi % 7.0))
        elif m == 2:
            out.append(0.5 + 1e-3 * fi)
        else:
            out.append(1e-9 * fi * fi)
    return out


h = EventQueue("heap")
c = EventQueue("calendar")
agree = True
popped = 0
for i, t in enumerate(times_400()):
    h.push(t, i)
    c.push(t, i)
    if i % 3 == 2:
        agree = agree and h.pop() == c.pop()
        popped += 1
while True:
    a, b = h.pop(), c.pop()
    agree = agree and a == b
    if a is None:
        break
    popped += 1
chk("interleaved push/pop agreement (400 events)", agree and popped == 400)
hs, cs = h.stats(), c.stats()
chk(
    "op counters agree across kinds",
    (hs["pushes"], hs["pops"], hs["peak_len"]) == (cs["pushes"], cs["pops"], cs["peak_len"]),
)
chk("400 events outgrow 4 buckets (resizes > 0)", cs["resizes"] > 0, f"resizes={cs['resizes']}")

# day-rollover: same-instant bursts around a boundary + far straggler +
# late rewind; pops must follow (t, seq) exactly
import struct


def next_ulp(x):
    return struct.unpack("<d", struct.pack("<q", struct.unpack("<q", struct.pack("<d", x))[0] + 1))[0]


q = EventQueue("calendar")
t0 = 64.0 * CAL_INIT_WIDTH
t1 = next_ulp(t0)
for i in range(12):
    q.push(t0, i)
    q.push(t1, 100 + i)
q.push(1e3, 999)
q.push(0.5 * t0, 1000)
evs = []
keys = []
while True:
    e = q.pop()
    if e is None:
        break
    keys.append(e[:2])
    evs.append(e[2])
chk("day rollover: pops sorted by (t, seq)", keys == sorted(keys))
chk(
    "day rollover: rewind first, FIFO within instants, straggler last",
    evs[0] == 1000 and evs[1:13] == list(range(12)) and evs[13:25] == list(range(100, 112)) and evs[-1] == 999,
)

# grow/shrink cycles stay exact
q = EventQueue("calendar")
ok = True
for rnd in range(3):
    for i in range(257):
        q.push((i * 31.0 % 97.0) * 1e-5 + float(rnd), i)
    ks = []
    while True:
        e = q.pop()
        if e is None:
            break
        ks.append(e[:2])
    ok = ok and len(ks) == 257 and ks == sorted(ks)
chk("grow/shrink cycles stay exact", ok and q.stats()["resizes"] >= 6)

# zero-span same-instant burst is pure FIFO
q = EventQueue("calendar")
for i in range(100):
    q.push(2.5e-6, i)
out = []
while True:
    e = q.pop()
    if e is None:
        break
    out.append(e[2])
chk("zero-span burst is FIFO by seq", out == list(range(100)))

# --- 2. heap vs calendar across the registry, static plans ---
print("\n== heap vs calendar: full registry, static (bit identity) ==")
mismatches = 0
cells = 0
cal_resizes_total = 0
for dims in [[9], [27], [3, 3], [8, 8], [4, 4, 4]]:
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t)
            for m in [4096, 256 << 10, 1 << 20]:
                kh, eh, sh = simulate_packet_batched_stats(plan, m, P, 4096, "heap")
                kc, ec, sc = simulate_packet_batched_stats(plan, m, P, 4096, "calendar")
                cells += 1
                cal_resizes_total += sc["resizes"]
                same = (
                    kh == kc
                    and eh == ec
                    and sh["pushes"] == sc["pushes"]
                    and sh["pops"] == sc["pops"]
                    and sh["peak_len"] == sc["peak_len"]
                )
                if not same:
                    mismatches += 1
                    print(f"  MISMATCH {dims} {algo}-{variant} m={m}: {kh} vs {kc}")
chk(f"static registry bit-identical ({cells} cells)", mismatches == 0)
chk("calendar resized on real workloads", cal_resizes_total > 0, f"total resizes={cal_resizes_total}")

# --- 3. heap vs calendar under dynamic timelines ---
print("\n== heap vs calendar: dynamic timelines (bit identity) ==")
mismatches = 0
cells = 0
for dims in [[9], [3, 3]]:
    t = Torus(dims)
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            plan = Plan(b.net, t)
            for m in [4096, 1 << 20]:
                for name in ("flap", "brownout"):
                    tl = dynamic_timeline(name, t, P, m)
                    res = []
                    for kind in ("heap", "calendar"):
                        try:
                            k, e, _ = simulate_packet_dyn_stats(plan, m, P, 4096, tl, kind)
                            res.append((k, e))
                        except StrandedError as exc:
                            res.append(("stranded", exc.link, exc.step))
                    cells += 1
                    if res[0] != res[1]:
                        mismatches += 1
                        print(f"  MISMATCH {name} {dims} {algo}-{variant} m={m}: {res}")
chk(f"dynamic registry bit-identical ({cells} cells)", mismatches == 0)

# --- 4. op counts on the BENCH_core workload ---
print("\n== BENCH_core workload op counts (trivance-B 8x8, 1 MiB, mtu 4096) ==")
t88 = Torus([8, 8])
b88 = build("trivance", "B", t88)
plan88 = Plan(b88.net, t88)
k, e, s = simulate_packet_batched_stats(plan88, 1 << 20, P, 4096, "calendar")
print(
    f"events={e} pushes={s['pushes']} pops={s['pops']} peak={s['peak_len']} "
    f"resizes={s['resizes']} scanned={s['scanned']} ({s['scanned'] / max(s['pops'], 1):.2f}/pop)"
)
chk("queue fully drained (pushes == pops)", s["pushes"] == s["pops"])
# scanned/pop is the calendar's cost model: near-constant when event times
# spread, degrading toward O(cluster) when many events share an instant
# (64 synchronized step events per round here). The degradation is now a
# first-class metric (packet.queue.calendar.scanned_per_pop in the rust
# registry) and PINNED here, mirroring events.rs's
# same_instant_bursts_pin_the_scanned_per_pop_degradation: the 8x8 BENCH
# workload's synchronized rounds must show the O(cluster) blow-up that the
# sparser ring27 workload avoids. Measured: 8x8 ~97.3/pop, ring27 ~30.1.
r88 = s["scanned"] / s["pops"]
t27 = Torus([27])
b27 = build("trivance", "L", t27)
_, e27, s27 = simulate_packet_batched_stats(Plan(b27.net, t27), 1 << 20, P, 4096, "calendar")
r27 = s27["scanned"] / s27["pops"]
print(
    f"ring27 trivance-L (sparser ties): events={e27} resizes={s27['resizes']} "
    f"scanned={s27['scanned']} ({r27:.2f}/pop)"
)
chk("8x8 same-instant bursts degrade scanned/pop (pinned)", r88 > 50.0, f"{r88:.2f}/pop")
chk("8x8 degradation exceeds ring27 by 2x (pinned)", r88 > 2.0 * r27, f"{r88:.2f} vs {r27:.2f}")

# synthetic burst-vs-spread pin (identical workloads to the events.rs
# test): 8 rounds x 64 events at one shared instant per round vs the same
# events spread 1 us apart, drained each round. Measured: burst
# 16640/512 = 32.5/pop, spread 776/512 ~ 1.52/pop.


def _drain_ratio(rounds):
    q = EventQueue("calendar")
    for times in rounds:
        for i, t in enumerate(times):
            q.push(t, i)
        popped = []
        while True:
            e = q.pop()
            if e is None:
                break
            popped.append(e[:2])
        assert len(popped) == len(times) and popped == sorted(popped)
    st = q.stats()
    assert st["pops"] == st["pushes"]
    return st["scanned"] / st["pops"]


r_burst = _drain_ratio([[r * 1e-3] * 64 for r in range(8)])
r_spread = _drain_ratio([[(r * 64 + i) * 1e-6 for i in range(64)] for r in range(8)])
chk("synthetic burst degrades (pinned > 16/pop)", r_burst > 16.0, f"{r_burst:.2f}/pop")
chk("synthetic spread stays amortized O(1) (pinned < 4/pop)", r_spread < 4.0, f"{r_spread:.3f}/pop")
chk("burst exceeds spread by 4x (pinned)", r_burst > 4.0 * r_spread)


# --- 5. optional: emit the pysim-provenance BENCH_core.json baseline ---
def emit_baseline(path):
    rows = []
    for kind in ("heap", "calendar"):
        wall = float("inf")
        for _ in range(3):
            s0 = time.perf_counter()
            k2, e2, st = simulate_packet_batched_stats(plan88, 1 << 20, P, 4096, kind)
            wall = min(wall, time.perf_counter() - s0)
        rows.append((kind, e2, wall, st))
    lines = [
        "{",
        '  "schema": "trivance.bench_core.v1",',
        '  "engine": "pysim-mirror",',
        '  "quick": false,',
        f'  "generated_unix_s": {int(time.time())},',
        '  "packet_workload": {"topo": [8, 8], "algo": "trivance", "variant": "B", '
        '"size_bytes": 1048576, "mtu": 4096},',
        '  "event_queue": [',
    ]
    for i, (kind, e2, wall, st) in enumerate(rows):
        comma = "," if i + 1 < len(rows) else ""
        lines.append(
            f'    {{"kind": "{kind}", "events": {e2}, "wall_s": {wall:e}, '
            f'"events_per_s": {e2 / wall:e}, "pushes": {st["pushes"]}, "pops": {st["pops"]}, '
            f'"peak_len": {st["peak_len"]}, "resizes": {st["resizes"]}, '
            f'"scanned": {st["scanned"]}}}{comma}'
        )
    lines += [
        "  ],",
        '  "reduce": {"elems": 4194304, "kernels": [',
        "  ]},",
        '  "sweep": null,',
        '  "plan_cache": {"hits": 0, "misses": 0, "evictions": 0, "cached": 0, "cap": 1024}',
        "}",
        "",
    ]
    with open(path, "w") as f:
        f.write("\n".join(lines))
    print(f"\nwrote pysim-mirror baseline to {path}")


if "--emit-baseline" in sys.argv:
    emit_baseline(sys.argv[sys.argv.index("--emit-baseline") + 1])

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("core-engine eval: heap and calendar queues are bit-interchangeable")
