"""Self-checks: pin mirror.py against the closed forms and tolerances that
the Rust test suite asserts TODAY (pre-overhaul), using the reference
per-packet engine. Run before trusting any batched-engine measurement."""

import sys

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from mirror import *  # noqa

P = DEFAULT_PARAMS
beta = 8.0 / P["bw"]
ph = per_hop(P)
fails = []


def chk(name, cond, detail=""):
    status = "ok " if cond else "FAIL"
    print(f"[{status}] {name} {detail}")
    if not cond:
        fails.append(name)


# --- plan shape: trivance ring9 (sim/plan.rs tests) ---
t9 = Torus([9])
s9 = latency_allreduce(trivance(9, "inc"))
p9 = Plan(s9, t9)
chk("plan ring9 steps", p9.nsteps == 2)
step0 = [m for m in p9.msgs if m[2] == 0]
chk("plan ring9 step0 msgs", len(step0) == 18, f"got {len(step0)}")
chk("plan ring9 step0 routes", all(len(m[4]) == 1 for m in step0))
chk(
    "plan ring9 step1 routes",
    all(len(m[4]) == 3 for m in p9.msgs if m[2] == 1),
)
chk("plan ring9 rel", all(abs(m[3] - 1.0) < 1e-9 for m in p9.msgs))

# --- flow closed forms (sim/flow.rs tests) ---
# single message 0->1 on ring4
s1 = Schedule("one", 4, 4)
st = s1.push_step()
st[0].append(Send(1, [(frozenset(range(4)), "reduce", frozenset())], MIN))
f, _ = simulate_flow(Plan(s1, Torus([4])), 1 << 20, P)
exp = P["alpha"] + (1 << 20) * beta + ph
chk("flow single message", abs(f - exp) < 1e-12, f"{f} vs {exp}")

# trivance ring9 latency closed form
f, _ = simulate_flow(p9, 1 << 20, P)
exp = 2 * P["alpha"] + 4.0 * (1 << 20) * beta + 4.0 * ph
chk("flow trivance ring9", abs(f - exp) < exp * 1e-9, f"{f} vs {exp}")

# alpha-dominated small messages, ring27
t27 = Torus([27])
p27 = Plan(latency_allreduce(trivance(27, "inc")), t27)
f, _ = simulate_flow(p27, 32, P)
chk("flow ring27 alpha-bound", 4.5e-6 < f < 7.5e-6, f"{f}")

# asymmetric load closed form (incremental_state_survives_asymmetric_load)
s6 = Schedule("asym", 6, 6)
st = s6.push_step()
for src, to in [(0, 2), (1, 2), (4, 5)]:
    st[src].append(Send(to, [(frozenset(range(6)), "reduce", frozenset())], MIN))
f, _ = simulate_flow(Plan(s6, Torus([6])), 1 << 20, P)
exp = P["alpha"] + 2.0 * (1 << 20) * beta + 2.0 * ph
chk("flow asymmetric", abs(f - exp) < exp * 1e-6, f"{f} vs {exp}")

# --- reference packet closed forms (sim/packet.rs tests) ---
s1b = Schedule("one", 4, 4)
st = s1b.push_step()
st[0].append(Send(1, [(frozenset(range(4)), "reduce", frozenset())], MIN))
k, _ = simulate_packet_ref(Plan(s1b, Torus([4])), 64 * 1024, P, 4096)
exp = P["alpha"] + 64 * 1024 * beta + ph
chk("ref packet single hop", abs(k - exp) < 1e-12, f"{k} vs {exp}")

s3 = Schedule("hop3", 9, 9)
st = s3.push_step()
st[0].append(Send(3, [(frozenset(range(9)), "reduce", frozenset())], MIN))
k, _ = simulate_packet_ref(Plan(s3, Torus([9])), 256 * 1024, P, 4096)
exp = P["alpha"] + 256 * 1024 * beta + 2 * 4096 * beta + 3 * ph
chk("ref packet 3-hop pipeline", abs(k - exp) < exp * 1e-9, f"{k} vs {exp}")

# --- flow vs ref packet: trivance ring9 (10%, sim/packet.rs test) ---
for m in [4096, 64 * 1024, 1 << 20]:
    r = crosscheck([9], "trivance", "L", m, engine=simulate_packet_ref)
    chk(f"flow/ref trivance ring9 m={m}", r[0] < 0.1, f"rel={r[0]:.4f}")

# --- exhaustive ring9 matrix at 10% with ref engine (sim_crosscheck) ---
for algo in ["trivance", "bruck", "bucket"]:
    for variant in VARIANTS:
        for m in [4096, 256 << 10]:
            r = crosscheck([9], algo, variant, m, engine=simulate_packet_ref)
            chk(
                f"ref ring9 {algo}-{variant} m={m}",
                r[0] < 0.10,
                f"rel={r[0]:.4f}",
            )

# --- property-set sample at 0.25 with ref engine ---
for dims in [[8], [9], [3, 3]]:
    for algo in ALGOS:
        for variant in VARIANTS:
            for m in [4096, 256 << 10]:
                r = crosscheck(dims, algo, variant, m, engine=simulate_packet_ref)
                if r is None:
                    continue
                chk(
                    f"ref {dims} {algo}-{variant} m={m}",
                    r[0] < 0.25,
                    f"rel={r[0]:.4f}",
                )

# --- registry shape claims (registry.rs tests) ---
b = build("trivance", "L", Torus([9, 9]))
chk("trivance 9x9 L steps", b.net.num_steps() == 4)
b = build("trivance", "L", Torus([3, 3, 3]))
chk("trivance 3x3x3 L steps", b.net.num_steps() == 3)
b = build("trivance", "L", Torus([3, 3]))
chk("trivance 3x3 L n_blocks", b.net.n_blocks == 18)
b = build("bucket", "B", Torus([3, 3]))
chk("bucket 3x3 B n_blocks", b.net.n_blocks == 36)
b = build("swing", "L", Torus([9]))
chk("swing ring9 padded", b.padded and b.net.n == 9)

# bandwidth data volume (Lemma 4.1)
for n in [9, 27]:
    s = bandwidth_allreduce(trivance(n, "dec"))
    sent = s.node_sent_rel_bytes(0)
    exp = 2.0 * (1.0 - 1.0 / n)
    chk(f"lemma41 n={n}", abs(sent - exp) < 1e-9, f"{sent} vs {exp}")

# hierarchical volume on 3x3
t33 = Torus([3, 3])
hp = [trivance(3, "dec"), trivance(3, "dec")]
hs = hierarchical_bandwidth(t33, hp, [0, 1], "t")
exp = 2.0 * (1.0 - 1.0 / 9.0)
chk(
    "hierarchical volume 3x3",
    all(abs(hs.node_sent_rel_bytes(r) - exp) < 1e-9 for r in range(9)),
)

print()
if fails:
    print(f"{len(fails)} FAILURES: {fails}")
    sys.exit(1)
print("all mirror self-checks passed")
