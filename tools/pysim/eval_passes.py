"""Pass-manager parity harness (ISSUE 10).

Replicates rust/src/verify/{passes,hazard,deadlock,memory,cost,diff}.rs
through the mirror and pins every constant the Rust test suite
(rust/tests/verify_passes.rs) asserts — this container has no rustc, so
these are the measurements the Rust constants were pinned from:

  * hazard pass: zero WAW races anywhere in the registry, zero WAR cells on
    every bandwidth (B) variant (the in-place gate), and the pinned WAR
    barrier-reliance table for the latency (L) variants — including the
    padded swing-L/recdoub-L builds, where host multiplicity is easiest to
    get wrong;
  * deadlock pass: forward-availability green on every exec schedule and on
    every mid-fault rewrite; golden known-bad fixtures for the cycle and
    stage-order findings;
  * memory pass: the pinned peak-live table (trivance-L 3.0 rel on every
    ring and the 3x3, 7.0 on 8x8, 19.0 on 4x4x4; bucket-B strictly
    monotone decreasing over the ring sizes; padded peaks exactly
    host_multiplicity x the per-virtual peak);
  * cost pass: certificate tx_rel identical to the congestion audit, and
    the closed-form bound within the pinned tolerance bands of the flow
    engine over the full registry x six topologies x four sizes
    (|rel| <= 0.22 native, <= 0.30 padded);
  * verify::diff: differential certification of every PR 5/6 rewrite
    fixture (mid-fault rewrites on all six topologies, the ring-9
    node-death rewrite, and the online two-fault rewrite responses);
  * the seeded mutation suite including the InjectHazard corruptor kills
    100% (944/944 at seed 0xC0FFEE07, per_class 8).
"""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from mirror import (Torus, NetModel, Schedule, Send, MIN, build,  # noqa: E402
                    ALGOS, VARIANTS, DEFAULT_PARAMS, Fault, Plan,
                    host_multiplicity, midfault_fault, rewrite_for_fault,
                    rewrite_for_faults, respond, two_fault_events,
                    step_time_estimates, simulate_flow,
                    select_passes, run_passes, audit_hazards, audit_deadlock,
                    audit_stages, audit_memory, memory_bound,
                    require_peak_within, cost_certificate, cost_bound_s,
                    require_cost_within, certify_rewrite, certify_response,
                    run_mutation_suite, mutation_sites, report_v2,
                    PASS_NAMES)

FAILED = []
P = DEFAULT_PARAMS
TOPOS = [Torus([8]), Torus([9]), Torus([27]),
         Torus([3, 3]), Torus([8, 8]), Torus([4, 4, 4])]


def check(name, ok, detail=""):
    print(f"[{'ok ' if ok else 'FAIL'}] {name} {detail}")
    if not ok:
        FAILED.append(name)


def registry(t):
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, t)
            if b is None:
                continue
            b.algo, b.variant = algo, variant
            yield algo, variant, b


# ── pass manager: selection closure ─────────────────────────────────────
check("select: default is every pass in order",
      select_passes() == PASS_NAMES)
check("select: cost pulls congestion+optimality",
      select_passes(["cost"]) == ["congestion", "optimality", "cost"])
check("select: deadlock pulls dataflow",
      select_passes(["deadlock"]) == ["dataflow", "deadlock"])

# ── hazard pass: pinned WAR table, WAW == 0, B-variant in-place gate ─────
PINNED_WAR_L = {  # (dims...) -> {algo: war_cells on the exec schedule}
    (8,): {"trivance": 128, "bruck": 128, "bruck-unidir": 128,
           "swing": 192, "recdoub": 192, "bucket": 448},
    (9,): {"trivance": 162, "bruck": 162, "bruck-unidir": 162,
           "swing": 1024, "recdoub": 1024, "bucket": 648},
    (27,): {"trivance": 2187, "bruck": 2187, "bruck-unidir": 2187,
            "swing": 5120, "recdoub": 5120, "bucket": 18954},
    (3, 3): {"trivance": 324, "bruck": 324, "bruck-unidir": 324,
             "swing": 1024, "recdoub": 1024, "bucket": 324},
    (8, 8): {"trivance": 32768, "bruck": 32768, "bruck-unidir": 32768,
             "swing": 24576, "recdoub": 24576, "bucket": 57344},
    (4, 4, 4): {"trivance": 55296, "bruck": 64512, "bruck-unidir": 64512,
                "swing": 24576, "recdoub": 24576, "bucket": 36864},
}
for t in TOPOS:
    for algo, variant, b in registry(t):
        haz = audit_hazards(b.exec_s)
        if haz["waw_conflicts"] != 0:
            check(f"{t.dims} {algo}-{variant}: WAW == 0", False,
                  str(haz["waw_conflicts"]))
        if variant == "B":
            if haz["war_cells"] != 0:
                check(f"{t.dims} {algo}-B: in-place (WAR == 0)", False,
                      str(haz["war_cells"]))
        else:
            want = PINNED_WAR_L[tuple(t.dims)][algo]
            if haz["war_cells"] != want:
                check(f"{t.dims} {algo}-L: pinned WAR cells", False,
                      f"{haz['war_cells']} vs {want}")
check("hazard: registry WAW-free, B-variants in-place, L table pinned",
      not FAILED)

# padded golden fixtures: host multiplicity must not distort the counts
b = build("swing", "L", Torus([9]))
check("padded swing-L ring-9: WAR == 1024 on the virtual exec schedule",
      b.padded and audit_hazards(b.exec_s)["war_cells"] == 1024)
b = build("swing", "B", Torus([9]))
check("padded swing-B ring-9: in-place (WAR == 0)",
      b.padded and audit_hazards(b.exec_s)["war_cells"] == 0)

# golden known-bad: a Set racing a Reduce into the same cell is WAW
s = Schedule("waw-bad", 3, 1)
st = s.push_step()
st[0].append(Send(2, [(frozenset([0]), "reduce", frozenset([0]))], MIN))
st[1].append(Send(2, [(frozenset([0]), "set", frozenset([0, 1, 2]))], MIN))
check("golden hazard fixture: WAW race detected",
      audit_hazards(s)["waw_conflicts"] == 1)

# ── deadlock pass: golden fixtures (registry coverage is in run_passes) ──
s = Schedule("deadlock-bad", 3, 1)
st = s.push_step()
st[0].append(Send(1, [(frozenset([0]), "reduce", frozenset([0, 2]))], MIN))
err = audit_deadlock(s)
check("golden deadlock fixture: later-produced contribution flagged",
      err is not None and err[0] == "deadlock", str(err))
t9 = Torus([9])
err = audit_stages([(2, NetModel.uniform(t9)), (1, NetModel.uniform(t9))], t9)
check("golden stage-order fixture: regressing from_step flagged",
      err is not None and err[0] == "stage_order", str(err))
err = audit_stages([(0, NetModel.uniform(Torus([8])))], t9)
check("golden stage-order fixture: foreign topology flagged",
      err is not None and err[0] == "stage_order", str(err))

# ── memory pass: pinned peaks, monotone bucket-B, padded folding ─────────
PINNED_MEM = {  # ((dims...), algo, variant) -> peak_live_rel
    ((8,), "trivance", "L"): 3.0, ((9,), "trivance", "L"): 3.0,
    ((27,), "trivance", "L"): 3.0, ((3, 3), "trivance", "L"): 3.0,
    ((8, 8), "trivance", "L"): 7.0, ((4, 4, 4), "trivance", "L"): 19.0,
    ((8,), "bucket", "B"): 1.0 + 1.0 / 8.0,
    ((9,), "bucket", "B"): 1.0 + 1.0 / 9.0,
    ((27,), "bucket", "B"): 1.0 + 1.0 / 27.0,
    ((9,), "swing", "L"): 4.0, ((3, 3), "swing", "L"): 8.0,
}
for (dims, algo, variant), want in PINNED_MEM.items():
    t = Torus(list(dims))
    b = build(algo, variant, t)
    b.algo, b.variant = algo, variant
    mem = audit_memory(b.exec_s, b.hosts, t.n)
    check(f"{list(dims)} {algo}-{variant}: pinned peak {want:.4f}",
          abs(mem["peak_live_rel"] - want) < 1e-9,
          f"got {mem['peak_live_rel']:.6f}")
    check(f"{list(dims)} {algo}-{variant}: peak within certified bound",
          require_peak_within(mem, memory_bound(b, mem)) is None)
ring_peaks = [audit_memory(build("bucket", "B", Torus([n])).exec_s, None,
                           n)["peak_live_rel"] for n in (8, 9, 27)]
check("bucket-B ring peaks strictly monotone decreasing",
      ring_peaks[0] > ring_peaks[1] > ring_peaks[2], str(ring_peaks))
# padded folding: peak == host_multiplicity x per-virtual peak
b = build("swing", "L", t9)
hm = host_multiplicity(b)
virt = audit_memory(b.exec_s, None, b.exec_s.n)["peak_live_rel"]
folded = audit_memory(b.exec_s, b.hosts, t9.n)["peak_live_rel"]
check("padded swing-L ring-9: folded peak == hm x virtual peak",
      hm == 2 and abs(folded - hm * virt) < 1e-9,
      f"hm {hm}, virtual {virt}, folded {folded}")
check("trivance-L 4x4x4: in_rel_max == 18 (merged concurrent dim-slices)",
      abs(audit_memory(build("trivance", "L", Torus([4, 4, 4])).exec_s,
                       None, 64)["in_rel_max"] - 18.0) < 1e-9)
# golden known-bad: an impossible bound trips the typed finding
mem = audit_memory(build("trivance", "L", Torus([8])).exec_s, None, 8)
err = require_peak_within(mem, 1.0)
check("golden memory fixture: regression against a 1.0 bound",
      err is not None and err[0] == "memory_regression", str(err))

# ── cost pass: certificate vs the flow engine, pinned tolerance bands ────
SIZES = [4 << 10, 64 << 10, 1 << 20, 16 << 20]
TOL_NATIVE, TOL_PADDED = 0.22, 0.30
worst_native = worst_padded = 0.0
for t in TOPOS:
    base = NetModel.uniform(t)
    for algo, variant, b in registry(t):
        cert = cost_certificate(b.net, base)
        cong_tx = run_passes(b, t, ["congestion"])[0]["congestion"][
            "tx_delay_rel"]
        if abs(cert["tx_rel"] - cong_tx) > 1e-12:
            check(f"{t.dims} {algo}-{variant}: cost tx == congestion tx",
                  False, f"{cert['tx_rel']} vs {cong_tx}")
        tol = TOL_PADDED if b.padded else TOL_NATIVE
        for m in SIZES:
            flow, _ev = simulate_flow(Plan(b.net, t, base), m, P)
            bound = cost_bound_s(cert, m, P)
            rel = abs(flow - bound) / bound
            if b.padded:
                worst_padded = max(worst_padded, rel)
            else:
                worst_native = max(worst_native, rel)
            if require_cost_within(cert, m, P, flow, tol) is not None:
                check(f"{t.dims} {algo}-{variant} m={m}: flow within "
                      f"certified bound (+{tol:.0%})", False,
                      f"flow {flow:.3e} bound {bound:.3e}")
check(f"cost certificates: native |rel| <= {TOL_NATIVE} over the registry",
      worst_native <= TOL_NATIVE, f"worst {worst_native:.4f}")
check(f"cost certificates: padded |rel| <= {TOL_PADDED} over the registry",
      worst_padded <= TOL_PADDED, f"worst {worst_padded:.4f}")
# golden known-bad: a measurement far above the bound trips the finding
cert = cost_certificate(build("trivance", "L", Torus([8])).net,
                        NetModel.uniform(Torus([8])))
err = require_cost_within(cert, 1 << 20, P,
                          2.0 * cost_bound_s(cert, 1 << 20, P), TOL_NATIVE)
check("golden cost fixture: 2x-bound measurement flagged",
      err is not None and err[0] == "cost_regression", str(err))

# ── verify::diff: every PR 5/6 rewrite fixture certifies ─────────────────
certified = 0
for t in TOPOS:
    base = NetModel.uniform(t)
    fault = midfault_fault(t)
    dead = {v: fault.step for v in fault.dead_nodes}
    for algo, variant, b in registry(t):
        if b.hosts is None:
            rw = rewrite_for_faults(b.net, base, [fault])
            err = certify_rewrite(b.net, rw, fault.step, dead)
        else:
            rw = rewrite_for_faults(b.exec_s, base, [fault], b.hosts)
            err = certify_rewrite(b.exec_s, rw, fault.step, dead, b.hosts)
        if err is not None:
            check(f"{t.dims} {algo}-{variant}: mid-fault diff", False,
                  str(err))
        if audit_deadlock(rw) is not None:
            check(f"{t.dims} {algo}-{variant}: mid-fault deadlock-free",
                  False)
        certified += 1
check("diff: every mid-fault rewrite certifies", certified == 72,
      f"{certified} fixtures")

b = build("trivance", "L", t9)
base9 = NetModel.uniform(t9)
rw = rewrite_for_fault(b.net, base9, Fault(1, dead_nodes=[4]))
check("diff: ring-9 node-death rewrite certifies",
      certify_rewrite(b.net, rw, 1, {4: 1}) is None)

online_certified = 0
for t in (Torus([9]), Torus([3, 3])):
    base = NetModel.uniform(t)
    m0 = 1 << 20
    for algo, variant, b in registry(t):
        if b.hosts is not None:
            continue
        ends = step_time_estimates(b.net, base, m0, P)
        events = two_fault_events(t, ends)
        resp = respond(b, base, events, m0, P, lambda ev, step: "rewrite")
        err = certify_response(b, base, resp)
        if err is not None:
            check(f"{t.dims} {algo}-{variant}: online diff", False, str(err))
        online_certified += 1
check("diff: every online two-fault rewrite response certifies",
      online_certified == 16, f"{online_certified} fixtures")

# golden known-bad: touching the executed prefix breaks equivalence
b = build("trivance", "L", Torus([8]))
rw = rewrite_for_fault(b.net, NetModel.uniform(Torus([8])),
                       midfault_fault(Torus([8])))
rw.steps[0][0] = []  # retroactively drop an already-executed send
err = certify_rewrite(b.net, rw, midfault_fault(Torus([8])).step, {})
check("golden diff fixture: modified prefix flagged",
      err is not None and err[0] == "divergence", str(err))

# ── mutation suite with the InjectHazard corruptor ───────────────────────
b = build("trivance", "L", Torus([8]))
check("hazard corruptor has sites on every payload reduce",
      len(mutation_sites(b.net, Torus([8]), "hazard")) > 0)
total, killed, survivors = run_mutation_suite(
    [Torus([8]), Torus([9]), Torus([3, 3])], 0xC0FFEE07, 8)
check("mutation suite pinned total (with hazard class)", total == 944,
      str(total))
check("mutation suite kills 100%", killed == total and not survivors,
      f"{killed}/{total}")

# ── report v2 shape (validated in depth by tools/check_verify_report.py) ─
rep = report_v2([Torus([8])])
check("report v2 schema tag", rep["schema"] == "trivance.verify.v2")
check("report v2 carries per-pass timings",
      [p["name"] for p in rep["passes"]] == PASS_NAMES and
      all(p["seconds"] >= 0.0 for p in rep["passes"]))
e = rep["topos"][0]["certs"][0]
V2_KEYS = {"hazard_war_cells", "hazard_waw_conflicts", "barrier_free",
           "deadlock_ok", "mem_peak_rel", "mem_in_rel_max", "cost_steps",
           "cost_tx_rel", "cost_hop_lat_rel", "cost_hop_proc_rel"}
V1_KEYS = {"collective", "algo", "variant", "padded", "steps", "lat_bound3",
           "lat_bound2", "max_node_sent_rel", "bw_lower_rel", "port_budget",
           "max_port_msgs", "tx_delay_rel", "max_link_rel", "mean_link_rel",
           "max_link_msgs", "bytes_on_wire_rel", "messages", "max_atoms",
           "class"}
check("report v2 preserves v1 cert fields and adds the pass fields",
      (V1_KEYS | V2_KEYS) <= set(e))

print()
if FAILED:
    print(f"eval_passes: {len(FAILED)} FAILURES: {FAILED}")
    sys.exit(1)
print("passes eval: hazard/deadlock/memory/cost certificates, the "
      "differential rewrite proofs and the extended mutation gate all hold")
