"""Python mirror of the trivance Rust schedule builders + simulators.

This container ships no rustc/cargo (see CHANGES.md, PR 1), so behavioural
changes to the simulator are validated here: the mirror re-implements, with
matching event ordering and float arithmetic, every layer needed to compute
flow-mode and packet-mode completions for the full algorithm registry:

  blockset (as Python sets) -> ExchangeAg ring builders -> agpattern
  (latency cut-propagation fixpoint, Reduce-Scatter tree reversal) ->
  multidim (ProductAg / reflection / concurrent slices / hierarchical) ->
  registry build (incl. virtual padding) -> torus routing -> SimPlan ->
  {flow water-filling, reference per-packet engine, batched packet engine}.

`check.py` pins the mirror against the closed-form expectations of the Rust
unit tests, then measures the batched-engine drift and flow-vs-packet
tolerances that the Rust test suite asserts.

Only message byte totals matter for simulation, but block sets are carried
as real sets end to end because the latency-variant cut propagation and the
Reduce-Scatter tree reversal operate on them.
"""

import heapq
from collections import deque
from itertools import product as iproduct

MIN = ("min",)


def directed(dim, dr):
    return ("dir", dim, dr)


# ---------------------------------------------------------------- topology


class Torus:
    def __init__(self, dims):
        assert dims and all(d >= 2 for d in dims)
        self.dims = list(dims)
        self.strides = []
        acc = 1
        for d in dims:
            self.strides.append(acc)
            acc *= d
        self.n = acc

    def ndims(self):
        return len(self.dims)

    def num_links(self):
        return self.n * len(self.dims) * 2

    def link_index(self, node, dim, dr):
        return (node * len(self.dims) + dim) * 2 + (1 if dr > 0 else 0)

    def coords(self, rank):
        c = []
        r = rank
        for d in self.dims:
            c.append(r % d)
            r //= d
        return c

    def rank(self, coords):
        return sum(c * s for c, s in zip(coords, self.strides))

    def coord(self, rank, dim):
        return (rank // self.strides[dim]) % self.dims[dim]

    def neighbor(self, rank, dim, offset):
        a = self.dims[dim]
        c = self.coord(rank, dim)
        nc = (c + offset) % a
        return rank - c * self.strides[dim] + nc * self.strides[dim]

    def route(self, src, dst):
        links = []
        cur = src
        for d in range(len(self.dims)):
            a = self.dims[d]
            cs, cd = self.coord(cur, d), self.coord(dst, d)
            if cs == cd:
                continue
            fwd = (cd - cs) % a
            bwd = a - fwd
            if fwd < bwd:
                dr = 1
            elif bwd < fwd:
                dr = -1
            else:
                dr = 1 if cs % 2 == 0 else -1
            for _ in range(min(fwd, bwd)):
                links.append(self.link_index(cur, d, dr))
                cur = self.neighbor(cur, d, dr)
        assert cur == dst
        return links

    def route_directed(self, src, dst, dim, dr):
        a = self.dims[dim]
        cs, cd = self.coord(src, dim), self.coord(dst, dim)
        hops = (cd - cs) % a if dr > 0 else (cs - cd) % a
        links = []
        cur = src
        for _ in range(hops):
            links.append(self.link_index(cur, dim, dr))
            cur = self.neighbor(cur, dim, dr)
        assert cur == dst
        return links

    def product_set(self, ranges):
        # ranges[d] = set of coords in dim d -> set of ranks
        out = set()
        for combo in iproduct(*[sorted(r) for r in ranges]):
            out.add(self.rank(list(combo)))
        return out


# ------------------------------------------------------------ net model
# Mirror of rust/src/net/mod.rs: per-link scale columns relative to the base
# NetParams, an optional down set, and detour routing around down links.
# Keep presets, the SplitMix64 draws, and the BFS in lockstep with Rust.

MASK64 = (1 << 64) - 1


class SplitMix64:
    """Mirror of rust/src/util/rng.rs (used for deterministic link picks)."""

    def __init__(self, seed):
        self.state = seed & MASK64

    def next_u64(self):
        self.state = (self.state + 0x9E3779B97F4A7C15) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return z ^ (z >> 31)

    def below(self, bound):
        return (self.next_u64() * bound) >> 64


def strongly_connected(torus, down):
    """Is the directed link graph minus `down` still strongly connected?"""
    for transpose in (False, True):
        seen = [False] * torus.n
        seen[0] = True
        stack = [0]
        count = 1
        while stack:
            u = stack.pop()
            for d in range(torus.ndims()):
                for dr in (1, -1):
                    if transpose:
                        v = torus.neighbor(u, d, -dr)
                        l = torus.link_index(v, d, dr)
                    else:
                        v = torus.neighbor(u, d, dr)
                        l = torus.link_index(u, d, dr)
                    if down[l] or seen[v]:
                        continue
                    seen[v] = True
                    count += 1
                    stack.append(v)
        if count != torus.n:
            return False
    return True


def pick_links(torus, k, seed, keep_connected):
    rng = SplitMix64(seed)
    chosen = []
    down = [False] * torus.num_links()
    attempts = 0
    while len(chosen) < k:
        attempts += 1
        assert attempts <= 64 * k + 1024, "link picking stalled"
        l = rng.below(torus.num_links())
        if down[l]:
            continue
        down[l] = True
        if keep_connected and not strongly_connected(torus, down):
            down[l] = False
            continue
        chosen.append(l)
    chosen.sort()
    return chosen


class NetModel:
    def __init__(self, torus):
        num_links = torus.num_links()
        self.torus = torus
        self.bw_scale = [1.0] * num_links
        self.lat_scale = [1.0] * num_links
        self.proc_scale = [1.0] * num_links
        self.down = [False] * num_links

    def is_uniform(self):
        return (
            not any(self.down)
            and all(s == 1.0 for s in self.bw_scale)
            and all(s == 1.0 for s in self.lat_scale)
            and all(s == 1.0 for s in self.proc_scale)
        )

    @staticmethod
    def uniform(torus):
        return NetModel(torus)

    @staticmethod
    def hetero_dims(torus, dim_bw_scale):
        m = NetModel(torus)
        for node in range(torus.n):
            for d in range(torus.ndims()):
                for dr in (1, -1):
                    m.bw_scale[torus.link_index(node, d, dr)] = dim_bw_scale[d]
        return m

    @staticmethod
    def asymmetric_dims(torus, up_scale, down_scale):
        """Per-direction bandwidth scales (mirror of
        NetModel::asymmetric_dims): +1 links of dim d at up_scale[d], -1
        links at down_scale[d]."""
        m = NetModel(torus)
        for node in range(torus.n):
            for d in range(torus.ndims()):
                m.bw_scale[torus.link_index(node, d, 1)] = up_scale[d]
                m.bw_scale[torus.link_index(node, d, -1)] = down_scale[d]
        return m

    @staticmethod
    def straggler(torus, k, factor, seed):
        m = NetModel(torus)
        for l in pick_links(torus, k, seed, keep_connected=False):
            m.bw_scale[l] = 1.0 / factor
        return m

    @staticmethod
    def faulty(torus, k, seed):
        m = NetModel(torus)
        for l in pick_links(torus, k, seed, keep_connected=True):
            m.down[l] = True
        return m

    def route(self, src, dst, hint):
        if hint == MIN:
            nominal = self.torus.route(src, dst)
        else:
            nominal = self.torus.route_directed(src, dst, hint[1], hint[2])
        if not any(self.down[l] for l in nominal):
            return nominal
        return self.route_avoiding(src, dst)

    def route_avoiding(self, src, dst):
        """Deterministic BFS shortest path skipping down links (neighbor
        order: dim ascending, +1 before -1; FIFO queue)."""
        if src == dst:
            return []
        t = self.torus
        parent = [-2] * t.n  # -2 = unvisited, -1 = source
        parent_link = [0] * t.n
        parent[src] = -1
        q = deque([src])
        while q:
            u = q.popleft()
            for d in range(t.ndims()):
                for dr in (1, -1):
                    l = t.link_index(u, d, dr)
                    if self.down[l]:
                        continue
                    v = t.neighbor(u, d, dr)
                    if parent[v] != -2:
                        continue
                    parent[v] = u
                    parent_link[v] = l
                    q.append(v)
        assert parent[dst] != -2, f"down links disconnect {src}->{dst}"
        links = []
        cur = dst
        while parent[cur] != -1:
            links.append(parent_link[cur])
            cur = parent[cur]
        return links[::-1]

    def distance_avoiding(self, src, dst):
        """BFS hop distance avoiding the down set (None if unreachable) —
        mirror of NetModel::distance_avoiding (rewrite donor metric)."""
        try:
            return len(self.route_avoiding(src, dst))
        except AssertionError:
            return None

    def distances_to(self, dst):
        """Hop distance from every node to `dst` avoiding the down set
        (None = unreachable): one reverse BFS — mirror of
        NetModel::distances_to (the rewrite cleanup's bulk donor metric;
        shortest-path lengths agree with distance_avoiding exactly)."""
        t = self.torus
        dist = [None] * t.n
        dist[dst] = 0
        q = deque([dst])
        while q:
            v = q.popleft()
            for d in range(t.ndims()):
                for dr in (1, -1):
                    u = t.neighbor(v, d, -dr)
                    if self.down[t.link_index(u, d, dr)]:
                        continue
                    if dist[u] is None:
                        dist[u] = dist[v] + 1
                        q.append(u)
        return dist


# ------------------------------------------------------------ util


def ceil_log(base, n):
    s, v = 0, 1
    while v < n:
        v *= base
        s += 1
    return s


def floor_log(base, n):
    s, v = 0, base
    while v <= n:
        v *= base
        s += 1
    return s


def is_power_of(base, n):
    return n >= 1 and base ** floor_log(base, n) == n


# ------------------------------------------------------------ AG patterns


class AgSend:
    __slots__ = ("src", "to", "blocks", "route")

    def __init__(self, src, to, blocks, route):
        self.src, self.to, self.blocks, self.route = src, to, blocks, route


class ExchangeAg:
    def __init__(self, name, n, num_steps, peers):
        self.name, self.n = name, n
        held = [{r} for r in range(n)]
        self.sends_by_step = []
        for k in range(num_steps):
            pending = [set() for _ in range(n)]
            step = []
            for r in range(n):
                for to, route in peers(k, r):
                    if to == r:
                        continue
                    blocks = held[r] - held[to] - pending[to]
                    if not blocks:
                        continue
                    pending[to] |= blocks
                    step.append(AgSend(r, to, frozenset(blocks), route))
            for r in range(n):
                held[r] |= pending[r]
            self.sends_by_step.append(step)

    def num_steps(self):
        return len(self.sends_by_step)

    def sends(self, k):
        return self.sends_by_step[k]

    def is_complete(self):
        held = [{r} for r in range(self.n)]
        for step in self.sends_by_step:
            for s in step:
                held[s.to] |= s.blocks
        return all(len(h) == self.n for h in held)


def ordered(k, steps, order):
    return k if order == "inc" else steps - 1 - k


def trivance(n, order):
    s = floor_log(3, n)
    dists = [3 ** k for k in range(s)]
    if not is_power_of(3, n):
        dists.append(-(-(n - 3 ** s) // 2))  # div_ceil
    if order == "dec":
        dists = dists[::-1]
    steps = len(dists)

    def peers(k, r):
        d = dists[k]
        return [((r + d) % n, MIN), ((r - d) % n, MIN)]

    return ExchangeAg(f"trivance(n={n})", n, steps, peers)


def bruck(n, order, unidirectional):
    steps = ceil_log(3, n)
    route = directed(0, 1) if unidirectional else MIN

    def peers(k, r):
        p = 3 ** ordered(k, steps, order)
        return [((r + p) % n, route), ((r + 2 * p) % n, route)]

    return ExchangeAg(f"bruck(n={n})", n, steps, peers)


def recdoub(n, order):
    assert is_power_of(2, n)
    steps = ceil_log(2, n)

    def peers(k, r):
        d = 1 << ordered(k, steps, order)
        return [(r ^ d, MIN)]

    return ExchangeAg(f"recdoub(n={n})", n, steps, peers)


def swing_rho(k):
    v = (1 - (-2) ** (k + 1)) // 3
    return v


def swing_peer(r, k, n):
    rho = swing_rho(k)
    p = r + rho if r % 2 == 0 else r - rho
    return p % n


def swing(n, order):
    assert is_power_of(2, n)
    steps = ceil_log(2, n)

    def peers(k, r):
        return [(swing_peer(r, ordered(k, steps, order), n), MIN)]

    return ExchangeAg(f"swing(n={n})", n, steps, peers)


def hamiltonian(n):
    return ExchangeAg(f"ring(n={n})", n, n - 1, lambda k, r: [((r + 1) % n, MIN)])


class ProductAg:
    """Product/interleave lifting of per-dimension ring patterns."""

    def __init__(self, name, torus, patterns, step_dims):
        self.name, self.torus = name, torus
        assert len(patterns) == torus.ndims()
        self.ring_sends = [[p.sends(k) for k in range(p.num_steps())] for p in patterns]
        self.ring_held = [simulate_held(p) for p in patterns]
        self.step_dims = step_dims

    @staticmethod
    def round_robin(dims_steps, start):
        d = len(dims_steps)
        remaining = list(dims_steps)
        total = sum(dims_steps)
        out = []
        i = start
        while len(out) < total:
            if remaining[i % d] > 0:
                remaining[i % d] -= 1
                out.append(i % d)
            i += 1
        return out

    @staticmethod
    def sequential(dims_steps, start):
        d = len(dims_steps)
        out = []
        for i in range(d):
            dim = (start + i) % d
            out.extend([dim] * dims_steps[dim])
        return out

    def num_steps(self):
        return len(self.step_dims)

    @property
    def n(self):
        return self.torus.n

    def sends(self, k):
        d = self.step_dims[k]
        t = sum(1 for x in self.step_dims[:k] if x == d)
        ndims = self.torus.ndims()
        t_of = [sum(1 for x in self.step_dims[:k] if x == e) for e in range(ndims)]
        out = []
        for rs in self.ring_sends[d][t]:
            for r in range(self.torus.n):
                if self.torus.coord(r, d) != rs.src:
                    continue
                c = self.torus.coords(r)
                c[d] = rs.to
                dst = self.torus.rank(c)
                ranges = []
                for e in range(ndims):
                    if e == d:
                        ranges.append(rs.blocks)
                    else:
                        ranges.append(self.ring_held[e][t_of[e]][self.torus.coord(r, e)])
                blocks = self.torus.product_set(ranges)
                if not blocks:
                    continue
                route = rs.route if rs.route == MIN else directed(d, rs.route[2])
                out.append(AgSend(r, dst, frozenset(blocks), route))
        return out

    def is_complete(self):
        held = [{r} for r in range(self.n)]
        for k in range(self.num_steps()):
            for s in self.sends(k):
                held[s.to] |= s.blocks
        return all(len(h) == self.n for h in held)


def simulate_held(p):
    n = p.n
    held = [[{r} for r in range(n)]]
    for k in range(p.num_steps()):
        nxt = [set(h) for h in held[k]]
        for s in p.sends(k):
            nxt[s.to] |= s.blocks
        held.append(nxt)
    return held


# ------------------------------------------------------------ schedule IR
# A Send mirrors what the SimPlan consumes — destination, pieces, route
# hint — plus (since the dynamic-fabrics PR) each piece's *contributor set*,
# which the fault-rewrite mirror's shrink/substitute algebra operates on.
# Pieces are (blocks_set, kind, contrib_set); steps[k][src] = [Send, ...].


class Send:
    __slots__ = ("to", "pieces", "route")

    def __init__(self, to, pieces, route):
        self.to, self.pieces, self.route = to, pieces, route

    def rel_bytes(self, n_blocks):
        return sum(len(b) for b, _k, _c in self.pieces) / n_blocks


class Schedule:
    def __init__(self, name, n, n_blocks):
        self.name, self.n, self.n_blocks = name, n, n_blocks
        self.steps = []

    def push_step(self):
        self.steps.append([[] for _ in range(self.n)])
        return self.steps[-1]

    def num_steps(self):
        return len(self.steps)

    def concat(self, other):
        assert self.n == other.n and self.n_blocks == other.n_blocks
        for st in other.steps:
            mine = self.push_step()
            for src in range(self.n):
                mine[src].extend(st[src])

    def node_sent_rel_bytes(self, node):
        return sum(
            snd.rel_bytes(self.n_blocks) for st in self.steps for snd in st[node]
        )


def allgather_schedule(p):
    full = frozenset(range(p.n))
    s = Schedule(f"ag", p.n, p.n)
    for k in range(p.num_steps()):
        st = s.push_step()
        for ag in p.sends(k):
            if not ag.blocks:
                continue
            st[ag.src].append(Send(ag.to, [(ag.blocks, "set", full)], ag.route))
    return s


def latency_allreduce(p):
    n = p.n
    steps = []
    for k in range(p.num_steps()):
        steps.append(
            [
                {"src": m.src, "to": m.to, "parts": [m.blocks], "route": m.route}
                for m in p.sends(k)
                if m.blocks
            ]
        )
    while True:
        state = [[(frozenset([r]), None)] for r in range(n)]
        fixes = {}
        for k in range(len(steps)):
            for msg in steps[k]:
                for part in msg["parts"]:
                    for atom, prov in state[msg["src"]]:
                        inter = atom & part
                        if not inter or inter == atom:
                            continue
                        assert prov is not None, "own atoms are singletons"
                        v = fixes.setdefault(prov, [])
                        if part not in v:
                            v.append(part)
            for mi, msg in enumerate(steps[k]):
                for pi, part in enumerate(msg["parts"]):
                    state[msg["to"]].append((part, (k, mi, pi)))
        if not fixes:
            break
        by_msg = {}
        for (step, umi, upi), bs in fixes.items():
            by_msg.setdefault((step, umi), []).append((upi, bs))
        for (step, umi), splits in by_msg.items():
            splits.sort(key=lambda x: x[0])
            msg = steps[step][umi]
            new_parts = []
            for pi, part in enumerate(msg["parts"]):
                pieces = [part]
                hit = [b for upi, bs in splits if upi == pi for b in bs]
                if hit:
                    # Rust takes the *first* matching split entry only
                    bounds = next(bs for upi, bs in splits if upi == pi)
                    for b in bounds:
                        nxt = []
                        for pp in pieces:
                            a = pp & b
                            rest = pp - a
                            if a:
                                nxt.append(a)
                            if rest:
                                nxt.append(rest)
                        pieces = nxt
                new_parts.extend(pieces)
            msg["parts"] = new_parts

    s = Schedule("lat", n, n)
    full = frozenset(range(n))
    for step_msgs in steps:
        st = s.push_step()
        for msg in step_msgs:
            st[msg["src"]].append(
                Send(
                    msg["to"],
                    [(full, "reduce", part) for part in msg["parts"]],
                    msg["route"],
                )
            )
    return s


def reduce_scatter_schedule(p):
    # Tree-reversal RS with real contributor sets (the subtree each sender
    # forwards), piece-merged per adjacent equal contrib exactly as Rust's
    # agpattern::reduce_scatter_schedule does.
    n = p.n
    s_total = p.num_steps()
    edges = [[] for _ in range(n)]
    for k in range(s_total):
        sends = p.sends(k)
        for ag in sends:
            for b in ag.blocks:
                edges[b].append((k, ag.src, ag.to))
    rs = Schedule("rs", n, n)
    for _ in range(s_total):
        rs.push_step()
    groups = {}  # (t, src, dst) -> [(b, contrib_frozenset)], block-ascending
    for b in range(n):
        subtree = {}
        for t, u, v in reversed(edges[b]):
            sub_v = subtree.pop(v, frozenset([v])) | {v}
            groups.setdefault((s_total - 1 - t, v, u), []).append((b, sub_v))
            subtree[u] = subtree.get(u, frozenset([u])) | sub_v
    for (t, src, dst) in sorted(groups):
        raw = sorted(groups[(t, src, dst)], key=lambda x: x[0])
        pieces = []
        for b, contrib in raw:
            if pieces and pieces[-1][2] == contrib:
                blocks, kind, c = pieces[-1]
                pieces[-1] = (blocks | {b}, kind, c)
            else:
                pieces.append((frozenset([b]), "reduce", contrib))
        rs.steps[t][src].append(Send(dst, pieces, MIN))
    return rs


def bandwidth_allreduce(p):
    s = reduce_scatter_schedule(p)
    s.concat(allgather_schedule(p))
    return s


def reflection_map(t):
    out = []
    for r in range(t.n):
        c = [(a - x) % a for x, a in zip(t.coords(r), t.dims)]
        out.append(t.rank(c))
    return out


def permute_schedule(s, mp):
    assert s.n == s.n_blocks
    out = Schedule(s.name + "-mirror", s.n, s.n_blocks)
    for step in s.steps:
        st = out.push_step()
        for src in range(s.n):
            for snd in step[src]:
                pieces = [
                    (
                        frozenset(mp[b] for b in blocks),
                        kind,
                        frozenset(mp[c] for c in contrib),
                    )
                    for blocks, kind, contrib in snd.pieces
                ]
                route = snd.route
                if route != MIN:
                    route = directed(route[1], -route[2])
                st[mp[src]].append(Send(mp[snd.to], pieces, route))
    return out


def concurrent_slices(slices, name):
    n, nb = slices[0].n, slices[0].n_blocks
    out = Schedule(name, n, len(slices) * nb)
    for c, sl in enumerate(slices):
        assert sl.n == n and sl.n_blocks == nb
        while len(out.steps) < len(sl.steps):
            out.push_step()
        off = c * nb
        for k, step in enumerate(sl.steps):
            for src in range(n):
                for snd in step[src]:
                    pieces = [
                        (frozenset(b + off for b in blocks), kind, contrib)
                        for blocks, kind, contrib in snd.pieces
                    ]
                    out.steps[k][src].append(Send(snd.to, pieces, snd.route))
    return out


def virtual_pad_network(vs, n_real):
    nv = vs.n
    host = lambda v: (v * n_real) // nv
    out = Schedule(vs.name + "-padded", n_real, vs.n_blocks)
    for step in vs.steps:
        st = out.push_step()
        for src in range(nv):
            hsrc = host(src)
            for snd in step[src]:
                hdst = host(snd.to)
                if hsrc == hdst:
                    continue
                st[hsrc].append(Send(hdst, snd.pieces, snd.route))
    return out


def padding_hosts(vtorus, torus):
    """hosts[v] = real rank hosting virtual rank v (per-coordinate floor
    scaling). Mirror of registry::padding_hosts. For rings this reduces to
    (v * n) // nv, so it matches virtual_pad_network's bit-identical map."""
    return [
        torus.rank(
            [
                (c * a) // av
                for c, (av, a) in zip(vtorus.coords(v), zip(vtorus.dims, torus.dims))
            ]
        )
        for v in range(vtorus.n)
    ]


def collapse_by_hosts(s, hosts, n_real, name):
    """Collapse a virtual-space executable schedule onto real ranks via an
    explicit host map (mirror of registry::collapse_by_hosts): co-hosted
    sends drop (local moves), steps are kept even when they empty out."""
    out = Schedule(name, n_real, s.n_blocks)
    for step in s.steps:
        st = out.push_step()
        for src in range(s.n):
            hsrc = hosts[src]
            for snd in step[src]:
                hdst = hosts[snd.to]
                if hsrc == hdst:
                    continue
                st[hsrc].append(Send(hdst, snd.pieces, snd.route))
    return out


def collapse_torus(s, vtorus, torus):
    hosts = padding_hosts(vtorus, torus)
    return collapse_by_hosts(s, hosts, torus.n, s.name + "-padded")


# ------------------------------------------------------------ hierarchical


def lift_phase(out, torus, phase, dim, processed):
    ndims = torus.ndims()

    def lift_blocks(x, ring):
        ranges = []
        for e in range(ndims):
            if e == dim:
                ranges.append(ring)
            elif e in processed:
                ranges.append(frozenset([torus.coord(x, e)]))
            else:
                ranges.append(frozenset(range(torus.dims[e])))
        return torus.product_set(ranges)

    def lift_contrib(x, ring):
        # contributors: processed dims full, `dim` from the ring set, rest
        # pinned to x (mirror of hierarchical::Lift::contrib)
        ranges = []
        for e in range(ndims):
            if e == dim:
                ranges.append(ring)
            elif e in processed:
                ranges.append(frozenset(range(torus.dims[e])))
            else:
                ranges.append(frozenset([torus.coord(x, e)]))
        return torus.product_set(ranges)

    full_n = frozenset(range(torus.n))
    for ring_step in phase.steps:
        st = out.push_step()
        for ring_src in range(phase.n):
            for snd in ring_step[ring_src]:
                for x in range(torus.n):
                    if torus.coord(x, dim) != ring_src:
                        continue
                    c = torus.coords(x)
                    c[dim] = snd.to
                    dst = torus.rank(c)
                    pieces = [
                        (
                            frozenset(lift_blocks(x, blocks)),
                            kind,
                            full_n if kind == "set" else frozenset(lift_contrib(x, contrib)),
                        )
                        for blocks, kind, contrib in snd.pieces
                    ]
                    route = snd.route
                    if route != MIN:
                        route = directed(dim, route[2])
                    st[x].append(Send(dst, pieces, route))


def hierarchical_bandwidth(torus, patterns, dim_order, name):
    out = Schedule(name, torus.n, torus.n)
    processed = []
    for d in dim_order:
        rs = reduce_scatter_schedule(patterns[d])
        lift_phase(out, torus, rs, d, processed)
        processed.append(d)
    for d in reversed(dim_order):
        processed.remove(d)
        ag = allgather_schedule(patterns[d])
        lift_phase(out, torus, ag, d, processed)
    return out


# ------------------------------------------------------------ registry

ALGOS = ["trivance", "bruck", "bruck-unidir", "swing", "recdoub", "bucket"]
VARIANTS = ["L", "B"]


def ring_pattern(algo, n, order):
    if algo == "trivance":
        p = trivance(n, order)
        return p if p.is_complete() else None
    if algo == "bruck":
        p = bruck(n, order, False)
        return p if p.is_complete() else None
    if algo == "bruck-unidir":
        p = bruck(n, order, True)
        return p if p.is_complete() else None
    if algo == "swing":
        return swing(n, order) if is_power_of(2, n) else None
    if algo == "recdoub":
        return recdoub(n, order) if is_power_of(2, n) else None
    if algo == "bucket":
        return hamiltonian(n)
    raise ValueError(algo)


def derive(p, variant):
    return latency_allreduce(p) if variant == "L" else bandwidth_allreduce(p)


def mirrored_family(algo):
    return algo in ("swing", "recdoub", "bucket")


class Built:
    def __init__(self, net, padded, exec_s=None, hosts=None):
        self.net, self.padded = net, padded
        # Mirror of registry::Built.exec / Built.padding.hosts: the
        # pre-collapse executable schedule and its virtual->real host map
        # (exec == net, hosts == None for natively supported sizes).
        self.exec_s = exec_s if exec_s is not None else net
        self.hosts = hosts


def build(algo, variant, torus):
    d = torus.ndims()
    order = "inc" if variant == "L" else "dec"
    native = [ring_pattern(algo, a, order) for a in torus.dims]
    if all(p is not None for p in native):
        dims_steps = [p.num_steps() for p in native]
        slices = []
        single_port_l = mirrored_family(algo) and variant == "L"
        if d == 1 and (not mirrored_family(algo) or single_port_l):
            slices.append(derive(native[0], variant))
        elif single_port_l:
            sd = ProductAg.sequential(dims_steps, 0)
            prod = ProductAg(algo, torus, native, sd)
            slices.append(derive(prod, variant))
        else:
            for start in range(d):
                if variant == "B" and d >= 2:
                    dim_order = [(start + i) % d for i in range(d)]
                    sched = hierarchical_bandwidth(torus, native, dim_order, algo)
                else:
                    if mirrored_family(algo):
                        sd = ProductAg.sequential(dims_steps, start)
                    else:
                        sd = ProductAg.round_robin(dims_steps, start)
                    if d == 1:
                        pat = native[0]
                    else:
                        pat = ProductAg(algo, torus, native, sd)
                    sched = derive(pat, variant)
                if mirrored_family(algo):
                    mirror = permute_schedule(sched, reflection_map(torus))
                    slices.append(sched)
                    slices.append(mirror)
                else:
                    slices.append(sched)
        if len(slices) == 1:
            merged = slices[0]
        else:
            merged = concurrent_slices(slices, algo)
        return Built(merged, False)

    pad_base = 2 if algo in ("swing", "recdoub") else 3
    padded_dims = [pad_base ** ceil_log(pad_base, a) for a in torus.dims]
    if padded_dims == torus.dims:
        return None
    vtorus = Torus(padded_dims)
    inner = build(algo, variant, vtorus)
    if inner is None:
        return None
    # Rust pads from inner.exec; padding never nests here (the padded size
    # is always natively supported), so inner.net == inner.exec.
    hosts = padding_hosts(vtorus, torus)
    if d == 1:
        net = virtual_pad_network(inner.net, torus.n)
    else:
        net = collapse_torus(inner.net, vtorus, torus)
    return Built(net, True, exec_s=inner.net, hosts=hosts)


# ------------------------------------------------------------ SimPlan


class UnreachableError(Exception):
    """Mirror of net::Unreachable (surfaced as SimError::Unroutable): the
    model's down set disconnects a (src, dst) pair the schedule needs."""


class StrandedError(Exception):
    """Mirror of SimError::Stranded: a timeline left traffic permanently
    blocked on a down link. Carries the blocked link and schedule step."""

    def __init__(self, link, step):
        super().__init__(f"traffic stranded on down link {link} (step {step})")
        self.link, self.step = link, step


class Plan:
    def __init__(self, schedule, torus, model=None, route_model=None, switch_step=None, stages=None):
        """`route_model`/`switch_step` mirror SimPlan::build_faulted: steps
        >= switch_step route on route_model (post-fault), earlier steps on
        `model` (pre-fault); scale columns always come from `model`.
        `stages` mirrors SimPlan::build_staged: [(from_step, NetModel), ...]
        sorted by from_step — step k routes on the last stage whose
        from_step <= k, else on `model` (one stage == build_faulted).
        Unreachable pairs raise UnreachableError (typed, never silent)."""
        assert schedule.n == torus.n
        if model is None:
            model = NetModel.uniform(torus)
        assert model.torus.dims == torus.dims
        if stages is None:
            if route_model is None:
                route_model, switch_step = model, schedule.num_steps()
            stages = [(switch_step, route_model)]
        else:
            assert route_model is None and switch_step is None
            assert all(a[0] <= b[0] for a, b in zip(stages, stages[1:]))
        self.n = schedule.n
        self.nsteps = schedule.num_steps()
        self.num_links = torus.num_links()
        self.bw_scale = list(model.bw_scale)
        self.lat_scale = list(model.lat_scale)
        self.proc_scale = list(model.proc_scale)
        self.uniform = model.is_uniform()
        self.msgs = []  # (src, dst, step, rel_bytes, route)
        for k, step in enumerate(schedule.steps):
            router = model
            for frm, m in stages:
                if k >= frm:
                    router = m
                else:
                    break
            for src in range(self.n):
                for snd in step[src]:
                    rel = snd.rel_bytes(schedule.n_blocks)
                    if rel <= 0.0:
                        continue
                    try:
                        route = router.route(src, snd.to, snd.route)
                    except AssertionError as e:
                        raise UnreachableError(str(e)) from None
                    self.msgs.append((src, snd.to, k, rel, route))
        self.inject = {}
        self.expected = {}
        for i, (src, dst, k, rel, route) in enumerate(self.msgs):
            self.inject.setdefault((src, k), []).append(i)
            self.expected[(dst, k)] = self.expected.get((dst, k), 0) + 1

    def injections(self, node, step):
        return self.inject.get((node, step), [])

    def expected_count(self, node, step):
        return self.expected.get((node, step), 0)

    def bytes(self, i, m_bytes):
        return self.msgs[i][3] * float(m_bytes)

    def bottleneck_serialization_s(self, m_bytes, params):
        load = [0.0] * self.num_links
        for (src, dst, k, rel, route) in self.msgs:
            b = rel * float(m_bytes)
            for l in route:
                load[l] += b
        worst = max(
            (load[l] / self.bw_scale[l] for l in range(self.num_links)),
            default=0.0,
        )
        return worst * 8.0 / params["bw"]


def link_caps(plan, params):
    """Per-link capacity in bytes/s (== the scalar cap when uniform)."""
    cap = params["bw"] / 8.0
    return [cap * s for s in plan.bw_scale]


def link_hop_lat(plan, params):
    """Per-link forwarding latency (propagation + processing, scaled)."""
    return [
        ls * params["link_lat"] + ps * params["hop_lat"]
        for ls, ps in zip(plan.lat_scale, plan.proc_scale)
    ]


def msg_hop_lat(plan, params):
    """Total route forwarding latency per message. Uniform plans keep the
    historical `hops * per_hop` product so results stay bit-identical."""
    ph = per_hop(params)
    if plan.uniform:
        return [len(m[4]) * ph for m in plan.msgs]
    hop = link_hop_lat(plan, params)
    return [sum(hop[l] for l in m[4]) for m in plan.msgs]


DEFAULT_PARAMS = {"alpha": 1.5e-6, "bw": 800e9, "link_lat": 100e-9, "hop_lat": 100e-9}


def per_hop(p):
    return p["link_lat"] + p["hop_lat"]


# ------------------------------------------------------------ flow simulator

TIME_EPS = 1e-15
SHARE_EPS = 1e-12


def simulate_flow(plan, m_bytes, params):
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0
    cap = params["bw"] / 8.0
    caps = link_caps(plan, params)
    mhl = msg_hop_lat(plan, params)

    received = [0] * (n * nsteps)
    entered = [-1] * n
    heap = []
    seq = 0

    def push(t, ev):
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, seq, ev))

    for r in range(n):
        push(params["alpha"], ("step", r, 0))

    active = []  # [msg, remaining, rate]
    nactive = [0] * plan.num_links
    touched = []
    in_touched = [False] * plan.num_links
    residual = [0.0] * plan.num_links
    unfrozen = [0] * plan.num_links
    now = 0.0
    completion = 0.0
    events = 0
    need_recompute = False

    def wf_inject(route):
        for l in route:
            if not in_touched[l]:
                in_touched[l] = True
                touched.append(l)
            nactive[l] += 1

    def wf_drain(route):
        for l in route:
            nactive[l] -= 1

    def recompute():
        nonlocal touched
        keep = []
        for l in touched:
            if nactive[l] == 0:
                in_touched[l] = False
            else:
                residual[l] = caps[l]
                unfrozen[l] = nactive[l]
                keep.append(l)
        touched = keep

        unfrozen_flows = list(range(len(active)))
        while unfrozen_flows:
            min_share = float("inf")
            for l in touched:
                if unfrozen[l] > 0:
                    share = residual[l] / unfrozen[l]
                    if share < min_share:
                        min_share = share
            if min_share == float("inf"):
                for fi in unfrozen_flows:
                    active[fi][2] = cap
                break
            freeze = []
            i = 0
            while i < len(unfrozen_flows):
                fi = unfrozen_flows[i]
                share = float("inf")
                for l in plan.msgs[active[fi][0]][4]:
                    s = residual[l] / max(unfrozen[l], 1)
                    if s < share:
                        share = s
                if share <= min_share * (1.0 + SHARE_EPS):
                    freeze.append(fi)
                    unfrozen_flows[i] = unfrozen_flows[-1]
                    unfrozen_flows.pop()
                else:
                    i += 1
            if not freeze:
                for fi in unfrozen_flows:
                    active[fi][2] = min_share
                break
            for fi in freeze:
                active[fi][2] = min_share
                for l in plan.msgs[active[fi][0]][4]:
                    residual[l] -= min_share
                    if residual[l] < 0.0:
                        residual[l] = 0.0
                    unfrozen[l] -= 1

    while True:
        t_event = heap[0][0] if heap else float("inf")
        t_drain = float("inf")
        for f in active:
            if f[2] > 0.0:
                t = now + f[1] / f[2]
                if t < t_drain:
                    t_drain = t
        t_next = min(t_event, t_drain)
        if t_next == float("inf"):
            break
        dt = t_next - now
        if dt > 0.0:
            for f in active:
                f[1] -= f[2] * dt
        now = t_next

        i = 0
        while i < len(active):
            f = active[i]
            if f[1] <= f[2] * TIME_EPS + 1e-9 * TIME_EPS or f[1] <= 1e-7:
                active[i] = active[-1]
                active.pop()
                src, dst, k, rel, route = plan.msgs[f[0]]
                wf_drain(route)
                push(now + mhl[f[0]], ("deliv", dst, k))
                need_recompute = True
            else:
                i += 1

        while heap and heap[0][0] <= now + max(TIME_EPS, now * 1e-12):
            _, _, ev = heapq.heappop(heap)
            events += 1
            if ev[0] == "step":
                _, node, step = ev
                entered[node] = step
                for mi in plan.injections(node, step):
                    active.append([mi, plan.bytes(mi, m_bytes), 0.0])
                    wf_inject(plan.msgs[mi][4])
                    need_recompute = True
                if (
                    plan.expected_count(node, step) == received[node * nsteps + step]
                    and step + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, step + 1))
            else:
                _, node, k = ev
                completion = max(completion, now)
                received[node * nsteps + k] += 1
                if (
                    received[node * nsteps + k] == plan.expected_count(node, k)
                    and entered[node] == k
                    and k + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, k + 1))

        if need_recompute:
            recompute()
            need_recompute = False

    return completion, events


# ---------------------------------------------- reference packet simulator
# Mirror of the pre-overhaul per-packet engine (one heap event per packet
# per hop), with f64 packet sizes (the f32 narrowing is a Rust-level detail
# that Python cannot reproduce; its effect is bounded separately).


def simulate_packet_ref(plan, m_bytes, params, mtu):
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0
    caps = link_caps(plan, params)
    hops = link_hop_lat(plan, params)

    received = [0] * (n * nsteps)
    entered = [-1] * n
    pkts_left = []
    for i in range(len(plan.msgs)):
        b = plan.bytes(i, m_bytes)
        pkts_left.append(max(int(-(-b // mtu)), 1))
    free_at = [0.0] * plan.num_links
    heap = []
    seq = 0

    def push(t, ev):
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, seq, ev))

    for r in range(n):
        push(params["alpha"], ("step", r, 0))

    completion = 0.0
    events = 0
    while heap:
        now, _, ev = heapq.heappop(heap)
        events += 1
        if ev[0] == "step":
            _, node, step = ev
            entered[node] = step
            for mi in plan.injections(node, step):
                full = pkts_left[mi]
                left = plan.bytes(mi, m_bytes)
                for _ in range(full):
                    sz = min(left, float(mtu))
                    left -= min(sz, left)
                    push(now, ("pkt", mi, 0, sz))
            if (
                plan.expected_count(node, step) == received[node * nsteps + step]
                and step + 1 < nsteps
            ):
                push(now + params["alpha"], ("step", node, step + 1))
        else:
            _, mi, hop, sz = ev
            src, dst, k, rel, route = plan.msgs[mi]
            if hop == len(route):
                pkts_left[mi] -= 1
                if pkts_left[mi] == 0:
                    completion = max(completion, now)
                    received[dst * nsteps + k] += 1
                    if (
                        received[dst * nsteps + k] == plan.expected_count(dst, k)
                        and entered[dst] == k
                        and k + 1 < nsteps
                    ):
                        push(now + params["alpha"], ("step", dst, k + 1))
            else:
                l = route[hop]
                start = max(now, free_at[l])
                end = start + sz / caps[l]
                free_at[l] = end
                push(end + hops[l], ("pkt", mi, hop + 1, sz))
    return completion, events


# --------------------------------------------------- calendar event queue
# Mirror of rust/src/sim/events.rs: an O(1)-amortized bucketed calendar
# queue selectable in place of the binary heap. Pop order is the strict
# (t, seq) total order — identical to heapq on the same push sequence —
# so the queue kinds are bit-interchangeable; eval_core.py asserts it.
# Keep the day arithmetic, resize thresholds, and rebuild width derivation
# in exact lockstep with events.rs (same f64 expressions).

CAL_MIN_BUCKETS = 4
CAL_INIT_WIDTH = 1e-6  # one day ~ 1 us — the engines' natural scale
CAL_MIN_WIDTH = 1e-12


class CalendarQueue:
    """buckets[d % nbuckets] holds every pending (t, seq, ev) whose day is
    d, unsorted. Grows when occupancy exceeds 2/bucket, shrinks below 1/2
    per bucket; each rebuild re-derives the day width from the pending span
    (target ~2 events per day)."""

    def __init__(self):
        self.buckets = [[] for _ in range(CAL_MIN_BUCKETS)]
        self.len = 0
        self.width = CAL_INIT_WIDTH
        self.cur_day = 0
        self.resizes = 0
        self.scanned = 0

    def day(self, t):
        return int(t / self.width)

    def push(self, e):
        d = self.day(e[0])
        # an earlier-than-cursor push rewinds the cursor (mirror: events.rs)
        if self.len == 0 or d < self.cur_day:
            self.cur_day = d
        self.buckets[d % len(self.buckets)].append(e)
        self.len += 1
        if self.len > 2 * len(self.buckets):
            self._rebuild(len(self.buckets) * 2)

    def pop(self):
        if self.len == 0:
            return None
        nb = len(self.buckets)
        for _ in range(nb):
            b = self.cur_day % nb
            i = self._min_of_day_in(b, self.cur_day)
            if i is not None:
                return self._take(b, i)
            self.cur_day += 1
        # a full lap found nothing: the earliest event is > nbuckets days
        # out; find it directly and jump the cursor to its day
        b, i, t = self._global_min()
        self.cur_day = self.day(t)
        return self._take(b, i)

    def _min_of_day_in(self, b, d):
        best = None
        w = self.width
        for i, e in enumerate(self.buckets[b]):
            self.scanned += 1
            if int(e[0] / w) != d:
                continue
            if best is None or e[:2] < best[0]:
                best = (e[:2], i)
        return None if best is None else best[1]

    def _global_min(self):
        best = None
        for b, bucket in enumerate(self.buckets):
            for i, e in enumerate(bucket):
                self.scanned += 1
                if best is None or e[:2] < best[0]:
                    best = (e[:2], b, i)
        key, b, i = best
        return b, i, key[0]

    def _take(self, b, i):
        bucket = self.buckets[b]
        e = bucket[i]
        bucket[i] = bucket[-1]  # swap_remove: in-bucket order is irrelevant
        bucket.pop()
        self.len -= 1
        if len(self.buckets) > CAL_MIN_BUCKETS and self.len * 2 < len(self.buckets):
            self._rebuild(len(self.buckets) // 2)
        return e

    def _rebuild(self, nb):
        nb = max(nb, CAL_MIN_BUCKETS)
        self.resizes += 1
        all_e = [e for b in self.buckets for e in b]
        if all_e:
            min_t = min(e[0] for e in all_e)
            max_t = max(e[0] for e in all_e)
            span = max_t - min_t
            if span > 0.0:
                self.width = max(span * 2.0 / len(all_e), CAL_MIN_WIDTH)
            self.cur_day = int(min_t / self.width)
        self.buckets = [[] for _ in range(nb)]
        for e in all_e:
            self.buckets[self.day(e[0]) % nb].append(e)


class EventQueue:
    """push/pop facade over heapq or CalendarQueue with op counters.
    Mirror of sim::events::EventQueue (seq assignment included, so either
    kind sees the identical (t, seq, ev) stream)."""

    def __init__(self, kind="heap"):
        if kind not in ("heap", "calendar"):
            raise ValueError(f"unknown queue kind: {kind}")
        self.heap = [] if kind == "heap" else None
        self.cal = CalendarQueue() if kind == "calendar" else None
        self.seq = 0
        self.pushes = 0
        self.pops = 0
        self.peak_len = 0

    def push(self, t, ev):
        self.seq += 1
        self.pushes += 1
        if self.heap is not None:
            heapq.heappush(self.heap, (t, self.seq, ev))
            n = len(self.heap)
        else:
            self.cal.push((t, self.seq, ev))
            n = self.cal.len
        if n > self.peak_len:
            self.peak_len = n

    def pop(self):
        if self.heap is not None:
            e = heapq.heappop(self.heap) if self.heap else None
        else:
            e = self.cal.pop()
        if e is not None:
            self.pops += 1
        return e

    def size(self):
        return len(self.heap) if self.heap is not None else self.cal.len

    def stats(self):
        return {
            "pushes": self.pushes,
            "pops": self.pops,
            "peak_len": self.peak_len,
            "resizes": self.cal.resizes if self.cal is not None else 0,
            "scanned": self.cal.scanned if self.cal is not None else 0,
        }


# ------------------------------------------------ batched packet simulator
# The overhauled engine: each message's packets on a link are one contiguous
# busy interval; heap traffic is O(messages x hops). Must stay in sync with
# rust/src/sim/packet.rs.


def simulate_packet_batched(plan, m_bytes, params, mtu, queue="heap"):
    completion, events, _ = simulate_packet_batched_stats(plan, m_bytes, params, mtu, queue)
    return completion, events


def simulate_packet_batched_stats(plan, m_bytes, params, mtu, queue="heap", sink=None):
    """As simulate_packet_batched but also returns the queue op counters.
    Mirror of packet::simulate_packet_plan_queue. When `sink` is a list,
    one per-link telemetry row (the mirror of obs::LinkSample — same keys
    as TRACE.json's `link_telemetry`) is appended per busy interval;
    sink=None skips telemetry entirely (the NoopSink path), and the
    returned numbers must be identical either way (eval_obs.py pins it)."""
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0, EventQueue(queue).stats()
    caps = link_caps(plan, params)
    hops = link_hop_lat(plan, params)

    received = [0] * (n * nsteps)
    entered = [-1] * n
    free_at = [0.0] * plan.num_links
    q = EventQueue(queue)
    push = q.push

    for r in range(n):
        push(params["alpha"], ("step", r, 0))

    completion = 0.0
    events = 0
    while True:
        e = q.pop()
        if e is None:
            break
        now, _, ev = e
        events += 1
        if ev[0] == "step":
            _, node, step = ev
            entered[node] = step
            for mi in plan.injections(node, step):
                # ready = when the batch's last byte is available here (the
                # whole payload is local at injection)
                push(now, ("batch", mi, 0, now))
            if (
                plan.expected_count(node, step) == received[node * nsteps + step]
                and step + 1 < nsteps
            ):
                push(now + params["alpha"], ("step", node, step + 1))
        else:
            _, mi, hop, ready = ev
            src, dst, k, rel, route = plan.msgs[mi]
            if hop == len(route):
                completion = max(completion, now)
                received[dst * nsteps + k] += 1
                if (
                    received[dst * nsteps + k] == plan.expected_count(dst, k)
                    and entered[dst] == k
                    and k + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", dst, k + 1))
            else:
                total = plan.bytes(mi, m_bytes)
                l = route[hop]
                start = max(now, free_at[l])
                # the batch cannot finish serializing before its last byte
                # arrived from upstream (`ready`); on a uniform model the
                # serialization term always dominates, so the max is exact
                # legacy behaviour
                batch_end = max(start + total / caps[l], ready)
                free_at[l] = batch_end
                tail_ready = batch_end + hops[l]
                if sink is not None:
                    sink.append(
                        {
                            "link": l,
                            "step": k,
                            "start_s": start,
                            "end_s": batch_end,
                            "bytes": total,
                            "cap_bytes_per_s": caps[l],
                            "queue_len": q.size(),
                        }
                    )
                if hop + 1 == len(route):
                    # last link: the tail packet arrives per_hop after the
                    # batch fully serializes
                    push(tail_ready, ("batch", mi, hop + 1, tail_ready))
                else:
                    # cut-through: the head packet is available at the next
                    # link one head-serialization + per_hop after the batch
                    # starts (the head packet is the largest — the only
                    # short packet is the tail — so with `ready` carrying
                    # the tail arrival downstream the schedule never
                    # outruns the bytes, even across rate changes).
                    head = min(total, float(mtu))
                    push(start + head / caps[l] + hops[l], ("batch", mi, hop + 1, tail_ready))
    return completion, events, q.stats()


# ------------------------------------------------------- dynamic fabrics
# Mirror of rust/src/net/timeline.rs + the *_timeline engines (flow epochs
# / packet busy-interval splitting), SimPlan::build_faulted (see Plan), and
# schedule::rewrite. Keep epoch application order, donor selection, and the
# preset window arithmetic in lockstep with Rust.


class Timeline:
    """Epochs: [(t, [mutation, ...])] sorted by t. Mutations:
    ("class", link, bw_scale, lat_scale, proc_scale) | ("down", link, flag)."""

    def __init__(self, epochs):
        for t, _ in epochs:
            assert t >= 0.0
        self.epochs = sorted(epochs, key=lambda e: e[0])

    def is_empty(self):
        return not self.epochs


EMPTY_TIMELINE = Timeline([])


def simulate_flow_dyn(plan, m_bytes, params, timeline):
    """Flow engine under a timeline: one epoch event per epoch, rates
    re-water-filled with the capacities in force (down = capacity 0, flows
    stall). Mirror of flow::simulate_flow_plan_timeline."""
    if timeline.is_empty():
        return simulate_flow(plan, m_bytes, params)
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0
    cap = params["bw"] / 8.0
    caps_up = link_caps(plan, params)
    caps_eff = list(caps_up)
    down = [False] * plan.num_links
    link_hop = link_hop_lat(plan, params)

    received = [0] * (n * nsteps)
    entered = [-1] * n
    heap = []
    seq = 0

    def push(t, ev):
        nonlocal seq
        seq += 1
        heapq.heappush(heap, (t, seq, ev))

    for r in range(n):
        push(params["alpha"], ("step", r, 0))
    for ei, (t, _) in enumerate(timeline.epochs):
        push(t, ("epoch", ei, 0))

    active = []  # [msg, remaining, rate]
    nactive = [0] * plan.num_links
    touched = []
    in_touched = [False] * plan.num_links
    residual = [0.0] * plan.num_links
    unfrozen = [0] * plan.num_links
    now = 0.0
    completion = 0.0
    events = 0
    need_recompute = False

    def wf_inject(route):
        for l in route:
            if not in_touched[l]:
                in_touched[l] = True
                touched.append(l)
            nactive[l] += 1

    def wf_drain(route):
        for l in route:
            nactive[l] -= 1

    def recompute():
        nonlocal touched
        keep = []
        for l in touched:
            if nactive[l] == 0:
                in_touched[l] = False
            else:
                residual[l] = caps_eff[l]
                unfrozen[l] = nactive[l]
                keep.append(l)
        touched = keep
        unfrozen_flows = list(range(len(active)))
        while unfrozen_flows:
            min_share = float("inf")
            for l in touched:
                if unfrozen[l] > 0:
                    share = residual[l] / unfrozen[l]
                    if share < min_share:
                        min_share = share
            if min_share == float("inf"):
                for fi in unfrozen_flows:
                    active[fi][2] = cap
                break
            freeze = []
            i = 0
            while i < len(unfrozen_flows):
                fi = unfrozen_flows[i]
                share = float("inf")
                for l in plan.msgs[active[fi][0]][4]:
                    s = residual[l] / max(unfrozen[l], 1)
                    if s < share:
                        share = s
                if share <= min_share * (1.0 + SHARE_EPS):
                    freeze.append(fi)
                    unfrozen_flows[i] = unfrozen_flows[-1]
                    unfrozen_flows.pop()
                else:
                    i += 1
            if not freeze:
                for fi in unfrozen_flows:
                    active[fi][2] = min_share
                break
            for fi in freeze:
                active[fi][2] = min_share
                for l in plan.msgs[active[fi][0]][4]:
                    residual[l] -= min_share
                    if residual[l] < 0.0:
                        residual[l] = 0.0
                    unfrozen[l] -= 1

    while True:
        t_event = heap[0][0] if heap else float("inf")
        t_drain = float("inf")
        for f in active:
            if f[2] > 0.0:
                t = now + f[1] / f[2]
                if t < t_drain:
                    t_drain = t
        t_next = min(t_event, t_drain)
        if t_next == float("inf"):
            break
        dt = t_next - now
        if dt > 0.0:
            for f in active:
                f[1] -= f[2] * dt
        now = t_next

        i = 0
        while i < len(active):
            f = active[i]
            if f[1] <= f[2] * TIME_EPS + 1e-9 * TIME_EPS or f[1] <= 1e-7:
                active[i] = active[-1]
                active.pop()
                src, dst, k, rel, route = plan.msgs[f[0]]
                wf_drain(route)
                lat = sum(link_hop[l] for l in route)
                push(now + lat, ("deliv", dst, k))
                need_recompute = True
            else:
                i += 1

        while heap and heap[0][0] <= now + max(TIME_EPS, now * 1e-12):
            _, _, ev = heapq.heappop(heap)
            events += 1
            if ev[0] == "step":
                _, node, step = ev
                entered[node] = step
                for mi in plan.injections(node, step):
                    active.append([mi, plan.bytes(mi, m_bytes), 0.0])
                    wf_inject(plan.msgs[mi][4])
                    need_recompute = True
                if (
                    plan.expected_count(node, step) == received[node * nsteps + step]
                    and step + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, step + 1))
            elif ev[0] == "deliv":
                _, node, k = ev
                completion = max(completion, now)
                received[node * nsteps + k] += 1
                if (
                    received[node * nsteps + k] == plan.expected_count(node, k)
                    and entered[node] == k
                    and k + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", node, k + 1))
            else:  # epoch
                _, ei, _ = ev
                for m in timeline.epochs[ei][1]:
                    if m[0] == "class":
                        _, l, bw, lat, proc = m
                        caps_up[l] = cap * bw
                        link_hop[l] = lat * params["link_lat"] + proc * params["hop_lat"]
                        caps_eff[l] = 0.0 if down[l] else caps_up[l]
                    else:
                        _, l, flag = m
                        down[l] = flag
                        caps_eff[l] = 0.0 if flag else caps_up[l]
                need_recompute = True

        if need_recompute:
            recompute()
            need_recompute = False

    if active:
        # Mirror of flow.rs stranded reporting: lowest-msg-id stranded flow,
        # first zero-capacity link on its route, the message's step.
        f = min(active, key=lambda fl: fl[0])
        route = plan.msgs[f[0]][4]
        link = next((l for l in route if caps_eff[l] == 0.0), route[0] if route else 0)
        raise StrandedError(link, plan.msgs[f[0]][2])
    return completion, events


def _build_tracks(plan, params, timeline):
    """Per-link (t, cap, hop) change tracks for mutated links (None =
    static). Mirror of packet::build_tracks."""
    base_cap = params["bw"] / 8.0
    tracks = [None] * plan.num_links
    cur_up = link_caps(plan, params)
    cur_hop = link_hop_lat(plan, params)
    cur_down = [False] * plan.num_links
    for t, muts in timeline.epochs:
        for m in muts:
            l = m[1]
            if m[0] == "class":
                _, _, bw, lat, proc = m
                cur_up[l] = base_cap * bw
                cur_hop[l] = lat * params["link_lat"] + proc * params["hop_lat"]
            else:
                cur_down[l] = m[2]
            cap = 0.0 if cur_down[l] else cur_up[l]
            if tracks[l] is None:
                tracks[l] = []
            tracks[l].append((t, cap, cur_hop[l]))
    return tracks


def _serialize_end(track, cap0, start, nbytes):
    """None = the track ends at rate 0 with bytes left (stranded); the
    caller raises StrandedError with link + step context."""
    if track is None:
        return start + nbytes / cap0
    if nbytes <= 0.0:
        return start
    rate = cap0
    idx = 0
    while idx < len(track) and track[idx][0] <= start:
        rate = track[idx][1]
        idx += 1
    remaining = nbytes
    cur = start
    while True:
        next_t = track[idx][0] if idx < len(track) else float("inf")
        if rate > 0.0:
            fin = cur + remaining / rate
            if fin <= next_t:
                return fin
            remaining -= rate * (next_t - cur)
            if remaining < 0.0:
                remaining = 0.0
        elif next_t == float("inf"):
            return None
        cur = next_t
        rate = track[idx][1]
        idx += 1


def _hop_at(track, hop0, t):
    if track is None:
        return hop0
    h = hop0
    for pt, _, ph in track:
        if pt <= t:
            h = ph
        else:
            break
    return h


def simulate_packet_dyn(plan, m_bytes, params, mtu, timeline, queue="heap"):
    completion, events, _ = simulate_packet_dyn_stats(plan, m_bytes, params, mtu, timeline, queue)
    return completion, events


def simulate_packet_dyn_stats(plan, m_bytes, params, mtu, timeline, queue="heap", sink=None):
    """Batched packet engine under a timeline: busy intervals split at
    epoch boundaries. Mirror of packet::simulate_packet_plan_timeline_queue
    (op counters included; `sink` as in simulate_packet_batched_stats —
    `cap_bytes_per_s` stays the pristine capacity, so a brownout shows up
    as achieved bandwidth below cap, never as a mutated cap column)."""
    if timeline.is_empty():
        return simulate_packet_batched_stats(plan, m_bytes, params, mtu, queue, sink)
    n, nsteps = plan.n, plan.nsteps
    if nsteps == 0:
        return 0.0, 0, EventQueue(queue).stats()
    caps = link_caps(plan, params)
    hops = link_hop_lat(plan, params)
    tracks = _build_tracks(plan, params, timeline)

    received = [0] * (n * nsteps)
    entered = [-1] * n
    free_at = [0.0] * plan.num_links
    q = EventQueue(queue)
    push = q.push

    for r in range(n):
        push(params["alpha"], ("step", r, 0))

    completion = 0.0
    events = 0
    while True:
        e = q.pop()
        if e is None:
            break
        now, _, ev = e
        events += 1
        if ev[0] == "step":
            _, node, step = ev
            entered[node] = step
            for mi in plan.injections(node, step):
                push(now, ("batch", mi, 0, now))
            if (
                plan.expected_count(node, step) == received[node * nsteps + step]
                and step + 1 < nsteps
            ):
                push(now + params["alpha"], ("step", node, step + 1))
        else:
            _, mi, hop, ready = ev
            src, dst, k, rel, route = plan.msgs[mi]
            if hop == len(route):
                completion = max(completion, now)
                received[dst * nsteps + k] += 1
                if (
                    received[dst * nsteps + k] == plan.expected_count(dst, k)
                    and entered[dst] == k
                    and k + 1 < nsteps
                ):
                    push(now + params["alpha"], ("step", dst, k + 1))
            else:
                total = plan.bytes(mi, m_bytes)
                l = route[hop]
                start = max(now, free_at[l])
                end = _serialize_end(tracks[l], caps[l], start, total)
                if end is None:
                    raise StrandedError(l, k)
                batch_end = max(end, ready)
                free_at[l] = batch_end
                tail_ready = batch_end + _hop_at(tracks[l], hops[l], batch_end)
                if sink is not None:
                    sink.append(
                        {
                            "link": l,
                            "step": k,
                            "start_s": start,
                            "end_s": batch_end,
                            "bytes": total,
                            "cap_bytes_per_s": caps[l],
                            "queue_len": q.size(),
                        }
                    )
                if hop + 1 == len(route):
                    push(tail_ready, ("batch", mi, hop + 1, tail_ready))
                else:
                    head = min(total, float(mtu))
                    head_end = _serialize_end(tracks[l], caps[l], start, head)
                    if head_end is None:
                        raise StrandedError(l, k)
                    push(
                        head_end + _hop_at(tracks[l], hops[l], head_end),
                        ("batch", mi, hop + 1, tail_ready),
                    )
    return completion, events, q.stats()


# --------------------------------------------------- fault-aware rewriting
# Mirror of rust/src/schedule/rewrite.rs.


class Fault:
    def __init__(self, step, down_links=(), dead_nodes=()):
        self.step = step
        self.down_links = list(down_links)
        self.dead_nodes = list(dead_nodes)

    @staticmethod
    def link(step, link):
        return Fault(step, [link])

    @staticmethod
    def node(step, node):
        return Fault(step, dead_nodes=[node])

    def apply(self, base):
        post = NetModel(base.torus)
        post.bw_scale = list(base.bw_scale)
        post.lat_scale = list(base.lat_scale)
        post.proc_scale = list(base.proc_scale)
        post.down = list(base.down)
        t = base.torus
        for l in self.down_links:
            post.down[l] = True
        for node in self.dead_nodes:
            for d in range(t.ndims()):
                for dr in (1, -1):
                    post.down[t.link_index(node, d, dr)] = True
                    nb = t.neighbor(node, d, -dr)
                    post.down[t.link_index(nb, d, dr)] = True
        return post


def _max_cover(atoms, target):
    cover = set()
    for a in atoms:
        if a <= target:
            cover |= a
    return frozenset(cover)


def rewrite_for_fault(s, base, fault):
    return rewrite_for_fault_hosted(s, base, fault, None)


def rewrite_for_fault_hosted(s, base, fault, hosts=None):
    """Shrink-and-substitute schedule rewrite (see schedule::rewrite).
    Returns a new Schedule; raises ValueError on unrecoverable faults.
    `hosts` translates virtual ranks of a padded executable schedule onto
    the real torus (mirror of rewrite_for_fault_hosted); without it, a
    virtual (padded) contributor space is refused."""
    torus = base.torus
    if hosts is None:
        assert s.n == torus.n
        real = lambda v: v
    else:
        assert len(hosts) == s.n
        real = lambda v: hosts[v]
    n, nb = s.n, s.n_blocks
    if hosts is None:
        for step in s.steps:
            for sends in step:
                for snd in sends:
                    for _b, _k, contrib in snd.pieces:
                        if any(c >= n for c in contrib):
                            raise ValueError("padded (virtual) contributor space")
    post = fault.apply(base)
    dead_real = [False] * torus.n
    for v in fault.dead_nodes:
        dead_real[v] = True
    dead = lambda v: dead_real[real(v)]

    full = frozenset(range(n))
    # state[r][b] = list of atoms; totals cached separately
    state = [[[frozenset([r])] for _ in range(nb)] for r in range(n)]

    def total(r, b):
        t = set()
        for a in state[r][b]:
            t |= a
        return t

    def absorb(r, b, kind, contrib):
        if kind == "reduce":
            state[r][b].append(contrib)
        else:
            state[r][b] = [full]

    out = Schedule(s.name + "+rewrite", n, nb)
    for k, step in enumerate(s.steps):
        snapshot = [[list(cell) for cell in row] for row in state]
        new_step = out.push_step()
        for src in range(n):
            for snd in step[src]:
                if k < fault.step:
                    keep = snd
                elif dead(src) or dead(snd.to):
                    keep = None
                elif real(src) == real(snd.to):
                    # co-hosted after padding collapse: a local move, never
                    # blocked by the fabric — shrink only
                    keep = _shrink_send(snd, snapshot[src], n, full)
                else:
                    try:
                        nominal = base.route(real(src), real(snd.to), snd.route)
                    except AssertionError as e:
                        raise ValueError(f"nominal route unavailable: {e}") from None
                    if any(post.down[l] for l in nominal):
                        keep = None
                    else:
                        keep = _shrink_send(snd, snapshot[src], n, full)
                if keep is not None:
                    for blocks, kind, contrib in keep.pieces:
                        for b in blocks:
                            absorb(keep.to, b, kind, contrib)
                    new_step[src].append(keep)

    snapshot = [[list(cell) for cell in row] for row in state]
    cleanup = [[] for _ in range(n)]
    any_cleanup = False
    for r in range(n):
        if dead(r):
            continue
        dist_to_r = post.distances_to(real(r))
        set_groups = []  # [(donor, [blocks])]
        reduce_groups = []  # [(donor, contrib, [blocks])]
        for b in range(nb):
            held = total(r, b)
            if held == full:
                continue
            missing = full - held
            set_donor = None  # (dist, donor)
            for d in range(n):
                if d == r or dead(d):
                    continue
                dt = set()
                for a in snapshot[d][b]:
                    dt |= a
                if dt != full:
                    continue
                dist = dist_to_r[real(d)]
                if dist is None:
                    continue
                if set_donor is None or dist < set_donor[0]:
                    set_donor = (dist, d)
            if set_donor is not None:
                d = set_donor[1]
                for g in set_groups:
                    if g[0] == d:
                        g[1].append(b)
                        break
                else:
                    set_groups.append((d, [b]))
                continue
            m = missing
            while m:
                best = None  # (len, dist, donor, cover)
                for d in range(n):
                    if d == r or dead(d):
                        continue
                    cover = _max_cover(snapshot[d][b], m)
                    if not cover:
                        continue
                    dist = dist_to_r[real(d)]
                    if dist is None:
                        continue
                    if best is None or len(cover) > best[0] or (
                        len(cover) == best[0] and dist < best[1]
                    ):
                        best = (len(cover), dist, d, cover)
                if best is None:
                    raise ValueError(
                        f"unrecoverable: node {r} block {b} missing {sorted(m)}"
                    )
                _, _, d, cover = best
                m = m - cover
                for g in reduce_groups:
                    if g[0] == d and g[1] == cover:
                        g[2].append(b)
                        break
                else:
                    reduce_groups.append((d, cover, [b]))
        for d, blocks in set_groups:
            any_cleanup = True
            cleanup[d].append(Send(r, [(frozenset(blocks), "set", full)], MIN))
        for d, contrib, blocks in reduce_groups:
            any_cleanup = True
            cleanup[d].append(Send(r, [(frozenset(blocks), "reduce", contrib)], MIN))
    if any_cleanup:
        st = out.push_step()
        for src in range(n):
            for snd in cleanup[src]:
                for blocks, kind, contrib in snd.pieces:
                    for b in blocks:
                        absorb(snd.to, b, kind, contrib)
                st[src].append(snd)

    for r in range(n):
        if dead(r):
            continue
        for b in range(nb):
            if total(r, b) != full:
                raise ValueError(f"internal rewrite error: node {r} block {b}")
    return out


def rewrite_for_faults(s, base, faults, hosts=None):
    """Iterative multi-fault rewrite (mirror of rewrite_for_faults_hosted):
    each fault rewrites the current schedule — cleanup steps included —
    against the model as degraded by the previous faults, then degrades the
    model further."""
    sched, model = s, base
    for f in faults:
        sched = rewrite_for_fault_hosted(sched, model, f, hosts)
        model = f.apply(model)
    return sched


def rewrite_collective_for_faults(b, base, faults):
    """Mirror of rewrite_collective_for_faults: native builds rewrite the
    net schedule directly; padded builds rewrite the *executable* schedule
    through the padding host map, then collapse back onto real ranks."""
    if b.hosts is None:
        return rewrite_for_faults(b.net, base, faults)
    rw = rewrite_for_faults(b.exec_s, base, faults, b.hosts)
    return collapse_by_hosts(rw, b.hosts, base.torus.n, b.net.name + "+rewrite")


def _shrink_send(snd, sender_cells, n, full):
    pieces = []
    for blocks, kind, contrib in snd.pieces:
        if kind == "reduce":
            groups = []  # [(cover, [blocks])]
            for b in sorted(blocks):
                cover = _max_cover(sender_cells[b], contrib)
                if not cover:
                    continue
                for g in groups:
                    if g[0] == cover:
                        g[1].append(b)
                        break
                else:
                    groups.append((cover, [b]))
            for cover, bs in groups:
                pieces.append((frozenset(bs), "reduce", cover))
        else:
            kept = [
                b
                for b in sorted(blocks)
                if frozenset().union(*sender_cells[b]) == full
            ]
            if kept:
                pieces.append((frozenset(kept), "set", contrib))
    if not pieces:
        return None
    return Send(snd.to, pieces, snd.route)


# ----------------------------------------------------- dynamic presets
# Mirror of harness::scenarios dynamic_presets window arithmetic.

FLAP_SEED = 0x5EED0003
DYNAMIC_NAMES = ["flap", "brownout", "mid-fault-detour", "mid-fault-rewrite"]


def dynamic_timeline(name, torus, params, m_bytes):
    ser = m_bytes * 8.0 / params["bw"]
    if name == "flap":
        l = pick_links(torus, 1, FLAP_SEED, keep_connected=False)[0]
        t0 = params["alpha"] + 0.25 * ser
        t1 = t0 + 2.0 * ser
        if t1 <= t0:
            return EMPTY_TIMELINE
        return Timeline([(t0, [("down", l, True)]), (t1, [("down", l, False)])])
    if name == "brownout":
        if ser <= 0.0:
            return EMPTY_TIMELINE
        degrade = [
            ("class", torus.link_index(node, 0, 1), 0.25, 1.0, 1.0)
            for node in range(torus.n)
        ]
        recover = [
            ("class", torus.link_index(node, 0, 1), 1.0, 1.0, 1.0)
            for node in range(torus.n)
        ]
        return Timeline([(params["alpha"], degrade), (params["alpha"] + 4.0 * ser, recover)])
    return EMPTY_TIMELINE


def link_at(torus, idx):
    """Inverse of Torus.link_index (mirror of Torus::link_at)."""
    dirbit = idx & 1
    rest = idx // 2
    dim = rest % torus.ndims()
    node = rest // torus.ndims()
    return node, dim, 1 if dirbit == 1 else -1


def midfault_fault(torus):
    """One physical cable (both directed links of the seeded faulty edge)
    dies before step 1 — mirror of Scenario::fault for MidFault."""
    idx = pick_links(torus, 1, FAULTY_SEED, keep_connected=True)[0]
    node, dim, dr = link_at(torus, idx)
    rev = torus.link_index(torus.neighbor(node, dim, dr), dim, -dr)
    return Fault(1, [idx, rev])


def midfault_plans(torus, algo, variant, params=None):
    """(detour_plan, rewrite_plan, padded) for one registry build under the
    mid-fault preset. Since PR 6 padded builds genuinely rewrite through
    their padding host map (no detour fallback)."""
    b = build(algo, variant, torus)
    if b is None:
        return None
    base = NetModel.uniform(torus)
    fault = midfault_fault(torus)
    post = fault.apply(base)
    detour = Plan(b.net, torus, base, route_model=post, switch_step=fault.step)
    rw = rewrite_collective_for_faults(b, base, [fault])
    rewrite = Plan(rw, torus, base, route_model=post, switch_step=fault.step)
    return detour, rewrite, b.padded


# ------------------------------------------------------------ tuner mirror
# Mirror of rust/src/tuner/{table,workload}.rs: the decision-table math
# (ladder indexing, winner distillation, trace generation, replay policy
# accounting). Keep seeds, weighted draws, and tie-breaks in lockstep.

STRAGGLER_SEED = 0x5EED0001
FAULTY_SEED = 0x5EED0002
SCENARIO_NAMES = ["uniform", "hetero-dims", "straggler", "faulty"]


def scenario_model(name, torus):
    """Mirror of harness::scenarios presets (same seeds/parameters)."""
    if name == "uniform":
        return NetModel.uniform(torus)
    if name == "hetero-dims":
        return NetModel.hetero_dims(torus, [1.0 / (1 << d) for d in range(torus.ndims())])
    if name == "straggler":
        return NetModel.straggler(torus, 2, 4.0, STRAGGLER_SEED)
    if name == "faulty":
        return NetModel.faulty(torus, 1, FAULTY_SEED)
    raise ValueError(name)


def size_ladder(max_bytes):
    v, m = [], 32
    while m <= max_bytes:
        v.append(m)
        m *= 4
    return v


def tune_ladder(max_bytes):
    """The tuner's distillation ladder: 32*2^k — twice as dense as the
    paper's x4 sweep axis, so a size landing between sweep points is never
    more than a quarter-decade from the winner the table stored."""
    v, m = [], 32
    while m <= max_bytes:
        v.append(m)
        m *= 2
    return v


def ladder_index(nbytes, n):
    """O(1) nearest-in-log-space index into the 32*2^k tune ladder:
    boundaries sit at the geometric midpoints 32*2^k*sqrt(2), tested with
    pure integer arithmetic (2*b^2 vs 2^(11+2k); Rust squares in u128 and
    folds the doubling into the exponent so the full u64 size range —
    u64::MAX included — indexes exactly). Mirror of
    tuner::table::ladder_index."""
    b = max(nbytes, 1)
    l = (b * b).bit_length()  # floor(log2(2 b^2)) = floor(log2 b^2) + 1
    idx = 0 if l < 10 else (l - 10) // 2
    return min(idx, n - 1)


def completion_key(v):
    return float("inf") if v != v else v


def build_variant_plans(torus, model, algos=None):
    """plans[algo] = [(variant, Plan), ...] for every supported algo, in
    registry order (mirrors harness::sweep::build_all + scenario plans)."""
    out = []
    for algo in algos or ALGOS:
        vs = []
        for variant in VARIANTS:
            b = build(algo, variant, torus)
            if b is not None:
                vs.append((variant, Plan(b.net, torus, model)))
        if vs:
            out.append((algo, vs))
    return out


def best_variant(plans, m, params):
    """(completion, variant) of the best variant — first minimum, matching
    Rust's min_by over Variant::ALL order."""
    best = None
    for variant, plan in plans:
        c, _ = simulate_flow(plan, m, params)
        if best is None or completion_key(c) < completion_key(best[0]):
            best = (c, variant)
    return best


def winner_at(built, m, params):
    """(algo, variant, completion): first-minimum across algos of the
    best-variant completion (mirrors Sweep::winners tie-break)."""
    win = None
    for algo, plans in built:
        c, v = best_variant(plans, m, params)
        if win is None or completion_key(c) < completion_key(win[2]):
            win = (algo, v, c)
    return win


def distill_winners(torus, model, sizes, params, algos=None):
    """Per-ladder-size (algo, variant) winners — one DecisionTable row."""
    built = build_variant_plans(torus, model, algos)
    return [winner_at(built, m, params)[:2] for m in sizes]


# --- workload traces (tuner::workload) ---

TRACE_SEEDS = {"data-parallel": 0x7A0E0001, "tensor-parallel": 0x7A0E0002, "mixed": 0x7A0E0003}
TRACE_MIX = {
    "data-parallel": [(4 << 20, 2), (16 << 20, 3), (32 << 20, 3), (64 << 20, 2)],
    "tensor-parallel": [(64 << 10, 2), (256 << 10, 3), (1 << 20, 3), (4 << 20, 2)],
    "mixed": [
        (32, 3),
        (512, 3),
        (8 << 10, 3),
        (64 << 10, 2),
        (1 << 20, 2),
        (16 << 20, 1),
        (64 << 20, 1),
    ],
}
TRACE_NAMES = ["data-parallel", "tensor-parallel", "mixed"]


def gen_trace(name, calls, max_bytes):
    """Deterministic synthetic trace: weighted base-size draw + x{3/4, 1,
    5/4} jitter, clamped to max_bytes. Mirror of tuner::workload::generate
    (same SplitMix64 draw order: weight then jitter)."""
    mix = TRACE_MIX[name]
    total_w = sum(w for _, w in mix)
    rng = SplitMix64(TRACE_SEEDS[name])
    sizes = []
    for _ in range(calls):
        w = rng.below(total_w)
        acc = 0
        base = mix[-1][0]
        for b, wt in mix:
            acc += wt
            if w < acc:
                base = b
                break
        j = rng.below(3)  # 0,1,2 -> x3/4, x1, x5/4
        size = base * (3 + j) // 4
        size = max(1, min(size, max_bytes))
        sizes.append(size)
    return sizes


def replay_totals(torus, model, sizes, table_winners, ladder_sizes, params, algos=None):
    """Total completion per policy over a trace. Returns dict:
    {"oracle": t, "table": t, "fixed:<algo>": t}. `table_winners` is the
    distilled per-ladder-size (algo, variant) list for this scenario."""
    built = build_variant_plans(torus, model, algos)
    distinct = sorted(set(sizes))
    counts = {s: sizes.count(s) for s in distinct}
    comp = {}  # (algo, variant, size) -> completion
    for algo, plans in built:
        for variant, plan in plans:
            for s in distinct:
                comp[(algo, variant, s)] = simulate_flow(plan, s, params)[0]
    totals = {"oracle": 0.0, "table": 0.0}
    for algo, plans in built:
        totals["fixed:" + algo] = 0.0
    for s in distinct:
        cnt = counts[s]
        per_algo_best = {}
        for algo, plans in built:
            best = None
            for variant, _ in plans:
                c = comp[(algo, variant, s)]
                if best is None or completion_key(c) < completion_key(best):
                    best = c
            per_algo_best[algo] = best
            totals["fixed:" + algo] += cnt * best
        totals["oracle"] += cnt * min(
            (per_algo_best[a] for a, _ in built), key=completion_key
        )
        wa, wv = table_winners[ladder_index(s, len(ladder_sizes))]
        totals["table"] += cnt * comp[(wa, wv, s)]
    return totals


# ------------------------------------------------------------ registry sweep


def crosscheck(dims, algo, variant, m, mtu=4096, params=None, engine=simulate_packet_batched, model=None):
    params = params or DEFAULT_PARAMS
    t = Torus(dims)
    b = build(algo, variant, t)
    if b is None:
        return None
    plan = Plan(b.net, t, model)
    f, _ = simulate_flow(plan, m, params)
    k, _ = engine(plan, m, params, mtu)
    if k <= 0.0:
        return ("ZERO", f, k)
    rel = abs(f - k) / k
    return (rel, f, k)


# ---------------------------------------------- online fault response
# Mirror of rust/src/schedule/online.rs (controller) and
# rust/src/tuner/online.rs (nearest-scenario selector). Keep estimator
# arithmetic, event->step mapping, and descriptor math in lockstep.


class FaultEvent:
    def __init__(self, t, down_links=(), dead_nodes=()):
        self.t = t
        self.down_links = list(down_links)
        self.dead_nodes = list(dead_nodes)

    @staticmethod
    def link(t, link):
        return FaultEvent(t, [link])

    @staticmethod
    def cable(t, torus, link):
        node, dim, dr = link_at(torus, link)
        rev = torus.link_index(torus.neighbor(node, dim, dr), dim, -dr)
        return FaultEvent(t, [link, rev])

    @staticmethod
    def node(t, node):
        return FaultEvent(t, [], [node])


def step_time_estimates(s, model, m_bytes, params):
    """Cumulative estimated end time of each step (mirror of
    schedule::online::step_time_estimates): alpha + busiest-link
    serialization + longest route's hop latency; unroutable sends skip."""
    return staged_step_time_estimates(s, model, [], m_bytes, params)


def staged_step_time_estimates(s, base, stages, m_bytes, params):
    """Mirror of schedule::online::staged_step_time_estimates: step k is
    priced on the model of the last stage with from_step <= k (falling back
    to `base`), so completed steps keep their pre-fault pricing."""
    torus = base.torus
    assert s.n == torus.n
    ends = []
    t = 0.0
    for k, step in enumerate(s.steps):
        model = base
        for frm, mm in stages:
            if k >= frm:
                model = mm
            else:
                break
        link_bytes = [0.0] * torus.num_links()
        lat = 0.0
        for src in range(s.n):
            for snd in step[src]:
                try:
                    route = model.route(src, snd.to, snd.route)
                except AssertionError:
                    continue
                nbytes = snd.rel_bytes(s.n_blocks) * m_bytes
                hop_lat = 0.0
                for l in route:
                    link_bytes[l] += nbytes
                    hop_lat += (
                        model.lat_scale[l] * params["link_lat"]
                        + model.proc_scale[l] * params["hop_lat"]
                    )
                if hop_lat > lat:
                    lat = hop_lat
        ser = max(
            (b * 8.0 / params["bw"] / model.bw_scale[l] for l, b in enumerate(link_bytes)),
            default=0.0,
        )
        t += params["alpha"] + ser + lat
        ends.append(t)
    return ends


class Response:
    def __init__(self, schedule, stages, actions):
        self.schedule, self.stages, self.actions = schedule, stages, actions

    def build_plan(self, base):
        return Plan(self.schedule, base.torus, base, stages=self.stages)


def respond(b, base, events, m_bytes, params, policy):
    """Mirror of schedule::online::respond. `policy(event, step)` returns
    "rewrite" or "detour"; a failed rewrite degrades to detour. Raises
    ValueError on out-of-order events."""
    hosts = b.hosts
    n_real = base.torus.n
    work = b.exec_s if hosts is not None else b.net

    def collapse(s):
        if hosts is not None:
            return collapse_by_hosts(s, hosts, n_real, b.net.name + "+rewrite")
        return s

    net_sched = b.net
    model = base
    ends = step_time_estimates(net_sched, base, m_bytes, params)
    stages = []
    actions = []
    prev_t = float("-inf")
    last_step = 0
    for ev in events:
        if not ev.t >= prev_t:
            raise ValueError(
                f"online controller: fault events must be time-ordered ({ev.t} after {prev_t})"
            )
        prev_t = ev.t
        if not ev.down_links and not ev.dead_nodes:
            continue
        if not ends:
            break
        if ev.t >= ends[-1]:
            continue  # by the controller's clock the collective finished
        step = next((i for i, e in enumerate(ends) if ev.t < e), len(ends))
        step = max(step, last_step)
        last_step = step
        fault = Fault(step, ev.down_links, ev.dead_nodes)
        applied = policy(ev, step)
        if applied == "rewrite":
            try:
                work = rewrite_for_fault_hosted(work, model, fault, hosts)
                net_sched = collapse(work)
            except ValueError:
                applied = "detour"  # unrecoverable rewrite: degrade honestly
        model = fault.apply(model)
        stages.append((step, model))
        actions.append((step, applied))
        ends = staged_step_time_estimates(net_sched, base, stages, m_bytes, params)
    return Response(net_sched, stages, actions)


def two_fault_events(torus, ends):
    """Mirror of harness::scenarios::two_fault_events: the seeded cable
    mid-early-step, then near the end a far cable on the next dimension
    (2D+) or — on rings, where any further link fault would directionally
    partition the line left by the cable death — the death of the node
    just across the dead cable (removing a line endpoint keeps the
    survivors connected)."""
    idx = pick_links(torus, 1, FAULTY_SEED, keep_connected=True)[0]
    node, dim, dr = link_at(torus, idx)
    t1 = 0.5 * (ends[0] + ends[min(len(ends), 2) - 1])
    ev1 = FaultEvent.cable(t1, torus, idx)
    t2 = ends[-1] * 0.98
    if torus.ndims() > 1:
        far_node = (node + torus.n // 2) % torus.n
        far_dim = (dim + 1) % torus.ndims()
        ev2 = FaultEvent.cable(t2, torus, torus.link_index(far_node, far_dim, dr))
    else:
        ev2 = FaultEvent.node(t2, torus.neighbor(node, dim, dr))
    return [ev1, ev2]


# Selector descriptor math (mirror of tuner::online). Features are the
# 5-vector (frac_links, severity, duration_frac, permanent, when_frac);
# observations are (t, link, cap_ratio) tuples.

PRISTINE_FEATURES = (0.0, 1.0, 0.0, 0.0, 1.0)
CANONICAL_SIZE = 1 << 20
SELECT_THRESHOLD = 0.5


def ref_horizon(params, m_bytes):
    return params["alpha"] + 4.0 * m_bytes * 8.0 / params["bw"]


def features_of_obs(torus, obs, horizon):
    """Mirror of ScenarioFeatures::of_obs (same accumulator semantics)."""
    if not obs:
        return PRISTINE_FEATURES
    horizon = max(horizon, 2.2250738585072014e-308)
    acc = {}  # link -> [since(None|t), total, worst, first]
    for t, link, ratio in sorted(obs, key=lambda o: o[0]):
        if ratio < 1.0:
            a = acc.get(link)
            if a is None:
                a = [None, 0.0, 1.0, t]
                acc[link] = a
            a[2] = min(a[2], max(ratio, 0.0))
            if a[0] is None:
                a[0] = t
        else:
            a = acc.get(link)
            if a is not None and a[0] is not None:
                a[1] += max(t - a[0], 0.0)
                a[0] = None
    severity, when, dur_sum, permanent = 1.0, float("inf"), 0.0, False
    for since, total, worst, first in acc.values():
        severity = min(severity, worst)
        when = min(when, first)
        if since is not None:
            total += max(horizon - since, 0.0)
            permanent = True
        dur_sum += min(max(total / horizon, 0.0), 1.0)
    n_aff = len(acc)
    return (
        n_aff / torus.num_links(),
        severity,
        dur_sum / n_aff if n_aff else 0.0,
        1.0 if permanent else 0.0,
        min(max(when / horizon, 0.0), 1.0) if when != float("inf") else 1.0,
    )


def features_dist(a, b):
    return sum((x - y) * (x - y) for x, y in zip(a, b)) ** 0.5


def preset_obs(name, torus, params, m_bytes):
    """A preset's canonical observation stream (mirror of
    tuner::online::preset_obs): its timeline's mutations as samples, plus
    the mid-fault cable death at its step boundary (step * alpha)."""
    obs = []
    tl = dynamic_timeline(name, torus, params, m_bytes)
    for t, muts in tl.epochs:
        for mu in muts:
            if mu[0] == "down":
                ratio = 0.0 if mu[2] else 1.0
            else:  # ("class", l, bw, lat, proc)
                ratio = mu[2]
            obs.append((t, mu[1], ratio))
    if name.startswith("mid-fault"):
        f = midfault_fault(torus)
        t = params["alpha"] * f.step
        for l in f.down_links:
            obs.append((t, l, 0.0))
    return obs


def obs_of_event(ev, torus):
    """A FaultEvent as link-health observations (mirror of obs_of_event):
    down links at ratio 0, dead nodes as all incident directed links."""
    obs = [(ev.t, l, 0.0) for l in ev.down_links]
    for node in ev.dead_nodes:
        for dim in range(torus.ndims()):
            for dr in (-1, 1):
                obs.append((ev.t, torus.link_index(node, dim, dr), 0.0))
                rev = torus.link_index(torus.neighbor(node, dim, dr), dim, -dr)
                obs.append((ev.t, rev, 0.0))
    return obs


def selector_rows(torus, params):
    """[(name, features, permanent)] for the dynamic preset family at the
    canonical embedding size (mirror of OnlineSelector::from_table)."""
    rows = []
    for name in DYNAMIC_NAMES:
        f = features_of_obs(
            torus,
            preset_obs(name, torus, params, CANONICAL_SIZE),
            ref_horizon(params, CANONICAL_SIZE),
        )
        rows.append((name, f, f[3] >= 0.5))
    return rows


def select(rows, torus, obs, m_bytes, params):
    """(scenario, distance, matched, action) — mirror of
    OnlineSelector::select; distance ties keep the first row."""
    f = features_of_obs(torus, obs, ref_horizon(params, m_bytes))
    best = None
    for name, rf, perm in rows:
        d = features_dist(rf, f)
        if best is None or d < best[1]:
            best = (name, d, perm)
    name, d, perm = best
    matched = d <= SELECT_THRESHOLD
    action = "rewrite" if matched and perm and f[3] >= 0.5 else "detour"
    return name, d, matched, action


def selector_policy(rows, torus, m_bytes, params):
    """The selector as a respond() policy: accumulates observations so a
    second fault is judged against the full stream seen so far. Hard rule
    above the fingerprint match: node-death events always rewrite —
    detouring cannot route around a dead endpoint."""
    seen = []

    def policy(ev, step):
        seen.extend(obs_of_event(ev, torus))
        if ev.dead_nodes:
            return "rewrite"
        return select(rows, torus, seen, m_bytes, params)[3]

    return policy


# ── static verification mirror (ISSUE 7: rust/src/verify/) ──────────────
#
# The dataflow lattice, port budgets, congestion sums and mutation
# corruptors of rust/src/verify/{mod,mutate}.rs, kept in numeric lockstep;
# eval_verify.py pins the registry certificates against these.

VERIFY_EPS = 1e-9


def verify_dataflow(s, alive=None):
    """Mirror of verify::verify_dataflow — atom-level abstract
    interpretation. Returns None on success or a (kind, detail) tuple with
    kind in {malformed, unrealizable, double_count, missing}."""
    n, nb = s.n, s.n_blocks
    full = frozenset(range(n))
    cells = [[([frozenset([r])], frozenset([r])) for _ in range(nb)]
             for r in range(n)]
    for k, step in enumerate(s.steps):
        snap = [[cells[r][b] for b in range(nb)] for r in range(n)]
        for src in range(n):
            for snd in step[src]:
                dst = snd.to
                if dst == src or not (0 <= dst < n):
                    return ("malformed", f"step {k} src {src} to {dst}")
                for blocks, kind, contrib in snd.pieces:
                    if not blocks:
                        return ("malformed", f"step {k} empty piece")
                    for b in blocks:
                        if not (0 <= b < nb):
                            return ("malformed", f"step {k} block {b}")
                        s_atoms, s_total = snap[src][b]
                        if kind == "reduce":
                            if not contrib:
                                return ("malformed",
                                        f"step {k} empty contribution")
                            if not contrib <= s_total:
                                return ("unrealizable",
                                        f"step {k} {src}->{dst} b{b}: "
                                        "sender lacks the contribution")
                            covered = sum(len(a) for a in s_atoms
                                          if a <= contrib)
                            if covered != len(contrib):
                                return ("unrealizable",
                                        f"step {k} {src}->{dst} b{b}: "
                                        "splits an already-reduced atom")
                            r_atoms, r_total = cells[dst][b]
                            if r_total & contrib:
                                return ("double_count",
                                        f"step {k} {src}->{dst} b{b}")
                            cells[dst][b] = (r_atoms + [contrib],
                                             r_total | contrib)
                        else:
                            if contrib != full:
                                return ("malformed",
                                        f"step {k} Set contrib b{b}")
                            if s_total != full:
                                return ("unrealizable",
                                        f"step {k} {src}->{dst} b{b}: "
                                        "Set of an unfinished block")
                            cells[dst][b] = ([full], full)
    for r in range(n):
        if alive is not None and not alive[r]:
            continue
        for b in range(nb):
            if cells[r][b][1] != full:
                return ("missing", f"node {r} b{b} missing "
                        f"{n - len(cells[r][b][1])}")
    return None


def port_budget(algo, variant):
    """Mirror of verify::port_budget."""
    if algo in ("bruck", "bruck-unidir"):
        return 2
    if (algo, variant) == ("recdoub", "B"):
        return 2
    return 1


def host_multiplicity(b):
    """Mirror of verify::host_multiplicity."""
    if b.hosts is None:
        return 1
    counts = {}
    for h in b.hosts:
        counts[h] = counts.get(h, 0) + 1
    return max(counts.values())


def _link_parts(torus, l):
    dirbit = l % 2
    rest = l // 2
    dim = rest % torus.ndims()
    node = rest // torus.ndims()
    return node, dim, (1 if dirbit == 1 else -1)


def audit_ports(s, torus, budget):
    """Mirror of verify::audit_ports. Returns (max_port_msgs, err) where
    err is None or a (kind, detail) tuple."""
    model = NetModel.uniform(torus)
    nb = s.n_blocks
    max_used = 0
    for k, step in enumerate(s.steps):
        ports = {}
        for src in range(s.n):
            for snd in step[src]:
                if snd.rel_bytes(nb) <= 0.0:
                    continue
                if snd.route != MIN:
                    _tag, dim, dr = snd.route
                    if dim >= torus.ndims():
                        return max_used, ("malformed",
                                          f"directed dim {dim}")
                    if dr not in (1, -1):
                        return max_used, ("malformed",
                                          f"directed dir {dr}")
                    for d in range(torus.ndims()):
                        if d != dim and torus.coord(src, d) != \
                                torus.coord(snd.to, d):
                            return max_used, (
                                "malformed",
                                f"directed off-dim step {k} "
                                f"{src}->{snd.to}")
                route = model.route(src, snd.to, snd.route)
                if route:
                    key = route[0]
                    ports[key] = ports.get(key, 0) + 1
        for key, used in ports.items():
            max_used = max(max_used, used)
            if used > budget:
                node, dim, dr = _link_parts(torus, key)
                return max_used, ("port",
                                  f"step {k} node {node} dim {dim} "
                                  f"dir {dr:+d}: {used} > {budget}")
    return max_used, None


def audit_congestion(s, torus):
    """Mirror of verify::audit_congestion: static per-link load under
    nominal routes on the uniform fabric."""
    model = NetModel.uniform(torus)
    nb = s.n_blocks
    tx_delay_rel = 0.0
    max_link_rel = 0.0
    max_link_msgs = 0
    bytes_on_wire = 0.0
    load_sum = 0.0
    loaded_pairs = 0
    messages = 0
    for step in s.steps:
        load = {}
        count = {}
        for src in range(s.n):
            for snd in step[src]:
                rel = snd.rel_bytes(nb)
                if rel <= 0.0:
                    continue
                route = model.route(src, snd.to, snd.route)
                messages += 1
                bytes_on_wire += rel * len(route)
                for l in route:
                    load[l] = load.get(l, 0.0) + rel
                    count[l] = count.get(l, 0) + 1
        if load:
            step_max = max(load.values())
            tx_delay_rel += step_max
            max_link_rel = max(max_link_rel, step_max)
            max_link_msgs = max(max_link_msgs, max(count.values()))
            load_sum += sum(load.values())
            loaded_pairs += len(load)
    mean = load_sum / loaded_pairs if loaded_pairs else 0.0
    return dict(tx_delay_rel=tx_delay_rel, max_link_rel=max_link_rel,
                max_link_msgs=max_link_msgs, mean_link_rel=mean,
                bytes_on_wire_rel=bytes_on_wire, messages=messages)


def audit_optimality(s, torus):
    """Mirror of verify::audit_optimality."""
    lat3 = sum(ceil_log(3, a) for a in torus.dims)
    lat2 = sum(ceil_log(2, a) for a in torus.dims)
    nb = s.n_blocks
    sent = [0.0] * s.n
    for step in s.steps:
        for src in range(s.n):
            for snd in step[src]:
                sent[src] += snd.rel_bytes(nb)
    max_sent = max(sent)
    n = torus.n
    bw_lb = 2.0 * (n - 1) / n
    lat_opt = s.num_steps() <= lat3
    bw_opt = max_sent <= bw_lb + VERIFY_EPS
    klass = ("latency-optimal" if lat_opt
             else "bandwidth-optimal" if bw_opt else "neither")
    return dict(steps=s.num_steps(), lat_bound3=lat3, lat_bound2=lat2,
                max_node_sent_rel=max_sent, bw_lower_rel=bw_lb,
                latency_optimal=lat_opt, bandwidth_optimal=bw_opt,
                klass=klass)


def certify_collective(b, torus):
    """Mirror of verify::certify_collective — since PR 10 a thin wrapper
    over the pass manager: every pass runs (dataflow/hazard/deadlock/memory
    on the exec schedule, ports/congestion/optimality/cost on the net
    schedule) and any Error-severity finding is a hard failure. Returns the
    cert dict or raises AssertionError on any defect."""
    cert, findings, _t = run_passes(b, torus)
    errors = [f for f in findings if f[1] == "error"]
    assert not errors, f"{b.net.name}: {errors}"
    return cert


def certify_registry(torus):
    """Mirror of verify::certify_registry, including the ring congestion
    gates (Trivance-L ≤ ⅓·BruckUnidir-L and ≤ Bruck-L)."""
    certs = {}
    for algo in ALGOS:
        for variant in VARIANTS:
            b = build(algo, variant, torus)
            if b is None:
                continue
            b.algo, b.variant = algo, variant
            certs[(algo, variant)] = certify_collective(b, torus)
    tri = certs.get(("trivance", "L"))
    if tri is not None:
        assert tri["optimality"]["latency_optimal"], \
            f"{torus.dims}: trivance-L not latency-optimal"
        if torus.ndims() == 1:
            tx = tri["congestion"]["tx_delay_rel"]
            uni = certs[("bruck-unidir", "L")]["congestion"]["tx_delay_rel"]
            bid = certs[("bruck", "L")]["congestion"]["tx_delay_rel"]
            assert tx <= uni / 3.0 + VERIFY_EPS, \
                f"{torus.dims}: trivance {tx} > uni/3 {uni / 3.0}"
            assert tx <= bid + VERIFY_EPS, \
                f"{torus.dims}: trivance {tx} > bruck {bid}"
    return certs


# Mutation corruptors — mirror of verify::mutate.
MUTATION_KINDS = ["drop", "swap", "dup", "shift", "hazard"]

# Mirror of verify::mutate scope notes (rendered in the kill report so a
# 100% kill rate is never overstated): which schedules each corruptor is
# seeded on, and why.
MUTATION_SCOPE = {
    "drop": "all native builds",
    "swap": "all native builds",
    "dup": "all native builds",
    "shift": ("trivance only: on single-message schedules and the 2-port "
              "Bruck family the flipped port is a legal routing equivalent, "
              "so the mutant is not a defect there"),
    "hazard": "all native builds",
}


def mutation_sites(s, torus, kind):
    out = []
    for k, st in enumerate(s.steps):
        for src in range(s.n):
            for si, snd in enumerate(st[src]):
                if kind == "drop":
                    if snd.rel_bytes(s.n_blocks) > 0:
                        out.append((k, src, si, 0))
                elif kind == "swap":
                    for pi, (_b, kd, c) in enumerate(snd.pieces):
                        if kd == "reduce" and 0 < len(c) < s.n:
                            out.append((k, src, si, pi))
                elif kind == "dup":
                    if any(kd == "reduce" and c
                           for _b, kd, c in snd.pieces):
                        out.append((k, src, si, 0))
                elif kind == "shift":
                    if snd.rel_bytes(s.n_blocks) <= 0:
                        continue
                    diff = [d for d in range(torus.ndims())
                            if torus.coord(src, d) != torus.coord(snd.to, d)]
                    if len(diff) == 1:
                        out.append((k, src, si, diff[0]))
                elif kind == "hazard":
                    if snd.rel_bytes(s.n_blocks) <= 0:
                        continue
                    for _pi, (bl, kd, _c) in enumerate(snd.pieces):
                        if kd == "reduce" and bl:
                            out.append((k, src, si, min(bl)))
    return out


def _clone_schedule(s):
    c = Schedule(s.name, s.n, s.n_blocks)
    for st in s.steps:
        new = c.push_step()
        for src in range(s.n):
            new[src] = [Send(x.to, list(x.pieces), x.route) for x in st[src]]
    return c


def apply_mutation(s, torus, kind, site):
    m = _clone_schedule(s)
    k, src, si, aux = site
    if kind == "drop":
        m.steps[k][src].pop(si)
    elif kind == "swap":
        snd = m.steps[k][src][si]
        b, kd, c = snd.pieces[aux]
        snd.pieces[aux] = (b, kd, frozenset((r + 1) % s.n for r in c))
    elif kind == "dup":
        snd = m.steps[k][src][si]
        m.steps[k][src].append(Send(snd.to, list(snd.pieces), snd.route))
    elif kind == "shift":
        snd = m.steps[k][src][si]
        model = NetModel.uniform(torus)
        nat = model.route(src, snd.to, snd.route)
        nat_dr = 1 if nat[0] % 2 == 1 else -1
        m.steps[k][src][si] = Send(snd.to, list(snd.pieces),
                                   directed(aux, -nat_dr))
    elif kind == "hazard":
        # InjectHazard: land a Set into a (rank, block) cell that already
        # absorbs a Reduce this step — a WAW race under any in-step
        # reordering, which only the hazard pass can see (the lattice
        # replay processes sends in a fixed order and may still complete).
        snd = m.steps[k][src][si]
        full = frozenset(range(s.n))
        m.steps[k][src].append(
            Send(snd.to, [(frozenset([aux]), "set", full)], MIN))
    return m


def run_mutation_suite(topos, seed, per_class):
    """Mirror of verify::mutate::run_mutation_suite: native builds only,
    shift-a-port on trivance only. Returns (total, killed, survivors)."""
    total = killed = 0
    survivors = []
    for torus in topos:
        for ai, algo in enumerate(ALGOS):
            for vi, variant in enumerate(VARIANTS):
                b = build(algo, variant, torus)
                if b is None or b.padded:
                    continue
                budget = port_budget(algo, variant)
                rng = SplitMix64((seed ^ (torus.n * 131 + ai * 7 + vi))
                                 & 0xFFFFFFFFFFFFFFFF)
                for kind in MUTATION_KINDS:
                    if kind == "shift" and algo != "trivance":
                        continue
                    ss = mutation_sites(b.net, torus, kind)
                    if not ss:
                        continue
                    for _ in range(min(per_class, len(ss))):
                        site = ss[rng.below(len(ss))]
                        m = apply_mutation(b.net, torus, kind, site)
                        # hazard pass first (mirrors killed_by_verifier):
                        # a WAW race is a defect even when the fixed-order
                        # lattice replay happens to complete.
                        haz = audit_hazards(m)
                        err = (("hazard", "waw race")
                               if haz["waw_conflicts"] > 0 else None)
                        if err is None:
                            err = verify_dataflow(m)
                        if err is None:
                            _mp, err = audit_ports(m, torus, budget)
                        total += 1
                        if err is not None:
                            killed += 1
                        else:
                            survivors.append(
                                (torus.dims, algo, variant, kind, site))
    return total, killed, survivors


# ------------------------------------------------------------ verify passes
# Mirror of rust/src/verify/{passes,hazard,deadlock,memory,cost,diff}.rs —
# the PR 10 pass manager. Keep pass names, dependency edges, severities and
# every gate constant in lockstep with the Rust side.

PASS_NAMES = ["dataflow", "hazard", "deadlock", "memory", "ports",
              "congestion", "optimality", "cost"]
PASS_DEPS = {"deadlock": ["dataflow"], "cost": ["congestion", "optimality"]}


def select_passes(requested=None):
    """Mirror of PassManager::select: requested passes plus their transitive
    dependencies, in the canonical (topologically sorted) PASS_NAMES order."""
    if not requested:
        return list(PASS_NAMES)
    want = set()

    def add(p):
        if p not in PASS_NAMES:
            raise ValueError(f"unknown pass: {p}")
        if p in want:
            return
        want.add(p)
        for d in PASS_DEPS.get(p, ()):
            add(d)

    for p in requested:
        add(p)
    return [p for p in PASS_NAMES if p in want]


def audit_hazards(s):
    """Mirror of verify::hazard::audit_hazards — within-step WAR/WAW
    analysis on (rank, block) cells under receive-barrier semantics.

      * WAW conflict: a Set landing in a cell that takes any other write the
        same step (Set+Set or Set+Reduce) — the result depends on in-step
        delivery order, a race under ANY engine. Concurrent Reduces into one
        cell are not WAW: the reduction is commutative and the dataflow pass
        separately proves their contributions disjoint.
      * WAR cell: an incoming write into a cell whose rank also sends from
        that block the same step — safe only behind the receive barrier
        (sends read the start-of-step snapshot), i.e. needs double-buffering.
    """
    n = s.n
    war = 0
    waw = 0
    for step in s.steps:
        writes = {}
        reads = set()
        for src in range(n):
            for snd in step[src]:
                for blocks, kind, _c in snd.pieces:
                    for b in blocks:
                        writes.setdefault((snd.to, b), []).append(kind)
                        reads.add((src, b))
        for cell, kinds in writes.items():
            if len(kinds) > 1 and "set" in kinds:
                waw += 1
            if cell in reads:
                war += 1
    return dict(war_cells=war, waw_conflicts=waw, barrier_free=(war == 0))


def audit_deadlock(s):
    """Mirror of verify::deadlock::audit_deadlock — forward-availability
    causality: every contribution a send consumes at step k must have been
    produced strictly earlier (union totals only; the atom algebra is the
    dataflow pass's job). Returns None or ("deadlock", detail)."""
    n, nb = s.n, s.n_blocks
    full = frozenset(range(n))
    avail = [[frozenset([r]) for _ in range(nb)] for r in range(n)]
    for k, step in enumerate(s.steps):
        snap = [[avail[r][b] for b in range(nb)] for r in range(n)]
        for src in range(n):
            for snd in step[src]:
                for blocks, kind, contrib in snd.pieces:
                    for b in blocks:
                        if kind == "reduce":
                            if not contrib <= snap[src][b]:
                                need = sorted(contrib - snap[src][b])
                                return ("deadlock",
                                        f"step {k} {src}->{snd.to} b{b} "
                                        f"waits on {need} produced later")
                            avail[snd.to][b] = avail[snd.to][b] | contrib
                        else:
                            if snap[src][b] != full:
                                return ("deadlock",
                                        f"step {k} {src}->{snd.to} b{b}: "
                                        "Set of a block completed later")
                            avail[snd.to][b] = full
    return None


def audit_stages(stages, torus):
    """Mirror of verify::deadlock::audit_stages — the typed check behind
    SimPlan::build_staged's assertions: from_steps non-decreasing, every
    stage model on the plan's topology. Returns None or
    ("stage_order", detail)."""
    prev = None
    for i, (frm, m) in enumerate(stages):
        if m.torus.dims != torus.dims:
            return ("stage_order", f"stage {i}: model topology "
                    f"{m.torus.dims} != plan topology {torus.dims}")
        if prev is not None and frm < prev:
            return ("stage_order",
                    f"stage {i}: from_step {frm} < previous {prev}")
        prev = frm
    return None


def audit_memory(s, hosts, n_real):
    """Mirror of verify::memory::audit_memory — peak live rel-units per REAL
    node per step: one full-vector accumulator per hosted virtual rank plus
    the in-flight bytes landing that step (receive-barrier: incoming buffers
    are held alongside the accumulator until the step's barrier). Also
    reports in_rel_max, the max incoming rel per (virtual rank, step) —
    latency schedules may land several full vectors per message (merged
    concurrent dim-slices), so the bound is on bytes, not message counts:
    folded peak <= hm·(1 + in_rel_max)."""
    n, nb = s.n, s.n_blocks
    real = (lambda v: hosts[v]) if hosts is not None else (lambda v: v)
    base = [0.0] * n_real
    for v in range(n):
        base[real(v)] += 1.0
    peak, peak_node, peak_step = max(base), max(range(n_real),
                                                key=lambda r: base[r]), None
    in_rel_max = 0.0
    for k, step in enumerate(s.steps):
        incoming = [0.0] * n_real
        in_rel = [0.0] * n
        for src in range(n):
            for snd in step[src]:
                r = snd.rel_bytes(nb)
                incoming[real(snd.to)] += r
                in_rel[snd.to] += r
        in_rel_max = max(in_rel_max, max(in_rel))
        for r in range(n_real):
            live = base[r] + incoming[r]
            if live > peak:
                peak, peak_node, peak_step = live, r, k
    return dict(peak_live_rel=peak, peak_node=peak_node,
                peak_step=peak_step, in_rel_max=in_rel_max)


def memory_bound(b, mem):
    """Mirror of verify::memory::certified_bound: hm·2 for bandwidth
    variants (streamed partial blocks never exceed one extra full vector
    per hosted rank — the sharp in-place invariant), hm·(1 + in_rel_max)
    for latency variants (each hosted rank buffers at most the per-virtual
    incoming maximum on top of its accumulator)."""
    hm = host_multiplicity(b)
    if b.variant == "B":
        return 2.0 * hm
    return hm * (1.0 + mem["in_rel_max"])


def require_peak_within(mem, bound):
    """None or ("memory_regression", detail)."""
    if mem["peak_live_rel"] > bound + VERIFY_EPS:
        return ("memory_regression",
                f"peak {mem['peak_live_rel']:.6f} rel at node "
                f"{mem['peak_node']} step {mem['peak_step']} exceeds "
                f"certified bound {bound:.6f}")
    return None


def cost_certificate(s, model):
    """Mirror of verify::cost::cost_certificate — size-independent symbolic
    coefficients of the closed-form completion bound

        T(m) <= steps·alpha + tx_rel·(8m/bw) + hop_lat_rel·link_lat
                + hop_proc_rel·hop_lat

    derived statically from the IR and the NetModel scale table: tx_rel is
    the serialization sum (per-step busiest scaled link), the hop terms the
    per-step longest route's latency/processing scale sums. Unroutable
    sends (down links) are priced by the surviving routes, matching
    staged_step_time_estimates."""
    torus = model.torus
    assert s.n == torus.n, "cost certificate prices the net schedule"
    nb = s.n_blocks
    tx_rel = 0.0
    hop_lat_rel = 0.0
    hop_proc_rel = 0.0
    for step in s.steps:
        link_rel = [0.0] * torus.num_links()
        lat = 0.0
        proc = 0.0
        for src in range(s.n):
            for snd in step[src]:
                try:
                    route = model.route(src, snd.to, snd.route)
                except AssertionError:
                    continue
                rel = snd.rel_bytes(nb)
                rlat = 0.0
                rproc = 0.0
                for l in route:
                    link_rel[l] += rel
                    rlat += model.lat_scale[l]
                    rproc += model.proc_scale[l]
                lat = max(lat, rlat)
                proc = max(proc, rproc)
        tx_rel += max((r / model.bw_scale[l] for l, r in enumerate(link_rel)),
                      default=0.0)
        hop_lat_rel += lat
        hop_proc_rel += proc
    return dict(steps=s.num_steps(), tx_rel=tx_rel,
                hop_lat_rel=hop_lat_rel, hop_proc_rel=hop_proc_rel)


def cost_bound_s(cert, m_bytes, params):
    """Mirror of CostCertificate::bound_s."""
    return (cert["steps"] * params["alpha"]
            + cert["tx_rel"] * m_bytes * 8.0 / params["bw"]
            + cert["hop_lat_rel"] * params["link_lat"]
            + cert["hop_proc_rel"] * params["hop_lat"])


def require_cost_within(cert, m_bytes, params, measured_s, tol_rel):
    """Mirror of verify::cost::require_within — the cross-check gate: a
    measured completion may not exceed the certified bound by more than
    tol_rel (relative). None or ("cost_regression", detail)."""
    bound = cost_bound_s(cert, m_bytes, params)
    if measured_s > bound * (1.0 + tol_rel) + VERIFY_EPS:
        return ("cost_regression",
                f"measured {measured_s:.3e}s exceeds certified bound "
                f"{bound:.3e}s by more than {tol_rel:.0%}")
    return None


# ------------------------------------------------------- verify::diff mirror
def _piece_shrinks(rw_piece, orig_pieces):
    blocks, kind, contrib = rw_piece
    for ob, ok, oc in orig_pieces:
        if ok != kind or not blocks <= ob:
            continue
        if kind == "reduce":
            if contrib <= oc:
                return True
        elif contrib == oc:
            return True
    return False


def certify_rewrite(orig, rw, fault_step, dead, hosts=None):
    """Mirror of verify::diff::certify_rewrite — differential certification
    of a fault rewrite against its original, replacing re-verify-from-
    scratch with a targeted equivalence proof. Obligations:

      1. prefix (steps < fault_step): verbatim — already-executed steps are
         immutable;
      2. body (fault_step <= k < len(orig)): every send shrink-matches an
         original send with the same (src, dst, route) — blocks and reduce
         contributions shrink, Set contributions are preserved — no new
         sends, and nothing touches a dead node (the rewrite is the same
         computation minus dead/blocked contributions);
      3. cleanup zone (k >= len(orig)): appended recovery steps are only
         required to stay between alive nodes;
      4. survivor completeness: one atom-lattice replay proves every alive
         rank still finishes with the full reduction (contributions already
         in flight before the fault included).

    `dead` maps REAL dead ranks to the step they died at (a rank sends
    legitimately until its own death step); `hosts` lifts virtual ranks of
    a padded executable schedule onto the real torus. Composes over fault
    sequences: shrink relations compose and every cleanup step of an
    earlier rewrite lands in the later rewrite's cleanup zone.
    Returns None or ("divergence", detail)."""
    n, nb = orig.n, orig.n_blocks
    if rw.n != n or rw.n_blocks != nb:
        return ("divergence", "rank/block shape mismatch")
    real = (lambda v: hosts[v]) if hosts is not None else (lambda v: v)
    is_dead = lambda v, k: dead.get(real(v), 1 << 60) <= k  # noqa: E731
    olen = len(orig.steps)
    guard = min(fault_step, olen)
    if len(rw.steps) < guard:
        return ("divergence", "rewrite shorter than the immutable prefix")
    for k, step in enumerate(rw.steps):
        for src in range(n):
            sends = step[src]
            if k < guard:
                o = orig.steps[k][src]
                same = (len(sends) == len(o) and all(
                    a.to == b.to and a.route == b.route
                    and sorted(a.pieces) == sorted(b.pieces)
                    for a, b in zip(sends, o)))
                if not same:
                    return ("divergence",
                            f"step {k} src {src}: executed prefix modified")
            elif k < olen:
                if sends and is_dead(src, k):
                    return ("divergence", f"step {k}: dead src {src} sends")
                orig_sends = orig.steps[k][src]
                used = [False] * len(orig_sends)
                for s_rw in sends:
                    if is_dead(s_rw.to, k):
                        return ("divergence",
                                f"step {k}: send to dead node {s_rw.to}")
                    hit = None
                    for i, s_o in enumerate(orig_sends):
                        if (used[i] or s_o.to != s_rw.to
                                or s_o.route != s_rw.route):
                            continue
                        if all(_piece_shrinks(p, s_o.pieces)
                               for p in s_rw.pieces):
                            hit = i
                            break
                    if hit is None:
                        return ("divergence",
                                f"step {k} src {src}->{s_rw.to}: no "
                                "shrink-match against the original")
                    used[hit] = True
            else:
                if sends and is_dead(src, k):
                    return ("divergence", f"cleanup step {k}: dead src "
                            f"{src} sends")
                for s_rw in sends:
                    if is_dead(s_rw.to, k):
                        return ("divergence", f"cleanup step {k}: send to "
                                f"dead node {s_rw.to}")
    alive = [real(r) not in dead for r in range(n)]
    err = verify_dataflow(rw, alive=alive)
    if err is not None:
        return ("divergence", f"survivor dataflow: {err[0]} ({err[1]})")
    return None


def certify_response(b, base, resp):
    """Differentially certify a schedule::online Response: stage order plus
    the rewrite diff against the pre-fault schedule (native builds only —
    online collapses padded rewrites internally). Returns None or a typed
    (kind, detail)."""
    err = audit_stages(resp.stages, base.torus)
    if err is not None:
        return err
    rewrites = [s for s, a in resp.actions if a == "rewrite"]
    if not rewrites:
        return None  # detour-only response: the schedule is the original
    # The controller records faults as staged models; a rank is dead from
    # the first stage in which every one of its ports is down. Only
    # rewrite-applied stages create proof obligations — a fault the
    # controller detoured (or could not rewrite) leaves the schedule
    # untouched, so its sends legitimately remain.
    t = base.torus

    def downed(model, r):
        return all(model.down[t.link_index(r, d, dr)]
                   for d in range(t.ndims()) for dr in (1, -1))

    dead = {}
    prev = None
    for (frm, model), (_s, applied) in zip(resp.stages, resp.actions):
        if applied == "rewrite":
            for r in range(t.n):
                if r not in dead and downed(model, r) and (
                        prev is None or not downed(prev, r)):
                    dead[r] = frm
        prev = model
    return certify_rewrite(b.net, resp.schedule, min(rewrites), dead)


# ------------------------------------------------------- pass manager lite
def run_passes(b, torus, passes=None):
    """Mirror of verify::passes::PassManager::run — executes the selected
    passes over one BuiltCollective, returning (cert, findings, timings):
    cert is the certificate dict (only the fields of executed passes),
    findings a list of (pass, severity, message) with severity in
    {"error", "warn", "info"}, timings a list of (pass, seconds)."""
    import time as _time
    sel = select_passes(passes)
    cert = dict(name=b.net.name, algo=b.algo, variant=b.variant,
                padded=b.padded)
    findings = []
    timings = []
    hm = host_multiplicity(b)
    for p in sel:
        t0 = _time.perf_counter()
        if p == "dataflow":
            err = verify_dataflow(b.exec_s)
            if err is not None:
                findings.append((p, "error", f"{err[0]}: {err[1]}"))
        elif p == "hazard":
            haz = audit_hazards(b.exec_s)
            cert["hazard"] = haz
            if haz["waw_conflicts"] > 0:
                findings.append((p, "error",
                                 f"{haz['waw_conflicts']} WAW race(s)"))
            if haz["war_cells"] > 0:
                if b.variant == "B":
                    findings.append((p, "error",
                                     f"{haz['war_cells']} WAR cell(s) on an "
                                     "in-place (bandwidth) variant"))
                else:
                    findings.append((p, "info",
                                     f"{haz['war_cells']} WAR cell(s) rely "
                                     "on the receive barrier"))
        elif p == "deadlock":
            err = audit_deadlock(b.exec_s)
            cert["deadlock_ok"] = err is None
            if err is not None:
                findings.append((p, "error", err[1]))
        elif p == "memory":
            mem = audit_memory(b.exec_s, b.hosts, torus.n)
            cert["memory"] = mem
            err = require_peak_within(mem, memory_bound(b, mem))
            if err is not None:
                findings.append((p, "error", err[1]))
        elif p == "ports":
            budget = port_budget(b.algo, b.variant) * hm
            max_port, err = audit_ports(b.net, torus, budget)
            cert["budget"], cert["max_port_msgs"] = budget, max_port
            if err is not None:
                findings.append((p, "error", f"{err[0]}: {err[1]}"))
        elif p == "congestion":
            cert["congestion"] = audit_congestion(b.net, torus)
        elif p == "optimality":
            cert["optimality"] = audit_optimality(b.net, torus)
        elif p == "cost":
            cc = cost_certificate(b.net, NetModel.uniform(torus))
            cert["cost"] = cc
            tx = cert["congestion"]["tx_delay_rel"]
            if abs(cc["tx_rel"] - tx) > 1e-12:
                findings.append((p, "error",
                                 f"certificate tx_rel {cc['tx_rel']} != "
                                 f"congestion audit {tx}"))
        timings.append((p, _time.perf_counter() - t0))
    return cert, findings, timings


def dataflow_max_atoms(s):
    """Peak atoms held by any (rank, block) cell during the lattice replay
    (mirror of DataflowProof::max_atoms)."""
    n, nb = s.n, s.n_blocks
    full = frozenset(range(n))
    cells = [[[frozenset([r])] for _ in range(nb)] for r in range(n)]
    peak = 1
    for step in s.steps:
        for src in range(n):
            for snd in step[src]:
                for blocks, kind, contrib in snd.pieces:
                    for b in blocks:
                        if kind == "reduce":
                            cells[snd.to][b].append(contrib)
                            peak = max(peak, len(cells[snd.to][b]))
                        else:
                            cells[snd.to][b] = [full]
    return peak


def report_v2(topos):
    """Mirror of verify::report_json schema trivance.verify.v2 — the exact
    shape the Rust side emits (every v1 field preserved under its v1 name,
    hazard/deadlock/memory/cost fields and per-pass timings added) — feeds
    tools/check_verify_report.py in the pysim CI job."""
    out_topos = []
    agg = {}
    for torus in topos:
        certs = certify_registry(torus)
        entries = []
        for (algo, variant), cert in sorted(certs.items()):
            b = build(algo, variant, torus)
            b.algo, b.variant = algo, variant
            _c, _f, timings = run_passes(b, torus)
            for p, dt in timings:
                agg[p] = agg.get(p, 0.0) + dt
            opt, cong = cert["optimality"], cert["congestion"]
            entries.append(dict(
                collective=cert["name"], algo=algo, variant=variant,
                padded=cert["padded"], steps=opt["steps"],
                lat_bound3=opt["lat_bound3"], lat_bound2=opt["lat_bound2"],
                max_node_sent_rel=opt["max_node_sent_rel"],
                bw_lower_rel=opt["bw_lower_rel"],
                port_budget=cert["budget"],
                max_port_msgs=cert["max_port_msgs"],
                tx_delay_rel=cong["tx_delay_rel"],
                max_link_rel=cong["max_link_rel"],
                mean_link_rel=cong["mean_link_rel"],
                max_link_msgs=cong["max_link_msgs"],
                bytes_on_wire_rel=cong["bytes_on_wire_rel"],
                messages=cong["messages"],
                max_atoms=dataflow_max_atoms(b.exec_s),
                hazard_war_cells=cert["hazard"]["war_cells"],
                hazard_waw_conflicts=cert["hazard"]["waw_conflicts"],
                barrier_free=cert["hazard"]["barrier_free"],
                deadlock_ok=cert["deadlock_ok"],
                mem_peak_rel=cert["memory"]["peak_live_rel"],
                mem_in_rel_max=cert["memory"]["in_rel_max"],
                cost_steps=cert["cost"]["steps"],
                cost_tx_rel=cert["cost"]["tx_rel"],
                cost_hop_lat_rel=cert["cost"]["hop_lat_rel"],
                cost_hop_proc_rel=cert["cost"]["hop_proc_rel"],
                **{"class": opt["klass"]}))
        out_topos.append(dict(dims=list(torus.dims), certs=entries))
    passes = [dict(name=p, seconds=agg.get(p, 0.0)) for p in PASS_NAMES]
    return {"schema": "trivance.verify.v2", "passes": passes,
            "topos": out_topos}
