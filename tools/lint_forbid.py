"""CI source lint: ban `.unwrap()`, `.expect(` and `panic!` in the library
paths of the Rust tree (rust/src/{sim,net,schedule,verify}).

Usage: lint_forbid.py [--root DIR] [--allow FILE]

Library code must surface failures as typed errors (VerifyError, SimError,
try_* variants) — a panic in the serving path takes the daemon down with
the plan it was certifying. Test code is exempt: this repo keeps tests in
a trailing `#[cfg(test)]` module, so scanning stops at the first
`#[cfg(test)]` line of each file.

Justified exceptions live in tools/lint_forbid_allow.txt, one per line:

    path :: substring :: reason

An allowlist entry excuses a flagged line when the line's file matches
`path` (relative to rust/src) and the line contains `substring`. Unused
allowlist entries are an error too — stale exceptions hide regressions.

Exit codes: 0 clean, 1 violations (or stale allowlist entries), 2 usage.
"""

import argparse
import os
import re
import sys

LIB_DIRS = ["sim", "net", "schedule", "verify"]
FORBIDDEN = re.compile(r"\.unwrap\(\)|\.expect\(|panic!")
TEST_GATE = re.compile(r"#\[cfg\(test\)\]")


def parse_allowlist(path):
    entries = []
    if not os.path.exists(path):
        return entries
    with open(path) as f:
        for ln, raw in enumerate(f, 1):
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            parts = [p.strip() for p in line.split("::")]
            if len(parts) != 3 or not all(parts):
                raise ValueError(f"{path}:{ln}: want 'path :: substring "
                                 f":: reason', got {line!r}")
            entries.append({"path": parts[0], "substring": parts[1],
                            "reason": parts[2], "used": False})
    return entries


def scan_file(root, rel, allow):
    violations = []
    with open(os.path.join(root, rel)) as f:
        for ln, line in enumerate(f, 1):
            if TEST_GATE.search(line):
                break
            m = FORBIDDEN.search(line)
            if not m:
                continue
            excused = False
            for e in allow:
                if e["path"] == rel and e["substring"] in line:
                    e["used"] = True
                    excused = True
                    break
            if not excused:
                violations.append((rel, ln, m.group(0), line.rstrip()))
    return violations


def main():
    ap = argparse.ArgumentParser(
        description="ban unwrap/expect/panic! in rust library paths")
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir))
    ap.add_argument("--allow", default=None)
    args = ap.parse_args()
    src = os.path.join(args.root, "rust", "src")
    if not os.path.isdir(src):
        print(f"no rust/src under {args.root}", file=sys.stderr)
        return 2
    allow_path = args.allow or os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "lint_forbid_allow.txt")
    try:
        allow = parse_allowlist(allow_path)
    except ValueError as e:
        print(e, file=sys.stderr)
        return 2

    violations = []
    scanned = 0
    for d in LIB_DIRS:
        base = os.path.join(src, d)
        if not os.path.isdir(base):
            continue
        for dirpath, _dirs, files in os.walk(base):
            for name in sorted(files):
                if not name.endswith(".rs"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, name), src)
                scanned += 1
                violations.extend(scan_file(src, rel, allow))

    rc = 0
    for rel, ln, tok, line in violations:
        print(f"FAIL: {rel}:{ln}: forbidden {tok!r}: {line.strip()}",
              file=sys.stderr)
        rc = 1
    for e in allow:
        if not e["used"]:
            print(f"FAIL: stale allowlist entry {e['path']} :: "
                  f"{e['substring']!r} matches nothing", file=sys.stderr)
            rc = 1
    if rc == 0:
        print(f"lint_forbid: {scanned} library files clean "
              f"({len(allow)} justified exceptions)")
    return rc


if __name__ == "__main__":
    sys.exit(main())
