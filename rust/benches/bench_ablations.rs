//! Ablation benches for the design choices DESIGN.md calls out: each case
//! quantifies one decision by comparing completion times / congestion of
//! the two alternatives on the paper's network.

use trivance::agpattern::{latency_allreduce, AgPattern};
use trivance::algo::multidim::{concurrent_slices, ProductAg};
use trivance::algo::rings::{bruck, fullport, trivance, Order};
use trivance::algo::{build, Algo, Variant};
use trivance::cost::{measure_optimality, NetParams};
use trivance::schedule::analysis::analyze;
use trivance::sim::{simulate, SimMode};
use trivance::topology::Torus;
use trivance::util::fmt;

fn completion(s: &trivance::schedule::Schedule, t: &Torus, m: u64) -> f64 {
    simulate(s, t, m, &NetParams::default(), SimMode::Flow).completion_s
}

fn main() {
    let p = NetParams::default();
    let _ = p;

    println!("== ablation: Bruck routing modification (ring 27, latency variant) ==");
    let t = Torus::ring(27);
    let modif = build(Algo::Bruck, Variant::Latency, &t).unwrap();
    let unmod = build(Algo::BruckUnidir, Variant::Latency, &t).unwrap();
    for m in [32u64, 64 << 10, 4 << 20] {
        let a = completion(&modif.net, &t, m);
        let b = completion(&unmod.net, &t, m);
        println!(
            "  m={:>8}: shortest-path {:>12}  unidirectional {:>12}  ({:.2}× worse)",
            fmt::bytes(m),
            fmt::secs(a),
            fmt::secs(b),
            b / a
        );
    }

    println!("\n== ablation: multidim dimension order for Trivance-L (9x9) ==");
    let t2 = Torus::new(&[9, 9]);
    let mk = |seq: bool| {
        let p0 = trivance(9, Order::Inc);
        let p1 = trivance(9, Order::Inc);
        let steps: Vec<usize> = vec![2, 2];
        let slices: Vec<_> = (0..2)
            .map(|c| {
                let sd = if seq {
                    ProductAg::sequential(&steps, c)
                } else {
                    ProductAg::round_robin(&steps, c)
                };
                latency_allreduce(&ProductAg::new(format!("abl{c}"), t2.clone(), &[&p0, &p1], sd))
            })
            .collect();
        concurrent_slices(slices, "abl".into())
    };
    for m in [32u64, 1 << 20] {
        let rr = completion(&mk(false), &t2, m);
        let sq = completion(&mk(true), &t2, m);
        println!(
            "  m={:>8}: round-robin (Fig. 5) {:>12}  sequential {:>12}",
            fmt::bytes(m),
            fmt::secs(rr),
            fmt::secs(sq)
        );
    }

    println!("\n== ablation: virtual padding cost (swing on n=27 via 32 virtual) ==");
    let t27 = Torus::ring(27);
    let sw = build(Algo::Swing, Variant::Latency, &t27).unwrap();
    let tv = build(Algo::Trivance, Variant::Latency, &t27).unwrap();
    for m in [32u64, 256 << 10] {
        println!(
            "  m={:>8}: padded swing {:>12}  native trivance {:>12}",
            fmt::bytes(m),
            fmt::secs(completion(&sw.net, &t27, m)),
            fmt::secs(completion(&tv.net, &t27, m))
        );
    }

    println!("\n== extension: full-port radix-(p+1) pattern (§7), steps & congestion ==");
    for (n, ports) in [(81u32, 2u32), (81, 4), (81, 8)] {
        let pat = fullport(n, ports, Order::Inc);
        let s = latency_allreduce(&pat);
        let t = Torus::ring(n);
        let stats = analyze(&s, &t);
        let o = measure_optimality(&stats, &t);
        println!(
            "  n={n} ports={ports}: steps={:>2}  Θ={:>7.1}  completion(32B)={}",
            s.num_steps(),
            o.theta,
            fmt::secs(completion(&s, &t, 32))
        );
    }

    println!("\n== reference: trivance vs bruck step structure (ring 81) ==");
    let tb = bruck(81, Order::Inc, false);
    let tt = trivance(81, Order::Inc);
    println!(
        "  bruck steps={}  trivance steps={}  (both ⌈log₃ 81⌉ = 4)",
        tb.num_steps(),
        tt.num_steps()
    );
}
