//! End-to-end benchmark: regenerate every figure of the paper's evaluation
//! (the workload of `trivance figures --all`), timing each artifact.
//!
//! Full-fidelity inputs for the small topologies; the 32×32 and 16×16×16
//! sweeps run once per invocation (they are minutes-scale by design — the
//! paper's own SST sweeps are hours-scale).

use trivance::util::bench::Bencher;

fn main() {
    println!("== figure regeneration (end-to-end) ==");
    // fast figures: several iterations for stable numbers
    let b = Bencher::new(1, 3);
    for id in ["table1", "table2", "fig6a", "fig6b", "fig7a"] {
        b.run(&format!("figures/{id}"), || {
            trivance::harness::run(id, false).unwrap().len()
        });
    }
    // heavyweight sweeps: single timed pass; fig7b/fig10 run their quick
    // (reduced-topology) configurations here to keep `cargo bench` bounded —
    // the full 32×32 / 16×16×16 sweeps are `trivance figures --id fig7b`
    // (~2 min) and `--id fig10` (~25 min), recorded in EXPERIMENTS.md.
    let b1 = Bencher::new(0, 1);
    b1.run("figures/fig9", || trivance::harness::run("fig9", false).unwrap().len());
    b1.run("figures/fig8-quick", || trivance::harness::run("fig8", true).unwrap().len());
    b1.run("figures/fig7b-quick", || trivance::harness::run("fig7b", true).unwrap().len());
    b1.run("figures/fig10-quick", || trivance::harness::run("fig10", true).unwrap().len());
}
