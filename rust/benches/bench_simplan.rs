//! SimPlan benchmarks: plan compilation, plan reuse across a message-size
//! ladder vs per-size rebuild, the incremental water-filling under heavy
//! congestion, the batched packet engine vs the per-packet reference, the
//! plan cache, and the parallel sweep engine vs one thread.
//!
//! (criterion is not in the vendored registry; this drives the same
//! hand-rolled harness as the other bench targets.)

use trivance::algo::{build, Algo, Variant};
use trivance::cost::NetParams;
use trivance::harness::sweep::{run_sweep_threads, size_ladder};
use trivance::sim::packet::{reference, simulate_packet_plan, simulate_packet_plan_queue};
use trivance::sim::{
    flow::simulate_flow_plan, simulate, PlanCache, PlanKey, QueueKind, SimMode, SimPlan,
    SimScratch,
};
use trivance::topology::Torus;
use trivance::util::bench::Bencher;
use trivance::util::par;

fn main() {
    let b = Bencher::new(1, 5);
    let p = NetParams::default();

    println!("== plan compilation (once per ladder) ==");
    let t81 = Torus::ring(81);
    let tv81 = build(Algo::Trivance, Variant::Bandwidth, &t81).unwrap();
    b.run("plan-build/ring81/trivance-B", || SimPlan::build(&tv81.net, &t81).num_msgs());
    let t88 = Torus::new(&[8, 8]);
    let bu88 = build(Algo::Bucket, Variant::Bandwidth, &t88).unwrap();
    b.run("plan-build/8x8/bucket-B", || SimPlan::build(&bu88.net, &t88).num_msgs());

    println!("\n== plan cache: hit vs fresh build ==");
    let cache = PlanCache::new();
    cache.get_or_build(PlanKey::new(Algo::Bucket, Variant::Bandwidth, t88.dims()), || {
        SimPlan::build(&bu88.net, &t88)
    });
    b.run("plan-cache/8x8/bucket-B/hit", || {
        cache
            .get_or_build(PlanKey::new(Algo::Bucket, Variant::Bandwidth, t88.dims()), || {
                SimPlan::build(&bu88.net, &t88)
            })
            .num_msgs()
    });
    b.run("plan-cache/8x8/bucket-B/fresh", || SimPlan::build(&bu88.net, &t88).num_msgs());

    println!("\n== ladder: one plan reused vs per-size rebuild ==");
    let ladder = size_ladder(8 << 20);
    let plan88 = SimPlan::build(&bu88.net, &t88);
    b.run("ladder/8x8/bucket-B/reuse-plan", || {
        ladder
            .iter()
            .map(|&m| simulate_flow_plan(&plan88, m, &p).events)
            .sum::<u64>()
    });
    b.run("ladder/8x8/bucket-B/rebuild-per-size", || {
        ladder
            .iter()
            .map(|&m| simulate(&bu88.net, &t88, m, &p, SimMode::Flow).events)
            .sum::<u64>()
    });

    println!("\n== incremental water-filling under congestion ==");
    let t27 = Torus::ring(27);
    let bu27 = build(Algo::BruckUnidir, Variant::Latency, &t27).unwrap();
    let plan27 = SimPlan::build(&bu27.net, &t27);
    b.run("flow/ring27/bruck-unidir-L/8MiB", || {
        simulate_flow_plan(&plan27, 8 << 20, &p).events
    });
    let tv27 = build(Algo::Trivance, Variant::Bandwidth, &t27).unwrap();
    let plan27b = SimPlan::build(&tv27.net, &t27);
    b.run("flow/ring27/trivance-B/8MiB", || {
        simulate_flow_plan(&plan27b, 8 << 20, &p).events
    });

    println!("\n== packet engine: batched vs per-packet reference (ring27, 1 MiB) ==");
    let tv27l = build(Algo::Trivance, Variant::Latency, &t27).unwrap();
    let plan27l = SimPlan::build(&tv27l.net, &t27);
    let batched = b.run("packet/ring27/trivance-L/1MiB/batched", || {
        simulate_packet_plan(&plan27l, 1 << 20, &p, 4096).events
    });
    let refr = b.run("packet/ring27/trivance-L/1MiB/reference", || {
        reference::simulate_packet_reference_plan(&plan27l, 1 << 20, &p, 4096).events
    });
    let be = simulate_packet_plan(&plan27l, 1 << 20, &p, 4096);
    let re = reference::simulate_packet_reference_plan(&plan27l, 1 << 20, &p, 4096);
    // The acceptance metric is simulated packet-work per wall second: both
    // engines simulate the same collective, so throughput is the per-packet
    // reference event count divided by each engine's wall time.
    let batched_throughput = re.events as f64 / batched.median_s;
    let reference_throughput = re.events as f64 / refr.median_s;
    println!(
        "batched: {} events in {:.3} ms | reference: {} events in {:.3} ms | \
         packet-work throughput {:.2e} vs {:.2e} pkt-ev/s ({:.1}x), \
         heap-event reduction {:.0}x, completion drift {:.2e}",
        be.events,
        batched.median_s * 1e3,
        re.events,
        refr.median_s * 1e3,
        batched_throughput,
        reference_throughput,
        batched_throughput / reference_throughput,
        re.events as f64 / be.events as f64,
        (be.completion_s - re.completion_s).abs() / re.completion_s,
    );

    println!("\n== event queue: heap vs calendar (8x8 trivance-B, 1 MiB packets) ==");
    let tv88 = build(Algo::Trivance, Variant::Bandwidth, &t88).unwrap();
    let plan88b = SimPlan::build(&tv88.net, &t88);
    let scratch88 = SimScratch::new(&plan88b, &p);
    for kind in [QueueKind::Heap, QueueKind::Calendar] {
        b.run(&format!("packet/8x8/trivance-B/1MiB/{kind}"), || {
            simulate_packet_plan_queue(&plan88b, 1 << 20, &p, 4096, &scratch88, kind).0.events
        });
    }
    let (hres, _) =
        simulate_packet_plan_queue(&plan88b, 1 << 20, &p, 4096, &scratch88, QueueKind::Heap);
    let (cres, cs) =
        simulate_packet_plan_queue(&plan88b, 1 << 20, &p, 4096, &scratch88, QueueKind::Calendar);
    assert_eq!(hres.completion_s.to_bits(), cres.completion_s.to_bits());
    println!(
        "bit-identical across kinds: {} events | calendar: {} resizes, {} entries scanned \
         over {} pops ({:.2}/pop)",
        hres.events,
        cs.resizes,
        cs.scanned,
        cs.pops,
        cs.scanned as f64 / cs.pops.max(1) as f64,
    );

    println!("\n== sweep engine: 3x3x3 full registry, 32 B – 4 MiB ==");
    let t333 = Torus::new(&[3, 3, 3]);
    let sizes = size_ladder(4 << 20);
    let b1 = Bencher::new(1, 3);
    b1.run("sweep/3x3x3/threads=1", || {
        run_sweep_threads(&t333, &Algo::ALL, &sizes, &p, 1).points.len()
    });
    let auto = par::available_threads();
    b1.run(&format!("sweep/3x3x3/threads={auto}"), || {
        run_sweep_threads(&t333, &Algo::ALL, &sizes, &p, 0).points.len()
    });
}
