//! SimPlan benchmarks: plan compilation, plan reuse across a message-size
//! ladder vs per-size rebuild, the incremental water-filling under heavy
//! congestion, and the parallel sweep engine vs one thread.
//!
//! (criterion is not in the vendored registry; this drives the same
//! hand-rolled harness as the other bench targets.)

use trivance::algo::{build, Algo, Variant};
use trivance::cost::NetParams;
use trivance::harness::sweep::{run_sweep_threads, size_ladder};
use trivance::sim::{flow::simulate_flow_plan, simulate, SimMode, SimPlan};
use trivance::topology::Torus;
use trivance::util::bench::Bencher;
use trivance::util::par;

fn main() {
    let b = Bencher::new(1, 5);
    let p = NetParams::default();

    println!("== plan compilation (once per ladder) ==");
    let t81 = Torus::ring(81);
    let tv81 = build(Algo::Trivance, Variant::Bandwidth, &t81).unwrap();
    b.run("plan-build/ring81/trivance-B", || SimPlan::build(&tv81.net, &t81).num_msgs());
    let t88 = Torus::new(&[8, 8]);
    let bu88 = build(Algo::Bucket, Variant::Bandwidth, &t88).unwrap();
    b.run("plan-build/8x8/bucket-B", || SimPlan::build(&bu88.net, &t88).num_msgs());

    println!("\n== ladder: one plan reused vs per-size rebuild ==");
    let ladder = size_ladder(8 << 20);
    let plan88 = SimPlan::build(&bu88.net, &t88);
    b.run("ladder/8x8/bucket-B/reuse-plan", || {
        ladder
            .iter()
            .map(|&m| simulate_flow_plan(&plan88, m, &p).events)
            .sum::<u64>()
    });
    b.run("ladder/8x8/bucket-B/rebuild-per-size", || {
        ladder
            .iter()
            .map(|&m| simulate(&bu88.net, &t88, m, &p, SimMode::Flow).events)
            .sum::<u64>()
    });

    println!("\n== incremental water-filling under congestion ==");
    let t27 = Torus::ring(27);
    let bu27 = build(Algo::BruckUnidir, Variant::Latency, &t27).unwrap();
    let plan27 = SimPlan::build(&bu27.net, &t27);
    b.run("flow/ring27/bruck-unidir-L/8MiB", || {
        simulate_flow_plan(&plan27, 8 << 20, &p).events
    });
    let tv27 = build(Algo::Trivance, Variant::Bandwidth, &t27).unwrap();
    let plan27b = SimPlan::build(&tv27.net, &t27);
    b.run("flow/ring27/trivance-B/8MiB", || {
        simulate_flow_plan(&plan27b, 8 << 20, &p).events
    });

    println!("\n== sweep engine: 3x3x3 full registry, 32 B – 4 MiB ==");
    let t333 = Torus::new(&[3, 3, 3]);
    let sizes = size_ladder(4 << 20);
    let b1 = Bencher::new(1, 3);
    b1.run("sweep/3x3x3/threads=1", || {
        run_sweep_threads(&t333, &Algo::ALL, &sizes, &p, 1).points.len()
    });
    let auto = par::available_threads();
    b1.run(&format!("sweep/3x3x3/threads={auto}"), || {
        run_sweep_threads(&t333, &Algo::ALL, &sizes, &p, 0).points.len()
    });
}
