//! Microbenchmarks of the hot paths (the §Perf targets in EXPERIMENTS.md):
//! schedule construction, validation, congestion analysis, both simulator
//! modes, and the numeric executor (native and, when artifacts exist,
//! PJRT reductions).

use trivance::algo::{build, Algo, Variant};
use trivance::cost::NetParams;
use trivance::exec::{verify_allreduce, NativeReducer, Reducer, VectorReducer};
use trivance::schedule::analysis::analyze;
use trivance::sim::{simulate, SimMode};
use trivance::topology::Torus;
use trivance::util::bench::Bencher;

fn main() {
    let b = Bencher::new(1, 5);

    println!("== schedule construction ==");
    for (label, dims) in [("ring64", vec![64u32]), ("ring81", vec![81]), ("8x8", vec![8, 8])] {
        let t = Torus::new(&dims);
        for algo in [Algo::Trivance, Algo::Bruck, Algo::Swing, Algo::Bucket] {
            for variant in Variant::ALL {
                if build(algo, variant, &t).is_err() {
                    continue;
                }
                b.run(&format!("build/{label}/{}-{}", algo.label(), variant.label()), || {
                    build(algo, variant, &t).unwrap().net.num_messages()
                });
            }
        }
    }
    // the heavy construction cases, once
    let b1 = Bencher::new(0, 1);
    let t32 = Torus::new(&[32, 32]);
    b1.run("build/32x32/trivance-L", || {
        build(Algo::Trivance, Variant::Latency, &t32).unwrap().net.num_messages()
    });
    b1.run("build/32x32/bucket-B", || {
        build(Algo::Bucket, Variant::Bandwidth, &t32).unwrap().net.num_messages()
    });

    println!("\n== validation ==");
    let t81 = Torus::ring(81);
    let tv81 = build(Algo::Trivance, Variant::Bandwidth, &t81).unwrap();
    b.run("validate/ring81/trivance-B", || tv81.validate().unwrap().messages);

    println!("\n== congestion analysis ==");
    let stats = b.run("analyze/ring81/trivance-B", || analyze(&tv81.net, &t81).tx_delay_rel);
    let _ = stats;

    println!("\n== simulators ==");
    let p = NetParams::default();
    let t27 = Torus::ring(27);
    let tv27 = build(Algo::Trivance, Variant::Bandwidth, &t27).unwrap();
    b.run("sim-flow/ring27/trivance-B/1MiB", || {
        simulate(&tv27.net, &t27, 1 << 20, &p, SimMode::Flow).events
    });
    b.run("sim-packet/ring27/trivance-B/1MiB", || {
        simulate(&tv27.net, &t27, 1 << 20, &p, SimMode::Packet { mtu: 4096 }).events
    });
    let t88 = Torus::new(&[8, 8]);
    let bu88 = build(Algo::Bucket, Variant::Bandwidth, &t88).unwrap();
    b.run("sim-flow/8x8/bucket-B/8MiB", || {
        simulate(&bu88.net, &t88, 8 << 20, &p, SimMode::Flow).events
    });
    let bu32 = build(Algo::Bucket, Variant::Bandwidth, &t32).unwrap();
    b1.run("sim-flow/32x32/bucket-B/8MiB", || {
        simulate(&bu32.net, &t32, 8 << 20, &p, SimMode::Flow).events
    });

    println!("\n== reduction kernels: scalar vs vectorized (4M f32) ==");
    let elems = 1usize << 22;
    let mut rng = trivance::util::SplitMix64::new(0xBE7C);
    let a0: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
    let bv: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
    let cv: Vec<f32> = (0..elems).map(|_| rng.f32()).collect();
    let kernels: [(&str, &dyn Reducer); 2] = [("scalar", &NativeReducer), ("vector", &VectorReducer)];
    for (name, r) in kernels {
        let mut acc = a0.clone();
        b.run(&format!("reduce/add2/{name}/4M"), || {
            r.add2_assign(&mut acc, &bv);
            acc[0]
        });
        let mut acc = a0.clone();
        b.run(&format!("reduce/add3/{name}/4M"), || {
            r.add3_assign(&mut acc, &bv, &cv);
            acc[0]
        });
    }

    println!("\n== numeric executor ==");
    let tv9 = build(Algo::Trivance, Variant::Latency, &Torus::ring(9)).unwrap();
    b.run("exec-native/ring9/trivance-L/L=1024", || {
        verify_allreduce(&tv9.exec, 1024, 1, &NativeReducer)
    });
    match trivance::runtime::Runtime::load_default() {
        Ok(rt) => {
            b.run("exec-pjrt/ring9/trivance-L/L=1024", || {
                verify_allreduce(&tv9.exec, 1024, 1, &rt as &dyn Reducer)
            });
            let a = vec![1.0f32; rt.meta.reduce_lanes];
            let c = vec![2.0f32; rt.meta.reduce_lanes];
            let d = vec![3.0f32; rt.meta.reduce_lanes];
            b.run("pjrt/reduce3/4096", || rt.reduce3(&a, &c, &d).unwrap().len());
        }
        Err(_) => println!("(artifacts not built — skipping PJRT benches)"),
    }
}
