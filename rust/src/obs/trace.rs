//! Span/event flight recorder with Chrome trace-event JSON export.
//!
//! [`Recorder`] is the in-memory [`Sink`]: it timestamps nothing itself —
//! every event arrives with the emitting subsystem's own simulation-time
//! (or wall-clock, for the harness lane) seconds — and buffers events plus
//! per-link telemetry rows under one mutex. [`Recorder::to_chrome_json`]
//! emits the Chrome trace-event "JSON object format": a `traceEvents`
//! array sorted by timestamp (stable on insertion order, so a zero-width
//! span's `B` still precedes its `E`), timestamps converted to
//! microseconds, with the telemetry rows preserved exactly (full-precision
//! f64 seconds) under the extra top-level key `link_telemetry` — Chrome
//! and Perfetto both ignore unknown top-level keys, so the file loads
//! as-is in `ui.perfetto.dev`.
//!
//! [`Recorder::validate`] is the schema check the tests and
//! `tools/check_trace.py` share: monotone export timestamps, matched `B`/`E`
//! pairs per `(pid, tid)`, and known lane pids.

use super::Sink;
use std::sync::{Mutex, PoisonError};

/// One packet-engine busy interval on one link: the per-link congestion
/// telemetry row. `bytes / (end_s − start_s)` is the achieved bandwidth;
/// `cap_bytes_per_s` is the link's *pristine* capacity (timeline brownouts
/// stretch the interval instead, so achieved < cap is the congestion
/// signal). `queue_len` is the event-queue depth when the batch was
/// scheduled — the queue-depth time series.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkSample {
    /// Dense directed-link index.
    pub link: u32,
    /// Schedule step of the batch occupying the link.
    pub step: u32,
    pub start_s: f64,
    pub end_s: f64,
    pub bytes: f64,
    pub cap_bytes_per_s: f64,
    pub queue_len: u32,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum Ph {
    B,
    E,
    X,
    I,
}

#[derive(Clone, Debug)]
struct TraceEvent {
    ph: Ph,
    pid: u32,
    tid: u32,
    name: String,
    ts_s: f64,
    /// Duration in seconds (X events only).
    dur_s: f64,
    args: Vec<(String, f64)>,
}

#[derive(Default)]
struct Inner {
    seq: u64,
    events: Vec<(u64, TraceEvent)>,
    samples: Vec<LinkSample>,
}

/// The buffering [`Sink`] behind `trivance trace`.
#[derive(Default)]
pub struct Recorder {
    inner: Mutex<Inner>,
}

impl Recorder {
    pub fn new() -> Recorder {
        Recorder::default()
    }

    fn push(&self, ev: TraceEvent) {
        let mut inner = self.inner.lock().unwrap_or_else(PoisonError::into_inner);
        let seq = inner.seq;
        inner.seq += 1;
        inner.events.push((seq, ev));
    }

    /// Events recorded so far.
    pub fn num_events(&self) -> usize {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).events.len()
    }

    /// Copy of the per-link telemetry rows (insertion order — the packet
    /// engine's event order).
    pub fn samples(&self) -> Vec<LinkSample> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).samples.clone()
    }

    /// Events in export order: stable-sorted by `(ts, insertion seq)`.
    fn sorted_events(&self) -> Vec<TraceEvent> {
        let mut events =
            self.inner.lock().unwrap_or_else(PoisonError::into_inner).events.clone();
        events.sort_by(|a, b| a.1.ts_s.total_cmp(&b.1.ts_s).then(a.0.cmp(&b.0)));
        events.into_iter().map(|(_, e)| e).collect()
    }

    /// Schema self-check (shared with `tools/check_trace.py`, which
    /// re-validates the exported JSON): export-order timestamps monotone
    /// non-decreasing and NaN-free, every `E` matches the innermost open
    /// `B` of the same name on its `(pid, tid)` track, no span left open,
    /// `X` durations non-negative, pids within the known lanes.
    pub fn validate(&self) -> Result<(), String> {
        use std::collections::BTreeMap;
        let events = self.sorted_events();
        let mut last_ts = f64::NEG_INFINITY;
        let mut stacks: BTreeMap<(u32, u32), Vec<String>> = BTreeMap::new();
        for (i, e) in events.iter().enumerate() {
            if e.ts_s.is_nan() {
                return Err(format!("event {i} ({}): NaN timestamp", e.name));
            }
            if e.ts_s < last_ts {
                return Err(format!("event {i} ({}): ts went backwards", e.name));
            }
            last_ts = e.ts_s;
            if !(super::PID_PACKET..=super::PID_LINKS).contains(&e.pid) {
                return Err(format!("event {i} ({}): unknown pid {}", e.name, e.pid));
            }
            match e.ph {
                Ph::B => stacks.entry((e.pid, e.tid)).or_default().push(e.name.clone()),
                Ph::E => match stacks.entry((e.pid, e.tid)).or_default().pop() {
                    Some(open) if open == e.name => {}
                    Some(open) => {
                        return Err(format!(
                            "event {i}: E \"{}\" closes open span \"{open}\"",
                            e.name
                        ))
                    }
                    None => {
                        return Err(format!("event {i}: E \"{}\" with no open span", e.name))
                    }
                },
                Ph::X => {
                    if e.dur_s.is_nan() || e.dur_s < 0.0 {
                        return Err(format!("event {i} ({}): negative dur", e.name));
                    }
                }
                Ph::I => {}
            }
        }
        for ((pid, tid), stack) in &stacks {
            if let Some(open) = stack.last() {
                return Err(format!("span \"{open}\" left open on ({pid}, {tid})"));
            }
        }
        Ok(())
    }

    /// Export as Chrome trace-event JSON (schema `trivance.trace.v1`).
    pub fn to_chrome_json(&self) -> String {
        use crate::util::json::escape;
        let events = self.sorted_events();
        let samples = self.samples();
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"schema\": \"trivance.trace.v1\",\n");
        out.push_str("  \"displayTimeUnit\": \"ms\",\n");
        out.push_str("  \"traceEvents\": [");
        let mut first = true;
        for e in &events {
            if !first {
                out.push(',');
            }
            first = false;
            let ph = match e.ph {
                Ph::B => "B",
                Ph::E => "E",
                Ph::X => "X",
                Ph::I => "i",
            };
            out.push_str(&format!(
                "\n    {{\"name\": \"{}\", \"ph\": \"{ph}\", \"pid\": {}, \"tid\": {}, \
                 \"ts\": {:e}",
                escape(&e.name),
                e.pid,
                e.tid,
                e.ts_s * 1e6,
            ));
            if e.ph == Ph::X {
                out.push_str(&format!(", \"dur\": {:e}", e.dur_s * 1e6));
            }
            if e.ph == Ph::I {
                out.push_str(", \"s\": \"t\"");
            }
            if !e.args.is_empty() {
                out.push_str(", \"args\": {");
                for (i, (k, v)) in e.args.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&format!("\"{}\": {:e}", escape(k), v));
                }
                out.push('}');
            }
            out.push('}');
        }
        out.push_str(if first { "],\n" } else { "\n  ],\n" });
        out.push_str("  \"link_telemetry\": [");
        let mut first = true;
        for s in &samples {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    {{\"link\": {}, \"step\": {}, \"start_s\": {:e}, \"end_s\": {:e}, \
                 \"bytes\": {:e}, \"cap_bytes_per_s\": {:e}, \"queue_len\": {}}}",
                s.link, s.step, s.start_s, s.end_s, s.bytes, s.cap_bytes_per_s, s.queue_len,
            ));
        }
        out.push_str(if first { "]\n" } else { "\n  ]\n" });
        out.push_str("}\n");
        out
    }
}

impl Sink for Recorder {
    fn span_begin(&self, pid: u32, tid: u32, name: &str, ts_s: f64) {
        self.push(TraceEvent {
            ph: Ph::B,
            pid,
            tid,
            name: name.to_string(),
            ts_s,
            dur_s: 0.0,
            args: Vec::new(),
        });
    }

    fn span_end(&self, pid: u32, tid: u32, name: &str, ts_s: f64) {
        self.push(TraceEvent {
            ph: Ph::E,
            pid,
            tid,
            name: name.to_string(),
            ts_s,
            dur_s: 0.0,
            args: Vec::new(),
        });
    }

    fn complete(&self, pid: u32, tid: u32, name: &str, t0_s: f64, t1_s: f64, args: &[(&str, f64)]) {
        self.push(TraceEvent {
            ph: Ph::X,
            pid,
            tid,
            name: name.to_string(),
            ts_s: t0_s,
            dur_s: t1_s - t0_s,
            args: args.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        });
    }

    fn instant(&self, pid: u32, tid: u32, name: &str, ts_s: f64, args: &[(&str, f64)]) {
        self.push(TraceEvent {
            ph: Ph::I,
            pid,
            tid,
            name: name.to_string(),
            ts_s,
            dur_s: 0.0,
            args: args.iter().map(|&(k, v)| (k.to_string(), v)).collect(),
        });
    }

    fn link_sample(&self, s: &LinkSample) {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner).samples.push(*s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{PID_FLOW, PID_LINKS, PID_PACKET};
    use crate::util::json;

    #[test]
    fn spans_sort_and_validate() {
        let r = Recorder::new();
        // emitted out of timestamp order — export must sort
        r.complete(PID_LINKS, 0, "link_busy", 2.0, 3.0, &[("bytes", 64.0)]);
        r.span_begin(PID_PACKET, 1, "packet_run", 0.0);
        r.instant(PID_PACKET, 1, "epoch", 1.5, &[("idx", 0.0)]);
        r.span_end(PID_PACKET, 1, "packet_run", 4.0);
        assert_eq!(r.num_events(), 4);
        r.validate().expect("valid trace");
    }

    #[test]
    fn zero_width_span_keeps_b_before_e() {
        let r = Recorder::new();
        r.span_begin(PID_FLOW, 7, "run", 1.0);
        r.span_end(PID_FLOW, 7, "run", 1.0);
        r.validate().expect("B sorts before E at equal ts");
    }

    #[test]
    fn mismatched_and_open_spans_are_rejected() {
        let r = Recorder::new();
        r.span_begin(PID_FLOW, 0, "outer", 0.0);
        r.span_end(PID_FLOW, 0, "inner", 1.0);
        assert!(r.validate().is_err());
        let r = Recorder::new();
        r.span_begin(PID_FLOW, 0, "outer", 0.0);
        assert!(r.validate().unwrap_err().contains("left open"));
        let r = Recorder::new();
        r.span_end(PID_FLOW, 0, "never_opened", 0.0);
        assert!(r.validate().unwrap_err().contains("no open span"));
    }

    #[test]
    fn chrome_json_parses_and_converts_to_microseconds() {
        let r = Recorder::new();
        r.span_begin(PID_PACKET, 3, "run", 0.0);
        r.complete(PID_LINKS, 2, "link_busy", 1e-6, 3e-6, &[("bytes", 4096.0)]);
        r.span_end(PID_PACKET, 3, "run", 5e-6);
        r.link_sample(&LinkSample {
            link: 2,
            step: 1,
            start_s: 1e-6,
            end_s: 3e-6,
            bytes: 4096.0,
            cap_bytes_per_s: 2.048e9,
            queue_len: 5,
        });
        let doc = json::parse(&r.to_chrome_json()).expect("valid JSON");
        assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("trivance.trace.v1"));
        let events = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(events.len(), 3);
        let x = events
            .iter()
            .find(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X"))
            .unwrap();
        assert_eq!(x.get("ts").and_then(|v| v.as_f64()), Some(1.0)); // 1 µs
        assert_eq!(x.get("dur").and_then(|v| v.as_f64()), Some(2e-6 * 1e6));
        assert_eq!(
            x.get("args").and_then(|a| a.get("bytes")).and_then(|v| v.as_f64()),
            Some(4096.0)
        );
        let rows = doc.get("link_telemetry").and_then(|v| v.as_arr()).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].get("link").and_then(|v| v.as_u64()), Some(2));
        // telemetry keeps full-precision seconds (not µs)
        assert_eq!(rows[0].get("start_s").and_then(|v| v.as_f64()), Some(1e-6));
        assert_eq!(rows[0].get("queue_len").and_then(|v| v.as_u64()), Some(5));
    }

    #[test]
    fn empty_recorder_exports_valid_json() {
        let r = Recorder::new();
        r.validate().expect("empty is valid");
        let doc = json::parse(&r.to_chrome_json()).expect("valid JSON");
        assert_eq!(doc.get("traceEvents").and_then(|v| v.as_arr()).map(|a| a.len()), Some(0));
    }
}
