//! Unified observability: metrics, tracing, and per-link congestion
//! telemetry across both engines, the harness, and the online controller.
//!
//! Three planes, one discipline:
//!
//! * [`metrics`] — a process-wide registry of counters / gauges /
//!   histograms (hand-rolled; the vendored registry has no metrics crate)
//!   that absorbs every previously ad-hoc counter: `QueueStats`,
//!   [`crate::sim::cache::PlanCache`] hit/miss/evict, the water-filler's
//!   recompute/round counts, the executor's reducer-call totals, and the
//!   online controller's decision log. Counters are integers only, so the
//!   always-on metric flushes can never perturb engine arithmetic; the
//!   [`metrics::Snapshot`] diff API is what turns cumulative process-wide
//!   totals into per-phase deltas (`harness::sweep` snapshots around its
//!   build/sim phases).
//! * [`trace`] — a span/event flight recorder exporting Chrome trace-event
//!   JSON (`trivance trace --out TRACE.json`, loadable in Perfetto):
//!   packet/flow run spans, timeline epoch instants, and the online
//!   controller's `FaultEvent → decision → outcome` chains.
//! * per-link congestion telemetry — [`trace::LinkSample`] rows sampled
//!   from the packet engine's busy intervals (one per `(link, batch)`,
//!   carrying the step, exact f64 interval bounds, bytes, pristine
//!   capacity, and instantaneous queue depth). These are the soft signals
//!   ROADMAP's Canary rung asks for; [`crate::tuner::online::obs_of_samples`]
//!   adapts them to the controller's `LinkObs` observation stream.
//!
//! ## Pure-selector discipline
//!
//! Everything hangs off the [`Sink`] trait. The default is no sink at all:
//! [`tracing`] is a single relaxed atomic load, `false` unless a sink was
//! [`install`]ed, and every trace/telemetry emission site is guarded by it
//! — so with observability off the engines run the exact same instruction
//! stream as before, and with it on the instrumentation only *reads*
//! engine state. Either way every simulation output is bit-identical
//! (pinned in `rust/tests/obs.rs` and mirrored in
//! `tools/pysim/eval_obs.py`).

pub mod metrics;
pub mod trace;

pub use trace::LinkSample;

use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Trace/telemetry consumer. All methods default to no-ops so a sink only
/// implements the planes it cares about; [`NoopSink`] implements none.
/// Timestamps are simulation seconds (the harness lane passes wall-clock
/// seconds); the exporter converts to Chrome's microseconds.
pub trait Sink: Send + Sync {
    /// Begin a duration span (`ph: "B"`).
    fn span_begin(&self, _pid: u32, _tid: u32, _name: &str, _ts_s: f64) {}
    /// End the innermost open span of `name` on `(pid, tid)` (`ph: "E"`).
    fn span_end(&self, _pid: u32, _tid: u32, _name: &str, _ts_s: f64) {}
    /// A complete event (`ph: "X"`): a closed interval with numeric args.
    fn complete(
        &self,
        _pid: u32,
        _tid: u32,
        _name: &str,
        _t0_s: f64,
        _t1_s: f64,
        _args: &[(&str, f64)],
    ) {
    }
    /// An instant event (`ph: "i"`).
    fn instant(&self, _pid: u32, _tid: u32, _name: &str, _ts_s: f64, _args: &[(&str, f64)]) {}
    /// One per-link congestion telemetry row (packet-engine busy interval).
    fn link_sample(&self, _s: &LinkSample) {}
}

/// The default sink: drops everything. Engines are never handed this —
/// "no sink installed" short-circuits at [`tracing`] — it exists so tests
/// can assert that installing a sink at all (even a discarding one) leaves
/// outputs bit-identical.
pub struct NoopSink;

impl Sink for NoopSink {}

/// Trace lanes (Chrome `pid`s): one per subsystem so Perfetto groups
/// tracks sensibly.
pub const PID_PACKET: u32 = 1;
pub const PID_FLOW: u32 = 2;
pub const PID_ONLINE: u32 = 3;
pub const PID_HARNESS: u32 = 4;
/// Per-link telemetry lane: `tid` is the dense directed-link index, so
/// each link renders as its own track of busy intervals.
pub const PID_LINKS: u32 = 5;

static TRACING: AtomicBool = AtomicBool::new(false);
static SINK: Mutex<Option<Arc<dyn Sink>>> = Mutex::new(None);
/// Serializes [`install`] across threads (cargo's parallel test runner):
/// the returned guard holds this until dropped.
static INSTALL_LOCK: Mutex<()> = Mutex::new(());

/// Whether a sink is installed. This is the hot-path guard: a single
/// relaxed atomic load, `false` in every default run.
#[inline]
pub fn tracing() -> bool {
    TRACING.load(Ordering::Relaxed)
}

/// Run `f` against the installed sink, if any. Emission sites call this
/// behind their own [`tracing`] check so the lock is never touched when
/// observability is off.
pub fn with_sink(f: impl FnOnce(&dyn Sink)) {
    if !tracing() {
        return;
    }
    let sink = SINK
        .lock()
        .unwrap_or_else(PoisonError::into_inner)
        .clone();
    if let Some(s) = sink {
        f(&*s);
    }
}

/// Uninstalls the sink (and re-clears [`tracing`]) on drop.
pub struct SinkGuard {
    _serial: MutexGuard<'static, ()>,
}

impl Drop for SinkGuard {
    fn drop(&mut self) {
        TRACING.store(false, Ordering::SeqCst);
        *SINK.lock().unwrap_or_else(PoisonError::into_inner) = None;
    }
}

/// Install `sink` process-wide until the returned guard drops. Installs
/// are serialized on a process-wide lock (held by the guard), so
/// concurrent tests can't observe each other's sinks.
#[must_use = "the sink is uninstalled when the guard drops"]
pub fn install(sink: Arc<dyn Sink>) -> SinkGuard {
    let serial = INSTALL_LOCK.lock().unwrap_or_else(PoisonError::into_inner);
    *SINK.lock().unwrap_or_else(PoisonError::into_inner) = Some(sink);
    TRACING.store(true, Ordering::SeqCst);
    SinkGuard { _serial: serial }
}

static NEXT_TID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static TID: u32 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// This thread's stable trace `tid` (assigned on first use). Keeps B/E
/// span stacks per-thread under the sweep harness's fan-out, so spans from
/// different worker threads never interleave on one track.
pub fn cur_tid() -> u32 {
    TID.with(|t| *t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    struct CountingSink(AtomicU64);

    impl Sink for CountingSink {
        fn instant(&self, _p: u32, _t: u32, _n: &str, _ts: f64, _a: &[(&str, f64)]) {
            self.0.fetch_add(1, Ordering::Relaxed);
        }
    }

    #[test]
    fn tracing_off_by_default_and_with_sink_skips() {
        // Cannot assert !tracing() unconditionally (another test may hold
        // an install); serialize through install() ourselves.
        let sink = Arc::new(CountingSink(AtomicU64::new(0)));
        let guard = install(sink.clone());
        assert!(tracing());
        with_sink(|s| s.instant(PID_PACKET, 0, "x", 0.0, &[]));
        assert_eq!(sink.0.load(Ordering::Relaxed), 1);
        drop(guard);
        assert!(!tracing());
        with_sink(|s| s.instant(PID_PACKET, 0, "x", 0.0, &[]));
        assert_eq!(sink.0.load(Ordering::Relaxed), 1, "uninstalled sink still reached");
    }

    #[test]
    fn noop_sink_installs_and_discards() {
        let guard = install(Arc::new(NoopSink));
        assert!(tracing());
        with_sink(|s| {
            s.span_begin(PID_FLOW, cur_tid(), "run", 0.0);
            s.span_end(PID_FLOW, cur_tid(), "run", 1.0);
            s.complete(PID_LINKS, 0, "busy", 0.0, 1.0, &[("bytes", 32.0)]);
            s.link_sample(&LinkSample {
                link: 0,
                step: 0,
                start_s: 0.0,
                end_s: 1.0,
                bytes: 32.0,
                cap_bytes_per_s: 1.0,
                queue_len: 0,
            });
        });
        drop(guard);
    }

    #[test]
    fn tids_are_stable_per_thread_and_distinct_across() {
        let a = cur_tid();
        assert_eq!(a, cur_tid());
        let b = std::thread::spawn(cur_tid).join().unwrap();
        assert_ne!(a, b);
    }
}
