//! Process-wide metrics registry: counters, gauges, and power-of-two
//! histograms behind one snapshot-and-diff API with hand-rolled JSON
//! export (schema `trivance.metrics.v1`; no metrics crate in the vendored
//! registry).
//!
//! Counters are monotone `u64` totals flushed by the engines once per
//! simulation (integer-only — metric accounting can never perturb the f64
//! simulation arithmetic). Because they are cumulative process-wide,
//! every multi-phase consumer reports *deltas*: take a [`snapshot`] at
//! each phase boundary and [`Snapshot::diff`] adjacent pairs
//! (`harness::sweep` does this around its build/sim phases). The
//! [`crate::sim::cache::PlanCache`] counters are injected at snapshot
//! time from the cache's own atomics, so its hit/miss/evict totals diff
//! the same way without double-maintaining state.
//!
//! Naming convention: `subsystem.object.event`, e.g.
//! `packet.queue.calendar.scanned` or `online.rewrites`. The calendar
//! queue's `scanned/pop` ratio — the PR 8 honest finding — is exported
//! per simulation as the histogram `packet.queue.calendar.scanned_per_pop`.

use std::collections::BTreeMap;
use std::sync::{Mutex, PoisonError};

/// Histogram bucket count: bucket `i` counts observations in
/// `(2^(i-1), 2^i]` (bucket 0: `<= 1`), with the last bucket absorbing
/// everything larger.
const HIST_BUCKETS: usize = 32;

#[derive(Clone, Debug, Default, PartialEq)]
pub struct Histogram {
    /// Per-bucket observation counts; bucket `i` has upper edge `2^i`.
    pub buckets: Vec<u64>,
    pub count: u64,
    pub sum: f64,
}

impl Histogram {
    fn new() -> Histogram {
        Histogram { buckets: vec![0; HIST_BUCKETS], count: 0, sum: 0.0 }
    }

    fn observe(&mut self, v: f64) {
        let mut i = 0usize;
        let mut edge = 1.0f64;
        while v > edge && i + 1 < HIST_BUCKETS {
            edge *= 2.0;
            i += 1;
        }
        self.buckets[i] += 1;
        self.count += 1;
        self.sum += v;
    }

    /// Mean observation (`NaN`-free: 0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, u64>,
    gauges: BTreeMap<String, f64>,
    histograms: BTreeMap<String, Histogram>,
}

static REGISTRY: Mutex<Option<Registry>> = Mutex::new(None);

fn with_registry<R>(f: impl FnOnce(&mut Registry) -> R) -> R {
    let mut guard = REGISTRY.lock().unwrap_or_else(PoisonError::into_inner);
    f(guard.get_or_insert_with(Registry::default))
}

/// Add `delta` to counter `name` (created at 0 on first touch).
pub fn counter_add(name: &str, delta: u64) {
    counters_add(&[(name, delta)]);
}

/// Batch counter update under one registry lock — the per-simulation
/// flush path the engines use.
pub fn counters_add(pairs: &[(&str, u64)]) {
    with_registry(|r| {
        for &(name, delta) in pairs {
            match r.counters.get_mut(name) {
                Some(c) => *c = c.saturating_add(delta),
                None => {
                    r.counters.insert(name.to_string(), delta);
                }
            }
        }
    });
}

/// Set gauge `name` to `v` (last-write-wins).
pub fn gauge_set(name: &str, v: f64) {
    with_registry(|r| {
        r.gauges.insert(name.to_string(), v);
    });
}

/// Record one observation into histogram `name`.
pub fn observe(name: &str, v: f64) {
    with_registry(|r| {
        r.histograms.entry(name.to_string()).or_insert_with(Histogram::new).observe(v);
    });
}

/// Clear every metric (tests and explicit CLI resets only — the registry
/// is otherwise cumulative for the process lifetime).
pub fn reset() {
    let mut guard = REGISTRY.lock().unwrap_or_else(PoisonError::into_inner);
    *guard = None;
}

/// A point-in-time copy of the registry, with the [`PlanCache`] state
/// injected (counters `plan_cache.hits/misses/evictions`, gauges
/// `plan_cache.len/cap/enabled`) so cache activity diffs per phase like
/// everything else.
///
/// [`PlanCache`]: crate::sim::cache::PlanCache
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Snapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, Histogram>,
}

/// Snapshot the registry now.
pub fn snapshot() -> Snapshot {
    let mut snap = with_registry(|r| Snapshot {
        counters: r.counters.clone(),
        gauges: r.gauges.clone(),
        histograms: r.histograms.clone(),
    });
    let c = crate::sim::cache::PlanCache::global();
    snap.counters.insert("plan_cache.hits".to_string(), c.hits());
    snap.counters.insert("plan_cache.misses".to_string(), c.misses());
    snap.counters.insert("plan_cache.evictions".to_string(), c.evictions());
    snap.gauges.insert("plan_cache.len".to_string(), c.len() as f64);
    snap.gauges.insert("plan_cache.cap".to_string(), c.cap() as f64);
    snap.gauges.insert(
        "plan_cache.enabled".to_string(),
        if c.is_enabled() { 1.0 } else { 0.0 },
    );
    snap
}

impl Snapshot {
    /// Counter value (0 when absent — diffs drop untouched counters).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    /// Gauge value, if set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// The delta `self − earlier`: counters subtract (saturating, so a
    /// reset between snapshots yields 0 rather than wrap), histograms
    /// subtract per bucket, gauges keep `self`'s value (a gauge is a
    /// level, not a rate). Counters that did not move are dropped so a
    /// phase report only names what the phase did.
    pub fn diff(&self, earlier: &Snapshot) -> Snapshot {
        let mut counters = BTreeMap::new();
        for (name, &v) in &self.counters {
            let d = v.saturating_sub(earlier.counter(name));
            if d > 0 {
                counters.insert(name.clone(), d);
            }
        }
        let mut histograms = BTreeMap::new();
        for (name, h) in &self.histograms {
            let mut d = h.clone();
            if let Some(e) = earlier.histograms.get(name) {
                for (b, eb) in d.buckets.iter_mut().zip(&e.buckets) {
                    *b = b.saturating_sub(*eb);
                }
                d.count = d.count.saturating_sub(e.count);
                d.sum -= e.sum;
            }
            if d.count > 0 {
                histograms.insert(name.clone(), d);
            }
        }
        Snapshot { counters, gauges: self.gauges.clone(), histograms }
    }

    /// Render as `trivance.metrics.v1` JSON (hand-rolled; floats via `{:e}`
    /// so the output is valid JSON and round-trips through
    /// [`crate::util::json::parse`]).
    pub fn to_json(&self) -> String {
        use crate::util::json::escape;
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str("  \"schema\": \"trivance.metrics.v1\",\n");
        out.push_str("  \"counters\": {");
        let mut first = true;
        for (name, v) in &self.counters {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\n    \"{}\": {}", escape(name), v));
        }
        out.push_str(if first { "},\n" } else { "\n  },\n" });
        out.push_str("  \"gauges\": {");
        let mut first = true;
        for (name, v) in &self.gauges {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!("\n    \"{}\": {:e}", escape(name), v));
        }
        out.push_str(if first { "},\n" } else { "\n  },\n" });
        out.push_str("  \"histograms\": {");
        let mut first = true;
        for (name, h) in &self.histograms {
            if !first {
                out.push(',');
            }
            first = false;
            out.push_str(&format!(
                "\n    \"{}\": {{\"count\": {}, \"sum\": {:e}, \"buckets\": [",
                escape(name),
                h.count,
                h.sum
            ));
            let mut first_b = true;
            for (i, &count) in h.buckets.iter().enumerate() {
                if count == 0 {
                    continue;
                }
                if !first_b {
                    out.push_str(", ");
                }
                first_b = false;
                out.push_str(&format!("{{\"le\": {:e}, \"count\": {count}}}", 2f64.powi(i as i32)));
            }
            out.push_str("]}");
        }
        out.push_str(if first { "}\n" } else { "\n  }\n" });
        out.push_str("}\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_diff() {
        counter_add("test.metrics.a", 3);
        let s0 = snapshot();
        counter_add("test.metrics.a", 2);
        counters_add(&[("test.metrics.b", 5), ("test.metrics.a", 1)]);
        let s1 = snapshot();
        let d = s1.diff(&s0);
        assert_eq!(d.counter("test.metrics.a"), 3);
        assert_eq!(d.counter("test.metrics.b"), 5);
        // untouched counters are dropped from the delta
        assert!(!d.counters.contains_key("test.metrics.untouched"));
    }

    #[test]
    fn gauges_keep_latest_value_in_diff() {
        gauge_set("test.metrics.g", 2.5);
        let s0 = snapshot();
        gauge_set("test.metrics.g", 7.25);
        let d = snapshot().diff(&s0);
        assert_eq!(d.gauge("test.metrics.g"), Some(7.25));
    }

    #[test]
    fn histogram_buckets_and_diff() {
        let name = "test.metrics.hist";
        observe(name, 0.5); // bucket 0 (<= 1)
        observe(name, 3.0); // bucket 2 (<= 4)
        observe(name, 1e30); // overflow bucket
        let s0 = snapshot();
        let h = &s0.histograms[name];
        assert_eq!(h.count, 3);
        assert_eq!(h.buckets[0], 1);
        assert_eq!(h.buckets[2], 1);
        assert_eq!(h.buckets[HIST_BUCKETS - 1], 1);
        assert!((h.mean() - (0.5 + 3.0 + 1e30) / 3.0).abs() < 1e15);
        observe(name, 3.5);
        let d = snapshot().diff(&s0);
        let dh = &d.histograms[name];
        assert_eq!(dh.count, 1);
        assert_eq!(dh.buckets[2], 1);
        assert_eq!(dh.buckets[0], 0);
    }

    #[test]
    fn plan_cache_state_is_injected_at_snapshot() {
        let s = snapshot();
        assert!(s.counters.contains_key("plan_cache.hits"));
        assert!(s.counters.contains_key("plan_cache.misses"));
        assert!(s.counters.contains_key("plan_cache.evictions"));
        assert!(s.gauge("plan_cache.len").is_some());
        assert!(s.gauge("plan_cache.cap").is_some());
        let enabled = s.gauge("plan_cache.enabled").unwrap();
        assert!(enabled == 0.0 || enabled == 1.0);
    }

    #[test]
    fn json_round_trips_through_own_parser() {
        use crate::util::json;
        counter_add("test.metrics.json", 7);
        gauge_set("test.metrics.json.g", -0.5);
        observe("test.metrics.json.h", 2.0);
        let s = snapshot();
        let doc = json::parse(&s.to_json()).expect("valid JSON");
        assert_eq!(
            doc.get("schema").and_then(|v| v.as_str()),
            Some("trivance.metrics.v1")
        );
        let counters = doc.get("counters").expect("counters");
        assert_eq!(
            counters.get("test.metrics.json").and_then(|v| v.as_u64()),
            Some(s.counter("test.metrics.json"))
        );
        assert_eq!(
            doc.get("gauges").and_then(|g| g.get("test.metrics.json.g")).and_then(|v| v.as_f64()),
            Some(-0.5)
        );
        let hist = doc.get("histograms").and_then(|h| h.get("test.metrics.json.h")).unwrap();
        assert!(hist.get("count").and_then(|v| v.as_u64()).unwrap() >= 1);
    }

    #[test]
    fn empty_snapshot_json_is_valid() {
        let empty = Snapshot::default();
        assert!(crate::util::json::parse(&empty.to_json()).is_ok());
    }
}
