//! # Trivance — latency-optimal AllReduce by shortcutting multiport networks
//!
//! Reproduction of *Trivance: Latency-Optimal AllReduce by Shortcutting
//! Multiport Networks* (CS.DC 2026). The crate provides:
//!
//! * [`topology`] — bidirectional rings and D-dimensional tori with minimal
//!   routing, the network substrate all schedules execute on.
//! * [`net`] — the heterogeneous per-link network model: a [`net::LinkClass`]
//!   scale table (bandwidth / latency / processing relative to the base
//!   [`cost::NetParams`]) plus a down set with deterministic detour routing,
//!   and [`net::Timeline`] — deterministic *mid-collective* fabric mutations
//!   (brownouts, flaps, asymmetric degradation) both simulator engines
//!   honor. The uniform model (and the empty timeline) reproduces the
//!   paper's homogeneous fabric bit for bit; named degradation presets —
//!   static and dynamic — live in [`harness::scenarios`], and fault-aware
//!   schedule rewriting in [`schedule::rewrite`].
//! * [`blockset`] — cyclic interval arithmetic over the rank/block space.
//! * [`schedule`] — the schedule IR (steps → sends → pieces), plus a static
//!   validator that proves contributor-set disjointness and coverage for any
//!   generated schedule, and congestion/bytes analysis under minimal routing.
//! * [`agpattern`] — the generic AllGather-pattern machinery: every collective
//!   is specified as an AllGather pattern; latency-optimal AllReduce is the
//!   reinterpretation of that pattern over full-vector partial aggregates
//!   (with backward cut-point propagation so every send is an exact segment
//!   cover), and bandwidth-optimal AllReduce is the tree-reversal
//!   Reduce-Scatter followed by the AllGather itself.
//! * [`algo`] — Trivance (§4), Bruck, Swing, Recursive Doubling, Ring /
//!   Bucket, each with latency- (L) and bandwidth-optimal (B) variants, on
//!   rings and multidimensional tori (§5), plus virtual power-of-three /
//!   power-of-two padding for arbitrary node counts.
//! * [`cost`] — the congestion-aware Hockney cost model (paper Eq. 1) and the
//!   optimality factors Λ/Δ/Θ of Tables 1 and 2.
//! * [`verify`] — static schedule certification, no simulation: atom-level
//!   dataflow proofs (exact full reduction, no double-counting), multiport
//!   legality (per-(node, step, direction) port budgets), congestion
//!   certificates (Trivance ≤ ⅓·Bruck on rings) and latency/bandwidth
//!   optimality classification for every registry collective
//!   (`trivance verify`), with a seeded mutation-kill suite
//!   ([`verify::mutate`]) proving the verifier itself has teeth.
//! * [`sim`] — the discrete-event network simulator substituting for SST:
//!   flow-level (incremental max-min fair sharing with a closed-form
//!   symmetric-step fast path) and packet-level modes (per-link FIFO batch
//!   scheduling, `O(messages × hops)` heap traffic), both executing
//!   precompiled size-independent [`sim::SimPlan`]s so message-size ladders
//!   reuse one plan per `(schedule, topology)`; registry plans are further
//!   shared process-wide through [`sim::PlanCache`].
//! * [`exec`] — the dataflow executor running schedules on real vectors with
//!   reductions through the AOT-compiled PJRT kernels ([`runtime`]).
//! * [`harness`] — regeneration of every table and figure in the paper; the
//!   sweep grid fans out across threads ([`util::par`]) with deterministic,
//!   bit-identical results through one shared grid engine
//!   ([`harness::sweep::eval_grid`]), and `trivance bench-sweep` emits the
//!   `BENCH_sweep.json` performance record.
//! * [`obs`] — unified observability: a process-wide metrics registry
//!   (counters / gauges / histograms with snapshot-and-diff,
//!   `trivance metrics`), a span/event flight recorder exporting Chrome
//!   trace-event JSON (`trivance trace`, Perfetto-loadable), and per-link
//!   congestion telemetry sampled from the packet engine's busy intervals
//!   — all behind an [`obs::Sink`] that is off (and bit-identically
//!   invisible) by default.
//! * [`tuner`] — offline sweeps distilled into servable per-`(topology,
//!   scenario, size)` algorithm-selection tables
//!   ([`tuner::DecisionTable`], O(1) lookups, NetModel-fingerprint
//!   staleness detection) plus synthetic workload traces and a replay
//!   engine scoring table-driven selection against the per-call oracle
//!   (`trivance tune` / `recommend` / `replay`).
//!
//! Python/JAX/Pallas exist only on the build path (`python/compile`), which
//! AOT-lowers the reduction kernels and the demo train step to HLO text in
//! `artifacts/`; the runtime loads those via the PJRT C API.

pub mod util;
pub mod blockset;
pub mod topology;
pub mod net;
pub mod schedule;
pub mod agpattern;
pub mod algo;
pub mod cost;
pub mod sim;
pub mod verify;
pub mod exec;
pub mod runtime;
pub mod obs;
pub mod harness;
pub mod tuner;
pub mod cli;
