//! Online selector: the live half of the tuner. Matches a stream of link
//! observations against the tuned scenario family by **nearest-scenario
//! distance** in a small descriptor space — not the decision table's
//! exact-fingerprint lookup, which (correctly) answers `StaleModel` for any
//! condition it was not tuned on. A live controller cannot afford that
//! refusal: a novel fault is *exactly* when it needs advice. So the
//! selector embeds every tuned dynamic preset as a [`ScenarioFeatures`]
//! vector, embeds the observed event stream the same way, and borrows the
//! nearest preset's tuned judgment:
//!
//! * **action** for the in-flight collective — [`Action::Rewrite`] when the
//!   observation is a permanent failure matched to a permanent-fault
//!   scenario, [`Action::Detour`] for transient conditions (flap/brownout:
//!   the fabric recovers, a rewrite would pay the cleanup step for
//!   nothing) and for anything too far from every tuned scenario
//!   (distance above [`OnlineSelector::threshold`] — honest fallback,
//!   detour routing is always safe);
//! * **algorithm switch** for the *next* collective — the matched
//!   scenario's tuned winner at the message size (a collective cannot
//!   change algorithm mid-flight; the recommendation is reported, scored
//!   by the `scenarios --online` sweep, not simulated mid-run).
//!
//! Provenance still applies: a table distilled before timeline support
//! ([`ScenarioTable::pre_dynamic`]) is refused at selector construction
//! with the same [`RecommendError::PreDynamicTable`] the exact-match path
//! returns — nearest-distance matching loosens *condition* identity, never
//! provenance.
//!
//! Deterministic and simulation-free, like the controller it advises.
//! Mirrored in `tools/pysim/mirror.py` (`ScenarioFeatures`,
//! `OnlineSelector`); keep the descriptor arithmetic in lockstep.

use crate::cost::NetParams;
use crate::harness::scenarios::{dynamic_presets, Scenario};
use crate::net::Mutation;
use crate::schedule::online::{Action, FaultEvent};
use crate::topology::{Link, Torus};
use crate::tuner::table::{ladder_index, Choice, DecisionTable, RecommendError};

/// One link-health observation: at time `t` (seconds since the collective
/// started), `link`'s usable capacity was `cap_ratio` of pristine
/// (`0.0` = down, `1.0` = recovered/healthy). The stream a monitoring
/// plane would feed the controller.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkObs {
    pub t: f64,
    pub link: usize,
    pub cap_ratio: f64,
}

/// Reference horizon for normalizing observation times: `α + 4·m·β`, the
/// outer edge of the preset family's degradation windows (the brownout
/// recovers at exactly this time). Mirrored in `tools/pysim`.
pub fn ref_horizon(params: &NetParams, m_bytes: u64) -> f64 {
    params.alpha_s + 4.0 * m_bytes as f64 * params.beta_per_byte()
}

/// A scenario (or observed event stream) embedded as a descriptor vector.
/// Every component is in `[0, 1]`, so unweighted L2 distance is meaningful:
///
/// | component       | meaning                                              |
/// |-----------------|------------------------------------------------------|
/// | `frac_links`    | affected directed links / all directed links         |
/// | `severity`      | worst capacity ratio seen (`0` = hard down)          |
/// | `duration_frac` | mean degraded time per affected link / horizon       |
/// | `permanent`     | `1` if any affected link never recovered             |
/// | `when_frac`     | first degradation time / horizon                     |
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScenarioFeatures {
    pub frac_links: f64,
    pub severity: f64,
    pub duration_frac: f64,
    pub permanent: f64,
    pub when_frac: f64,
}

impl ScenarioFeatures {
    /// The healthy-fabric descriptor (no observations).
    pub const PRISTINE: ScenarioFeatures = ScenarioFeatures {
        frac_links: 0.0,
        severity: 1.0,
        duration_frac: 0.0,
        permanent: 0.0,
        when_frac: 1.0,
    };

    /// Summarize an observation stream (any order; sorted internally by
    /// time) over `horizon` seconds.
    pub fn of_obs(torus: &Torus, obs: &[LinkObs], horizon: f64) -> ScenarioFeatures {
        if obs.is_empty() {
            return ScenarioFeatures::PRISTINE;
        }
        let horizon = horizon.max(f64::MIN_POSITIVE);
        let mut sorted: Vec<&LinkObs> = obs.iter().collect();
        sorted.sort_by(|a, b| a.t.total_cmp(&b.t));
        // per-link accumulator: (degraded-since, total degraded time,
        // worst ratio, first degradation time)
        #[derive(Clone, Copy)]
        struct Acc {
            since: Option<f64>,
            total: f64,
            worst: f64,
            first: f64,
        }
        let mut acc: std::collections::BTreeMap<usize, Acc> = std::collections::BTreeMap::new();
        for o in sorted {
            if o.cap_ratio < 1.0 {
                let a = acc.entry(o.link).or_insert(Acc {
                    since: None,
                    total: 0.0,
                    worst: 1.0,
                    first: o.t,
                });
                a.worst = a.worst.min(o.cap_ratio.max(0.0));
                if a.since.is_none() {
                    a.since = Some(o.t);
                }
            } else if let Some(a) = acc.get_mut(&o.link) {
                if let Some(s) = a.since.take() {
                    a.total += (o.t - s).max(0.0);
                }
            }
        }
        let mut severity = 1.0f64;
        let mut when = f64::INFINITY;
        let mut dur_sum = 0.0f64;
        let mut permanent = false;
        for a in acc.values() {
            severity = severity.min(a.worst);
            when = when.min(a.first);
            let mut total = a.total;
            if let Some(s) = a.since {
                total += (horizon - s).max(0.0);
                permanent = true;
            }
            dur_sum += (total / horizon).clamp(0.0, 1.0);
        }
        let n_aff = acc.len();
        ScenarioFeatures {
            frac_links: n_aff as f64 / torus.num_links() as f64,
            severity,
            duration_frac: if n_aff == 0 { 0.0 } else { dur_sum / n_aff as f64 },
            permanent: if permanent { 1.0 } else { 0.0 },
            when_frac: if when.is_finite() { (when / horizon).clamp(0.0, 1.0) } else { 1.0 },
        }
    }

    /// Unweighted L2 distance in descriptor space.
    pub fn dist(&self, other: &ScenarioFeatures) -> f64 {
        let a = self.as_vec();
        let b = other.as_vec();
        a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt()
    }

    fn as_vec(&self) -> [f64; 5] {
        [self.frac_links, self.severity, self.duration_frac, self.permanent, self.when_frac]
    }
}

/// A preset's canonical observation stream: its capacity timeline's
/// mutations read as link-health samples, plus (for mid-fault presets) the
/// permanent cable death observed at its step boundary (`step · α`, the
/// latency-regime estimate — by then `step` latency-bound steps have run).
pub fn preset_obs(
    sc: &Scenario,
    torus: &Torus,
    params: &NetParams,
    m_bytes: u64,
) -> Vec<LinkObs> {
    let mut obs = Vec::new();
    for e in sc.timeline(torus, params, m_bytes).epochs() {
        for mu in &e.mutations {
            let cap_ratio = match mu {
                Mutation::SetDown { down, .. } => {
                    if *down {
                        0.0
                    } else {
                        1.0
                    }
                }
                Mutation::SetClass { class, .. } => class.bw_scale,
            };
            obs.push(LinkObs { t: e.t, link: mu.link() as usize, cap_ratio });
        }
    }
    if let Some(f) = sc.fault(torus) {
        let t = params.alpha_s * f.step as f64;
        for &l in &f.down_links {
            obs.push(LinkObs { t, link: l, cap_ratio: 0.0 });
        }
    }
    obs
}

/// A [`FaultEvent`] read as link-health observations: each down link at
/// ratio 0, each dead node as all of its incident directed links (both
/// directions of every port) at ratio 0.
pub fn obs_of_event(ev: &FaultEvent, torus: &Torus) -> Vec<LinkObs> {
    let mut obs: Vec<LinkObs> = ev
        .down_links
        .iter()
        .map(|&l| LinkObs { t: ev.t, link: l, cap_ratio: 0.0 })
        .collect();
    for &node in &ev.dead_nodes {
        for dim in 0..torus.ndims() {
            for dir in [-1i8, 1] {
                let out = Link { node, dim: dim as u8, dir };
                obs.push(LinkObs { t: ev.t, link: torus.link_index(out), cap_ratio: 0.0 });
                obs.push(LinkObs {
                    t: ev.t,
                    link: torus.link_index(torus.reverse_link(out)),
                    cap_ratio: 0.0,
                });
            }
        }
    }
    obs
}

/// Per-link congestion telemetry ([`crate::obs::LinkSample`] rows — the
/// packet engine's busy intervals) read as link-health observations: the
/// observation stream the ROADMAP Canary rung asks the monitoring plane
/// for, now sampled from the engine itself. One [`LinkObs`] per busy
/// interval, stamped at the interval start; `cap_ratio` is the achieved
/// bandwidth over the pristine capacity, clamped to `[0, 1]` (cut-through
/// `ready` stalls can stretch an interval past its serialization time, and
/// a brownout shows up as achieved ≪ pristine — exactly the congestion
/// signal). Zero-length and zero-capacity intervals carry no observable
/// rate and are skipped.
pub fn obs_of_samples(samples: &[crate::obs::LinkSample]) -> Vec<LinkObs> {
    samples
        .iter()
        .filter(|s| s.end_s > s.start_s && s.cap_bytes_per_s > 0.0)
        .map(|s| {
            let achieved = s.bytes / (s.end_s - s.start_s);
            LinkObs {
                t: s.start_s,
                link: s.link as usize,
                cap_ratio: (achieved / s.cap_bytes_per_s).clamp(0.0, 1.0),
            }
        })
        .collect()
}

/// One embedded tuned scenario: its descriptor, whether its condition is
/// permanent (fault) or transient (timeline), and the tuned per-size
/// winners (empty when the table was not tuned on this preset).
#[derive(Clone, Debug)]
pub struct SelectorRow {
    pub scenario: String,
    pub features: ScenarioFeatures,
    pub permanent: bool,
    pub winners: Vec<Choice>,
}

/// What the selector decided for one observation stream.
#[derive(Clone, Debug, PartialEq)]
pub struct Selection {
    /// Nearest tuned scenario by descriptor distance.
    pub scenario: String,
    pub distance: f64,
    /// `false` when the distance exceeded the threshold (action falls back
    /// to [`Action::Detour`], no algorithm switch is recommended).
    pub matched: bool,
    /// What the in-flight collective should do about the event.
    pub action: Action,
    /// Tuned winner to switch to for the *next* collective, when matched
    /// and the table carries winners for the matched scenario.
    pub algo_switch: Option<Choice>,
}

/// Reference message size for embedding the preset family (the preset
/// windows scale with `m·β`, so descriptors are nearly size-invariant;
/// this matches the tuner's canonical fingerprint size).
const CANONICAL_SIZE: u64 = 1 << 20;

/// Distance beyond which an observation matches *no* tuned scenario and
/// the selector falls back to detour. Descriptor components live in
/// `[0, 1]`; 0.5 tolerates one component drifting halfway (e.g. a fault
/// landing later than the preset's) without accepting a categorically
/// different condition (permanent vs transient alone contributes 1.0).
pub const DEFAULT_THRESHOLD: f64 = 0.5;

/// The nearest-scenario policy distilled from a tuned [`DecisionTable`]
/// (module docs). Construct once per (table, topology) with
/// [`OnlineSelector::from_table`]; consult per event with
/// [`OnlineSelector::select`] or hand [`OnlineSelector::policy`] straight
/// to [`crate::schedule::online::respond`].
#[derive(Clone, Debug)]
pub struct OnlineSelector {
    pub dims: Vec<u32>,
    /// The tuned size ladder (for the algorithm-switch lookup).
    pub sizes: Vec<u64>,
    pub threshold: f64,
    pub rows: Vec<SelectorRow>,
}

impl OnlineSelector {
    /// Embed the dynamic preset family against `table`'s tuned rows for
    /// `torus`. Errs [`RecommendError::UnknownTopo`] when the table has no
    /// row for the topology and [`RecommendError::PreDynamicTable`] when a
    /// matched row predates timeline support (provenance, module docs).
    pub fn from_table(table: &DecisionTable, torus: &Torus) -> Result<OnlineSelector, RecommendError> {
        let topo = table
            .topos
            .iter()
            .find(|t| t.dims.as_slice() == torus.dims())
            .ok_or_else(|| RecommendError::UnknownTopo { dims: torus.dims().to_vec() })?;
        let mut rows = Vec::new();
        for sc in dynamic_presets() {
            let obs = preset_obs(&sc, torus, &table.params, CANONICAL_SIZE);
            let features = ScenarioFeatures::of_obs(
                torus,
                &obs,
                ref_horizon(&table.params, CANONICAL_SIZE),
            );
            let permanent = features.permanent >= 0.5;
            let winners = match topo.scenarios.iter().find(|r| r.scenario == sc.name) {
                Some(row) if row.pre_dynamic => {
                    return Err(RecommendError::PreDynamicTable {
                        dims: topo.dims.clone(),
                        timeline_fp: sc.dyn_fingerprint(torus),
                    });
                }
                Some(row) => row.winners.clone(),
                None => Vec::new(),
            };
            rows.push(SelectorRow { scenario: sc.name, features, permanent, winners });
        }
        Ok(OnlineSelector {
            dims: torus.dims().to_vec(),
            sizes: topo.sizes.clone(),
            threshold: DEFAULT_THRESHOLD,
            rows,
        })
    }

    /// Match an observation stream and decide (module docs). Deterministic:
    /// ties in distance keep the first row (the preset family's order).
    pub fn select(
        &self,
        torus: &Torus,
        obs: &[LinkObs],
        m_bytes: u64,
        params: &NetParams,
    ) -> Selection {
        let f = ScenarioFeatures::of_obs(torus, obs, ref_horizon(params, m_bytes));
        let (row, distance) = self
            .rows
            .iter()
            .map(|r| (r, r.features.dist(&f)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
            .expect("the dynamic preset family is never empty");
        let matched = distance <= self.threshold;
        let action = if matched && row.permanent && f.permanent >= 0.5 {
            Action::Rewrite
        } else {
            Action::Detour
        };
        let algo_switch = if matched {
            row.winners.get(ladder_index(m_bytes, self.sizes.len())).copied()
        } else {
            None
        };
        Selection { scenario: row.scenario.clone(), distance, matched, action, algo_switch }
    }

    /// The selector as a [`crate::schedule::online::respond`] policy
    /// closure: accumulates each event's observations and re-selects, so a
    /// second fault is judged against the full stream seen so far. One
    /// hard rule sits above the fingerprint match: an event that kills a
    /// node always rewrites — detouring cannot route around a dead
    /// endpoint, so the nearest-scenario vote is irrelevant there.
    pub fn policy<'a>(
        &'a self,
        torus: &'a Torus,
        m_bytes: u64,
        params: &'a NetParams,
    ) -> impl FnMut(&FaultEvent, usize) -> Action + 'a {
        let mut seen: Vec<LinkObs> = Vec::new();
        move |ev, _step| {
            seen.extend(obs_of_event(ev, torus));
            // a dead node is never detourable — no route into it can
            // exist — so rewrite strictly dominates regardless of which
            // scenario the observation stream resembles
            if !ev.dead_nodes.is_empty() {
                return Action::Rewrite;
            }
            self.select(torus, &seen, m_bytes, params).action
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{Algo, Variant};
    use crate::net::NetModel;
    use crate::tuner::table::{tune_ladder, ScenarioTable, TopoTable};

    fn toy_table(t: &Torus, pre_dynamic: bool) -> DecisionTable {
        let params = NetParams::default();
        let sizes = tune_ladder(1 << 20);
        let scenarios = dynamic_presets()
            .iter()
            .map(|sc| ScenarioTable {
                scenario: sc.name.clone(),
                net_fp: NetModel::uniform(t).fingerprint(),
                timeline_fp: sc.dyn_fingerprint(t),
                pre_dynamic,
                winners: vec![
                    Choice { algo: Algo::Trivance, variant: Variant::Latency };
                    sizes.len()
                ],
            })
            .collect();
        DecisionTable {
            params,
            topos: vec![TopoTable { dims: t.dims().to_vec(), sizes, scenarios }],
        }
    }

    #[test]
    fn obs_of_samples_converts_busy_intervals_to_cap_ratios() {
        use crate::obs::LinkSample;
        let mk = |link, start_s, end_s, bytes, cap| LinkSample {
            link,
            step: 0,
            start_s,
            end_s,
            bytes,
            cap_bytes_per_s: cap,
            queue_len: 0,
        };
        let samples = [
            mk(3, 1.0, 2.0, 100.0, 100.0),  // fully utilized: ratio 1
            mk(4, 2.0, 4.0, 50.0, 100.0),   // browned out: 25 of 100
            mk(5, 5.0, 7.0, 1000.0, 100.0), // float slop above cap: clamped
            mk(6, 8.0, 8.0, 10.0, 100.0),   // zero-length: dropped
            mk(7, 9.0, 10.0, 10.0, 0.0),    // zero capacity: dropped
        ];
        let obs = obs_of_samples(&samples);
        assert_eq!(obs.len(), 3);
        assert_eq!(obs[0], LinkObs { t: 1.0, link: 3, cap_ratio: 1.0 });
        assert_eq!(obs[1], LinkObs { t: 2.0, link: 4, cap_ratio: 0.25 });
        assert_eq!(obs[2], LinkObs { t: 5.0, link: 5, cap_ratio: 1.0 });
        assert!(obs_of_samples(&[]).is_empty());
    }

    #[test]
    fn features_separate_transient_from_permanent_presets() {
        let t = Torus::new(&[3, 3]);
        let p = NetParams::default();
        let fam = dynamic_presets();
        let feats: Vec<ScenarioFeatures> = fam
            .iter()
            .map(|sc| {
                ScenarioFeatures::of_obs(
                    &t,
                    &preset_obs(sc, &t, &p, CANONICAL_SIZE),
                    ref_horizon(&p, CANONICAL_SIZE),
                )
            })
            .collect();
        // flap: one link hard down, recovers
        assert_eq!(feats[0].permanent, 0.0);
        assert_eq!(feats[0].severity, 0.0);
        // brownout: many links softly degraded, recovers
        assert_eq!(feats[1].permanent, 0.0);
        assert!((feats[1].severity - 0.25).abs() < 1e-12);
        assert!(feats[1].frac_links > feats[0].frac_links);
        // mid-fault (both strategies share the physical condition): permanent
        for f in &feats[2..] {
            assert_eq!(f.permanent, 1.0);
            assert_eq!(f.severity, 0.0);
        }
        assert!(feats[0].dist(&feats[2]) > 0.9, "flap vs cable death must be far apart");
        assert!(feats[2].dist(&feats[3]) < 1e-12, "mid-fault strategies share features");
    }

    #[test]
    fn selector_rewrites_on_permanent_faults_and_detours_on_transients() {
        let t = Torus::new(&[3, 3]);
        let p = NetParams::default();
        let sel = OnlineSelector::from_table(&toy_table(&t, false), &t).unwrap();
        assert_eq!(sel.rows.len(), 4);
        let m = 256 << 10;
        // a cable death observed mid-collective: nearest scenario is the
        // mid-fault family, the observation is permanent -> rewrite + switch
        let ev = FaultEvent::cable(p.alpha_s, &t, 0);
        let s = sel.select(&t, &obs_of_event(&ev, &t), m, &p);
        assert!(s.matched, "cable death must match the tuned family ({})", s.distance);
        assert!(s.scenario.starts_with("mid-fault"));
        assert_eq!(s.action, Action::Rewrite);
        assert_eq!(
            s.algo_switch,
            Some(Choice { algo: Algo::Trivance, variant: Variant::Latency })
        );
        // a flap (down then recovered) is transient -> detour, no rewrite
        let l = crate::net::pick_links(&t, 1, crate::harness::scenarios::FLAP_SEED, false)[0];
        let ser = m as f64 * p.beta_per_byte();
        let flap = [
            LinkObs { t: p.alpha_s + 0.25 * ser, link: l, cap_ratio: 0.0 },
            LinkObs { t: p.alpha_s + 2.25 * ser, link: l, cap_ratio: 1.0 },
        ];
        let s = sel.select(&t, &flap, m, &p);
        assert!(s.matched);
        assert_eq!(s.scenario, "flap");
        assert_eq!(s.action, Action::Detour);
        // nothing observed at all: pristine is far from every degraded
        // preset -> unmatched, detour, no switch
        let s = sel.select(&t, &[], m, &p);
        assert!(!s.matched);
        assert_eq!(s.action, Action::Detour);
        assert_eq!(s.algo_switch, None);
    }

    #[test]
    fn selector_refuses_pre_dynamic_provenance() {
        let t = Torus::new(&[3, 3]);
        let err = OnlineSelector::from_table(&toy_table(&t, true), &t).unwrap_err();
        assert!(matches!(err, RecommendError::PreDynamicTable { .. }), "{err}");
        let err = OnlineSelector::from_table(&toy_table(&t, false), &Torus::ring(5)).unwrap_err();
        assert!(matches!(err, RecommendError::UnknownTopo { .. }), "{err}");
    }

    #[test]
    fn dead_node_observations_cover_all_incident_links() {
        let t = Torus::ring(9);
        let obs = obs_of_event(&FaultEvent::node(1.0, 4), &t);
        // a ring node has 2 outgoing + 2 incoming directed links
        let mut links: Vec<usize> = obs.iter().map(|o| o.link).collect();
        links.sort_unstable();
        links.dedup();
        assert_eq!(links.len(), 4);
        assert!(obs.iter().all(|o| o.cap_ratio == 0.0));
    }

    #[test]
    fn selector_policy_drives_the_controller() {
        // 3x3 at 256 KiB: a mid-first-step cable death sits within the
        // match threshold of the mid-fault fingerprint (measured d=0.484),
        // so the policy rewrites. On ring-9 the same event is farther from
        // the tuned fingerprint (d>1) and conservatively detours instead.
        let t = Torus::new(&[3, 3]);
        let sel = OnlineSelector::from_table(&toy_table(&t, false), &t).unwrap();
        let b = crate::algo::build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let m = 256 * 1024u64;
        let ends =
            crate::schedule::online::step_time_estimates(&b.net, &base, m, &p);
        let ev = FaultEvent::cable(0.5 * (ends[0] + ends[1]), &t, 0);
        let resp = crate::schedule::online::respond(
            &b,
            &base,
            &[ev],
            m,
            &p,
            sel.policy(&t, m, &p),
        )
        .unwrap();
        assert_eq!(resp.actions, vec![(1, Action::Rewrite)]);
    }
}
