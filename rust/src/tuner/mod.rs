//! `tuner` — online algorithm selection distilled from offline sweeps.
//!
//! The paper's headline claim is regime-dependent: Trivance wins
//! latency-bound message sizes while bandwidth-optimal schedules win huge
//! ones, and the crossover moves with topology, fabric health, and base
//! bandwidth. A deployment therefore needs a *selector* — the per-size
//! choice the paper's evaluation sweeps over by hand. This subsystem is
//! that selector, the first rung of the serving story:
//!
//! * [`table`] — [`table::tune`] sweeps `(topology, scenario preset, algo,
//!   size)` through the shared grid engine and distills the winners into a
//!   [`DecisionTable`]: O(1) [`DecisionTable::recommend`] lookups, JSON
//!   round-tripping, [`crate::net::NetModel`]-fingerprint staleness
//!   detection, and [`crate::cost::NetParams`] provenance.
//! * [`workload`] — deterministic synthetic traces (data-parallel /
//!   tensor-parallel / mixed, [`crate::util::rng::SplitMix64`]-seeded) and
//!   the [`workload::replay`] engine scoring table-driven selection against
//!   the per-call oracle and every fixed-algorithm baseline.
//! * [`online`] — the live rung: link observations matched against the
//!   tuned scenario family by nearest-descriptor distance, yielding a
//!   rewrite / detour action for the in-flight collective plus an
//!   algorithm switch for the next one, scored by
//!   `trivance scenarios --online` against the oracle and the static
//!   strategies.
//!
//! CLI: `trivance tune`, `trivance recommend`, `trivance replay`.
//! Acceptance (pinned by `tools/pysim/eval_tuner.py`, mirrored math):
//! table-driven selection lands within 5% of the per-call oracle on every
//! built-in trace × scenario preset (measured worst +0.94%) and strictly
//! beats every fixed-algorithm policy on the mixed trace.

pub mod online;
pub mod table;
pub mod workload;

pub use online::{
    obs_of_event, obs_of_samples, preset_obs, ref_horizon, LinkObs, OnlineSelector,
    ScenarioFeatures, Selection, SelectorRow,
};
pub use table::{
    distill, ladder_index, tune, tune_ladder, Choice, DecisionTable, Recommendation,
    RecommendError, ScenarioTable, TopoTable,
};
pub use workload::{
    builtin_traces, generate, replay, PolicyOutcome, ReplayCell, ReplayReport, Trace,
    TRACE_NAMES,
};
