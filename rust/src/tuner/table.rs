//! Decision tables: offline scenario sweeps distilled into a servable
//! per-`(topology, scenario, size)` algorithm choice.
//!
//! [`tune`] runs the full `(scenario, algo, size)` grid of
//! [`crate::harness::scenarios::run_scenarios`] (one parallel task pool per
//! topology, plans shared through the process-wide
//! [`crate::sim::PlanCache`]) over the **tune ladder** — `32·2^k`, twice as
//! dense as the paper's `×4` sweep axis, so a production message size is
//! never more than a quarter-decade in log-space from a tuned point — and
//! [`distill`]s each sweep into per-size winners. The result is a
//! [`DecisionTable`]:
//!
//! * [`DecisionTable::recommend`] answers "which algorithm do I run right
//!   now" in O(1): topology row, scenario row matched by the live
//!   [`NetModel`]'s [`NetModel::fingerprint`], then a pure-integer
//!   nearest-in-log-space ladder lookup ([`ladder_index`] — midpoints
//!   `32·2^k·√2` tested as `2·b²` against powers of two, no float log).
//! * A model whose link table or down set matches **no** tuned scenario is
//!   rejected ([`RecommendError::StaleModel`]) instead of silently served a
//!   winner tuned for a different fabric — the same stale-plan trap the
//!   plan cache's fingerprint key closes.
//! * Tables serialize to JSON with the crate's hand-rolled writer and load
//!   back through [`crate::util::json`]; floats round-trip bit-exactly
//!   (Rust's shortest-representation formatter) and the stored
//!   [`NetParams`] are fingerprinted so a table tuned at 800 Gb/s is never
//!   consulted for a 200 Gb/s fabric ([`DecisionTable::params_match`]).
//!
//! The decision-table math is mirrored in `tools/pysim/mirror.py`
//! (`tune_ladder` / `ladder_index` / `distill_winners`) — keep them in
//! lockstep; `eval_tuner.py` pins the acceptance bounds.

use crate::algo::{Algo, Variant};
use crate::cost::NetParams;
use crate::harness::scenarios::{run_scenarios, Scenario, ScenarioSweep};
use crate::harness::sweep::completion_key;
use crate::net::NetModel;
use crate::sim::SimMode;
use crate::topology::Torus;
use crate::util::{fmt, json};

/// Schema tag of the serialized table.
pub const SCHEMA: &str = "trivance.tuner.v1";

/// One tuned choice: the winning algorithm and variant at a ladder point.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Choice {
    pub algo: Algo,
    pub variant: Variant,
}

impl Choice {
    pub fn label(&self) -> String {
        format!("{}-{}", self.algo.label(), self.variant.label())
    }

    fn parse(s: &str) -> Option<Choice> {
        let (a, v) = s.rsplit_once('-')?;
        let algo = Algo::parse(a)?;
        let variant = match v {
            "L" => Variant::Latency,
            "B" => Variant::Bandwidth,
            _ => return None,
        };
        Some(Choice { algo, variant })
    }
}

/// Winners of one scenario on one topology, aligned with the owning
/// [`TopoTable`]'s ladder.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioTable {
    pub scenario: String,
    /// [`NetModel::fingerprint`] of the fabric this row was tuned for
    /// (`0` = uniform).
    pub net_fp: u64,
    /// [`crate::harness::scenarios::Scenario::dyn_fingerprint`] of the
    /// dynamic condition this row was tuned for (`0` = static fabric). A
    /// lookup under a live timeline/fault must match it, so a table tuned
    /// on static fabrics is *timeline-stale* for a dynamic one — rejected,
    /// never silently served.
    pub timeline_fp: u64,
    /// Provenance: true when this row was parsed from a JSON that predates
    /// timeline support (no `timeline_fp` key). Such rows carry
    /// `timeline_fp = 0`, which is also the legitimate "static condition"
    /// fingerprint — so dynamic lookups against a pre-dynamic table are
    /// rejected with [`RecommendError::PreDynamicTable`] (naming the
    /// provenance) instead of a generic stale-model error.
    pub pre_dynamic: bool,
    pub winners: Vec<Choice>,
}

/// All scenario rows of one topology, sharing one tune ladder.
#[derive(Clone, Debug, PartialEq)]
pub struct TopoTable {
    pub dims: Vec<u32>,
    pub sizes: Vec<u64>,
    pub scenarios: Vec<ScenarioTable>,
}

/// The distilled decision table (module docs).
#[derive(Clone, Debug)]
pub struct DecisionTable {
    /// The base network parameters the winners were tuned under.
    pub params: NetParams,
    pub topos: Vec<TopoTable>,
}

/// A resolved recommendation.
#[derive(Clone, Debug, PartialEq)]
pub struct Recommendation {
    pub algo: Algo,
    pub variant: Variant,
    /// Name of the matched scenario row.
    pub scenario: String,
    /// The tuned ladder size the decision was read from.
    pub table_bytes: u64,
    /// True when the requested size sat *below* the ladder floor (32 B) and
    /// was clamped to the 32 B row — the documented sub-floor behaviour:
    /// everything under 32 B is pure-latency-bound, so the 32 B winner
    /// applies. Sizes *above* the tuned maximum are never clamped
    /// ([`RecommendError::OutOfRange`]).
    pub clamped: bool,
}

/// Why a lookup could not be served.
#[derive(Clone, Debug, PartialEq)]
pub enum RecommendError {
    /// No tuned row for this topology.
    UnknownTopo { dims: Vec<u32> },
    /// The live `(model, dynamic-condition)` fingerprint pair matches no
    /// tuned scenario row: the table is stale for this fabric (re-run
    /// `trivance tune`). `timeline_fp == 0` means the lookup was static.
    StaleModel { dims: Vec<u32>, fingerprint: u64, timeline_fp: u64 },
    /// A *dynamic* lookup was attempted against a table whose rows were
    /// distilled before timeline support existed (their JSON carries no
    /// `timeline_fp`): `0` there means "provenance unknown", not "matches
    /// the empty timeline", so the lookup is refused by provenance instead
    /// of pretending the static winners were tuned for this condition.
    PreDynamicTable { dims: Vec<u32>, timeline_fp: u64 },
    /// The requested size lies above the tuned ladder's maximum: the
    /// nearest-in-log-space index would silently extrapolate the last
    /// winner arbitrarily far, so the lookup is refused instead (re-tune
    /// with a larger `--max-size`).
    OutOfRange { dims: Vec<u32>, bytes: u64, max: u64 },
}

impl std::fmt::Display for RecommendError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RecommendError::UnknownTopo { dims } => {
                write!(f, "decision table has no row for topology {dims:?} — re-run `trivance tune --topo ...`")
            }
            RecommendError::StaleModel { dims, fingerprint, timeline_fp } => {
                write!(
                    f,
                    "decision table is stale for {dims:?}: live NetModel fingerprint \
                     {fingerprint:#x} (dynamic-condition fingerprint {timeline_fp:#x}) \
                     matches no tuned scenario — re-run `trivance tune`"
                )
            }
            RecommendError::PreDynamicTable { dims, timeline_fp } => {
                write!(
                    f,
                    "decision table for {dims:?} was distilled before timeline support \
                     (its rows carry no timeline_fp) and cannot serve a dynamic lookup \
                     (live dynamic-condition fingerprint {timeline_fp:#x}) — re-run \
                     `trivance tune` to regenerate the table with dynamic scenario rows"
                )
            }
            RecommendError::OutOfRange { dims, bytes, max } => {
                write!(
                    f,
                    "requested size {bytes} B exceeds the tuned ladder's maximum {max} B for \
                     {dims:?} — the table has no signal there; re-run `trivance tune` with a \
                     larger --max-size"
                )
            }
        }
    }
}

/// The tuner's distillation ladder: `32·2^k` up to `max` (inclusive) —
/// twice as dense as the paper's `×4` sweep axis ([`crate::harness::sweep::size_ladder`]).
pub fn tune_ladder(max: u64) -> Vec<u64> {
    let mut v = Vec::new();
    let mut m = 32u64;
    while m <= max {
        v.push(m);
        // a caller-supplied max near u64::MAX must terminate, not wrap
        match m.checked_mul(2) {
            Some(next) => m = next,
            None => break,
        }
    }
    v
}

/// O(1) nearest-in-log-space index into the `32·2^k` tune ladder, clamped
/// to `[0, len)`. The boundary between index `k` and `k+1` is the geometric
/// midpoint `32·2^k·√2`, tested in pure integer arithmetic:
/// `round(log2(b/32)) = (⌊log2(2·b²)⌋ − 10) / 2` (floor-division identity
/// `⌊x/2⌋ = ⌊⌊x⌋/2⌋`; the square is taken in u128 and the doubling folded
/// into the exponent — `⌊log2(2x)⌋ = ⌊log2 x⌋ + 1` — so the full u64 size
/// range indexes exactly, `u64::MAX` included). Mirrored in
/// `tools/pysim/mirror.py::ladder_index`.
pub fn ladder_index(bytes: u64, len: usize) -> usize {
    assert!(len > 0, "empty ladder");
    let b = bytes.max(1) as u128;
    let l = (128 - (b * b).leading_zeros()) as usize; // ⌊log2(2·b²)⌋
    let idx = if l < 10 { 0 } else { (l - 10) / 2 };
    idx.min(len - 1)
}

/// Distill one topology's scenario sweep into its [`TopoTable`]: the winner
/// at each `(scenario, size)` cell is the first minimum across algorithms
/// of the best-variant completion — the same NaN-safe tie-break as
/// [`crate::harness::sweep::Sweep::winners`].
pub fn distill(torus: &Torus, sweep: &ScenarioSweep) -> TopoTable {
    let scenarios = sweep
        .scenarios
        .iter()
        .enumerate()
        .map(|(ci, sc)| {
            let winners = (0..sweep.sizes.len())
                .map(|si| {
                    let row = &sweep.points[ci][si];
                    let ai = row
                        .iter()
                        .enumerate()
                        .min_by(|a, b| {
                            completion_key(a.1.completion_s)
                                .total_cmp(&completion_key(b.1.completion_s))
                        })
                        .expect("non-empty algo row")
                        .0;
                    Choice { algo: sweep.algos[ai], variant: row[ai].variant }
                })
                .collect();
            ScenarioTable {
                scenario: sc.name.clone(),
                net_fp: sc.model(torus).fingerprint(),
                timeline_fp: sc.dyn_fingerprint(torus),
                pre_dynamic: false,
                winners,
            }
        })
        .collect();
    TopoTable { dims: torus.dims().to_vec(), sizes: sweep.sizes.clone(), scenarios }
}

/// Run the offline sweep over every `(topology, scenario, algo, ladder
/// size)` cell and distill it into a [`DecisionTable`]. Plans are shared
/// through the global [`crate::sim::PlanCache`] (keyed by each scenario
/// model's fingerprint), so repeated tunes in one process re-simulate but
/// never re-flatten.
pub fn tune(
    topos: &[Torus],
    scenarios: &[Scenario],
    max_size: u64,
    params: &NetParams,
    threads: usize,
    mode: SimMode,
) -> Result<DecisionTable, String> {
    params.validate();
    assert!(
        max_size >= 32,
        "tune: max_size must be >= 32 B (got {max_size}) — the tune ladder starts at 32"
    );
    let sizes = tune_ladder(max_size);
    let topo_tables = topos
        .iter()
        .map(|torus| {
            let sweep =
                run_scenarios(torus, &Algo::ALL, &sizes, params, scenarios, threads, mode)?;
            Ok(distill(torus, &sweep))
        })
        .collect::<Result<_, String>>()?;
    Ok(DecisionTable { params: *params, topos: topo_tables })
}

impl DecisionTable {
    /// The tuned rows for `(dims, model)` on a *static* fabric: topology
    /// matched exactly, scenario matched by the model's fingerprint
    /// (module docs).
    pub fn scenario_row(
        &self,
        dims: &[u32],
        model: &NetModel,
    ) -> Result<(&TopoTable, &ScenarioTable), RecommendError> {
        self.scenario_row_dyn(dims, model, 0)
    }

    /// [`scenario_row`](Self::scenario_row) under a dynamic condition:
    /// the row must match **both** the model fingerprint and the dynamic
    /// (timeline/fault) fingerprint — a table tuned on static fabrics is
    /// timeline-stale for a live dynamic one, and vice versa.
    pub fn scenario_row_dyn(
        &self,
        dims: &[u32],
        model: &NetModel,
        timeline_fp: u64,
    ) -> Result<(&TopoTable, &ScenarioTable), RecommendError> {
        let topo = self
            .topos
            .iter()
            .find(|t| t.dims == dims)
            .ok_or_else(|| RecommendError::UnknownTopo { dims: dims.to_vec() })?;
        if timeline_fp != 0
            && !topo.scenarios.is_empty()
            && topo.scenarios.iter().all(|s| s.pre_dynamic)
        {
            return Err(RecommendError::PreDynamicTable {
                dims: dims.to_vec(),
                timeline_fp,
            });
        }
        let fp = model.fingerprint();
        let sc = topo
            .scenarios
            .iter()
            .find(|s| s.net_fp == fp && s.timeline_fp == timeline_fp)
            .ok_or(RecommendError::StaleModel {
                dims: dims.to_vec(),
                fingerprint: fp,
                timeline_fp,
            })?;
        Ok((topo, sc))
    }

    /// O(1) lookup: which algorithm (and variant) to run for a `bytes`
    /// AllReduce on `dims` under the live (static) `model`. Sizes below the
    /// 32 B ladder floor clamp to the 32 B row (`clamped` is set — the
    /// sub-floor regime is pure-latency-bound, where the 32 B winner
    /// applies); sizes above the tuned maximum return
    /// [`RecommendError::OutOfRange`] instead of extrapolating.
    pub fn recommend(
        &self,
        dims: &[u32],
        model: &NetModel,
        bytes: u64,
    ) -> Result<Recommendation, RecommendError> {
        self.recommend_dyn(dims, model, 0, bytes)
    }

    /// [`recommend`](Self::recommend) under a dynamic condition — pass the
    /// live scenario's
    /// [`crate::harness::scenarios::Scenario::dyn_fingerprint`].
    pub fn recommend_dyn(
        &self,
        dims: &[u32],
        model: &NetModel,
        timeline_fp: u64,
        bytes: u64,
    ) -> Result<Recommendation, RecommendError> {
        let (topo, sc) = self.scenario_row_dyn(dims, model, timeline_fp)?;
        let max = *topo.sizes.last().expect("non-empty ladder");
        if bytes > max {
            return Err(RecommendError::OutOfRange { dims: dims.to_vec(), bytes, max });
        }
        let clamped = bytes < topo.sizes[0];
        let idx = ladder_index(bytes, topo.sizes.len());
        let c = sc.winners[idx];
        Ok(Recommendation {
            algo: c.algo,
            variant: c.variant,
            scenario: sc.scenario.clone(),
            table_bytes: topo.sizes[idx],
            clamped,
        })
    }

    /// Were the winners tuned under exactly these base parameters?
    /// (Bit-compared: a table tuned at another bandwidth has different
    /// crossovers and must not be consulted.)
    pub fn params_match(&self, params: &NetParams) -> bool {
        self.params.alpha_s.to_bits() == params.alpha_s.to_bits()
            && self.params.link_bw_bps.to_bits() == params.link_bw_bps.to_bits()
            && self.params.link_latency_s.to_bits() == params.link_latency_s.to_bits()
            && self.params.hop_latency_s.to_bits() == params.hop_latency_s.to_bits()
    }

    /// Hand-rolled JSON (schema [`SCHEMA`]). Floats print with Rust's
    /// shortest round-trip formatter; fingerprints as decimal strings (u64
    /// does not fit in a JSON double).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"schema\": \"{SCHEMA}\",\n"));
        out.push_str(&format!(
            "  \"params\": {{\"alpha_s\": {}, \"link_bw_bps\": {}, \
             \"link_latency_s\": {}, \"hop_latency_s\": {}}},\n",
            self.params.alpha_s,
            self.params.link_bw_bps,
            self.params.link_latency_s,
            self.params.hop_latency_s
        ));
        out.push_str("  \"topos\": [");
        let mut first_topo = true;
        for topo in &self.topos {
            if !first_topo {
                out.push(',');
            }
            first_topo = false;
            let dims: Vec<String> = topo.dims.iter().map(|d| d.to_string()).collect();
            let sizes: Vec<String> = topo.sizes.iter().map(|s| s.to_string()).collect();
            out.push_str(&format!(
                "\n    {{\n      \"dims\": [{}],\n      \"sizes\": [{}],\n      \"scenarios\": [",
                dims.join(", "),
                sizes.join(", ")
            ));
            let mut first_sc = true;
            for sc in &topo.scenarios {
                if !first_sc {
                    out.push(',');
                }
                first_sc = false;
                let winners: Vec<String> =
                    sc.winners.iter().map(|c| format!("\"{}\"", c.label())).collect();
                out.push_str(&format!(
                    "\n        {{\"name\": \"{}\", \"net_fp\": \"{}\", \
                     \"timeline_fp\": \"{}\", \"winners\": [{}]}}",
                    json::escape(&sc.scenario),
                    sc.net_fp,
                    sc.timeline_fp,
                    winners.join(", ")
                ));
            }
            out.push_str("\n      ]\n    }");
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// Parse a table serialized by [`DecisionTable::to_json`], validating
    /// the schema tag, the `32·2^k` ladder shape [`ladder_index`] assumes,
    /// and the winner/ladder alignment.
    pub fn from_json(text: &str) -> Result<DecisionTable, String> {
        let doc = json::parse(text)?;
        let schema = doc.get("schema").and_then(|s| s.as_str()).unwrap_or("");
        if schema != SCHEMA {
            return Err(format!("unsupported decision-table schema {schema:?} (want {SCHEMA})"));
        }
        let p = doc.get("params").ok_or("missing params")?;
        let field = |k: &str| -> Result<f64, String> {
            p.get(k)
                .and_then(|v| v.as_f64())
                .ok_or_else(|| format!("missing params.{k}"))
        };
        let params = NetParams {
            alpha_s: field("alpha_s")?,
            link_bw_bps: field("link_bw_bps")?,
            link_latency_s: field("link_latency_s")?,
            hop_latency_s: field("hop_latency_s")?,
        };
        // reject (rather than panic on) a corrupted file: same predicates
        // as NetParams::validate
        if !(params.link_bw_bps.is_finite() && params.link_bw_bps > 0.0)
            || !(params.alpha_s.is_finite() && params.alpha_s >= 0.0)
            || !(params.link_latency_s.is_finite() && params.link_latency_s >= 0.0)
            || !(params.hop_latency_s.is_finite() && params.hop_latency_s >= 0.0)
        {
            return Err("decision table carries invalid network parameters".into());
        }
        let mut topos = Vec::new();
        for topo in doc.get("topos").and_then(|t| t.as_arr()).ok_or("missing topos")? {
            let dims: Vec<u32> = topo
                .get("dims")
                .and_then(|d| d.as_arr())
                .ok_or("missing dims")?
                .iter()
                .map(|v| v.as_u64().map(|d| d as u32).ok_or("bad dim"))
                .collect::<Result<_, _>>()?;
            let sizes: Vec<u64> = topo
                .get("sizes")
                .and_then(|s| s.as_arr())
                .ok_or("missing sizes")?
                .iter()
                .map(|v| v.as_u64().ok_or("bad size"))
                .collect::<Result<_, _>>()?;
            if sizes.is_empty()
                || sizes[0] != 32
                || sizes.windows(2).any(|w| w[1] != w[0] * 2)
            {
                return Err(format!(
                    "sizes {sizes:?} is not the 32·2^k tune ladder recommend() indexes into"
                ));
            }
            let mut scenarios = Vec::new();
            for sc in topo
                .get("scenarios")
                .and_then(|s| s.as_arr())
                .ok_or("missing scenarios")?
            {
                let name = sc
                    .get("name")
                    .and_then(|n| n.as_str())
                    .ok_or("missing scenario name")?
                    .to_string();
                let net_fp: u64 = sc
                    .get("net_fp")
                    .and_then(|f| f.as_str())
                    .ok_or("missing net_fp")?
                    .parse()
                    .map_err(|e| format!("bad net_fp: {e}"))?;
                // absent in pre-dynamic tables: parse as 0 but mark the
                // provenance, so dynamic lookups are refused by name
                // instead of treating 0 as "matches the empty timeline"
                let (timeline_fp, pre_dynamic): (u64, bool) = match sc.get("timeline_fp") {
                    None => (0, true),
                    Some(v) => (
                        v.as_str()
                            .ok_or("bad timeline_fp")?
                            .parse()
                            .map_err(|e| format!("bad timeline_fp: {e}"))?,
                        false,
                    ),
                };
                let winners: Vec<Choice> = sc
                    .get("winners")
                    .and_then(|w| w.as_arr())
                    .ok_or("missing winners")?
                    .iter()
                    .map(|v| {
                        v.as_str()
                            .and_then(Choice::parse)
                            .ok_or_else(|| format!("bad winner {v:?}"))
                    })
                    .collect::<Result<_, _>>()?;
                if winners.len() != sizes.len() {
                    return Err(format!(
                        "scenario {name:?}: {} winners for {} ladder sizes",
                        winners.len(),
                        sizes.len()
                    ));
                }
                scenarios.push(ScenarioTable {
                    scenario: name,
                    net_fp,
                    timeline_fp,
                    pre_dynamic,
                    winners,
                });
            }
            topos.push(TopoTable { dims, sizes, scenarios });
        }
        Ok(DecisionTable { params, topos })
    }

    /// Markdown summary: per topology, each scenario's winner as collapsed
    /// size ranges.
    pub fn render(&self) -> String {
        let mut out = String::new();
        for topo in &self.topos {
            out.push_str(&format!(
                "#### decision table — {:?} ({} ladder points up to {})\n\n",
                topo.dims,
                topo.sizes.len(),
                fmt::bytes(*topo.sizes.last().expect("non-empty ladder"))
            ));
            let mut t = fmt::Table::new(vec!["scenario", "size range → algorithm"]);
            for sc in &topo.scenarios {
                let mut segs: Vec<String> = Vec::new();
                let mut start = 0usize;
                for i in 1..=sc.winners.len() {
                    if i == sc.winners.len() || sc.winners[i] != sc.winners[start] {
                        let lo = fmt::bytes(topo.sizes[start]);
                        let range = if start == i - 1 {
                            lo
                        } else {
                            format!("{lo}–{}", fmt::bytes(topo.sizes[i - 1]))
                        };
                        segs.push(format!("{range} → {}", sc.winners[start].label()));
                        start = i;
                    }
                }
                t.row(vec![sc.scenario.clone(), segs.join("; ")]);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_ladder_shape() {
        let v = tune_ladder(128 << 20);
        assert_eq!(v[0], 32);
        assert_eq!(v[1], 64);
        assert_eq!(*v.last().unwrap(), 128 << 20);
        assert_eq!(v.len(), 23);
    }

    #[test]
    fn ladder_index_is_exact_log_rounding() {
        let n = tune_ladder(128 << 20).len();
        // every ladder point maps to itself
        for (i, m) in tune_ladder(128 << 20).iter().enumerate() {
            assert_eq!(ladder_index(*m, n), i, "ladder point {m}");
        }
        // geometric midpoints 32·2^k·√2: below rounds down, above up
        for (k, below, above) in [(0usize, 45u64, 46u64), (1, 90, 91), (2, 181, 182)] {
            assert_eq!(ladder_index(below, n), k);
            assert_eq!(ladder_index(above, n), k + 1);
        }
        // clamping
        assert_eq!(ladder_index(0, 5), 0);
        assert_eq!(ladder_index(1, 5), 0);
        assert_eq!(ladder_index(u64::MAX, 5), 4);
        // ladders tuned past 2 GiB index exactly (u128 square, no clamp)
        let big = tune_ladder(8 << 30);
        assert_eq!(big.len(), 29);
        for (i, m) in big.iter().enumerate() {
            assert_eq!(ladder_index(*m, big.len()), i, "big ladder point {m}");
        }
        assert_eq!(ladder_index((4u64 << 30) + 1, big.len()), 27);
        assert_eq!(ladder_index(u64::MAX, big.len()), 28);
    }

    #[test]
    fn pre_dynamic_table_rejects_dynamic_lookups_by_provenance() {
        // a table serialized before timeline support: no timeline_fp keys
        let legacy = format!(
            "{{\n  \"schema\": \"{SCHEMA}\",\n  \"params\": {{\"alpha_s\": 0.0000015, \
             \"link_bw_bps\": 800000000000, \"link_latency_s\": 0.0000001, \
             \"hop_latency_s\": 0.0000001}},\n  \"topos\": [\n    {{\"dims\": [9], \
             \"sizes\": [32, 64], \"scenarios\": [\n      {{\"name\": \"uniform\", \
             \"net_fp\": \"0\", \"winners\": [\"trivance-L\", \"trivance-L\"]}}\n    ]}}\n  ]\n}}\n"
        );
        let table = DecisionTable::from_json(&legacy).unwrap();
        assert!(table.topos[0].scenarios[0].pre_dynamic);
        assert_eq!(table.topos[0].scenarios[0].timeline_fp, 0);
        let t = Torus::ring(9);
        let model = NetModel::uniform(&t);
        // static lookups still work (the rows WERE tuned for static fabrics)
        let rec = table.recommend(&[9], &model, 64).unwrap();
        assert_eq!(rec.algo, Algo::Trivance);
        // any dynamic lookup is refused with the provenance-naming error
        let err = table.recommend_dyn(&[9], &model, 0xBEEF, 64).unwrap_err();
        assert_eq!(
            err,
            RecommendError::PreDynamicTable { dims: vec![9], timeline_fp: 0xBEEF }
        );
        assert!(err.to_string().contains("before timeline support"), "{err}");
        // a freshly serialized table round-trips with provenance intact:
        // its rows carry timeline_fp keys, so dynamic lookups fall through
        // to normal fingerprint matching (StaleModel here, not provenance)
        let rt = DecisionTable::from_json(&table.to_json()).unwrap();
        assert!(!rt.topos[0].scenarios[0].pre_dynamic);
        let err = rt.recommend_dyn(&[9], &model, 0xBEEF, 64).unwrap_err();
        assert!(matches!(err, RecommendError::StaleModel { .. }), "{err:?}");
    }

    #[test]
    fn choice_labels_round_trip() {
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let c = Choice { algo, variant };
                assert_eq!(Choice::parse(&c.label()), Some(c), "{}", c.label());
            }
        }
        assert_eq!(Choice::parse("bruck-unidir-B").unwrap().algo, Algo::BruckUnidir);
        assert!(Choice::parse("nope-L").is_none());
        assert!(Choice::parse("trivance-X").is_none());
        assert!(Choice::parse("trivance").is_none());
    }
}
