//! Synthetic multi-collective workload traces and the replay engine that
//! scores selection policies against them.
//!
//! A [`Trace`] is a deterministic sequence of AllReduce message sizes —
//! SplitMix64-generated from a fixed per-trace seed, so every run (and the
//! Python mirror) sees the same workload:
//!
//! | name              | shape                                                    |
//! |-------------------|----------------------------------------------------------|
//! | `data-parallel`   | DDP gradient buckets: 4–64 MiB, bandwidth-dominated      |
//! | `tensor-parallel` | layer-wise activation reductions: 64 KiB–4 MiB, the      |
//! |                   | crossover regime where selection is hardest              |
//! | `mixed`           | inference + training mix: many tiny latency-bound calls  |
//! |                   | interleaved with large gradient buckets — no fixed       |
//! |                   | algorithm wins both ends                                 |
//!
//! Each draw picks a weighted base size then a `×{3/4, 1, 5/4}` jitter, so
//! most replayed sizes sit **between** tuned ladder points — the replay
//! exercises the table's nearest-point rounding, not just exact hits.
//!
//! [`replay`] runs every trace through the simulators under three policy
//! families — per-call **oracle** (lower bound), **table**-driven
//! ([`DecisionTable::recommend`]), and **fixed-algorithm** (best variant
//! per call, the strongest fixed baseline) — on every scenario preset, as
//! one `(scenario, size, algo)` grid through the shared
//! [`crate::harness::sweep::eval_grid`] engine with hoisted
//! [`crate::sim::SimScratch`] columns (a trace never rebuilds
//! per-collective scratch), on the same plan/scratch lattice the scenario
//! sweep tunes on ([`crate::harness::scenarios`]'s `build_scenario_plans`).
//! The report carries total completion and regret-vs-oracle per cell;
//! `tools/pysim/eval_tuner.py` pins the acceptance bounds (table within 5%
//! of oracle everywhere, strictly ahead of every fixed policy on the mixed
//! trace — measured worst regret +0.94%).

use crate::algo::{Algo, Variant};
use crate::cost::NetParams;
use crate::harness::scenarios::{build_scenario_plans, Scenario, ScenarioKind, ScenarioPlans};
use crate::harness::sweep::{completion_key, eval_grid};
use crate::net::NetModel;
use crate::sim::{simulate_plan_timeline, SimMode};
use crate::topology::Torus;
use crate::util::fmt;
use crate::util::rng::SplitMix64;

use super::table::{ladder_index, DecisionTable};

/// A deterministic workload trace (module docs).
#[derive(Clone, Debug)]
pub struct Trace {
    pub name: &'static str,
    pub desc: &'static str,
    pub sizes: Vec<u64>,
}

/// `(name, description, seed, weighted base sizes)` per built-in trace.
/// Keep in lockstep with `tools/pysim/mirror.py::TRACE_MIX`/`TRACE_SEEDS`.
const TRACE_SPECS: [(&str, &str, u64, &[(u64, u64)]); 3] = [
    (
        "data-parallel",
        "DDP gradient buckets (4-64 MiB, bandwidth-dominated)",
        0x7A0E_0001,
        &[(4 << 20, 2), (16 << 20, 3), (32 << 20, 3), (64 << 20, 2)],
    ),
    (
        "tensor-parallel",
        "layer-wise activation reductions (64 KiB-4 MiB, crossover regime)",
        0x7A0E_0002,
        &[(64 << 10, 2), (256 << 10, 3), (1 << 20, 3), (4 << 20, 2)],
    ),
    (
        "mixed",
        "inference+training mix (32 B token syncs to 64 MiB gradient buckets)",
        0x7A0E_0003,
        &[
            (32, 3),
            (512, 3),
            (8 << 10, 3),
            (64 << 10, 2),
            (1 << 20, 2),
            (16 << 20, 1),
            (64 << 20, 1),
        ],
    ),
];

/// Names of the built-in traces, in replay order.
pub const TRACE_NAMES: [&str; 3] = ["data-parallel", "tensor-parallel", "mixed"];

/// Generate one named trace: `calls` draws, each a weighted base size and a
/// `×{3/4, 1, 5/4}` jitter (two SplitMix64 draws per call, weight first),
/// clamped to `[1, max_bytes]`. `None` for an unknown name.
pub fn generate(name: &str, calls: usize, max_bytes: u64) -> Option<Trace> {
    let &(name, desc, seed, mix) = TRACE_SPECS.iter().find(|(n, ..)| *n == name)?;
    let total_w: u64 = mix.iter().map(|&(_, w)| w).sum();
    let mut rng = SplitMix64::new(seed);
    let sizes = (0..calls)
        .map(|_| {
            let w = rng.below(total_w);
            let mut acc = 0u64;
            let mut base = mix.last().expect("non-empty mix").0;
            for &(b, wt) in mix {
                acc += wt;
                if w < acc {
                    base = b;
                    break;
                }
            }
            let j = rng.below(3); // 0,1,2 -> x3/4, x1, x5/4
            (base * (3 + j) / 4).clamp(1, max_bytes)
        })
        .collect();
    Some(Trace { name, desc, sizes })
}

/// All built-in traces at the given call count and size cap.
pub fn builtin_traces(calls: usize, max_bytes: u64) -> Vec<Trace> {
    TRACE_NAMES
        .iter()
        .map(|n| generate(n, calls, max_bytes).expect("built-in trace"))
        .collect()
}

/// One policy's accounting for one `(trace, scenario)` cell.
#[derive(Clone, Debug)]
pub struct PolicyOutcome {
    /// `oracle`, `table`, or `fixed:<algo>`.
    pub label: String,
    /// Total completion of the whole trace (seconds).
    pub total_s: f64,
    /// `total_s / oracle_total − 1` (0 for the oracle row).
    pub regret: f64,
}

/// All policies on one `(trace, scenario)` cell.
#[derive(Clone, Debug)]
pub struct ReplayCell {
    pub scenario: String,
    /// The preset instantiated to the uniform model on this topology.
    pub degenerate: bool,
    /// Oracle first, table second, then one `fixed:<algo>` per algorithm.
    pub outcomes: Vec<PolicyOutcome>,
}

impl ReplayCell {
    fn outcome(&self, label: &str) -> Option<&PolicyOutcome> {
        self.outcomes.iter().find(|o| o.label == label)
    }

    /// The table policy's regret vs the oracle.
    pub fn table_regret(&self) -> f64 {
        self.outcome("table").expect("table row").regret
    }

    /// Is the table policy strictly ahead of every fixed-algorithm policy?
    pub fn table_beats_every_fixed(&self) -> bool {
        let table = self.outcome("table").expect("table row").total_s;
        self.outcomes
            .iter()
            .filter(|o| o.label.starts_with("fixed:"))
            .all(|o| table < o.total_s)
    }
}

/// Full replay result: `cells[trace][scenario]`.
#[derive(Clone, Debug)]
pub struct ReplayReport {
    pub dims: Vec<u32>,
    pub traces: Vec<Trace>,
    pub scenarios: Vec<String>,
    pub cells: Vec<Vec<ReplayCell>>,
}

/// Replay every trace under every scenario on `torus`, scoring the oracle,
/// the table, and every fixed-algorithm policy (module docs). Fails if the
/// table was tuned under different [`NetParams`] or has no row for this
/// topology/scenario — stale tables are rejected, never silently served.
pub fn replay(
    torus: &Torus,
    scenarios: &[Scenario],
    traces: &[Trace],
    table: &DecisionTable,
    params: &NetParams,
    threads: usize,
    mode: SimMode,
) -> Result<ReplayReport, String> {
    params.validate();
    if let Some(t) = traces.iter().find(|t| t.sizes.is_empty()) {
        return Err(format!(
            "trace {:?} is empty — an empty trace has no oracle total to regret against",
            t.name
        ));
    }
    if !table.params_match(params) {
        return Err(format!(
            "decision table was tuned under different network parameters \
             (table: {:.3e} bps / α {:.3e}s; requested: {:.3e} bps / α {:.3e}s) — re-run `trivance tune`",
            table.params.link_bw_bps, table.params.alpha_s, params.link_bw_bps, params.alpha_s
        ));
    }

    // Build each algorithm once; per-scenario plans through the
    // fingerprint-keyed global cache, with hoisted scratch columns — the
    // same lattice the scenario sweep (and therefore `tune`) ran on.
    let models: Vec<NetModel> = scenarios.iter().map(|sc| sc.model(torus)).collect();
    let ScenarioPlans { built, plans, scratches } =
        build_scenario_plans(torus, &Algo::ALL, scenarios, params)?;

    // Resolve each scenario's table row up front (fingerprint checked once
    // per scenario, not once per collective). Dynamic scenarios match on
    // their timeline fingerprint too — a static-tuned table is stale for
    // them and vice versa.
    let rows: Vec<&super::table::ScenarioTable> = models
        .iter()
        .zip(scenarios)
        .map(|(model, sc)| {
            table
                .scenario_row_dyn(torus.dims(), model, sc.dyn_fingerprint(torus))
                .map(|(_, row)| row)
                .map_err(|e| e.to_string())
        })
        .collect::<Result<_, _>>()?;
    let topo_sizes = &table
        .topos
        .iter()
        .find(|t| t.dims == torus.dims())
        .expect("scenario_row verified the topo")
        .sizes;
    // A hand-edited or mismatched table could name winners this topology
    // cannot build — reject up front instead of panicking mid-accounting.
    for row in &rows {
        for c in &row.winners {
            let buildable = built
                .iter()
                .any(|(a, vs)| *a == c.algo && vs.iter().any(|b| b.variant == c.variant));
            if !buildable {
                return Err(format!(
                    "decision table winner {} (scenario {}) is not buildable on {:?} — \
                     re-run `trivance tune` for this topology",
                    c.label(),
                    row.scenario,
                    torus.dims()
                ));
            }
        }
    }

    // Distinct sizes across all traces; one (scenario, size, algo) grid.
    let mut distinct: Vec<u64> = traces.iter().flat_map(|t| t.sizes.iter().copied()).collect();
    distinct.sort_unstable();
    distinct.dedup();
    // timelines depend only on (scenario, size): one per pair, not per cell
    let timelines: Vec<Vec<crate::net::Timeline>> = scenarios
        .iter()
        .map(|sc| distinct.iter().map(|&m| sc.timeline(torus, params, m)).collect())
        .collect();
    let grid = eval_grid(scenarios.len(), distinct.len(), built.len(), threads, |ci, si, ai| {
        let timeline = &timelines[ci][si];
        built[ai]
            .1
            .iter()
            .zip(&plans[ci][ai])
            .zip(&scratches[ci][ai])
            .map(|((b, plan), scratch)| {
                (
                    b.variant,
                    simulate_plan_timeline(plan, scratch, distinct[si], params, mode, timeline)
                        // replay runs the scenario presets, whose timelines
                        // never strand (flaps recover, mid-fault plans
                        // route on the post-fault model)
                        .expect("scenario preset timelines never strand")
                        .completion_s,
                )
            })
            .collect::<Vec<(Variant, f64)>>()
    });

    // Policy accounting per (trace, scenario).
    let cells: Vec<Vec<ReplayCell>> = traces
        .iter()
        .map(|trace| {
            scenarios
                .iter()
                .enumerate()
                .map(|(ci, sc)| {
                    let mut oracle = 0.0f64;
                    let mut table_total = 0.0f64;
                    let mut fixed = vec![0.0f64; built.len()];
                    for &s in &trace.sizes {
                        let si = distinct.binary_search(&s).expect("distinct covers trace");
                        let mut best_all = f64::INFINITY;
                        for (ai, _) in built.iter().enumerate() {
                            let best = grid[ci][si][ai]
                                .iter()
                                .map(|&(_, c)| completion_key(c))
                                .fold(f64::INFINITY, f64::min);
                            fixed[ai] += best;
                            if best < best_all {
                                best_all = best;
                            }
                        }
                        oracle += best_all;
                        let choice = rows[ci].winners[ladder_index(s, topo_sizes.len())];
                        let ai = built
                            .iter()
                            .position(|(a, _)| *a == choice.algo)
                            .expect("tuned winner is a built algorithm");
                        let &(_, c) = grid[ci][si][ai]
                            .iter()
                            .find(|(v, _)| *v == choice.variant)
                            .expect("tuned winner variant was built");
                        table_total += c;
                    }
                    let mut outcomes = vec![
                        PolicyOutcome { label: "oracle".into(), total_s: oracle, regret: 0.0 },
                        PolicyOutcome {
                            label: "table".into(),
                            total_s: table_total,
                            regret: table_total / oracle - 1.0,
                        },
                    ];
                    for ((algo, _), &total) in built.iter().zip(&fixed) {
                        outcomes.push(PolicyOutcome {
                            label: format!("fixed:{}", algo.label()),
                            total_s: total,
                            regret: total / oracle - 1.0,
                        });
                    }
                    let degenerate = !matches!(sc.kind, ScenarioKind::Uniform)
                        && !sc.is_dynamic()
                        && models[ci].is_uniform();
                    ReplayCell { scenario: sc.name.clone(), degenerate, outcomes }
                })
                .collect()
        })
        .collect();

    Ok(ReplayReport {
        dims: torus.dims().to_vec(),
        traces: traces.to_vec(),
        scenarios: scenarios.iter().map(|s| s.name.clone()).collect(),
        cells,
    })
}

impl ReplayReport {
    /// Worst table-vs-oracle regret across every `(trace, scenario)` cell.
    pub fn worst_table_regret(&self) -> f64 {
        self.cells
            .iter()
            .flatten()
            .map(|c| c.table_regret())
            .fold(f64::NEG_INFINITY, f64::max)
    }

    /// Does the table policy strictly beat every fixed-algorithm policy on
    /// the named trace, in every scenario?
    pub fn strictly_beats_fixed_on(&self, trace: &str) -> bool {
        self.traces
            .iter()
            .zip(&self.cells)
            .filter(|(t, _)| t.name == trace)
            .flat_map(|(_, cells)| cells.iter())
            .all(|c| c.table_beats_every_fixed())
    }

    /// Markdown report: per trace, one `policy × scenario` table of total
    /// completion and regret-vs-oracle, plus the acceptance summary.
    pub fn render(&self, title: &str) -> String {
        let mut out = format!("### {title}\n\n");
        for (ti, trace) in self.traces.iter().enumerate() {
            out.push_str(&format!(
                "#### trace `{}` — {} ({} collectives)\n\n",
                trace.name,
                trace.desc,
                trace.sizes.len()
            ));
            let mut header = vec!["policy".to_string()];
            for (ci, name) in self.scenarios.iter().enumerate() {
                let tag = if self.cells[ti][ci].degenerate { " (=uniform)" } else { "" };
                header.push(format!("{name}{tag}"));
            }
            let mut t = fmt::Table::new(header);
            let n_policies = self.cells[ti][0].outcomes.len();
            for pi in 0..n_policies {
                let mut row = vec![self.cells[ti][0].outcomes[pi].label.clone()];
                for cell in &self.cells[ti] {
                    let o = &cell.outcomes[pi];
                    if o.label == "oracle" {
                        row.push(fmt::secs(o.total_s));
                    } else {
                        row.push(format!("{} ({:+.2}%)", fmt::secs(o.total_s), o.regret * 100.0));
                    }
                }
                t.row(row);
            }
            out.push_str(&t.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "table-driven worst regret vs per-call oracle: {:+.2}%\n",
            self.worst_table_regret() * 100.0
        ));
        if self.traces.iter().any(|t| t.name == "mixed") {
            out.push_str(&format!(
                "mixed trace: table strictly beats every fixed-algorithm policy in every scenario: {}\n",
                if self.strictly_beats_fixed_on("mixed") { "yes" } else { "NO" }
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traces_are_deterministic_and_bounded() {
        for name in TRACE_NAMES {
            let a = generate(name, 160, 128 << 20).unwrap();
            let b = generate(name, 160, 128 << 20).unwrap();
            assert_eq!(a.sizes, b.sizes, "{name}");
            assert_eq!(a.sizes.len(), 160);
            assert!(a.sizes.iter().all(|&s| (1..=128 << 20).contains(&s)));
            // jitter keeps the distinct set small enough to replay exactly
            let mut d = a.sizes.clone();
            d.sort_unstable();
            d.dedup();
            assert!(d.len() <= 3 * 7, "{name}: {} distinct", d.len());
            let capped = generate(name, 160, 256 << 10).unwrap();
            assert!(capped.sizes.iter().all(|&s| s <= 256 << 10));
        }
        assert!(generate("nope", 10, 1024).is_none());
    }

    #[test]
    fn mixed_trace_spans_both_regimes() {
        let t = generate("mixed", 160, 128 << 20).unwrap();
        assert!(t.sizes.iter().any(|&s| s <= 1024), "latency-bound calls present");
        assert!(t.sizes.iter().any(|&s| s >= 8 << 20), "bandwidth-bound calls present");
    }
}
