//! Dataflow executor: runs a schedule on *real vectors*, performing every
//! reduction the schedule prescribes, and checks the AllReduce
//! postcondition numerically.
//!
//! The executor is the semantic twin of the static validator
//! ([`crate::schedule::validate`]): the validator proves contributor-set
//! disjointness symbolically; the executor proves it arithmetically — every
//! node ends with the exact global sum, for every algorithm, variant, and
//! topology. It also powers the end-to-end training demo, where the
//! reductions run through the AOT-compiled PJRT kernels
//! ([`crate::runtime`]).
//!
//! State is kept at *atom* granularity (one aggregate per received piece),
//! mirroring what a real implementation must do: an aggregate can be
//! summed further but never split.

use crate::blockset::BlockSet;
use crate::schedule::{Kind, Schedule};

/// The reduction backend. `add3` is Trivance's joint reduction (one fused
/// pass over the accumulator and both incoming aggregates).
pub trait Reducer {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32>;
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32>;
}

/// Plain-Rust reducer (no artifacts needed); also the perf baseline the
/// PJRT path is compared against in benches.
pub struct NativeReducer;

impl Reducer for NativeReducer {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32> {
        a.iter().zip(b).map(|(x, y)| x + y).collect()
    }
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        a.iter().zip(b).zip(c).map(|((x, y), z)| x + y + z).collect()
    }
}

impl Reducer for crate::runtime::Runtime {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32> {
        self.reduce2(a, b).expect("pjrt reduce2")
    }
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        self.reduce3(a, b, c).expect("pjrt reduce3")
    }
}

/// One stored aggregate: the partial sum over `contrib` for one block.
#[derive(Clone, Debug)]
struct Atom {
    contrib: BlockSet,
    data: Vec<f32>,
}

/// Sum a list of vectors with the reducer, preferring 3-way joint
/// reductions (the Trivance fast path).
fn sum_all(reducer: &dyn Reducer, parts: &[&Vec<f32>]) -> Vec<f32> {
    assert!(!parts.is_empty());
    let mut acc: Vec<f32> = parts[0].clone();
    let mut i = 1;
    while i < parts.len() {
        if i + 1 < parts.len() {
            acc = reducer.add3(&acc, parts[i], parts[i + 1]);
            i += 2;
        } else {
            acc = reducer.add2(&acc, parts[i]);
            i += 1;
        }
    }
    acc
}

/// Execute `schedule` on per-node input vectors. `inputs[r]` must have
/// length `n_blocks · block_len`. Returns each node's final vector.
///
/// Panics if the schedule violates exact-cover/disjointness — schedules
/// must come from the validated registry.
pub fn run_allreduce(
    schedule: &Schedule,
    inputs: &[Vec<f32>],
    block_len: usize,
    reducer: &dyn Reducer,
) -> Vec<Vec<f32>> {
    let n = schedule.n as usize;
    let nb = schedule.n_blocks as usize;
    assert_eq!(inputs.len(), n, "one input vector per node");
    for (r, v) in inputs.iter().enumerate() {
        assert_eq!(v.len(), nb * block_len, "input {r} length");
    }

    // state[node][block] = atoms
    let mut state: Vec<Vec<Vec<Atom>>> = inputs
        .iter()
        .enumerate()
        .map(|(r, v)| {
            (0..nb)
                .map(|b| {
                    vec![Atom {
                        contrib: BlockSet::singleton(r as u32, schedule.n),
                        data: v[b * block_len..(b + 1) * block_len].to_vec(),
                    }]
                })
                .collect()
        })
        .collect();

    for (k, step) in schedule.steps.iter().enumerate() {
        // Phase 1: materialize payloads against start-of-step state.
        // payloads: (dst, block, kind, contrib, data)
        let mut deliveries: Vec<(usize, usize, Kind, BlockSet, Vec<f32>)> = Vec::new();
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                for piece in &snd.pieces {
                    for b in piece.blocks.iter() {
                        let cell = &state[src][b as usize];
                        match piece.kind {
                            Kind::Reduce => {
                                let parts: Vec<&Vec<f32>> = cell
                                    .iter()
                                    .filter(|a| piece.contrib.is_superset(&a.contrib))
                                    .map(|a| &a.data)
                                    .collect();
                                let got: u64 = cell
                                    .iter()
                                    .filter(|a| piece.contrib.is_superset(&a.contrib))
                                    .map(|a| a.contrib.len())
                                    .sum();
                                assert_eq!(
                                    got,
                                    piece.contrib.len(),
                                    "step {k}: {src}->{}: block {b}: contrib {:?} is not an \
                                     exact atom cover",
                                    snd.to,
                                    piece.contrib
                                );
                                let data = sum_all(reducer, &parts);
                                deliveries.push((
                                    snd.to as usize,
                                    b as usize,
                                    Kind::Reduce,
                                    piece.contrib.clone(),
                                    data,
                                ));
                            }
                            Kind::Set => {
                                let total: u64 = cell.iter().map(|a| a.contrib.len()).sum();
                                assert_eq!(
                                    total, schedule.n as u64,
                                    "step {k}: {src}->{}: Set of incomplete block {b}",
                                    snd.to
                                );
                                let parts: Vec<&Vec<f32>> = cell.iter().map(|a| &a.data).collect();
                                let data = sum_all(reducer, &parts);
                                deliveries.push((
                                    snd.to as usize,
                                    b as usize,
                                    Kind::Set,
                                    BlockSet::full(schedule.n),
                                    data,
                                ));
                            }
                        }
                    }
                }
            }
        }
        // Phase 2: apply.
        for (dst, b, kind, contrib, data) in deliveries {
            match kind {
                Kind::Reduce => state[dst][b].push(Atom { contrib, data }),
                Kind::Set => state[dst][b] = vec![Atom { contrib, data }],
            }
        }
    }

    // Collapse: every node, every block must have full coverage.
    state
        .into_iter()
        .enumerate()
        .map(|(r, node)| {
            let mut out = Vec::with_capacity(nb * block_len);
            for (b, cell) in node.into_iter().enumerate() {
                let total: u64 = cell.iter().map(|a| a.contrib.len()).sum();
                assert_eq!(
                    total, schedule.n as u64,
                    "node {r} block {b}: incomplete coverage"
                );
                let parts: Vec<&Vec<f32>> = cell.iter().map(|a| &a.data).collect();
                out.extend_from_slice(&sum_all(reducer, &parts));
            }
            out
        })
        .collect()
}

/// Build random inputs, run the schedule, and compare every node's result
/// against the reference global sum. Returns the max absolute error.
pub fn verify_allreduce(
    schedule: &Schedule,
    block_len: usize,
    seed: u64,
    reducer: &dyn Reducer,
) -> f64 {
    let n = schedule.n as usize;
    let nb = schedule.n_blocks as usize;
    let mut rng = crate::util::SplitMix64::new(seed);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..nb * block_len).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let mut expect = vec![0f64; nb * block_len];
    for v in &inputs {
        for (e, x) in expect.iter_mut().zip(v) {
            *e += *x as f64;
        }
    }
    let results = run_allreduce(schedule, &inputs, block_len, reducer);
    let mut max_err = 0f64;
    for res in &results {
        for (got, want) in res.iter().zip(&expect) {
            max_err = max_err.max((*got as f64 - want).abs());
        }
    }
    max_err
}

/// Error tolerance for f32 summation over n contributors.
pub fn f32_sum_tolerance(n: u32) -> f64 {
    1e-4 * (n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{build, Algo, Variant};
    use crate::topology::Torus;

    #[test]
    fn trivance_ring9_numerics() {
        let t = Torus::ring(9);
        for variant in Variant::ALL {
            let b = build(Algo::Trivance, variant, &t).unwrap();
            let err = verify_allreduce(&b.exec, 8, 42, &NativeReducer);
            assert!(err < f32_sum_tolerance(9), "{variant:?}: err {err}");
        }
    }

    #[test]
    fn all_algorithms_ring8_numerics() {
        let t = Torus::ring(8);
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 4, 7, &NativeReducer);
                assert!(err < f32_sum_tolerance(8), "{algo:?} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn trivance_arbitrary_n_numerics() {
        for n in [5u32, 7, 11, 26, 32] {
            let t = Torus::ring(n);
            for variant in Variant::ALL {
                let b = build(Algo::Trivance, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 2, n as u64, &NativeReducer);
                assert!(err < f32_sum_tolerance(n), "n={n} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn torus_3x3_numerics() {
        let t = Torus::new(&[3, 3]);
        for algo in [Algo::Trivance, Algo::Bruck, Algo::Bucket] {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 2, 3, &NativeReducer);
                assert!(err < f32_sum_tolerance(9), "{algo:?} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn padded_swing_numerics() {
        // swing on n=6 pads to 8 virtual nodes; executor runs the virtual
        // schedule (real nodes take their virtual result).
        let t = Torus::ring(6);
        let b = build(Algo::Swing, Variant::Bandwidth, &t).unwrap();
        assert!(b.padded);
        let err = verify_allreduce(&b.exec, 2, 3, &NativeReducer);
        assert!(err < f32_sum_tolerance(8), "err {err}");
    }

    #[test]
    #[should_panic(expected = "incomplete coverage")]
    fn incomplete_schedule_panics() {
        let t = Torus::ring(9);
        let mut b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        // drop the last step: coverage must fail loudly
        b.exec.steps.pop();
        let _ = verify_allreduce(&b.exec, 2, 1, &NativeReducer);
    }
}
