//! Dataflow executor: runs a schedule on *real vectors*, performing every
//! reduction the schedule prescribes, and checks the AllReduce
//! postcondition numerically.
//!
//! The executor is the semantic twin of the static validator
//! ([`crate::schedule::validate`]): the validator proves contributor-set
//! disjointness symbolically; the executor proves it arithmetically — every
//! node ends with the exact global sum, for every algorithm, variant, and
//! topology. It also powers the end-to-end training demo, where the
//! reductions run through the AOT-compiled PJRT kernels
//! ([`crate::runtime`]).
//!
//! State is kept at *atom* granularity (one aggregate per received piece),
//! mirroring what a real implementation must do: an aggregate can be
//! summed further but never split.

use crate::blockset::BlockSet;
use crate::schedule::{Kind, Schedule};

/// The reduction backend. `add3` is Trivance's joint reduction (one fused
/// pass over the accumulator and both incoming aggregates).
///
/// The `_assign` variants reduce *into* the accumulator; the defaults
/// delegate to the allocating methods so external backends (PJRT) stay
/// source-compatible, while in-process reducers override them to make
/// [`run_allreduce`]'s inner sums allocation-free past the initial clone.
/// Float addition is elementwise here, so every implementation must be
/// **bit-identical** per element to the scalar oracle ([`NativeReducer`]) —
/// `add3` is the left-associated `(a + b) + c`, never a re-association.
pub trait Reducer {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32>;
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32>;
    fn add2_assign(&self, acc: &mut Vec<f32>, b: &[f32]) {
        *acc = self.add2(acc, b);
    }
    fn add3_assign(&self, acc: &mut Vec<f32>, b: &[f32], c: &[f32]) {
        *acc = self.add3(acc, b, c);
    }
}

/// Plain-Rust scalar reducer: the bit-level oracle every other backend is
/// checked against (and the historical seed implementation).
pub struct NativeReducer;

impl Reducer for NativeReducer {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32> {
        a.iter().zip(b).map(|(x, y)| x + y).collect()
    }
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        a.iter().zip(b).zip(c).map(|((x, y), z)| x + y + z).collect()
    }
}

/// Number of f32 lanes per vectorized chunk (8 × f32 = one 256-bit
/// register — AVX2 on x86-64, two NEON ops on aarch64).
const LANES: usize = 8;

/// Chunked, autovectorization-friendly reducer: explicit 8-wide chunks
/// over fixed-size `[f32; 8]` views (no bounds checks in the hot loop, no
/// unstable features) plus a scalar remainder tail. Elementwise adds in
/// the same left-to-right association as [`NativeReducer`], so results are
/// bit-identical — including NaN and −0.0 propagation (the tests pin
/// this at every chunk-boundary size). The in-place `_assign` overrides
/// skip the per-call allocation entirely.
pub struct VectorReducer;

impl VectorReducer {
    #[inline]
    fn add2_in(acc: &mut [f32], b: &[f32]) {
        assert_eq!(acc.len(), b.len(), "reducer operand lengths");
        let mut ai = acc.chunks_exact_mut(LANES);
        let mut bi = b.chunks_exact(LANES);
        for (ca, cb) in ai.by_ref().zip(bi.by_ref()) {
            let ca: &mut [f32; LANES] = ca.try_into().expect("exact chunk");
            let cb: &[f32; LANES] = cb.try_into().expect("exact chunk");
            for (x, y) in ca.iter_mut().zip(cb) {
                *x += *y;
            }
        }
        for (x, y) in ai.into_remainder().iter_mut().zip(bi.remainder()) {
            *x += *y;
        }
    }

    #[inline]
    fn add3_in(acc: &mut [f32], b: &[f32], c: &[f32]) {
        assert_eq!(acc.len(), b.len(), "reducer operand lengths");
        assert_eq!(acc.len(), c.len(), "reducer operand lengths");
        let mut ai = acc.chunks_exact_mut(LANES);
        let mut bi = b.chunks_exact(LANES);
        let mut ci = c.chunks_exact(LANES);
        for ((ca, cb), cc) in ai.by_ref().zip(bi.by_ref()).zip(ci.by_ref()) {
            let ca: &mut [f32; LANES] = ca.try_into().expect("exact chunk");
            let cb: &[f32; LANES] = cb.try_into().expect("exact chunk");
            let cc: &[f32; LANES] = cc.try_into().expect("exact chunk");
            for ((x, y), z) in ca.iter_mut().zip(cb).zip(cc) {
                *x = *x + *y + *z;
            }
        }
        for ((x, y), z) in
            ai.into_remainder().iter_mut().zip(bi.remainder()).zip(ci.remainder())
        {
            *x = *x + *y + *z;
        }
    }
}

impl Reducer for VectorReducer {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut out = a.to_vec();
        VectorReducer::add2_in(&mut out, b);
        out
    }
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        let mut out = a.to_vec();
        VectorReducer::add3_in(&mut out, b, c);
        out
    }
    fn add2_assign(&self, acc: &mut Vec<f32>, b: &[f32]) {
        VectorReducer::add2_in(acc, b);
    }
    fn add3_assign(&self, acc: &mut Vec<f32>, b: &[f32], c: &[f32]) {
        VectorReducer::add3_in(acc, b, c);
    }
}

impl Reducer for crate::runtime::Runtime {
    fn add2(&self, a: &[f32], b: &[f32]) -> Vec<f32> {
        self.reduce2(a, b).expect("pjrt reduce2")
    }
    fn add3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Vec<f32> {
        self.reduce3(a, b, c).expect("pjrt reduce3")
    }
}

/// One stored aggregate: the partial sum over `contrib` for one block.
#[derive(Clone, Debug)]
struct Atom {
    contrib: BlockSet,
    data: Vec<f32>,
}

/// Reducer-call totals for one [`run_allreduce`]: plain local integers
/// bumped alongside each dispatch, flushed to the `exec.reduce.*` metrics
/// once per run — the observability plane never touches the f32 data.
#[derive(Default)]
struct ReduceCounts {
    add2: u64,
    add3: u64,
}

/// Sum a list of vectors with the reducer, preferring 3-way joint
/// reductions (the Trivance fast path). Accumulates in place via the
/// `_assign` face — one allocation (the initial clone) per call, and the
/// exact left-to-right association the seed used: `((p0 + p1) + p2) + …`.
fn sum_all(reducer: &dyn Reducer, parts: &[&Vec<f32>], counts: &mut ReduceCounts) -> Vec<f32> {
    assert!(!parts.is_empty());
    let mut acc: Vec<f32> = parts[0].clone();
    let mut i = 1;
    while i < parts.len() {
        if i + 1 < parts.len() {
            counts.add3 += 1;
            reducer.add3_assign(&mut acc, parts[i], parts[i + 1]);
            i += 2;
        } else {
            counts.add2 += 1;
            reducer.add2_assign(&mut acc, parts[i]);
            i += 1;
        }
    }
    acc
}

/// Execute `schedule` on per-node input vectors. `inputs[r]` must have
/// length `n_blocks · block_len`. Returns each node's final vector.
///
/// Panics if the schedule violates exact-cover/disjointness — schedules
/// must come from the validated registry.
pub fn run_allreduce(
    schedule: &Schedule,
    inputs: &[Vec<f32>],
    block_len: usize,
    reducer: &dyn Reducer,
) -> Vec<Vec<f32>> {
    let n = schedule.n as usize;
    let nb = schedule.n_blocks as usize;
    assert_eq!(inputs.len(), n, "one input vector per node");
    for (r, v) in inputs.iter().enumerate() {
        assert_eq!(v.len(), nb * block_len, "input {r} length");
    }

    let mut counts = ReduceCounts::default();

    // state[node][block] = atoms
    let mut state: Vec<Vec<Vec<Atom>>> = inputs
        .iter()
        .enumerate()
        .map(|(r, v)| {
            (0..nb)
                .map(|b| {
                    vec![Atom {
                        contrib: BlockSet::singleton(r as u32, schedule.n),
                        data: v[b * block_len..(b + 1) * block_len].to_vec(),
                    }]
                })
                .collect()
        })
        .collect();

    for (k, step) in schedule.steps.iter().enumerate() {
        // Phase 1: materialize payloads against start-of-step state.
        // payloads: (dst, block, kind, contrib, data)
        let mut deliveries: Vec<(usize, usize, Kind, BlockSet, Vec<f32>)> = Vec::new();
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                for piece in &snd.pieces {
                    for b in piece.blocks.iter() {
                        let cell = &state[src][b as usize];
                        match piece.kind {
                            Kind::Reduce => {
                                let parts: Vec<&Vec<f32>> = cell
                                    .iter()
                                    .filter(|a| piece.contrib.is_superset(&a.contrib))
                                    .map(|a| &a.data)
                                    .collect();
                                let got: u64 = cell
                                    .iter()
                                    .filter(|a| piece.contrib.is_superset(&a.contrib))
                                    .map(|a| a.contrib.len())
                                    .sum();
                                assert_eq!(
                                    got,
                                    piece.contrib.len(),
                                    "step {k}: {src}->{}: block {b}: contrib {:?} is not an \
                                     exact atom cover",
                                    snd.to,
                                    piece.contrib
                                );
                                let data = sum_all(reducer, &parts, &mut counts);
                                deliveries.push((
                                    snd.to as usize,
                                    b as usize,
                                    Kind::Reduce,
                                    piece.contrib.clone(),
                                    data,
                                ));
                            }
                            Kind::Set => {
                                let total: u64 = cell.iter().map(|a| a.contrib.len()).sum();
                                assert_eq!(
                                    total, schedule.n as u64,
                                    "step {k}: {src}->{}: Set of incomplete block {b}",
                                    snd.to
                                );
                                let parts: Vec<&Vec<f32>> = cell.iter().map(|a| &a.data).collect();
                                let data = sum_all(reducer, &parts, &mut counts);
                                deliveries.push((
                                    snd.to as usize,
                                    b as usize,
                                    Kind::Set,
                                    BlockSet::full(schedule.n),
                                    data,
                                ));
                            }
                        }
                    }
                }
            }
        }
        // Phase 2: apply.
        for (dst, b, kind, contrib, data) in deliveries {
            match kind {
                Kind::Reduce => state[dst][b].push(Atom { contrib, data }),
                Kind::Set => state[dst][b] = vec![Atom { contrib, data }],
            }
        }
    }

    // Collapse: every node, every block must have full coverage.
    let outputs: Vec<Vec<f32>> = state
        .into_iter()
        .enumerate()
        .map(|(r, node)| {
            let mut out = Vec::with_capacity(nb * block_len);
            for (b, cell) in node.into_iter().enumerate() {
                let total: u64 = cell.iter().map(|a| a.contrib.len()).sum();
                assert_eq!(
                    total, schedule.n as u64,
                    "node {r} block {b}: incomplete coverage"
                );
                let parts: Vec<&Vec<f32>> = cell.iter().map(|a| &a.data).collect();
                out.extend_from_slice(&sum_all(reducer, &parts, &mut counts));
            }
            out
        })
        .collect();

    crate::obs::metrics::counters_add(&[
        ("exec.runs", 1),
        ("exec.reduce.add2_calls", counts.add2),
        ("exec.reduce.add3_calls", counts.add3),
    ]);
    outputs
}

/// Build random inputs, run the schedule, and compare every node's result
/// against the reference global sum. Returns the max absolute error.
pub fn verify_allreduce(
    schedule: &Schedule,
    block_len: usize,
    seed: u64,
    reducer: &dyn Reducer,
) -> f64 {
    let n = schedule.n as usize;
    let nb = schedule.n_blocks as usize;
    let mut rng = crate::util::SplitMix64::new(seed);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..nb * block_len).map(|_| rng.f32() * 2.0 - 1.0).collect())
        .collect();
    let mut expect = vec![0f64; nb * block_len];
    for v in &inputs {
        for (e, x) in expect.iter_mut().zip(v) {
            *e += *x as f64;
        }
    }
    let results = run_allreduce(schedule, &inputs, block_len, reducer);
    let mut max_err = 0f64;
    for res in &results {
        for (got, want) in res.iter().zip(&expect) {
            max_err = max_err.max((*got as f64 - want).abs());
        }
    }
    max_err
}

/// Error tolerance for f32 summation over n contributors.
pub fn f32_sum_tolerance(n: u32) -> f64 {
    1e-4 * (n as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::{build, Algo, Variant};
    use crate::topology::Torus;

    #[test]
    fn trivance_ring9_numerics() {
        let t = Torus::ring(9);
        for variant in Variant::ALL {
            let b = build(Algo::Trivance, variant, &t).unwrap();
            let err = verify_allreduce(&b.exec, 8, 42, &NativeReducer);
            assert!(err < f32_sum_tolerance(9), "{variant:?}: err {err}");
        }
    }

    #[test]
    fn all_algorithms_ring8_numerics() {
        let t = Torus::ring(8);
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 4, 7, &NativeReducer);
                assert!(err < f32_sum_tolerance(8), "{algo:?} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn trivance_arbitrary_n_numerics() {
        for n in [5u32, 7, 11, 26, 32] {
            let t = Torus::ring(n);
            for variant in Variant::ALL {
                let b = build(Algo::Trivance, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 2, n as u64, &NativeReducer);
                assert!(err < f32_sum_tolerance(n), "n={n} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn torus_3x3_numerics() {
        let t = Torus::new(&[3, 3]);
        for algo in [Algo::Trivance, Algo::Bruck, Algo::Bucket] {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                let err = verify_allreduce(&b.exec, 2, 3, &NativeReducer);
                assert!(err < f32_sum_tolerance(9), "{algo:?} {variant:?}: err {err}");
            }
        }
    }

    #[test]
    fn padded_swing_numerics() {
        // swing on n=6 pads to 8 virtual nodes; executor runs the virtual
        // schedule (real nodes take their virtual result).
        let t = Torus::ring(6);
        let b = build(Algo::Swing, Variant::Bandwidth, &t).unwrap();
        assert!(b.padded);
        let err = verify_allreduce(&b.exec, 2, 3, &NativeReducer);
        assert!(err < f32_sum_tolerance(8), "err {err}");
    }

    #[test]
    #[should_panic(expected = "incomplete coverage")]
    fn incomplete_schedule_panics() {
        let t = Torus::ring(9);
        let mut b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        // drop the last step: coverage must fail loudly
        b.exec.steps.pop();
        let _ = verify_allreduce(&b.exec, 2, 1, &NativeReducer);
    }

    /// Adversarial operand generator: mostly ordinary values, salted with
    /// NaN, ±0.0, ±inf, subnormals, and magnitude cliffs — the inputs
    /// where a re-associated kernel would diverge bitwise.
    fn adversarial(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = crate::util::SplitMix64::new(seed);
        (0..len)
            .map(|i| match (i as u64).wrapping_add(rng.next_u64()) % 11 {
                0 => f32::NAN,
                1 => -0.0,
                2 => 0.0,
                3 => f32::INFINITY,
                4 => f32::NEG_INFINITY,
                5 => 1e-40,            // subnormal
                6 => -1e-40,
                7 => 3.4e38,           // near-max (inf on doubling)
                8 => 1e-8,             // vanishes against O(1) addends
                _ => rng.f32() * 2.0 - 1.0,
            })
            .collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: element {i}: {x} vs {y} (bits differ)"
            );
        }
    }

    #[test]
    fn vector_reducer_is_bit_identical_at_every_chunk_boundary() {
        // chunk-boundary sizes: below / at / above one 8-lane chunk and
        // the 4096-element page used by the benches — with NaN, −0.0, inf,
        // and subnormal operands, vector output must equal the scalar
        // oracle bit for bit (allocating AND in-place faces)
        for len in [1usize, 7, 8, 9, 4095, 4096, 4097] {
            let a = adversarial(len, 0xA0 + len as u64);
            let b = adversarial(len, 0xB0 + len as u64);
            let c = adversarial(len, 0xC0 + len as u64);
            let s2 = NativeReducer.add2(&a, &b);
            let v2 = VectorReducer.add2(&a, &b);
            assert_bits_eq(&s2, &v2, &format!("add2 len={len}"));
            let s3 = NativeReducer.add3(&a, &b, &c);
            let v3 = VectorReducer.add3(&a, &b, &c);
            assert_bits_eq(&s3, &v3, &format!("add3 len={len}"));
            let mut acc2 = a.clone();
            VectorReducer.add2_assign(&mut acc2, &b);
            assert_bits_eq(&s2, &acc2, &format!("add2_assign len={len}"));
            let mut acc3 = a.clone();
            VectorReducer.add3_assign(&mut acc3, &b, &c);
            assert_bits_eq(&s3, &acc3, &format!("add3_assign len={len}"));
            // NaN propagation is positional: a NaN operand yields NaN out
            for (i, x) in a.iter().enumerate() {
                if x.is_nan() {
                    assert!(v2[i].is_nan() && v3[i].is_nan(), "len={len} elem {i}");
                }
            }
        }
    }

    #[test]
    fn negative_zero_signs_match_the_scalar_oracle() {
        // (−0.0) + (−0.0) = −0.0 but (−0.0) + 0.0 = +0.0: sign handling
        // must be the hardware's, not a shortcut's — at sizes straddling
        // the chunk tail so both code paths see every pattern
        for len in [8usize, 9, 16, 23] {
            let patterns = [(-0.0f32, -0.0f32), (-0.0, 0.0), (0.0, -0.0), (0.0, 0.0)];
            for (pa, pb) in patterns {
                let a = vec![pa; len];
                let b = vec![pb; len];
                let s = NativeReducer.add2(&a, &b);
                let v = VectorReducer.add2(&a, &b);
                assert_bits_eq(&s, &v, &format!("len={len} {pa:?}+{pb:?}"));
                let s3 = NativeReducer.add3(&a, &b, &a);
                let v3 = VectorReducer.add3(&a, &b, &a);
                assert_bits_eq(&s3, &v3, &format!("add3 len={len} {pa:?}+{pb:?}"));
            }
        }
    }

    #[test]
    fn registry_numerics_identical_under_vector_kernel() {
        // the whole-executor claim: running the registry's schedules with
        // the vector kernel reproduces the scalar oracle's max error
        // exactly (elementwise adds in the same association ⇒ identical
        // result vectors ⇒ identical error)
        let t = Torus::ring(8);
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                let scalar = verify_allreduce(&b.exec, 4, 7, &NativeReducer);
                let vector = verify_allreduce(&b.exec, 4, 7, &VectorReducer);
                assert_eq!(
                    scalar.to_bits(),
                    vector.to_bits(),
                    "{algo:?} {variant:?}: scalar {scalar} vs vector {vector}"
                );
                assert!(vector < f32_sum_tolerance(8), "{algo:?} {variant:?}: err {vector}");
            }
        }
    }
}
