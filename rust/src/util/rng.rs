//! SplitMix64 PRNG — deterministic, seedable, dependency-free.
//!
//! Used by tests (property-test inputs), the workload generators, and the
//! executor's random initial vectors. Not cryptographic.

/// SplitMix64 state. Passes BigCrush for the purposes of test-data
/// generation; one u64 of state, closed-form jump.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound > 0`.
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        // Multiply-shift rejection-free mapping (slight bias negligible for
        // test purposes; bounds here are tiny relative to 2^64).
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Shuffle a slice in place (Fisher-Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
        }
    }

    #[test]
    fn f32_unit_interval() {
        let mut r = SplitMix64::new(9);
        for _ in 0..1000 {
            let v = r.f32();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = SplitMix64::new(7);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..50).collect::<Vec<_>>());
    }
}
