//! Tiny randomized property-test driver (proptest is not vendored).
//!
//! `check(seed, cases, gen, prop)` draws `cases` inputs from `gen` and
//! asserts `prop` on each; failures report the failing case and the seed so
//! the exact input reproduces deterministically. No shrinking — generators
//! here draw from small structured spaces (node counts, message sizes) where
//! the raw failing case is already readable.

use super::rng::SplitMix64;
use std::fmt::Debug;

/// Run a randomized property: draws `cases` values and asserts the property.
pub fn check<T: Debug>(
    seed: u64,
    cases: u32,
    mut gen: impl FnMut(&mut SplitMix64) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = SplitMix64::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed on case {i}/{cases} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Assert helper returning `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes() {
        check(
            1,
            50,
            |r| r.range(1, 100),
            |&v| {
                if v >= 1 && v <= 100 {
                    Ok(())
                } else {
                    Err(format!("out of range: {v}"))
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_fails_loudly() {
        check(2, 50, |r| r.range(0, 10), |&v| {
            if v < 5 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
