//! Byte-size parsing/formatting and markdown table rendering for the
//! harness output.

/// Format a byte count the way the paper labels its x-axes (32 B, 8 KiB,
/// 128 MiB, ...).
pub fn bytes(b: u64) -> String {
    const UNITS: [(&str, u64); 4] = [
        ("GiB", 1 << 30),
        ("MiB", 1 << 20),
        ("KiB", 1 << 10),
        ("B", 1),
    ];
    for (name, unit) in UNITS {
        if b >= unit && b % unit == 0 {
            return format!("{} {}", b / unit, name);
        }
    }
    for (name, unit) in UNITS {
        if b >= unit {
            return format!("{:.1} {}", b as f64 / unit as f64, name);
        }
    }
    format!("{b} B")
}

/// Parse "8KiB", "8 KiB", "32B", "1.5MiB", plain integers.
pub fn parse_bytes(s: &str) -> Option<u64> {
    let s = s.trim();
    let split = s.find(|c: char| !c.is_ascii_digit() && c != '.')?;
    let (num, unit) = s.split_at(split);
    let num: f64 = num.parse().ok()?;
    let mult = match unit.trim().to_ascii_lowercase().as_str() {
        "b" => 1u64,
        "k" | "kb" | "kib" => 1 << 10,
        "m" | "mb" | "mib" => 1 << 20,
        "g" | "gb" | "gib" => 1 << 30,
        _ => return None,
    };
    Some((num * mult as f64).round() as u64)
}

/// Parse a plain integer or a byte string.
pub fn parse_size(s: &str) -> Option<u64> {
    s.trim().parse::<u64>().ok().or_else(|| parse_bytes(s))
}

/// Format a duration in seconds the way the harness reports completion
/// times (ns/µs/ms/s with 3 significant digits).
pub fn secs(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.1} ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2} µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.3} ms", t * 1e3)
    } else {
        format!("{t:.3} s")
    }
}

/// Minimal markdown table renderer: rows of equal length, first row is the
/// header.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: Vec<S>) -> Self {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, row: Vec<S>) {
        let row: Vec<String> = row.into_iter().map(Into::into).collect();
        assert_eq!(row.len(), self.header.len(), "table row arity mismatch");
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths = vec![0usize; ncol];
        for row in std::iter::once(&self.header).chain(self.rows.iter()) {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let mut out = String::new();
        let fmt_row = |row: &[String]| -> String {
            let cells: Vec<String> = row
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:w$}", c, w = widths[i]))
                .collect();
            format!("| {} |\n", cells.join(" | "))
        };
        out.push_str(&fmt_row(&self.header));
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&format!("| {} |\n", sep.join(" | ")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }

    /// CSV rendering for machine consumption.
    pub fn render_csv(&self) -> String {
        let mut out = String::new();
        for row in std::iter::once(&self.header).chain(self.rows.iter()) {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_roundtrip() {
        assert_eq!(bytes(32), "32 B");
        assert_eq!(bytes(8 << 10), "8 KiB");
        assert_eq!(bytes(128 << 20), "128 MiB");
        assert_eq!(parse_bytes("8KiB"), Some(8 << 10));
        assert_eq!(parse_bytes("32 B"), Some(32));
        assert_eq!(parse_bytes("128MiB"), Some(128 << 20));
        assert_eq!(parse_bytes("1.5 KiB"), Some(1536));
        assert_eq!(parse_size("4096"), Some(4096));
    }

    #[test]
    fn secs_scales() {
        assert!(secs(1.5e-6).contains("µs"));
        assert!(secs(2e-9).contains("ns"));
        assert!(secs(0.5).contains("ms"));
        assert!(secs(2.0).contains("s"));
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "22"]);
        let s = t.render();
        assert!(s.contains("| a | b  |"));
        assert!(s.contains("| 1 | 22 |"));
        assert_eq!(t.render_csv(), "a,b\n1,22\n");
    }
}
