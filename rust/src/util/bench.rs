//! Minimal benchmark harness (criterion is not in the vendored registry).
//!
//! Each `rust/benches/*.rs` target uses `harness = false` and drives this:
//! warmup, repeated timed runs, median/mean/min reporting, and an output
//! format stable enough to diff across optimization iterations
//! (EXPERIMENTS.md §Perf).

use std::time::Instant;

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl BenchStats {
    pub fn report(&self) -> String {
        format!(
            "{:<48} iters={:>3}  mean={:>12}  median={:>12}  min={:>12}",
            self.name,
            self.iters,
            super::fmt::secs(self.mean_s),
            super::fmt::secs(self.median_s),
            super::fmt::secs(self.min_s),
        )
    }
}

/// Benchmark runner: fixed warmup count then `iters` timed iterations.
pub struct Bencher {
    warmup: u32,
    iters: u32,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 2, iters: 7 }
    }
}

impl Bencher {
    pub fn new(warmup: u32, iters: u32) -> Self {
        assert!(iters >= 1);
        Bencher { warmup, iters }
    }

    /// Runs `f`, timing each call; `f` should return something observable to
    /// keep the optimizer honest (the value is black-boxed).
    pub fn run<T>(&self, name: &str, mut f: impl FnMut() -> T) -> BenchStats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
        }
        times.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iters: self.iters,
            mean_s: mean,
            median_s: times[times.len() / 2],
            min_s: times[0],
            max_s: *times.last().unwrap(),
        };
        println!("{}", stats.report());
        stats
    }
}

/// Opaque identity to prevent the optimizer from deleting benchmarked work.
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_orders() {
        let b = Bencher::new(0, 5);
        let s = b.run("noop", || 1 + 1);
        assert_eq!(s.iters, 5);
        assert!(s.min_s <= s.median_s && s.median_s <= s.max_s);
    }
}
