//! Minimal JSON parser (the vendored registry has no serde).
//!
//! The crate already *writes* JSON by hand (`BENCH_sweep.json`, the tuner's
//! decision tables); this module is the matching reader so artifacts can be
//! loaded back (e.g. `trivance recommend --table tuner_table.json`). It is a
//! strict recursive-descent parser over the subset the writers emit —
//! objects, arrays, double-quoted strings with the standard escapes,
//! numbers parsed as `f64` via `str::parse` (round-trip-exact for every
//! value Rust's own float formatter printed — `-0.0` and extreme exponents
//! included, property-pinned below — and for integers below 2^53),
//! `true`/`false`/`null` — with a depth limit instead of unbounded
//! recursion. Bare `NaN`/`Infinity` tokens are rejected with a targeted
//! error before they can reach Rust's (permissive) float parser. It is **not** a general-purpose validator: surrogate pairs in
//! `\u` escapes are passed through as-is and duplicate object keys are kept
//! in order (last `get` match wins is *not* implemented; `get` returns the
//! first).

/// Maximum nesting depth accepted by the parser (artifacts nest ~5 deep).
const MAX_DEPTH: usize = 64;

/// A parsed JSON value. Object member order is preserved.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// First member of an object by key (`None` on missing key or non-object).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// Number as u64 (exact only below 2^53; values the writers emit).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(v) if *v >= 0.0 && v.fract() == 0.0 && *v <= 9.007_199_254_740_992e15 => {
                Some(*v as u64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s.as_str()),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a JSON document; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json, String> {
    let bytes = input.as_bytes();
    let mut pos = 0usize;
    let value = parse_value(bytes, &mut pos, 0)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing data at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(bytes: &[u8], pos: &mut usize) {
    while *pos < bytes.len() && matches!(bytes[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(bytes: &[u8], pos: &mut usize, b: u8) -> Result<(), String> {
    if *pos < bytes.len() && bytes[*pos] == b {
        *pos += 1;
        Ok(())
    } else {
        Err(format!(
            "expected {:?} at byte {} (found {:?})",
            b as char,
            *pos,
            bytes.get(*pos).map(|&c| c as char)
        ))
    }
}

fn parse_value(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    if depth > MAX_DEPTH {
        return Err(format!("nesting deeper than {MAX_DEPTH}"));
    }
    skip_ws(bytes, pos);
    match bytes.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_obj(bytes, pos, depth),
        Some(b'[') => parse_arr(bytes, pos, depth),
        Some(b'"') => Ok(Json::Str(parse_string(bytes, pos)?)),
        Some(b't') => parse_lit(bytes, pos, "true", Json::Bool(true)),
        Some(b'f') => parse_lit(bytes, pos, "false", Json::Bool(false)),
        Some(b'n') => parse_lit(bytes, pos, "null", Json::Null),
        // Bare IEEE tokens some writers emit are NOT JSON — reject them
        // with a targeted message instead of the generic "bad number" the
        // digit scanner would produce (Rust's f64 parser would otherwise
        // happily accept "NaN"/"inf" if they reached it).
        Some(b'N') | Some(b'I') | Some(b'i') => Err(format!(
            "bare NaN/Infinity at byte {} — JSON has no non-finite numbers",
            *pos
        )),
        Some(_) => parse_number(bytes, pos),
    }
}

fn parse_lit(bytes: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, String> {
    if bytes[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_number(bytes: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    if matches!(bytes.get(*pos), Some(b'-')) {
        *pos += 1;
        if matches!(bytes.get(*pos), Some(b'N') | Some(b'I') | Some(b'i')) {
            return Err(format!(
                "bare NaN/Infinity at byte {start} — JSON has no non-finite numbers"
            ));
        }
    }
    while *pos < bytes.len()
        && matches!(bytes[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let text = std::str::from_utf8(&bytes[start..*pos]).map_err(|e| e.to_string())?;
    let v: f64 = text
        .parse()
        .map_err(|e| format!("bad number {text:?} at byte {start}: {e}"))?;
    if !v.is_finite() {
        return Err(format!("non-finite number {text:?} at byte {start}"));
    }
    Ok(Json::Num(v))
}

fn parse_string(bytes: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(bytes, pos, b'"')?;
    let mut out = String::new();
    loop {
        match bytes.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match bytes.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = bytes
                            .get(*pos + 1..*pos + 5)
                            .ok_or("truncated \\u escape")?;
                        let hex = std::str::from_utf8(hex).map_err(|e| e.to_string())?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|e| format!("bad \\u: {e}"))?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // copy one UTF-8 scalar (multi-byte sequences pass through)
                let s = std::str::from_utf8(&bytes[*pos..]).map_err(|e| e.to_string())?;
                let c = s.chars().next().ok_or("unterminated string")?;
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    expect(bytes, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(bytes, pos);
    if matches!(bytes.get(*pos), Some(b']')) {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(bytes, pos, depth + 1)?);
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            other => return Err(format!("expected ',' or ']', found {other:?}")),
        }
    }
}

fn parse_obj(bytes: &[u8], pos: &mut usize, depth: usize) -> Result<Json, String> {
    expect(bytes, pos, b'{')?;
    let mut members = Vec::new();
    skip_ws(bytes, pos);
    if matches!(bytes.get(*pos), Some(b'}')) {
        *pos += 1;
        return Ok(Json::Obj(members));
    }
    loop {
        skip_ws(bytes, pos);
        let key = parse_string(bytes, pos)?;
        skip_ws(bytes, pos);
        expect(bytes, pos, b':')?;
        let value = parse_value(bytes, pos, depth + 1)?;
        members.push((key, value));
        skip_ws(bytes, pos);
        match bytes.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(members));
            }
            other => return Err(format!("expected ',' or '}}', found {other:?}")),
        }
    }
}

/// Escape a string for embedding in hand-rolled JSON output (the inverse of
/// [`parse_string`] for the characters the writers can produce).
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e-6").unwrap(), Json::Num(-1.5e-6));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": "c\"d"}], "e": null}"#).unwrap();
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[1].as_u64(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c\"d"));
        assert_eq!(v.get("e"), Some(&Json::Null));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn floats_round_trip_through_display() {
        // the writers print with Rust's shortest round-trip formatter; the
        // reader must recover the bits exactly
        for v in [1.5e-6, 8e11, 0.088, f64::MIN_POSITIVE, 123456789.123456789] {
            let s = format!("{v}");
            let e = format!("{v:e}");
            assert_eq!(parse(&s).unwrap().as_f64().unwrap().to_bits(), v.to_bits());
            assert_eq!(parse(&e).unwrap().as_f64().unwrap().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("1e999").is_err(), "non-finite numbers rejected");
        let deep = "[".repeat(100) + &"]".repeat(100);
        assert!(parse(&deep).is_err(), "depth limit enforced");
    }

    #[test]
    fn nesting_depth_boundary_is_exact_and_error_is_targeted() {
        // ISSUE 7 satellite: pin the exact MAX_DEPTH boundary (mirrored in
        // tools/pysim/eval_json.py). A scalar payload wrapped in exactly
        // MAX_DEPTH brackets parses; one more level must fail with the
        // targeted depth error, not a stack overflow or a generic message.
        let ok = "[".repeat(MAX_DEPTH) + "1" + &"]".repeat(MAX_DEPTH);
        let v = parse(&ok).unwrap_or_else(|e| panic!("{MAX_DEPTH} levels must parse: {e}"));
        let mut cur = &v;
        for _ in 0..MAX_DEPTH {
            cur = &cur.as_arr().unwrap()[0];
        }
        assert_eq!(cur.as_f64(), Some(1.0));
        let too_deep = "[".repeat(MAX_DEPTH + 1) + "1" + &"]".repeat(MAX_DEPTH + 1);
        let err = parse(&too_deep).unwrap_err();
        assert!(
            err.contains("nesting deeper than"),
            "error should name the depth limit, got {err:?}"
        );
        // same boundary through object nesting
        let obj_ok = "{\"k\": ".repeat(MAX_DEPTH / 2) + "1" + &"}".repeat(MAX_DEPTH / 2);
        parse(&obj_ok).unwrap_or_else(|e| panic!("object nesting within the limit: {e}"));
        let obj_deep = "{\"k\": ".repeat(MAX_DEPTH + 1) + "1" + &"}".repeat(MAX_DEPTH + 1);
        let err = parse(&obj_deep).unwrap_err();
        assert!(err.contains("nesting deeper than"), "{err:?}");
    }

    #[test]
    fn bare_nan_and_infinity_tokens_rejected_with_clear_error() {
        // Rust's f64 parser accepts "NaN"/"inf"/"Infinity", so these must
        // never reach it — and the error must say what happened, not the
        // generic empty-number message.
        for doc in [
            "NaN", "-NaN", "Infinity", "-Infinity", "inf", "-inf",
            "[1, NaN]", "{\"a\": Infinity}", "{\"a\": -Infinity}",
        ] {
            let err = parse(doc).unwrap_err();
            assert!(
                err.contains("NaN/Infinity"),
                "{doc:?}: error should name the token class, got {err:?}"
            );
        }
    }

    #[test]
    fn negative_zero_and_extreme_exponents_round_trip_bit_exactly() {
        // -0.0 must keep its sign bit through the round trip
        let z = parse("-0.0").unwrap().as_f64().unwrap();
        assert_eq!(z.to_bits(), (-0.0f64).to_bits());
        assert_ne!(z.to_bits(), 0.0f64.to_bits());
        // the writer prints -0.0 as "-0": still sign-exact on re-parse
        assert_eq!(
            parse(&format!("{}", -0.0f64)).unwrap().as_f64().unwrap().to_bits(),
            (-0.0f64).to_bits()
        );
        // extreme magnitudes: largest/smallest normals and subnormals
        for v in [
            f64::MAX,
            -f64::MAX,
            f64::MIN_POSITIVE,
            5e-324,            // smallest subnormal
            -5e-324,
            1.7976931348623157e308,
            2.2250738585072014e-308,
        ] {
            for s in [format!("{v}"), format!("{v:e}")] {
                assert_eq!(
                    parse(&s).unwrap().as_f64().unwrap().to_bits(),
                    v.to_bits(),
                    "{s}"
                );
            }
        }
    }

    #[test]
    fn property_random_finite_floats_round_trip_bit_exactly() {
        // random bit patterns (filtered to finite values) must survive
        // write -> parse with the exact same bits — the invariant the
        // tuner tables and BENCH records rely on
        crate::util::prop::check(
            0x150B_0001,
            500,
            |r| f64::from_bits(r.next_u64()),
            |&v| {
                if !v.is_finite() {
                    return Ok(()); // writers never emit non-finite values
                }
                for s in [format!("{v}"), format!("{v:e}")] {
                    let got = parse(&s)
                        .map_err(|e| format!("{s}: {e}"))?
                        .as_f64()
                        .ok_or_else(|| format!("{s}: not a number"))?;
                    if got.to_bits() != v.to_bits() {
                        return Err(format!("{s}: {got} != {v}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_random_documents_round_trip() {
        // random nested documents rendered with the writers' conventions
        // must parse back equal (and re-render to a fixpoint)
        fn gen_value(r: &mut crate::util::SplitMix64, depth: u32) -> Json {
            match if depth >= 3 { r.below(4) } else { r.below(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.below(2) == 1),
                2 => {
                    // finite doubles, occasionally integral / signed-zero
                    let v = match r.below(4) {
                        0 => r.below(1 << 20) as f64,
                        1 => -0.0,
                        _ => loop {
                            let v = f64::from_bits(r.next_u64());
                            if v.is_finite() {
                                break v;
                            }
                        },
                    };
                    Json::Num(v)
                }
                3 => Json::Str(
                    (0..r.below(8))
                        .map(|_| *r.choose(&['a', '"', '\\', '\n', '\t', 'µ', 'z']))
                        .collect(),
                ),
                4 => Json::Arr((0..r.below(4)).map(|_| gen_value(r, depth + 1)).collect()),
                _ => Json::Obj(
                    (0..r.below(4))
                        .map(|i| (format!("k{i}"), gen_value(r, depth + 1)))
                        .collect(),
                ),
            }
        }
        fn render(v: &Json) -> String {
            match v {
                Json::Null => "null".into(),
                Json::Bool(b) => b.to_string(),
                Json::Num(x) => format!("{x}"),
                Json::Str(s) => format!("\"{}\"", escape(s)),
                Json::Arr(items) => format!(
                    "[{}]",
                    items.iter().map(render).collect::<Vec<_>>().join(", ")
                ),
                Json::Obj(members) => format!(
                    "{{{}}}",
                    members
                        .iter()
                        .map(|(k, v)| format!("\"{}\": {}", escape(k), render(v)))
                        .collect::<Vec<_>>()
                        .join(", ")
                ),
            }
        }
        crate::util::prop::check(
            0x150B_0002,
            200,
            |r| gen_value(r, 0),
            |v| {
                let doc = render(v);
                let parsed = parse(&doc).map_err(|e| format!("{doc}: {e}"))?;
                // Num(-0.0) == Num(0.0) under f64 PartialEq, so compare the
                // re-render (which is bit-faithful) as the fixpoint check
                if render(&parsed) != doc {
                    return Err(format!("not a fixpoint: {doc} -> {}", render(&parsed)));
                }
                if parsed != *v {
                    return Err(format!("value changed through {doc}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn escape_round_trips() {
        let s = "a\"b\\c\nd\te\u{1}";
        let parsed = parse(&format!("\"{}\"", escape(s))).unwrap();
        assert_eq!(parsed.as_str(), Some(s));
    }

    #[test]
    fn bench_sweep_shape_parses() {
        // the existing hand-rolled writer's output must be readable
        let doc = r#"{
  "schema": "trivance.bench_sweep.v2",
  "topo": [3, 3],
  "build_wall_s": 1.5e-3,
  "points": [
    {"algo": "trivance", "variant": "L", "size_bytes": 32, "completion_s": 4.5e-6, "wall_s": 1e-5}
  ],
  "scenarios": []
}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("schema").unwrap().as_str(), Some("trivance.bench_sweep.v2"));
        assert_eq!(v.get("topo").unwrap().as_arr().unwrap()[0].as_u64(), Some(3));
        let p = &v.get("points").unwrap().as_arr().unwrap()[0];
        assert_eq!(p.get("size_bytes").unwrap().as_u64(), Some(32));
    }
}
