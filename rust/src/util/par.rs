//! Minimal data-parallel map (rayon is not in the offline vendored
//! registry; this is the dependency-free substitute the sweep engine runs
//! on).
//!
//! [`par_map`] evaluates `f` over a slice on a scoped thread pool with an
//! atomic work-stealing cursor (dynamic load balancing — sweep points vary
//! by orders of magnitude in cost between 32 B and 128 MiB). Results are
//! returned **in input order** regardless of scheduling, so parallel runs
//! are deterministic and bit-identical to `threads == 1`: each point's
//! computation is untouched, only the iteration is distributed. A worker
//! panic propagates to the caller after the scope joins.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Hardware parallelism (1 when unavailable).
pub fn available_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a user-facing thread-count knob: `0` = auto (all cores).
pub fn resolve_threads(threads: usize) -> usize {
    if threads == 0 {
        available_threads()
    } else {
        threads
    }
}

/// Map `f` over `items` on up to `threads` scoped threads (`0` = auto).
/// `f` receives `(index, &item)`; the result vector is in input order.
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let threads = resolve_threads(threads).min(items.len().max(1));
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, x)| f(i, x)).collect();
    }
    let cursor = AtomicUsize::new(0);
    let chunks: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut out: Vec<(usize, R)> = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= items.len() {
                            break;
                        }
                        out.push((i, f(i, &items[i])));
                    }
                    out
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("par_map worker panicked"))
            .collect()
    });
    let mut slots: Vec<Option<R>> = Vec::with_capacity(items.len());
    slots.resize_with(items.len(), || None);
    for (i, r) in chunks.into_iter().flatten() {
        debug_assert!(slots[i].is_none());
        slots[i] = Some(r);
    }
    slots
        .into_iter()
        .map(|s| s.expect("par_map missed an index"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let xs: Vec<u64> = (0..100).collect();
        let seq = par_map(&xs, 1, |i, &x| x * 2 + i as u64);
        let par = par_map(&xs, 4, |i, &x| x * 2 + i as u64);
        assert_eq!(seq, par);
        assert_eq!(seq[10], 30);
    }

    #[test]
    fn empty_and_single() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, 8, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], 8, |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn auto_threads_resolves() {
        assert!(resolve_threads(0) >= 1);
        assert_eq!(resolve_threads(3), 3);
    }

    #[test]
    fn uneven_work_is_balanced() {
        // heavier items at the front; the atomic cursor must still cover all
        let xs: Vec<u64> = (0..64).rev().collect();
        let out = par_map(&xs, 8, |_, &x| {
            let mut acc = 0u64;
            for i in 0..(x * 100) {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            x
        });
        assert_eq!(out, xs);
    }
}
