//! Small self-contained utilities.
//!
//! The offline vendored registry ships neither `rand`, `criterion`,
//! `proptest`, nor `rayon`, so this module provides the minimal equivalents
//! used across the crate: a SplitMix64 PRNG, a tiny benchmark harness, a
//! randomized property-test driver, a scoped-thread parallel map,
//! table/byte formatting helpers, and a minimal JSON reader matching the
//! hand-rolled writers.

pub mod rng;
pub mod bench;
pub mod fmt;
pub mod json;
pub mod par;
pub mod prop;

pub use rng::SplitMix64;

/// FNV-1a fingerprint accumulator — the one hash behind every persisted
/// fingerprint in the crate ([`crate::net::NetModel::fingerprint`],
/// [`crate::net::Timeline::fingerprint`],
/// [`crate::schedule::rewrite::Fault::fingerprint`], the scenario
/// dynamic-condition fingerprints). Those values live in tuner JSON tables
/// and [`crate::sim::PlanKey`]s, so all producers must share one
/// implementation: a divergent copy would silently break cross-component
/// staleness comparisons.
#[derive(Clone, Copy, Debug)]
pub struct Fnv(u64);

impl Fnv {
    const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const PRIME: u64 = 0x0000_0100_0000_01b3;

    #[allow(clippy::new_without_default)]
    pub fn new() -> Fnv {
        Fnv(Self::OFFSET)
    }

    pub fn mix(&mut self, v: u64) {
        self.0 ^= v;
        self.0 = self.0.wrapping_mul(Self::PRIME);
    }

    /// The accumulated hash with the low bit forced to 1 — for fingerprint
    /// namespaces where `0` is reserved (uniform model, empty timeline,
    /// static scenario).
    pub fn finish_nonzero(self) -> u64 {
        self.0 | 1
    }
}

/// `⌈log_base(n)⌉` for integers (`n >= 1`, `base >= 2`).
pub fn ceil_log(base: u64, n: u64) -> u32 {
    assert!(base >= 2 && n >= 1);
    let mut s = 0;
    let mut v = 1u64;
    while v < n {
        v = v.saturating_mul(base);
        s += 1;
    }
    s
}

/// `⌊log_base(n)⌋` for integers (`n >= 1`, `base >= 2`).
pub fn floor_log(base: u64, n: u64) -> u32 {
    assert!(base >= 2 && n >= 1);
    let mut s = 0;
    let mut v = base;
    while v <= n {
        v = v.saturating_mul(base);
        s += 1;
    }
    s
}

/// Is `n` an exact power of `base`?
pub fn is_power_of(base: u64, n: u64) -> bool {
    n >= 1 && base.pow(floor_log(base, n)) == n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn logs() {
        assert_eq!(ceil_log(3, 1), 0);
        assert_eq!(ceil_log(3, 3), 1);
        assert_eq!(ceil_log(3, 4), 2);
        assert_eq!(ceil_log(3, 9), 2);
        assert_eq!(ceil_log(3, 27), 3);
        assert_eq!(ceil_log(3, 28), 4);
        assert_eq!(floor_log(3, 1), 0);
        assert_eq!(floor_log(3, 2), 0);
        assert_eq!(floor_log(3, 3), 1);
        assert_eq!(floor_log(3, 26), 2);
        assert_eq!(floor_log(3, 27), 3);
        assert_eq!(floor_log(2, 1024), 10);
    }

    #[test]
    fn powers() {
        assert!(is_power_of(3, 1));
        assert!(is_power_of(3, 27));
        assert!(!is_power_of(3, 26));
        assert!(is_power_of(2, 64));
        assert!(!is_power_of(2, 63));
    }
}
