//! Cyclic interval sets over the rank/block space `Z_n`.
//!
//! All collective schedules in this crate describe *which* blocks (or
//! contributor ranks) a message carries as subsets of `{0, .., n-1}` with
//! ring (cyclic) structure. The sets arising from the algorithms in the
//! paper are unions of a handful of contiguous cyclic ranges, so we store
//! them as sorted, disjoint, non-adjacent half-open intervals in linear
//! coordinates; a wrapped range `[s, s+len)` with `s+len > n` is normalized
//! into two linear intervals.

use std::fmt;

/// A set of ranks in `Z_n`, stored as sorted disjoint half-open intervals.
#[derive(Clone, PartialEq, Eq, Hash, Default)]
pub struct BlockSet {
    /// Sorted, disjoint, non-adjacent `[start, end)` intervals, `end <= n`.
    ivs: Vec<(u32, u32)>,
}

impl BlockSet {
    /// The empty set.
    pub fn empty() -> Self {
        BlockSet { ivs: Vec::new() }
    }

    /// The full set `{0, .., n-1}`.
    pub fn full(n: u32) -> Self {
        BlockSet { ivs: vec![(0, n)] }
    }

    /// A single rank.
    pub fn singleton(r: u32, n: u32) -> Self {
        Self::cyc_range(r, 1, n)
    }

    /// The cyclic range of `len` ranks starting at `start` (mod `n`).
    /// `len >= n` yields the full set.
    pub fn cyc_range(start: u32, len: u64, n: u32) -> Self {
        if len == 0 {
            return Self::empty();
        }
        if len >= n as u64 {
            return Self::full(n);
        }
        let len = len as u32;
        let s = start % n;
        if s + len <= n {
            BlockSet { ivs: vec![(s, s + len)] }
        } else {
            // wraps: [s, n) ∪ [0, s+len-n)
            BlockSet { ivs: vec![(0, s + len - n), (s, n)] }
        }
    }

    /// Cyclic range centered at `center` with the given `radius`
    /// (i.e. `2*radius + 1` ranks), mod `n`.
    pub fn cyc_ball(center: i64, radius: u64, n: u32) -> Self {
        let len = 2 * radius + 1;
        let start = (center - radius as i64).rem_euclid(n as i64) as u32;
        Self::cyc_range(start, len, n)
    }

    /// Build from a list of (possibly unsorted/overlapping) half-open
    /// linear intervals with `end <= n`.
    pub fn from_intervals(mut ivs: Vec<(u32, u32)>) -> Self {
        ivs.retain(|&(s, e)| s < e);
        ivs.sort_unstable();
        let mut out: Vec<(u32, u32)> = Vec::with_capacity(ivs.len());
        for (s, e) in ivs {
            match out.last_mut() {
                Some(last) if s <= last.1 => last.1 = last.1.max(e),
                _ => out.push((s, e)),
            }
        }
        BlockSet { ivs: out }
    }

    /// Build from an unsorted list of ranks (deduplicated).
    pub fn from_ranks(ranks: &[u32], n: u32) -> Self {
        let mut v: Vec<u32> = ranks.iter().map(|&r| r % n).collect();
        v.sort_unstable();
        v.dedup();
        let mut ivs = Vec::new();
        let mut i = 0;
        while i < v.len() {
            let s = v[i];
            let mut e = s + 1;
            i += 1;
            while i < v.len() && v[i] == e {
                e += 1;
                i += 1;
            }
            ivs.push((s, e));
        }
        BlockSet { ivs }
    }

    pub fn is_empty(&self) -> bool {
        self.ivs.is_empty()
    }

    /// Number of ranks in the set.
    pub fn len(&self) -> u64 {
        self.ivs.iter().map(|&(s, e)| (e - s) as u64).sum()
    }

    /// Number of linear intervals (the "piece count" a sender needs if it
    /// transmits this set as contiguous runs). Note: two intervals that are
    /// cyclically adjacent across the 0 boundary count as one run.
    pub fn runs(&self, n: u32) -> usize {
        let k = self.ivs.len();
        if k >= 2 && self.ivs[0].0 == 0 && self.ivs[k - 1].1 == n {
            k - 1
        } else {
            k
        }
    }

    pub fn contains(&self, r: u32) -> bool {
        self.ivs.iter().any(|&(s, e)| s <= r && r < e)
    }

    /// Union of two sets.
    pub fn union(&self, other: &BlockSet) -> BlockSet {
        if self.is_empty() {
            return other.clone();
        }
        let mut out = self.clone();
        out.union_with(other);
        out
    }

    /// In-place union: merges `other`'s intervals into this set's buffer
    /// with no intermediate allocation (the hot validator path — every
    /// received piece unions into the receiver's contributor set used to
    /// allocate two scratch `Vec`s per call). The appended intervals are
    /// sorted only when the concatenation is actually out of order, then
    /// coalesced with one in-place pass.
    pub fn union_with(&mut self, other: &BlockSet) {
        if other.is_empty() {
            return;
        }
        let old_len = self.ivs.len();
        self.ivs.extend_from_slice(&other.ivs);
        // Both halves are sorted; skip the sort when the concatenation
        // already is (common: accumulating ascending pieces).
        if old_len > 0 && self.ivs[old_len - 1] > self.ivs[old_len] {
            self.ivs.sort_unstable();
        }
        // Coalesce overlapping/adjacent intervals in place.
        let mut w = 0;
        for r in 1..self.ivs.len() {
            let (s, e) = self.ivs[r];
            if s <= self.ivs[w].1 {
                if e > self.ivs[w].1 {
                    self.ivs[w].1 = e;
                }
            } else {
                w += 1;
                self.ivs[w] = (s, e);
            }
        }
        self.ivs.truncate(w + 1);
    }

    /// Intersection.
    pub fn intersect(&self, other: &BlockSet) -> BlockSet {
        if self.is_empty() || other.is_empty() {
            return BlockSet::empty();
        }
        // an intersection has at most |self| + |other| − 1 intervals; the
        // common validator case is much smaller, so hint conservatively
        let mut out = Vec::with_capacity(self.ivs.len().max(other.ivs.len()));
        let (mut i, mut j) = (0, 0);
        while i < self.ivs.len() && j < other.ivs.len() {
            let (s1, e1) = self.ivs[i];
            let (s2, e2) = other.ivs[j];
            let s = s1.max(s2);
            let e = e1.min(e2);
            if s < e {
                out.push((s, e));
            }
            if e1 <= e2 {
                i += 1;
            } else {
                j += 1;
            }
        }
        BlockSet { ivs: out }
    }

    pub fn is_disjoint(&self, other: &BlockSet) -> bool {
        let (mut i, mut j) = (0, 0);
        while i < self.ivs.len() && j < other.ivs.len() {
            let (s1, e1) = self.ivs[i];
            let (s2, e2) = other.ivs[j];
            if s1.max(s2) < e1.min(e2) {
                return false;
            }
            if e1 <= e2 {
                i += 1;
            } else {
                j += 1;
            }
        }
        true
    }

    /// Set difference `self \ other`.
    pub fn difference(&self, other: &BlockSet) -> BlockSet {
        let mut out = Vec::new();
        let mut j = 0;
        for &(s, e) in &self.ivs {
            let mut cur = s;
            while j < other.ivs.len() && other.ivs[j].1 <= cur {
                j += 1;
            }
            let mut jj = j;
            while cur < e {
                if jj >= other.ivs.len() || other.ivs[jj].0 >= e {
                    out.push((cur, e));
                    break;
                }
                let (os, oe) = other.ivs[jj];
                if os > cur {
                    out.push((cur, os));
                }
                cur = cur.max(oe);
                jj += 1;
            }
        }
        BlockSet { ivs: out }
    }

    /// `self == {0,..,n-1}`?
    pub fn is_full(&self, n: u32) -> bool {
        self.ivs.len() == 1 && self.ivs[0] == (0, n)
    }

    /// Is `other` a subset of `self`? Allocation-free two-pointer walk:
    /// because intervals are disjoint and non-adjacent, every interval of a
    /// subset must lie inside a single interval of the superset.
    pub fn is_superset(&self, other: &BlockSet) -> bool {
        let mut i = 0;
        'outer: for &(s, e) in &other.ivs {
            while i < self.ivs.len() {
                let (ss, se) = self.ivs[i];
                if se <= s {
                    i += 1;
                    continue;
                }
                if ss <= s && e <= se {
                    continue 'outer;
                }
                return false;
            }
            return false;
        }
        true
    }

    /// Iterate over all ranks in the set, ascending.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.ivs.iter().flat_map(|&(s, e)| s..e)
    }

    /// Iterate over the linear intervals.
    pub fn intervals(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.ivs.iter().copied()
    }

    /// Shift every rank by `delta` mod `n` (used to translate a schedule
    /// built for node 0 to node `r`).
    pub fn shift(&self, delta: i64, n: u32) -> BlockSet {
        let mut out = Self::empty();
        for &(s, e) in &self.ivs {
            let ns = (s as i64 + delta).rem_euclid(n as i64) as u32;
            out = out.union(&Self::cyc_range(ns, (e - s) as u64, n));
        }
        out
    }
}

impl fmt::Debug for BlockSet {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{{")?;
        for (i, (s, e)) in self.ivs.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            if e - s == 1 {
                write!(f, "{s}")?;
            } else {
                write!(f, "{s}..{e}")?;
            }
        }
        write!(f, "}}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cyc_range_basic() {
        let s = BlockSet::cyc_range(2, 3, 9);
        assert_eq!(s.len(), 3);
        assert!(s.contains(2) && s.contains(3) && s.contains(4));
        assert!(!s.contains(5) && !s.contains(1));
    }

    #[test]
    fn cyc_range_wrap() {
        let s = BlockSet::cyc_range(7, 4, 9); // {7,8,0,1}
        assert_eq!(s.len(), 4);
        for r in [7, 8, 0, 1] {
            assert!(s.contains(r), "missing {r}");
        }
        assert!(!s.contains(2) && !s.contains(6));
        assert_eq!(s.runs(9), 1); // cyclically one run
    }

    #[test]
    fn cyc_range_full() {
        assert!(BlockSet::cyc_range(5, 9, 9).is_full(9));
        assert!(BlockSet::cyc_range(5, 100, 9).is_full(9));
    }

    #[test]
    fn cyc_ball() {
        let s = BlockSet::cyc_ball(0, 1, 9); // {8,0,1}
        assert_eq!(s.len(), 3);
        assert!(s.contains(8) && s.contains(0) && s.contains(1));
    }

    #[test]
    fn union_and_merge() {
        let a = BlockSet::cyc_range(0, 3, 10);
        let b = BlockSet::cyc_range(3, 2, 10);
        let u = a.union(&b);
        assert_eq!(u.len(), 5);
        assert_eq!(u.intervals().count(), 1);
    }

    #[test]
    fn disjoint_and_intersect() {
        let a = BlockSet::cyc_range(0, 3, 10);
        let b = BlockSet::cyc_range(5, 3, 10);
        assert!(a.is_disjoint(&b));
        let c = BlockSet::cyc_range(2, 4, 10);
        assert!(!a.is_disjoint(&c));
        assert_eq!(a.intersect(&c).len(), 1);
    }

    #[test]
    fn difference() {
        let a = BlockSet::full(10);
        let b = BlockSet::cyc_range(3, 4, 10);
        let d = a.difference(&b);
        assert_eq!(d.len(), 6);
        assert!(d.is_disjoint(&b));
        assert!(d.union(&b).is_full(10));
    }

    #[test]
    fn from_ranks() {
        let s = BlockSet::from_ranks(&[3, 1, 2, 7, 7, 8], 10);
        assert_eq!(s.len(), 5);
        assert_eq!(s.intervals().count(), 2);
    }

    #[test]
    fn shift() {
        let s = BlockSet::cyc_range(0, 3, 9).shift(7, 9); // {7,8,0}
        assert!(s.contains(7) && s.contains(8) && s.contains(0));
        assert_eq!(s.len(), 3);
        let back = s.shift(-7, 9);
        assert_eq!(back, BlockSet::cyc_range(0, 3, 9));
    }

    #[test]
    fn superset() {
        let a = BlockSet::cyc_range(0, 5, 9);
        let b = BlockSet::cyc_range(1, 3, 9);
        assert!(a.is_superset(&b));
        assert!(!b.is_superset(&a));
        // multi-interval containment: each piece inside a different interval
        let c = BlockSet::from_intervals(vec![(0, 3), (5, 8)]);
        let d = BlockSet::from_intervals(vec![(1, 2), (5, 6), (7, 8)]);
        assert!(c.is_superset(&d));
        assert!(!c.is_superset(&BlockSet::from_intervals(vec![(2, 4)])));
        assert!(c.is_superset(&BlockSet::empty()));
        assert!(!BlockSet::empty().is_superset(&c));
    }

    #[test]
    fn union_with_wraparound_intervals() {
        // {7,8,0,1} stored as [(0,2),(7,9)] unioned with {1,2} must merge
        // across the seam into [(0,3),(7,9)] — cyclically one run.
        let mut a = BlockSet::cyc_range(7, 4, 9);
        a.union_with(&BlockSet::cyc_range(1, 2, 9));
        assert_eq!(a.len(), 5);
        for r in [7, 8, 0, 1, 2] {
            assert!(a.contains(r), "missing {r}");
        }
        assert_eq!(a.intervals().count(), 2);
        assert_eq!(a.runs(9), 1);
        // and merging the gap closes it into the full set
        a.union_with(&BlockSet::cyc_range(3, 4, 9));
        assert!(a.is_full(9));
    }

    #[test]
    fn union_with_matches_union_on_random_wrapped_ranges() {
        // in-place union must agree with the pure one for every mix of
        // wrapped/linear/overlapping/adjacent inputs
        let mut rng = crate::util::SplitMix64::new(0x5EED);
        for _ in 0..500 {
            let n = rng.range(2, 40) as u32;
            let mk = |rng: &mut crate::util::SplitMix64| {
                let a = BlockSet::cyc_range(
                    rng.below(n as u64) as u32,
                    rng.range(0, n as u64 + 1),
                    n,
                );
                let b = BlockSet::cyc_range(
                    rng.below(n as u64) as u32,
                    rng.range(0, n as u64),
                    n,
                );
                a.union(&b)
            };
            let x = mk(&mut rng);
            let y = mk(&mut rng);
            let mut inplace = x.clone();
            inplace.union_with(&y);
            // reference: rank-by-rank membership
            for r in 0..n {
                assert_eq!(
                    inplace.contains(r),
                    x.contains(r) || y.contains(r),
                    "n={n} r={r} x={x:?} y={y:?} got {inplace:?}"
                );
            }
            // structural invariants: sorted, disjoint, non-adjacent
            let ivs: Vec<(u32, u32)> = inplace.intervals().collect();
            for w in ivs.windows(2) {
                assert!(w[0].1 < w[1].0, "not coalesced: {ivs:?}");
            }
        }
    }

    #[test]
    fn intersect_wraparound() {
        let a = BlockSet::cyc_range(7, 4, 9); // {7,8,0,1}
        let b = BlockSet::cyc_range(8, 3, 9); // {8,0,1}
        let i = a.intersect(&b);
        assert_eq!(i.len(), 3);
        assert!(i.contains(8) && i.contains(0) && i.contains(1));
        assert!(a.intersect(&BlockSet::empty()).is_empty());
    }
}
