//! Lifting ring patterns onto multidimensional tori (§5).
//!
//! * [`ProductAg`] — the product/interleave construction: given one ring
//!   pattern per torus dimension and a global step→dimension assignment,
//!   every node's held set is the *product* of its per-dimension held sets;
//!   a step in dimension `d` runs that dimension's next ring step on every
//!   fiber, carrying `held(other dims) × (new-in-d)`. This is exactly the
//!   Fig. 5 pattern when the assignment round-robins dimensions, and the
//!   sequential per-dimension phase structure of Bucket/Swing/RD when it
//!   concatenates them.
//! * [`reflect_schedule`] — the mirrored-collective combinator (§2.4
//!   "bidirectional design"): relabels every rank by coordinate reflection,
//!   producing the opposite-direction copy.
//! * [`concurrent_slices`] — overlays `S` collectives, each operating on a
//!   `1/S` slice of the data vector, into one schedule (block space `S·n`).
//! * [`virtual_pad`] — embeds a collective built for `N > n` virtual nodes
//!   onto `n` real nodes (co-hosted messages leave the network schedule);
//!   the documented fallback for sizes where a pattern has no native
//!   arbitrary-`n` form.

use crate::agpattern::{AgPattern, AgSend};
use crate::blockset::BlockSet;
use crate::schedule::{RouteHint, Schedule, Send};
use crate::topology::Torus;

/// Simulate an AG pattern and return `held[t][node]` = blocks held *before*
/// step `t` (index `num_steps()` = final state).
pub fn simulate_held(p: &dyn AgPattern) -> Vec<Vec<BlockSet>> {
    let n = p.n();
    let mut held: Vec<Vec<BlockSet>> = Vec::with_capacity(p.num_steps() + 1);
    held.push((0..n).map(|r| BlockSet::singleton(r, n)).collect());
    for k in 0..p.num_steps() {
        let mut next = held[k].clone();
        for s in p.sends(k) {
            next[s.to as usize].union_with(&s.blocks);
        }
        held.push(next);
    }
    held
}

/// Product/interleave lifting of per-dimension ring patterns (module docs).
pub struct ProductAg {
    name: String,
    torus: Torus,
    /// Per dim: ring sends per ring step.
    ring_sends: Vec<Vec<Vec<AgSend>>>,
    /// Per dim: held-before tables from [`simulate_held`].
    ring_held: Vec<Vec<Vec<BlockSet>>>,
    /// Global step → dimension.
    step_dims: Vec<usize>,
}

impl ProductAg {
    /// `patterns[d]` must be a pattern over a ring of size `torus.dims()[d]`.
    /// `step_dims` assigns every global step to a dimension and must contain
    /// each dimension exactly `patterns[d].num_steps()` times.
    pub fn new(
        name: String,
        torus: Torus,
        patterns: &[&dyn AgPattern],
        step_dims: Vec<usize>,
    ) -> Self {
        assert_eq!(patterns.len(), torus.ndims());
        for (d, p) in patterns.iter().enumerate() {
            assert_eq!(p.n(), torus.dims()[d], "pattern/torus dim {d} mismatch");
            let count = step_dims.iter().filter(|&&x| x == d).count();
            assert_eq!(count, p.num_steps(), "step_dims gives dim {d} {count} steps");
        }
        let ring_sends: Vec<Vec<Vec<AgSend>>> = patterns
            .iter()
            .map(|p| (0..p.num_steps()).map(|k| p.sends(k)).collect())
            .collect();
        let ring_held = patterns.iter().map(|p| simulate_held(*p)).collect();
        ProductAg { name, torus, ring_sends, ring_held, step_dims }
    }

    /// Round-robin dimension assignment starting at `start` (the Fig. 5
    /// interleave): cycles dimensions, skipping ones whose pattern is
    /// exhausted.
    pub fn round_robin(dims_steps: &[usize], start: usize) -> Vec<usize> {
        let d = dims_steps.len();
        let mut remaining = dims_steps.to_vec();
        let total: usize = dims_steps.iter().sum();
        let mut out = Vec::with_capacity(total);
        let mut i = start;
        while out.len() < total {
            if remaining[i % d] > 0 {
                remaining[i % d] -= 1;
                out.push(i % d);
            }
            i += 1;
        }
        out
    }

    /// Sequential per-dimension phases, rotated to start at `start` (the
    /// Bucket/Swing/RD structure).
    pub fn sequential(dims_steps: &[usize], start: usize) -> Vec<usize> {
        let d = dims_steps.len();
        let mut out = Vec::new();
        for i in 0..d {
            let dim = (start + i) % d;
            out.extend(std::iter::repeat(dim).take(dims_steps[dim]));
        }
        out
    }

    /// Ring-step index within `dim` for global step `k`.
    fn ring_step(&self, k: usize) -> usize {
        let d = self.step_dims[k];
        self.step_dims[..k].iter().filter(|&&x| x == d).count()
    }
}

impl AgPattern for ProductAg {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn n(&self) -> u32 {
        self.torus.n()
    }

    fn num_steps(&self) -> usize {
        self.step_dims.len()
    }

    fn sends(&self, k: usize) -> Vec<AgSend> {
        let d = self.step_dims[k];
        let t = self.ring_step(k);
        let ndims = self.torus.ndims();
        // Per-dim ring-step counters at global step k.
        let t_of: Vec<usize> = (0..ndims)
            .map(|e| self.step_dims[..k].iter().filter(|&&x| x == e).count())
            .collect();
        let mut out = Vec::new();
        // For each ring send and each fiber through dimension d.
        for rs in &self.ring_sends[d][t] {
            for r in 0..self.torus.n() {
                if self.torus.coord(r, d) != rs.src {
                    continue;
                }
                let dst = {
                    let mut c = self.torus.coords(r);
                    c[d] = rs.to;
                    self.torus.rank(&c)
                };
                // blocks = product(held in other dims, new blocks in d)
                let ranges: Vec<BlockSet> = (0..ndims)
                    .map(|e| {
                        if e == d {
                            rs.blocks.clone()
                        } else {
                            self.ring_held[e][t_of[e]][self.torus.coord(r, e) as usize].clone()
                        }
                    })
                    .collect();
                let blocks = self.torus.product_set(&ranges);
                if blocks.is_empty() {
                    continue;
                }
                let route = match rs.route {
                    RouteHint::Minimal => RouteHint::Minimal,
                    RouteHint::Directed { dir, .. } => RouteHint::Directed { dim: d as u8, dir },
                };
                out.push(AgSend { src: r, to: dst, blocks, route });
            }
        }
        out
    }
}

/// Coordinate-reflection rank map on a torus (`c_d → (a_d − c_d) mod a_d`).
pub fn reflection_map(t: &Torus) -> Vec<u32> {
    (0..t.n())
        .map(|r| {
            let c: Vec<u32> = t
                .coords(r)
                .iter()
                .zip(t.dims())
                .map(|(&c, &a)| (a - c) % a)
                .collect();
            t.rank(&c)
        })
        .collect()
}

/// Apply a rank permutation to a whole schedule: node ids, contributor
/// sets, and block ids (block `b` is rank `b`'s block). With the
/// reflection map this yields the mirrored collective of §2.4.
pub fn permute_schedule(s: &Schedule, map: &[u32]) -> Schedule {
    assert_eq!(map.len(), s.n as usize);
    assert_eq!(s.n, s.n_blocks, "permute_schedule expects rank-indexed blocks");
    let map_set = |bs: &BlockSet| -> BlockSet {
        let ranks: Vec<u32> = bs.iter().map(|r| map[r as usize]).collect();
        BlockSet::from_ranks(&ranks, s.n)
    };
    let mut out = Schedule::new(format!("{}-mirror", s.name), s.n, s.n_blocks);
    for step in &s.steps {
        let st = out.push_step();
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                let pieces = snd
                    .pieces
                    .iter()
                    .map(|p| crate::schedule::Piece {
                        blocks: map_set(&p.blocks),
                        contrib: map_set(&p.contrib),
                        kind: p.kind,
                    })
                    .collect();
                let route = match snd.route {
                    RouteHint::Minimal => RouteHint::Minimal,
                    RouteHint::Directed { dim, dir } => RouteHint::Directed { dim, dir: -dir },
                };
                st.push(map[src], Send { to: map[snd.to as usize], pieces, route });
            }
        }
    }
    out
}

/// Overlay `S` schedules, each owning a `1/S` slice of the vector, into one
/// schedule with block space `S·n_blocks` (slice `c`'s block `b` becomes
/// global block `c·n_blocks + b`).
pub fn concurrent_slices(slices: Vec<Schedule>, name: String) -> Schedule {
    assert!(!slices.is_empty());
    let n = slices[0].n;
    let nb = slices[0].n_blocks;
    let s_count = slices.len() as u32;
    let mut out = Schedule::new(name, n, s_count * nb);
    for (c, sl) in slices.iter().enumerate() {
        assert_eq!(sl.n, n);
        assert_eq!(sl.n_blocks, nb);
        while out.steps.len() < sl.steps.len() {
            out.push_step();
        }
        let off = (c as u32 * nb) as i64;
        for (k, step) in sl.steps.iter().enumerate() {
            for (src, sends) in step.sends.iter().enumerate() {
                for snd in sends {
                    let pieces = snd
                        .pieces
                        .iter()
                        .map(|p| crate::schedule::Piece {
                            // embed the slice's block ids into the global
                            // block space (no wrap: offsets are multiples
                            // of nb and the space is s_count·nb)
                            blocks: BlockSet::from_intervals(
                                p.blocks
                                    .intervals()
                                    .map(|(s, e)| ((s as i64 + off) as u32, (e as i64 + off) as u32))
                                    .collect(),
                            ),
                            contrib: p.contrib.clone(),
                            kind: p.kind,
                        })
                        .collect();
                    out.steps[k].sends[src].push(Send {
                        to: snd.to,
                        pieces,
                        route: snd.route,
                    });
                }
            }
        }
    }
    out
}

/// Virtual padding: a collective built for `nv > n` virtual nodes executed
/// on `n` real hosts. Returns the **network schedule** over the real nodes:
/// virtual rank `v` is hosted on `host(v) = ⌊v·n/nv⌋` (order-preserving, so
/// virtual distances map proportionally onto real distances); messages
/// between co-hosted virtual ranks cost nothing on the network and are
/// dropped. The *virtual* schedule remains the source of truth for
/// validation and numeric execution (real node `r` takes the result of its
/// first hosted virtual rank).
pub fn virtual_pad_network(virtual_schedule: &Schedule, n_real: u32) -> Schedule {
    let nv = virtual_schedule.n;
    assert!(n_real <= nv);
    let host = |v: u32| -> u32 { ((v as u64 * n_real as u64) / nv as u64) as u32 };
    let mut out = Schedule::new(
        format!("{}-padded(n={n_real})", virtual_schedule.name),
        n_real,
        virtual_schedule.n_blocks,
    );
    for step in &virtual_schedule.steps {
        let st = out.push_step();
        let mut any = false;
        for (src, sends) in step.sends.iter().enumerate() {
            let hsrc = host(src as u32);
            for snd in sends {
                let hdst = host(snd.to);
                if hsrc == hdst {
                    continue; // co-hosted: a local memory move
                }
                any = true;
                st.push(hsrc, Send { to: hdst, pieces: snd.pieces.clone(), route: snd.route });
            }
        }
        if !any {
            // A step whose traffic is entirely local still costs α
            // (the virtual algorithm synchronizes on it); keep the empty
            // step so step counting stays faithful.
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::{
        allgather_schedule, bandwidth_allreduce, latency_allreduce,
    };
    use crate::algo::rings::{hamiltonian, trivance, Order};
    use crate::schedule::validate::{validate_allgather, validate_allreduce};

    #[test]
    fn product_trivance_3x3_valid() {
        let t = Torus::new(&[3, 3]);
        let p0 = trivance(3, Order::Inc);
        let p1 = trivance(3, Order::Inc);
        let sd = ProductAg::round_robin(&[1, 1], 0);
        let p = ProductAg::new("t2d".into(), t, &[&p0, &p1], sd);
        assert_eq!(p.num_steps(), 2); // log₃ 9
        validate_allgather(&allgather_schedule(&p)).unwrap();
        validate_allreduce(&latency_allreduce(&p)).unwrap();
    }

    #[test]
    fn product_trivance_9x9_steps_and_valid() {
        let t = Torus::new(&[9, 9]);
        let p0 = trivance(9, Order::Inc);
        let p1 = trivance(9, Order::Inc);
        let sd = ProductAg::round_robin(&[2, 2], 0);
        let p = ProductAg::new("t2d".into(), t, &[&p0, &p1], sd);
        assert_eq!(p.num_steps(), 4); // log₃ 81
        validate_allgather(&allgather_schedule(&p)).unwrap();
        validate_allreduce(&latency_allreduce(&p)).unwrap();
    }

    #[test]
    fn product_bandwidth_3x3_valid() {
        let t = Torus::new(&[3, 3]);
        let p0 = trivance(3, Order::Dec);
        let p1 = trivance(3, Order::Dec);
        let sd = ProductAg::round_robin(&[1, 1], 0);
        let p = ProductAg::new("t2d".into(), t, &[&p0, &p1], sd);
        let s = bandwidth_allreduce(&p);
        assert_eq!(s.num_steps(), 4);
        validate_allreduce(&s).unwrap();
    }

    #[test]
    fn product_bucket_sequential_valid() {
        let t = Torus::new(&[3, 4]);
        let p0 = hamiltonian(3);
        let p1 = hamiltonian(4);
        let sd = ProductAg::sequential(&[2, 3], 0);
        let p = ProductAg::new("bucket2d".into(), t.clone(), &[&p0, &p1], sd);
        validate_allgather(&allgather_schedule(&p)).unwrap();
        validate_allreduce(&bandwidth_allreduce(&p)).unwrap();
    }

    #[test]
    fn step_dim_assignments() {
        assert_eq!(ProductAg::round_robin(&[2, 2], 0), vec![0, 1, 0, 1]);
        assert_eq!(ProductAg::round_robin(&[2, 2], 1), vec![1, 0, 1, 0]);
        assert_eq!(ProductAg::round_robin(&[3, 1], 0), vec![0, 1, 0, 0]);
        assert_eq!(ProductAg::sequential(&[2, 3], 1), vec![1, 1, 1, 0, 0]);
    }

    #[test]
    fn reflection_is_involution() {
        let t = Torus::new(&[4, 3]);
        let m = reflection_map(&t);
        for r in 0..t.n() {
            assert_eq!(m[m[r as usize] as usize], r);
        }
    }

    #[test]
    fn mirrored_schedule_valid() {
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let t = Torus::ring(9);
        let m = permute_schedule(&s, &reflection_map(&t));
        validate_allreduce(&m).unwrap();
    }

    #[test]
    fn concurrent_slices_valid() {
        // two mirrored trivance collectives, half data each
        let t = Torus::ring(9);
        let a = latency_allreduce(&trivance(9, Order::Inc));
        let b = permute_schedule(&a, &reflection_map(&t));
        let merged = concurrent_slices(vec![a.clone(), b], "pair".into());
        assert_eq!(merged.n_blocks, 18);
        validate_allreduce(&merged).unwrap();
        // each message carries half the vector
        let rel = merged.steps[0].sends[0][0].rel_bytes(merged.n_blocks);
        assert!((rel - 0.5).abs() < 1e-12, "rel={rel}");
        // total sent per node is unchanged vs the single collective
        let single = a.node_sent_rel_bytes(0);
        let merged_sent = merged.node_sent_rel_bytes(0);
        assert!((merged_sent - single).abs() < 1e-9);
    }

    #[test]
    fn virtual_pad_drops_local_messages() {
        // pad a 9-node trivance onto 7 real nodes
        let s = latency_allreduce(&trivance(9, Order::Inc));
        validate_allreduce(&s).unwrap(); // virtual schedule is the validated one
        let net = virtual_pad_network(&s, 7);
        assert_eq!(net.n, 7);
        assert_eq!(net.num_steps(), s.num_steps());
        assert!(net.num_messages() < s.num_messages());
        // no self-sends remain
        for st in &net.steps {
            for (src, sends) in st.sends.iter().enumerate() {
                for snd in sends {
                    assert_ne!(snd.to as usize, src);
                }
            }
        }
    }
}
