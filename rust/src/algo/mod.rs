//! Collective algorithms as AllGather patterns.
//!
//! * [`rings`] — the 1-D building blocks: Trivance (§4, including the §4.4
//!   arbitrary-n final adjustment step), Bruck (radix-3, two same-direction
//!   sends per step), Swing, Recursive Doubling, and the Hamiltonian ring.
//! * [`multidim`] — the product/interleave machinery lifting any set of
//!   per-dimension ring patterns onto a torus (§5), the mirrored
//!   (reflection) combinator, concurrent data slices, and virtual
//!   power-of-three padding.
//! * [`registry`] — the user-facing catalogue: algorithm × variant × torus
//!   → validated schedule, exactly the configurations of the paper's
//!   evaluation.

pub mod rings;
pub mod multidim;
pub mod hierarchical;
pub mod registry;

pub use registry::{build, Algo, BuiltCollective, Variant};
