//! Hierarchical (per-dimension phase) bandwidth-optimal composition.
//!
//! The real multidimensional structure of bandwidth-optimal collectives
//! (§2.4 Bucket, and the per-dimension decomposition all baselines use):
//! Reduce-Scatter along dim `o₀`, then along `o₁`, …, followed by the
//! AllGather phases in reverse dimension order. Each phase is a *ring*
//! schedule lifted onto every fiber of its dimension:
//!
//! * a ring Reduce-Scatter piece with ring blocks `J` and ring contributors
//!   `C` lifts to torus blocks `∏(processed: {x_e}) × J × ∏(unprocessed:
//!   full)` and contributors `∏(processed: full) × C × ∏(unprocessed:
//!   {x_f})` — the node has already fully reduced the processed dimensions
//!   over its still-held shard, and still owns only its own coordinate in
//!   unprocessed ones;
//! * an AllGather piece lifts analogously with "still-reduced" dimensions
//!   pinned.
//!
//! Compared to reversing the product-pattern tree globally, this builds
//! from `O(a)`-sized ring schedules per dimension — constant-factor memory
//! even on 16×16×16 — and is exactly what a real implementation pipelines.

use crate::agpattern::{allgather_schedule, reduce_scatter_schedule, AgPattern};
use crate::blockset::BlockSet;
use crate::schedule::{Piece, Schedule, Send};
use crate::topology::Torus;

/// Lift state: which dims are "pinned to the node coordinate" for blocks
/// vs. contributors.
struct Lift<'a> {
    torus: &'a Torus,
    dim: usize,
    /// dims already reduced (before this phase, in RS order).
    processed: Vec<usize>,
}

impl Lift<'_> {
    /// blocks: processed dims pinned to x, `dim` from the ring set, rest free.
    fn blocks(&self, x: u32, ring: &BlockSet) -> BlockSet {
        let ranges: Vec<BlockSet> = (0..self.torus.ndims())
            .map(|e| {
                if e == self.dim {
                    ring.clone()
                } else if self.processed.contains(&e) {
                    BlockSet::singleton(self.torus.coord(x, e), self.torus.dims()[e])
                } else {
                    BlockSet::full(self.torus.dims()[e])
                }
            })
            .collect();
        self.torus.product_set(&ranges)
    }

    /// contributors: processed dims full, `dim` from the ring set, rest
    /// pinned to x.
    fn contrib(&self, x: u32, ring: &BlockSet) -> BlockSet {
        let ranges: Vec<BlockSet> = (0..self.torus.ndims())
            .map(|e| {
                if e == self.dim {
                    ring.clone()
                } else if self.processed.contains(&e) {
                    BlockSet::full(self.torus.dims()[e])
                } else {
                    BlockSet::singleton(self.torus.coord(x, e), self.torus.dims()[e])
                }
            })
            .collect();
        self.torus.product_set(&ranges)
    }
}

/// Append the lifted version of ring-phase `phase` (over dim `dim`) to
/// `out`, with `processed` = dims fully reduced before this phase.
fn lift_phase(out: &mut Schedule, torus: &Torus, phase: &Schedule, dim: usize, processed: &[usize]) {
    let lift = Lift { torus, dim, processed: processed.to_vec() };
    for ring_step in &phase.steps {
        let st = out.push_step();
        for (ring_src, sends) in ring_step.sends.iter().enumerate() {
            for snd in sends {
                // every fiber node with coord(dim) == ring_src
                for x in 0..torus.n() {
                    if torus.coord(x, dim) as usize != ring_src {
                        continue;
                    }
                    let dst = {
                        let mut c = torus.coords(x);
                        c[dim] = snd.to;
                        torus.rank(&c)
                    };
                    let pieces: Vec<Piece> = snd
                        .pieces
                        .iter()
                        .map(|p| Piece {
                            blocks: lift.blocks(x, &p.blocks),
                            // AG-phase Set pieces carry fully-reduced
                            // blocks: contributors are all ranks, not a
                            // lifted ring set.
                            contrib: match p.kind {
                                crate::schedule::Kind::Set => BlockSet::full(torus.n()),
                                crate::schedule::Kind::Reduce => lift.contrib(x, &p.contrib),
                            },
                            kind: p.kind,
                        })
                        .collect();
                    // directed hints must follow the lifted dimension
                    let route = match snd.route {
                        crate::schedule::RouteHint::Directed { dir, .. } => {
                            crate::schedule::RouteHint::Directed { dim: dim as u8, dir }
                        }
                        r => r,
                    };
                    st.sends[x as usize].push(Send { to: dst, pieces, route });
                }
            }
        }
    }
}

/// Build the hierarchical bandwidth-optimal AllReduce over `torus`:
/// `patterns[d]` is the (decreasing-order) ring pattern for dimension `d`;
/// `dim_order` gives the RS phase order (AG runs reversed).
pub fn hierarchical_bandwidth(
    torus: &Torus,
    patterns: &[&dyn AgPattern],
    dim_order: &[usize],
    name: String,
) -> Schedule {
    assert_eq!(patterns.len(), torus.ndims());
    for (d, p) in patterns.iter().enumerate() {
        assert_eq!(p.n(), torus.dims()[d]);
    }
    let mut out = Schedule::new(name, torus.n(), torus.n());
    let mut processed: Vec<usize> = Vec::new();
    // Reduce-Scatter phases.
    for &d in dim_order {
        let rs = reduce_scatter_schedule(patterns[d]);
        lift_phase(&mut out, torus, &rs, d, &processed);
        processed.push(d);
    }
    // AllGather phases, reverse order; before AG of dim d, d is still
    // "processed" — remove it first so blocks stay pinned on the other
    // still-reduced dims but range over d per the ring AG.
    for &d in dim_order.iter().rev() {
        processed.retain(|&e| e != d);
        let ag = allgather_schedule(patterns[d]);
        lift_phase(&mut out, torus, &ag, d, &processed);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::rings::{hamiltonian, recdoub, swing, trivance, Order};
    use crate::schedule::validate::validate_allreduce;

    #[test]
    fn bucket_2d_valid() {
        let t = Torus::new(&[3, 4]);
        let p0 = hamiltonian(3);
        let p1 = hamiltonian(4);
        let s = hierarchical_bandwidth(&t, &[&p0, &p1], &[0, 1], "bucket".into());
        assert_eq!(s.num_steps(), 2 * (2 + 3));
        validate_allreduce(&s).unwrap();
    }

    #[test]
    fn trivance_2d_valid() {
        let t = Torus::new(&[9, 3]);
        let p0 = trivance(9, Order::Dec);
        let p1 = trivance(3, Order::Dec);
        let s = hierarchical_bandwidth(&t, &[&p0, &p1], &[1, 0], "t".into());
        assert_eq!(s.num_steps(), 2 * 3);
        validate_allreduce(&s).unwrap();
    }

    #[test]
    fn trivance_3d_valid() {
        let t = Torus::new(&[3, 3, 3]);
        let ps: Vec<_> = (0..3).map(|_| trivance(3, Order::Dec)).collect();
        let refs: Vec<&dyn AgPattern> = ps.iter().map(|p| p as &dyn AgPattern).collect();
        let s = hierarchical_bandwidth(&t, &refs, &[0, 1, 2], "t3".into());
        assert_eq!(s.num_steps(), 6);
        validate_allreduce(&s).unwrap();
    }

    #[test]
    fn swing_recdoub_2d_valid() {
        let t = Torus::new(&[4, 4]);
        let s0 = swing(4, Order::Dec);
        let s1 = swing(4, Order::Dec);
        let s = hierarchical_bandwidth(&t, &[&s0, &s1], &[0, 1], "swing".into());
        validate_allreduce(&s).unwrap();
        let r0 = recdoub(4, Order::Dec);
        let r1 = recdoub(4, Order::Dec);
        let s = hierarchical_bandwidth(&t, &[&r0, &r1], &[1, 0], "rd".into());
        validate_allreduce(&s).unwrap();
    }

    #[test]
    fn data_volume_is_bandwidth_optimal() {
        // hierarchical B still moves 2m(1−1/n) per node in total
        let t = Torus::new(&[3, 3]);
        let p0 = trivance(3, Order::Dec);
        let p1 = trivance(3, Order::Dec);
        let s = hierarchical_bandwidth(&t, &[&p0, &p1], &[0, 1], "t".into());
        let expect = 2.0 * (1.0 - 1.0 / 9.0);
        for r in 0..9 {
            let sent = s.node_sent_rel_bytes(r);
            assert!((sent - expect).abs() < 1e-9, "r={r}: {sent} vs {expect}");
        }
    }
}
