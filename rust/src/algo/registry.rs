//! The algorithm catalogue: algorithm × variant × torus → schedules.
//!
//! This is the single entry point the harness, CLI, simulator, and executor
//! go through. A [`BuiltCollective`] carries two schedules:
//!
//! * `exec` — the semantically complete schedule used for validation and
//!   numeric execution. For virtually-padded configurations it runs over
//!   the padded (virtual) node count.
//! * `net` — the schedule whose messages actually hit the network (equal to
//!   `exec` except under virtual padding, where co-hosted messages vanish).

use crate::agpattern::{bandwidth_allreduce, latency_allreduce, AgPattern};
use crate::algo::multidim::{
    concurrent_slices, permute_schedule, reflection_map, virtual_pad_network, ProductAg,
};
use crate::algo::rings::{bruck, hamiltonian, recdoub, swing, trivance, Order};
use crate::schedule::Schedule;
use crate::topology::Torus;
use crate::util::{ceil_log, is_power_of};

/// The AllReduce algorithms of the paper's evaluation.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    /// §4 — this paper's contribution.
    Trivance,
    /// Bruck with the evaluation's shortest-path routing modification.
    Bruck,
    /// Original Bruck: all traffic in one ring direction (ablation).
    BruckUnidir,
    /// Swing (De Sensi et al., NSDI'24); power-of-two sizes.
    Swing,
    /// Recursive Doubling / Rabenseifner; power-of-two sizes.
    RecDoub,
    /// Hamiltonian-ring / Bucket (bandwidth-optimal baseline).
    Bucket,
}

impl Algo {
    pub const ALL: [Algo; 6] = [
        Algo::Trivance,
        Algo::Bruck,
        Algo::BruckUnidir,
        Algo::Swing,
        Algo::RecDoub,
        Algo::Bucket,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            Algo::Trivance => "trivance",
            Algo::Bruck => "bruck",
            Algo::BruckUnidir => "bruck-unidir",
            Algo::Swing => "swing",
            Algo::RecDoub => "recdoub",
            Algo::Bucket => "bucket",
        }
    }

    pub fn parse(s: &str) -> Option<Algo> {
        Algo::ALL.into_iter().find(|a| a.label() == s)
    }
}

/// Latency-optimal (single phase, full-vector aggregates) or
/// bandwidth-optimal (Reduce-Scatter + AllGather) variant.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Variant {
    Latency,
    Bandwidth,
}

impl Variant {
    pub const ALL: [Variant; 2] = [Variant::Latency, Variant::Bandwidth];

    pub fn label(&self) -> &'static str {
        match self {
            Variant::Latency => "L",
            Variant::Bandwidth => "B",
        }
    }
}

/// The virtual-padding embedding of a padded [`BuiltCollective`]: which
/// padded (virtual) torus the `exec` schedule runs on and which real node
/// hosts each virtual rank. This is what lets `schedule::rewrite` operate
/// on padded Bruck/Trivance schedules: the rewrite machine runs in virtual
/// space on `exec` and the result is collapsed back through `hosts`.
#[derive(Clone, Debug)]
pub struct Padding {
    /// Dimensions of the padded virtual torus `exec` runs over.
    pub vdims: Vec<u32>,
    /// `hosts[v]` = real rank hosting virtual rank `v` (per-coordinate
    /// `⌊c·a/av⌋`, which for rings is `⌊v·n/nv⌋` — the same map
    /// `virtual_pad_network` collapses the network schedule with).
    pub hosts: Vec<u32>,
}

/// A built collective: execution + network schedules (see module docs).
#[derive(Clone, Debug)]
pub struct BuiltCollective {
    pub name: String,
    pub algo: Algo,
    pub variant: Variant,
    pub exec: Schedule,
    pub net: Schedule,
    /// True when the collective was embedded via virtual padding.
    pub padded: bool,
    /// The padding map when `padded` (virtual dims + host assignment).
    pub padding: Option<Padding>,
}

impl BuiltCollective {
    fn plain(name: String, algo: Algo, variant: Variant, s: Schedule) -> Self {
        BuiltCollective {
            name,
            algo,
            variant,
            net: s.clone(),
            exec: s,
            padded: false,
            padding: None,
        }
    }

    /// Validate the execution schedule (disjointness + coverage).
    pub fn validate(&self) -> Result<crate::schedule::validate::Report, String> {
        crate::schedule::validate::validate_allreduce(&self.exec)
    }
}

/// Build the ring pattern for one dimension of `algo`, in the given step
/// order. Returns `None` when the size is unsupported natively (then the
/// caller pads).
fn ring_pattern(algo: Algo, n: u32, order: Order) -> Option<Box<dyn AgPattern>> {
    match algo {
        Algo::Trivance => {
            let p = trivance(n, order);
            p.is_complete().then(|| Box::new(p) as Box<dyn AgPattern>)
        }
        Algo::Bruck => {
            let p = bruck(n, order, false);
            p.is_complete().then(|| Box::new(p) as Box<dyn AgPattern>)
        }
        Algo::BruckUnidir => {
            let p = bruck(n, order, true);
            p.is_complete().then(|| Box::new(p) as Box<dyn AgPattern>)
        }
        Algo::Swing => {
            is_power_of(2, n as u64).then(|| Box::new(swing(n, order)) as Box<dyn AgPattern>)
        }
        Algo::RecDoub => {
            is_power_of(2, n as u64).then(|| Box::new(recdoub(n, order)) as Box<dyn AgPattern>)
        }
        Algo::Bucket => Some(Box::new(hamiltonian(n))),
    }
}

/// Derive one slice's AllReduce schedule from its pattern.
fn derive(p: &dyn AgPattern, variant: Variant) -> Schedule {
    match variant {
        Variant::Latency => latency_allreduce(p),
        Variant::Bandwidth => bandwidth_allreduce(p),
    }
}

/// Step order used for the given variant: latency variants run distances
/// increasing; bandwidth variants are derived from the decreasing-distance
/// AllGather phase (see [`crate::algo::rings`] module docs).
fn order_for(variant: Variant) -> Order {
    match variant {
        Variant::Latency => Order::Inc,
        Variant::Bandwidth => Order::Dec,
    }
}

/// Does this algorithm family use mirrored pairs (Swing/RD/Bucket `2D`
/// slices) rather than one inherently bidirectional collective per
/// dimension (Trivance/Bruck, `D` slices)? Applies to the bandwidth
/// variants only: per Appendix B, "the latency-optimal variants of
/// Recursive Doubling and Swing utilize only a single port per node" —
/// their L variants run one un-mirrored collective on the full vector
/// (which is exactly what makes Δ = log₂n/2 and Θ = n/3 in Table 1).
fn mirrored_family(algo: Algo) -> bool {
    matches!(algo, Algo::Swing | Algo::RecDoub | Algo::Bucket)
}

/// Build `algo` (`variant`) on `torus`. Errors only on genuinely
/// unsupported configurations (e.g. Swing on a non-power-of-two dimension,
/// where the paper's SST setup has no implementation either and this crate
/// falls back to virtual padding).
pub fn build(algo: Algo, variant: Variant, torus: &Torus) -> Result<BuiltCollective, String> {
    let name = format!("{}-{} {:?}", algo.label(), variant.label(), torus.dims());
    let d = torus.ndims();
    let order = order_for(variant);

    // Try native per-dimension patterns first.
    let native: Option<Vec<Box<dyn AgPattern>>> = torus
        .dims()
        .iter()
        .map(|&a| ring_pattern(algo, a, order))
        .collect();

    if let Some(pats) = native {
        let dims_steps: Vec<usize> = pats.iter().map(|p| p.num_steps()).collect();
        let refs: Vec<&dyn AgPattern> = pats.iter().map(|b| b.as_ref()).collect();
        let mut slices = Vec::new();
        let single_port_l = mirrored_family(algo) && variant == Variant::Latency;
        if d == 1 && (!mirrored_family(algo) || single_port_l) {
            // Trivance/Bruck on a ring (bidirectional by construction), or
            // a single-port latency variant: one collective, full vector.
            slices.push(derive(refs[0], variant));
        } else if single_port_l {
            // Single-port L variant on a torus: one sequential
            // per-dimension collective, full vector.
            let step_dims = ProductAg::sequential(&dims_steps, 0);
            let prod = ProductAg::new(algo.label().to_string(), torus.clone(), &refs, step_dims);
            slices.push(derive(&prod, variant));
        } else {
            for start in 0..d {
                let sched = match (variant, d) {
                    // Multidimensional bandwidth variant: hierarchical
                    // per-dimension RS/AG phases (§2.4 / §5), built from
                    // O(a)-sized ring schedules — the scalable path.
                    (Variant::Bandwidth, 2..) => {
                        let dim_order: Vec<usize> = (0..d).map(|i| (start + i) % d).collect();
                        crate::algo::hierarchical::hierarchical_bandwidth(
                            torus,
                            &refs,
                            &dim_order,
                            format!("{}[d0={start}]", algo.label()),
                        )
                    }
                    _ => {
                        let step_dims = if mirrored_family(algo) {
                            ProductAg::sequential(&dims_steps, start)
                        } else {
                            ProductAg::round_robin(&dims_steps, start)
                        };
                        let prod;
                        let pat: &dyn AgPattern = if d == 1 {
                            refs[0]
                        } else {
                            prod = ProductAg::new(
                                format!("{}[d0={start}]", algo.label()),
                                torus.clone(),
                                &refs,
                                step_dims,
                            );
                            &prod
                        };
                        derive(pat, variant)
                    }
                };
                if mirrored_family(algo) {
                    let mirror = permute_schedule(&sched, &reflection_map(torus));
                    slices.push(sched);
                    slices.push(mirror);
                } else {
                    slices.push(sched);
                }
            }
        }
        let merged = if slices.len() == 1 {
            let mut s = slices.pop().unwrap();
            s.name = name.clone();
            s
        } else {
            concurrent_slices(slices, name.clone())
        };
        return Ok(BuiltCollective::plain(name, algo, variant, merged));
    }

    // Virtual padding fallback: embed the collective built for the next
    // supported dimension sizes onto the real torus.
    let pad_base: u64 = match algo {
        Algo::Swing | Algo::RecDoub => 2,
        _ => 3,
    };
    let padded_dims: Vec<u32> = torus
        .dims()
        .iter()
        .map(|&a| pad_base.pow(ceil_log(pad_base, a as u64)) as u32)
        .collect();
    if padded_dims.iter().zip(torus.dims()).all(|(a, b)| a == b) {
        return Err(format!("{name}: unsupported size and padding is a no-op"));
    }
    let vtorus = Torus::new(&padded_dims);
    let inner = build(algo, variant, &vtorus)?;
    // Per-dimension host mapping ⌊c·a/av⌋ composes into the rank map used
    // by virtual_pad_network only for rings; for tori map per dimension.
    let hosts = padding_hosts(&vtorus, torus);
    let net = if d == 1 {
        virtual_pad_network(&inner.exec, torus.n())
    } else {
        // Build an explicit host map per rank and collapse.
        collapse_by_hosts(
            &inner.exec,
            &hosts,
            torus.n(),
            format!("{}-padded({:?})", inner.exec.name, torus.dims()),
        )
    };
    Ok(BuiltCollective {
        name: format!("{name} (padded {:?})", padded_dims),
        algo,
        variant,
        exec: inner.exec,
        net,
        padded: true,
        padding: Some(Padding { vdims: padded_dims, hosts }),
    })
}

/// The host map of a virtual-padding embedding: `hosts[v]` = real rank of
/// virtual rank `v`, per-coordinate `⌊c·a/av⌋`.
fn padding_hosts(vtorus: &Torus, torus: &Torus) -> Vec<u32> {
    (0..vtorus.n())
        .map(|v| {
            let cs: Vec<u32> = vtorus
                .coords(v)
                .iter()
                .zip(vtorus.dims().iter().zip(torus.dims()))
                .map(|(&c, (&av, &a))| ((c as u64 * a as u64) / av as u64) as u32)
                .collect();
            torus.rank(&cs)
        })
        .collect()
}

/// Collapse a virtual-space schedule onto the real torus through a host
/// map: endpoints become their hosts, co-hosted messages are dropped
/// (local memory moves). Steps are kept even when fully local — the
/// virtual algorithm synchronizes on them, so step counting stays
/// faithful. Used both for the registry's padded `net` schedules and for
/// collapsing *rewritten* virtual schedules in `schedule::rewrite`.
pub fn collapse_by_hosts(s: &Schedule, hosts: &[u32], n_real: u32, name: String) -> Schedule {
    assert_eq!(hosts.len(), s.n as usize, "host map must cover every virtual rank");
    let mut out = Schedule::new(name, n_real, s.n_blocks);
    for step in &s.steps {
        let st = out.push_step();
        for (src, sends) in step.sends.iter().enumerate() {
            let hsrc = hosts[src];
            for snd in sends {
                let hdst = hosts[snd.to as usize];
                if hsrc == hdst {
                    continue;
                }
                st.push(
                    hsrc,
                    crate::schedule::Send {
                        to: hdst,
                        pieces: snd.pieces.clone(),
                        route: snd.route,
                    },
                );
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_catalogue_valid() {
        let t = Torus::ring(8);
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t)
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
                b.validate()
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
            }
        }
    }

    #[test]
    fn registry_statically_certifies_on_small_topologies() {
        // ISSUE 7: every buildable collective must pass the full static
        // verifier (dataflow proof + port legality + congestion gates),
        // not just the disjointness/coverage validator above.
        for t in [Torus::ring(8), Torus::ring(9), Torus::new(&[3, 3])] {
            let rep = crate::verify::certify_registry(&t)
                .unwrap_or_else(|e| panic!("{:?}: {e}", t.dims()));
            assert!(rep.certs.len() >= 8, "{:?}: {} certs", t.dims(), rep.certs.len());
        }
    }

    #[test]
    fn ring9_trivance_and_bruck() {
        let t = Torus::ring(9);
        for algo in [Algo::Trivance, Algo::Bruck, Algo::Bucket] {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t).unwrap();
                assert!(!b.padded);
                b.validate().unwrap();
            }
        }
    }

    #[test]
    fn swing_pads_on_non_pow2() {
        let t = Torus::ring(9);
        let b = build(Algo::Swing, Variant::Latency, &t).unwrap();
        assert!(b.padded);
        b.validate().unwrap(); // exec schedule over 16 virtual nodes
        assert_eq!(b.exec.n, 16);
        assert_eq!(b.net.n, 9);
    }

    #[test]
    fn padding_map_collapses_exec_to_the_shipped_net() {
        // 1-D: the recorded host map must reproduce virtual_pad_network's
        // collapse message for message (rewrite relies on this equivalence)
        let b = build(Algo::Swing, Variant::Latency, &Torus::ring(9)).unwrap();
        let pad = b.padding.as_ref().expect("padded build records its map");
        assert_eq!(pad.vdims, vec![16]);
        assert_eq!(pad.hosts.len(), b.exec.n as usize);
        let again = collapse_by_hosts(&b.exec, &pad.hosts, 9, b.net.name.clone());
        assert_eq!(again.num_steps(), b.net.num_steps());
        for (a, n) in again.steps.iter().zip(&b.net.steps) {
            for (sa, sn) in a.sends.iter().zip(&n.sends) {
                assert_eq!(sa.len(), sn.len());
                for (x, y) in sa.iter().zip(sn) {
                    assert_eq!(x.to, y.to);
                    assert_eq!(x.pieces, y.pieces);
                }
            }
        }
        // 2-D padded case records the map too
        let b2 = build(Algo::Trivance, Variant::Latency, &Torus::new(&[4, 4])).unwrap();
        assert!(b2.padded);
        let pad2 = b2.padding.as_ref().unwrap();
        assert_eq!(pad2.vdims, vec![9, 9]);
        assert_eq!(pad2.hosts.len(), 81);
        assert!(pad2.hosts.iter().all(|&h| h < 16));
        // native builds carry no map
        assert!(build(Algo::Trivance, Variant::Latency, &Torus::ring(9))
            .unwrap()
            .padding
            .is_none());
    }

    #[test]
    fn torus_3x3_catalogue_valid() {
        let t = Torus::new(&[3, 3]);
        for algo in [Algo::Trivance, Algo::Bruck, Algo::Bucket] {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t)
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
                b.validate()
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
            }
        }
    }

    #[test]
    fn torus_4x4_catalogue_valid() {
        let t = Torus::new(&[4, 4]);
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let b = build(algo, variant, &t)
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
                b.validate()
                    .unwrap_or_else(|e| panic!("{algo:?} {variant:?}: {e}"));
            }
        }
    }

    #[test]
    fn trivance_torus_latency_steps() {
        // §5: ⌈log₃ n⌉ steps on the torus (n = a^D, a a power of three).
        let t = Torus::new(&[9, 9]);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        assert_eq!(b.net.num_steps(), 4); // log₃ 81
        let t3 = Torus::new(&[3, 3, 3]);
        let b3 = build(Algo::Trivance, Variant::Latency, &t3).unwrap();
        assert_eq!(b3.net.num_steps(), 3); // log₃ 27
    }

    #[test]
    fn slices_have_split_data() {
        // On a D-dim torus Trivance runs D collectives with 1/D of the data.
        let t = Torus::new(&[3, 3]);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        assert_eq!(b.net.n_blocks, 2 * 9);
        // Bucket/Swing families run 2D mirrored collectives.
        let bb = build(Algo::Bucket, Variant::Bandwidth, &t).unwrap();
        assert_eq!(bb.net.n_blocks, 4 * 9);
    }
}
