//! The 1-D (ring) AllGather patterns underlying every collective.
//!
//! Each builder returns an [`ExchangeAg`] over a bidirectional ring of `n`
//! nodes; the generic machinery in [`crate::agpattern`] derives the
//! latency-optimal and bandwidth-optimal AllReduce schedules, and
//! [`crate::algo::multidim`] lifts them onto tori.
//!
//! ## Step ordering
//!
//! Every pattern comes in two step orders:
//!
//! * [`Order::Inc`] — communication distance *grows* each step. This is the
//!   latency-optimal variant's own pattern and the direction of the
//!   bandwidth-optimal Reduce-Scatter phase ("the communication distance is
//!   tripled each step, the size of sent data is divided by three", §4.1).
//! * [`Order::Dec`] — distance *shrinks* each step: the AllGather phase of
//!   the bandwidth-optimal variant ("in reverse order, tripling the data
//!   size each step and reducing the communication distance by a factor of
//!   three"). The bandwidth-optimal AllReduce is
//!   `bandwidth_allreduce(P_dec)`: its tree-reversal Reduce-Scatter then
//!   runs distances increasing with message sizes shrinking, keeping the
//!   per-step congestion·size product constant (Appendix B) — deriving it
//!   from `P_inc` instead would pay `3^{s-1}`-fold congestion on the first
//!   step.

use crate::agpattern::ExchangeAg;
use crate::schedule::RouteHint;
use crate::util::{ceil_log, floor_log, is_power_of};

/// Step ordering of a pattern (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    /// Distances increasing (latency variant / Reduce-Scatter direction).
    Inc,
    /// Distances decreasing (AllGather-phase direction).
    Dec,
}

/// Map a step index according to the order.
fn ordered(k: usize, steps: usize, order: Order) -> usize {
    match order {
        Order::Inc => k,
        Order::Dec => steps - 1 - k,
    }
}

/// § 4 — Trivance: the distance sequence is `3^0, 3^1, …, 3^{s-1}` plus,
/// for `n` not a power of three (§4.4), a final adjustment exchange at
/// distance `q = ⌈(n − 3^s)/2⌉`. At every step each node exchanges with
/// both directions simultaneously, sending everything the peer is missing
/// (for powers of three: its entire radius-`R_{k-1}` ball, Lemma 4.2).
pub fn trivance(n: u32, order: Order) -> ExchangeAg {
    assert!(n >= 2);
    let s = floor_log(3, n as u64);
    let mut dists: Vec<i64> = (0..s).map(|k| 3i64.pow(k)).collect();
    if !is_power_of(3, n as u64) {
        let q = (n as u64 - 3u64.pow(s)).div_ceil(2) as i64;
        dists.push(q);
    }
    if order == Order::Dec {
        dists.reverse();
    }
    let steps = dists.len();
    debug_assert_eq!(steps, ceil_log(3, n as u64) as usize);
    ExchangeAg::new(format!("trivance(n={n})"), n, steps, move |k, r| {
        let d = dists[k];
        let ni = n as i64;
        vec![
            ((r as i64 + d).rem_euclid(ni) as u32, RouteHint::Minimal),
            ((r as i64 - d).rem_euclid(ni) as u32, RouteHint::Minimal),
        ]
    })
}

/// The §4.4 final-step distance, exposed for tests and docs.
pub fn trivance_final_distance(n: u32) -> Option<u64> {
    let s = floor_log(3, n as u64);
    if is_power_of(3, n as u64) {
        None
    } else {
        Some((n as u64 - 3u64.pow(s)).div_ceil(2))
    }
}

/// Bruck's radix-3 concatenation (§2.4): at step `k` every node sends to
/// `r + 3^k` and `r + 2·3^k`, all in one direction; the greedy assignment
/// reproduces the partial final step for arbitrary `n`. The paper's
/// evaluation uses the modified variant with shortest-path routing;
/// `unidirectional` reproduces the original, which drags long transfers the
/// long way around the ring.
pub fn bruck(n: u32, order: Order, unidirectional: bool) -> ExchangeAg {
    assert!(n >= 2);
    let steps = ceil_log(3, n as u64) as usize;
    let route = if unidirectional {
        RouteHint::Directed { dim: 0, dir: 1 }
    } else {
        RouteHint::Minimal
    };
    ExchangeAg::new(format!("bruck(n={n})"), n, steps, move |k, r| {
        let p = 3i64.pow(ordered(k, steps, order) as u32);
        let ni = n as i64;
        vec![
            ((r as i64 + p).rem_euclid(ni) as u32, route),
            ((r as i64 + 2 * p).rem_euclid(ni) as u32, route),
        ]
    })
}

/// Recursive Doubling / Rabenseifner (§2.4): step `k` pairs `r ↔ r XOR 2^k`.
/// Requires a power-of-two `n` (as in the paper's SST setup). `Order::Dec`
/// gives the recursive-halving direction used by the bandwidth-optimal
/// variant's phases.
pub fn recdoub(n: u32, order: Order) -> ExchangeAg {
    assert!(is_power_of(2, n as u64), "recursive doubling requires power-of-two n");
    let steps = ceil_log(2, n as u64) as usize;
    ExchangeAg::new(format!("recdoub(n={n})"), n, steps, move |k, r| {
        let d = 1u32 << ordered(k, steps, order);
        vec![(r ^ d, RouteHint::Minimal)]
    })
}

/// Swing's signed distance `ρ(k) = Σ_{i≤k} (−2)^i = (1 − (−2)^{k+1}) / 3`.
pub fn swing_rho(k: u32) -> i64 {
    (1 - (-2i64).pow(k + 1)) / 3
}

/// Swing's peer function `π(r, k)`: even ranks add `ρ(k)`, odd ranks
/// subtract it, so pairs alternate direction every step (§2.4).
pub fn swing_peer(r: u32, k: u32, n: u32) -> u32 {
    let rho = swing_rho(k);
    let ri = r as i64;
    let p = if r % 2 == 0 { ri + rho } else { ri - rho };
    p.rem_euclid(n as i64) as u32
}

/// Swing (De Sensi et al., NSDI'24): `log₂ n` steps with the alternating
/// peer function above. Requires a power-of-two `n`.
pub fn swing(n: u32, order: Order) -> ExchangeAg {
    assert!(is_power_of(2, n as u64), "swing requires power-of-two n");
    let steps = ceil_log(2, n as u64) as usize;
    ExchangeAg::new(format!("swing(n={n})"), n, steps, move |k, r| {
        vec![(
            swing_peer(r, ordered(k, steps, order) as u32, n),
            RouteHint::Minimal,
        )]
    })
}

/// §7 future-work extension — **full-port** generalization: with `p`
/// send ports per node (a D-dimensional torus offers `p = 2D`), exchange at
/// step `k` with peers at `±j·(p+1)^k` for `j = 1..p/2`, jointly reducing
/// all `p` incoming aggregates. Coverage grows by `(p+1)×` per step
/// (incoming radius-`R_{k-1}` balls at spacing `(p+1)^k` are pairwise
/// disjoint and tile the new ball exactly, the Lemma-4.2 argument with
/// radix `p+1`), completing AllReduce in `⌈log_{p+1} n⌉` steps — the Chan
/// et al. lower bound for `p`-port nodes. `p = 2` is exactly Trivance.
///
/// As §7 notes, the pattern trades heavily against congestion and wants
/// `(p+1)`-power sizes; it is exposed for study (see
/// `fullport_*` tests and the optimality tables), not as an evaluated
/// baseline.
pub fn fullport(n: u32, ports: u32, order: Order) -> ExchangeAg {
    assert!(n >= 2);
    assert!(ports >= 2 && ports % 2 == 0, "ports must be even (± per virtual dim)");
    let radix = (ports + 1) as u64;
    let s = floor_log(radix, n as u64);
    let mut dists: Vec<i64> = (0..s).map(|k| radix.pow(k) as i64).collect();
    if !is_power_of(radix, n as u64) {
        // final adjustment exchange, the §4.4 idea generalized: the 2·(p/2)
        // greedy-trimmed sends deliver exactly the missing arcs.
        let q = (n as u64 - radix.pow(s)).div_ceil(ports as u64).max(1) as i64;
        dists.push(q);
    }
    if order == Order::Dec {
        dists.reverse();
    }
    let steps = dists.len();
    let half = (ports / 2) as i64;
    ExchangeAg::new(format!("fullport{ports}(n={n})"), n, steps, move |k, r| {
        let d = dists[k];
        let ni = n as i64;
        let mut peers = Vec::with_capacity(ports as usize);
        for j in 1..=half {
            peers.push(((r as i64 + j * d).rem_euclid(ni) as u32, RouteHint::Minimal));
            peers.push(((r as i64 - j * d).rem_euclid(ni) as u32, RouteHint::Minimal));
        }
        peers
    })
}

/// Hamiltonian ring (§2.4): `n − 1` neighbor steps; each step passes the
/// single block the right neighbor is missing. Its tree reversal is the
/// classic bandwidth-optimal ring Reduce-Scatter (the Bucket building
/// block).
pub fn hamiltonian(n: u32) -> ExchangeAg {
    assert!(n >= 2);
    ExchangeAg::new(format!("ring(n={n})"), n, n as usize - 1, move |_k, r| {
        vec![((r + 1) % n, RouteHint::Minimal)]
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::{
        allgather_schedule, bandwidth_allreduce, latency_allreduce, reduce_scatter_schedule,
        AgPattern,
    };
    use crate::schedule::validate::{validate_allgather, validate_allreduce};
    use crate::util::ceil_log;

    #[test]
    fn trivance_pow3_valid_both_orders() {
        for n in [3u32, 9, 27, 81] {
            for order in [Order::Inc, Order::Dec] {
                let p = trivance(n, order);
                assert!(p.is_complete());
                assert_eq!(p.num_steps() as u32, ceil_log(3, n as u64));
                validate_allgather(&allgather_schedule(&p)).unwrap();
            }
            validate_allreduce(&latency_allreduce(&trivance(n, Order::Inc))).unwrap();
            validate_allreduce(&bandwidth_allreduce(&trivance(n, Order::Dec))).unwrap();
        }
    }

    #[test]
    fn trivance_arbitrary_n_latency_valid() {
        // §4.4 for every n in 2..=100: ⌈log₃ n⌉ steps, valid AllReduce.
        for n in 2u32..=100 {
            let p = trivance(n, Order::Inc);
            assert_eq!(p.num_steps() as u32, ceil_log(3, n as u64), "n={n}");
            assert!(p.is_complete(), "incomplete n={n}");
            validate_allgather(&allgather_schedule(&p))
                .unwrap_or_else(|e| panic!("allgather n={n}: {e}"));
            validate_allreduce(&latency_allreduce(&p))
                .unwrap_or_else(|e| panic!("latency n={n}: {e}"));
        }
    }

    #[test]
    fn trivance_arbitrary_n_bandwidth_valid() {
        for n in 2u32..=100 {
            let p = trivance(n, Order::Dec);
            if !p.is_complete() {
                // The registry falls back to virtual padding for such n;
                // record which sizes need it (none are expected below 100,
                // this guards the assumption).
                panic!("trivance dec incomplete at n={n}");
            }
            validate_allreduce(&bandwidth_allreduce(&p))
                .unwrap_or_else(|e| panic!("bandwidth n={n}: {e}"));
        }
    }

    #[test]
    fn trivance_final_distance_examples() {
        // Paper: n=7 → distance 2 (Fig. 4); n=32 → 3; "increases by one
        // for each two nodes exceeding 3^⌊log₃n⌋".
        assert_eq!(trivance_final_distance(7), Some(2));
        assert_eq!(trivance_final_distance(32), Some(3));
        assert_eq!(trivance_final_distance(27), None);
        assert_eq!(trivance_final_distance(4), Some(1));
    }

    #[test]
    fn trivance_latency_steps_match_theorem() {
        for (n, steps) in [(3u32, 1), (7, 2), (9, 2), (27, 3), (32, 4), (81, 4)] {
            assert_eq!(trivance(n, Order::Inc).num_steps(), steps, "n={n}");
        }
    }

    #[test]
    fn trivance_pow3_single_piece_messages() {
        // On powers of three no cuts are needed: every latency-variant
        // message is one m-byte aggregate.
        for n in [9u32, 27] {
            let s = latency_allreduce(&trivance(n, Order::Inc));
            for st in &s.steps {
                for sends in &st.sends {
                    for snd in sends {
                        assert_eq!(snd.pieces.len(), 1, "n={n}");
                    }
                }
            }
        }
    }

    #[test]
    fn trivance_rs_message_sizes_match_paper() {
        // §4.1: at RS step k each node sends m/3^{k+1} to each peer.
        let n = 27u32;
        let s = reduce_scatter_schedule(&trivance(n, Order::Dec));
        for (k, st) in s.steps.iter().enumerate() {
            for sends in &st.sends {
                for snd in sends {
                    let rel = snd.rel_bytes(n);
                    let expect = 1.0 / 3f64.powi(k as i32 + 1);
                    assert!(
                        (rel - expect).abs() < 1e-9,
                        "step {k}: rel {rel} expect {expect}"
                    );
                }
            }
        }
    }

    #[test]
    fn bandwidth_optimality_lemma_4_1() {
        // Lemma 4.1: 2m(1 − 1/n) bytes per node over both phases.
        for n in [9u32, 27, 81] {
            let s = bandwidth_allreduce(&trivance(n, Order::Dec));
            for r in 0..n {
                let sent = s.node_sent_rel_bytes(r);
                let expect = 2.0 * (1.0 - 1.0 / n as f64);
                assert!(
                    (sent - expect).abs() < 1e-9,
                    "n={n} r={r}: sent {sent}, expect {expect}"
                );
            }
        }
    }

    #[test]
    fn bruck_valid_all_n() {
        for n in 2u32..=100 {
            let p = bruck(n, Order::Inc, false);
            assert!(p.is_complete(), "n={n}");
            validate_allgather(&allgather_schedule(&p))
                .unwrap_or_else(|e| panic!("bruck ag n={n}: {e}"));
            validate_allreduce(&latency_allreduce(&p))
                .unwrap_or_else(|e| panic!("bruck L n={n}: {e}"));
            let pd = bruck(n, Order::Dec, false);
            assert!(pd.is_complete(), "dec n={n}");
            validate_allreduce(&bandwidth_allreduce(&pd))
                .unwrap_or_else(|e| panic!("bruck B n={n}: {e}"));
        }
    }

    #[test]
    fn bruck_matches_trivance_steps() {
        for n in [3u32, 9, 27, 81, 64] {
            assert_eq!(
                bruck(n, Order::Inc, false).num_steps(),
                trivance(n, Order::Inc).num_steps()
            );
        }
    }

    #[test]
    fn recdoub_valid() {
        for n in [2u32, 4, 8, 16, 32, 64] {
            let p = recdoub(n, Order::Inc);
            assert_eq!(p.num_steps() as u32, ceil_log(2, n as u64));
            validate_allgather(&allgather_schedule(&p)).unwrap();
            validate_allreduce(&latency_allreduce(&p)).unwrap();
            validate_allreduce(&bandwidth_allreduce(&recdoub(n, Order::Dec))).unwrap();
        }
    }

    #[test]
    fn swing_rho_sequence() {
        assert_eq!(swing_rho(0), 1);
        assert_eq!(swing_rho(1), -1);
        assert_eq!(swing_rho(2), 3);
        assert_eq!(swing_rho(3), -5);
        assert_eq!(swing_rho(4), 11);
    }

    #[test]
    fn swing_peer_symmetric() {
        for n in [8u32, 16, 32] {
            for k in 0..ceil_log(2, n as u64) {
                for r in 0..n {
                    let p = swing_peer(r, k, n);
                    assert_eq!(swing_peer(p, k, n), r, "n={n} k={k} r={r}");
                    assert_ne!(p, r);
                }
            }
        }
    }

    #[test]
    fn swing_valid() {
        for n in [2u32, 4, 8, 16, 32, 64] {
            let p = swing(n, Order::Inc);
            assert!(p.is_complete(), "n={n}");
            validate_allgather(&allgather_schedule(&p))
                .unwrap_or_else(|e| panic!("swing ag n={n}: {e}"));
            validate_allreduce(&latency_allreduce(&p))
                .unwrap_or_else(|e| panic!("swing L n={n}: {e}"));
            validate_allreduce(&bandwidth_allreduce(&swing(n, Order::Dec)))
                .unwrap_or_else(|e| panic!("swing B n={n}: {e}"));
        }
    }

    #[test]
    fn hamiltonian_valid() {
        for n in [2u32, 3, 5, 9, 16] {
            let p = hamiltonian(n);
            assert_eq!(p.num_steps(), n as usize - 1);
            validate_allgather(&allgather_schedule(&p)).unwrap();
            validate_allreduce(&bandwidth_allreduce(&p)).unwrap();
        }
    }

    #[test]
    fn fullport_is_trivance_at_two_ports() {
        for n in [9u32, 27, 32] {
            let fp = fullport(n, 2, Order::Inc);
            let tv = trivance(n, Order::Inc);
            assert_eq!(fp.num_steps(), tv.num_steps(), "n={n}");
            assert!(fp.is_complete());
        }
    }

    #[test]
    fn fullport_meets_chan_lower_bound() {
        // ⌈log_{2D+1} n⌉ steps with 2D ports (§7 / Chan et al.)
        for (n, ports, steps) in [
            (25u32, 4u32, 2usize), // log₅ 25
            (125, 4, 3),
            (49, 6, 2), // log₇ 49
            (81, 8, 2), // log₉ 81
        ] {
            let p = fullport(n, ports, Order::Inc);
            assert_eq!(p.num_steps(), steps, "n={n} p={ports}");
            assert!(p.is_complete(), "n={n} p={ports}");
            validate_allreduce(&latency_allreduce(&p))
                .unwrap_or_else(|e| panic!("fullport n={n} p={ports}: {e}"));
        }
    }

    #[test]
    fn fullport_arbitrary_n_latency_valid() {
        for n in 3u32..=60 {
            for ports in [4u32, 6] {
                let p = fullport(n, ports, Order::Inc);
                if !p.is_complete() {
                    // the generalized adjustment step is best-effort off
                    // (p+1)-powers; record which sizes it covers
                    continue;
                }
                validate_allreduce(&latency_allreduce(&p))
                    .unwrap_or_else(|e| panic!("fullport n={n} p={ports}: {e}"));
            }
        }
    }

    #[test]
    fn fullport_bandwidth_valid_on_radix_powers() {
        for (n, ports) in [(25u32, 4u32), (49, 6)] {
            let p = fullport(n, ports, Order::Dec);
            validate_allreduce(&bandwidth_allreduce(&p))
                .unwrap_or_else(|e| panic!("fullport B n={n} p={ports}: {e}"));
        }
    }

    #[test]
    fn rabenseifner_data_volume() {
        // Classic bound for the B variants of every pattern.
        for (name, p) in [
            ("recdoub", recdoub(16, Order::Dec)),
            ("swing", swing(16, Order::Dec)),
            ("ring", hamiltonian(16)),
        ] {
            let s = bandwidth_allreduce(&p);
            let expect = 2.0 * (1.0 - 1.0 / 16.0);
            for r in 0..16 {
                let sent = s.node_sent_rel_bytes(r);
                assert!(
                    (sent - expect).abs() < 1e-9,
                    "{name} r={r}: sent {sent}, expect {expect}"
                );
            }
        }
    }
}
