//! Schedule analysis: per-step link loads, congestion, transmitted volume.
//!
//! Bridges the schedule IR to the paper's congestion-aware cost model
//! (Eq. 1): for each step `k` it computes the chunk size `m_k` and the
//! congestion `c_k` ("number of chunks sharing a link") by actually routing
//! every message on the topology and accounting per-link byte loads; the
//! bottleneck link determines the step's transmission term.
//!
//! [`analyze`] runs on the uniform fabric; [`analyze_with_model`] runs
//! under a heterogeneous [`NetModel`] — messages detour around down links,
//! the bottleneck is the most *time-expensive* link (`load / bw_scale`,
//! still in units of `m` at the base bandwidth), and the per-step route
//! latency maxima carry the per-link propagation/processing scales for
//! [`crate::cost::eq1_with_hops_model`]. On a uniform model the two are
//! bit-identical.

use super::Schedule;
use crate::net::{LinkClass, Mutation, NetModel, Timeline, Unreachable};
use crate::topology::Torus;

/// Per-step figures, all byte quantities in units of the vector size `m`.
#[derive(Clone, Debug)]
pub struct StepStats {
    /// Max over links of the summed payload crossing it, divided by the
    /// link's bandwidth scale (⇒ the step's transmission delay is
    /// `beta * m * max_link_rel`). On a uniform fabric this is simply the
    /// most-loaded link's payload.
    pub max_link_rel: f64,
    /// Max messages sharing one link (the paper's `c_k` chunk count).
    pub max_link_msgs: u32,
    /// Largest single message in the step (`m_k`).
    pub max_msg_rel: f64,
    /// Total payload injected in the step.
    pub total_rel: f64,
    /// Longest route (hops) of any message in the step.
    pub max_hops: u32,
    /// Max over messages of the route's summed propagation-latency scales
    /// (`== max_hops` on a uniform fabric).
    pub max_route_lat_rel: f64,
    /// Max over messages of the route's summed processing-latency scales
    /// (`== max_hops` on a uniform fabric).
    pub max_route_proc_rel: f64,
    /// Number of messages.
    pub messages: usize,
}

/// Whole-schedule figures.
#[derive(Clone, Debug)]
pub struct ScheduleStats {
    pub steps: Vec<StepStats>,
    /// Max over nodes of total injected payload (units of m) — the Δ
    /// numerator (per-port bandwidth term uses this divided by ports).
    pub max_node_sent_rel: f64,
    /// Σ_k max_link_rel — the transmission-delay figure Θ·(m·β) of
    /// Appendix B, in units of m·β.
    pub tx_delay_rel: f64,
}

/// Analyze `s` on topology `t` (uniform fabric).
pub fn analyze(s: &Schedule, t: &Torus) -> ScheduleStats {
    analyze_with_model(s, &NetModel::uniform(t))
}

/// Analyze `s` under a heterogeneous [`NetModel`]: routes detour around
/// down links, and the per-step bottleneck is the most time-expensive link
/// (`load / bw_scale`). Bit-identical to [`analyze`] on a uniform model.
/// Panics on a partitioned fabric — use [`try_analyze_with_model`] to
/// surface that as an error.
pub fn analyze_with_model(s: &Schedule, model: &NetModel) -> ScheduleStats {
    try_analyze_with_model(s, model).unwrap_or_else(|e| panic!("analyze: {e}"))
}

/// [`analyze_with_model`], returning [`Unreachable`] when the model's down
/// set disconnects a pair the schedule needs.
pub fn try_analyze_with_model(
    s: &Schedule,
    model: &NetModel,
) -> Result<ScheduleStats, Unreachable> {
    let t = model.torus();
    assert_eq!(s.n, t.n(), "schedule/topology node count mismatch");
    let mut steps = Vec::with_capacity(s.steps.len());
    let mut loads = vec![0f64; t.num_links()];
    let mut counts = vec![0u32; t.num_links()];
    for step in &s.steps {
        loads.iter_mut().for_each(|x| *x = 0.0);
        counts.iter_mut().for_each(|x| *x = 0);
        let mut max_msg_rel = 0f64;
        let mut total_rel = 0f64;
        let mut max_hops = 0u32;
        let mut max_route_lat_rel = 0f64;
        let mut max_route_proc_rel = 0f64;
        let mut messages = 0usize;
        for (src, sends) in step.sends.iter().enumerate() {
            for send in sends {
                let rel = send.rel_bytes(s.n_blocks);
                if rel == 0.0 {
                    continue;
                }
                messages += 1;
                max_msg_rel = max_msg_rel.max(rel);
                total_rel += rel;
                let route = model.try_route(src as u32, send.to, send.route)?;
                max_hops = max_hops.max(route.len() as u32);
                let mut lat_rel = 0f64;
                let mut proc_rel = 0f64;
                for link in route {
                    let idx = t.link_index(link);
                    loads[idx] += rel;
                    counts[idx] += 1;
                    lat_rel += model.lat_scale(idx);
                    proc_rel += model.proc_scale(idx);
                }
                max_route_lat_rel = max_route_lat_rel.max(lat_rel);
                max_route_proc_rel = max_route_proc_rel.max(proc_rel);
            }
        }
        let max_link_rel = loads
            .iter()
            .enumerate()
            .map(|(idx, &ld)| ld / model.bw_scale(idx))
            .fold(0f64, f64::max);
        let max_link_msgs = counts.iter().copied().max().unwrap_or(0);
        steps.push(StepStats {
            max_link_rel,
            max_link_msgs,
            max_msg_rel,
            total_rel,
            max_hops,
            max_route_lat_rel,
            max_route_proc_rel,
            messages,
        });
    }
    let max_node_sent_rel = (0..s.n)
        .map(|r| s.node_sent_rel_bytes(r))
        .fold(0f64, f64::max);
    let tx_delay_rel = steps.iter().map(|st| st.max_link_rel).sum();
    Ok(ScheduleStats { steps, max_node_sent_rel, tx_delay_rel })
}

/// Analytic envelope of a schedule under a time-varying fabric: stats on
/// the **best** and **worst static projections** of the timeline — per
/// link, the maximum bandwidth scale / minimum latency scales over every
/// state the timeline visits (base state included) on the best side, and
/// the symmetric minima/maxima on the worst side. A timeline can *upgrade*
/// a link above its base class (e.g. a recovery preset on a degraded
/// fabric), so the best side must fold the mutations in too — the base
/// model alone is not a lower envelope.
/// [`crate::cost::eq1_with_hops_model`] applied to the pair brackets the
/// true dynamic Eq. 1 cost: the real collective sees each state for only
/// part of its lifetime. Down windows ([`Mutation::SetDown`]) contribute
/// their surrounding class scales, not an infinite cost — stall time is
/// the simulator's to measure, a static formula cannot bound it.
pub fn analyze_timeline_envelope(
    s: &Schedule,
    base: &NetModel,
    timeline: &Timeline,
) -> Result<(ScheduleStats, ScheduleStats), Unreachable> {
    if timeline.is_empty() {
        // both envelope sides ARE the base analysis — don't run it twice
        let best = try_analyze_with_model(s, base)?;
        let worst = best.clone();
        return Ok((best, worst));
    }
    let mut best_model = base.clone();
    let mut worst_model = base.clone();
    for e in timeline.epochs() {
        for m in &e.mutations {
            if let Mutation::SetClass { link, class } = *m {
                let l = link as usize;
                let b = *best_model.class(l);
                best_model.set_class(
                    l,
                    LinkClass::new(
                        b.bw_scale.max(class.bw_scale),
                        b.lat_scale.min(class.lat_scale),
                        b.proc_scale.min(class.proc_scale),
                    ),
                );
                let w = *worst_model.class(l);
                worst_model.set_class(
                    l,
                    LinkClass::new(
                        w.bw_scale.min(class.bw_scale),
                        w.lat_scale.max(class.lat_scale),
                        w.proc_scale.max(class.proc_scale),
                    ),
                );
            }
        }
    }
    let best = try_analyze_with_model(s, &best_model)?;
    let worst = try_analyze_with_model(s, &worst_model)?;
    Ok((best, worst))
}

impl ScheduleStats {
    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockset::BlockSet;
    use crate::schedule::{Kind, Piece, RouteHint, Send};

    #[test]
    fn analyze_neighbor_exchange() {
        // 4-ring, everyone sends a full vector to the right neighbor.
        let n = 4;
        let t = Torus::ring(n);
        let mut s = Schedule::new("x", n, n);
        let st = s.push_step();
        for r in 0..n {
            st.push(
                r,
                Send {
                    to: (r + 1) % n,
                    pieces: vec![Piece {
                        blocks: BlockSet::full(n),
                        contrib: BlockSet::singleton(r, n),
                        kind: Kind::Reduce,
                    }],
                    route: RouteHint::Minimal,
                },
            );
        }
        let st = analyze(&s, &t);
        assert_eq!(st.num_steps(), 1);
        let s0 = &st.steps[0];
        assert!((s0.max_link_rel - 1.0).abs() < 1e-12); // one message per link
        assert_eq!(s0.max_link_msgs, 1);
        assert_eq!(s0.max_hops, 1);
        assert!((st.max_node_sent_rel - 1.0).abs() < 1e-12);
    }

    #[test]
    fn analyze_distance_two_congestion() {
        // 6-ring, everyone sends distance +2: each link carries 2 messages.
        let n = 6;
        let t = Torus::ring(n);
        let mut s = Schedule::new("d2", n, n);
        let st = s.push_step();
        for r in 0..n {
            st.push(
                r,
                Send {
                    to: (r + 2) % n,
                    pieces: vec![Piece {
                        blocks: BlockSet::full(n),
                        contrib: BlockSet::singleton(r, n),
                        kind: Kind::Reduce,
                    }],
                    route: RouteHint::Minimal,
                },
            );
        }
        let stats = analyze(&s, &t);
        assert_eq!(stats.steps[0].max_link_msgs, 2);
        assert!((stats.steps[0].max_link_rel - 2.0).abs() < 1e-12);
        assert_eq!(stats.steps[0].max_hops, 2);
    }

    #[test]
    fn model_analysis_scales_bottleneck_and_detours() {
        // 4-ring neighbor exchange: uniformly one full vector per link
        let n = 4;
        let t = Torus::ring(n);
        let mut s = Schedule::new("x", n, n);
        let st = s.push_step();
        for r in 0..n {
            st.push(
                r,
                Send {
                    to: (r + 1) % n,
                    pieces: vec![Piece {
                        blocks: BlockSet::full(n),
                        contrib: BlockSet::singleton(r, n),
                        kind: Kind::Reduce,
                    }],
                    route: RouteHint::Minimal,
                },
            );
        }
        // uniform model is bit-identical to plain analyze
        let plain = analyze(&s, &t);
        let uni = analyze_with_model(&s, &NetModel::uniform(&t));
        assert_eq!(plain.tx_delay_rel.to_bits(), uni.tx_delay_rel.to_bits());
        assert_eq!(
            plain.steps[0].max_route_lat_rel.to_bits(),
            uni.steps[0].max_route_lat_rel.to_bits()
        );
        // slow 0->1 by 2x: that link's relative cost doubles
        let mut m = NetModel::uniform(&t);
        let l01 = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 });
        m.set_class(l01, crate::net::LinkClass::slowdown(2.0));
        let slow = analyze_with_model(&s, &m);
        assert!((slow.steps[0].max_link_rel - 2.0).abs() < 1e-12);
        // down 0->1: the 0->1 message detours the long way (3 hops), and
        // every load sits on an unscaled link again
        let mut f = NetModel::uniform(&t);
        f.set_down(l01, true);
        let det = analyze_with_model(&s, &f);
        assert_eq!(det.steps[0].max_hops, 3);
        assert!((det.steps[0].max_route_lat_rel - 3.0).abs() < 1e-12);
    }

    #[test]
    fn partitioned_model_errs_instead_of_panicking() {
        use crate::topology::Link;
        let n = 4;
        let t = Torus::ring(n);
        let mut s = Schedule::new("x", n, n);
        let st = s.push_step();
        st.push(
            0,
            Send {
                to: 1,
                pieces: vec![Piece {
                    blocks: BlockSet::full(n),
                    contrib: BlockSet::singleton(0, n),
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            },
        );
        let mut m = NetModel::uniform(&t);
        m.set_down(t.link_index(Link { node: 0, dim: 0, dir: 1 }), true);
        m.set_down(t.link_index(Link { node: 2, dim: 0, dir: -1 }), true);
        let err = try_analyze_with_model(&s, &m).unwrap_err();
        assert_eq!((err.src, err.dst), (0, 1));
    }

    #[test]
    fn timeline_envelope_brackets_the_static_cases() {
        use crate::net::{Epoch, LinkClass, Mutation, Timeline};
        use crate::topology::Link;
        let n = 4;
        let t = Torus::ring(n);
        let mut s = Schedule::new("x", n, n);
        let st = s.push_step();
        for r in 0..n {
            st.push(
                r,
                Send {
                    to: (r + 1) % n,
                    pieces: vec![Piece {
                        blocks: BlockSet::full(n),
                        contrib: BlockSet::singleton(r, n),
                        kind: Kind::Reduce,
                    }],
                    route: RouteHint::Minimal,
                },
            );
        }
        let base = NetModel::uniform(&t);
        let l = t.link_index(Link { node: 0, dim: 0, dir: 1 });
        // slow 4x, then recover: worst projection pins the link at 4x slow
        let tl = Timeline::new(vec![
            Epoch {
                t: 1e-6,
                mutations: vec![Mutation::SetClass { link: l as u32, class: LinkClass::slowdown(4.0) }],
            },
            Epoch {
                t: 2e-6,
                mutations: vec![Mutation::SetClass { link: l as u32, class: LinkClass::UNIFORM }],
            },
        ]);
        let (best, worst) = analyze_timeline_envelope(&s, &base, &tl).unwrap();
        assert!((best.steps[0].max_link_rel - 1.0).abs() < 1e-12);
        assert!((worst.steps[0].max_link_rel - 4.0).abs() < 1e-12);
        // empty timeline: envelope degenerates to the base on both sides
        let (b2, w2) = analyze_timeline_envelope(&s, &base, &Timeline::empty()).unwrap();
        assert_eq!(b2.tx_delay_rel.to_bits(), w2.tx_delay_rel.to_bits());
        // a timeline can UPGRADE a link above its base class (recovery on a
        // degraded fabric): the best side must fold that in, the worst side
        // keeps the degraded base
        let mut degraded = NetModel::uniform(&t);
        degraded.set_class(l, LinkClass::slowdown(4.0));
        let recover = Timeline::new(vec![Epoch {
            t: 1e-6,
            mutations: vec![Mutation::SetClass { link: l as u32, class: LinkClass::UNIFORM }],
        }]);
        let (b3, w3) = analyze_timeline_envelope(&s, &degraded, &recover).unwrap();
        assert!((b3.steps[0].max_link_rel - 1.0).abs() < 1e-12, "best folds the upgrade in");
        assert!((w3.steps[0].max_link_rel - 4.0).abs() < 1e-12, "worst keeps the degraded base");
    }

    #[test]
    fn directed_route_congestion_differs() {
        // distance 4 on a 6-ring: minimal routes 2 hops backward; directed
        // +1 routes 4 hops forward.
        let n = 6;
        let t = Torus::ring(n);
        let mk = |route| {
            let mut s = Schedule::new("d", n, n);
            let st = s.push_step();
            st.push(
                0,
                Send {
                    to: 4,
                    pieces: vec![Piece {
                        blocks: BlockSet::full(n),
                        contrib: BlockSet::singleton(0, n),
                        kind: Kind::Reduce,
                    }],
                    route,
                },
            );
            s
        };
        let min = analyze(&mk(RouteHint::Minimal), &t);
        let fwd = analyze(&mk(RouteHint::Directed { dim: 0, dir: 1 }), &t);
        assert_eq!(min.steps[0].max_hops, 2);
        assert_eq!(fwd.steps[0].max_hops, 4);
    }
}
