//! Fault-aware schedule rewriting: complete an AllReduce whose fabric lost
//! a link (or node) *between* steps — by changing the **schedule**, not
//! just the routes.
//!
//! PR 3's answer to a down link was detour routing: keep every send and let
//! [`crate::net::NetModel::route`] find a BFS path around the hole. That
//! keeps the collective correct but piles the blocked messages' full
//! payloads onto long alternate paths *inside* the original steps, where
//! they collide with the step's own traffic — the congestion (and thus the
//! completion time) of the fault-hit step roughly doubles on a ring.
//!
//! [`rewrite_for_fault`] instead **shrinks and substitutes** on the
//! BlockSet algebra:
//!
//! 1. Steps before [`Fault::step`] ran on the healthy fabric — copied
//!    verbatim.
//! 2. In every later step, sends whose nominal route crosses a dead link
//!    (or touches a dead node) are **dropped**, and every surviving send is
//!    **shrunk** to what its sender still holds: a Reduce piece's
//!    contributor set becomes the maximal union of whole atoms the sender
//!    kept (a partial aggregate cannot be un-summed — the same exact-cover
//!    rule [`super::validate`] enforces), split per block group when the
//!    cascade left different blocks with different holdings; a Set piece
//!    keeps only the blocks the sender actually completed.
//! 3. One appended **cleanup step** settles the debts: every node missing
//!    contributors for a block receives them from the nearest (post-fault
//!    BFS distance, deterministic tie-break) donor — preferring a single
//!    `Set` piece from a node that already completed the block (overwriting
//!    the receiver's partial with the final value, which the validator
//!    semantics permit), falling back to `Reduce` pieces assembled greedily
//!    from whole atoms held anywhere (every rank always holds its own
//!    singleton atom, so link faults are always recoverable).
//!
//! The result is a *valid* AllReduce ([`super::validate::validate_allreduce`]
//! passes whenever no node died) that pays one extra `α` but keeps the
//! original steps free of detour traffic. **Measured trade-off**
//! (`tools/pysim/eval_dynamic.py`, both engines agree): rewriting wins
//! where the remaining schedule would re-cross the dead cable step after
//! step — ring Bucket-B re-crosses once per neighbor step and rewriting
//! beats detour by +59%/+16% at 4/256 KiB on ring-9 — while for shallow
//! schedules (trivance-L: one blocked crossing) the detour overlaps into
//! spare fluid capacity and detour-in-place stays within a few percent of
//! the rewrite. Rewriting is also the only strategy that *completes* under
//! node death, where detour routing has no path at all. Simulate rewritten
//! schedules with [`crate::sim::SimPlan::build_faulted`] so pre-fault
//! steps route on the healthy fabric.
//!
//! Node death is supported (`dead_nodes`): the dead node's sends and
//! receives vanish from post-fault steps and survivors recover its already
//! propagated contribution; if the death predates any propagation
//! (`fault.step == 0`), its contribution is unrecoverable and rewriting
//! errs — honestly, rather than completing a collective that silently lost
//! an input. Mirrored in `tools/pysim/mirror.py` (`rewrite_for_fault`);
//! keep donor selection order in lockstep.
//!
//! **Fault sequences** ([`rewrite_for_faults`]): each fault is applied
//! incrementally against the already-rewritten schedule on the
//! already-degraded model, so `fault.step` indexes the *evolving* schedule
//! — a second fault landing during a previous fault's cleanup step is just
//! an ordinary step of the input schedule. Simulate the result with
//! [`crate::sim::SimPlan::build_staged`], one stage per fault.
//!
//! **Padded (virtual-rank) schedules** ([`rewrite_for_fault_hosted`],
//! [`rewrite_collective_for_faults`]): the rewrite machine runs in
//! *virtual* space on the collective's `exec` schedule, with the padding
//! host map translating every physical question (routing, liveness, donor
//! distance) to real ranks — co-hosted sends are local memory moves that no
//! link fault can block, and co-hosted donors sit at distance 0. The
//! rewritten virtual schedule is then collapsed back onto the real torus
//! through [`crate::algo::registry::collapse_by_hosts`], so Bruck/Trivance
//! non-power sizes rewrite instead of erroring.

use super::{Kind, Piece, RouteHint, Schedule, Send, Step};
use crate::algo::registry::{collapse_by_hosts, BuiltCollective};
use crate::blockset::BlockSet;
use crate::net::NetModel;
use crate::topology::Link;

/// A fabric fault observed between schedule steps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Fault {
    /// First step that can no longer use the failed resources (steps
    /// `< step` completed on the healthy fabric).
    pub step: usize,
    /// Dense directed-link indices that died.
    pub down_links: Vec<usize>,
    /// Nodes that died entirely (every incident directed link down, the
    /// node excluded from the rest of the collective).
    pub dead_nodes: Vec<u32>,
}

impl Fault {
    /// A single-link death before `step`.
    pub fn link(step: usize, link: usize) -> Fault {
        Fault { step, down_links: vec![link], dead_nodes: Vec::new() }
    }

    /// A single-node death before `step`.
    pub fn node(step: usize, node: u32) -> Fault {
        Fault { step, down_links: Vec::new(), dead_nodes: vec![node] }
    }

    /// Deterministic fingerprint of the fault (never 0), mixed into
    /// [`crate::sim::PlanKey::timeline_fp`] so fault-routed plans can never
    /// collide with static ones in the plan cache.
    pub fn fingerprint(&self) -> u64 {
        let mut h = crate::util::Fnv::new();
        h.mix(self.step as u64);
        for &l in &self.down_links {
            h.mix(1);
            h.mix(l as u64);
        }
        for &v in &self.dead_nodes {
            h.mix(2);
            h.mix(v as u64);
        }
        h.finish_nonzero()
    }

    /// The post-fault model: `base` plus this fault's links down, plus
    /// every directed link incident to a dead node (in and out).
    pub fn apply(&self, base: &NetModel) -> NetModel {
        let mut post = base.clone();
        let torus = base.torus().clone();
        for &l in &self.down_links {
            post.set_down(l, true);
        }
        for &node in &self.dead_nodes {
            for d in 0..torus.ndims() {
                for dir in [1i8, -1] {
                    // outbound: the node's own link
                    post.set_down(torus.link_index(Link { node, dim: d as u8, dir }), true);
                    // inbound: the neighbor's link pointing at the node
                    let nb = torus.neighbor(node, d, -(dir as i64));
                    post.set_down(torus.link_index(Link { node: nb, dim: d as u8, dir }), true);
                }
            }
        }
        post
    }
}

/// Per-(node, block) symbolic storage, as in the validator: the disjoint
/// aggregates ("atoms") the node keeps, plus their cached union.
#[derive(Clone)]
struct Cell {
    atoms: Vec<BlockSet>,
    total: BlockSet,
}

impl Cell {
    fn new(own: u32, n: u32) -> Cell {
        let s = BlockSet::singleton(own, n);
        Cell { atoms: vec![s.clone()], total: s }
    }

    /// The maximal subset of `target` expressible as a union of whole
    /// atoms — the largest contributor set this node can legally send.
    fn max_cover(&self, target: &BlockSet) -> BlockSet {
        let mut cover = BlockSet::empty();
        for a in &self.atoms {
            if target.is_superset(a) {
                cover.union_with(a);
            }
        }
        cover
    }

    fn absorb(&mut self, piece: &Piece, n: u32) {
        match piece.kind {
            Kind::Reduce => {
                self.atoms.push(piece.contrib.clone());
                self.total.union_with(&piece.contrib);
            }
            Kind::Set => {
                let full = BlockSet::full(n);
                self.atoms = vec![full.clone()];
                self.total = full;
            }
        }
    }
}

/// Rewrite `s` around `fault` (module docs). `base` is the healthy
/// pre-fault model the schedule was planned for. Deterministic; errs when a
/// dead node's contribution is unrecoverable or the surviving fabric cannot
/// reach a debtor.
pub fn rewrite_for_fault(s: &Schedule, base: &NetModel, fault: &Fault) -> Result<Schedule, String> {
    rewrite_for_fault_hosted(s, base, fault, None)
}

/// [`rewrite_for_fault`] for a schedule whose ranks are *virtual*:
/// `hosts[v]` is the real rank hosting virtual rank `v` (a padded
/// collective's [`crate::algo::registry::Padding::hosts`]). The BlockSet
/// algebra runs in virtual space; routing, node liveness, and donor
/// distances are evaluated on the real fabric through the host map.
/// Co-hosted sends (same real host) are local moves — never blocked, and
/// co-hosted donors are at distance 0. With `hosts = None` the rank spaces
/// coincide and this is exactly [`rewrite_for_fault`].
pub fn rewrite_for_fault_hosted(
    s: &Schedule,
    base: &NetModel,
    fault: &Fault,
    hosts: Option<&[u32]>,
) -> Result<Schedule, String> {
    let torus = base.torus();
    match hosts {
        None => assert_eq!(s.n, torus.n(), "schedule/topology node count mismatch"),
        Some(h) => {
            assert_eq!(h.len(), s.n as usize, "host map must cover every virtual rank");
            assert!(h.iter().all(|&x| x < torus.n()), "host map points past the torus");
        }
    }
    let real = |v: u32| -> u32 { hosts.map_or(v, |h| h[v as usize]) };
    let n = s.n;
    let nb = s.n_blocks;
    // Without a host map, virtually-padded schedules keep their contributor
    // sets in a rank space larger than `n`: the shrink/substitute algebra
    // would be incoherent there, so refuse loudly — callers pass the
    // padding's host map and rewrite the `exec` schedule instead (see
    // [`rewrite_collective_for_faults`]).
    if hosts.is_none() {
        for step in &s.steps {
            for sends in &step.sends {
                for send in sends {
                    for piece in &send.pieces {
                        if piece.contrib.intervals().any(|(_, e)| e > n) {
                            return Err(format!(
                                "{}: contributor sets live in a virtual (padded) rank \
                                 space — rewrite the exec schedule through the padding \
                                 host map (rewrite_collective_for_faults)",
                                s.name
                            ));
                        }
                    }
                }
            }
        }
    }
    let post = fault.apply(base);
    // liveness is a *real*-node property: a virtual rank is dead iff its
    // host died
    let mut dead_real = vec![false; torus.n() as usize];
    for &v in &fault.dead_nodes {
        dead_real[v as usize] = true;
    }
    let dead = |v: u32| -> bool { dead_real[real(v) as usize] };

    let mut state: Vec<Vec<Cell>> = (0..n)
        .map(|r| (0..nb).map(|_| Cell::new(r, n)).collect())
        .collect();

    let mut out = Schedule::new(format!("{}+rewrite", s.name), n, nb);
    for (k, step) in s.steps.iter().enumerate() {
        let snapshot: Vec<Vec<Cell>> = state.clone();
        let mut new_step = Step::new(n);
        for (src, sends) in step.sends.iter().enumerate() {
            for send in sends {
                let keep: Option<Send> = if k < fault.step {
                    // pre-fault: ran on the healthy fabric, verbatim
                    Some(send.clone())
                } else if dead(src as u32) || dead(send.to) {
                    None
                } else if real(src as u32) == real(send.to) {
                    // co-hosted: a local memory move — no network link to
                    // block, but the payload still shrinks to holdings
                    shrink_send(send, &snapshot[src], n, nb)
                } else {
                    let nominal = base
                        .try_route(real(src as u32), real(send.to), send.route)
                        .map_err(|e| format!("{}: step {k}: {e}", s.name))?;
                    let blocked =
                        nominal.iter().any(|&l| post.is_down(torus.link_index(l)));
                    if blocked {
                        None // dropped; the cleanup step settles the debt
                    } else {
                        shrink_send(send, &snapshot[src], n, nb)
                    }
                };
                if let Some(snd) = keep {
                    // apply to state (receiver side), then record
                    for piece in &snd.pieces {
                        for b in piece.blocks.iter() {
                            state[snd.to as usize][b as usize].absorb(piece, n);
                        }
                    }
                    new_step.sends[src].push(snd);
                }
            }
        }
        out.steps.push(new_step);
    }

    // Cleanup: settle every (alive node, block) still missing contributors.
    let snapshot: Vec<Vec<Cell>> = state.clone();
    let mut cleanup = Step::new(n);
    let full = BlockSet::full(n);
    let mut any = false;
    for r in 0..n as usize {
        if dead(r as u32) {
            continue;
        }
        // every donor candidate's distance to this receiver, in one
        // reverse BFS (the per-(block, donor) forward BFS this replaces
        // dominated rewrite time on larger tori); hosted: distances are
        // between real hosts, so co-hosted donors sit at distance 0
        let dist_to_r = post.distances_to(real(r as u32));
        // blocks grouped per donor for Set pieces, per (donor, contrib) for
        // Reduce pieces — deterministic insertion order
        let mut set_groups: Vec<(u32, Vec<u32>)> = Vec::new();
        let mut reduce_groups: Vec<(u32, BlockSet, Vec<u32>)> = Vec::new();
        for b in 0..nb as usize {
            if state[r][b].total.is_full(n) {
                continue;
            }
            let missing = full.difference(&state[r][b].total);
            // preferred: one Set piece from the nearest completed donor
            let mut set_donor: Option<(usize, u32)> = None; // (dist, donor)
            for d in 0..n {
                if d as usize == r || dead(d) {
                    continue;
                }
                if !snapshot[d as usize][b].total.is_full(n) {
                    continue;
                }
                let Some(dist) = dist_to_r[real(d) as usize] else { continue };
                let better = match set_donor {
                    None => true,
                    Some((bd, _)) => dist < bd,
                };
                if better {
                    set_donor = Some((dist, d));
                }
            }
            if let Some((_, d)) = set_donor {
                match set_groups.iter_mut().find(|(g, _)| *g == d) {
                    Some((_, blocks)) => blocks.push(b as u32),
                    None => set_groups.push((d, vec![b as u32])),
                }
                continue;
            }
            // fallback: assemble the missing set from whole atoms, greedily
            // largest-cover-first (nearest donor, lowest id on ties)
            let mut m = missing;
            while !m.is_empty() {
                let mut best: Option<(u64, usize, u32, BlockSet)> = None; // (len, dist, donor, cover)
                for d in 0..n {
                    if d as usize == r || dead(d) {
                        continue;
                    }
                    let cover = snapshot[d as usize][b].max_cover(&m);
                    if cover.is_empty() {
                        continue;
                    }
                    let Some(dist) = dist_to_r[real(d) as usize] else { continue };
                    let better = match &best {
                        None => true,
                        Some((bl, bd, _, _)) => {
                            cover.len() > *bl || (cover.len() == *bl && dist < *bd)
                        }
                    };
                    if better {
                        best = Some((cover.len(), dist, d, cover));
                    }
                }
                let Some((_, _, d, cover)) = best else {
                    return Err(format!(
                        "{}: fault at step {} leaves node {r} block {b} missing \
                         contributors {:?} with no reachable donor — the lost \
                         contribution was never propagated (unrecoverable)",
                        s.name, fault.step, m
                    ));
                };
                m = m.difference(&cover);
                match reduce_groups.iter_mut().find(|(g, c, _)| *g == d && *c == cover) {
                    Some((_, _, blocks)) => blocks.push(b as u32),
                    None => reduce_groups.push((d, cover, vec![b as u32])),
                }
            }
        }
        for (d, blocks) in set_groups {
            any = true;
            cleanup.sends[d as usize].push(Send {
                to: r as u32,
                pieces: vec![Piece {
                    blocks: BlockSet::from_ranks(&blocks, nb),
                    contrib: full.clone(),
                    kind: Kind::Set,
                }],
                route: RouteHint::Minimal,
            });
        }
        for (d, contrib, blocks) in reduce_groups {
            any = true;
            cleanup.sends[d as usize].push(Send {
                to: r as u32,
                pieces: vec![Piece {
                    blocks: BlockSet::from_ranks(&blocks, nb),
                    contrib,
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            });
        }
    }
    if any {
        // apply the cleanup step so the final completeness check sees it
        for sends in &cleanup.sends {
            for snd in sends {
                for piece in &snd.pieces {
                    for b in piece.blocks.iter() {
                        state[snd.to as usize][b as usize].absorb(piece, n);
                    }
                }
            }
        }
        out.steps.push(cleanup);
    }

    // Internal completeness guarantee: every alive node holds every
    // contributor for every block (a failed check is a rewriter bug).
    for r in 0..n as usize {
        if dead(r as u32) {
            continue;
        }
        for b in 0..nb as usize {
            if !state[r][b].total.is_full(n) {
                return Err(format!(
                    "{}: internal rewrite error: node {r} block {b} ends with {:?}",
                    s.name, state[r][b].total
                ));
            }
        }
    }
    Ok(out)
}

/// Rewrite `s` around an ordered **fault sequence** (module docs): each
/// fault is applied against the schedule as rewritten so far, on the model
/// as degraded so far — `faults[i].step` indexes the schedule *after*
/// rewrite `i-1`, so a fault landing during a previous fault's cleanup step
/// is expressed naturally (the cleanup is an ordinary step of that
/// schedule). Faults must be ordered by occurrence. Returns the fully
/// rewritten schedule; simulate it with
/// [`crate::sim::SimPlan::build_staged`], one stage per fault.
pub fn rewrite_for_faults(
    s: &Schedule,
    base: &NetModel,
    faults: &[Fault],
) -> Result<Schedule, String> {
    rewrite_for_faults_hosted(s, base, faults, None)
}

/// [`rewrite_for_faults`] through a padding host map (see
/// [`rewrite_for_fault_hosted`]).
pub fn rewrite_for_faults_hosted(
    s: &Schedule,
    base: &NetModel,
    faults: &[Fault],
    hosts: Option<&[u32]>,
) -> Result<Schedule, String> {
    let mut sched = s.clone();
    let mut model = base.clone();
    for f in faults {
        sched = rewrite_for_fault_hosted(&sched, &model, f, hosts)?;
        model = f.apply(&model);
    }
    Ok(sched)
}

/// Rewrite a registry [`BuiltCollective`] around a fault sequence,
/// returning the **network** schedule to simulate on the real torus. Native
/// builds rewrite `net` directly; padded builds rewrite `exec` in virtual
/// space through the padding host map and collapse the result back with
/// [`collapse_by_hosts`] — this is what lifts PR 5's padded-schedule
/// refusal for Bruck/Trivance non-power sizes.
pub fn rewrite_collective_for_faults(
    b: &BuiltCollective,
    base: &NetModel,
    faults: &[Fault],
) -> Result<Schedule, String> {
    match &b.padding {
        None => rewrite_for_faults(&b.net, base, faults),
        Some(pad) => {
            let rw = rewrite_for_faults_hosted(&b.exec, base, faults, Some(&pad.hosts))?;
            Ok(collapse_by_hosts(
                &rw,
                &pad.hosts,
                base.torus().n(),
                format!("{}+rewrite", b.net.name),
            ))
        }
    }
}

/// Shrink one surviving send to what its sender actually holds (module
/// docs, step 2). Returns `None` when nothing survives.
fn shrink_send(send: &Send, sender: &[Cell], n: u32, nb: u32) -> Option<Send> {
    let mut pieces: Vec<Piece> = Vec::new();
    for piece in &send.pieces {
        match piece.kind {
            Kind::Reduce => {
                // group the piece's blocks by their shrunk contributor set
                let mut groups: Vec<(BlockSet, Vec<u32>)> = Vec::new();
                for b in piece.blocks.iter() {
                    let cover = sender[b as usize].max_cover(&piece.contrib);
                    if cover.is_empty() {
                        continue;
                    }
                    match groups.iter_mut().find(|(c, _)| *c == cover) {
                        Some((_, blocks)) => blocks.push(b),
                        None => groups.push((cover, vec![b])),
                    }
                }
                for (contrib, blocks) in groups {
                    pieces.push(Piece {
                        blocks: BlockSet::from_ranks(&blocks, nb),
                        contrib,
                        kind: Kind::Reduce,
                    });
                }
            }
            Kind::Set => {
                let kept: Vec<u32> = piece
                    .blocks
                    .iter()
                    .filter(|&b| sender[b as usize].total.is_full(n))
                    .collect();
                if !kept.is_empty() {
                    pieces.push(Piece {
                        blocks: BlockSet::from_ranks(&kept, nb),
                        contrib: piece.contrib.clone(),
                        kind: Kind::Set,
                    });
                }
            }
        }
    }
    if pieces.is_empty() {
        None
    } else {
        Some(Send { to: send.to, pieces, route: send.route })
    }
}

#[cfg(test)]
mod tests {
    use super::super::validate::validate_allreduce;
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};
    use crate::algo::{build, Algo, Variant};
    use crate::topology::Torus;
    use crate::verify::diff::certify_rewrite;
    use crate::verify::{verify_dataflow, verify_dataflow_surviving};
    use std::collections::HashMap;

    fn down_link_of(t: &Torus, node: u32) -> usize {
        t.link_index(Link { node, dim: 0, dir: 1 })
    }

    #[test]
    fn link_fault_rewrite_validates_and_avoids_the_link() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let fault = Fault::link(1, down_link_of(&t, 0));
        let rw = rewrite_for_fault(&s, &base, &fault).unwrap();
        // still a correct AllReduce (no node died) — both by the classic
        // validator and the typed static dataflow proof
        validate_allreduce(&rw).unwrap_or_else(|e| panic!("{e}"));
        verify_dataflow(&rw).unwrap_or_else(|e| panic!("{e}"));
        // and differentially certified equivalent to the original
        certify_rewrite(&s, &rw, fault.step, &HashMap::new(), None)
            .unwrap_or_else(|e| panic!("{e}"));
        // post-fault steps never route over the dead link nominally
        let post = fault.apply(&base);
        for (k, step) in rw.steps.iter().enumerate().skip(fault.step) {
            for (src, sends) in step.sends.iter().enumerate() {
                for snd in sends {
                    let route = post.route(src as u32, snd.to, snd.route);
                    for l in route {
                        assert!(
                            !post.is_down(t.link_index(l)),
                            "step {k}: {src}->{} crosses the dead link",
                            snd.to
                        );
                    }
                }
            }
        }
        // the rewrite adds at most one cleanup step
        assert!(rw.num_steps() <= s.num_steps() + 1);
        // pre-fault step is verbatim
        assert_eq!(rw.steps[0].sends.iter().map(Vec::len).sum::<usize>(),
                   s.steps[0].sends.iter().map(Vec::len).sum::<usize>());
    }

    #[test]
    fn registry_rewrites_validate_on_ring9_and_3x3() {
        for dims in [vec![9u32], vec![3, 3]] {
            let t = Torus::new(&dims);
            let base = NetModel::uniform(&t);
            let fault = Fault::link(1, down_link_of(&t, 0));
            for algo in Algo::ALL {
                for variant in Variant::ALL {
                    let Ok(b) = build(algo, variant, &t) else { continue };
                    if b.padded {
                        // padded builds rewrite in virtual space through the
                        // host map (the raw net schedule still refuses)
                        let err = rewrite_for_fault(&b.net, &base, &fault).unwrap_err();
                        assert!(err.contains("virtual"), "{algo:?} {variant:?}: {err}");
                        let pad = b.padding.as_ref().unwrap();
                        let rw = rewrite_for_fault_hosted(&b.exec, &base, &fault, Some(&pad.hosts))
                            .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                        // the virtual rewrite is a complete AllReduce
                        validate_allreduce(&rw)
                            .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                        verify_dataflow(&rw)
                            .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                        // differentially certified against the virtual exec
                        // schedule through the host map
                        certify_rewrite(&b.exec, &rw, fault.step, &HashMap::new(), Some(&pad.hosts))
                            .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                        // and collapses onto the real torus with no send
                        // nominally crossing the dead link
                        let net = rewrite_collective_for_faults(
                            &b,
                            &base,
                            std::slice::from_ref(&fault),
                        )
                        .unwrap();
                        let post = fault.apply(&base);
                        for step in net.steps.iter().skip(fault.step) {
                            for (src, sends) in step.sends.iter().enumerate() {
                                for snd in sends {
                                    for l in post.route(src as u32, snd.to, snd.route) {
                                        assert!(
                                            !post.is_down(t.link_index(l)),
                                            "{algo:?} {variant:?} {dims:?}: rewritten \
                                             padded send crosses the dead link"
                                        );
                                    }
                                }
                            }
                        }
                        continue;
                    }
                    let rw = rewrite_for_fault(&b.net, &base, &fault)
                        .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                    validate_allreduce(&rw)
                        .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                    verify_dataflow(&rw)
                        .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                    certify_rewrite(&b.net, &rw, fault.step, &HashMap::new(), None)
                        .unwrap_or_else(|e| panic!("{algo:?} {variant:?} {dims:?}: {e}"));
                }
            }
        }
    }

    #[test]
    fn node_death_after_propagation_recovers_survivors() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        // node 4 dies after step 0: its contribution already reached 3 and 5
        let fault = Fault::node(1, 4);
        let rw = rewrite_for_fault(&s, &base, &fault).unwrap();
        // no post-fault send touches the dead node
        for step in rw.steps.iter().skip(1) {
            assert!(step.sends[4].is_empty(), "dead node still sends");
            for sends in &step.sends {
                for snd in sends {
                    assert_ne!(snd.to, 4, "send to the dead node survived");
                }
            }
        }
        // survivor completeness, proved statically: every living rank ends
        // with the full reduction including dead node 4's contribution
        let mut alive = vec![true; 9];
        alive[4] = false;
        verify_dataflow_surviving(&rw, &alive).unwrap_or_else(|e| panic!("{e}"));
        // differential certification: the rewrite is the original minus
        // node 4's dead contributions from its death step on
        let dead = HashMap::from([(4u32, fault.step)]);
        certify_rewrite(&s, &rw, fault.step, &dead, None).unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn node_death_before_any_propagation_is_unrecoverable() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let err = rewrite_for_fault(&s, &base, &Fault::node(0, 4)).unwrap_err();
        assert!(err.contains("unrecoverable"), "{err}");
    }

    #[test]
    fn fault_after_last_step_is_identity() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let fault = Fault::link(s.num_steps(), down_link_of(&t, 0));
        let rw = rewrite_for_fault(&s, &base, &fault).unwrap();
        assert_eq!(rw.num_steps(), s.num_steps(), "no cleanup needed");
        assert_eq!(rw.num_messages(), s.num_messages());
    }

    #[test]
    fn second_fault_during_cleanup_rewrites_incrementally() {
        // cable death before step 1, second cable death landing during the
        // first rewrite's cleanup step — `rewrite_for_faults` must treat
        // the cleanup as an ordinary step of the evolving schedule
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let f1 = Fault::link(1, down_link_of(&t, 0));
        let rw1 = rewrite_for_fault(&s, &base, &f1).unwrap();
        assert_eq!(rw1.num_steps(), s.num_steps() + 1, "first rewrite appends cleanup");
        let cleanup = rw1.num_steps() - 1;
        let f2 = Fault::link(cleanup, down_link_of(&t, 4));
        let rw2 = rewrite_for_faults(&s, &base, &[f1.clone(), f2.clone()]).unwrap();
        validate_allreduce(&rw2).unwrap_or_else(|e| panic!("{e}"));
        verify_dataflow(&rw2).unwrap_or_else(|e| panic!("{e}"));
        // the composed rewrite still diffs clean against the ORIGINAL:
        // shrink relations compose, and the second fault's edits land in
        // the first rewrite's cleanup zone
        certify_rewrite(&s, &rw2, f1.step, &HashMap::new(), None)
            .unwrap_or_else(|e| panic!("{e}"));
        // identical to applying the second rewrite by hand against rw1 on
        // the post-f1 model
        let manual = rewrite_for_fault(&rw1, &f1.apply(&base), &f2).unwrap();
        assert_eq!(rw2.num_steps(), manual.num_steps());
        assert_eq!(rw2.num_messages(), manual.num_messages());
        // post-f2 steps avoid BOTH dead cables
        let post = f2.apply(&f1.apply(&base));
        for step in rw2.steps.iter().skip(f2.step) {
            for (src, sends) in step.sends.iter().enumerate() {
                for snd in sends {
                    for l in post.route(src as u32, snd.to, snd.route) {
                        assert!(!post.is_down(t.link_index(l)));
                    }
                }
            }
        }
    }

    #[test]
    fn node_death_after_link_rewrite_recovers_survivors() {
        // link fault at step 1, then node 1 — an endpoint of the rewired
        // link — dies during the cleanup step. Only victims adjacent to
        // the dead link keep the survivor path connected on a ring; a
        // mid-ring victim (e.g. node 4) partitions the residual path and
        // the rewrite correctly refuses.
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let f1 = Fault::link(1, down_link_of(&t, 0));
        let rw1 = rewrite_for_fault(&s, &base, &f1).unwrap();
        let f2 = Fault::node(rw1.num_steps() - 1, 1);
        let rw2 = rewrite_for_faults(&s, &base, &[f1, f2.clone()]).unwrap();
        // no post-death send touches the dead node
        for step in rw2.steps.iter().skip(f2.step) {
            assert!(step.sends[1].is_empty(), "dead node still sends");
            for sends in &step.sends {
                for snd in sends {
                    assert_ne!(snd.to, 1, "send to the dead node survived");
                }
            }
        }
        // survivor completeness, proved statically for dead node 1
        let mut alive = vec![true; 9];
        alive[1] = false;
        verify_dataflow_surviving(&rw2, &alive).unwrap_or_else(|e| panic!("{e}"));
        // differentially: node 1 is dead only from f2's step, so its
        // earlier sends (including the first rewrite's) stay legitimate
        let dead = HashMap::from([(1u32, f2.step)]);
        certify_rewrite(&s, &rw2, 1, &dead, None).unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn empty_fault_sequence_is_identity() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let rw = rewrite_for_faults(&s, &base, &[]).unwrap();
        assert_eq!(rw.num_steps(), s.num_steps());
        assert_eq!(rw.num_messages(), s.num_messages());
    }

    #[test]
    fn fault_fingerprints_are_distinct_and_nonzero() {
        let a = Fault::link(1, 3);
        let b = Fault::link(2, 3);
        let c = Fault::node(1, 3);
        assert_ne!(a.fingerprint(), 0);
        assert_ne!(a.fingerprint(), b.fingerprint());
        assert_ne!(a.fingerprint(), c.fingerprint());
        assert_eq!(a.fingerprint(), Fault::link(1, 3).fingerprint());
    }
}
