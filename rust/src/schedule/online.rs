//! Online fault-response controller: turn a stream of timed fault events
//! into a rewritten schedule plus a per-step-range model stack, replayed
//! deterministically by [`crate::sim::SimPlan::build_staged`].
//!
//! PR 5 chose rewrite-vs-detour *before* the collective started, for
//! exactly one fault. This module is the live version: the controller is
//! consulted once per observed [`FaultEvent`], maps the event's wall-clock
//! time onto a schedule step through a cheap deterministic cost estimate
//! ([`step_time_estimates`]), and asks a policy (a closure — the tuned
//! nearest-scenario policy lives in [`crate::tuner::online`]) whether to
//! **detour** (keep the remaining sends, let the degraded model's BFS
//! re-route them) or **rewrite** (swap the remaining steps for a tail
//! produced by [`super::rewrite::rewrite_for_fault_hosted`], shrinking
//! survivors and appending a cleanup step). Either way the degraded model
//! is pushed as a new stage, so steps before the fault keep routing — and
//! costing — exactly as they ran, which is the "in-flight bytes on
//! surviving links are preserved" contract: a completed or unaffected
//! step's traffic is never re-priced by a later fault.
//!
//! The controller is **deterministic and simulation-free**: it never runs
//! the DES engines, so the same event stream always produces the same
//! [`Response`] (the `scenarios --online` sweep then *scores* responses in
//! both engines against the oracle). Fault sequences compose naturally —
//! each rewrite is applied against the already-rewritten schedule, so a
//! second fault landing during a previous fault's cleanup step is just a
//! later step index in the evolving schedule. Padded collectives rewrite
//! through their [`crate::algo::registry::Padding`] host map and collapse
//! back to the real torus per event.
//!
//! A rewrite that fails (e.g. a dead node whose contribution never
//! propagated) falls back to detour for that event — honest degradation,
//! recorded in [`Response::actions`]. Stranded traffic at simulation time
//! surfaces as [`crate::sim::SimError::Stranded`], a partitioned fabric as
//! [`crate::sim::SimError::Unroutable`]; the controller itself never
//! panics on fault input. Mirrored in `tools/pysim/mirror.py`
//! (`step_time_estimates` / `respond`) — keep estimator arithmetic and
//! event→step mapping in lockstep.

use super::rewrite::{rewrite_for_fault_hosted, Fault};
use super::Schedule;
use crate::algo::registry::{collapse_by_hosts, BuiltCollective};
use crate::cost::NetParams;
use crate::net::{NetModel, Unreachable};
use crate::obs;
use crate::sim::SimPlan;

/// One observed fabric fault at wall-clock time `t` (seconds since the
/// collective started): links and/or nodes that died *permanently*.
/// Transient capacity changes (flaps, brownouts) are not fault events —
/// they stay in the [`crate::net::Timeline`] the engines consume directly.
#[derive(Clone, Debug, PartialEq)]
pub struct FaultEvent {
    pub t: f64,
    /// Dense directed-link indices that died at `t`.
    pub down_links: Vec<usize>,
    /// Nodes that died entirely at `t`.
    pub dead_nodes: Vec<u32>,
}

impl FaultEvent {
    /// A single directed link dying at `t`.
    pub fn link(t: f64, link: usize) -> FaultEvent {
        FaultEvent { t, down_links: vec![link], dead_nodes: Vec::new() }
    }

    /// A full cable (both directions of a link) dying at `t`.
    pub fn cable(t: f64, torus: &crate::topology::Torus, link: usize) -> FaultEvent {
        let rev = torus.link_index(torus.reverse_link(torus.link_at(link)));
        FaultEvent { t, down_links: vec![link, rev], dead_nodes: Vec::new() }
    }

    /// A node dying at `t`.
    pub fn node(t: f64, node: u32) -> FaultEvent {
        FaultEvent { t, down_links: Vec::new(), dead_nodes: vec![node] }
    }

    fn is_empty(&self) -> bool {
        self.down_links.is_empty() && self.dead_nodes.is_empty()
    }
}

/// The controller's per-event choice.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Action {
    /// Keep the remaining sends; the degraded model's BFS re-routes blocked
    /// traffic inside the original steps.
    Detour,
    /// Swap the remaining steps for a rewritten tail (shrink + substitute +
    /// cleanup, [`super::rewrite`]).
    Rewrite,
}

/// What the controller decided and produced for one event stream: simulate
/// with [`Response::build_plan`].
#[derive(Clone, Debug)]
pub struct Response {
    /// The final (possibly rewritten) network schedule on the real torus.
    pub schedule: Schedule,
    /// Per-step-range degraded models, one per applied event, sorted by
    /// step — the stage stack for [`SimPlan::build_staged`].
    pub stages: Vec<(u32, NetModel)>,
    /// Per consulted event: the step the event mapped to and the action
    /// actually applied (a failed rewrite degrades to [`Action::Detour`]).
    pub actions: Vec<(usize, Action)>,
}

impl Response {
    /// Compile the response into a staged [`SimPlan`]: steps before the
    /// first fault route on `base`, each later range on its stage's model.
    /// Errs ([`Unreachable`]) when a stage's down set disconnects a pair
    /// the schedule still needs — e.g. detouring around a dead node.
    pub fn build_plan(&self, base: &NetModel) -> Result<SimPlan, Unreachable> {
        let stages: Vec<(u32, &NetModel)> =
            self.stages.iter().map(|(s, m)| (*s, m)).collect();
        SimPlan::build_staged(&self.schedule, base, &stages)
    }
}

/// Cumulative estimated end time of each schedule step under `model` — the
/// controller's clock for mapping a [`FaultEvent::t`] onto a step index.
/// Per step: `α` + the busiest link's serialization (summing each send's
/// bytes over its resolved route, at the link's own rate) + the longest
/// route's accumulated hop latency. Deliberately congestion-free and
/// cheap (no DES run): the controller only needs a monotone, deterministic
/// time→step map, not an exact completion. Sends the degraded model cannot
/// route are skipped — the *plan build* reports those as typed errors.
pub fn step_time_estimates(
    s: &Schedule,
    model: &NetModel,
    m_bytes: u64,
    params: &NetParams,
) -> Vec<f64> {
    staged_step_time_estimates(s, model, &[], m_bytes, params)
}

/// [`step_time_estimates`] under a stage stack: step `k` is priced on the
/// model of the last stage with `from_step <= k` — the model actually in
/// force when the step runs — falling back to `base` before the first
/// stage. This is the controller's clock *between* events: a completed
/// step keeps its pre-fault pricing (the "never re-priced" contract the
/// plan compiler also honours), so a later event's time maps onto the step
/// that is genuinely in flight, not onto a retroactively slowed past.
pub fn staged_step_time_estimates(
    s: &Schedule,
    base: &NetModel,
    stages: &[(u32, NetModel)],
    m_bytes: u64,
    params: &NetParams,
) -> Vec<f64> {
    let torus = base.torus();
    assert_eq!(s.n, torus.n(), "schedule/topology node count mismatch");
    let mut ends = Vec::with_capacity(s.num_steps());
    let mut t = 0.0f64;
    let mut link_bytes = vec![0.0f64; torus.num_links()];
    for (k, step) in s.steps.iter().enumerate() {
        let mut model = base;
        for (from, m) in stages {
            if k as u32 >= *from {
                model = m;
            } else {
                break;
            }
        }
        link_bytes.iter_mut().for_each(|b| *b = 0.0);
        let mut lat = 0.0f64;
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                let Ok(route) = model.try_route(src as u32, snd.to, snd.route) else {
                    continue;
                };
                let bytes = snd.rel_bytes(s.n_blocks) * m_bytes as f64;
                let mut hop_lat = 0.0f64;
                for l in &route {
                    let li = torus.link_index(*l);
                    link_bytes[li] += bytes;
                    hop_lat += model.lat_scale(li) * params.link_latency_s
                        + model.proc_scale(li) * params.hop_latency_s;
                }
                lat = lat.max(hop_lat);
            }
        }
        let ser = link_bytes
            .iter()
            .enumerate()
            .map(|(l, &b)| b * params.beta_per_byte() / model.bw_scale(l))
            .fold(0.0f64, f64::max);
        t += params.alpha_s + ser + lat;
        ends.push(t);
    }
    ends
}

/// Run the controller over a time-ordered fault-event stream (module
/// docs). `policy` is consulted once per non-empty event that lands before
/// the estimated completion of the *current* (evolving) schedule; events
/// arriving after estimated completion are ignored — the collective is
/// already done by the controller's clock. Errs only on malformed input
/// (events out of order); fault-induced failures surface later, typed,
/// from [`Response::build_plan`] or the engines.
pub fn respond(
    b: &BuiltCollective,
    base: &NetModel,
    events: &[FaultEvent],
    m_bytes: u64,
    params: &NetParams,
    mut policy: impl FnMut(&FaultEvent, usize) -> Action,
) -> Result<Response, String> {
    let hosts = b.padding.as_ref().map(|p| p.hosts.as_slice());
    let n_real = base.torus().n();
    // the rewrite machine works in virtual space for padded builds; the
    // network-facing schedule (for estimates and the final plan) is its
    // collapse
    let mut work = match hosts {
        Some(_) => b.exec.clone(),
        None => b.net.clone(),
    };
    let collapse = |s: &Schedule| -> Schedule {
        match hosts {
            Some(h) => collapse_by_hosts(s, h, n_real, format!("{}+rewrite", b.net.name)),
            None => s.clone(),
        }
    };
    let mut net_sched = b.net.clone();
    let mut model = base.clone();
    let mut ends = step_time_estimates(&net_sched, base, m_bytes, params);
    let mut stages: Vec<(u32, NetModel)> = Vec::new();
    let mut actions = Vec::new();
    let mut prev_t = f64::NEG_INFINITY;
    let mut last_step = 0usize;
    // Decision-log counters, flushed to `online.*` once per respond().
    let (mut n_faults, mut n_ignored) = (0u64, 0u64);
    let (mut n_rewrites, mut n_detours, mut n_fallbacks) = (0u64, 0u64, 0u64);
    for ev in events {
        if !(ev.t >= prev_t) {
            return Err(format!(
                "online controller: fault events must be time-ordered ({} after {prev_t})",
                ev.t
            ));
        }
        prev_t = ev.t;
        if ev.is_empty() {
            continue;
        }
        n_faults += 1;
        let Some(&done) = ends.last() else { break };
        if ev.t >= done {
            n_ignored += 1;
            continue; // by the controller's clock the collective finished
        }
        // the step in flight when the event landed: first step whose
        // estimated end exceeds t. Clamped monotone so the stage stack
        // stays sorted even when a rewrite re-times earlier steps.
        let step = ends
            .iter()
            .position(|&e| ev.t < e)
            .unwrap_or(ends.len())
            .max(last_step);
        last_step = step;
        let fault = Fault {
            step,
            down_links: ev.down_links.clone(),
            dead_nodes: ev.dead_nodes.clone(),
        };
        if obs::tracing() {
            obs::with_sink(|s| {
                s.instant(
                    obs::PID_ONLINE,
                    obs::cur_tid(),
                    "fault_event",
                    ev.t,
                    &[
                        ("step", step as f64),
                        ("down_links", ev.down_links.len() as f64),
                        ("dead_nodes", ev.dead_nodes.len() as f64),
                    ],
                );
            });
        }
        let requested = policy(ev, step);
        let mut applied = requested;
        if applied == Action::Rewrite {
            match rewrite_for_fault_hosted(&work, &model, &fault, hosts) {
                Ok(rw) => {
                    work = rw;
                    net_sched = collapse(&work);
                }
                // unrecoverable rewrite: degrade to detour, honestly
                Err(_) => applied = Action::Detour,
            }
        }
        match applied {
            Action::Rewrite => n_rewrites += 1,
            Action::Detour => n_detours += 1,
        }
        if requested == Action::Rewrite && applied == Action::Detour {
            n_fallbacks += 1;
        }
        model = fault.apply(&model);
        stages.push((step as u32, model.clone()));
        actions.push((step, applied));
        ends = staged_step_time_estimates(&net_sched, base, &stages, m_bytes, params);
        if obs::tracing() {
            // The full FaultEvent → decision → outcome chain: the decision
            // instant and an X span from the event to the re-estimated
            // completion of the (possibly rewritten) schedule.
            let name = match applied {
                Action::Rewrite => "fault_rewrite",
                Action::Detour => "fault_detour",
            };
            let new_done = ends.last().copied().unwrap_or(ev.t);
            let fb = if requested == applied { 0.0 } else { 1.0 };
            obs::with_sink(|s| {
                s.instant(
                    obs::PID_ONLINE,
                    obs::cur_tid(),
                    "decision",
                    ev.t,
                    &[
                        ("step", step as f64),
                        ("rewrite", matches!(applied, Action::Rewrite) as u8 as f64),
                        ("fallback", fb),
                    ],
                );
                s.complete(
                    obs::PID_ONLINE,
                    obs::cur_tid(),
                    name,
                    ev.t,
                    new_done.max(ev.t),
                    &[("step", step as f64)],
                );
            });
        }
    }
    obs::metrics::counters_add(&[
        ("online.responds", 1),
        ("online.faults", n_faults),
        ("online.ignored", n_ignored),
        ("online.rewrites", n_rewrites),
        ("online.detours", n_detours),
        ("online.rewrite_fallbacks", n_fallbacks),
    ]);
    Ok(Response { schedule: net_sched, stages, actions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};
    use crate::algo::{build, Algo, Variant};
    use crate::sim::{simulate_plan, SimMode};
    use crate::topology::{Link, Torus};
    use crate::verify::diff::certify_response;
    use crate::verify::{verify_dataflow, verify_dataflow_surviving, verify_plan};

    fn cable(t: &Torus, node: u32) -> usize {
        t.link_index(Link { node, dim: 0, dir: 1 })
    }

    #[test]
    fn estimates_are_monotone_and_scale_with_bytes() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let m = NetModel::uniform(&t);
        let p = NetParams::default();
        let small = step_time_estimates(&s, &m, 4096, &p);
        let large = step_time_estimates(&s, &m, 1 << 20, &p);
        assert_eq!(small.len(), s.num_steps());
        assert!(small.windows(2).all(|w| w[0] < w[1]), "cumulative ends must increase");
        assert!(large.iter().zip(&small).all(|(l, s)| l > s));
        // every step costs at least alpha
        assert!(small[0] >= p.alpha_s);
    }

    #[test]
    fn no_events_is_the_identity_response() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let resp = respond(&b, &base, &[], 4096, &p, |_, _| Action::Rewrite).unwrap();
        assert!(resp.stages.is_empty());
        assert!(resp.actions.is_empty());
        assert_eq!(resp.schedule.num_messages(), b.net.num_messages());
        // the identity response re-verifies statically before simulation
        verify_dataflow(&resp.schedule).unwrap_or_else(|e| panic!("{e}"));
        // and trivially diffs clean against the pre-fault collective
        certify_response(&b, &base, &resp).unwrap_or_else(|e| panic!("{e}"));
        // and the compiled plan is the plain static plan (same routes)
        let plan = resp.build_plan(&base).unwrap();
        let r = simulate_plan(&plan, 4096, &p, SimMode::Flow);
        let plain = simulate_plan(&SimPlan::build(&b.net, &t), 4096, &p, SimMode::Flow);
        assert_eq!(r.completion_s.to_bits(), plain.completion_s.to_bits());
    }

    #[test]
    fn two_fault_sequence_rewrites_and_completes_in_both_engines() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let m = 64 * 1024u64;
        let ends = step_time_estimates(&b.net, &base, m, &p);
        // cable death mid-step-1, then the node *adjacent to the dead
        // cable* dies late. On a cable-cut ring any further link fault
        // directionally partitions the path, but removing a path endpoint
        // keeps the survivors connected — the second rewrite succeeds.
        let ev1 = FaultEvent::cable(0.5 * (ends[0] + ends[1]), &t, cable(&t, 0));
        let ev2 = FaultEvent::node(ends.last().unwrap() * 0.98, 1);
        let resp =
            respond(&b, &base, &[ev1, ev2], m, &p, |_, _| Action::Rewrite).unwrap();
        assert_eq!(resp.actions.len(), 2);
        assert!(resp.actions.iter().all(|&(_, a)| a == Action::Rewrite));
        assert_eq!(resp.actions[0].0, 1, "first fault lands in step 1");
        assert_eq!(
            resp.actions[1].0, 1,
            "staged clock keeps pre-fault pricing: the late event still \
             maps into the re-planned step 1 range"
        );
        assert_eq!(
            resp.schedule.num_steps(),
            b.net.num_steps() + 2,
            "each rewrite appends a cleanup step"
        );
        // survivor completeness, proved statically: every rank except dead
        // node 1 ends with the full reduction
        let mut alive = vec![true; 9];
        alive[1] = false;
        verify_dataflow_surviving(&resp.schedule, &alive).unwrap_or_else(|e| panic!("{e}"));
        // the full differential proof: prefix verbatim, body shrink-only,
        // cleanup alive-to-alive, node 1 dead from its rewrite stage on
        certify_response(&b, &base, &resp).unwrap_or_else(|e| panic!("{e}"));
        // and nothing touches the dead node after the fault
        for step in resp.schedule.steps.iter().skip(resp.actions[1].0) {
            assert!(step.sends[1].is_empty(), "dead node still sends");
            for sends in &step.sends {
                for snd in sends {
                    assert_ne!(snd.to, 1, "send to the dead node survived");
                }
            }
        }
        let plan = resp.build_plan(&base).unwrap();
        verify_plan(&plan, &t).unwrap_or_else(|e| panic!("{e}"));
        for mode in [SimMode::Flow, SimMode::Packet { mtu: 4096 }] {
            let r = simulate_plan(&plan, m, &p, mode);
            assert!(r.completion_s.is_finite() && r.completion_s > 0.0);
        }
    }

    #[test]
    fn events_after_completion_are_ignored_and_order_is_enforced() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let ends = step_time_estimates(&b.net, &base, 4096, &p);
        let late = FaultEvent::cable(ends.last().unwrap() * 2.0, &t, cable(&t, 0));
        let resp = respond(&b, &base, &[late], 4096, &p, |_, _| Action::Rewrite).unwrap();
        assert!(resp.stages.is_empty(), "post-completion events are ignored");
        let e1 = FaultEvent::cable(1.0, &t, cable(&t, 0));
        let e2 = FaultEvent::cable(0.5, &t, cable(&t, 4));
        let err = respond(&b, &base, &[e1, e2], 4096, &p, |_, _| Action::Detour).unwrap_err();
        assert!(err.contains("time-ordered"), "{err}");
    }

    #[test]
    fn padded_collective_rewrites_online_through_the_host_map() {
        // swing on ring-9 pads to 16 virtual ranks: the online controller
        // must rewrite (not refuse) through the padding map
        let t = Torus::ring(9);
        let b = build(Algo::Swing, Variant::Latency, &t).unwrap();
        assert!(b.padded);
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let m = 64 * 1024u64;
        let ends = step_time_estimates(&b.net, &base, m, &p);
        let ev = FaultEvent::cable(0.5 * (ends[0] + ends[1]), &t, cable(&t, 0));
        let resp = respond(&b, &base, &[ev], m, &p, |_, _| Action::Rewrite).unwrap();
        assert_eq!(resp.actions, vec![(1, Action::Rewrite)]);
        assert_eq!(resp.schedule.n, 9, "response schedule lives on the real torus");
        // the collapsed schedule merges co-hosted contributions and is not
        // a real-rank reduction trace, but its compiled plan must still be
        // a connected, topology-consistent route set
        let plan = resp.build_plan(&base).unwrap();
        verify_plan(&plan, &t).unwrap_or_else(|e| panic!("{e}"));
        for mode in [SimMode::Flow, SimMode::Packet { mtu: 4096 }] {
            let r = simulate_plan(&plan, m, &p, mode);
            assert!(r.completion_s.is_finite() && r.completion_s > 0.0);
        }
    }

    #[test]
    fn failed_rewrite_degrades_to_detour() {
        // node 4 dies before anything propagated (t inside step 0):
        // rewriting is unrecoverable, the controller must fall back to
        // detour and record it
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let base = NetModel::uniform(&t);
        let p = NetParams::default();
        let ends = step_time_estimates(&b.net, &base, 4096, &p);
        let ev = FaultEvent::node(0.5 * ends[0], 4);
        let resp = respond(&b, &base, &[ev], 4096, &p, |_, _| Action::Rewrite).unwrap();
        assert_eq!(resp.actions, vec![(0, Action::Detour)]);
        // and the plan build reports the partition as a typed error
        let err = resp.build_plan(&base).unwrap_err();
        let _ = err; // Unreachable: routes to the dead node cannot exist
    }
}
