//! Static schedule validator.
//!
//! Symbolically executes a [`Schedule`] over contributor sets and proves the
//! two properties that make an AllReduce schedule *correct*:
//!
//! 1. **No double reduction** — a Reduce piece's contributor set is disjoint
//!    from the receiver's accumulated contributors for every block it
//!    carries, and the sender actually holds that contributor set as an
//!    exact union of its stored atoms (a partial aggregate cannot be
//!    un-summed, so "send contributors C" is only realizable if C is a
//!    union of aggregates the sender has kept separate).
//! 2. **Coverage** — after the last step every node holds, for every block,
//!    the contribution of every rank.
//!
//! Every schedule produced by [`crate::algo`] is validated in tests (and can
//! be validated at run time with `trivance validate`), so an incorrect
//! communication pattern can never silently reach the simulator or the
//! numeric executor.

use super::{Kind, Schedule};
use crate::blockset::BlockSet;

/// Per-(node, block) storage: the disjoint aggregates ("atoms") the node
/// keeps. The union is the accumulated contributor set.
#[derive(Clone, Debug)]
struct Cell {
    atoms: Vec<BlockSet>,
    /// Cached union of `atoms`.
    total: BlockSet,
}

impl Cell {
    fn new(own: u32, n: u32) -> Self {
        let s = BlockSet::singleton(own, n);
        Cell { atoms: vec![s.clone()], total: s }
    }

    /// Can the node send exactly the aggregate over `c`? True iff `c` is a
    /// union of whole atoms.
    fn exact_cover(&self, c: &BlockSet) -> bool {
        let mut covered = 0u64;
        for a in &self.atoms {
            let inter = a.intersect(c);
            if inter.is_empty() {
                continue;
            }
            if inter != *a {
                return false; // partial overlap: would need to split an aggregate
            }
            covered += a.len();
        }
        covered == c.len()
    }
}

/// Summary statistics of a successful validation.
#[derive(Clone, Debug)]
pub struct Report {
    pub n: u32,
    pub n_blocks: u32,
    pub steps: usize,
    pub messages: usize,
    /// Maximum number of atoms any (node, block) cell held — a proxy for
    /// the bookkeeping cost of the schedule.
    pub max_atoms: usize,
}

/// Validate an AllReduce schedule (see module docs). `O(steps · messages ·
/// blocks)` with small interval sets; intended for rings and small tori —
/// large multidimensional instances are covered by per-dimension validation
/// plus the numeric executor.
pub fn validate_allreduce(s: &Schedule) -> Result<Report, String> {
    let n = s.n;
    let nb = s.n_blocks;
    let mut state: Vec<Vec<Cell>> = (0..n)
        .map(|r| (0..nb).map(|_| Cell::new(r, n)).collect())
        .collect();
    let mut max_atoms = 1;
    let mut messages = 0;

    for (k, step) in s.steps.iter().enumerate() {
        // Pieces are materialized against the *start-of-step* state: a node
        // cannot forward data received in the same step (the per-step
        // receive barrier of §4.3).
        let snapshot = state.clone();
        for (src, sends) in step.sends.iter().enumerate() {
            for send in sends {
                messages += 1;
                if send.to >= n {
                    return Err(format!("{}: step {k}: send to invalid node {}", s.name, send.to));
                }
                if send.to as usize == src {
                    return Err(format!("{}: step {k}: self-send at node {src}", s.name));
                }
                for piece in &send.pieces {
                    if piece.blocks.is_empty() {
                        return Err(format!(
                            "{}: step {k}: empty piece {src}->{}",
                            s.name, send.to
                        ));
                    }
                    match piece.kind {
                        Kind::Reduce => {
                            for b in piece.blocks.iter() {
                                if b >= nb {
                                    return Err(format!(
                                        "{}: step {k}: block {b} out of range",
                                        s.name
                                    ));
                                }
                                let sender = &snapshot[src][b as usize];
                                if !sender.total.is_superset(&piece.contrib) {
                                    return Err(format!(
                                        "{}: step {k}: {src}->{} block {b}: sender lacks \
                                         contrib {:?} (has {:?})",
                                        s.name, send.to, piece.contrib, sender.total
                                    ));
                                }
                                if !sender.exact_cover(&piece.contrib) {
                                    return Err(format!(
                                        "{}: step {k}: {src}->{} block {b}: contrib {:?} is \
                                         not an exact union of sender atoms {:?}",
                                        s.name, send.to, piece.contrib, sender.atoms
                                    ));
                                }
                                let recv = &mut state[send.to as usize][b as usize];
                                if !recv.total.is_disjoint(&piece.contrib) {
                                    return Err(format!(
                                        "{}: step {k}: {src}->{} block {b}: double reduction, \
                                         incoming {:?} overlaps held {:?}",
                                        s.name, send.to, piece.contrib, recv.total
                                    ));
                                }
                                recv.atoms.push(piece.contrib.clone());
                                recv.total.union_with(&piece.contrib);
                                max_atoms = max_atoms.max(recv.atoms.len());
                            }
                        }
                        Kind::Set => {
                            if !piece.contrib.is_full(n) {
                                return Err(format!(
                                    "{}: step {k}: Set piece with partial contrib {:?}",
                                    s.name, piece.contrib
                                ));
                            }
                            for b in piece.blocks.iter() {
                                let sender = &snapshot[src][b as usize];
                                if !sender.total.is_full(n) {
                                    return Err(format!(
                                        "{}: step {k}: {src}->{} block {b}: Set piece but \
                                         sender holds only {:?}",
                                        s.name, send.to, sender.total
                                    ));
                                }
                                let recv = &mut state[send.to as usize][b as usize];
                                let full = BlockSet::full(n);
                                recv.atoms = vec![full.clone()];
                                recv.total = full;
                            }
                        }
                    }
                }
            }
        }
    }

    for r in 0..n {
        for b in 0..nb {
            if !state[r as usize][b as usize].total.is_full(n) {
                return Err(format!(
                    "{}: incomplete: node {r} block {b} ends with contributors {:?} (want all {n})",
                    s.name, state[r as usize][b as usize].total
                ));
            }
        }
    }

    Ok(Report { n, n_blocks: nb, steps: s.steps.len(), messages, max_atoms })
}

/// Validate a pure AllGather schedule: initial state "node r holds block r"
/// (for `n_blocks == n`); Set pieces move whole blocks; requires the sender
/// to hold what it sends, the receiver not to already hold it (no duplicate
/// transfers — the efficiency invariant the latency-optimal reinterpretation
/// depends on), and full coverage at the end.
pub fn validate_allgather(s: &Schedule) -> Result<Report, String> {
    let n = s.n;
    let nb = s.n_blocks;
    if nb != n {
        return Err(format!("{}: allgather validation requires n_blocks == n", s.name));
    }
    let mut held: Vec<BlockSet> = (0..n).map(|r| BlockSet::singleton(r, n)).collect();
    let mut messages = 0;
    for (k, step) in s.steps.iter().enumerate() {
        let snapshot = held.clone();
        for (src, sends) in step.sends.iter().enumerate() {
            for send in sends {
                messages += 1;
                for piece in &send.pieces {
                    if !snapshot[src].is_superset(&piece.blocks) {
                        return Err(format!(
                            "{}: step {k}: {src}->{} sends blocks it does not hold: {:?} vs {:?}",
                            s.name, send.to, piece.blocks, snapshot[src]
                        ));
                    }
                    let recv = &mut held[send.to as usize];
                    if !recv.is_disjoint(&piece.blocks) {
                        return Err(format!(
                            "{}: step {k}: {src}->{} duplicate blocks {:?} (receiver holds {:?})",
                            s.name,
                            send.to,
                            piece.blocks.intersect(recv),
                            recv
                        ));
                    }
                    recv.union_with(&piece.blocks);
                }
            }
        }
    }
    for r in 0..n {
        if !held[r as usize].is_full(n) {
            return Err(format!(
                "{}: incomplete allgather: node {r} holds {:?}",
                s.name, held[r as usize]
            ));
        }
    }
    Ok(Report { n, n_blocks: nb, steps: s.steps.len(), messages, max_atoms: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockset::BlockSet;
    use crate::schedule::{Kind, Piece, RouteHint, Schedule, Send};

    /// Hand-built 3-node latency-optimal AllReduce: one step, everyone
    /// exchanges full vectors with both neighbors.
    fn tiny_valid() -> Schedule {
        let n = 3;
        let mut s = Schedule::new("tiny", n, n);
        let st = s.push_step();
        for r in 0..n {
            for d in [1i64, -1] {
                let to = ((r as i64 + d).rem_euclid(n as i64)) as u32;
                st.push(
                    r,
                    Send {
                        to,
                        pieces: vec![Piece {
                            blocks: BlockSet::full(n),
                            contrib: BlockSet::singleton(r, n),
                            kind: Kind::Reduce,
                        }],
                        route: RouteHint::Minimal,
                    },
                );
            }
        }
        s
    }

    #[test]
    fn accepts_valid() {
        let rep = validate_allreduce(&tiny_valid()).unwrap();
        assert_eq!(rep.steps, 1);
        assert_eq!(rep.messages, 6);
    }

    #[test]
    fn rejects_incomplete() {
        let mut s = tiny_valid();
        s.steps[0].sends[0].pop(); // drop one message
        let err = validate_allreduce(&s).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
    }

    #[test]
    fn rejects_double_reduction() {
        let mut s = tiny_valid();
        // node 0 sends its contribution to node 1 twice
        let dup = s.steps[0].sends[0][0].clone();
        s.steps[0].sends[0].push(dup);
        let err = validate_allreduce(&s).unwrap_err();
        assert!(err.contains("double reduction"), "{err}");
    }

    #[test]
    fn rejects_sending_unheld_contrib() {
        let n = 3;
        let mut s = Schedule::new("bad", n, n);
        let st = s.push_step();
        st.push(
            0,
            Send {
                to: 1,
                pieces: vec![Piece {
                    blocks: BlockSet::full(n),
                    contrib: BlockSet::singleton(2, n), // node 0 doesn't hold rank 2
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            },
        );
        let err = validate_allreduce(&s).unwrap_err();
        assert!(err.contains("sender lacks"), "{err}");
    }

    #[test]
    fn rejects_non_exact_cover() {
        // Node 0 receives {1,2} as ONE aggregate in step 0, then tries to
        // send only {1} in step 1 — impossible without un-summing.
        let n = 4;
        let mut s = Schedule::new("split", n, n);
        let st = s.push_step();
        st.push(
            1,
            Send {
                to: 0,
                pieces: vec![Piece {
                    blocks: BlockSet::full(n),
                    contrib: BlockSet::singleton(1, n),
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            },
        );
        // make it a combined aggregate {1,2}: first 2 -> 1 would be step 0
        // too; simpler: node 1 cannot do it in one step, so build directly:
        // step 0: 2->0 sends {2}; 1->0 sends {1}. Node 0 stores two atoms,
        // exact covers exist. Then make node 0 send {1,2,3}: lacks 3.
        let st = s.steps.last_mut().unwrap();
        st.push(
            2,
            Send {
                to: 0,
                pieces: vec![Piece {
                    blocks: BlockSet::full(n),
                    contrib: BlockSet::singleton(2, n),
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            },
        );
        let st = s.push_step();
        st.push(
            0,
            Send {
                to: 3,
                pieces: vec![Piece {
                    blocks: BlockSet::full(n),
                    // {0,1,2} is fine (three atoms); {0 plus half of a
                    // merged aggregate} would not be. Here we test the
                    // positive path of multi-atom exact cover.
                    contrib: BlockSet::cyc_range(0, 3, n),
                    kind: Kind::Reduce,
                }],
                route: RouteHint::Minimal,
            },
        );
        // Incomplete overall, but the error must NOT be about covers.
        let err = validate_allreduce(&s).unwrap_err();
        assert!(err.contains("incomplete"), "{err}");
    }

    #[test]
    fn allgather_roundtrip() {
        // ring allgather: n-1 steps passing one block right
        let n = 4;
        let mut s = Schedule::new("ag-ring", n, n);
        for t in 0..n - 1 {
            let st = s.push_step();
            for r in 0..n {
                let blk = (r + n - t) % n;
                st.push(
                    r,
                    Send {
                        to: (r + 1) % n,
                        pieces: vec![Piece {
                            blocks: BlockSet::singleton(blk, n),
                            contrib: BlockSet::full(n),
                            kind: Kind::Set,
                        }],
                        route: RouteHint::Minimal,
                    },
                );
            }
        }
        validate_allgather(&s).unwrap();
    }

    #[test]
    fn allgather_rejects_duplicates() {
        let n = 3;
        let mut s = Schedule::new("dup", n, n);
        let st = s.push_step();
        st.push(
            0,
            Send {
                to: 1,
                pieces: vec![Piece {
                    blocks: BlockSet::singleton(1, n), // receiver already has block 1
                    contrib: BlockSet::full(n),
                    kind: Kind::Set,
                }],
                route: RouteHint::Minimal,
            },
        );
        // sender 0 doesn't even hold block 1:
        let err = validate_allgather(&s).unwrap_err();
        assert!(err.contains("does not hold"), "{err}");
    }
}
