//! Schedule IR.
//!
//! A [`Schedule`] is the complete, static description of a collective: a
//! sequence of steps, each step mapping every source node to the messages it
//! sends. A message ([`Send`]) carries one or more [`Piece`]s.
//!
//! ## Semantics
//!
//! The AllReduce input vector of `m` bytes is partitioned into `n_blocks`
//! equal blocks. Every node initially contributes to *every* block (its own
//! local vector). A piece is either:
//!
//! * **Reduce**: for each block in `blocks`, the partial aggregate over the
//!   contributor ranks in `contrib`. The receiver adds it in; correctness
//!   requires `contrib` to be disjoint from the receiver's accumulated
//!   contributor set for those blocks, and the *sender* must hold `contrib`
//!   as an exact union of its stored atoms (you cannot un-sum an aggregate).
//! * **Set**: the final, fully-reduced value of each block in `blocks`
//!   (AllGather phase of bandwidth-optimal variants). `contrib` is the full
//!   rank set by construction.
//!
//! Message size: a piece carrying `|blocks|` of the `n_blocks` blocks is
//! `|blocks| / n_blocks · m` bytes — for latency-optimal variants pieces
//! carry all blocks (a full-vector partial aggregate, `m` bytes); for
//! bandwidth-optimal variants they carry the block subsets of the
//! reduce-scatter/allgather bookkeeping.
//!
//! The IR is *paper-faithful*: the per-step structure gives `steps(A)·α`,
//! and per-link byte loads under minimal routing give the `β·m_k·c_k`
//! congestion terms of Eq. 1.

pub mod validate;
pub mod analysis;
pub mod rewrite;
pub mod online;

use crate::blockset::BlockSet;

/// Piece semantics (see module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kind {
    Reduce,
    Set,
}

/// A contiguous unit of payload within a message.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Piece {
    /// Which vector blocks this piece carries (block space `0..n_blocks`).
    pub blocks: BlockSet,
    /// Whose contributions are aggregated in (rank space `0..n`).
    pub contrib: BlockSet,
    pub kind: Kind,
}

/// Routing directive for a message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RouteHint {
    /// Minimal (shortest-path, tie split by parity) routing.
    Minimal,
    /// Forced direction along one dimension (e.g. unmodified Bruck routes
    /// everything in the +1 direction regardless of distance).
    Directed { dim: u8, dir: i8 },
}

/// One message from an implicit source (the index into `Step::sends`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Send {
    pub to: u32,
    pub pieces: Vec<Piece>,
    pub route: RouteHint,
}

impl Send {
    /// Payload in units of the full vector size `m` (i.e. fraction of `m`).
    pub fn rel_bytes(&self, n_blocks: u32) -> f64 {
        self.pieces
            .iter()
            .map(|p| p.blocks.len() as f64 / n_blocks as f64)
            .sum()
    }
}

/// One communication step: `sends[src]` are the messages node `src` injects.
#[derive(Clone, Debug, Default)]
pub struct Step {
    pub sends: Vec<Vec<Send>>,
}

impl Step {
    pub fn new(n: u32) -> Self {
        Step { sends: vec![Vec::new(); n as usize] }
    }

    pub fn push(&mut self, src: u32, send: Send) {
        self.sends[src as usize].push(send);
    }
}

/// A complete collective schedule.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Human-readable identity, e.g. `trivance-L n=9`.
    pub name: String,
    /// Number of nodes.
    pub n: u32,
    /// Number of vector blocks (`n` for ring schedules; `D·a` etc. for
    /// merged multidimensional schedules).
    pub n_blocks: u32,
    pub steps: Vec<Step>,
}

impl Schedule {
    pub fn new(name: impl Into<String>, n: u32, n_blocks: u32) -> Self {
        Schedule { name: name.into(), n, n_blocks, steps: Vec::new() }
    }

    pub fn num_steps(&self) -> usize {
        self.steps.len()
    }

    /// Append an empty step and return a mutable reference to it.
    pub fn push_step(&mut self) -> &mut Step {
        self.steps.push(Step::new(self.n));
        let last = self.steps.len() - 1;
        &mut self.steps[last]
    }

    /// Total payload injected by `node` over the whole schedule, in units
    /// of `m`.
    pub fn node_sent_rel_bytes(&self, node: u32) -> f64 {
        self.steps
            .iter()
            .map(|s| {
                s.sends[node as usize]
                    .iter()
                    .map(|snd| snd.rel_bytes(self.n_blocks))
                    .sum::<f64>()
            })
            .sum()
    }

    /// Merge another schedule's steps into this one, step-aligned from
    /// `offset`; both must agree on `n` and `n_blocks`. Used to overlay the
    /// concurrent per-dimension collectives of multidimensional variants.
    pub fn overlay(&mut self, other: &Schedule, offset: usize) {
        assert_eq!(self.n, other.n);
        assert_eq!(self.n_blocks, other.n_blocks);
        while self.steps.len() < offset + other.steps.len() {
            self.push_step();
        }
        for (i, st) in other.steps.iter().enumerate() {
            for (src, sends) in st.sends.iter().enumerate() {
                for s in sends {
                    self.steps[offset + i].sends[src].push(s.clone());
                }
            }
        }
    }

    /// Concatenate `other` after this schedule (phase composition, e.g.
    /// Reduce-Scatter followed by AllGather).
    pub fn concat(&mut self, other: &Schedule) {
        let off = self.steps.len();
        self.overlay(other, off);
    }

    /// Number of messages in the whole schedule.
    pub fn num_messages(&self) -> usize {
        self.steps
            .iter()
            .map(|s| s.sends.iter().map(|v| v.len()).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reduce_piece(blocks: BlockSet, contrib: BlockSet) -> Piece {
        Piece { blocks, contrib, kind: Kind::Reduce }
    }

    #[test]
    fn rel_bytes_full_vector() {
        let s = Send {
            to: 1,
            pieces: vec![reduce_piece(BlockSet::full(9), BlockSet::singleton(0, 9))],
            route: RouteHint::Minimal,
        };
        assert!((s.rel_bytes(9) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_bytes_blocks() {
        let s = Send {
            to: 1,
            pieces: vec![reduce_piece(BlockSet::cyc_range(0, 3, 9), BlockSet::singleton(0, 9))],
            route: RouteHint::Minimal,
        };
        assert!((s.rel_bytes(9) - 1.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn overlay_and_concat() {
        let mut a = Schedule::new("a", 4, 4);
        a.push_step();
        let mut b = Schedule::new("b", 4, 4);
        let st = b.push_step();
        st.push(
            0,
            Send { to: 1, pieces: vec![], route: RouteHint::Minimal },
        );
        a.overlay(&b, 0);
        assert_eq!(a.num_steps(), 1);
        assert_eq!(a.num_messages(), 1);
        a.concat(&b);
        assert_eq!(a.num_steps(), 2);
        assert_eq!(a.num_messages(), 2);
    }

    #[test]
    fn node_sent_rel_bytes_sums() {
        let mut a = Schedule::new("a", 3, 3);
        let st = a.push_step();
        st.push(
            0,
            Send {
                to: 1,
                pieces: vec![reduce_piece(BlockSet::full(3), BlockSet::singleton(0, 3))],
                route: RouteHint::Minimal,
            },
        );
        st.push(
            0,
            Send {
                to: 2,
                pieces: vec![reduce_piece(BlockSet::cyc_range(0, 1, 3), BlockSet::singleton(0, 3))],
                route: RouteHint::Minimal,
            },
        );
        assert!((a.node_sent_rel_bytes(0) - (1.0 + 1.0 / 3.0)).abs() < 1e-12);
        assert_eq!(a.node_sent_rel_bytes(1), 0.0);
    }
}
