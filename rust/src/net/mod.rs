//! Heterogeneous per-link network model.
//!
//! The paper's SST configuration (§6) is a perfectly uniform fabric — one
//! bandwidth, one latency for every directed link — and that is what
//! [`crate::cost::NetParams`] describes. Real direct-connect tori are not
//! uniform: TPU-style systems mix fast intra-dimension links with slower
//! wrap/inter-dimension ones, links degrade (stragglers) and fail outright.
//! A [`NetModel`] layers that heterogeneity on top of a [`Torus`]:
//!
//! * a per-link [`LinkClass`] table of *scale factors* relative to the base
//!   `NetParams` — bandwidth, propagation latency, and hop-processing
//!   multipliers. Keeping the table relative (instead of absolute) means
//!   one simulation plan serves every base bandwidth (`fig8`'s sweep) and
//!   the uniform model (`all scales == 1.0`) is **bit-identical** to the
//!   model-less path: `x * 1.0 == x` exactly in IEEE-754.
//! * an optional *down set* of failed directed links. Route resolution
//!   ([`NetModel::route`]) keeps the nominal torus route whenever it avoids
//!   the down set and otherwise detours via a deterministic BFS shortest
//!   path ([`NetModel::route_avoiding`]).
//!
//! Every consumer that used to hard-code uniformity threads the model
//! through: [`crate::sim::SimPlan`] carries the per-link scale columns,
//! both simulator engines serialize at each link's own rate,
//! [`crate::schedule::analysis::analyze_with_model`] picks the Eq. 1
//! bottleneck as `max_k bytes_k / bw_link`, and the plan cache keys on
//! [`NetModel::fingerprint`] so a changed link table can never produce a
//! false cache hit. The scenario presets built from this model live in
//! [`crate::harness::scenarios`].
//!
//! The Python mirror of this module (`tools/pysim/mirror.py`, `NetModel`)
//! must stay in lockstep — including the [`SplitMix64`] draws behind the
//! deterministic straggler/faulty link picks and the BFS tie-breaks
//! (neighbor order: dimension ascending, direction `+1` before `-1`).

pub mod timeline;

pub use timeline::{Epoch, Mutation, Timeline};

use crate::schedule::RouteHint;
use crate::topology::{Link, Torus};
use crate::util::rng::SplitMix64;
use std::collections::VecDeque;

/// The down set disconnects `src` from `dst`: no route avoids it. Returned
/// (not panicked) by [`NetModel::try_route_avoiding`] so a partitioned
/// fabric surfaces as a clean error through plan building, analysis, the
/// `faulty` preset, and the `scenarios` CLI instead of a panic mid-sweep.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Unreachable {
    pub src: u32,
    pub dst: u32,
}

impl std::fmt::Display for Unreachable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "down links disconnect node {} from node {}: the fabric is partitioned \
             (no route avoids the down set)",
            self.src, self.dst
        )
    }
}

impl std::error::Error for Unreachable {}

/// Per-link scale factors relative to the base [`crate::cost::NetParams`].
/// `UNIFORM` (all `1.0`) reproduces the paper's homogeneous fabric exactly.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkClass {
    /// Bandwidth multiplier (`0.25` = a 4x-slower straggler link).
    pub bw_scale: f64,
    /// Propagation-latency multiplier.
    pub lat_scale: f64,
    /// Hop-processing-latency multiplier.
    pub proc_scale: f64,
}

impl LinkClass {
    pub const UNIFORM: LinkClass =
        LinkClass { bw_scale: 1.0, lat_scale: 1.0, proc_scale: 1.0 };

    /// Validated constructor: a zero/negative/non-finite bandwidth scale
    /// would silently produce infinite or negative serialization times
    /// downstream, so construction rejects it loudly.
    pub fn new(bw_scale: f64, lat_scale: f64, proc_scale: f64) -> LinkClass {
        assert!(
            bw_scale.is_finite() && bw_scale > 0.0,
            "LinkClass bandwidth scale must be finite and > 0, got {bw_scale}"
        );
        assert!(
            lat_scale.is_finite() && lat_scale >= 0.0,
            "LinkClass latency scale must be finite and >= 0, got {lat_scale}"
        );
        assert!(
            proc_scale.is_finite() && proc_scale >= 0.0,
            "LinkClass processing scale must be finite and >= 0, got {proc_scale}"
        );
        LinkClass { bw_scale, lat_scale, proc_scale }
    }

    /// A link slowed by `factor` (bandwidth only).
    pub fn slowdown(factor: f64) -> LinkClass {
        assert!(
            factor.is_finite() && factor > 0.0,
            "LinkClass slowdown factor must be finite and > 0, got {factor}"
        );
        LinkClass::new(1.0 / factor, 1.0, 1.0)
    }

    pub fn is_uniform(&self) -> bool {
        self.bw_scale == 1.0 && self.lat_scale == 1.0 && self.proc_scale == 1.0
    }
}

/// A torus plus its per-link link-class table and down set (module docs).
#[derive(Clone, Debug)]
pub struct NetModel {
    torus: Torus,
    classes: Vec<LinkClass>,
    down: Vec<bool>,
    num_down: usize,
}

impl NetModel {
    /// The paper's homogeneous fabric: every link `LinkClass::UNIFORM`, no
    /// down links. Reproduces the model-less code paths bit for bit.
    pub fn uniform(torus: &Torus) -> NetModel {
        let num_links = torus.num_links();
        NetModel {
            torus: torus.clone(),
            classes: vec![LinkClass::UNIFORM; num_links],
            down: vec![false; num_links],
            num_down: 0,
        }
    }

    /// Per-dimension bandwidth ratios (TPU-style fast/slow dimensions):
    /// every link along dimension `d` gets bandwidth scale `dim_bw_scale[d]`.
    pub fn hetero_dims(torus: &Torus, dim_bw_scale: &[f64]) -> NetModel {
        assert_eq!(
            dim_bw_scale.len(),
            torus.ndims(),
            "hetero_dims: one bandwidth scale per dimension"
        );
        let mut m = NetModel::uniform(torus);
        for node in 0..torus.n() {
            for (d, &s) in dim_bw_scale.iter().enumerate() {
                for dir in [1i8, -1] {
                    let idx = torus.link_index(Link { node, dim: d as u8, dir });
                    m.classes[idx] = LinkClass::new(s, 1.0, 1.0);
                }
            }
        }
        m
    }

    /// `k` deterministic-random links slowed by `factor` (bandwidth only).
    pub fn straggler(torus: &Torus, k: usize, factor: f64, seed: u64) -> NetModel {
        let mut m = NetModel::uniform(torus);
        for l in pick_links(torus, k, seed, false) {
            m.classes[l] = LinkClass::slowdown(factor);
        }
        m
    }

    /// Asymmetric per-direction bandwidth (up ≠ down): every link along
    /// dimension `d` gets `up_scale[d]` in the `+1` direction and
    /// `down_scale[d]` in the `-1` direction. Models degraded cable
    /// directions (a real failure mode the symmetric presets cannot
    /// express); `up == down == 1.0` everywhere is the uniform fabric.
    pub fn asymmetric_dims(torus: &Torus, up_scale: &[f64], down_scale: &[f64]) -> NetModel {
        assert_eq!(up_scale.len(), torus.ndims(), "asymmetric_dims: one up scale per dim");
        assert_eq!(down_scale.len(), torus.ndims(), "asymmetric_dims: one down scale per dim");
        let mut m = NetModel::uniform(torus);
        for node in 0..torus.n() {
            for d in 0..torus.ndims() {
                for (dir, s) in [(1i8, up_scale[d]), (-1, down_scale[d])] {
                    let idx = torus.link_index(Link { node, dim: d as u8, dir });
                    m.classes[idx] = LinkClass::new(s, 1.0, 1.0);
                }
            }
        }
        m
    }

    /// `k` deterministic-random links taken down; the selection rejects any
    /// link whose removal would disconnect the directed link graph, so
    /// every pair stays routable.
    pub fn faulty(torus: &Torus, k: usize, seed: u64) -> NetModel {
        let mut m = NetModel::uniform(torus);
        for l in pick_links(torus, k, seed, true) {
            m.down[l] = true;
            m.num_down += 1;
        }
        m
    }

    pub fn torus(&self) -> &Torus {
        &self.torus
    }

    /// Override one link's class (dense link index).
    pub fn set_class(&mut self, link: usize, class: LinkClass) {
        self.classes[link] = class;
    }

    /// Mark one link up/down (dense link index). Routability is checked at
    /// route resolution, not here: [`route_avoiding`](Self::route_avoiding)
    /// panics with a clear message if a needed pair becomes disconnected.
    pub fn set_down(&mut self, link: usize, down: bool) {
        if self.down[link] != down {
            self.down[link] = down;
            if down {
                self.num_down += 1;
            } else {
                self.num_down -= 1;
            }
        }
    }

    pub fn class(&self, link: usize) -> &LinkClass {
        &self.classes[link]
    }

    pub fn bw_scale(&self, link: usize) -> f64 {
        self.classes[link].bw_scale
    }

    pub fn lat_scale(&self, link: usize) -> f64 {
        self.classes[link].lat_scale
    }

    pub fn proc_scale(&self, link: usize) -> f64 {
        self.classes[link].proc_scale
    }

    pub fn is_down(&self, link: usize) -> bool {
        self.down[link]
    }

    pub fn num_down(&self) -> usize {
        self.num_down
    }

    /// Is this exactly the paper's homogeneous fabric? Gates the simulator
    /// fast paths and the legacy (bit-identical) arithmetic.
    pub fn is_uniform(&self) -> bool {
        self.num_down == 0 && self.classes.iter().all(LinkClass::is_uniform)
    }

    /// Cache fingerprint of the link table + down set. `0` is reserved for
    /// the uniform model (any dims — the topology is already part of
    /// [`crate::sim::PlanKey`]); heterogeneous models hash their class bits
    /// and down links FNV-1a style with the low bit forced to 1, so a
    /// hetero model can never collide with uniform.
    pub fn fingerprint(&self) -> u64 {
        if self.is_uniform() {
            return 0;
        }
        let mut h = crate::util::Fnv::new();
        for &d in self.torus.dims() {
            h.mix(d as u64);
        }
        for c in &self.classes {
            h.mix(c.bw_scale.to_bits());
            h.mix(c.lat_scale.to_bits());
            h.mix(c.proc_scale.to_bits());
        }
        for (l, &down) in self.down.iter().enumerate() {
            if down {
                h.mix(l as u64);
            }
        }
        h.finish_nonzero()
    }

    /// Resolve a route under this model: the nominal torus route (minimal
    /// or directed per the hint) when it avoids every down link, otherwise
    /// a BFS shortest-path detour. With an empty down set this is exactly
    /// the torus routing the plans always used. Errs when the down set
    /// disconnects the pair.
    pub fn try_route(&self, src: u32, dst: u32, hint: RouteHint) -> Result<Vec<Link>, Unreachable> {
        let nominal = match hint {
            RouteHint::Minimal => self.torus.route(src, dst),
            RouteHint::Directed { dim, dir } => {
                self.torus.route_directed(src, dst, dim as usize, dir)
            }
        };
        if self.num_down == 0
            || !nominal.iter().any(|&l| self.down[self.torus.link_index(l)])
        {
            return Ok(nominal);
        }
        self.try_route_avoiding(src, dst)
    }

    /// [`try_route`](Self::try_route), panicking on a partitioned fabric —
    /// for callers that already validated connectivity (the presets do).
    pub fn route(&self, src: u32, dst: u32, hint: RouteHint) -> Vec<Link> {
        self.try_route(src, dst, hint).unwrap_or_else(|e| panic!("NetModel: {e}"))
    }

    /// Deterministic BFS shortest path skipping down links (neighbor order:
    /// dimension ascending, direction `+1` before `-1`; FIFO queue — keep
    /// in lockstep with the pysim mirror). Errs with [`Unreachable`] when
    /// the down set disconnects the pair.
    pub fn try_route_avoiding(&self, src: u32, dst: u32) -> Result<Vec<Link>, Unreachable> {
        if src == dst {
            return Ok(Vec::new());
        }
        let n = self.torus.n() as usize;
        let mut parent: Vec<i64> = vec![-2; n]; // -2 unvisited, -1 source
        let mut parent_link: Vec<Link> = vec![Link { node: 0, dim: 0, dir: 1 }; n];
        parent[src as usize] = -1;
        let mut queue = VecDeque::new();
        queue.push_back(src);
        while let Some(u) = queue.pop_front() {
            for d in 0..self.torus.ndims() {
                for dir in [1i8, -1] {
                    let link = Link { node: u, dim: d as u8, dir };
                    if self.down[self.torus.link_index(link)] {
                        continue;
                    }
                    let v = self.torus.neighbor(u, d, dir as i64);
                    if parent[v as usize] != -2 {
                        continue;
                    }
                    parent[v as usize] = u as i64;
                    parent_link[v as usize] = link;
                    queue.push_back(v);
                }
            }
        }
        if parent[dst as usize] == -2 {
            return Err(Unreachable { src, dst });
        }
        let mut links = Vec::new();
        let mut cur = dst;
        while parent[cur as usize] != -1 {
            links.push(parent_link[cur as usize]);
            cur = parent[cur as usize] as u32;
        }
        links.reverse();
        Ok(links)
    }

    /// [`try_route_avoiding`](Self::try_route_avoiding), panicking on a
    /// partitioned fabric.
    pub fn route_avoiding(&self, src: u32, dst: u32) -> Vec<Link> {
        self.try_route_avoiding(src, dst).unwrap_or_else(|e| panic!("NetModel: {e}"))
    }

    /// BFS hop distance from `src` to `dst` avoiding the down set — the
    /// per-pair oracle [`distances_to`](Self::distances_to) (the bulk
    /// metric [`crate::schedule::rewrite`] actually uses) is validated
    /// against in tests.
    pub fn distance_avoiding(&self, src: u32, dst: u32) -> Result<usize, Unreachable> {
        Ok(self.try_route_avoiding(src, dst)?.len())
    }

    /// Hop distance from **every** node to `dst` avoiding the down set
    /// (`None` = unreachable): one reverse-direction BFS instead of one
    /// forward BFS per source — the bulk donor-selection metric of
    /// [`crate::schedule::rewrite`]'s cleanup (which otherwise scans
    /// `O(nodes × blocks)` donor candidates per receiver). Agrees with
    /// [`distance_avoiding`](Self::distance_avoiding) exactly: shortest
    /// path *lengths* are search-order independent.
    pub fn distances_to(&self, dst: u32) -> Vec<Option<usize>> {
        let n = self.torus.n() as usize;
        let mut dist: Vec<Option<usize>> = vec![None; n];
        dist[dst as usize] = Some(0);
        let mut queue = VecDeque::new();
        queue.push_back(dst);
        while let Some(v) = queue.pop_front() {
            let dv = dist[v as usize].expect("queued nodes have distances");
            for d in 0..self.torus.ndims() {
                for dir in [1i8, -1] {
                    // u reaches v over link (u, d, dir) with neighbor(u) = v
                    let u = self.torus.neighbor(v, d, -(dir as i64));
                    let link = Link { node: u, dim: d as u8, dir };
                    if self.down[self.torus.link_index(link)] {
                        continue;
                    }
                    if dist[u as usize].is_none() {
                        dist[u as usize] = Some(dv + 1);
                        queue.push_back(u);
                    }
                }
            }
        }
        dist
    }
}

/// Is the directed link graph minus `down` still strongly connected?
pub fn strongly_connected(torus: &Torus, down: &[bool]) -> bool {
    for transpose in [false, true] {
        let mut seen = vec![false; torus.n() as usize];
        seen[0] = true;
        let mut stack = vec![0u32];
        let mut count = 1usize;
        while let Some(u) = stack.pop() {
            for d in 0..torus.ndims() {
                for dir in [1i8, -1] {
                    // forward edge u->v over link (u, d, dir); transposed
                    // edge v->u over link (v, d, dir) with v = u - dir
                    let (v, l) = if transpose {
                        let v = torus.neighbor(u, d, -(dir as i64));
                        (v, torus.link_index(Link { node: v, dim: d as u8, dir }))
                    } else {
                        let v = torus.neighbor(u, d, dir as i64);
                        (v, torus.link_index(Link { node: u, dim: d as u8, dir }))
                    };
                    if down[l] || seen[v as usize] {
                        continue;
                    }
                    seen[v as usize] = true;
                    count += 1;
                    stack.push(v);
                }
            }
        }
        if count != torus.n() as usize {
            return false;
        }
    }
    true
}

/// Draw `k` distinct links deterministically from `seed`; with
/// `keep_connected`, reject draws that would disconnect the link graph.
/// Public so the scenario presets (static *and* dynamic/timeline families)
/// share one seeded pick — mirrored in `tools/pysim`.
pub fn pick_links(torus: &Torus, k: usize, seed: u64, keep_connected: bool) -> Vec<usize> {
    let num_links = torus.num_links();
    assert!(k < num_links, "cannot pick {k} of {num_links} links");
    let mut rng = SplitMix64::new(seed);
    let mut down = vec![false; num_links];
    let mut chosen = Vec::with_capacity(k);
    let mut attempts = 0usize;
    while chosen.len() < k {
        attempts += 1;
        assert!(attempts <= 64 * k + 1024, "link picking stalled (k={k}, seed={seed})");
        let l = rng.below(num_links as u64) as usize;
        if down[l] {
            continue;
        }
        down[l] = true;
        if keep_connected && !strongly_connected(torus, &down) {
            down[l] = false;
            continue;
        }
        chosen.push(l);
    }
    chosen.sort_unstable();
    chosen
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_model_is_uniform_and_routes_nominally() {
        let t = Torus::ring(9);
        let m = NetModel::uniform(&t);
        assert!(m.is_uniform());
        assert_eq!(m.fingerprint(), 0);
        for (src, dst) in [(0u32, 3u32), (7, 2), (4, 4)] {
            assert_eq!(m.route(src, dst, RouteHint::Minimal), t.route(src, dst));
        }
    }

    #[test]
    fn hetero_dims_scales_per_dimension() {
        let t = Torus::new(&[3, 3]);
        let m = NetModel::hetero_dims(&t, &[1.0, 0.5]);
        assert!(!m.is_uniform());
        for node in 0..t.n() {
            for dir in [1i8, -1] {
                let l0 = t.link_index(Link { node, dim: 0, dir });
                let l1 = t.link_index(Link { node, dim: 1, dir });
                assert_eq!(m.bw_scale(l0), 1.0);
                assert_eq!(m.bw_scale(l1), 0.5);
            }
        }
    }

    #[test]
    fn fingerprints_separate_models() {
        let t = Torus::new(&[3, 3]);
        let uniform = NetModel::uniform(&t);
        let straggled = NetModel::straggler(&t, 2, 4.0, 1);
        let faulty = NetModel::faulty(&t, 1, 1);
        let hetero = NetModel::hetero_dims(&t, &[1.0, 0.5]);
        let fps = [
            uniform.fingerprint(),
            straggled.fingerprint(),
            faulty.fingerprint(),
            hetero.fingerprint(),
        ];
        for i in 0..fps.len() {
            for j in i + 1..fps.len() {
                assert_ne!(fps[i], fps[j], "fingerprint collision {i} vs {j}");
            }
        }
        // deterministic: same preset, same fingerprint
        assert_eq!(
            NetModel::straggler(&t, 2, 4.0, 1).fingerprint(),
            straggled.fingerprint()
        );
        // different seed, different selection (with overwhelming likelihood
        // on 36 links), different fingerprint
        assert_ne!(
            NetModel::straggler(&t, 2, 4.0, 2).fingerprint(),
            straggled.fingerprint()
        );
    }

    #[test]
    fn detour_avoids_down_links_and_connects() {
        let t = Torus::ring(9);
        let mut m = NetModel::uniform(&t);
        // take down 0 -> 1 (forward): 0's +1 route to 3 must detour
        let l = t.link_index(Link { node: 0, dim: 0, dir: 1 });
        m.set_down(l, true);
        assert!(!m.is_uniform());
        let route = m.route(0, 3, RouteHint::Minimal);
        // walk it: connects 0 -> 3, never crosses the down link
        let mut cur = 0u32;
        for link in &route {
            assert_eq!(link.node, cur);
            assert!(!m.is_down(t.link_index(*link)), "route crosses a down link");
            cur = t.neighbor(cur, link.dim as usize, link.dir as i64);
        }
        assert_eq!(cur, 3);
        // unaffected pairs keep their nominal route
        assert_eq!(m.route(1, 3, RouteHint::Minimal), t.route(1, 3));
        // directed routes detour too when blocked
        let dr = m.route(0, 2, RouteHint::Directed { dim: 0, dir: 1 });
        let mut cur = 0u32;
        for link in &dr {
            assert!(!m.is_down(t.link_index(*link)));
            cur = t.neighbor(cur, link.dim as usize, link.dir as i64);
        }
        assert_eq!(cur, 2);
    }

    #[test]
    fn faulty_preset_stays_strongly_connected() {
        for dims in [vec![9u32], vec![3, 3], vec![4, 4]] {
            let t = Torus::new(&dims);
            for k in [1usize, 2, 3] {
                let m = NetModel::faulty(&t, k, 0xDEAD);
                assert_eq!(m.num_down(), k);
                assert!(strongly_connected(&t, &m.down));
                // every pair remains routable
                for src in 0..t.n() {
                    for dst in 0..t.n() {
                        let r = m.route_avoiding(src, dst);
                        assert_eq!(r.is_empty(), src == dst);
                    }
                }
            }
        }
    }

    #[test]
    fn distances_to_agrees_with_per_pair_bfs() {
        let t = Torus::new(&[3, 3]);
        let mut m = NetModel::uniform(&t);
        // cut one cable so the down set actually matters
        let l = t.link_index(Link { node: 0, dim: 0, dir: 1 });
        m.set_down(l, true);
        m.set_down(t.link_index(t.reverse_link(t.link_at(l))), true);
        for dst in 0..t.n() {
            let bulk = m.distances_to(dst);
            for src in 0..t.n() {
                assert_eq!(
                    bulk[src as usize],
                    m.distance_avoiding(src, dst).ok(),
                    "{src}->{dst}"
                );
            }
        }
    }

    #[test]
    fn bfs_route_is_minimal_without_faults() {
        let t = Torus::new(&[5, 5]);
        let m = NetModel::uniform(&t);
        for src in 0..t.n() {
            for dst in 0..t.n() {
                assert_eq!(
                    m.route_avoiding(src, dst).len() as u32,
                    t.distance(src, dst),
                    "{src}->{dst}"
                );
            }
        }
    }

    #[test]
    fn partitioned_fabric_returns_unreachable_not_garbage() {
        // Isolate node 1 on a ring: cut its forward in-link (0 -> 1) and
        // its backward in-link (2 -> 1). Every route *to* node 1 must
        // resolve to a clean Unreachable, while unrelated pairs still work.
        let t = Torus::ring(9);
        let mut m = NetModel::uniform(&t);
        m.set_down(t.link_index(Link { node: 0, dim: 0, dir: 1 }), true);
        m.set_down(t.link_index(Link { node: 2, dim: 0, dir: -1 }), true);
        assert!(!strongly_connected(&t, &m.down));
        let err = m.try_route_avoiding(0, 1).unwrap_err();
        assert_eq!(err, Unreachable { src: 0, dst: 1 });
        assert!(err.to_string().contains("partitioned"), "{err}");
        assert_eq!(m.try_route(5, 1, RouteHint::Minimal), Err(Unreachable { src: 5, dst: 1 }));
        // node 1 can still send (its out-links are up), and bystanders route
        assert!(m.try_route_avoiding(1, 4).is_ok());
        assert!(m.try_route(3, 7, RouteHint::Minimal).is_ok());
    }

    #[test]
    fn asymmetric_dims_scales_directions_independently() {
        let t = Torus::new(&[3, 3]);
        let m = NetModel::asymmetric_dims(&t, &[0.5, 1.0], &[1.0, 0.25]);
        assert!(!m.is_uniform());
        for node in 0..t.n() {
            assert_eq!(m.bw_scale(t.link_index(Link { node, dim: 0, dir: 1 })), 0.5);
            assert_eq!(m.bw_scale(t.link_index(Link { node, dim: 0, dir: -1 })), 1.0);
            assert_eq!(m.bw_scale(t.link_index(Link { node, dim: 1, dir: 1 })), 1.0);
            assert_eq!(m.bw_scale(t.link_index(Link { node, dim: 1, dir: -1 })), 0.25);
        }
        // symmetric scales reproduce hetero_dims exactly
        let sym = NetModel::asymmetric_dims(&t, &[1.0, 0.5], &[1.0, 0.5]);
        assert_eq!(sym.fingerprint(), NetModel::hetero_dims(&t, &[1.0, 0.5]).fingerprint());
    }

    #[test]
    #[should_panic(expected = "bandwidth scale must be finite and > 0")]
    fn zero_bandwidth_class_rejected() {
        let _ = LinkClass::new(0.0, 1.0, 1.0);
    }

    #[test]
    #[should_panic(expected = "latency scale must be finite and >= 0")]
    fn negative_latency_class_rejected() {
        let _ = LinkClass::new(1.0, -0.5, 1.0);
    }

    #[test]
    #[should_panic(expected = "slowdown factor must be finite and > 0")]
    fn nan_slowdown_rejected() {
        let _ = LinkClass::slowdown(f64::NAN);
    }
}
