//! Time-varying fabrics: a deterministic schedule of [`NetModel`] capacity
//! mutations applied *during* a collective.
//!
//! PR 3's `NetModel` degrades links statically — the fabric the plan was
//! routed for is the fabric the whole collective runs on. Real fabrics
//! change mid-collective: links brown out and recover, one direction of a
//! cable degrades while the other stays clean, a link flaps. A
//! [`Timeline`] is the deterministic description of those changes: a sorted
//! list of [`Epoch`]s, each applying a batch of [`Mutation`]s at an
//! absolute simulation time.
//!
//! Semantics, by engine:
//!
//! * [`crate::sim::flow`] pushes one event per epoch and **re-water-fills**
//!   when it fires: per-link capacities (and forwarding latencies) switch to
//!   the new values and every active flow's max-min fair rate is recomputed.
//!   A link taken down ([`Mutation::SetDown`]) has capacity zero — flows
//!   crossing it stall at rate 0 and resume on recovery.
//! * [`crate::sim::packet`] needs no epoch events: rates are pre-scheduled,
//!   so a batch's busy interval is **split at epoch boundaries** — bytes
//!   serialize at each window's own rate, zero-rate (down) windows pass no
//!   bytes, and the hop latency charged is the one in force when the batch
//!   finishes the link.
//!
//! Routing does **not** change with a timeline: a capacity mutation never
//! reroutes traffic (the plan's routes are fixed at build time). A link that
//! *fails for good* mid-collective is a schedule-level event, not a capacity
//! event — that case is [`crate::schedule::rewrite`]'s job (fault-aware
//! schedule rewriting / detour planning via
//! [`crate::sim::SimPlan::build_faulted`]), because traffic still routed
//! over a dead link would otherwise stall forever. The engines enforce this:
//! a timeline that leaves bytes stranded on a permanently-down link returns
//! the typed [`crate::sim::SimError::Stranded`] — carrying the blocked link
//! and schedule step — instead of reporting a bogus completion (or
//! aborting the process). The online controller
//! ([`crate::schedule::online`]) is the recovery path: it turns the same
//! permanent failure into a mid-collective rewrite or detour.
//!
//! The **empty timeline is the static fabric**: every simulator entry point
//! short-circuits to the exact pre-timeline code path (same float ops, same
//! event counts), so static results are bit-identical by construction —
//! `rust/tests/sim_crosscheck.rs` asserts it across the registry.
//!
//! Mirrored in `tools/pysim/mirror.py` (`Timeline`, the `*_dyn` engines);
//! keep the window arithmetic and the epoch application order in lockstep.

use super::LinkClass;

/// One capacity mutation applied at an epoch boundary.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mutation {
    /// Replace one link's [`LinkClass`] (bandwidth / latency / processing
    /// scales relative to the base `NetParams`). `LinkClass::UNIFORM`
    /// restores the pristine link.
    SetClass { link: u32, class: LinkClass },
    /// Take one link down (capacity 0) or bring it back up. Traffic routed
    /// over a down link stalls until recovery — permanent failures belong
    /// to [`crate::schedule::rewrite`], not the timeline (module docs).
    SetDown { link: u32, down: bool },
}

impl Mutation {
    /// The dense link index this mutation targets.
    pub fn link(&self) -> u32 {
        match *self {
            Mutation::SetClass { link, .. } => link,
            Mutation::SetDown { link, .. } => link,
        }
    }
}

/// A batch of mutations applied atomically at time `t` (seconds).
#[derive(Clone, Debug, PartialEq)]
pub struct Epoch {
    pub t: f64,
    pub mutations: Vec<Mutation>,
}

/// A deterministic schedule of fabric mutations (module docs). Epochs are
/// kept sorted by time; mutations within an epoch apply in list order.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Timeline {
    epochs: Vec<Epoch>,
}

impl Timeline {
    /// The static fabric: no mutations, bit-identical simulation.
    pub fn empty() -> Timeline {
        Timeline { epochs: Vec::new() }
    }

    /// Build a timeline from epochs; sorts by time. Epoch times must be
    /// finite and non-negative (prefer expressing the t = 0 state in the
    /// `NetModel` itself; a 0-time epoch exists for degenerate windows,
    /// e.g. a brownout under `α = 0`).
    pub fn new(mut epochs: Vec<Epoch>) -> Timeline {
        for e in &epochs {
            assert!(
                e.t.is_finite() && e.t >= 0.0,
                "Timeline epoch time must be finite and >= 0, got {}",
                e.t
            );
        }
        epochs.sort_by(|a, b| a.t.total_cmp(&b.t));
        Timeline { epochs }
    }

    pub fn is_empty(&self) -> bool {
        self.epochs.is_empty()
    }

    pub fn epochs(&self) -> &[Epoch] {
        &self.epochs
    }

    /// Cache/staleness fingerprint of the mutation schedule. `0` is
    /// reserved for the empty timeline (the static fabric); non-empty
    /// timelines hash times and mutations FNV-1a style with the low bit
    /// forced to 1, so a dynamic timeline can never collide with static.
    pub fn fingerprint(&self) -> u64 {
        if self.epochs.is_empty() {
            return 0;
        }
        let mut h = crate::util::Fnv::new();
        for e in &self.epochs {
            h.mix(e.t.to_bits());
            for m in &e.mutations {
                match *m {
                    Mutation::SetClass { link, class } => {
                        h.mix(1);
                        h.mix(link as u64);
                        h.mix(class.bw_scale.to_bits());
                        h.mix(class.lat_scale.to_bits());
                        h.mix(class.proc_scale.to_bits());
                    }
                    Mutation::SetDown { link, down } => {
                        h.mix(2);
                        h.mix(link as u64);
                        h.mix(down as u64);
                    }
                }
            }
        }
        h.finish_nonzero()
    }

}

#[cfg(test)]
mod tests {
    use super::*;

    fn slow(link: u32, factor: f64) -> Mutation {
        Mutation::SetClass { link, class: LinkClass::slowdown(factor) }
    }

    #[test]
    fn empty_timeline_is_static() {
        let t = Timeline::empty();
        assert!(t.is_empty());
        assert_eq!(t.fingerprint(), 0);
        assert!(t.epochs().is_empty());
    }

    #[test]
    fn epochs_sort_by_time_and_fingerprints_separate() {
        let a = Timeline::new(vec![
            Epoch { t: 2e-6, mutations: vec![slow(3, 4.0)] },
            Epoch { t: 1e-6, mutations: vec![Mutation::SetDown { link: 3, down: true }] },
        ]);
        assert_eq!(a.epochs()[0].t, 1e-6);
        assert_eq!(a.epochs()[1].t, 2e-6);
        let b = Timeline::new(vec![Epoch { t: 1e-6, mutations: vec![slow(3, 4.0)] }]);
        assert_ne!(a.fingerprint(), 0);
        assert_ne!(a.fingerprint(), b.fingerprint());
        // deterministic
        assert_eq!(b.fingerprint(), b.clone().fingerprint());
    }

    #[test]
    #[should_panic(expected = "epoch time must be finite and >= 0")]
    fn negative_time_epoch_rejected() {
        let _ = Timeline::new(vec![Epoch { t: -1e-9, mutations: vec![] }]);
    }

    #[test]
    #[should_panic(expected = "epoch time must be finite and >= 0")]
    fn nan_time_epoch_rejected() {
        let _ = Timeline::new(vec![Epoch { t: f64::NAN, mutations: vec![] }]);
    }
}
