//! Bidirectional ring / D-dimensional torus topology and minimal routing.
//!
//! Model (paper §2): `n = ∏ dims` nodes; every node has two ports per
//! dimension (one per direction), i.e. `2D` ports total, and can inject one
//! message per port concurrently. Links are directed (a physical
//! bidirectional link is two directed links). Packets are forwarded with
//! minimal routing; on exact-half-ring ties the direction is split
//! deterministically by source parity (the "minimal adaptive" assumption).

use crate::blockset::BlockSet;

/// A D-dimensional torus (D = 1 is the bidirectional ring).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Torus {
    dims: Vec<u32>,
    /// Strides for coordinate <-> rank conversion (row-major, dim 0 fastest).
    strides: Vec<u64>,
    n: u32,
}

/// A directed link: from `node`, along `dim`, in direction `dir`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Link {
    pub node: u32,
    pub dim: u8,
    /// +1 = increasing coordinate, -1 = decreasing.
    pub dir: i8,
}

impl Torus {
    pub fn ring(n: u32) -> Self {
        Self::new(&[n])
    }

    pub fn new(dims: &[u32]) -> Self {
        assert!(!dims.is_empty() && dims.iter().all(|&d| d >= 2), "torus dims must be >= 2");
        let mut strides = Vec::with_capacity(dims.len());
        let mut acc = 1u64;
        for &d in dims {
            strides.push(acc);
            acc *= d as u64;
        }
        assert!(acc <= u32::MAX as u64, "torus too large");
        Torus { dims: dims.to_vec(), strides, n: acc as u32 }
    }

    pub fn n(&self) -> u32 {
        self.n
    }

    pub fn ndims(&self) -> usize {
        self.dims.len()
    }

    pub fn dims(&self) -> &[u32] {
        &self.dims
    }

    /// Total number of directed links.
    pub fn num_links(&self) -> usize {
        self.n as usize * self.dims.len() * 2
    }

    /// Dense index of a directed link, for per-link load accounting.
    pub fn link_index(&self, l: Link) -> usize {
        let d = l.dim as usize;
        let dirbit = usize::from(l.dir > 0);
        (l.node as usize * self.dims.len() + d) * 2 + dirbit
    }

    /// Inverse of [`link_index`](Self::link_index): the directed link at
    /// dense index `idx`.
    pub fn link_at(&self, idx: usize) -> Link {
        debug_assert!(idx < self.num_links());
        let dirbit = idx & 1;
        let rest = idx / 2;
        let dim = (rest % self.dims.len()) as u8;
        let node = (rest / self.dims.len()) as u32;
        Link { node, dim, dir: if dirbit == 1 { 1 } else { -1 } }
    }

    /// The opposite-direction link of the same physical cable: a real
    /// cable failure takes out **both** directed links of an edge.
    pub fn reverse_link(&self, l: Link) -> Link {
        Link {
            node: self.neighbor(l.node, l.dim as usize, l.dir as i64),
            dim: l.dim,
            dir: -l.dir,
        }
    }

    pub fn coords(&self, rank: u32) -> Vec<u32> {
        let mut c = Vec::with_capacity(self.dims.len());
        let mut r = rank as u64;
        for &d in &self.dims {
            c.push((r % d as u64) as u32);
            r /= d as u64;
        }
        c
    }

    pub fn rank(&self, coords: &[u32]) -> u32 {
        assert_eq!(coords.len(), self.dims.len());
        let mut r = 0u64;
        for (i, &c) in coords.iter().enumerate() {
            debug_assert!(c < self.dims[i]);
            r += c as u64 * self.strides[i];
        }
        r as u32
    }

    /// The coordinate of `rank` in `dim`.
    pub fn coord(&self, rank: u32, dim: usize) -> u32 {
        ((rank as u64 / self.strides[dim]) % self.dims[dim] as u64) as u32
    }

    /// Neighbor of `rank` at cyclic `offset` along `dim`.
    pub fn neighbor(&self, rank: u32, dim: usize, offset: i64) -> u32 {
        let a = self.dims[dim] as i64;
        let c = self.coord(rank, dim) as i64;
        let nc = (c + offset).rem_euclid(a) as u64;
        let base = rank as u64 - (c as u64) * self.strides[dim];
        (base + nc * self.strides[dim]) as u32
    }

    /// Cyclic distance between two coordinates along `dim`.
    pub fn cyc_distance(&self, a: u32, b: u32, dim: usize) -> u32 {
        let m = self.dims[dim];
        let d = (b + m - a) % m;
        d.min(m - d)
    }

    /// Hop distance between two ranks (sum of per-dim minimal distances).
    pub fn distance(&self, a: u32, b: u32) -> u32 {
        (0..self.dims.len())
            .map(|d| self.cyc_distance(self.coord(a, d), self.coord(b, d), d))
            .sum()
    }

    /// Minimal route from `src` to `dst` as a sequence of directed links,
    /// dimension-ordered. On an exact-half-ring tie in a dimension the
    /// direction is chosen by the parity of the source coordinate, which
    /// splits tied traffic evenly across both directions (minimal adaptive
    /// routing under uniform symmetric load).
    pub fn route(&self, src: u32, dst: u32) -> Vec<Link> {
        let mut links = Vec::new();
        let mut cur = src;
        for d in 0..self.dims.len() {
            let a = self.dims[d];
            let cs = self.coord(cur, d);
            let cd = self.coord(dst, d);
            if cs == cd {
                continue;
            }
            let fwd = (cd + a - cs) % a;
            let bwd = a - fwd;
            let dir: i8 = if fwd < bwd {
                1
            } else if bwd < fwd {
                -1
            } else if cs % 2 == 0 {
                1
            } else {
                -1
            };
            let hops = fwd.min(bwd);
            for _ in 0..hops {
                links.push(Link { node: cur, dim: d as u8, dir });
                cur = self.neighbor(cur, d, dir as i64);
            }
        }
        debug_assert_eq!(cur, dst);
        links
    }

    /// Route that is forced to travel in `dir` along `dim` (used by
    /// unidirectional algorithms such as unmodified Bruck, which route all
    /// traffic one way regardless of distance).
    pub fn route_directed(&self, src: u32, dst: u32, dim: usize, dir: i8) -> Vec<Link> {
        let a = self.dims[dim];
        let cs = self.coord(src, dim);
        let cd = self.coord(dst, dim);
        assert_eq!(
            self.rank(&{
                let mut c = self.coords(src);
                c[dim] = cd;
                c
            }),
            dst,
            "route_directed requires src/dst to differ only in `dim`"
        );
        let hops = if dir > 0 { (cd + a - cs) % a } else { (cs + a - cd) % a };
        let mut links = Vec::with_capacity(hops as usize);
        let mut cur = src;
        for _ in 0..hops {
            links.push(Link { node: cur, dim: dim as u8, dir });
            cur = self.neighbor(cur, dim, dir as i64);
        }
        links
    }

    /// All ranks forming the 1-D ring through `rank` along `dim`, in
    /// coordinate order starting at coordinate 0.
    pub fn ring_through(&self, rank: u32, dim: usize) -> Vec<u32> {
        let c = self.coord(rank, dim);
        let base = rank as u64 - c as u64 * self.strides[dim];
        (0..self.dims[dim])
            .map(|i| (base + i as u64 * self.strides[dim]) as u32)
            .collect()
    }

    /// The set of ranks whose coordinate in every dim `d` lies in
    /// `ranges[d]` — used to build product contributor sets for
    /// multidimensional schedules. Dim 0 is the fastest-varying (stride-1)
    /// coordinate, so the result is assembled as one linear interval per
    /// combination of the higher-dimension coordinates.
    pub fn product_set(&self, ranges: &[BlockSet]) -> BlockSet {
        assert_eq!(ranges.len(), self.dims.len());
        if ranges.iter().any(|r| r.is_empty()) {
            return BlockSet::empty();
        }
        // Linear intervals of dim-0 coordinates (stride 1 in rank space).
        let dim0: Vec<(u32, u32)> = ranges[0].intervals().collect();
        // Enumerate higher-dim coordinate combinations as base offsets.
        let mut bases: Vec<u64> = vec![0];
        for d in 1..ranges.len() {
            let stride = self.strides[d];
            let mut next = Vec::with_capacity(bases.len() * ranges[d].len() as usize);
            for c in ranges[d].iter() {
                let off = c as u64 * stride;
                next.extend(bases.iter().map(|&b| b + off));
            }
            bases = next;
        }
        let mut ivs = Vec::with_capacity(bases.len() * dim0.len());
        for &b in &bases {
            for &(s, e) in &dim0 {
                ivs.push((b as u32 + s, b as u32 + e));
            }
        }
        BlockSet::from_intervals(ivs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_basics() {
        let t = Torus::ring(9);
        assert_eq!(t.n(), 9);
        assert_eq!(t.neighbor(0, 0, -1), 8);
        assert_eq!(t.neighbor(8, 0, 1), 0);
        assert_eq!(t.distance(0, 5), 4);
        assert_eq!(t.distance(0, 4), 4);
    }

    #[test]
    fn torus_coords_roundtrip() {
        let t = Torus::new(&[4, 3, 5]);
        assert_eq!(t.n(), 60);
        for r in 0..60 {
            assert_eq!(t.rank(&t.coords(r)), r);
        }
    }

    #[test]
    fn neighbor_wraps_in_dim() {
        let t = Torus::new(&[4, 3]);
        let r = t.rank(&[3, 2]);
        assert_eq!(t.coords(t.neighbor(r, 0, 1)), vec![0, 2]);
        assert_eq!(t.coords(t.neighbor(r, 1, 1)), vec![3, 0]);
        assert_eq!(t.coords(t.neighbor(r, 0, -2)), vec![1, 2]);
    }

    #[test]
    fn route_is_minimal_and_connects() {
        let t = Torus::new(&[5, 5]);
        for src in 0..25 {
            for dst in 0..25 {
                let route = t.route(src, dst);
                assert_eq!(route.len() as u32, t.distance(src, dst));
                // walk the route
                let mut cur = src;
                for l in &route {
                    assert_eq!(l.node, cur);
                    cur = t.neighbor(cur, l.dim as usize, l.dir as i64);
                }
                assert_eq!(cur, dst);
            }
        }
    }

    #[test]
    fn route_tie_splits_by_parity() {
        let t = Torus::ring(8);
        // distance exactly 4: even sources go +, odd sources go -
        let r0 = t.route(0, 4);
        let r1 = t.route(1, 5);
        assert_eq!(r0[0].dir, 1);
        assert_eq!(r1[0].dir, -1);
    }

    #[test]
    fn route_directed_wraps() {
        let t = Torus::ring(9);
        let r = t.route_directed(7, 2, 0, 1);
        assert_eq!(r.len(), 4); // 7->8->0->1->2
        let back = t.route_directed(2, 7, 0, -1);
        assert_eq!(back.len(), 4);
    }

    #[test]
    fn link_index_dense_and_unique() {
        let t = Torus::new(&[3, 3]);
        let mut seen = vec![false; t.num_links()];
        for node in 0..t.n() {
            for dim in 0..2u8 {
                for dir in [-1i8, 1] {
                    let idx = t.link_index(Link { node, dim, dir });
                    assert!(idx < t.num_links());
                    assert!(!seen[idx]);
                    seen[idx] = true;
                }
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn link_at_inverts_link_index_and_reverse_pairs_up() {
        let t = Torus::new(&[3, 4]);
        for idx in 0..t.num_links() {
            let l = t.link_at(idx);
            assert_eq!(t.link_index(l), idx);
            let r = t.reverse_link(l);
            assert_ne!(t.link_index(r), idx);
            // reversing twice is the identity
            assert_eq!(t.link_index(t.reverse_link(r)), idx);
            // both ends of one physical cable
            assert_eq!(t.neighbor(r.node, r.dim as usize, r.dir as i64), l.node);
        }
    }

    #[test]
    fn ring_through() {
        let t = Torus::new(&[3, 4]);
        let r = t.rank(&[1, 2]);
        let ring0 = t.ring_through(r, 0);
        assert_eq!(ring0.len(), 3);
        assert_eq!(t.coords(ring0[0]), vec![0, 2]);
        assert_eq!(t.coords(ring0[2]), vec![2, 2]);
        let ring1 = t.ring_through(r, 1);
        assert_eq!(ring1.len(), 4);
        assert!(ring1.iter().all(|&x| t.coord(x, 0) == 1));
    }

    #[test]
    fn product_set_matches_bruteforce() {
        let t = Torus::new(&[3, 3]);
        let ranges = vec![
            crate::blockset::BlockSet::cyc_range(2, 2, 3), // coords {2,0} in dim0
            crate::blockset::BlockSet::cyc_range(0, 1, 3), // coord {0} in dim1
        ];
        let s = t.product_set(&ranges);
        assert_eq!(s.len(), 2);
        assert!(s.contains(t.rank(&[2, 0])));
        assert!(s.contains(t.rank(&[0, 0])));
    }
}
