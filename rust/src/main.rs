//! `trivance` — leader entrypoint. See `trivance help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(trivance::cli::main(argv));
}
