//! Hand-rolled CLI (the vendored registry has no clap).
//!
//! ```text
//! trivance figures  [--id ID]... [--all] [--quick] [--out DIR]
//! trivance simulate --topo 8x8 [--algo A] [--variant L|B] [--size BYTES]
//!                   [--bw-gbps N] [--mode flow|packet] [--mtu BYTES]
//! trivance validate --topo 27 [--algo A]
//! trivance verify   [--topo 9]... [--all] [--out VERIFY_report.json] [--mutants]
//!                   [--pass NAME]... [--list-passes]
//!                   [--numeric [--algo A] [--block-len N] [--pjrt]]
//! trivance pattern  --n 9 [--algo trivance|bruck]
//! trivance optimality --topo 81
//! trivance train-demo [--workers 9] [--steps 200] [--lr 0.5]
//! trivance tune     [--topo 8x8]... [--quick] [--out tuner_table.json]
//! trivance recommend --topo 8x8 --size 1MiB [--scenario uniform]
//! trivance replay   [--topo 8x8] [--quick] [--table tuner_table.json]
//! trivance metrics  [--topo 4x4x4] [--quick] [--out METRICS.json]
//! trivance trace    [--topo 4x4x4] [--quick] [--out TRACE.json]
//! ```

use crate::algo::{build, Algo, Variant};
use crate::cost::{eq1_with_hops, measure_optimality, NetParams};
use crate::exec::{f32_sum_tolerance, verify_allreduce, NativeReducer, Reducer, VectorReducer};
use crate::schedule::analysis::analyze;
use crate::sim::{simulate, SimMode};
use crate::topology::Torus;
use crate::util::fmt;

/// Parsed flag map: `--key value` and bare `--flag`.
struct Args {
    flags: Vec<(String, Option<String>)>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Args, String> {
        let mut flags = Vec::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| format!("expected --flag, got {a:?}"))?
                .to_string();
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.push((key, Some(argv[i + 1].clone())));
                i += 2;
            } else {
                flags.push((key, None));
                i += 1;
            }
        }
        Ok(Args { flags })
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(k, _)| k == key)
            .and_then(|(_, v)| v.as_deref())
    }

    fn getall(&self, key: &str) -> Vec<&str> {
        self.flags
            .iter()
            .filter(|(k, _)| k == key)
            .filter_map(|(_, v)| v.as_deref())
            .collect()
    }

    fn has(&self, key: &str) -> bool {
        self.flags.iter().any(|(k, _)| k == key)
    }
}

/// "27" → ring(27); "8x8" / "16x16x16" → torus.
pub fn parse_topo(s: &str) -> Result<Torus, String> {
    let dims: Result<Vec<u32>, _> = s.split(['x', 'X']).map(str::parse).collect();
    let dims = dims.map_err(|e| format!("bad --topo {s:?}: {e}"))?;
    if dims.is_empty() || dims.iter().any(|&d| d < 2) {
        return Err(format!("bad --topo {s:?}: dims must be >= 2"));
    }
    Ok(Torus::new(&dims))
}

fn parse_algo(s: &str) -> Result<Algo, String> {
    Algo::parse(s).ok_or_else(|| {
        format!(
            "unknown --algo {s:?} (known: {})",
            Algo::ALL.map(|a| a.label()).join(", ")
        )
    })
}

fn parse_variant(s: &str) -> Result<Variant, String> {
    match s {
        "L" | "l" | "latency" => Ok(Variant::Latency),
        "B" | "b" | "bandwidth" => Ok(Variant::Bandwidth),
        _ => Err(format!("unknown --variant {s:?} (L or B)")),
    }
}

fn net_params(args: &Args) -> Result<NetParams, String> {
    let mut p = NetParams::default();
    if let Some(bw) = args.get("bw-gbps") {
        p = p.with_bandwidth_gbps(bw.parse().map_err(|e| format!("bad --bw-gbps: {e}"))?);
    }
    if let Some(a) = args.get("alpha-us") {
        p.alpha_s = a.parse::<f64>().map_err(|e| format!("bad --alpha-us: {e}"))? * 1e-6;
    }
    p.validate();
    Ok(p)
}

/// Parse `--mode flow|packet` (+ `--mtu` for packet mode).
fn parse_mode(args: &Args) -> Result<SimMode, String> {
    match args.get("mode").unwrap_or("flow") {
        "flow" => Ok(SimMode::Flow),
        "packet" => Ok(SimMode::Packet {
            mtu: args
                .get("mtu")
                .map(|s| s.parse().map_err(|e| format!("bad --mtu: {e}")))
                .transpose()?
                .unwrap_or(4096),
        }),
        other => Err(format!("unknown --mode {other:?}")),
    }
}

const USAGE: &str = "\
trivance — latency-optimal AllReduce by shortcutting multiport networks

USAGE:
  trivance figures  [--id ID]... [--all] [--quick] [--out DIR] [--threads N]
                    [--no-plan-cache]
  trivance simulate --topo 8x8 [--algo A] [--variant L|B] [--size 1MiB]
                    [--bw-gbps 800] [--alpha-us 1.5] [--mode flow|packet] [--mtu 4096]
  trivance scenarios [--topo 4x4x4] [--quick] [--max-size 4MiB] [--threads N]
                    [--bw-gbps 800] [--alpha-us 1.5] [--mode flow|packet] [--mtu 4096]
                    [--no-plan-cache] [--static-only]
                    [--online [--table tuner_table.json]]
  trivance bench-sweep [--topo 3x3x3] [--max-size 128MiB] [--threads N]
                    [--bw-gbps 800] [--alpha-us 1.5] [--out BENCH_sweep.json]
                    [--core-out BENCH_core.json] [--quick]
                    [--no-plan-cache] [--no-scenarios]
  trivance tune     [--topo 8x8]... [--quick] [--max-size 128MiB] [--threads N]
                    [--bw-gbps 800] [--alpha-us 1.5] [--mode flow|packet] [--mtu 4096]
                    [--out tuner_table.json] [--no-plan-cache] [--dynamic]
  trivance recommend --topo 8x8 --size 1MiB [--scenario uniform]
                    [--table tuner_table.json]
  trivance replay   [--topo 8x8] [--quick] [--calls 160] [--table tuner_table.json]
                    [--threads N] [--bw-gbps 800] [--alpha-us 1.5]
                    [--mode flow|packet] [--mtu 4096] [--no-plan-cache]
  trivance metrics  [--topo 4x4x4] [--size 1MiB] [--quick] [--out METRICS.json]
                    [--bw-gbps 800] [--alpha-us 1.5] [--mtu 4096] [--no-plan-cache]
  trivance trace    [--topo 4x4x4] [--size 1MiB] [--quick] [--out TRACE.json]
                    [--bw-gbps 800] [--alpha-us 1.5] [--mtu 4096] [--no-plan-cache]
  trivance validate --topo 27 [--algo A]
  trivance verify   [--topo 9]... [--all] [--out VERIFY_report.json]
                    [--pass NAME]... [--list-passes]
                    [--mutants] [--numeric [--algo A] [--block-len 8] [--pjrt]
                    [--reducer scalar|vector]]
  trivance pattern  --n 9 [--algo trivance|bruck]
  trivance optimality --topo 81
  trivance train-demo [--workers 9] [--steps 200] [--lr 0.5] [--log-every 20]

scenarios sweeps the registry under named network-model presets — the four
static ones (uniform / hetero-dims / straggler / faulty) plus the dynamic
family (flap / brownout / mid-fault-detour / mid-fault-rewrite: links that
fail and recover mid-collective, asymmetric brownouts, and a permanent
mid-collective link death answered by detour routing vs fault-aware
schedule rewriting) — and renders per-scenario tables relative to Trivance
plus a rewrite-vs-detour comparison; --static-only restricts to the four
static presets. scenarios --online instead replays the seeded two-fault
timeline (a cable dies mid-step-1, a second fault lands during the rewrite's
cleanup) through the online fault-response controller and scores
always-detour vs always-rewrite vs the tuned nearest-scenario policy vs the
per-event oracle; strategies that cannot complete (partitioned fabric,
stranded traffic) render `—` instead of aborting — permanent-fault
strandedness is a typed error end to end. --table supplies a tuned
(--dynamic) table for the policy's algorithm-switch advice. bench-sweep
includes the static presets as per-scenario rows in BENCH_sweep.json
(schema v2) unless --no-scenarios.

tune distills the same scenario sweeps into a decision table (per-(topo,
scenario) size-ladder winners, fingerprinted against the network model and
the tuning parameters); recommend answers "which algorithm for this size
right now" from that table in O(1); replay runs the built-in workload
traces (data-parallel / tensor-parallel / mixed) under every preset and
scores table-driven selection against the per-call oracle and every
fixed-algorithm baseline. Without --table, replay tunes its topology
in-memory first. tune --dynamic additionally tunes the dynamic presets
(tables carry a timeline fingerprint per row, so a static-tuned table is
rejected as stale for a dynamic lookup and vice versa); recommend --scenario
accepts the dynamic preset names and sizes above the tuned ladder are
refused (OutOfRange) instead of extrapolated.

verify statically certifies every registry collective through the pass
manager (verify::passes) — dataflow proved exact, WAR/WAW hazards
classified, deadlock-freedom by forward availability, peak live memory
within the variant's certified bound, per-(node, step, direction) port
usage within the fabric budget, per-algo congestion, latency/bandwidth
optimality classification, and a symbolic cost certificate cross-checked
against the congestion audit — without running a simulator; the
default/--all topology set is the acceptance six (8, 9, 27, 3x3, 8x8,
4x4x4). --list-passes names the passes and their dependencies;
--pass NAME (repeatable) runs just those passes (dependencies pulled in
automatically) and prints per-collective findings. --out writes the
machine-readable VERIFY_report.json (schema trivance.verify.v2, with
per-pass wall-clock timing); --mutants runs the seeded mutation-kill
suite instead (the verifier must kill >= 95% of drop-a-send /
swap-contributors / duplicate-a-reduce / shift-a-port / inject-hazard
mutants); --numeric is the legacy end-to-end numeric check on real
vectors.

--threads 0 (default) uses every core; sweep results are identical for any
thread count. Simulation plans are shared process-wide via a bounded LRU
cache keyed by (algo, variant, dims, net-model fingerprint);
--no-plan-cache forces fresh builds and --plan-cache-cap N bounds the
cache (0 = unbounded) — results are bit-identical either way, eviction
just rebuilds on the next lookup. --event-queue heap|calendar selects the
packet engine's scheduler (default calendar, proven bit-identical to the
heap); both knobs are accepted by every simulating subcommand. bench-sweep
additionally runs the hot-path microbenchmarks (packet events/sec per
queue kind with op counts, reducer kernel GB/s scalar vs vectorized) and
writes them to BENCH_core.json; --quick shrinks the workload for the CI
perf-smoke job. verify --numeric --reducer vector runs the end-to-end
check through the vectorized reduction kernel (bit-identical to scalar).

metrics and trace run one small deterministic observed workload — both
engines over Trivance (static plus the flap and brownout timelines), one
executor run, and the seeded two-fault online response — with
observability on. metrics exports the metrics-registry delta as
trivance.metrics.v1 JSON (engine/queue/water-filler counters, plan-cache
traffic, the calendar queue's scanned-per-pop histogram); trace installs
the flight recorder and exports Chrome trace-event JSON
(trivance.trace.v1, loadable in Perfetto or chrome://tracing) with
per-link congestion telemetry rows sampled from the packet engine's busy
intervals. Observability is off by default everywhere else, and
instrumented runs are bit-identical to uninstrumented ones (pinned in
rust/tests/obs.rs).

IDs: table1 table2 fig6a fig6b fig7a fig7b fig8 fig9 fig10
Algorithms: trivance bruck bruck-unidir swing recdoub bucket
";

/// CLI entry point; returns the process exit code.
pub fn main(argv: Vec<String>) -> i32 {
    match run(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            eprintln!("{USAGE}");
            1
        }
    }
}

fn run(argv: Vec<String>) -> Result<(), String> {
    let Some((cmd, rest)) = argv.split_first() else {
        return Err("missing subcommand".into());
    };
    let args = Args::parse(rest)?;
    match cmd.as_str() {
        "figures" => figures(&args),
        "scenarios" => scenarios_cmd(&args),
        "bench-sweep" => bench_sweep_cmd(&args),
        "tune" => tune_cmd(&args),
        "recommend" => recommend_cmd(&args),
        "replay" => replay_cmd(&args),
        "simulate" => simulate_cmd(&args),
        "metrics" => metrics_cmd(&args),
        "trace" => trace_cmd(&args),
        "validate" => validate_cmd(&args),
        "verify" => verify_cmd(&args),
        "pattern" => pattern_cmd(&args),
        "optimality" => optimality_cmd(&args),
        "train-demo" => train_cmd(&args),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(format!("unknown subcommand {other:?}")),
    }
}

/// Parse the `--threads` knob (`0` = all cores).
fn parse_threads(args: &Args) -> Result<usize, String> {
    args.get("threads")
        .map(|s| s.parse().map_err(|e| format!("bad --threads: {e}")))
        .transpose()
        .map(|t| t.unwrap_or(0))
}

/// Apply the process-wide engine knobs: `--no-plan-cache`,
/// `--plan-cache-cap N` (0 = unbounded), and `--event-queue
/// heap|calendar` (the packet engine's scheduler — bit-identical either
/// way, so the knob is purely a performance selector).
fn apply_engine_flags(args: &Args) -> Result<(), String> {
    if args.has("no-plan-cache") {
        crate::sim::PlanCache::global().set_enabled(false);
    }
    if let Some(cap) = args.get("plan-cache-cap") {
        let cap: usize = cap.parse().map_err(|e| format!("bad --plan-cache-cap: {e}"))?;
        crate::sim::PlanCache::global().set_cap(cap);
    }
    if let Some(q) = args.get("event-queue") {
        let kind = crate::sim::QueueKind::parse(q)
            .ok_or_else(|| format!("unknown --event-queue {q:?} (heap or calendar)"))?;
        crate::sim::events::set_default_kind(kind);
    }
    Ok(())
}

/// The plan-cache summary line, as a thin view over the metrics registry:
/// [`crate::obs::metrics::snapshot`] injects the cache's state as
/// `plan_cache.*` counters/gauges, and this renders exactly the line the
/// CLI has always printed from those.
fn plan_cache_stats() -> String {
    let s = crate::obs::metrics::snapshot();
    let cap = s.gauge("plan_cache.cap").unwrap_or(0.0) as usize;
    format!(
        "plan cache: {} hits / {} misses / {} evictions, {} plans cached (cap {}){}",
        s.counter("plan_cache.hits"),
        s.counter("plan_cache.misses"),
        s.counter("plan_cache.evictions"),
        s.gauge("plan_cache.len").unwrap_or(0.0) as usize,
        if cap == 0 { "unbounded".to_string() } else { cap.to_string() },
        if s.gauge("plan_cache.enabled") == Some(0.0) { " (disabled)" } else { "" }
    )
}

/// The small deterministic workload `trivance metrics` / `trivance trace`
/// observe: both engines over Trivance-L (static plus every transient
/// dynamic preset — flap and brownout), one executor run, and the seeded
/// two-fault online response. Touches every instrumented subsystem.
fn observed_workload(torus: &Torus, m: u64, params: &NetParams, mtu: u32) -> Result<(), String> {
    use crate::harness::scenarios::{dynamic_presets, two_fault_events};
    use crate::net::NetModel;
    use crate::schedule::online::{respond, step_time_estimates, Action};
    use crate::sim::{simulate_plan_scratch, simulate_plan_timeline, SimPlan, SimScratch};

    let b = build(Algo::Trivance, Variant::Latency, torus).map_err(|e| e.to_string())?;
    let plan = SimPlan::build(&b.net, torus);
    let scratch = SimScratch::new(&plan, params);
    let modes = [SimMode::Flow, SimMode::Packet { mtu }];
    for mode in modes {
        simulate_plan_scratch(&plan, &scratch, m, params, mode);
    }
    for sc in dynamic_presets().iter().filter(|s| s.fault(torus).is_none()) {
        let tl = sc.timeline(torus, params, m);
        for mode in modes {
            simulate_plan_timeline(&plan, &scratch, m, params, mode, &tl)
                .map_err(|e| format!("scenario {}: {e}", sc.name))?;
        }
    }
    // the online controller's FaultEvent → decision → outcome chain
    let model = NetModel::uniform(torus);
    let ends = step_time_estimates(&b.net, &model, m, params);
    let events = two_fault_events(torus, &ends);
    respond(&b, &model, &events, m, params, |_, _| Action::Rewrite)?;
    // one executor run for the reducer-call counters
    verify_allreduce(&b.exec, 4, 42, &NativeReducer);
    Ok(())
}

/// `trivance metrics`: run the observed workload and export the metrics
/// registry delta as `trivance.metrics.v1` JSON.
fn metrics_cmd(args: &Args) -> Result<(), String> {
    apply_engine_flags(args)?;
    let quick = args.has("quick");
    let torus = match args.get("topo") {
        Some(t) => parse_topo(t)?,
        None if quick => Torus::new(&[3, 3]),
        None => Torus::new(&[4, 4, 4]),
    };
    let m = args
        .get("size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --size {s:?}")))
        .transpose()?
        .unwrap_or(if quick { 64 << 10 } else { 1 << 20 });
    let mtu: u32 = args
        .get("mtu")
        .map(|s| s.parse().map_err(|e| format!("bad --mtu: {e}")))
        .transpose()?
        .unwrap_or(4096);
    let params = net_params(args)?;
    let out = args.get("out").unwrap_or("METRICS.json");

    let s0 = crate::obs::metrics::snapshot();
    observed_workload(&torus, m, &params, mtu)?;
    let delta = crate::obs::metrics::snapshot().diff(&s0);
    std::fs::write(out, delta.to_json()).map_err(|e| format!("writing {out}: {e}"))?;

    println!(
        "observed workload on {:?} ({} nodes), {}:",
        torus.dims(),
        torus.n(),
        fmt::bytes(m)
    );
    for (name, v) in &delta.counters {
        println!("  {name} = {v}");
    }
    for (name, h) in &delta.histograms {
        println!("  {name} ~ mean {:.3} over {} observations", h.mean(), h.count);
    }
    println!("wrote {out}; {}", plan_cache_stats());
    Ok(())
}

/// `trivance trace`: run the observed workload under the flight recorder
/// and export Chrome trace-event JSON (`trivance.trace.v1`).
fn trace_cmd(args: &Args) -> Result<(), String> {
    apply_engine_flags(args)?;
    let quick = args.has("quick");
    let torus = match args.get("topo") {
        Some(t) => parse_topo(t)?,
        None if quick => Torus::new(&[3, 3]),
        None => Torus::new(&[4, 4, 4]),
    };
    let m = args
        .get("size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --size {s:?}")))
        .transpose()?
        .unwrap_or(if quick { 64 << 10 } else { 1 << 20 });
    let mtu: u32 = args
        .get("mtu")
        .map(|s| s.parse().map_err(|e| format!("bad --mtu: {e}")))
        .transpose()?
        .unwrap_or(4096);
    let params = net_params(args)?;
    let out = args.get("out").unwrap_or("TRACE.json");

    let recorder = std::sync::Arc::new(crate::obs::trace::Recorder::new());
    let guard = crate::obs::install(recorder.clone());
    let run = observed_workload(&torus, m, &params, mtu);
    drop(guard);
    run?;
    recorder.validate().map_err(|e| format!("trace failed self-validation: {e}"))?;
    std::fs::write(out, recorder.to_chrome_json()).map_err(|e| format!("writing {out}: {e}"))?;
    println!(
        "wrote {out}: {} trace events, {} link-telemetry rows (load in Perfetto \
         or chrome://tracing)",
        recorder.num_events(),
        recorder.samples().len()
    );
    Ok(())
}

fn figures(args: &Args) -> Result<(), String> {
    let quick = args.has("quick");
    let threads = parse_threads(args)?;
    apply_engine_flags(args)?;
    let ids: Vec<String> = if args.has("all") || args.getall("id").is_empty() {
        crate::harness::ALL_IDS.iter().map(|s| s.to_string()).collect()
    } else {
        args.getall("id").iter().map(|s| s.to_string()).collect()
    };
    let out_dir = args.get("out");
    for id in &ids {
        eprintln!("[figures] running {id} ...");
        let t0 = std::time::Instant::now();
        let md = crate::harness::run_opts(id, quick, threads)?;
        eprintln!("[figures] {id} done in {:.1}s", t0.elapsed().as_secs_f64());
        match out_dir {
            Some(dir) => {
                std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
                let path = format!("{dir}/{id}.md");
                std::fs::write(&path, &md).map_err(|e| e.to_string())?;
                println!("wrote {path}");
            }
            None => println!("{md}"),
        }
    }
    Ok(())
}

/// Sweep the registry under the named network-model presets (uniform /
/// hetero-dims / straggler / faulty) and render per-scenario tables
/// relative to Trivance.
fn scenarios_cmd(args: &Args) -> Result<(), String> {
    use crate::harness::scenarios::{all_presets, presets, run_online, run_scenarios};
    use crate::harness::sweep::size_ladder;
    use crate::tuner::DecisionTable;
    let quick = args.has("quick");
    let torus = match args.get("topo") {
        Some(t) => parse_topo(t)?,
        None if quick => Torus::new(&[3, 3]),
        None => Torus::new(&[4, 4, 4]),
    };
    let max = args
        .get("max-size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --max-size {s:?}")))
        .transpose()?
        .unwrap_or(if quick { 256 << 10 } else { 4 << 20 });
    let threads = parse_threads(args)?;
    apply_engine_flags(args)?;
    let params = net_params(args)?;
    let mode = parse_mode(args)?;
    let sizes = size_ladder(max);

    if args.has("online") {
        let table = args
            .get("table")
            .map(|path| -> Result<DecisionTable, String> {
                let text = std::fs::read_to_string(path).map_err(|e| {
                    format!("reading {path}: {e} — run `trivance tune --dynamic` first")
                })?;
                DecisionTable::from_json(&text)
            })
            .transpose()?;
        eprintln!(
            "[scenarios] online two-fault replay on {:?} ({} nodes), {} sizes up to {} ...",
            torus.dims(),
            torus.n(),
            sizes.len(),
            fmt::bytes(max),
        );
        let t0 = std::time::Instant::now();
        let sweep = run_online(&torus, &Algo::ALL, &sizes, &params, table.as_ref(), mode)?;
        println!(
            "{}",
            sweep.render(&format!(
                "Online fault response — {:?} ({} nodes), seeded two-fault timeline",
                torus.dims(),
                torus.n()
            ))
        );
        println!("done in {:.1}s; {}", t0.elapsed().as_secs_f64(), plan_cache_stats());
        return Ok(());
    }

    let scenario_set = if args.has("static-only") { presets() } else { all_presets() };

    eprintln!(
        "[scenarios] {:?} ({} nodes), {} sizes up to {}, {} presets ...",
        torus.dims(),
        torus.n(),
        sizes.len(),
        fmt::bytes(max),
        scenario_set.len(),
    );
    let t0 = std::time::Instant::now();
    let sweep =
        run_scenarios(&torus, &Algo::ALL, &sizes, &params, &scenario_set, threads, mode)?;
    println!(
        "{}",
        sweep.render(&format!(
            "Scenario sweep — {:?} ({} nodes), completion relative to Trivance",
            torus.dims(),
            torus.n()
        ))
    );
    println!("done in {:.1}s; {}", t0.elapsed().as_secs_f64(), plan_cache_stats());
    Ok(())
}

/// Full-registry sweep with wall-clock accounting; writes the
/// machine-readable `BENCH_sweep.json` perf record (the acceptance artifact
/// future PRs diff against). Schema v2 adds per-scenario rows from the
/// named presets (`--no-scenarios` skips them).
fn bench_sweep_cmd(args: &Args) -> Result<(), String> {
    use crate::harness::scenarios::{presets, run_scenarios};
    use crate::harness::sweep::{
        run_core_bench, run_sweep_timed, size_ladder, write_bench_core_json, write_bench_json,
    };
    let quick = args.has("quick");
    let torus = match args.get("topo") {
        Some(t) => parse_topo(t)?,
        None if quick => Torus::new(&[3, 3]),
        None => Torus::new(&[3, 3, 3]),
    };
    let max = args
        .get("max-size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --max-size {s:?}")))
        .transpose()?
        .unwrap_or(if quick { 1 << 20 } else { 128 << 20 });
    let threads = parse_threads(args)?;
    apply_engine_flags(args)?;
    let params = net_params(args)?;
    let out = args.get("out").unwrap_or("BENCH_sweep.json");
    let sizes = size_ladder(max);

    eprintln!(
        "[bench-sweep] {:?} ({} nodes), {} sizes up to {} ...",
        torus.dims(),
        torus.n(),
        sizes.len(),
        fmt::bytes(max),
    );
    let t0 = std::time::Instant::now();
    let (sweep, timing) = run_sweep_timed(&torus, &Algo::ALL, &sizes, &params, threads);
    let scenario_sweep = if args.has("no-scenarios") {
        None
    } else {
        eprintln!("[bench-sweep] scenario presets ...");
        Some(run_scenarios(
            &torus,
            &Algo::ALL,
            &sizes,
            &params,
            &presets(),
            threads,
            SimMode::Flow,
        )?)
    };
    let wall = t0.elapsed().as_secs_f64();
    write_bench_json(out, &sweep, &timing, scenario_sweep.as_ref())
        .map_err(|e| format!("writing {out}: {e}"))?;

    // Raw-speed hot-path microbenchmarks: packet events/sec under each
    // event-queue kind (heap vs calendar, with op counts) and reducer
    // kernel GB/s (scalar vs vectorized) — the BENCH_core.json trajectory
    // the CI perf-smoke job gates on.
    eprintln!("[bench-sweep] core hot-path benchmarks ...");
    let core = run_core_bench(quick);
    let core_out = args.get("core-out").unwrap_or("BENCH_core.json");
    write_bench_core_json(core_out, &core, Some((&sweep, &timing)))
        .map_err(|e| format!("writing {core_out}: {e}"))?;
    for q in &core.queues {
        println!(
            "event queue {:>8}: {:.3e} events/s ({} events, {} pushes, peak {}, \
             {} resizes, {} scanned)",
            q.kind.to_string(),
            q.events_per_s,
            q.events,
            q.stats.pushes,
            q.stats.peak_len,
            q.stats.resizes,
            q.stats.scanned,
        );
    }
    for r in &core.reducers {
        println!(
            "reduce {:>8}: add2 {:.1} GB/s, add3 {:.1} GB/s",
            r.name, r.add2_gbps, r.add3_gbps
        );
    }

    println!("{}", sweep.render("bench-sweep — completion relative to Trivance"));
    println!(
        "build {:.3}s + sim {:.3}s = {:.3}s wall ({} threads); wrote {out} and {core_out}",
        timing.build_wall_s, timing.sim_wall_s, wall, timing.threads
    );
    // per-phase metrics-registry deltas (what each phase actually did)
    let phase_line = |name: &str, snap: &crate::obs::metrics::Snapshot| {
        format!(
            "{name} phase: plan cache {} hits / {} misses, {} flow sims, {} packet sims, \
             {} queue events",
            snap.counter("plan_cache.hits"),
            snap.counter("plan_cache.misses"),
            snap.counter("flow.sims"),
            snap.counter("packet.sims"),
            snap.counter("flow.events") + snap.counter("packet.events"),
        )
    };
    println!("{}", phase_line("build", &timing.build_metrics));
    println!("{}", phase_line("sim", &timing.sim_metrics));
    println!("{}", plan_cache_stats());
    Ok(())
}

/// Distill scenario sweeps over one or more topologies into a decision
/// table and write it as JSON (`trivance tune`).
fn tune_cmd(args: &Args) -> Result<(), String> {
    use crate::harness::scenarios::{all_presets, presets};
    use crate::tuner::{tune, tune_ladder};
    let quick = args.has("quick");
    let topo_flags = args.getall("topo");
    let topos: Vec<Torus> = if topo_flags.is_empty() {
        if quick {
            vec![Torus::new(&[3, 3])]
        } else {
            vec![
                Torus::ring(9),
                Torus::ring(27),
                Torus::new(&[3, 3]),
                Torus::new(&[8, 8]),
                Torus::new(&[4, 4, 4]),
            ]
        }
    } else {
        topo_flags.iter().map(|&s| parse_topo(s)).collect::<Result<_, _>>()?
    };
    let max = args
        .get("max-size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --max-size {s:?}")))
        .transpose()?
        .unwrap_or(if quick { 256 << 10 } else { 128 << 20 });
    if max < 32 {
        return Err(format!("--max-size must be >= 32 B (the tune ladder starts at 32), got {max}"));
    }
    let threads = parse_threads(args)?;
    apply_engine_flags(args)?;
    let params = net_params(args)?;
    let mode = parse_mode(args)?;
    let out = args.get("out").unwrap_or("tuner_table.json");
    let scenario_set = if args.has("dynamic") { all_presets() } else { presets() };

    eprintln!(
        "[tune] {} topolog{}, {} ladder sizes up to {}, {} presets ...",
        topos.len(),
        if topos.len() == 1 { "y" } else { "ies" },
        tune_ladder(max).len(),
        fmt::bytes(max),
        scenario_set.len(),
    );
    let t0 = std::time::Instant::now();
    let table = tune(&topos, &scenario_set, max, &params, threads, mode)?;
    std::fs::write(out, table.to_json()).map_err(|e| format!("writing {out}: {e}"))?;
    println!("{}", table.render());
    println!("wrote {out}; done in {:.1}s; {}", t0.elapsed().as_secs_f64(), plan_cache_stats());
    Ok(())
}

/// O(1) lookup into a tuned decision table (`trivance recommend`).
fn recommend_cmd(args: &Args) -> Result<(), String> {
    use crate::harness::scenarios::all_presets;
    use crate::tuner::DecisionTable;
    let torus = parse_topo(args.get("topo").ok_or("--topo required")?)?;
    let bytes = args
        .get("size")
        .ok_or("--size required")
        .and_then(|s| fmt::parse_size(s).ok_or("bad --size"))
        .map_err(|e| e.to_string())?;
    let scenario_name = args.get("scenario").unwrap_or("uniform");
    let path = args.get("table").unwrap_or("tuner_table.json");
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("reading {path}: {e} — run `trivance tune` first"))?;
    let table = DecisionTable::from_json(&text)?;
    let scenario = all_presets()
        .into_iter()
        .find(|s| s.name == scenario_name)
        .ok_or_else(|| {
            format!(
                "unknown --scenario {scenario_name:?} (known: {})",
                all_presets().iter().map(|s| s.name.clone()).collect::<Vec<_>>().join(", ")
            )
        })?;
    let model = scenario.model(&torus);
    let rec = table
        .recommend_dyn(torus.dims(), &model, scenario.dyn_fingerprint(&torus), bytes)
        .map_err(|e| e.to_string())?;
    println!(
        "{}-{} for {} on {:?} (scenario {}, nearest tuned size {}{}, tuned at {:.0} Gb/s / α {:.2} µs)",
        rec.algo.label(),
        rec.variant.label(),
        fmt::bytes(bytes),
        torus.dims(),
        rec.scenario,
        fmt::bytes(rec.table_bytes),
        if rec.clamped { ", clamped to the 32 B latency floor" } else { "" },
        table.params.link_bw_bps / 1e9,
        table.params.alpha_s * 1e6,
    );
    Ok(())
}

/// Replay the built-in workload traces under every scenario preset and
/// score selection policies against the per-call oracle
/// (`trivance replay`).
fn replay_cmd(args: &Args) -> Result<(), String> {
    use crate::harness::scenarios::presets;
    use crate::tuner::{builtin_traces, replay, tune, DecisionTable};
    let quick = args.has("quick");
    let torus = match args.get("topo") {
        Some(t) => parse_topo(t)?,
        None if quick => Torus::new(&[3, 3]),
        None => Torus::new(&[8, 8]),
    };
    let threads = parse_threads(args)?;
    apply_engine_flags(args)?;
    let params = net_params(args)?;
    let mode = parse_mode(args)?;
    let calls: usize = args
        .get("calls")
        .map(|s| s.parse().map_err(|e| format!("bad --calls: {e}")))
        .transpose()?
        .unwrap_or(if quick { 40 } else { 160 });
    if calls == 0 {
        return Err("--calls must be >= 1 (an empty trace has no oracle to regret against)".into());
    }
    let scenarios = presets();

    let table = match args.get("table") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .map_err(|e| format!("reading {path}: {e} — run `trivance tune` first"))?;
            eprintln!("[replay] using decision table {path}");
            DecisionTable::from_json(&text)?
        }
        None => {
            let max = if quick { 256 << 10 } else { 128 << 20 };
            eprintln!("[replay] no --table given: tuning {:?} in-memory first ...", torus.dims());
            tune(&[torus.clone()], &scenarios, max, &params, threads, mode)?
        }
    };
    // Cap traces at the table's tuned range so every replayed size has a
    // tuned row (stale tables for this topology are rejected by replay).
    let cap = table
        .topos
        .iter()
        .find(|t| t.dims == torus.dims())
        .and_then(|t| t.sizes.last().copied())
        .ok_or_else(|| {
            format!(
                "decision table has no row for {:?} — re-run `trivance tune --topo ...`",
                torus.dims()
            )
        })?;
    let traces = builtin_traces(calls, cap);

    eprintln!(
        "[replay] {:?} ({} nodes), {} traces x {} collectives, {} presets ...",
        torus.dims(),
        torus.n(),
        traces.len(),
        calls,
        scenarios.len(),
    );
    let t0 = std::time::Instant::now();
    let report = replay(&torus, &scenarios, &traces, &table, &params, threads, mode)?;
    println!(
        "{}",
        report.render(&format!(
            "Workload replay — {:?} ({} nodes), selection policies vs per-call oracle",
            torus.dims(),
            torus.n()
        ))
    );
    println!("done in {:.1}s; {}", t0.elapsed().as_secs_f64(), plan_cache_stats());
    Ok(())
}

fn simulate_cmd(args: &Args) -> Result<(), String> {
    apply_engine_flags(args)?;
    let torus = parse_topo(args.get("topo").ok_or("--topo required")?)?;
    let m = args
        .get("size")
        .map(|s| fmt::parse_size(s).ok_or_else(|| format!("bad --size {s:?}")))
        .transpose()?
        .unwrap_or(1 << 20);
    let params = net_params(args)?;
    let mode = parse_mode(args)?;
    let algos: Vec<Algo> = match args.get("algo") {
        Some(a) => vec![parse_algo(a)?],
        None => Algo::ALL.to_vec(),
    };
    let variants: Vec<Variant> = match args.get("variant") {
        Some(v) => vec![parse_variant(v)?],
        None => Variant::ALL.to_vec(),
    };
    let mut table = fmt::Table::new(vec![
        "collective", "steps", "messages", "completion", "eq1 (analytic)",
    ]);
    for algo in algos {
        for variant in variants.iter().copied() {
            let Ok(b) = build(algo, variant, &torus) else { continue };
            let r = simulate(&b.net, &torus, m, &params, mode);
            let stats = analyze(&b.net, &torus);
            table.row(vec![
                b.name.clone(),
                b.net.num_steps().to_string(),
                r.messages.to_string(),
                fmt::secs(r.completion_s),
                fmt::secs(eq1_with_hops(&stats, m, &params)),
            ]);
        }
    }
    println!(
        "AllReduce of {} on {:?} ({} nodes), {} Gb/s links\n",
        fmt::bytes(m),
        torus.dims(),
        torus.n(),
        params.link_bw_bps / 1e9
    );
    println!("{}", table.render());
    Ok(())
}

fn validate_cmd(args: &Args) -> Result<(), String> {
    let torus = parse_topo(args.get("topo").ok_or("--topo required")?)?;
    let algos: Vec<Algo> = match args.get("algo") {
        Some(a) => vec![parse_algo(a)?],
        None => Algo::ALL.to_vec(),
    };
    for algo in algos {
        for variant in Variant::ALL {
            match build(algo, variant, &torus) {
                Err(e) => println!("{:>14} ({}): unsupported: {e}", algo.label(), variant.label()),
                Ok(b) => match b.validate() {
                    Ok(rep) => println!(
                        "{:>14} ({}): OK — {} steps, {} messages, max {} atoms{}",
                        algo.label(),
                        variant.label(),
                        rep.steps,
                        rep.messages,
                        rep.max_atoms,
                        if b.padded { " (padded)" } else { "" }
                    ),
                    Err(e) => return Err(format!("{} {}: INVALID: {e}", algo.label(), variant.label())),
                },
            }
        }
    }
    Ok(())
}

/// The six acceptance topologies `verify` certifies by default.
const VERIFY_TOPOS: [&str; 6] = ["8", "9", "27", "3x3", "8x8", "4x4x4"];

fn verify_cmd(args: &Args) -> Result<(), String> {
    apply_engine_flags(args)?;
    if args.has("list-passes") {
        println!("passes (canonical order; --pass selects a subset, dependencies included):");
        for &p in &crate::verify::passes::PASS_NAMES {
            let deps = crate::verify::passes::pass_deps(p);
            if deps.is_empty() {
                println!("  {p}");
            } else {
                println!("  {p} (after {})", deps.join(", "));
            }
        }
        return Ok(());
    }
    if args.has("numeric") {
        return verify_numeric_cmd(args);
    }
    if args.has("mutants") {
        let topos = [Torus::ring(8), Torus::ring(9), Torus::new(&[3, 3])];
        let rep = crate::verify::mutate::run_mutation_suite(&topos, 0xC0FF_EE07, 8);
        print!("{}", rep.render());
        if rep.kill_rate() < 0.95 {
            return Err(format!(
                "mutation-kill rate {:.1}% below the 95% gate",
                100.0 * rep.kill_rate()
            ));
        }
        return Ok(());
    }
    let named = args.getall("topo");
    let topos: Vec<Torus> = if named.is_empty() || args.has("all") {
        VERIFY_TOPOS.iter().map(|s| parse_topo(s)).collect::<Result<_, _>>()?
    } else {
        named.iter().map(|s| parse_topo(s)).collect::<Result<_, _>>()?
    };
    let requested = args.getall("pass");
    if !requested.is_empty() {
        return verify_passes_cmd(&topos, &requested);
    }
    let mut reports = Vec::new();
    for t in &topos {
        let rep = crate::verify::certify_registry(t)
            .map_err(|e| format!("topology {:?}: {e}", t.dims()))?;
        println!("{}", crate::verify::render_report(&rep));
        reports.push(rep);
    }
    println!("per-pass wall-clock (summed over {} topologies):", reports.len());
    for &p in &crate::verify::passes::PASS_NAMES {
        let ms: f64 = reports
            .iter()
            .flat_map(|r| &r.timings)
            .filter(|tm| tm.pass == p)
            .map(|tm| tm.seconds * 1e3)
            .sum();
        println!("  {p:<12} {ms:9.3} ms");
    }
    if let Some(path) = args.get("out") {
        std::fs::write(path, crate::verify::report_json(&reports))
            .map_err(|e| format!("writing {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `verify --pass NAME...`: run the selected passes (plus dependencies)
/// over every registry build on every topo, printing typed findings and
/// per-pass timing; any `error`-severity finding fails the command.
fn verify_passes_cmd(topos: &[Torus], requested: &[&str]) -> Result<(), String> {
    use crate::verify::passes::{run_passes, select_passes, Severity};
    let sel = select_passes(requested)?;
    println!("running passes: {}", sel.join(", "));
    let mut failures = 0usize;
    for t in topos {
        for algo in Algo::ALL {
            for variant in Variant::ALL {
                let Ok(b) = build(algo, variant, t) else { continue };
                let out = run_passes(&b, t, &sel);
                let total_ms: f64 = out.timings.iter().map(|tm| tm.seconds * 1e3).sum();
                let status = if out.first_error().is_some() { "FAIL" } else { "ok" };
                println!("{:?} {:<24} {status} ({total_ms:.2} ms)", t.dims(), out.name);
                for f in &out.findings {
                    println!("    [{}] {}: {}", f.severity.label(), f.pass, f.message);
                    if f.severity == Severity::Error {
                        failures += 1;
                    }
                }
            }
        }
    }
    if failures > 0 {
        return Err(format!("{failures} error finding(s) across the swept builds"));
    }
    Ok(())
}

/// Legacy end-to-end numeric verification on real vectors
/// (`verify --numeric`): executes the schedule through [`crate::exec`]
/// and checks the float error against the tolerance model.
fn verify_numeric_cmd(args: &Args) -> Result<(), String> {
    let torus = parse_topo(args.get("topo").ok_or("--topo required")?)?;
    let block_len: usize = args
        .get("block-len")
        .map(|s| s.parse().map_err(|e| format!("bad --block-len: {e}")))
        .transpose()?
        .unwrap_or(8);
    let pjrt = args.has("pjrt");
    let rt;
    let reducer: &dyn Reducer = if pjrt {
        rt = crate::runtime::Runtime::load_default().map_err(|e| e.to_string())?;
        println!("reductions via PJRT ({})", rt.platform());
        &rt
    } else {
        match args.get("reducer").unwrap_or("scalar") {
            "scalar" => &NativeReducer,
            // bit-identical to scalar (exec tests pin this), so the knob
            // only selects the kernel, never the answer
            "vector" => &VectorReducer,
            other => return Err(format!("unknown --reducer {other:?} (scalar or vector)")),
        }
    };
    let algos: Vec<Algo> = match args.get("algo") {
        Some(a) => vec![parse_algo(a)?],
        None => Algo::ALL.to_vec(),
    };
    for algo in algos {
        for variant in Variant::ALL {
            let Ok(b) = build(algo, variant, &torus) else { continue };
            let err = verify_allreduce(&b.exec, block_len, 42, reducer);
            let tol = f32_sum_tolerance(b.exec.n);
            let ok = if err < tol { "OK" } else { "FAIL" };
            println!(
                "{:>14} ({}): {ok} — max numeric error {err:.3e} (tolerance {tol:.1e})",
                algo.label(),
                variant.label()
            );
            if err >= tol {
                return Err("numeric verification failed".into());
            }
        }
    }
    Ok(())
}

fn pattern_cmd(args: &Args) -> Result<(), String> {
    let n: u32 = args
        .get("n")
        .ok_or("--n required")?
        .parse()
        .map_err(|e| format!("bad --n: {e}"))?;
    let algo = args.get("algo").unwrap_or("trivance");
    print!("{}", crate::harness::pattern::render_ring_pattern(algo, n)?);
    Ok(())
}

fn optimality_cmd(args: &Args) -> Result<(), String> {
    let torus = parse_topo(args.get("topo").ok_or("--topo required")?)?;
    let mut table = fmt::Table::new(vec!["collective", "steps", "Λ", "Δ", "Θ"]);
    for algo in Algo::ALL {
        for variant in Variant::ALL {
            let Ok(b) = build(algo, variant, &torus) else { continue };
            let stats = analyze(&b.net, &torus);
            let o = measure_optimality(&stats, &torus);
            table.row(vec![
                b.name.clone(),
                stats.num_steps().to_string(),
                format!("{:.2}", o.lambda),
                format!("{:.2}", o.delta),
                format!("{:.2}", o.theta),
            ]);
        }
    }
    println!("{}", table.render());
    Ok(())
}

fn train_cmd(args: &Args) -> Result<(), String> {
    let workers: u32 = args.get("workers").unwrap_or("9").parse().map_err(|e| format!("{e}"))?;
    let steps: u32 = args.get("steps").unwrap_or("200").parse().map_err(|e| format!("{e}"))?;
    let lr: f32 = args.get("lr").unwrap_or("0.5").parse().map_err(|e| format!("{e}"))?;
    let log_every: u32 = args.get("log-every").unwrap_or("20").parse().map_err(|e| format!("{e}"))?;
    let rt = crate::runtime::Runtime::load_default()
        .map_err(|e| format!("{e:#} — run `make artifacts` first"))?;
    let report = crate::harness::train::run_train_demo(&rt, workers, steps, lr, log_every)
        .map_err(|e| format!("{e:#}"))?;
    println!("{}", report.render());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_topo_forms() {
        assert_eq!(parse_topo("27").unwrap().dims(), &[27]);
        assert_eq!(parse_topo("8x8").unwrap().dims(), &[8, 8]);
        assert_eq!(parse_topo("16x16x16").unwrap().n(), 4096);
        assert!(parse_topo("").is_err());
        assert!(parse_topo("8x1").is_err());
    }

    #[test]
    fn args_parsing() {
        let a = Args::parse(&["--topo".into(), "8x8".into(), "--quick".into()]).unwrap();
        assert_eq!(a.get("topo"), Some("8x8"));
        assert!(a.has("quick"));
        assert!(!a.has("nope"));
    }

    #[test]
    fn variant_parse() {
        assert_eq!(parse_variant("L").unwrap(), Variant::Latency);
        assert_eq!(parse_variant("bandwidth").unwrap(), Variant::Bandwidth);
        assert!(parse_variant("x").is_err());
    }

    #[test]
    fn threads_parse() {
        let a = Args::parse(&["--threads".into(), "4".into()]).unwrap();
        assert_eq!(parse_threads(&a).unwrap(), 4);
        let none = Args::parse(&[]).unwrap();
        assert_eq!(parse_threads(&none).unwrap(), 0);
        let bad = Args::parse(&["--threads".into(), "x".into()]).unwrap();
        assert!(parse_threads(&bad).is_err());
    }
}
