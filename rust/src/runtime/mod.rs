//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) and
//! executes them on the CPU PJRT client via the `xla` crate.
//!
//! Pipeline: `PjRtClient::cpu()` → `HloModuleProto::from_text_file` →
//! `XlaComputation::from_proto` → `client.compile` → `execute`. Executables
//! are compiled once at load and cached for the life of the process; the
//! request path never touches Python.
//!
//! The real implementation needs the `xla` and `anyhow` crates, which the
//! offline vendored registry does not ship; it is therefore gated behind the
//! `pjrt` cargo feature **and** the feature alone is not sufficient:
//! `--features pjrt` only compiles after `xla` and `anyhow` are added as
//! path dependencies in `Cargo.toml` (they are intentionally undeclared so
//! the default build resolves offline). The default build compiles a
//! dependency-free stub with the identical API whose `load` always fails —
//! callers (CLI `verify --pjrt`, the train demo, benches) degrade
//! gracefully, and the rest of the crate (simulator, validator, native
//! executor) is unaffected.

use std::path::PathBuf;

/// Runtime error type: `anyhow::Error` with the `pjrt` feature, a minimal
/// message wrapper without it. Both support `Error::msg`, `Display`, and
/// alternate (`{:#}`) formatting.
#[cfg(feature = "pjrt")]
pub use anyhow::Error;

#[cfg(not(feature = "pjrt"))]
#[derive(Debug)]
pub struct Error(String);

#[cfg(not(feature = "pjrt"))]
impl Error {
    pub fn msg(m: impl std::fmt::Display) -> Error {
        Error(m.to_string())
    }
}

#[cfg(not(feature = "pjrt"))]
impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

#[cfg(not(feature = "pjrt"))]
impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Static shape metadata emitted by `python/compile/aot.py` (`meta.txt`).
#[derive(Clone, Copy, Debug)]
pub struct Meta {
    pub reduce_lanes: usize,
    pub mlp_in: usize,
    pub mlp_hidden: usize,
    pub mlp_classes: usize,
    pub mlp_batch: usize,
    pub mlp_params: usize,
}

// Without the `pjrt` feature the parser is exercised only by tests (the
// stub never loads artifacts).
#[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
impl Meta {
    fn get(text: &str, key: &str) -> Result<usize> {
        text.lines()
            .find_map(|l| l.strip_prefix(&format!("{key}=")))
            .ok_or_else(|| Error::msg(format!("meta.txt missing key {key}")))?
            .trim()
            .parse()
            .map_err(|e| Error::msg(format!("meta.txt bad value for {key}: {e}")))
    }

    fn parse(text: &str) -> Result<Meta> {
        Ok(Meta {
            reduce_lanes: Self::get(text, "reduce_lanes")?,
            mlp_in: Self::get(text, "mlp_in")?,
            mlp_hidden: Self::get(text, "mlp_hidden")?,
            mlp_classes: Self::get(text, "mlp_classes")?,
            mlp_batch: Self::get(text, "mlp_batch")?,
            mlp_params: Self::get(text, "mlp_params")?,
        })
    }
}

/// Default artifact directory (relative to the repo root / CWD).
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("TRIVANCE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

pub use imp::Runtime;

#[cfg(feature = "pjrt")]
mod imp {
    use super::{default_artifact_dir, Meta, Result};
    use anyhow::{bail, Context};
    use std::path::Path;

    /// The loaded runtime: compiled executables + metadata.
    pub struct Runtime {
        client: xla::PjRtClient,
        reduce2: xla::PjRtLoadedExecutable,
        reduce3: xla::PjRtLoadedExecutable,
        mlp_grad: xla::PjRtLoadedExecutable,
        pub meta: Meta,
    }

    impl Runtime {
        /// Load and compile all artifacts from `dir`.
        pub fn load(dir: &Path) -> Result<Runtime> {
            let meta_text = std::fs::read_to_string(dir.join("meta.txt")).with_context(|| {
                format!("reading {}/meta.txt (run `make artifacts`)", dir.display())
            })?;
            let meta = Meta::parse(&meta_text)?;
            let client = xla::PjRtClient::cpu()?;
            let compile = |name: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path = dir.join(format!("{name}.hlo.txt"));
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 artifact path")?,
                )
                .with_context(|| format!("parsing {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                Ok(client.compile(&comp)?)
            };
            Ok(Runtime {
                reduce2: compile("reduce2")?,
                reduce3: compile("reduce3")?,
                mlp_grad: compile("mlp_grad")?,
                client,
                meta,
            })
        }

        /// Load from the default directory if artifacts exist.
        pub fn load_default() -> Result<Runtime> {
            Self::load(&default_artifact_dir())
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn run1(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            args: &[xla::Literal],
        ) -> Result<xla::Literal> {
            let result = exe.execute::<xla::Literal>(args)?;
            Ok(result[0][0].to_literal_sync()?)
        }

        /// One lanes-wide chunked call of an elementwise executable.
        fn reduce_chunked(
            &self,
            exe: &xla::PjRtLoadedExecutable,
            parts: &[&[f32]],
        ) -> Result<Vec<f32>> {
            let n = parts[0].len();
            if parts.iter().any(|p| p.len() != n) {
                bail!("reduce arity length mismatch");
            }
            let lanes = self.meta.reduce_lanes;
            let mut out = Vec::with_capacity(n);
            let mut off = 0;
            let mut padded = vec![0f32; lanes];
            while off < n {
                let take = lanes.min(n - off);
                let args: Vec<xla::Literal> = parts
                    .iter()
                    .map(|p| {
                        if take == lanes {
                            xla::Literal::vec1(&p[off..off + lanes])
                        } else {
                            padded[..take].copy_from_slice(&p[off..off + take]);
                            padded[take..].iter_mut().for_each(|x| *x = 0.0);
                            xla::Literal::vec1(&padded)
                        }
                    })
                    .collect();
                let res = self.run1(exe, &args)?.to_tuple1()?;
                let v = res.to_vec::<f32>()?;
                out.extend_from_slice(&v[..take]);
                off += take;
            }
            Ok(out)
        }

        /// Elementwise `a + b` through the AOT `reduce2` kernel.
        pub fn reduce2(&self, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
            self.reduce_chunked(&self.reduce2, &[a, b])
        }

        /// Joint reduction `a + b + c` through the AOT `reduce3` kernel.
        pub fn reduce3(&self, a: &[f32], b: &[f32], c: &[f32]) -> Result<Vec<f32>> {
            self.reduce_chunked(&self.reduce3, &[a, b, c])
        }

        /// One worker's (gradient, loss) for a batch, via the AOT train step.
        /// `x` is row-major `[batch, in]`, `y_onehot` row-major `[batch,
        /// classes]`.
        pub fn mlp_grad(
            &self,
            params: &[f32],
            x: &[f32],
            y_onehot: &[f32],
        ) -> Result<(Vec<f32>, f32)> {
            let m = &self.meta;
            if params.len() != m.mlp_params
                || x.len() != m.mlp_batch * m.mlp_in
                || y_onehot.len() != m.mlp_batch * m.mlp_classes
            {
                bail!("mlp_grad argument shape mismatch");
            }
            let args = [
                xla::Literal::vec1(params),
                xla::Literal::vec1(x).reshape(&[m.mlp_batch as i64, m.mlp_in as i64])?,
                xla::Literal::vec1(y_onehot)
                    .reshape(&[m.mlp_batch as i64, m.mlp_classes as i64])?,
            ];
            let (grad, loss) = self.run1(&self.mlp_grad, &args)?.to_tuple2()?;
            let g = grad.to_vec::<f32>()?;
            let l = loss.to_vec::<f32>()?;
            Ok((g, l[0]))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::{default_artifact_dir, Error, Meta, Result};
    use std::path::Path;

    const UNAVAILABLE: &str =
        "trivance was built without the `pjrt` feature; AOT artifacts cannot be executed \
         (rebuild with `--features pjrt` and the xla/anyhow path dependencies)";

    /// Dependency-free stand-in for the PJRT runtime. `load` always fails,
    /// so a value of this type is never actually constructed; the type only
    /// exists to keep every consumer (CLI, train demo, benches) compiling
    /// identically with and without the feature.
    pub struct Runtime {
        pub meta: Meta,
    }

    impl Runtime {
        pub fn load(_dir: &Path) -> Result<Runtime> {
            Err(Error::msg(UNAVAILABLE))
        }

        pub fn load_default() -> Result<Runtime> {
            Self::load(&default_artifact_dir())
        }

        pub fn platform(&self) -> String {
            "stub".to_string()
        }

        pub fn reduce2(&self, _a: &[f32], _b: &[f32]) -> Result<Vec<f32>> {
            Err(Error::msg(UNAVAILABLE))
        }

        pub fn reduce3(&self, _a: &[f32], _b: &[f32], _c: &[f32]) -> Result<Vec<f32>> {
            Err(Error::msg(UNAVAILABLE))
        }

        pub fn mlp_grad(
            &self,
            _params: &[f32],
            _x: &[f32],
            _y_onehot: &[f32],
        ) -> Result<(Vec<f32>, f32)> {
            Err(Error::msg(UNAVAILABLE))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn runtime() -> Option<Runtime> {
        // Tests are skipped when artifacts have not been built (plain
        // `cargo test` without `make artifacts`, or a build without the
        // `pjrt` feature); `make test` always builds them first.
        Runtime::load_default().ok()
    }

    #[test]
    fn meta_parses() {
        let m = Meta::parse(
            "reduce_lanes=4096\nmlp_in=2\nmlp_hidden=128\nmlp_classes=3\nmlp_batch=64\nmlp_params=771\n",
        )
        .unwrap();
        assert_eq!(m.reduce_lanes, 4096);
        assert_eq!(m.mlp_params, 771);
    }

    #[test]
    fn meta_rejects_missing_key() {
        assert!(Meta::parse("reduce_lanes=4096\n").is_err());
    }

    #[test]
    fn stub_or_real_load_reports_cleanly() {
        // Whatever the build mode, a failed load must surface a displayable
        // error (the CLI prints it with `{:#}`), never panic.
        match Runtime::load(std::path::Path::new("/nonexistent-artifact-dir")) {
            Ok(_) => {}
            Err(e) => {
                let msg = format!("{e:#}");
                assert!(!msg.is_empty());
            }
        }
    }

    #[test]
    fn reduce2_matches_native() {
        let Some(rt) = runtime() else { return };
        let n = 10_000; // forces chunking + padding
        let a: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..n).map(|i| (n - i) as f32).collect();
        let got = rt.reduce2(&a, &b).unwrap();
        for i in 0..n {
            assert_eq!(got[i], a[i] + b[i], "i={i}");
        }
    }

    #[test]
    fn reduce3_matches_native() {
        let Some(rt) = runtime() else { return };
        let n = 4096 + 7;
        let a: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let b = vec![1.0f32; n];
        let c = vec![2.0f32; n];
        let got = rt.reduce3(&a, &b, &c).unwrap();
        for i in 0..n {
            assert_eq!(got[i], a[i] + 3.0);
        }
    }

    #[test]
    fn mlp_grad_runs_and_is_finite() {
        let Some(rt) = runtime() else { return };
        let m = rt.meta;
        let params = vec![0.01f32; m.mlp_params];
        let x = vec![0.5f32; m.mlp_batch * m.mlp_in];
        let mut y = vec![0f32; m.mlp_batch * m.mlp_classes];
        for r in 0..m.mlp_batch {
            y[r * m.mlp_classes + r % m.mlp_classes] = 1.0;
        }
        let (grad, loss) = rt.mlp_grad(&params, &x, &y).unwrap();
        assert_eq!(grad.len(), m.mlp_params);
        assert!(loss.is_finite() && loss > 0.0);
        assert!(grad.iter().all(|g| g.is_finite()));
    }
}
