//! Symbolic cost certificates: size-independent coefficients of a
//! closed-form completion bound, derived statically from the IR.
//!
//! For a schedule priced on a [`NetModel`], the certificate pins four
//! coefficients such that for every vector size `m`:
//!
//! ```text
//! T(m) ≤ steps·α + tx_rel·(8m/bw) + hop_lat_rel·link_lat + hop_proc_rel·hop_lat
//! ```
//!
//! * `tx_rel` — the serialization sum: Σ over steps of the busiest
//!   *scaled* link load (`load/bw_scale`), i.e. the Eq. 1 bottleneck term.
//!   On the uniform fabric this equals the congestion audit's
//!   `tx_delay_rel` exactly — the pass manager gates on agreement to
//!   1e-12, so the two independent implementations cross-check each other.
//! * `hop_lat_rel` / `hop_proc_rel` — Σ over steps of the longest route's
//!   latency / processing scale sums (the per-step critical path pays each
//!   hop's propagation and forwarding once).
//!
//! Unroutable sends (a down set disconnecting the pair) are priced by the
//! surviving routes, matching `schedule::online`'s staged estimates. The
//! certificate is audited against *measured* `sim::flow` completions in
//! `tools/pysim/eval_passes.py` (and `rust/tests/verify_passes.rs`): the
//! flow engine's round-robin sharing overlaps steps, so measurements run
//! at or under the bound within a pinned tolerance (worst measured
//! deviation 0.176 native / 0.249 padded across the full registry ×
//! {4 KiB..16 MiB} — gated at 0.22 / 0.30). A measurement exceeding
//! `bound·(1+tol)` is a typed [`VerifyError::CostRegression`].

use super::VerifyError;
use crate::cost::NetParams;
use crate::net::NetModel;
use crate::schedule::Schedule;

/// Size-independent cost coefficients of one schedule on one fabric.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CostCertificate {
    pub steps: usize,
    /// Σ per-step busiest scaled link load (units of `m`).
    pub tx_rel: f64,
    /// Σ per-step longest route's propagation-latency scale sum.
    pub hop_lat_rel: f64,
    /// Σ per-step longest route's hop-processing scale sum.
    pub hop_proc_rel: f64,
}

impl CostCertificate {
    /// Evaluate the closed-form bound for an `m_bytes` AllReduce.
    pub fn bound_s(&self, m_bytes: u64, p: &NetParams) -> f64 {
        self.steps as f64 * p.alpha_s
            + self.tx_rel * m_bytes as f64 * 8.0 / p.link_bw_bps
            + self.hop_lat_rel * p.link_latency_s
            + self.hop_proc_rel * p.hop_latency_s
    }
}

/// Derive the certificate of `s` priced on `model` (module docs).
pub fn cost_certificate(s: &Schedule, model: &NetModel) -> CostCertificate {
    let t = model.torus();
    assert_eq!(s.n, t.n(), "cost certificate prices the net schedule on its real torus");
    let mut tx_rel = 0.0f64;
    let mut hop_lat_rel = 0.0f64;
    let mut hop_proc_rel = 0.0f64;
    let mut link_rel = vec![0.0f64; t.num_links()];
    for step in &s.steps {
        link_rel.fill(0.0);
        let mut lat = 0.0f64;
        let mut proc = 0.0f64;
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                let Ok(route) = model.try_route(src as u32, snd.to, snd.route) else {
                    continue; // partitioned pair: priced by surviving routes
                };
                let rel = snd.rel_bytes(s.n_blocks);
                let mut rlat = 0.0f64;
                let mut rproc = 0.0f64;
                for l in &route {
                    let idx = t.link_index(*l);
                    link_rel[idx] += rel;
                    rlat += model.lat_scale(idx);
                    rproc += model.proc_scale(idx);
                }
                lat = lat.max(rlat);
                proc = proc.max(rproc);
            }
        }
        let step_tx = link_rel
            .iter()
            .enumerate()
            .map(|(l, &r)| r / model.bw_scale(l))
            .fold(0.0f64, f64::max);
        tx_rel += step_tx;
        hop_lat_rel += lat;
        hop_proc_rel += proc;
    }
    CostCertificate { steps: s.num_steps(), tx_rel, hop_lat_rel, hop_proc_rel }
}

/// The cross-check gate (module docs): a measured completion may not
/// exceed the certified bound by more than `tol_rel` (relative).
pub fn require_within(
    cert: &CostCertificate,
    m_bytes: u64,
    p: &NetParams,
    measured_s: f64,
    tol_rel: f64,
) -> Result<(), VerifyError> {
    let bound = cert.bound_s(m_bytes, p);
    if measured_s > bound * (1.0 + tol_rel) + super::EPS {
        return Err(VerifyError::CostRegression {
            detail: format!(
                "measured {measured_s:.3e}s exceeds the certified bound {bound:.3e}s \
                 by more than {:.0}%",
                tol_rel * 100.0
            ),
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockset::BlockSet;
    use crate::schedule::{Kind, Piece, RouteHint, Send};
    use crate::topology::Torus;

    fn tiny() -> Schedule {
        // ring-3, one step: each node reduces its full vector into both
        // neighbors — per-step busiest link carries exactly 1.0
        let n = 3u32;
        let mut s = Schedule::new("tiny", n, 1);
        let step = s.push_step();
        for r in 0..n {
            for d in [1i64, -1] {
                let to = (i64::from(r) + d).rem_euclid(i64::from(n)) as u32;
                step.push(
                    r,
                    Send {
                        to,
                        pieces: vec![Piece {
                            blocks: BlockSet::singleton(0, 1),
                            contrib: BlockSet::singleton(r, n),
                            kind: Kind::Reduce,
                        }],
                        route: RouteHint::Minimal,
                    },
                );
            }
        }
        s
    }

    #[test]
    fn tiny_certificate_is_exact() {
        let t = Torus::ring(3);
        let cert = cost_certificate(&tiny(), &NetModel::uniform(&t));
        assert_eq!(cert.steps, 1);
        assert!((cert.tx_rel - 1.0).abs() < 1e-12, "{}", cert.tx_rel);
        // every route is one hop on the uniform fabric
        assert!((cert.hop_lat_rel - 1.0).abs() < 1e-12);
        assert!((cert.hop_proc_rel - 1.0).abs() < 1e-12);
    }

    #[test]
    fn bound_arithmetic_matches_the_formula() {
        let t = Torus::ring(3);
        let cert = cost_certificate(&tiny(), &NetModel::uniform(&t));
        let p = NetParams::default();
        let m = 1u64 << 20;
        let want = p.alpha_s + m as f64 * 8.0 / p.link_bw_bps + p.link_latency_s + p.hop_latency_s;
        assert!((cert.bound_s(m, &p) - want).abs() < 1e-18);
    }

    #[test]
    fn golden_cost_regression_is_typed() {
        let t = Torus::ring(3);
        let cert = cost_certificate(&tiny(), &NetModel::uniform(&t));
        let p = NetParams::default();
        let bound = cert.bound_s(4096, &p);
        require_within(&cert, 4096, &p, bound, 0.0).unwrap();
        match require_within(&cert, 4096, &p, 2.0 * bound, 0.25) {
            Err(VerifyError::CostRegression { .. }) => {}
            other => panic!("expected CostRegression, got {other:?}"),
        }
    }

    #[test]
    fn straggler_link_scales_the_serialization_term() {
        let t = Torus::ring(3);
        let mut m = NetModel::uniform(&t);
        // slow every link 4x: tx_rel quadruples, hop terms stay
        for l in 0..t.num_links() {
            m.set_class(l, crate::net::LinkClass::slowdown(4.0));
        }
        let cert = cost_certificate(&tiny(), &m);
        assert!((cert.tx_rel - 4.0).abs() < 1e-12, "{}", cert.tx_rel);
        assert!((cert.hop_lat_rel - 1.0).abs() < 1e-12);
    }
}
