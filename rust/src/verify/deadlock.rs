//! Deadlock-freedom: forward-availability causality over the schedule and
//! typed stage-order certification for staged plans.
//!
//! A static schedule cannot literally deadlock — steps are globally
//! ordered — but a *rewrite or generator bug* can emit a send that
//! consumes a contribution produced only in a LATER step. Executed by a
//! real runtime that blocks each send until its payload is available,
//! such a schedule stalls forever: a dependency cycle through the step
//! barrier. [`audit_deadlock`] proves the absence of that cycle by
//! forward availability: walking steps in order, every Reduce's claimed
//! contribution must already be available at the sender (union totals
//! only — the atom *algebra* is the dataflow pass's job, which is why the
//! pass manager orders `deadlock` after `dataflow`), and every `Set`
//! requires the sender to have finished the block at the step's start.
//!
//! Like the dataflow proof, this runs on the **exec** schedule (virtual
//! ranks for padded builds): collapsing co-hosted virtual ranks merges
//! their contribution sets, so the collapsed net schedule is not a valid
//! reduction trace and legitimately fails availability.
//!
//! [`audit_stages`] is the typed twin of [`crate::sim::SimPlan::build_staged`]'s
//! assertions: a fault-response stage stack must be sorted by `from_step`
//! with every stage model on the plan's topology — violations surface as
//! [`VerifyError::StageOrderViolation`] instead of a panic inside the
//! plan compiler.

use super::VerifyError;
use crate::blockset::BlockSet;
use crate::net::NetModel;
use crate::schedule::{Kind, Schedule};
use crate::topology::Torus;

/// Prove every consumed contribution is produced strictly earlier
/// (module docs). Runs on the exec schedule.
pub fn audit_deadlock(s: &Schedule) -> Result<(), VerifyError> {
    let n = s.n;
    let nb = s.n_blocks as usize;
    let mut avail: Vec<BlockSet> = (0..n)
        .flat_map(|r| (0..nb).map(move |_| BlockSet::singleton(r, n)))
        .collect();
    for (k, step) in s.steps.iter().enumerate() {
        // availability snapshot at the step's start: a send may only
        // consume what was produced in strictly earlier steps
        let snap = avail.clone();
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                for p in &snd.pieces {
                    for b in p.blocks.iter() {
                        if b as usize >= nb || snd.to >= n {
                            continue; // dataflow reports these as MalformedSend
                        }
                        let cell = src * nb + b as usize;
                        match p.kind {
                            Kind::Reduce => {
                                if !snap[cell].is_superset(&p.contrib) {
                                    let need: Vec<u32> =
                                        p.contrib.difference(&snap[cell]).iter().collect();
                                    return Err(VerifyError::DeadlockCycle {
                                        step: k,
                                        src: src as u32,
                                        dst: snd.to,
                                        block: b,
                                        detail: format!(
                                            "waits on contribution(s) {need:?} produced \
                                             in a later step"
                                        ),
                                    });
                                }
                                avail[snd.to as usize * nb + b as usize].union_with(&p.contrib);
                            }
                            Kind::Set => {
                                if !snap[cell].is_full(n) {
                                    return Err(VerifyError::DeadlockCycle {
                                        step: k,
                                        src: src as u32,
                                        dst: snd.to,
                                        block: b,
                                        detail: "Set of a block the sender only completes \
                                                 in a later step"
                                            .into(),
                                    });
                                }
                                avail[snd.to as usize * nb + b as usize] = BlockSet::full(n);
                            }
                        }
                    }
                }
            }
        }
    }
    Ok(())
}

/// Typed stage-order certification for a fault-response stage stack
/// (module docs): `from_step`s non-decreasing, every model on `t`.
pub fn audit_stages(stages: &[(u32, NetModel)], t: &Torus) -> Result<(), VerifyError> {
    let mut prev: Option<u32> = None;
    for (i, (from, m)) in stages.iter().enumerate() {
        if m.torus().dims() != t.dims() {
            return Err(VerifyError::StageOrderViolation {
                stage: i,
                detail: format!(
                    "stage model topology {:?} != plan topology {:?}",
                    m.torus().dims(),
                    t.dims()
                ),
            });
        }
        if let Some(p) = prev {
            if *from < p {
                return Err(VerifyError::StageOrderViolation {
                    stage: i,
                    detail: format!("from_step {from} < previous stage's {p}"),
                });
            }
        }
        prev = Some(*from);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::{Piece, RouteHint, Send};

    fn reduce(to: u32, contrib: &[u32], n: u32) -> Send {
        Send {
            to,
            pieces: vec![Piece {
                blocks: BlockSet::singleton(0, 1),
                contrib: BlockSet::from_ranks(contrib, n),
                kind: Kind::Reduce,
            }],
            route: RouteHint::Minimal,
        }
    }

    #[test]
    fn forward_chain_is_deadlock_free() {
        // 0→1 ({0}), then 1→2 ({0,1}): strictly forward
        let mut s = Schedule::new("fwd", 3, 1);
        s.push_step().push(0, reduce(1, &[0], 3));
        s.push_step().push(1, reduce(2, &[0, 1], 3));
        audit_deadlock(&s).unwrap();
    }

    #[test]
    fn golden_consume_before_produce_is_a_typed_cycle() {
        // step 0: node 1 ships {0,1} — but {0} only arrives in step 1
        let mut s = Schedule::new("cycle", 3, 1);
        s.push_step().push(1, reduce(2, &[0, 1], 3));
        s.push_step().push(0, reduce(1, &[0], 3));
        match audit_deadlock(&s) {
            Err(VerifyError::DeadlockCycle { step: 0, src: 1, dst: 2, block: 0, .. }) => {}
            other => panic!("expected a DeadlockCycle at step 0, got {other:?}"),
        }
    }

    #[test]
    fn same_step_consume_is_a_cycle_not_a_race() {
        // both sends in ONE step: 0→1 ({0}) and 1→2 ({0,1}) — under the
        // receive barrier node 1 cannot yet hold {0}
        let mut s = Schedule::new("same-step", 3, 1);
        let st = s.push_step();
        st.push(0, reduce(1, &[0], 3));
        st.push(1, reduce(2, &[0, 1], 3));
        assert!(matches!(
            audit_deadlock(&s),
            Err(VerifyError::DeadlockCycle { step: 0, src: 1, .. })
        ));
    }

    #[test]
    fn golden_unsorted_stages_are_typed() {
        let t = Torus::ring(9);
        let m = NetModel::uniform(&t);
        let stages = vec![(2u32, m.clone()), (1u32, m.clone())];
        match audit_stages(&stages, &t) {
            Err(VerifyError::StageOrderViolation { stage: 1, .. }) => {}
            other => panic!("expected StageOrderViolation at stage 1, got {other:?}"),
        }
    }

    #[test]
    fn golden_wrong_topology_stage_is_typed() {
        let t = Torus::ring(9);
        let other = NetModel::uniform(&Torus::new(&[3, 3]));
        match audit_stages(&[(0u32, other)], &t) {
            Err(VerifyError::StageOrderViolation { stage: 0, .. }) => {}
            got => panic!("expected StageOrderViolation at stage 0, got {got:?}"),
        }
    }

    #[test]
    fn sorted_matching_stages_pass() {
        let t = Torus::ring(9);
        let m = NetModel::uniform(&t);
        audit_stages(&[(0u32, m.clone()), (1, m.clone()), (1, m)], &t).unwrap();
    }
}
