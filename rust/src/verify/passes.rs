//! The schedule-IR pass manager: named analyses with declared
//! dependencies, typed findings, and per-pass wall-clock timing.
//!
//! Every verifier in this crate is registered here as a *pass* — a named
//! analysis over one [`BuiltCollective`] — so the CLI can run any subset
//! (`trivance verify --pass <name>`), the registry gate runs all of them,
//! and every result lands in `VERIFY_report.json` (schema
//! `trivance.verify.v2`) with its wall-clock cost. The canonical order:
//!
//! | pass         | schedule | proves / measures                             |
//! |--------------|----------|-----------------------------------------------|
//! | `dataflow`   | exec     | exact atom-lattice AllReduce proof            |
//! | `hazard`     | exec     | WAR/WAW races on (rank, block) cells          |
//! | `deadlock`   | exec     | forward availability (after `dataflow`)       |
//! | `memory`     | exec     | peak live rel-bytes vs the certified bound    |
//! | `ports`      | net      | per-(node, port, step) injection budget       |
//! | `congestion` | net      | link-load profile (Eq. 1 serialization)       |
//! | `optimality` | net      | step count / traffic vs the paper's bounds    |
//! | `cost`       | net      | symbolic bound coefficients, cross-checked    |
//!
//! Dependencies ([`pass_deps`]) are closed transitively by
//! [`select_passes`]: `deadlock` consumes only union totals and defers
//! the atom algebra to `dataflow`; `cost` cross-checks its `tx_rel`
//! against `congestion` to 1e-12 and reports next to `optimality`'s
//! class. Selection is always re-sorted into canonical order, so a pass
//! never runs before its dependencies.
//!
//! A pass emits [`Finding`]s instead of failing fast: `Error` findings
//! carry the typed [`VerifyError`] (the severity policy — e.g. WAR is an
//! error on in-place bandwidth variants but informational on
//! barrier-protected latency variants — lives HERE, not in the
//! analyses, which stay pure). [`super::certify_collective`] is a thin
//! wrapper: run everything, propagate the first `Error` finding, fold
//! the results into a [`Certificate`].

use std::time::Instant;

use super::cost::{cost_certificate, CostCertificate};
use super::deadlock::audit_deadlock;
use super::hazard::{audit_hazards, first_war, first_waw, HazardAudit};
use super::memory::{audit_memory, certified_bound, require_peak_within, MemoryAudit};
use super::{
    audit_congestion, audit_optimality, audit_ports, host_multiplicity, port_budget,
    Certificate, CongestionAudit, DataflowProof, OptAudit, PortAudit, VerifyError,
};
use crate::algo::{Algo, BuiltCollective, Variant};
use crate::net::NetModel;
use crate::topology::Torus;

/// Canonical pass order — selection subsets preserve it.
pub const PASS_NAMES: [&str; 8] = [
    "dataflow",
    "hazard",
    "deadlock",
    "memory",
    "ports",
    "congestion",
    "optimality",
    "cost",
];

/// Declared dependencies of a pass (module docs).
pub fn pass_deps(name: &str) -> &'static [&'static str] {
    match name {
        "deadlock" => &["dataflow"],
        "cost" => &["congestion", "optimality"],
        _ => &[],
    }
}

/// Resolve a requested subset into an executable selection: close over
/// [`pass_deps`] transitively and re-sort into [`PASS_NAMES`] order.
/// An empty request selects every pass; unknown names are an error.
pub fn select_passes(requested: &[&str]) -> Result<Vec<&'static str>, String> {
    if requested.is_empty() {
        return Ok(PASS_NAMES.to_vec());
    }
    let mut want: Vec<&'static str> = Vec::new();
    let mut queue: Vec<&str> = requested.to_vec();
    while let Some(p) = queue.pop() {
        let Some(&canon) = PASS_NAMES.iter().find(|&&q| q == p) else {
            return Err(format!(
                "unknown pass '{p}' (known: {})",
                PASS_NAMES.join(", ")
            ));
        };
        if !want.contains(&canon) {
            want.push(canon);
            queue.extend(pass_deps(canon));
        }
    }
    Ok(PASS_NAMES.iter().copied().filter(|p| want.contains(p)).collect())
}

/// How severe a finding is. `Error` findings fail certification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    pub fn label(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// One typed observation from one pass.
#[derive(Clone, Debug)]
pub struct Finding {
    pub pass: &'static str,
    pub severity: Severity,
    pub message: String,
    /// The typed error — always `Some` for [`Severity::Error`] findings
    /// (enforced by construction: [`Finding::error`] is the only error
    /// constructor).
    pub error: Option<VerifyError>,
}

impl Finding {
    fn error(pass: &'static str, err: VerifyError) -> Finding {
        Finding { pass, severity: Severity::Error, message: err.to_string(), error: Some(err) }
    }

    fn info(pass: &'static str, message: String) -> Finding {
        Finding { pass, severity: Severity::Info, message, error: None }
    }
}

/// Wall-clock cost of one executed pass.
#[derive(Clone, Copy, Debug)]
pub struct PassTiming {
    pub pass: &'static str,
    pub seconds: f64,
}

/// Raw results of the executed passes — `None` for passes that were not
/// selected or whose audit erred before producing a value.
#[derive(Clone, Debug, Default)]
pub struct PassResults {
    pub dataflow: Option<DataflowProof>,
    pub hazard: Option<HazardAudit>,
    pub deadlock_ok: Option<bool>,
    pub memory: Option<MemoryAudit>,
    pub ports: Option<PortAudit>,
    pub congestion: Option<CongestionAudit>,
    pub optimality: Option<OptAudit>,
    pub cost: Option<CostCertificate>,
}

/// Everything one [`run_passes`] execution produced.
#[derive(Clone, Debug)]
pub struct PassOutcome {
    pub name: String,
    pub algo: Algo,
    pub variant: Variant,
    pub padded: bool,
    pub results: PassResults,
    pub findings: Vec<Finding>,
    pub timings: Vec<PassTiming>,
}

impl PassOutcome {
    /// The first `Error` finding's typed error, if any pass failed.
    pub fn first_error(&self) -> Option<&VerifyError> {
        self.findings
            .iter()
            .find(|f| f.severity == Severity::Error)
            .and_then(|f| f.error.as_ref())
    }

    /// Fold a full, error-free run into a [`Certificate`] (`None` when a
    /// pass was skipped or erred before producing its result).
    pub fn certificate(&self) -> Option<Certificate> {
        Some(Certificate {
            name: self.name.clone(),
            algo: self.algo,
            variant: self.variant,
            padded: self.padded,
            dataflow: self.results.dataflow.clone()?,
            hazard: self.results.hazard?,
            deadlock_ok: self.results.deadlock_ok?,
            memory: self.results.memory?,
            ports: self.results.ports?,
            congestion: self.results.congestion?,
            optimality: self.results.optimality?,
            cost: self.results.cost?,
        })
    }
}

/// Execute `selection` (from [`select_passes`] — assumed closed and in
/// canonical order) over one built collective on the real torus `t`.
/// Exec-schedule passes see virtual ranks for padded builds; net-schedule
/// passes see the collapsed schedule actually shipped to the fabric.
pub fn run_passes(b: &BuiltCollective, t: &Torus, selection: &[&'static str]) -> PassOutcome {
    let mut out = PassOutcome {
        name: b.name.clone(),
        algo: b.algo,
        variant: b.variant,
        padded: b.padded,
        results: PassResults::default(),
        findings: Vec::new(),
        timings: Vec::new(),
    };
    let hm = host_multiplicity(b);
    for &pass in selection {
        let t0 = Instant::now();
        match pass {
            "dataflow" => match verify_dataflow_of(b) {
                Ok(proof) => out.results.dataflow = Some(proof),
                Err(e) => out.findings.push(Finding::error(pass, e)),
            },
            "hazard" => {
                let haz = audit_hazards(&b.exec);
                out.results.hazard = Some(haz);
                if haz.waw_conflicts > 0 {
                    if let Some(e) = first_waw(&b.exec) {
                        out.findings.push(Finding::error(pass, e));
                    }
                }
                if haz.war_cells > 0 {
                    match b.variant {
                        Variant::Bandwidth => {
                            if let Some(e) = first_war(&b.exec) {
                                out.findings.push(Finding::error(pass, e));
                            }
                        }
                        Variant::Latency => out.findings.push(Finding::info(
                            pass,
                            format!(
                                "{} WAR cell(s) rely on the receive barrier",
                                haz.war_cells
                            ),
                        )),
                    }
                }
            }
            "deadlock" => match audit_deadlock(&b.exec) {
                Ok(()) => out.results.deadlock_ok = Some(true),
                Err(e) => {
                    out.results.deadlock_ok = Some(false);
                    out.findings.push(Finding::error(pass, e));
                }
            },
            "memory" => {
                let hosts = b.padding.as_ref().map(|p| p.hosts.as_slice());
                let mem = audit_memory(&b.exec, hosts, t.n());
                out.results.memory = Some(mem);
                if let Err(e) = require_peak_within(&mem, certified_bound(b, &mem)) {
                    out.findings.push(Finding::error(pass, e));
                }
            }
            "ports" => {
                let budget = port_budget(b.algo, b.variant) * hm;
                match audit_ports(&b.net, t, budget) {
                    Ok(ports) => out.results.ports = Some(ports),
                    Err(e) => out.findings.push(Finding::error(pass, e)),
                }
            }
            "congestion" => match audit_congestion(&b.net, t) {
                Ok(c) => out.results.congestion = Some(c),
                Err(e) => out.findings.push(Finding::error(pass, e)),
            },
            "optimality" => out.results.optimality = Some(audit_optimality(&b.net, t)),
            "cost" => {
                let cc = cost_certificate(&b.net, &NetModel::uniform(t));
                out.results.cost = Some(cc);
                // the two independent serialization sums must agree exactly
                if let Some(cong) = &out.results.congestion {
                    if (cc.tx_rel - cong.tx_delay_rel).abs() > 1e-12 {
                        out.findings.push(Finding::error(
                            pass,
                            VerifyError::CostRegression {
                                detail: format!(
                                    "certificate tx_rel {} != congestion audit {}",
                                    cc.tx_rel, cong.tx_delay_rel
                                ),
                            },
                        ));
                    }
                }
            }
            _ => {}
        }
        out.timings.push(PassTiming { pass, seconds: t0.elapsed().as_secs_f64() });
    }
    out
}

fn verify_dataflow_of(b: &BuiltCollective) -> Result<DataflowProof, VerifyError> {
    super::verify_dataflow(&b.exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::build;

    #[test]
    fn empty_selection_is_every_pass_in_order() {
        assert_eq!(select_passes(&[]).unwrap(), PASS_NAMES.to_vec());
    }

    #[test]
    fn selection_closes_over_dependencies_in_canonical_order() {
        assert_eq!(select_passes(&["cost"]).unwrap(), vec!["congestion", "optimality", "cost"]);
        assert_eq!(select_passes(&["deadlock"]).unwrap(), vec!["dataflow", "deadlock"]);
        assert_eq!(select_passes(&["hazard"]).unwrap(), vec!["hazard"]);
        // request order is irrelevant; duplicates collapse
        assert_eq!(
            select_passes(&["cost", "deadlock", "cost"]).unwrap(),
            vec!["dataflow", "deadlock", "congestion", "optimality", "cost"]
        );
    }

    #[test]
    fn unknown_pass_is_an_error() {
        assert!(select_passes(&["hazards"]).is_err());
    }

    #[test]
    fn full_run_on_trivance_ring9_has_no_error_findings() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let out = run_passes(&b, &t, &PASS_NAMES);
        assert!(out.first_error().is_none(), "{:?}", out.findings);
        assert_eq!(out.timings.len(), PASS_NAMES.len());
        let cert = out.certificate().unwrap();
        assert!(cert.deadlock_ok);
        assert_eq!(cert.hazard.waw_conflicts, 0);
        assert_eq!(cert.cost.steps, cert.optimality.steps);
    }

    #[test]
    fn partial_selection_cannot_build_a_certificate() {
        let t = Torus::ring(8);
        let b = build(Algo::Bucket, Variant::Bandwidth, &t).unwrap();
        let sel = select_passes(&["hazard"]).unwrap();
        let out = run_passes(&b, &t, &sel);
        assert!(out.certificate().is_none());
        assert!(out.results.hazard.is_some());
        assert!(out.results.dataflow.is_none());
    }
}
