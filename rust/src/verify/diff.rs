//! Differential rewrite certification: prove a fault rewrite equivalent
//! to its original modulo dead contributions.
//!
//! Re-verifying a rewritten schedule from scratch ([`super::verify_dataflow_surviving`])
//! proves it is *a* correct surviving AllReduce — not that it is the
//! *same collective minus the fault*. A rewrite bug that silently swaps
//! in a different (slower, or subtly re-routed) schedule would still pass.
//! [`certify_rewrite`] closes that gap with four obligations against the
//! original:
//!
//! 1. **Immutable prefix** (steps `< fault_step`): verbatim — those steps
//!    already executed when the fault landed.
//! 2. **Shrink-only body** (`fault_step ≤ k <` original length): every
//!    rewritten send must shrink-match an original send with the same
//!    `(dst, route)` — blocks and Reduce contributions may only shrink,
//!    `Set` contributions are preserved — no new sends appear, and
//!    nothing touches a dead node. The rewrite is the same computation
//!    minus dead/blocked contributions.
//! 3. **Cleanup zone** (`k ≥` original length): appended recovery steps
//!    are only required to stay between alive nodes.
//! 4. **Survivor completeness**: one atom-lattice replay proves every
//!    alive rank still ends with the full reduction (contributions in
//!    flight before the fault included).
//!
//! `dead` maps REAL dead ranks to their death *step* — a rank sends
//! legitimately until its own death (a late node fault must not poison
//! its earlier sends). `hosts` lifts virtual ranks of a padded exec
//! schedule onto the real torus. The obligations compose over fault
//! sequences: shrink relations compose, and every cleanup step of an
//! earlier rewrite lands in the later rewrite's cleanup zone.
//!
//! [`certify_response`] applies the same proof to a full
//! [`crate::schedule::online::Response`]: the stage stack is
//! order-certified ([`super::deadlock::audit_stages`]), death obligations
//! are derived only from stages whose action actually *rewrote* the
//! schedule (a fault the controller detoured — or failed to rewrite and
//! degraded to a detour — leaves the schedule untouched, so its sends
//! legitimately remain), and the diff runs from the first rewrite step.

use std::collections::HashMap;

use super::deadlock::audit_stages;
use super::{verify_dataflow_surviving, VerifyError};
use crate::algo::BuiltCollective;
use crate::net::NetModel;
use crate::schedule::online::{Action, Response};
use crate::schedule::{Kind, Piece, Schedule, Send};
use crate::topology::Link;

fn divergence(detail: String) -> VerifyError {
    VerifyError::RewriteDivergence { detail }
}

/// Does `rw_piece` shrink-match some original piece? Same kind, blocks a
/// subset; Reduce contributions shrink, Set contributions are preserved.
fn piece_shrinks(rw_piece: &Piece, orig_pieces: &[Piece]) -> bool {
    orig_pieces.iter().any(|o| {
        if o.kind != rw_piece.kind || !o.blocks.is_superset(&rw_piece.blocks) {
            return false;
        }
        match rw_piece.kind {
            Kind::Reduce => o.contrib.is_superset(&rw_piece.contrib),
            Kind::Set => o.contrib == rw_piece.contrib,
        }
    })
}

/// Multiset equality of two piece lists (order-insensitive — generators
/// may emit pieces in any order, the payload is the same).
fn same_pieces(a: &[Piece], b: &[Piece]) -> bool {
    if a.len() != b.len() {
        return false;
    }
    let mut used = vec![false; b.len()];
    a.iter().all(|p| {
        b.iter().enumerate().any(|(i, q)| {
            if !used[i] && p == q {
                used[i] = true;
                true
            } else {
                false
            }
        })
    })
}

fn same_send(a: &Send, b: &Send) -> bool {
    a.to == b.to && a.route == b.route && same_pieces(&a.pieces, &b.pieces)
}

/// Certify `rw` as a faithful rewrite of `orig` for a fault at
/// `fault_step` (module docs). `dead` maps real dead ranks to their death
/// step; `hosts` maps virtual ranks to real nodes for padded schedules.
pub fn certify_rewrite(
    orig: &Schedule,
    rw: &Schedule,
    fault_step: usize,
    dead: &HashMap<u32, usize>,
    hosts: Option<&[u32]>,
) -> Result<(), VerifyError> {
    let n = orig.n;
    if rw.n != n || rw.n_blocks != orig.n_blocks {
        return Err(divergence("rank/block shape mismatch".into()));
    }
    let real = |v: u32| -> u32 {
        match hosts {
            Some(h) => h[v as usize],
            None => v,
        }
    };
    let is_dead = |v: u32, k: usize| dead.get(&real(v)).is_some_and(|&d| d <= k);
    let olen = orig.steps.len();
    let guard = fault_step.min(olen);
    if rw.steps.len() < guard {
        return Err(divergence("rewrite shorter than the immutable prefix".into()));
    }
    for (k, step) in rw.steps.iter().enumerate() {
        for (src_i, sends) in step.sends.iter().enumerate() {
            let src = src_i as u32;
            if k < guard {
                // obligation 1: executed prefix is verbatim (send order
                // preserved; pieces compared as multisets)
                let o = &orig.steps[k].sends[src_i];
                let same = sends.len() == o.len()
                    && sends.iter().zip(o).all(|(a, b)| same_send(a, b));
                if !same {
                    return Err(divergence(format!(
                        "step {k} src {src}: executed prefix modified"
                    )));
                }
            } else if k < olen {
                // obligation 2: shrink-only body
                if !sends.is_empty() && is_dead(src, k) {
                    return Err(divergence(format!("step {k}: dead src {src} sends")));
                }
                let orig_sends = &orig.steps[k].sends[src_i];
                let mut used = vec![false; orig_sends.len()];
                for s_rw in sends {
                    if is_dead(s_rw.to, k) {
                        return Err(divergence(format!(
                            "step {k}: send to dead node {}",
                            s_rw.to
                        )));
                    }
                    let hit = orig_sends.iter().enumerate().find_map(|(i, s_o)| {
                        if used[i] || s_o.to != s_rw.to || s_o.route != s_rw.route {
                            return None;
                        }
                        if s_rw.pieces.iter().all(|p| piece_shrinks(p, &s_o.pieces)) {
                            Some(i)
                        } else {
                            None
                        }
                    });
                    match hit {
                        Some(i) => used[i] = true,
                        None => {
                            return Err(divergence(format!(
                                "step {k} src {src}->{}: no shrink-match against \
                                 the original",
                                s_rw.to
                            )))
                        }
                    }
                }
            } else {
                // obligation 3: cleanup stays between alive nodes
                if !sends.is_empty() && is_dead(src, k) {
                    return Err(divergence(format!(
                        "cleanup step {k}: dead src {src} sends"
                    )));
                }
                for s_rw in sends {
                    if is_dead(s_rw.to, k) {
                        return Err(divergence(format!(
                            "cleanup step {k}: send to dead node {}",
                            s_rw.to
                        )));
                    }
                }
            }
        }
    }
    // obligation 4: survivor completeness
    let alive: Vec<bool> = (0..n).map(|r| !dead.contains_key(&real(r))).collect();
    verify_dataflow_surviving(rw, &alive).map_err(|e| {
        divergence(format!("survivor dataflow: {e}"))
    })?;
    Ok(())
}

/// Every port of `r` is down under `model` — the controller's node-death
/// encoding (a node fault downs all its links).
fn downed(model: &NetModel, r: u32) -> bool {
    let t = model.torus();
    (0..t.ndims()).all(|d| {
        [1i8, -1].iter().all(|&dir| {
            model.is_down(t.link_index(Link { node: r, dim: d as u8, dir }))
        })
    })
}

/// Differentially certify an online fault [`Response`] against its
/// pre-fault collective (module docs). Native builds only — the online
/// controller collapses padded rewrites internally, so `resp.schedule`
/// lives on the real torus like `b.net`.
pub fn certify_response(
    b: &BuiltCollective,
    base: &NetModel,
    resp: &Response,
) -> Result<(), VerifyError> {
    audit_stages(&resp.stages, base.torus())?;
    let rewrites: Vec<usize> = resp
        .actions
        .iter()
        .filter(|&&(_, a)| a == Action::Rewrite)
        .map(|&(s, _)| s)
        .collect();
    let Some(&first_rewrite) = rewrites.iter().min() else {
        return Ok(()); // detour-only: the schedule is the original
    };
    // A rank is dead from the first REWRITE-applied stage in which every
    // one of its ports is down; detoured faults create no obligations.
    let t = base.torus();
    let mut dead: HashMap<u32, usize> = HashMap::new();
    let mut prev: Option<&NetModel> = None;
    for ((from, model), (_, applied)) in resp.stages.iter().zip(&resp.actions) {
        if *applied == Action::Rewrite {
            for r in 0..t.n() {
                if !dead.contains_key(&r)
                    && downed(model, r)
                    && prev.is_none_or(|p| !downed(p, r))
                {
                    dead.insert(r, *from as usize);
                }
            }
        }
        prev = Some(model);
    }
    certify_rewrite(&b.net, &resp.schedule, first_rewrite, &dead, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};
    use crate::blockset::BlockSet;
    use crate::schedule::rewrite::{rewrite_for_fault, Fault};
    use crate::schedule::RouteHint;
    use crate::topology::Torus;

    fn ring9() -> (Torus, Schedule, NetModel) {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let m = NetModel::uniform(&t);
        (t, s, m)
    }

    #[test]
    fn identity_certifies_against_itself() {
        let (_t, s, _m) = ring9();
        certify_rewrite(&s, &s, 1, &HashMap::new(), None).unwrap();
    }

    #[test]
    fn link_fault_rewrite_certifies() {
        let (t, s, base) = ring9();
        let fault = Fault::link(1, t.link_index(Link { node: 0, dim: 0, dir: 1 }));
        let rw = rewrite_for_fault(&s, &base, &fault).unwrap_or_else(|e| panic!("{e}"));
        certify_rewrite(&s, &rw, fault.step, &HashMap::new(), None)
            .unwrap_or_else(|e| panic!("{e}"));
    }

    #[test]
    fn node_death_rewrite_certifies_with_death_step() {
        let (_t, s, base) = ring9();
        let fault = Fault::node(1, 4);
        let rw = rewrite_for_fault(&s, &base, &fault).unwrap_or_else(|e| panic!("{e}"));
        let dead = HashMap::from([(4u32, 1usize)]);
        certify_rewrite(&s, &rw, 1, &dead, None).unwrap_or_else(|e| panic!("{e}"));
        // with the death step at 0 the proof must refuse: node 4 sends in
        // step 0 of the (verbatim) prefix... the prefix is exempt, but the
        // survivor replay also passes — move the fault_step to 0 so step 0
        // enters the body and the dead sender is caught
        match certify_rewrite(&s, &rw, 0, &HashMap::from([(4u32, 0usize)]), None) {
            Err(VerifyError::RewriteDivergence { detail }) => {
                assert!(detail.contains("dead"), "{detail}");
            }
            other => panic!("expected RewriteDivergence, got {other:?}"),
        }
    }

    #[test]
    fn golden_modified_prefix_is_a_typed_divergence() {
        let (t, s, base) = ring9();
        let fault = Fault::link(1, t.link_index(Link { node: 0, dim: 0, dir: 1 }));
        let mut rw = rewrite_for_fault(&s, &base, &fault).unwrap_or_else(|e| panic!("{e}"));
        // tamper with an already-executed step
        rw.steps[0].sends[0][0].route = RouteHint::Directed { dim: 0, dir: -1 };
        match certify_rewrite(&s, &rw, fault.step, &HashMap::new(), None) {
            Err(VerifyError::RewriteDivergence { detail }) => {
                assert!(detail.contains("prefix"), "{detail}");
            }
            other => panic!("expected a prefix RewriteDivergence, got {other:?}"),
        }
    }

    #[test]
    fn golden_grown_contribution_is_a_typed_divergence() {
        let (t, s, base) = ring9();
        let fault = Fault::link(1, t.link_index(Link { node: 0, dim: 0, dir: 1 }));
        let mut rw = rewrite_for_fault(&s, &base, &fault).unwrap_or_else(|e| panic!("{e}"));
        // grow a body-step contribution beyond its original: not a shrink
        let step = fault.step;
        let snd = &mut rw.steps[step].sends[3][0];
        snd.pieces[0].contrib = BlockSet::full(9);
        match certify_rewrite(&s, &rw, fault.step, &HashMap::new(), None) {
            Err(VerifyError::RewriteDivergence { detail }) => {
                assert!(detail.contains("shrink"), "{detail}");
            }
            other => panic!("expected a shrink RewriteDivergence, got {other:?}"),
        }
    }
}
