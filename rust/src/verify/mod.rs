//! Static schedule certification: a pass manager ([`passes`]) running
//! dataflow proofs, hazard/deadlock/memory analyses, port-conflict
//! detection, congestion/optimality audits and symbolic cost
//! certificates — no simulation involved.
//!
//! The paper's central claims are *static* properties of schedules:
//! ⌈log₃ n⌉ steps, both ring ports busy every step with exactly one
//! message each, congestion a third of classic (unidirectional) Bruck,
//! bandwidth-optimality of the pipeline variants. The simulators check
//! none of that directly — a rewrite or online-controller bug that emits a
//! subtly wrong-but-completing schedule only surfaces when a numeric drift
//! bound happens to trip. This module closes the gap with four analyses
//! over [`Schedule`] (and a route-chain audit over [`SimPlan`]):
//!
//! 1. **Dataflow correctness** ([`verify_dataflow`]) — atom-level abstract
//!    interpretation. Each (rank, block) cell carries the set of original
//!    contributions it holds, as a union of *atoms* (contribution sets
//!    that were reduced together and can no longer be separated). Every
//!    Reduce must ship an exact union of sender atoms the sender actually
//!    holds, land disjointly at the receiver (no double-counting), and the
//!    final state must be the full reduction on every rank. The lattice is
//!    the one [`crate::schedule::validate`] uses; here every defect is a
//!    typed [`VerifyError`] so callers (CI, the online controller's tests,
//!    fuzzers) can gate on the *class* of defect, and node-death rewrites
//!    can be proved survivor-complete via [`verify_dataflow_surviving`].
//! 2. **Multiport legality** ([`audit_ports`]) — per (node, step, dim,
//!    direction) transmission-port usage must not exceed the fabric's port
//!    budget ([`port_budget`]; 1 for the single-message-per-port
//!    algorithms — the paper's one-message-per-port claim for Trivance —
//!    2 for the multiport Bruck family, scaled by host multiplicity for
//!    padded builds). Directed route hints are structurally checked before
//!    any routing, so a corrupt hint is a typed error, never a panic.
//! 3. **Congestion certification** ([`audit_congestion`]) — static
//!    per-link load (relative bytes crossing each link, per step) with
//!    max/mean and total bytes-on-wire, summed into the same `tx_delay`
//!    figure as [`crate::schedule::analysis`]. [`certify_registry`]
//!    asserts the paper's ring claim: Trivance-L ≤ ⅓ · unidirectional
//!    Bruck (and never worse than bidirectional Bruck).
//! 4. **Optimality audit** ([`audit_optimality`]) — step count against
//!    Σ_d ⌈log₃ a_d⌉ and Σ_d ⌈log₂ a_d⌉, max per-node bytes against the
//!    2(n−1)/n AllReduce lower bound, classifying every collective as
//!    latency-optimal / bandwidth-optimal / neither.
//!
//! Those four analyses predate the pass manager; they are now passes
//! alongside four newer ones, each in its own submodule:
//!
//! 5. **Write hazards** ([`hazard`]) — WAR/WAW races on (rank, block)
//!    cells within a step (policy: WAW always errs; WAR errs only on
//!    in-place bandwidth variants).
//! 6. **Deadlock freedom** ([`deadlock`]) — forward availability (no send
//!    consumes a contribution produced in a later step) plus typed
//!    stage-order certification for fault-response stage stacks.
//! 7. **Memory certification** ([`memory`]) — peak live rel-bytes per
//!    real node per step against a per-variant certified bound.
//! 8. **Symbolic cost certificates** ([`cost`]) — size-independent
//!    coefficients of `steps·α + tx_rel·β·m + …`, cross-checked against
//!    the congestion audit to 1e-12 and against measured `sim::flow`
//!    completions within pinned tolerances.
//!
//! [`certify_collective`] runs every pass through [`passes::run_passes`]
//! and folds the results into a [`Certificate`]: exec-schedule passes see
//! virtual ranks for padded builds — the collapsed net schedule merges
//! co-hosted contribution sets and is not a meaningful reduction trace at
//! the real-rank level — while net-schedule passes audit what actually
//! ships to the fabric. `trivance verify` renders the per-algorithm
//! report, accepts `--pass <name>` / `--list-passes`, and writes
//! `VERIFY_report.json` (schema `trivance.verify.v2`, with per-pass
//! wall-clock timing). [`diff`] differentially certifies fault rewrites
//! against their originals; the verifier itself is mutation-tested by
//! [`mutate`] (drop-a-send / swap-contributors / duplicate-a-reduce /
//! shift-a-port / inject-hazard must all be killed).
//!
//! Mirrored in `tools/pysim/mirror.py` + `eval_verify.py` /
//! `eval_passes.py` (this container has no rustc): the dataflow lattice,
//! port budgets, congestion sums, per-pass policies, WAR/memory pins and
//! the registry certificates are pinned there — keep the arithmetic in
//! lockstep.

pub mod cost;
pub mod deadlock;
pub mod diff;
pub mod hazard;
pub mod memory;
pub mod mutate;
pub mod passes;

use std::fmt as stdfmt;

use crate::algo::{build, Algo, BuiltCollective, Variant};
use crate::blockset::BlockSet;
use crate::schedule::{Kind, RouteHint, Schedule, Send};
use crate::sim::SimPlan;
use crate::topology::{Link, Torus};
use crate::util::{ceil_log, fmt, json};

/// Slack for floating-point comparisons against exact rational bounds.
pub const EPS: f64 = 1e-9;

/// A typed static-verification defect. Every analysis reports the first
/// defect it can prove; `Display` renders a human-readable sentence.
#[derive(Clone, Debug, PartialEq)]
pub enum VerifyError {
    /// Structurally invalid send: bad destination, empty piece, corrupt
    /// route hint, block out of range — anything that has no meaning.
    MalformedSend { step: usize, src: u32, dst: u32, detail: String },
    /// The sender cannot produce the claimed contribution at this step:
    /// it lacks part of it, or the claim splits an already-reduced atom.
    UnrealizableSend { step: usize, src: u32, dst: u32, block: u32, detail: String },
    /// The receiver already holds part of the shipped contribution — the
    /// reduction would count some rank's data twice.
    DoubleCount { step: usize, src: u32, dst: u32, block: u32, overlap: u64 },
    /// A rank ends the schedule without the full reduction for a block.
    MissingContribution { node: u32, block: u32, missing: u64 },
    /// More simultaneous messages leave one (node, dim, direction) port
    /// than the fabric has transmission ports for.
    PortOvercommit { step: usize, node: u32, dim: u8, dir: i8, used: u32, budget: u32 },
    /// A collective that must be latency-optimal takes more steps than
    /// its ⌈log₃⌉ bound.
    StepCountRegression { name: String, steps: usize, bound: u32 },
    /// A pinned congestion relation (Trivance ≤ ⅓·Bruck on rings) broke.
    CongestionRegression { detail: String },
    /// A compiled plan's route is not a connected src→dst link chain.
    BrokenRoute { msg: usize, hop: usize, detail: String },
    /// A compiled plan does not match the topology it claims to run on.
    PlanMismatch { detail: String },
    /// A within-step write race on one (rank, block) cell ([`hazard`]).
    WriteHazard { step: usize, node: u32, block: u32, detail: String },
    /// A send consumes a contribution produced only in a later step — a
    /// dependency cycle through the step barrier ([`deadlock`]).
    DeadlockCycle { step: usize, src: u32, dst: u32, block: u32, detail: String },
    /// A fault-response stage stack is unsorted or on the wrong topology.
    StageOrderViolation { stage: usize, detail: String },
    /// Peak live memory exceeds the variant's certified bound ([`memory`]).
    MemoryRegression { node: u32, step: usize, peak_rel: f64, bound_rel: f64 },
    /// A measured completion exceeds the symbolic cost bound, or the
    /// certificate disagrees with the congestion audit ([`cost`]).
    CostRegression { detail: String },
    /// A fault rewrite is not the original collective minus dead
    /// contributions ([`diff`]).
    RewriteDivergence { detail: String },
}

impl stdfmt::Display for VerifyError {
    fn fmt(&self, f: &mut stdfmt::Formatter<'_>) -> stdfmt::Result {
        match self {
            VerifyError::MalformedSend { step, src, dst, detail } => {
                write!(f, "malformed send at step {step} ({src}->{dst}): {detail}")
            }
            VerifyError::UnrealizableSend { step, src, dst, block, detail } => write!(
                f,
                "unrealizable send at step {step} ({src}->{dst}, block {block}): {detail}"
            ),
            VerifyError::DoubleCount { step, src, dst, block, overlap } => write!(
                f,
                "double-counted reduction at step {step} ({src}->{dst}, block {block}): \
                 {overlap} contribution(s) already held by the receiver"
            ),
            VerifyError::MissingContribution { node, block, missing } => write!(
                f,
                "incomplete reduction: node {node} block {block} is missing \
                 {missing} contribution(s)"
            ),
            VerifyError::PortOvercommit { step, node, dim, dir, used, budget } => write!(
                f,
                "port overcommit at step {step}: node {node} dim {dim} dir {dir:+} \
                 carries {used} messages (budget {budget})"
            ),
            VerifyError::StepCountRegression { name, steps, bound } => write!(
                f,
                "step-count regression: {name} takes {steps} steps \
                 (latency-optimal bound {bound})"
            ),
            VerifyError::CongestionRegression { detail } => {
                write!(f, "congestion regression: {detail}")
            }
            VerifyError::BrokenRoute { msg, hop, detail } => {
                write!(f, "broken route in plan message {msg} at hop {hop}: {detail}")
            }
            VerifyError::PlanMismatch { detail } => write!(f, "plan/topology mismatch: {detail}"),
            VerifyError::WriteHazard { step, node, block, detail } => write!(
                f,
                "write hazard at step {step} (node {node}, block {block}): {detail}"
            ),
            VerifyError::DeadlockCycle { step, src, dst, block, detail } => write!(
                f,
                "deadlock cycle at step {step} ({src}->{dst}, block {block}): {detail}"
            ),
            VerifyError::StageOrderViolation { stage, detail } => {
                write!(f, "stage-order violation at stage {stage}: {detail}")
            }
            VerifyError::MemoryRegression { node, step, peak_rel, bound_rel } => write!(
                f,
                "memory regression: node {node} holds {peak_rel} m at step {step} \
                 (certified bound {bound_rel} m)"
            ),
            VerifyError::CostRegression { detail } => write!(f, "cost regression: {detail}"),
            VerifyError::RewriteDivergence { detail } => {
                write!(f, "rewrite divergence: {detail}")
            }
        }
    }
}

/// Witness of a proved-correct dataflow: summary statistics only — the
/// proof itself is the successful abstract interpretation.
#[derive(Clone, Debug)]
pub struct DataflowProof {
    pub n: u32,
    pub n_blocks: u32,
    pub steps: usize,
    pub messages: usize,
    /// Largest atom count any (rank, block) cell reached — a measure of
    /// how fragmented partial reductions got before converging.
    pub max_atoms: usize,
}

/// One (rank, block) abstract cell: contributions held, as a union of
/// inseparable atoms.
#[derive(Clone)]
struct Cell {
    atoms: Vec<BlockSet>,
    total: BlockSet,
}

impl Cell {
    fn new(own: u32, n: u32) -> Cell {
        Cell { atoms: vec![BlockSet::singleton(own, n)], total: BlockSet::singleton(own, n) }
    }
}

/// Is `contrib` an exact union of some of the sender's atoms? Shipping a
/// *part* of an atom is unrealizable: those contributions were already
/// reduced together and cannot be separated again.
fn exact_cover(atoms: &[BlockSet], contrib: &BlockSet) -> bool {
    let mut covered = 0u64;
    for a in atoms {
        let inter = a.intersect(contrib);
        if inter.is_empty() {
            continue;
        }
        if inter != *a {
            return false;
        }
        covered += a.len();
    }
    covered == contrib.len()
}

/// Prove `s` computes the exact full AllReduce on every rank (module
/// docs, analysis 1). Typed twin of
/// [`crate::schedule::validate::validate_allreduce`].
pub fn verify_dataflow(s: &Schedule) -> Result<DataflowProof, VerifyError> {
    dataflow_core(s, None)
}

/// [`verify_dataflow`], but final completeness is only required on ranks
/// with `alive[rank]` — the contract of a node-death rewrite: survivors
/// must still end with the full reduction (including the dead node's
/// contribution, which must have propagated before the death).
pub fn verify_dataflow_surviving(s: &Schedule, alive: &[bool]) -> Result<DataflowProof, VerifyError> {
    dataflow_core(s, Some(alive))
}

fn dataflow_core(s: &Schedule, alive: Option<&[bool]>) -> Result<DataflowProof, VerifyError> {
    let n = s.n;
    let mut cells: Vec<Vec<Cell>> = (0..n)
        .map(|r| (0..s.n_blocks).map(|_| Cell::new(r, n)).collect())
        .collect();
    let mut max_atoms = 1usize;
    for (k, step) in s.steps.iter().enumerate() {
        // Receive barrier: everything sent in step k is computed from the
        // state at the *start* of step k.
        let snap = cells.clone();
        for (src_i, sends) in step.sends.iter().enumerate() {
            let src = src_i as u32;
            for snd in sends {
                let dst = snd.to;
                if dst >= n {
                    return Err(VerifyError::MalformedSend {
                        step: k,
                        src,
                        dst,
                        detail: format!("destination outside the {n}-node torus"),
                    });
                }
                if dst == src {
                    return Err(VerifyError::MalformedSend {
                        step: k,
                        src,
                        dst,
                        detail: "self-send".into(),
                    });
                }
                for piece in &snd.pieces {
                    if piece.blocks.is_empty() {
                        return Err(VerifyError::MalformedSend {
                            step: k,
                            src,
                            dst,
                            detail: "piece addresses no blocks".into(),
                        });
                    }
                    for b in piece.blocks.iter() {
                        if b >= s.n_blocks {
                            return Err(VerifyError::MalformedSend {
                                step: k,
                                src,
                                dst,
                                detail: format!("block {b} out of range ({})", s.n_blocks),
                            });
                        }
                        let sender = &snap[src_i][b as usize];
                        match piece.kind {
                            Kind::Reduce => {
                                if piece.contrib.is_empty() {
                                    return Err(VerifyError::MalformedSend {
                                        step: k,
                                        src,
                                        dst,
                                        detail: "reduce with an empty contribution".into(),
                                    });
                                }
                                if !sender.total.is_superset(&piece.contrib) {
                                    return Err(VerifyError::UnrealizableSend {
                                        step: k,
                                        src,
                                        dst,
                                        block: b,
                                        detail: "sender lacks part of the claimed contribution"
                                            .into(),
                                    });
                                }
                                if !exact_cover(&sender.atoms, &piece.contrib) {
                                    return Err(VerifyError::UnrealizableSend {
                                        step: k,
                                        src,
                                        dst,
                                        block: b,
                                        detail: "contribution is not an exact union of sender \
                                                 atoms (splits an already-reduced sum)"
                                            .into(),
                                    });
                                }
                                let recv = &mut cells[dst as usize][b as usize];
                                if !recv.total.is_disjoint(&piece.contrib) {
                                    let overlap = recv.total.intersect(&piece.contrib).len();
                                    return Err(VerifyError::DoubleCount {
                                        step: k,
                                        src,
                                        dst,
                                        block: b,
                                        overlap,
                                    });
                                }
                                recv.atoms.push(piece.contrib.clone());
                                recv.total.union_with(&piece.contrib);
                                max_atoms = max_atoms.max(recv.atoms.len());
                            }
                            Kind::Set => {
                                if !piece.contrib.is_full(n) {
                                    return Err(VerifyError::MalformedSend {
                                        step: k,
                                        src,
                                        dst,
                                        detail: "Set piece must carry the full contribution"
                                            .into(),
                                    });
                                }
                                if !sender.total.is_full(n) {
                                    return Err(VerifyError::UnrealizableSend {
                                        step: k,
                                        src,
                                        dst,
                                        block: b,
                                        detail: "Set of a block the sender has not finished"
                                            .into(),
                                    });
                                }
                                cells[dst as usize][b as usize] = Cell {
                                    atoms: vec![BlockSet::full(n)],
                                    total: BlockSet::full(n),
                                };
                            }
                        }
                    }
                }
            }
        }
    }
    for (r, row) in cells.iter().enumerate() {
        if alive.is_some_and(|a| !a[r]) {
            continue;
        }
        for (b, cell) in row.iter().enumerate() {
            if !cell.total.is_full(n) {
                return Err(VerifyError::MissingContribution {
                    node: r as u32,
                    block: b as u32,
                    missing: u64::from(n) - cell.total.len(),
                });
            }
        }
    }
    Ok(DataflowProof {
        n,
        n_blocks: s.n_blocks,
        steps: s.num_steps(),
        messages: s.num_messages(),
        max_atoms,
    })
}

/// Resolve a send's nominal route, checking a `Directed` hint
/// structurally first so a corrupt hint becomes a typed error instead of
/// a panic inside [`Torus::route_directed`].
fn resolve_route(t: &Torus, step: usize, src: u32, snd: &Send) -> Result<Vec<Link>, VerifyError> {
    let dst = snd.to;
    if dst >= t.n() {
        return Err(VerifyError::MalformedSend {
            step,
            src,
            dst,
            detail: format!("destination outside the {}-node torus", t.n()),
        });
    }
    if dst == src {
        return Err(VerifyError::MalformedSend { step, src, dst, detail: "self-send".into() });
    }
    match snd.route {
        RouteHint::Minimal => Ok(t.route(src, dst)),
        RouteHint::Directed { dim, dir } => {
            let d = dim as usize;
            if d >= t.ndims() {
                return Err(VerifyError::MalformedSend {
                    step,
                    src,
                    dst,
                    detail: format!("directed hint names dimension {dim} of a {}-dim torus", t.ndims()),
                });
            }
            if dir != 1 && dir != -1 {
                return Err(VerifyError::MalformedSend {
                    step,
                    src,
                    dst,
                    detail: format!("directed hint direction {dir} is not ±1"),
                });
            }
            for other in 0..t.ndims() {
                if other != d && t.coord(src, other) != t.coord(dst, other) {
                    return Err(VerifyError::MalformedSend {
                        step,
                        src,
                        dst,
                        detail: format!(
                            "directed hint (dim {dim}) on a send that also moves in dim {other}"
                        ),
                    });
                }
            }
            Ok(t.route_directed(src, dst, d, dir))
        }
    }
}

/// Per-(node, dim, direction) transmission-port budget of a registry
/// collective on its *native* build: the multiport Bruck family injects
/// up to two messages per port-step by construction; recursive-doubling's
/// bandwidth variant overlaps its reduce-scatter and allgather halves;
/// everything else — Trivance included, which is the paper's
/// one-message-per-port claim — is single-message. Padded builds multiply
/// this by the host multiplicity ([`host_multiplicity`]): co-hosted
/// virtual ranks share the real node's ports.
pub fn port_budget(algo: Algo, variant: Variant) -> u32 {
    match (algo, variant) {
        (Algo::Bruck | Algo::BruckUnidir, _) => 2,
        (Algo::RecDoub, Variant::Bandwidth) => 2,
        _ => 1,
    }
}

/// Largest number of virtual ranks any real node hosts (1 for native
/// builds).
pub fn host_multiplicity(b: &BuiltCollective) -> u32 {
    let Some(p) = &b.padding else { return 1 };
    let mut counts = vec![0u32; b.net.n as usize];
    for &h in &p.hosts {
        counts[h as usize] += 1;
    }
    counts.into_iter().max().unwrap_or(1)
}

/// Result of a passed port audit.
#[derive(Clone, Copy, Debug)]
pub struct PortAudit {
    /// The budget the schedule was checked against.
    pub budget: u32,
    /// Highest observed per-port message count (≤ `budget`).
    pub max_port_msgs: u32,
}

/// Check multiport legality (module docs, analysis 2): in every step, at
/// most `budget` messages leave any (node, dim, direction) first-hop
/// port. Zero-byte sends occupy no port.
pub fn audit_ports(s: &Schedule, t: &Torus, budget: u32) -> Result<PortAudit, VerifyError> {
    let mut counts = vec![0u32; t.num_links()];
    let mut max_used = 0u32;
    for (k, step) in s.steps.iter().enumerate() {
        counts.iter_mut().for_each(|c| *c = 0);
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                if snd.rel_bytes(s.n_blocks) <= 0.0 {
                    continue;
                }
                let route = resolve_route(t, k, src as u32, snd)?;
                // The first hop always leaves `src`: its dense link index
                // *is* the (node, dim, direction) transmission port.
                if let Some(first) = route.first() {
                    counts[t.link_index(*first)] += 1;
                }
            }
        }
        for (idx, &used) in counts.iter().enumerate() {
            max_used = max_used.max(used);
            if used > budget {
                let l = t.link_at(idx);
                return Err(VerifyError::PortOvercommit {
                    step: k,
                    node: l.node,
                    dim: l.dim,
                    dir: l.dir,
                    used,
                    budget,
                });
            }
        }
    }
    Ok(PortAudit { budget, max_port_msgs: max_used })
}

/// Static congestion profile of a schedule (module docs, analysis 3).
#[derive(Clone, Copy, Debug, Default)]
pub struct CongestionAudit {
    /// Σ over steps of the busiest link's relative load — the same
    /// transmission-delay figure [`crate::schedule::analysis`] computes.
    pub tx_delay_rel: f64,
    /// Busiest single (step, link) relative load.
    pub max_link_rel: f64,
    /// Most messages crossing one link in one step.
    pub max_link_msgs: u32,
    /// Mean relative load over loaded (step, link) pairs.
    pub mean_link_rel: f64,
    /// Σ rel_bytes × hops — total relative bytes-on-wire.
    pub bytes_on_wire_rel: f64,
    /// Messages with a nonzero payload.
    pub messages: usize,
}

/// Compute the static per-link load profile of `s` on `t` (nominal
/// minimal/hinted routes, uniform fabric).
pub fn audit_congestion(s: &Schedule, t: &Torus) -> Result<CongestionAudit, VerifyError> {
    let mut loads = vec![0.0f64; t.num_links()];
    let mut counts = vec![0u32; t.num_links()];
    let mut audit = CongestionAudit::default();
    let mut load_sum = 0.0f64;
    let mut loaded_pairs = 0usize;
    for (k, step) in s.steps.iter().enumerate() {
        loads.iter_mut().for_each(|l| *l = 0.0);
        counts.iter_mut().for_each(|c| *c = 0);
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                let rel = snd.rel_bytes(s.n_blocks);
                if rel <= 0.0 {
                    continue;
                }
                let route = resolve_route(t, k, src as u32, snd)?;
                audit.messages += 1;
                audit.bytes_on_wire_rel += rel * route.len() as f64;
                for l in &route {
                    let idx = t.link_index(*l);
                    loads[idx] += rel;
                    counts[idx] += 1;
                }
            }
        }
        let mut step_max = 0.0f64;
        for (&load, &cnt) in loads.iter().zip(&counts) {
            if cnt == 0 {
                continue;
            }
            step_max = step_max.max(load);
            load_sum += load;
            loaded_pairs += 1;
            audit.max_link_msgs = audit.max_link_msgs.max(cnt);
        }
        audit.tx_delay_rel += step_max;
        audit.max_link_rel = audit.max_link_rel.max(step_max);
    }
    if loaded_pairs > 0 {
        audit.mean_link_rel = load_sum / loaded_pairs as f64;
    }
    Ok(audit)
}

/// Latency/bandwidth classification of one collective.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptClass {
    /// Step count ≤ Σ_d ⌈log₃ a_d⌉ — the multiport latency bound.
    Latency,
    /// Max per-node bytes ≤ 2(n−1)/n · m — the AllReduce bandwidth bound.
    Bandwidth,
    Neither,
}

impl OptClass {
    pub fn label(self) -> &'static str {
        match self {
            OptClass::Latency => "latency-optimal",
            OptClass::Bandwidth => "bandwidth-optimal",
            OptClass::Neither => "neither",
        }
    }
}

/// Step-count and bytes-on-wire audit against the paper's lower bounds
/// (module docs, analysis 4).
#[derive(Clone, Copy, Debug)]
pub struct OptAudit {
    pub steps: usize,
    /// Σ_d ⌈log₃ a_d⌉ — the 2-port (triple-fanout) latency lower bound.
    pub lat_bound3: u32,
    /// Σ_d ⌈log₂ a_d⌉ — the classic single-port latency lower bound.
    pub lat_bound2: u32,
    /// Busiest node's total sent bytes, relative to the vector size.
    pub max_node_sent_rel: f64,
    /// 2(n−1)/n — the AllReduce bandwidth lower bound (relative).
    pub bw_lower_rel: f64,
    pub latency_optimal: bool,
    pub bandwidth_optimal: bool,
    /// Latency-optimality wins the label when both bounds are met.
    pub class: OptClass,
}

impl OptAudit {
    /// Gate used by [`certify_registry`] for Trivance-L — exposed so a
    /// step-count regression is a constructible, exactly-typed fixture.
    pub fn require_latency_optimal(&self, name: &str) -> Result<(), VerifyError> {
        if self.latency_optimal {
            Ok(())
        } else {
            Err(VerifyError::StepCountRegression {
                name: name.to_string(),
                steps: self.steps,
                bound: self.lat_bound3,
            })
        }
    }
}

/// Audit step count and per-node traffic against the lower bounds.
pub fn audit_optimality(s: &Schedule, t: &Torus) -> OptAudit {
    let lat_bound3: u32 = t.dims().iter().map(|&a| ceil_log(3, u64::from(a))).sum();
    let lat_bound2: u32 = t.dims().iter().map(|&a| ceil_log(2, u64::from(a))).sum();
    let steps = s.num_steps();
    let max_node_sent_rel =
        (0..t.n()).map(|r| s.node_sent_rel_bytes(r)).fold(0.0f64, f64::max);
    let n = f64::from(t.n());
    let bw_lower_rel = 2.0 * (n - 1.0) / n;
    let latency_optimal = steps as u32 <= lat_bound3;
    let bandwidth_optimal = max_node_sent_rel <= bw_lower_rel + EPS;
    let class = if latency_optimal {
        OptClass::Latency
    } else if bandwidth_optimal {
        OptClass::Bandwidth
    } else {
        OptClass::Neither
    };
    OptAudit {
        steps,
        lat_bound3,
        lat_bound2,
        max_node_sent_rel,
        bw_lower_rel,
        latency_optimal,
        bandwidth_optimal,
        class,
    }
}

/// A full static certificate for one built collective.
#[derive(Clone, Debug)]
pub struct Certificate {
    pub name: String,
    pub algo: Algo,
    pub variant: Variant,
    pub padded: bool,
    /// Proved on the exec schedule (virtual ranks for padded builds).
    pub dataflow: DataflowProof,
    /// Within-step race profile of the exec schedule ([`hazard`]).
    pub hazard: hazard::HazardAudit,
    /// Forward-availability causality holds ([`deadlock`]).
    pub deadlock_ok: bool,
    /// Peak live memory per real node ([`memory`]).
    pub memory: memory::MemoryAudit,
    /// Audited on the net schedule actually shipped to the fabric.
    pub ports: PortAudit,
    pub congestion: CongestionAudit,
    pub optimality: OptAudit,
    /// Symbolic completion-bound coefficients of the net schedule ([`cost`]).
    pub cost: cost::CostCertificate,
}

/// Certify one built collective (module docs): every pass through the
/// pass manager, first `Error` finding propagated as the typed error.
pub fn certify_collective(b: &BuiltCollective, t: &Torus) -> Result<Certificate, VerifyError> {
    certify_collective_timed(b, t).map(|(cert, _)| cert)
}

/// [`certify_collective`] plus the per-pass wall-clock timings of the run.
pub fn certify_collective_timed(
    b: &BuiltCollective,
    t: &Torus,
) -> Result<(Certificate, Vec<passes::PassTiming>), VerifyError> {
    let out = passes::run_passes(b, t, &passes::PASS_NAMES);
    if let Some(e) = out.first_error() {
        return Err(e.clone());
    }
    let timings = out.timings.clone();
    let cert = out.certificate().ok_or_else(|| VerifyError::PlanMismatch {
        detail: format!("pass manager produced no full certificate for {}", b.name),
    })?;
    Ok((cert, timings))
}

/// Certificates for every buildable (algorithm, variant) on one topology.
#[derive(Clone, Debug)]
pub struct RegistryReport {
    pub dims: Vec<u32>,
    pub certs: Vec<Certificate>,
    /// Per-pass wall-clock, summed over every certified build, in
    /// canonical [`passes::PASS_NAMES`] order.
    pub timings: Vec<passes::PassTiming>,
}

impl RegistryReport {
    pub fn find(&self, algo: Algo, variant: Variant) -> Option<&Certificate> {
        self.certs.iter().find(|c| c.algo == algo && c.variant == variant)
    }
}

/// Certify the whole registry on `t` and enforce the paper's gates:
/// Trivance-L must be latency-optimal at Σ⌈log₃⌉ steps, and on rings its
/// transmission delay must be ≤ ⅓ of unidirectional (classic) Bruck and
/// no worse than the bidirectional Bruck port-spread.
pub fn certify_registry(t: &Torus) -> Result<RegistryReport, VerifyError> {
    let mut certs = Vec::new();
    let mut agg = vec![0.0f64; passes::PASS_NAMES.len()];
    for algo in Algo::ALL {
        for variant in Variant::ALL {
            let Ok(b) = build(algo, variant, t) else { continue };
            let (cert, timings) = certify_collective_timed(&b, t)?;
            certs.push(cert);
            for tm in timings {
                if let Some(i) = passes::PASS_NAMES.iter().position(|&p| p == tm.pass) {
                    agg[i] += tm.seconds;
                }
            }
        }
    }
    let timings = passes::PASS_NAMES
        .iter()
        .zip(agg)
        .map(|(&pass, seconds)| passes::PassTiming { pass, seconds })
        .collect();
    let rep = RegistryReport { dims: t.dims().to_vec(), certs, timings };
    if let Some(tri) = rep.find(Algo::Trivance, Variant::Latency) {
        tri.optimality.require_latency_optimal(&tri.name)?;
        if t.ndims() == 1 {
            let tx = tri.congestion.tx_delay_rel;
            if let Some(bu) = rep.find(Algo::BruckUnidir, Variant::Latency) {
                let bound = bu.congestion.tx_delay_rel / 3.0;
                if tx > bound + EPS {
                    return Err(VerifyError::CongestionRegression {
                        detail: format!(
                            "ring {:?}: trivance-L tx_delay {tx} exceeds a third of \
                             unidirectional Bruck ({bound})",
                            rep.dims
                        ),
                    });
                }
            }
            if let Some(br) = rep.find(Algo::Bruck, Variant::Latency) {
                if tx > br.congestion.tx_delay_rel + EPS {
                    return Err(VerifyError::CongestionRegression {
                        detail: format!(
                            "ring {:?}: trivance-L tx_delay {tx} exceeds bidirectional \
                             Bruck ({})",
                            rep.dims, br.congestion.tx_delay_rel
                        ),
                    });
                }
            }
        }
    }
    Ok(rep)
}

/// Result of a passed plan audit.
#[derive(Clone, Copy, Debug)]
pub struct PlanAudit {
    pub messages: usize,
    /// Most messages injected through one (node, dim, direction) port in
    /// one step (reported, not gated: detoured/staged plans legitimately
    /// exceed the native budget).
    pub max_port_msgs: u32,
}

/// Audit a compiled [`SimPlan`] against its topology: every route must be
/// a connected src→dst chain of valid dense links (a zero-hop route is
/// only legal for a co-located src/dst pair), and every message's step
/// must exist. This is the last line before the simulators consume the
/// plan — rewrites, staged fault responses and collapsed padded builds
/// all pass through here in the test suite.
pub fn verify_plan(plan: &SimPlan, t: &Torus) -> Result<PlanAudit, VerifyError> {
    if plan.n() != t.n() as usize {
        return Err(VerifyError::PlanMismatch {
            detail: format!("plan has {} nodes, torus has {}", plan.n(), t.n()),
        });
    }
    if plan.num_links() != t.num_links() {
        return Err(VerifyError::PlanMismatch {
            detail: format!("plan has {} links, torus has {}", plan.num_links(), t.num_links()),
        });
    }
    let steps = plan.num_steps();
    let mut ports = vec![0u32; steps * t.num_links()];
    let mut max_port_msgs = 0u32;
    for i in 0..plan.num_msgs() {
        let m = plan.msg(i);
        if m.step as usize >= steps {
            return Err(VerifyError::PlanMismatch {
                detail: format!("message {i} claims step {} of {steps}", m.step),
            });
        }
        let route = plan.route(i);
        if route.is_empty() {
            if m.src != m.dst {
                return Err(VerifyError::BrokenRoute {
                    msg: i,
                    hop: 0,
                    detail: format!("empty route for {}->{}", m.src, m.dst),
                });
            }
            continue;
        }
        let mut cur = m.src;
        for (hop, &li) in route.iter().enumerate() {
            let li = li as usize;
            if li >= t.num_links() {
                return Err(VerifyError::BrokenRoute {
                    msg: i,
                    hop,
                    detail: format!("link index {li} out of range"),
                });
            }
            let l = t.link_at(li);
            if l.node != cur {
                return Err(VerifyError::BrokenRoute {
                    msg: i,
                    hop,
                    detail: format!("chain discontinuity: at node {cur}, link leaves {}", l.node),
                });
            }
            cur = t.neighbor(cur, l.dim as usize, i64::from(l.dir));
        }
        if cur != m.dst {
            return Err(VerifyError::BrokenRoute {
                msg: i,
                hop: route.len(),
                detail: format!("route ends at {cur}, message is for {}", m.dst),
            });
        }
        let port = &mut ports[m.step as usize * t.num_links() + route[0] as usize];
        *port += 1;
        max_port_msgs = max_port_msgs.max(*port);
    }
    Ok(PlanAudit { messages: plan.num_msgs(), max_port_msgs })
}

/// Render one registry report as the `trivance verify` table.
pub fn render_report(rep: &RegistryReport) -> String {
    let n: u32 = rep.dims.iter().product();
    let mut table = fmt::Table::new(vec![
        "collective",
        "steps",
        "lb3",
        "lb2",
        "sent/m",
        "bw-lb",
        "ports",
        "budget",
        "tx-rel",
        "max-atoms",
        "class",
    ]);
    for c in &rep.certs {
        table.row(vec![
            c.name.clone(),
            c.optimality.steps.to_string(),
            c.optimality.lat_bound3.to_string(),
            c.optimality.lat_bound2.to_string(),
            format!("{:.4}", c.optimality.max_node_sent_rel),
            format!("{:.4}", c.optimality.bw_lower_rel),
            c.ports.max_port_msgs.to_string(),
            c.ports.budget.to_string(),
            format!("{:.3}", c.congestion.tx_delay_rel),
            c.dataflow.max_atoms.to_string(),
            c.optimality.class.label().to_string(),
        ]);
    }
    format!(
        "topology {:?} ({n} nodes): {} collectives certified (dataflow exact, ports legal)\n{}",
        rep.dims,
        rep.certs.len(),
        table.render()
    )
}

/// Hand-rolled `VERIFY_report.json` (schema `trivance.verify.v2`) — the
/// CI artifact; parseable by [`crate::util::json`] and validated by
/// `tools/check_verify_report.py`. Every v1 field is preserved under its
/// v1 name; v2 adds the hazard/deadlock/memory/cost fields per cert and
/// a top-level `passes` array with per-pass wall-clock seconds summed
/// over every report.
pub fn report_json(reports: &[RegistryReport]) -> String {
    let mut out = String::from("{\n  \"schema\": \"trivance.verify.v2\",\n  \"passes\": [\n");
    for (i, &pass) in passes::PASS_NAMES.iter().enumerate() {
        let seconds: f64 = reports
            .iter()
            .flat_map(|r| &r.timings)
            .filter(|tm| tm.pass == pass)
            .map(|tm| tm.seconds)
            .sum();
        out.push_str(&format!(
            "    {{\"name\": \"{pass}\", \"seconds\": {seconds}}}{}\n",
            if i + 1 < passes::PASS_NAMES.len() { "," } else { "" }
        ));
    }
    out.push_str("  ],\n  \"topos\": [\n");
    for (ti, rep) in reports.iter().enumerate() {
        let dims: Vec<String> = rep.dims.iter().map(u32::to_string).collect();
        out.push_str(&format!("    {{\"dims\": [{}], \"certs\": [\n", dims.join(", ")));
        for (ci, c) in rep.certs.iter().enumerate() {
            out.push_str(&format!(
                "      {{\"collective\": \"{}\", \"algo\": \"{}\", \"variant\": \"{}\", \
                 \"padded\": {}, \"steps\": {}, \"lat_bound3\": {}, \"lat_bound2\": {}, \
                 \"max_node_sent_rel\": {}, \"bw_lower_rel\": {}, \"port_budget\": {}, \
                 \"max_port_msgs\": {}, \"tx_delay_rel\": {}, \"max_link_rel\": {}, \
                 \"mean_link_rel\": {}, \"max_link_msgs\": {}, \"bytes_on_wire_rel\": {}, \
                 \"messages\": {}, \"max_atoms\": {}, \"hazard_war_cells\": {}, \
                 \"hazard_waw_conflicts\": {}, \"barrier_free\": {}, \"deadlock_ok\": {}, \
                 \"mem_peak_rel\": {}, \"mem_in_rel_max\": {}, \"cost_steps\": {}, \
                 \"cost_tx_rel\": {}, \"cost_hop_lat_rel\": {}, \"cost_hop_proc_rel\": {}, \
                 \"class\": \"{}\"}}{}\n",
                json::escape(&c.name),
                c.algo.label(),
                c.variant.label(),
                c.padded,
                c.optimality.steps,
                c.optimality.lat_bound3,
                c.optimality.lat_bound2,
                c.optimality.max_node_sent_rel,
                c.optimality.bw_lower_rel,
                c.ports.budget,
                c.ports.max_port_msgs,
                c.congestion.tx_delay_rel,
                c.congestion.max_link_rel,
                c.congestion.mean_link_rel,
                c.congestion.max_link_msgs,
                c.congestion.bytes_on_wire_rel,
                c.congestion.messages,
                c.dataflow.max_atoms,
                c.hazard.war_cells,
                c.hazard.waw_conflicts,
                c.hazard.barrier_free,
                c.deadlock_ok,
                c.memory.peak_live_rel,
                c.memory.in_rel_max,
                c.cost.steps,
                c.cost.tx_rel,
                c.cost.hop_lat_rel,
                c.cost.hop_proc_rel,
                c.optimality.class.label(),
                if ci + 1 < rep.certs.len() { "," } else { "" },
            ));
        }
        out.push_str(&format!(
            "    ]}}{}\n",
            if ti + 1 < reports.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Piece;

    /// Ring-3, one block, one step: every node reduces its own
    /// contribution into both neighbors — a minimal complete AllReduce.
    fn tiny_valid() -> Schedule {
        let n = 3u32;
        let mut s = Schedule::new("tiny", n, 1);
        let step = s.push_step();
        for r in 0..n {
            for d in [1i64, -1] {
                let to = (r as i64 + d).rem_euclid(n as i64) as u32;
                step.push(
                    r,
                    Send {
                        to,
                        pieces: vec![Piece {
                            blocks: BlockSet::singleton(0, 1),
                            contrib: BlockSet::singleton(r, n),
                            kind: Kind::Reduce,
                        }],
                        route: RouteHint::Minimal,
                    },
                );
            }
        }
        s
    }

    #[test]
    fn tiny_schedule_proves_and_certifies() {
        let s = tiny_valid();
        let proof = verify_dataflow(&s).unwrap();
        assert_eq!(proof.steps, 1);
        assert_eq!(proof.messages, 6);
        let t = Torus::ring(3);
        let ports = audit_ports(&s, &t, 1).unwrap();
        assert_eq!(ports.max_port_msgs, 1, "one message per direction port");
        let cong = audit_congestion(&s, &t).unwrap();
        assert_eq!(cong.messages, 6);
        assert!((cong.tx_delay_rel - 1.0).abs() < EPS, "{}", cong.tx_delay_rel);
    }

    // ── golden known-bad fixtures: one per defect class, asserting the
    //    exact typed error (ISSUE 7 satellite) ────────────────────────────

    #[test]
    fn golden_missing_contribution_is_typed() {
        // drop node 2's send to node 0: node 0 never sees contribution 2
        let mut s = tiny_valid();
        s.steps[0].sends[2].retain(|snd| snd.to != 0);
        let err = verify_dataflow(&s).unwrap_err();
        assert_eq!(
            err,
            VerifyError::MissingContribution { node: 0, block: 0, missing: 1 },
            "{err}"
        );
    }

    #[test]
    fn golden_double_count_is_typed() {
        // node 2 ships its contribution to node 0 twice in the same step
        let mut s = tiny_valid();
        let dup = s.steps[0].sends[2].iter().find(|snd| snd.to == 0).unwrap().clone();
        s.steps[0].sends[2].push(dup);
        let err = verify_dataflow(&s).unwrap_err();
        assert_eq!(
            err,
            VerifyError::DoubleCount { step: 0, src: 2, dst: 0, block: 0, overlap: 1 },
            "{err}"
        );
    }

    #[test]
    fn golden_unrealizable_send_is_typed() {
        // node 0 claims to ship node 1's contribution, which it never had
        let mut s = tiny_valid();
        s.steps[0].sends[0][0].pieces[0].contrib = BlockSet::singleton(1, 3);
        let err = verify_dataflow(&s).unwrap_err();
        match err {
            VerifyError::UnrealizableSend { step: 0, src: 0, block: 0, .. } => {}
            other => panic!("expected UnrealizableSend, got {other} ({other:?})"),
        }
    }

    #[test]
    fn golden_split_atom_is_unrealizable() {
        // node 2 → node 1 ({2}); node 1 → node 0 ({1,2}, which lands as
        // ONE reduced atom); node 0 then tries to ship only {1} out of
        // that atom — contributions reduced together cannot be separated
        let n = 3u32;
        let reduce = |to: u32, contrib: &[u32]| Send {
            to,
            pieces: vec![Piece {
                blocks: BlockSet::singleton(0, 1),
                contrib: BlockSet::from_ranks(contrib, n),
                kind: Kind::Reduce,
            }],
            route: RouteHint::Minimal,
        };
        let mut s = Schedule::new("split-atom", n, 1);
        s.push_step().push(2, reduce(1, &[2]));
        s.push_step().push(1, reduce(0, &[1, 2]));
        s.push_step().push(0, reduce(2, &[1]));
        let err = verify_dataflow(&s).unwrap_err();
        match err {
            VerifyError::UnrealizableSend { step: 2, src: 0, dst: 2, .. } => {}
            other => panic!("expected a split-atom UnrealizableSend, got {other:?}"),
        }
    }

    #[test]
    fn golden_port_overcommit_is_typed() {
        // two blocks: node 0 sends each block to node 1 as a separate
        // message in one step — dataflow-legal, but both leave the same
        // (node 0, dim 0, +1) port
        let n = 3u32;
        let mut s = Schedule::new("overcommit", n, 2);
        let step = s.push_step();
        for b in 0..2u32 {
            step.push(
                0,
                Send {
                    to: 1,
                    pieces: vec![Piece {
                        blocks: BlockSet::singleton(b, 2),
                        contrib: BlockSet::singleton(0, n),
                        kind: Kind::Reduce,
                    }],
                    route: RouteHint::Minimal,
                },
            );
        }
        let t = Torus::ring(3);
        let err = audit_ports(&s, &t, 1).unwrap_err();
        assert_eq!(
            err,
            VerifyError::PortOvercommit { step: 0, node: 0, dim: 0, dir: 1, used: 2, budget: 1 },
            "{err}"
        );
        // with a 2-port budget the same schedule is legal
        assert_eq!(audit_ports(&s, &t, 2).unwrap().max_port_msgs, 2);
    }

    #[test]
    fn golden_step_count_regression_is_typed() {
        // a ring-3 schedule taking 2 steps where ⌈log₃ 3⌉ = 1 suffices:
        // tiny_valid stretched by an idle-free extra exchange
        let mut s = tiny_valid();
        let extra = s.steps[0].clone();
        // second step re-reduces everything — dataflow-invalid, but the
        // optimality audit is purely structural
        s.steps.push(extra);
        let t = Torus::ring(3);
        let audit = audit_optimality(&s, &t);
        assert_eq!(audit.lat_bound3, 1);
        assert!(!audit.latency_optimal);
        let err = audit.require_latency_optimal("tiny-slow").unwrap_err();
        assert_eq!(
            err,
            VerifyError::StepCountRegression { name: "tiny-slow".into(), steps: 2, bound: 1 },
            "{err}"
        );
    }

    #[test]
    fn golden_corrupt_directed_hint_is_malformed_not_a_panic() {
        let mut s = tiny_valid();
        // dimension 3 does not exist on a ring
        s.steps[0].sends[0][0].route = RouteHint::Directed { dim: 3, dir: 1 };
        let t = Torus::ring(3);
        match audit_ports(&s, &t, 1).unwrap_err() {
            VerifyError::MalformedSend { step: 0, src: 0, .. } => {}
            other => panic!("expected MalformedSend, got {other:?}"),
        }
    }

    #[test]
    fn survivor_aware_dataflow_skips_dead_ranks() {
        // drop every send *to* node 2 (it died): full verification fails
        // with a missing contribution at node 2, survivor-aware passes
        let mut s = tiny_valid();
        for sends in &mut s.steps[0].sends {
            sends.retain(|snd| snd.to != 2);
        }
        match verify_dataflow(&s).unwrap_err() {
            VerifyError::MissingContribution { node: 2, .. } => {}
            other => panic!("expected node 2 incomplete, got {other:?}"),
        }
        let alive = [true, true, false];
        verify_dataflow_surviving(&s, &alive).unwrap();
    }

    #[test]
    fn report_json_is_parseable() {
        let rep = certify_registry(&Torus::ring(3)).unwrap();
        let doc = report_json(std::slice::from_ref(&rep));
        let v = json::parse(&doc).unwrap_or_else(|e| panic!("{e}\n{doc}"));
        assert_eq!(v.get("schema").unwrap().as_str(), Some("trivance.verify.v2"));
        let ps = v.get("passes").unwrap().as_arr().unwrap();
        assert_eq!(ps.len(), passes::PASS_NAMES.len());
        assert_eq!(ps[0].get("name").unwrap().as_str(), Some("dataflow"));
        let topos = v.get("topos").unwrap().as_arr().unwrap();
        assert_eq!(topos.len(), 1);
        let certs = topos[0].get("certs").unwrap().as_arr().unwrap();
        assert_eq!(certs.len(), rep.certs.len());
        assert!(certs[0].get("class").unwrap().as_str().is_some());
        // v2 fields are present on every cert
        for c in certs {
            assert!(c.get("deadlock_ok").is_some());
            assert!(c.get("mem_peak_rel").is_some());
            assert!(c.get("cost_tx_rel").is_some());
            assert!(c.get("hazard_waw_conflicts").is_some());
        }
    }
}
