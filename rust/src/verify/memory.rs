//! Memory certification: peak live bytes per real node per step, against
//! a per-variant certified bound.
//!
//! Under receive-barrier execution a node holds, at any step, one
//! full-vector accumulator per hosted virtual rank **plus** every
//! incoming buffer landing that step (incoming data cannot be folded into
//! the accumulator until the step's barrier). [`audit_memory`] walks the
//! exec schedule and reports the peak of that live set, in units of the
//! vector size `m`, folded onto real nodes through the padding host map.
//!
//! The audit also reports `in_rel_max` — the largest incoming relative
//! payload any *virtual* rank sees in one step. Latency schedules may
//! land several full vectors in a single message (merged concurrent
//! dim-slices: trivance-L on a cube receives rel 3.0 per message, 18.0
//! per rank-step), so the certified bound is on **bytes**, never message
//! counts:
//!
//! * bandwidth (`B`) variants: `2·hm` — the in-place streaming invariant:
//!   each hosted rank's incoming partial blocks never exceed one extra
//!   full vector;
//! * latency (`L`) variants: `hm·(1 + in_rel_max)` — each hosted rank
//!   buffers at most the per-virtual incoming maximum on top of its
//!   accumulator.
//!
//! (`hm` = host multiplicity, [`super::host_multiplicity`].) Exceeding
//! the bound is a typed [`VerifyError::MemoryRegression`]; the pinned
//! per-collective peaks live in `tools/pysim/eval_passes.py`.

use super::{host_multiplicity, VerifyError, EPS};
use crate::algo::{BuiltCollective, Variant};
use crate::schedule::Schedule;

/// Peak-live-memory profile of one (possibly padded) exec schedule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MemoryAudit {
    /// Peak live data on any real node, in units of `m`.
    pub peak_live_rel: f64,
    /// Real node reaching the peak.
    pub peak_node: u32,
    /// Step of the peak (`None` when the accumulators alone are the peak,
    /// i.e. no step's incoming traffic raised it).
    pub peak_step: Option<usize>,
    /// Max incoming relative payload of any (virtual rank, step).
    pub in_rel_max: f64,
}

/// Measure peak live rel-bytes per real node per step (module docs).
/// `hosts` maps virtual ranks to real nodes for padded builds (`None` =
/// identity), `n_real` is the real torus size.
pub fn audit_memory(s: &Schedule, hosts: Option<&[u32]>, n_real: u32) -> MemoryAudit {
    let nr = n_real as usize;
    let real = |v: usize| -> usize {
        match hosts {
            Some(h) => h[v] as usize,
            None => v,
        }
    };
    // one full-vector accumulator per hosted virtual rank
    let mut base = vec![0.0f64; nr];
    for v in 0..s.n as usize {
        base[real(v)] += 1.0;
    }
    let mut peak = 0.0f64;
    let mut peak_node = 0usize;
    for (r, &b) in base.iter().enumerate() {
        if b > peak {
            peak = b;
            peak_node = r;
        }
    }
    let mut peak_step = None;
    let mut in_rel_max = 0.0f64;
    let mut incoming = vec![0.0f64; nr];
    let mut in_rel = vec![0.0f64; s.n as usize];
    for (k, step) in s.steps.iter().enumerate() {
        incoming.fill(0.0);
        in_rel.fill(0.0);
        for sends in &step.sends {
            for snd in sends {
                if (snd.to as usize) >= s.n as usize {
                    continue; // dataflow reports these as MalformedSend
                }
                let rel = snd.rel_bytes(s.n_blocks);
                incoming[real(snd.to as usize)] += rel;
                in_rel[snd.to as usize] += rel;
            }
        }
        in_rel_max = in_rel.iter().fold(in_rel_max, |a, &b| a.max(b));
        for (r, &inc) in incoming.iter().enumerate() {
            let live = base[r] + inc;
            if live > peak {
                peak = live;
                peak_node = r;
                peak_step = Some(k);
            }
        }
    }
    MemoryAudit { peak_live_rel: peak, peak_node: peak_node as u32, peak_step, in_rel_max }
}

/// The per-variant certified peak bound (module docs): `2·hm` for
/// bandwidth variants, `hm·(1 + in_rel_max)` for latency variants.
pub fn certified_bound(b: &BuiltCollective, mem: &MemoryAudit) -> f64 {
    let hm = f64::from(host_multiplicity(b));
    match b.variant {
        Variant::Bandwidth => 2.0 * hm,
        Variant::Latency => hm * (1.0 + mem.in_rel_max),
    }
}

/// Gate a measured peak against its certified bound.
pub fn require_peak_within(mem: &MemoryAudit, bound: f64) -> Result<(), VerifyError> {
    if mem.peak_live_rel > bound + EPS {
        return Err(VerifyError::MemoryRegression {
            node: mem.peak_node,
            step: mem.peak_step.unwrap_or(0),
            peak_rel: mem.peak_live_rel,
            bound_rel: bound,
        });
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockset::BlockSet;
    use crate::schedule::{Kind, Piece, RouteHint, Send};

    fn full_reduce(to: u32, contrib: u32, n: u32) -> Send {
        Send {
            to,
            pieces: vec![Piece {
                blocks: BlockSet::full(n),
                contrib: BlockSet::singleton(contrib, n),
                kind: Kind::Reduce,
            }],
            route: RouteHint::Minimal,
        }
    }

    #[test]
    fn two_full_vectors_into_one_node_peak_at_three() {
        // node 0's accumulator (1.0) + two incoming full vectors
        let mut s = Schedule::new("m", 3, 3);
        let st = s.push_step();
        st.push(1, full_reduce(0, 1, 3));
        st.push(2, full_reduce(0, 2, 3));
        let mem = audit_memory(&s, None, 3);
        assert!((mem.peak_live_rel - 3.0).abs() < 1e-12, "{}", mem.peak_live_rel);
        assert_eq!(mem.peak_node, 0);
        assert_eq!(mem.peak_step, Some(0));
        assert!((mem.in_rel_max - 2.0).abs() < 1e-12);
    }

    #[test]
    fn host_map_folds_virtual_peaks_onto_real_nodes() {
        // virtual ranks 0 and 3 co-hosted on real node 0: base 2.0, and
        // an incoming full vector at virtual 3 lands on real 0
        let mut s = Schedule::new("pad", 4, 4);
        s.push_step().push(1, full_reduce(3, 1, 4));
        let hosts = [0u32, 1, 2, 0];
        let mem = audit_memory(&s, Some(&hosts), 3);
        assert!((mem.peak_live_rel - 3.0).abs() < 1e-12, "{}", mem.peak_live_rel);
        assert_eq!(mem.peak_node, 0);
    }

    #[test]
    fn golden_memory_regression_is_typed() {
        let mut s = Schedule::new("m", 3, 3);
        let st = s.push_step();
        st.push(1, full_reduce(0, 1, 3));
        st.push(2, full_reduce(0, 2, 3));
        let mem = audit_memory(&s, None, 3);
        // against an (artificially tight) bound of one accumulator the
        // peak regresses with exact typed coordinates
        match require_peak_within(&mem, 1.0) {
            Err(VerifyError::MemoryRegression { node: 0, step: 0, peak_rel, bound_rel }) => {
                assert!((peak_rel - 3.0).abs() < 1e-12);
                assert!((bound_rel - 1.0).abs() < 1e-12);
            }
            other => panic!("expected MemoryRegression at node 0 step 0, got {other:?}"),
        }
        require_peak_within(&mem, 3.0).unwrap();
    }
}
