//! Mutation testing of the static verifier: seeded schedule corruptors
//! whose mutants the verifier must kill.
//!
//! A verifier that accepts everything is worse than none — it converts
//! real defects into green checkmarks. This module proves the analyses
//! in [`super`] have teeth by corrupting known-good registry schedules
//! in five ways and checking each mutant is rejected by the hazard,
//! dataflow or port analysis:
//!
//! - **drop-a-send**: remove one payload-carrying message → some rank
//!   must end incomplete ([`VerifyError::MissingContribution`]).
//! - **swap-contributors**: cyclically shift one Reduce piece's
//!   contribution set → the sender no longer holds exactly that set, or
//!   the receiver double-counts.
//! - **duplicate-a-reduce**: inject a verbatim copy of a Reduce-carrying
//!   send → the duplicate's contribution lands twice
//!   ([`VerifyError::DoubleCount`]).
//! - **shift-a-port**: flip one send onto the opposite-direction port by
//!   replacing its route hint with an anti-natural `Directed` hint.
//!   Applied to Trivance only: the paper's both-ports-busy property means
//!   any wrongly-ported message collides with the traffic already on that
//!   port ([`VerifyError::PortOvercommit`]). On single-message-per-step
//!   schedules (Bucket, the halving-trees' latency variants) and on the
//!   2-port Bruck family the flipped send is a *legal equivalent
//!   schedule*, not a defect — measured in `tools/pysim` before pinning
//!   this scope.
//! - **inject-hazard**: append a `Set` landing in a (rank, block) cell
//!   that already absorbs a Reduce the same step — a WAW race under any
//!   in-step reordering, which only [`super::hazard`] can see (the
//!   dataflow lattice replays sends in a fixed order and may still
//!   complete). Proves the hazard pass pulls its weight in the kill
//!   chain.
//!
//! Each class's seeding scope is part of the contract
//! ([`MutationKind::scope`], rendered in the kill report) so a 100% kill
//! rate is never overstated: shift-a-port's Trivance-only restriction is
//! a statement about where a flipped port IS a defect, not a blind spot.
//!
//! Mutation targets are the registry's *native* builds (`net == exec`);
//! padded builds collapse virtual ranks onto hosts, so a real-rank mutant
//! would conflate verifier soundness with padding semantics. The runner
//! is fully seeded ([`SplitMix64`]) and the acceptance gate
//! (`trivance verify --mutants`, `rust/tests/verify_static.rs`) requires
//! ≥ 95% kills; the pinned pysim measurement is 100% (944/944 across
//! ring-8/ring-9/3×3).

use super::hazard::first_waw;
use super::{audit_ports, port_budget, verify_dataflow, VerifyError};
use crate::blockset::BlockSet;
use crate::schedule::{Piece, Send};
use crate::algo::{build, Algo, Variant};
use crate::schedule::{Kind, RouteHint, Schedule};
use crate::topology::Torus;
use crate::util::{fmt, SplitMix64};

/// The five seeded corruption classes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MutationKind {
    DropSend,
    SwapContributors,
    DuplicateReduce,
    ShiftPort,
    InjectHazard,
}

impl MutationKind {
    pub const ALL: [MutationKind; 5] = [
        MutationKind::DropSend,
        MutationKind::SwapContributors,
        MutationKind::DuplicateReduce,
        MutationKind::ShiftPort,
        MutationKind::InjectHazard,
    ];

    pub fn label(self) -> &'static str {
        match self {
            MutationKind::DropSend => "drop-a-send",
            MutationKind::SwapContributors => "swap-contributors",
            MutationKind::DuplicateReduce => "duplicate-a-reduce",
            MutationKind::ShiftPort => "shift-a-port",
            MutationKind::InjectHazard => "inject-hazard",
        }
    }

    /// Where this corruptor is seeded, and why (module docs) — rendered
    /// in the kill report so the scope is part of the published contract.
    pub fn scope(self) -> &'static str {
        match self {
            MutationKind::ShiftPort => {
                "trivance only: on single-message schedules and the 2-port Bruck \
                 family the flipped port is a legal routing equivalent, so the \
                 mutant is not a defect there"
            }
            _ => "all native builds",
        }
    }
}

/// Address of one mutation site: `(step, src, send index, aux)` where
/// `aux` is the piece index (swap) or the movement dimension (shift).
#[derive(Clone, Copy, Debug)]
struct Site {
    step: usize,
    src: usize,
    idx: usize,
    aux: usize,
}

/// Enumerate every site where `kind` can be applied to `s` on `t`.
fn sites(s: &Schedule, t: &Torus, kind: MutationKind) -> Vec<Site> {
    let mut out = Vec::new();
    for (step, st) in s.steps.iter().enumerate() {
        for (src, sends) in st.sends.iter().enumerate() {
            for (idx, snd) in sends.iter().enumerate() {
                match kind {
                    MutationKind::DropSend => {
                        if snd.rel_bytes(s.n_blocks) > 0.0 {
                            out.push(Site { step, src, idx, aux: 0 });
                        }
                    }
                    MutationKind::SwapContributors => {
                        for (aux, p) in snd.pieces.iter().enumerate() {
                            let len = p.contrib.len();
                            if p.kind == Kind::Reduce && len > 0 && len < u64::from(s.n) {
                                out.push(Site { step, src, idx, aux });
                            }
                        }
                    }
                    MutationKind::DuplicateReduce => {
                        if snd
                            .pieces
                            .iter()
                            .any(|p| p.kind == Kind::Reduce && !p.contrib.is_empty())
                        {
                            out.push(Site { step, src, idx, aux: 0 });
                        }
                    }
                    MutationKind::ShiftPort => {
                        if snd.rel_bytes(s.n_blocks) <= 0.0 || snd.to as usize == src {
                            continue;
                        }
                        let diff: Vec<usize> = (0..t.ndims())
                            .filter(|&d| t.coord(src as u32, d) != t.coord(snd.to, d))
                            .collect();
                        if let [d] = diff[..] {
                            out.push(Site { step, src, idx, aux: d });
                        }
                    }
                    MutationKind::InjectHazard => {
                        if snd.rel_bytes(s.n_blocks) <= 0.0 {
                            continue;
                        }
                        for p in &snd.pieces {
                            if p.kind == Kind::Reduce {
                                if let Some(b) = p.blocks.iter().next() {
                                    out.push(Site { step, src, idx, aux: b as usize });
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Apply one mutation, returning the corrupted clone.
fn apply(s: &Schedule, t: &Torus, kind: MutationKind, site: Site) -> Schedule {
    let mut m = s.clone();
    let sends = &mut m.steps[site.step].sends[site.src];
    match kind {
        MutationKind::DropSend => {
            sends.remove(site.idx);
        }
        MutationKind::SwapContributors => {
            let p = &mut sends[site.idx].pieces[site.aux];
            p.contrib = p.contrib.shift(1, s.n);
        }
        MutationKind::DuplicateReduce => {
            let dup = sends[site.idx].clone();
            sends.push(dup);
        }
        MutationKind::ShiftPort => {
            let snd = &mut sends[site.idx];
            // natural direction = the first hop of the minimal route;
            // force the opposite port
            let nat = t.route(site.src as u32, snd.to)[0].dir;
            snd.route = RouteHint::Directed { dim: site.aux as u8, dir: -nat };
        }
        MutationKind::InjectHazard => {
            // land a Set into a cell a Reduce already writes this step
            let to = sends[site.idx].to;
            sends.push(Send {
                to,
                pieces: vec![Piece {
                    blocks: BlockSet::singleton(site.aux as u32, s.n_blocks),
                    contrib: BlockSet::full(s.n),
                    kind: Kind::Set,
                }],
                route: RouteHint::Minimal,
            });
        }
    }
    m
}

/// Per-class kill tally.
#[derive(Clone, Copy, Debug)]
pub struct ClassKill {
    pub kind: MutationKind,
    pub total: usize,
    pub killed: usize,
}

/// Outcome of one mutation-suite run.
#[derive(Clone, Debug)]
pub struct KillReport {
    pub per_class: Vec<ClassKill>,
    /// Human-readable descriptions of every surviving mutant (empty when
    /// the verifier is sound on the swept corpus).
    pub survivors: Vec<String>,
}

impl KillReport {
    pub fn total(&self) -> usize {
        self.per_class.iter().map(|c| c.total).sum()
    }

    pub fn killed(&self) -> usize {
        self.per_class.iter().map(|c| c.killed).sum()
    }

    pub fn kill_rate(&self) -> f64 {
        let total = self.total();
        if total == 0 {
            return 1.0;
        }
        self.killed() as f64 / total as f64
    }

    /// Render the per-class table plus the total, for `verify --mutants`.
    pub fn render(&self) -> String {
        let mut table = fmt::Table::new(vec!["mutation", "mutants", "killed", "rate"]);
        for c in &self.per_class {
            table.row(vec![
                c.kind.label().to_string(),
                c.total.to_string(),
                c.killed.to_string(),
                format!("{:.1}%", 100.0 * c.killed as f64 / c.total.max(1) as f64),
            ]);
        }
        let mut out = table.render();
        out.push_str(&format!(
            "\ntotal: {}/{} killed ({:.1}%)\n",
            self.killed(),
            self.total(),
            100.0 * self.kill_rate()
        ));
        for s in &self.survivors {
            out.push_str(&format!("SURVIVED: {s}\n"));
        }
        out.push_str("\nseeding scope:\n");
        for kind in MutationKind::ALL {
            out.push_str(&format!("  {}: {}\n", kind.label(), kind.scope()));
        }
        out
    }
}

/// Would the verifier reject this mutant? Hazard first (a WAW race is a
/// defect even when the fixed-order lattice replay happens to complete),
/// then dataflow, then port legality at the native budget.
fn killed_by_verifier(m: &Schedule, t: &Torus, budget: u32) -> Option<VerifyError> {
    if let Some(e) = first_waw(m) {
        return Some(e);
    }
    if let Err(e) = verify_dataflow(m) {
        return Some(e);
    }
    audit_ports(m, t, budget).err()
}

/// Run the seeded suite: for every native registry build on every topo,
/// draw up to `per_class` sites per mutation class and check the verifier
/// kills each mutant. Deterministic for a fixed `seed`.
pub fn run_mutation_suite(topos: &[Torus], seed: u64, per_class: usize) -> KillReport {
    let mut per: Vec<ClassKill> =
        MutationKind::ALL.iter().map(|&kind| ClassKill { kind, total: 0, killed: 0 }).collect();
    let mut survivors = Vec::new();
    for t in topos {
        for (ai, algo) in Algo::ALL.into_iter().enumerate() {
            for (vi, variant) in Variant::ALL.into_iter().enumerate() {
                let Ok(b) = build(algo, variant, t) else { continue };
                if b.padded {
                    continue; // mutation targets are native builds only
                }
                let budget = port_budget(algo, variant);
                let mut rng = SplitMix64::new(
                    seed ^ (u64::from(t.n()) * 131 + ai as u64 * 7 + vi as u64),
                );
                for (ki, &kind) in MutationKind::ALL.iter().enumerate() {
                    if kind == MutationKind::ShiftPort && algo != Algo::Trivance {
                        continue; // legal equivalent mutants elsewhere (module docs)
                    }
                    let ss = sites(&b.net, t, kind);
                    if ss.is_empty() {
                        continue;
                    }
                    for _ in 0..per_class.min(ss.len()) {
                        let site = ss[rng.below(ss.len() as u64) as usize];
                        let mutant = apply(&b.net, t, kind, site);
                        per[ki].total += 1;
                        match killed_by_verifier(&mutant, t, budget) {
                            Some(_) => per[ki].killed += 1,
                            None => survivors.push(format!(
                                "{} {:?} {} at {site:?}",
                                b.name,
                                t.dims(),
                                kind.label()
                            )),
                        }
                    }
                }
            }
        }
    }
    KillReport { per_class: per, survivors }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_class_enumerates_sites_on_trivance_ring9() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        for kind in MutationKind::ALL {
            assert!(
                !sites(&b.net, &t, kind).is_empty(),
                "{}: no sites on trivance-L ring-9",
                kind.label()
            );
        }
    }

    #[test]
    fn mutants_differ_from_the_original() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        for kind in MutationKind::ALL {
            let site = sites(&b.net, &t, kind)[0];
            let m = apply(&b.net, &t, kind, site);
            let identical = m.num_messages() == b.net.num_messages()
                && m.steps.iter().zip(&b.net.steps).all(|(a, c)| a.sends == c.sends);
            assert!(!identical, "{}: mutant identical to original", kind.label());
        }
    }

    #[test]
    fn inject_hazard_mutants_are_typed_waw_kills() {
        let t = Torus::ring(9);
        let b = build(Algo::Trivance, Variant::Latency, &t).unwrap();
        let site = sites(&b.net, &t, MutationKind::InjectHazard)[0];
        let m = apply(&b.net, &t, MutationKind::InjectHazard, site);
        assert!(matches!(first_waw(&m), Some(VerifyError::WriteHazard { .. })));
        let budget = port_budget(Algo::Trivance, Variant::Latency);
        assert!(killed_by_verifier(&m, &t, budget).is_some());
    }

    #[test]
    fn ring8_suite_kills_every_mutant() {
        // the full 3-topology sweep lives in rust/tests/verify_static.rs;
        // this is the fast unit-level gate
        let rep = run_mutation_suite(&[Torus::ring(8)], 0xC0FF_EE01, 4);
        assert!(rep.total() >= 40, "suite too small: {}", rep.total());
        assert_eq!(rep.killed(), rep.total(), "survivors: {:?}", rep.survivors);
    }
}
