//! Within-step write-hazard analysis on (rank, block) cells.
//!
//! The IR's receive barrier (every send reads the *start-of-step*
//! snapshot) makes concurrent traffic into one cell safe — but only for
//! commutative Reduce landings, and only for engines that actually
//! implement the barrier. This pass classifies the two ways a step can
//! race:
//!
//! * **WAW conflict** — a `Set` lands in a cell that takes *any other
//!   write* the same step (`Set`+`Set` or `Set`+`Reduce`). The final cell
//!   value depends on in-step delivery order: a race under ANY engine,
//!   barrier or not. Concurrent Reduces into one cell are *not* WAW — the
//!   reduction is commutative, and the dataflow pass separately proves
//!   their contributions disjoint.
//! * **WAR cell** — an incoming write into a cell whose rank also *sends
//!   from* that block the same step. Safe only behind the receive barrier
//!   (i.e. the executor must double-buffer); an in-place engine without a
//!   barrier would ship partially-overwritten data.
//!
//! The pass manager's policy ([`super::passes`]): WAW is always an error;
//! WAR is an error on bandwidth (`B`) variants — whose in-place streaming
//! invariant forbids barrier reliance — and an informational finding on
//! latency (`L`) variants. The pinned per-collective WAR counts live in
//! `tools/pysim/eval_passes.py`; WAW is zero on every registry build.
//!
//! [`super::mutate`]'s `InjectHazard` corruptor appends a `Set` into a
//! cell that already absorbs a Reduce — a mutant only this pass can see
//! (the dataflow lattice replays sends in a fixed order and may still
//! complete).

use super::VerifyError;
use crate::schedule::{Kind, Schedule};

/// Aggregate hazard profile of one schedule (summed over steps; each
/// (step, rank, block) cell counts once).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HazardAudit {
    /// Cells written in a step whose rank also sends from that block the
    /// same step (barrier-dependent).
    pub war_cells: u64,
    /// Cells where a `Set` races another write in one step.
    pub waw_conflicts: u64,
    /// `war_cells == 0`: the schedule is correct even without the receive
    /// barrier (no double-buffering needed).
    pub barrier_free: bool,
}

/// Per-step scratch: write counts, set flags, read flags over the dense
/// `(rank, block)` cell space.
struct StepCells {
    nb: usize,
    write_cnt: Vec<u32>,
    write_set: Vec<bool>,
    reads: Vec<bool>,
}

impl StepCells {
    fn new(n: usize, nb: usize) -> StepCells {
        StepCells {
            nb,
            write_cnt: vec![0; n * nb],
            write_set: vec![false; n * nb],
            reads: vec![false; n * nb],
        }
    }

    fn clear(&mut self) {
        self.write_cnt.fill(0);
        self.write_set.fill(false);
        self.reads.fill(false);
    }

    /// Record one step's sends; out-of-range blocks are skipped here (the
    /// dataflow pass reports them as typed [`VerifyError::MalformedSend`]s).
    fn record(&mut self, step: &crate::schedule::Step, n_blocks: u32) {
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                for p in &snd.pieces {
                    for b in p.blocks.iter() {
                        if b >= n_blocks {
                            continue;
                        }
                        let wi = snd.to as usize * self.nb + b as usize;
                        self.write_cnt[wi] += 1;
                        if p.kind == Kind::Set {
                            self.write_set[wi] = true;
                        }
                        self.reads[src * self.nb + b as usize] = true;
                    }
                }
            }
        }
    }
}

/// Count WAR cells and WAW conflicts over the whole schedule (module
/// docs). Purely structural — never fails; policy lives in the pass
/// manager.
pub fn audit_hazards(s: &Schedule) -> HazardAudit {
    let (n, nb) = (s.n as usize, s.n_blocks as usize);
    let mut cells = StepCells::new(n, nb);
    let mut audit = HazardAudit { war_cells: 0, waw_conflicts: 0, barrier_free: true };
    for step in &s.steps {
        cells.clear();
        cells.record(step, s.n_blocks);
        for cell in 0..n * nb {
            if cells.write_cnt[cell] > 1 && cells.write_set[cell] {
                audit.waw_conflicts += 1;
            }
            if cells.write_cnt[cell] > 0 && cells.reads[cell] {
                audit.war_cells += 1;
            }
        }
    }
    audit.barrier_free = audit.war_cells == 0;
    audit
}

/// First WAW race as a typed error, or `None` when the schedule is
/// WAW-free. `Some` exactly when [`audit_hazards`] counts
/// `waw_conflicts > 0`.
pub fn first_waw(s: &Schedule) -> Option<VerifyError> {
    first_hazard(s, true)
}

/// First WAR cell as a typed error, or `None` when the schedule is
/// barrier-free. `Some` exactly when [`audit_hazards`] counts
/// `war_cells > 0`.
pub fn first_war(s: &Schedule) -> Option<VerifyError> {
    first_hazard(s, false)
}

fn first_hazard(s: &Schedule, waw: bool) -> Option<VerifyError> {
    let (n, nb) = (s.n as usize, s.n_blocks as usize);
    let mut cells = StepCells::new(n, nb);
    for (k, step) in s.steps.iter().enumerate() {
        cells.clear();
        cells.record(step, s.n_blocks);
        for cell in 0..n * nb {
            let hit = if waw {
                cells.write_cnt[cell] > 1 && cells.write_set[cell]
            } else {
                cells.write_cnt[cell] > 0 && cells.reads[cell]
            };
            if hit {
                return Some(VerifyError::WriteHazard {
                    step: k,
                    node: (cell / nb) as u32,
                    block: (cell % nb) as u32,
                    detail: if waw {
                        format!(
                            "{} concurrent writes including a Set — the cell value \
                             depends on in-step delivery order",
                            cells.write_cnt[cell]
                        )
                    } else {
                        "cell is written while its rank sends from the same block \
                         (WAR: correct only behind the receive barrier)"
                            .into()
                    },
                });
            }
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockset::BlockSet;
    use crate::schedule::{Piece, RouteHint, Send};

    fn reduce(to: u32, block: u32, contrib: u32, n: u32, nb: u32) -> Send {
        Send {
            to,
            pieces: vec![Piece {
                blocks: BlockSet::singleton(block, nb),
                contrib: BlockSet::singleton(contrib, n),
                kind: Kind::Reduce,
            }],
            route: RouteHint::Minimal,
        }
    }

    fn set(to: u32, block: u32, n: u32, nb: u32) -> Send {
        Send {
            to,
            pieces: vec![Piece {
                blocks: BlockSet::singleton(block, nb),
                contrib: BlockSet::full(n),
                kind: Kind::Set,
            }],
            route: RouteHint::Minimal,
        }
    }

    #[test]
    fn concurrent_reduces_are_not_waw() {
        // nodes 1 and 2 both reduce into node 0's block 0 in one step:
        // commutative, disjoint contributions — no WAW, but node 0 is not
        // sending so no WAR either
        let mut s = Schedule::new("r", 3, 1);
        let st = s.push_step();
        st.push(1, reduce(0, 0, 1, 3, 1));
        st.push(2, reduce(0, 0, 2, 3, 1));
        let a = audit_hazards(&s);
        assert_eq!(a.waw_conflicts, 0);
        assert_eq!(a.war_cells, 0);
        assert!(a.barrier_free);
        assert!(first_waw(&s).is_none());
    }

    #[test]
    fn set_racing_a_reduce_is_waw() {
        let mut s = Schedule::new("w", 3, 1);
        let st = s.push_step();
        st.push(1, reduce(0, 0, 1, 3, 1));
        st.push(2, set(0, 0, 3, 1));
        let a = audit_hazards(&s);
        assert_eq!(a.waw_conflicts, 1);
        match first_waw(&s) {
            Some(VerifyError::WriteHazard { step: 0, node: 0, block: 0, .. }) => {}
            other => panic!("expected a WAW WriteHazard at (0, 0, 0), got {other:?}"),
        }
    }

    #[test]
    fn sender_receiving_into_a_read_block_is_war() {
        // node 0 sends from block 0 while node 1 reduces into node 0's
        // block 0 — barrier-dependent
        let mut s = Schedule::new("war", 3, 1);
        let st = s.push_step();
        st.push(0, reduce(2, 0, 0, 3, 1));
        st.push(1, reduce(0, 0, 1, 3, 1));
        let a = audit_hazards(&s);
        assert_eq!(a.war_cells, 1);
        assert_eq!(a.waw_conflicts, 0);
        assert!(!a.barrier_free);
        match first_war(&s) {
            Some(VerifyError::WriteHazard { step: 0, node: 0, block: 0, .. }) => {}
            other => panic!("expected a WAR WriteHazard at (0, 0, 0), got {other:?}"),
        }
    }
}
