//! Discrete-event network simulator — the substitute for the paper's SST
//! testbed (§6).
//!
//! Both modes share the same execution semantics:
//!
//! * Every node proceeds through the schedule's steps sequentially; step
//!   `k+1`'s sends are injected `α` after **all** of the node's step-`k`
//!   receives have fully arrived (the joint-reduction dependency of §4.3)
//!   and not before the node itself entered step `k`.
//! * Messages are routed per the schedule's route hints on the torus
//!   (minimal adaptive by default) and pay `hops · (link latency +
//!   processing latency)` propagation plus serialization on shared links.
//! * The completion time is the last delivery.
//!
//! [`flow`] models each message as a fluid flow with **max-min fair**
//! bandwidth sharing, recomputed whenever the active flow set changes —
//! accurate for the steady, step-synchronized traffic these collectives
//! generate and fast enough for 4096-node × 128 MiB sweeps. [`packet`]
//! models MTU-sized packets with store-and-forward FIFO queueing per link —
//! the ground-truth mode used at small scale to cross-validate the flow
//! model (see `rust/tests/sim_crosscheck.rs`).

pub mod flow;
pub mod packet;

use crate::cost::NetParams;
use crate::schedule::{RouteHint, Schedule};
use crate::topology::Torus;

/// Simulation fidelity mode.
#[derive(Clone, Copy, Debug)]
pub enum SimMode {
    /// Fluid flows with max-min fair sharing.
    Flow,
    /// Packet-level store-and-forward with the given MTU (bytes).
    Packet { mtu: u32 },
}

/// Result of one simulated collective.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// AllReduce completion time (seconds).
    pub completion_s: f64,
    /// Number of network messages simulated.
    pub messages: usize,
    /// Number of simulator events processed.
    pub events: u64,
}

/// A materialized message ready for simulation.
#[derive(Clone, Debug)]
pub(crate) struct SimMsg {
    pub src: u32,
    pub dst: u32,
    pub step: usize,
    pub bytes: f64,
    /// Directed link indices along the route.
    pub route: Vec<u32>,
}

/// Flatten a schedule into per-step message lists with resolved routes.
pub(crate) fn materialize(s: &Schedule, t: &Torus, m_bytes: u64) -> Vec<Vec<SimMsg>> {
    assert_eq!(s.n, t.n(), "schedule/topology mismatch");
    let mut out: Vec<Vec<SimMsg>> = Vec::with_capacity(s.steps.len());
    for (k, step) in s.steps.iter().enumerate() {
        let mut msgs = Vec::new();
        for (src, sends) in step.sends.iter().enumerate() {
            for snd in sends {
                let bytes = snd.rel_bytes(s.n_blocks) * m_bytes as f64;
                if bytes <= 0.0 {
                    continue;
                }
                let route = match snd.route {
                    RouteHint::Minimal => t.route(src as u32, snd.to),
                    RouteHint::Directed { dim, dir } => {
                        t.route_directed(src as u32, snd.to, dim as usize, dir)
                    }
                };
                let route: Vec<u32> = route.into_iter().map(|l| t.link_index(l) as u32).collect();
                msgs.push(SimMsg { src: src as u32, dst: snd.to, step: k, bytes, route });
            }
        }
        out.push(msgs);
    }
    out
}

/// Simulate the collective: `m_bytes` AllReduce of `schedule` on `torus`.
pub fn simulate(
    schedule: &Schedule,
    torus: &Torus,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
) -> SimResult {
    match mode {
        SimMode::Flow => flow::simulate_flow(schedule, torus, m_bytes, params),
        SimMode::Packet { mtu } => packet::simulate_packet(schedule, torus, m_bytes, params, mtu),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};

    #[test]
    fn materialize_routes_and_bytes() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let steps = materialize(&s, &t, 900);
        assert_eq!(steps.len(), 2);
        // step 0: distance 1, full vector
        for m in &steps[0] {
            assert_eq!(m.route.len(), 1);
            assert!((m.bytes - 900.0).abs() < 1e-9);
        }
        // step 1: distance 3
        for m in &steps[1] {
            assert_eq!(m.route.len(), 3);
        }
        assert_eq!(steps[0].len(), 18);
    }
}
