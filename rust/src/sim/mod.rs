//! Discrete-event network simulator — the substitute for the paper's SST
//! testbed (§6).
//!
//! Both modes share the same execution semantics:
//!
//! * Every node proceeds through the schedule's steps sequentially; step
//!   `k+1`'s sends are injected `α` after **all** of the node's step-`k`
//!   receives have fully arrived (the joint-reduction dependency of §4.3)
//!   and not before the node itself entered step `k`.
//! * Messages are routed per the schedule's route hints on the torus
//!   (minimal adaptive by default) and pay `hops · (link latency +
//!   processing latency)` propagation plus serialization on shared links.
//! * The completion time is the last delivery.
//!
//! [`flow`] models each message as a fluid flow with **max-min fair**
//! bandwidth sharing, recomputed whenever the active flow set changes
//! (with a closed-form fast path for the uniform-congestion steady state) —
//! accurate for the steady, step-synchronized traffic these collectives
//! generate and fast enough for 4096-node × 128 MiB sweeps. [`packet`]
//! models MTU-sized packets with per-link FIFO **batch** scheduling: each
//! message's packets occupy a link as one contiguous busy interval, so heap
//! traffic is `O(messages × hops)` and the ground-truth mode cross-validates
//! the flow model up to 8×8 / 4×4×4 tori (see
//! `rust/tests/sim_crosscheck.rs`); the pre-overhaul per-packet engine
//! survives as [`packet::reference`], the drift oracle. The batched
//! engine's events are scheduled on a pluggable [`events`] queue — a
//! bucketed calendar queue by default (amortized `O(1)` per operation,
//! proven bit-identical to the seed `BinaryHeap`; `--event-queue heap`
//! selects the heap).
//!
//! ## Network models
//!
//! Both engines price each link individually. A plan built through
//! [`SimPlan::build`] (or [`simulate`]) runs the paper's **uniform**
//! fabric: every link at `NetParams` rate and latency — the legacy
//! arithmetic, bit for bit. A plan built against a heterogeneous
//! [`crate::net::NetModel`] ([`SimPlan::try_build_with_model`],
//! [`simulate_model`]) carries per-link bandwidth/latency scale columns
//! and routes detoured around down links; the flow water-filling fills
//! per-link capacities, and the packet engine serializes each batch at the
//! link's own rate with a tail-arrival carry so a fast link downstream of
//! a slow one can never ship bytes before they arrive. Named degradation
//! scenarios (stragglers, per-dimension ratios, faults) live in
//! [`crate::harness::scenarios`].
//!
//! Both modes execute against a precompiled [`SimPlan`] ([`plan`]): the
//! schedule→routes structure is flattened once per `(schedule, torus,
//! model)` and reused across every message size (and across sweep
//! threads). Registry consumers additionally share plans across
//! invocations through the process-wide [`cache::PlanCache`], keyed by
//! `(algo, variant, dims, net fingerprint)`. Use [`simulate`] /
//! [`simulate_model`] for one-off runs, [`simulate_plan`] when sweeping a
//! ladder.

pub mod cache;
pub mod events;
pub mod flow;
pub mod packet;
pub mod plan;

pub use cache::{PlanCache, PlanKey};
pub use events::{QueueKind, QueueStats};
pub use plan::{SimPlan, SimScratch};

use crate::cost::NetParams;
use crate::net::{NetModel, Timeline, Unreachable};
use crate::schedule::Schedule;
use crate::topology::Torus;

/// Typed simulator failure — the sim layer's replacement for its former
/// abort paths. Every fallible entry point surfaces one of these instead of
/// panicking, so the CLI (and the online controller) can report *what*
/// failed and react.
#[derive(Clone, Debug, PartialEq)]
pub enum SimError {
    /// A [`Timeline`] left traffic permanently stranded on a down link:
    /// `link` is the dense directed-link index the bytes are blocked on,
    /// `step` the schedule step of (one of) the stranded message(s). A
    /// link that fails *for good* is a schedule-level event — the fix is
    /// [`crate::schedule::rewrite`] / [`crate::schedule::online`], not a
    /// capacity timeline.
    Stranded { link: usize, step: u32 },
    /// The model's down set disconnects a (src, dst) pair the schedule
    /// needs — no detour exists.
    Unroutable(Unreachable),
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Stranded { link, step } => write!(
                f,
                "timeline leaves traffic stranded on down link {link} (step {step}): a \
                 permanent failure needs a schedule rewrite or detour (schedule::rewrite / \
                 schedule::online), not a capacity timeline"
            ),
            SimError::Unroutable(u) => write!(f, "{u}"),
        }
    }
}

impl std::error::Error for SimError {}

impl From<Unreachable> for SimError {
    fn from(u: Unreachable) -> SimError {
        SimError::Unroutable(u)
    }
}

/// A heap entry for the discrete-event engines: min-heap by time, FIFO
/// tie-break by push sequence (`BinaryHeap` is a max-heap, so the ordering
/// is reversed). The event payload never participates in the ordering.
/// Times must never be NaN (`total_cmp` would otherwise sort a NaN event
/// deterministically but *wrongly* — after every finite time — so the
/// debug assertion catches the corrupted model at the source instead of
/// letting the heap silently scramble).
#[derive(Clone, Copy)]
pub(crate) struct Timed<E> {
    pub t: f64,
    pub seq: u64,
    pub ev: E,
}

impl<E> PartialEq for Timed<E> {
    fn eq(&self, other: &Self) -> bool {
        self.t == other.t && self.seq == other.seq
    }
}
impl<E> Eq for Timed<E> {}
impl<E> Ord for Timed<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        debug_assert!(
            !self.t.is_nan() && !other.t.is_nan(),
            "NaN event time in the DES heap"
        );
        other.t.total_cmp(&self.t).then(other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for Timed<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

/// Simulation fidelity mode.
#[derive(Clone, Copy, Debug)]
pub enum SimMode {
    /// Fluid flows with max-min fair sharing.
    Flow,
    /// Packet-level store-and-forward with the given MTU (bytes).
    Packet { mtu: u32 },
}

/// Result of one simulated collective.
#[derive(Clone, Debug)]
pub struct SimResult {
    /// AllReduce completion time (seconds).
    pub completion_s: f64,
    /// Number of network messages simulated.
    pub messages: usize,
    /// Number of simulator events processed.
    pub events: u64,
}

/// Simulate the collective: `m_bytes` AllReduce of `schedule` on `torus`.
///
/// Builds a fresh [`SimPlan`] per call — when simulating the same schedule
/// at several sizes, build the plan once and call [`simulate_plan`].
pub fn simulate(
    schedule: &Schedule,
    torus: &Torus,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
) -> SimResult {
    simulate_plan(&SimPlan::build(schedule, torus), m_bytes, params, mode)
}

/// [`simulate`] under a heterogeneous [`NetModel`] (per-link bandwidth and
/// latency scales, down-link detours). With a uniform model this is
/// bit-identical to [`simulate`]. Returns [`SimError::Unroutable`] when the
/// model's down set partitions a pair the schedule needs.
pub fn simulate_model(
    schedule: &Schedule,
    model: &NetModel,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
) -> Result<SimResult, SimError> {
    let plan = SimPlan::try_build_with_model(schedule, model)?;
    Ok(simulate_plan(&plan, m_bytes, params, mode))
}

/// Simulate an `m_bytes` collective against a precompiled plan. Builds the
/// per-`(plan, params)` [`SimScratch`] internally; ladder/replay callers
/// should build the scratch once and call [`simulate_plan_scratch`]
/// (bit-identical — the scratch holds exactly the columns this path
/// computes per call).
pub fn simulate_plan(
    plan: &SimPlan,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
) -> SimResult {
    params.validate();
    match mode {
        SimMode::Flow => flow::simulate_flow_plan(plan, m_bytes, params),
        SimMode::Packet { mtu } => packet::simulate_packet_plan(plan, m_bytes, params, mtu),
    }
}

/// [`simulate_plan`] against a precomputed [`SimScratch`] — the sweep/replay
/// hot path, which no longer rebuilds the per-link capacity and latency
/// columns per collective.
pub fn simulate_plan_scratch(
    plan: &SimPlan,
    scratch: &SimScratch,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
) -> SimResult {
    params.validate();
    match mode {
        SimMode::Flow => flow::simulate_flow_plan_scratch(plan, m_bytes, params, scratch),
        SimMode::Packet { mtu } => {
            packet::simulate_packet_plan_scratch(plan, m_bytes, params, mtu, scratch)
        }
    }
}

/// [`simulate_plan_scratch`] under a [`Timeline`] of mid-collective fabric
/// mutations: the flow engine re-water-fills at every epoch, the packet
/// engine splits busy intervals at epoch boundaries. An **empty** timeline
/// short-circuits to [`simulate_plan_scratch`] — the static path, bit for
/// bit (`sim_crosscheck.rs` pins this across the registry). A timeline that
/// leaves bytes stranded on a permanently-down link returns
/// [`SimError::Stranded`] (never a panic): that case is a schedule-level
/// fault and belongs to [`crate::schedule::rewrite`] /
/// [`crate::schedule::online`].
pub fn simulate_plan_timeline(
    plan: &SimPlan,
    scratch: &SimScratch,
    m_bytes: u64,
    params: &NetParams,
    mode: SimMode,
    timeline: &Timeline,
) -> Result<SimResult, SimError> {
    params.validate();
    match mode {
        SimMode::Flow => {
            flow::simulate_flow_plan_timeline(plan, m_bytes, params, scratch, timeline)
        }
        SimMode::Packet { mtu } => {
            packet::simulate_packet_plan_timeline(plan, m_bytes, params, mtu, scratch, timeline)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};

    #[test]
    fn modes_dispatch_against_one_plan() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let plan = SimPlan::build(&s, &t);
        let p = NetParams::default();
        let f = simulate_plan(&plan, 4096, &p, SimMode::Flow);
        let k = simulate_plan(&plan, 4096, &p, SimMode::Packet { mtu: 4096 });
        assert_eq!(f.messages, k.messages);
        assert!(f.completion_s > 0.0 && k.completion_s > 0.0);
        // and the schedule-level entry point agrees exactly
        let f2 = simulate(&s, &t, 4096, &p, SimMode::Flow);
        assert_eq!(f.completion_s.to_bits(), f2.completion_s.to_bits());
    }
}
