//! Precompiled simulation plans: the plan/execute split of the simulator.
//!
//! Simulating one `(schedule, topology)` pair across a message-size ladder
//! used to re-materialize the identical structure — per-message routes,
//! per-(step, source) injection lists, expected-receive counts — once per
//! size, even though none of it depends on the message size. A [`SimPlan`]
//! does that work **once**: [`SimPlan::build`] flattens the schedule into
//! immutable, cache-friendly arrays, and both simulator modes
//! ([`crate::sim::flow`], [`crate::sim::packet`]) execute against
//! `&SimPlan + (m_bytes, NetParams)`. The paper's sweep tables (one point
//! per algorithm × variant × topology × size) therefore pay schedule
//! flattening and route resolution once per ladder instead of once per
//! point, and plans are `Sync`, so the sweep harness fans points out across
//! threads against shared plans.
//!
//! Layout notes:
//!
//! * Messages with zero relative payload are dropped at build time (they
//!   carry no bytes at any size — same as the old per-size materializer).
//! * Routes are stored as one flattened array of dense link indices with
//!   per-message `(offset, len)` — no per-message `Vec`, no pointer chasing.
//! * `injections(node, step)` and `msgs_on_link(link)` are CSR adjacency
//!   lists; the latter exists for link-centric consumers (congestion
//!   accounting, future incremental schedulers).
//! * Heterogeneity ([`crate::net::NetModel`]) is baked in as three
//!   per-link *scale* columns (bandwidth / propagation / processing,
//!   relative to the [`NetParams`] base) plus routes resolved with
//!   down-link detours. The columns are still size- *and*
//!   parameter-independent, so one plan serves every message size and
//!   every base bandwidth; [`SimPlan::build`] is the uniform special case
//!   (all scales `1.0`) and stays bit-identical to the pre-NetModel plans.

use crate::cost::NetParams;
use crate::net::{NetModel, Unreachable};
use crate::schedule::Schedule;
use crate::topology::Torus;

/// One flattened message: everything size-independent about it.
#[derive(Clone, Copy, Debug)]
pub struct PlanMsg {
    pub src: u32,
    pub dst: u32,
    pub step: u32,
    /// Payload in units of the full vector size `m` (multiply by `m_bytes`).
    pub rel_bytes: f64,
    route_off: u32,
    route_len: u32,
}

/// An immutable, size-independent simulation plan for one
/// `(schedule, torus)` pair. See the module docs.
#[derive(Clone, Debug)]
pub struct SimPlan {
    n: usize,
    nsteps: usize,
    num_links: usize,
    msgs: Vec<PlanMsg>,
    /// Flattened routes (dense directed-link indices), indexed by each
    /// message's `(route_off, route_len)`.
    route_links: Vec<u32>,
    /// CSR offsets/ids: messages injected by `(node, step)`.
    inject_off: Vec<u32>,
    inject_ids: Vec<u32>,
    /// Expected receive count per `(node, step)`.
    expected: Vec<u32>,
    /// CSR offsets/ids: messages whose route crosses each link.
    link_off: Vec<u32>,
    link_ids: Vec<u32>,
    /// Per-link bandwidth multipliers relative to `NetParams::link_bw_bps`
    /// (all `1.0` for uniform models).
    link_bw_scale: Vec<f64>,
    /// Per-link propagation-latency multipliers.
    link_lat_scale: Vec<f64>,
    /// Per-link processing-latency multipliers.
    link_proc_scale: Vec<f64>,
    /// True iff the plan was built against the uniform model — gates the
    /// simulators' legacy (bit-identical) arithmetic and fast paths.
    uniform: bool,
}

impl SimPlan {
    /// Flatten `schedule` routed on `torus` into a plan (uniform fabric).
    /// Cost is one route resolution per message; the result is reused for
    /// every message size (and across threads).
    pub fn build(schedule: &Schedule, torus: &Torus) -> SimPlan {
        SimPlan::try_build_with_model(schedule, &NetModel::uniform(torus))
            .expect("uniform fabric routes are total")
    }

    /// Flatten `schedule` under a heterogeneous [`NetModel`]: routes detour
    /// around down links and the model's per-link scale columns are carried
    /// into the plan. With a uniform model this is exactly [`SimPlan::build`].
    /// Returns [`Unreachable`] when the model's down set disconnects a
    /// (src, dst) pair the schedule needs — surfaced as a typed error all
    /// the way through [`crate::sim::SimError`], never a panic.
    pub fn try_build_with_model(
        schedule: &Schedule,
        model: &NetModel,
    ) -> Result<SimPlan, Unreachable> {
        SimPlan::build_staged(schedule, model, &[])
    }

    /// Flatten a schedule hit by a fault *between* steps: messages in steps
    /// `< fault_step` route on the pre-fault `base` model (the fabric they
    /// actually ran on), messages in steps `>= fault_step` route on the
    /// post-fault `post` model (detouring around — or, for a rewritten
    /// schedule, already avoiding — the newly down links). Scale columns
    /// come from `base`: a fault changes reachability, not the surviving
    /// links' rates. With `fault_step >= num_steps` or `post == base` this
    /// is exactly [`try_build_with_model`](Self::try_build_with_model).
    /// The two-stage special case of [`build_staged`](Self::build_staged).
    pub fn build_faulted(
        schedule: &Schedule,
        base: &NetModel,
        post: &NetModel,
        fault_step: u32,
    ) -> Result<SimPlan, Unreachable> {
        SimPlan::build_staged(schedule, base, &[(fault_step, post)])
    }

    /// Flatten a schedule under a per-step-range **model stack**: each
    /// `(from_step, model)` stage routes the steps `>= from_step` (up to the
    /// next stage); steps before the first stage route on `class_model`.
    /// This is how a *fault sequence* is priced: every fault contributes one
    /// stage, so step `k`'s messages route on the fabric that was live when
    /// step `k` ran. Scale columns (and the uniform flag) always come from
    /// `class_model` — faults change reachability, not surviving links'
    /// rates. An empty stack is exactly
    /// [`try_build_with_model`](Self::try_build_with_model); one stage is
    /// exactly [`build_faulted`](Self::build_faulted).
    pub fn build_staged(
        schedule: &Schedule,
        class_model: &NetModel,
        stages: &[(u32, &NetModel)],
    ) -> Result<SimPlan, Unreachable> {
        for w in stages.windows(2) {
            assert!(
                w[0].0 <= w[1].0,
                "build_staged: stages must be sorted by from_step"
            );
        }
        for (_, m) in stages {
            assert_eq!(
                class_model.torus().dims(),
                m.torus().dims(),
                "build_staged: all stage models must share the topology"
            );
        }
        let model = class_model;
        let torus = model.torus();
        assert_eq!(schedule.n, torus.n(), "schedule/topology mismatch");
        let n = schedule.n as usize;
        let nsteps = schedule.steps.len();
        let num_links = torus.num_links();

        let mut msgs: Vec<PlanMsg> = Vec::new();
        let mut route_links: Vec<u32> = Vec::new();
        for (k, step) in schedule.steps.iter().enumerate() {
            // the last stage whose from_step covers step k routes it
            let mut router: &NetModel = class_model;
            for &(from, m) in stages {
                if (k as u32) >= from {
                    router = m;
                } else {
                    break;
                }
            }
            for (src, sends) in step.sends.iter().enumerate() {
                for snd in sends {
                    let rel = snd.rel_bytes(schedule.n_blocks);
                    if rel <= 0.0 {
                        continue;
                    }
                    let route = router.try_route(src as u32, snd.to, snd.route)?;
                    let route_off = route_links.len() as u32;
                    route_links.extend(route.into_iter().map(|l| torus.link_index(l) as u32));
                    let route_len = route_links.len() as u32 - route_off;
                    msgs.push(PlanMsg {
                        src: src as u32,
                        dst: snd.to,
                        step: k as u32,
                        rel_bytes: rel,
                        route_off,
                        route_len,
                    });
                }
            }
        }

        // CSR: (node, step) -> injected message ids, plus expected receives.
        let mut inject_counts = vec![0u32; n * nsteps];
        let mut expected = vec![0u32; n * nsteps];
        for m in &msgs {
            inject_counts[m.src as usize * nsteps + m.step as usize] += 1;
            expected[m.dst as usize * nsteps + m.step as usize] += 1;
        }
        let (inject_off, mut cursor) = prefix_sum(&inject_counts);
        let mut inject_ids = vec![0u32; msgs.len()];
        for (i, m) in msgs.iter().enumerate() {
            let slot = m.src as usize * nsteps + m.step as usize;
            inject_ids[cursor[slot] as usize] = i as u32;
            cursor[slot] += 1;
        }

        // CSR: link -> message ids crossing it.
        let mut link_counts = vec![0u32; num_links];
        for &l in &route_links {
            link_counts[l as usize] += 1;
        }
        let (link_off, mut lcursor) = prefix_sum(&link_counts);
        let mut link_ids = vec![0u32; route_links.len()];
        for (i, m) in msgs.iter().enumerate() {
            let (off, len) = (m.route_off as usize, m.route_len as usize);
            for &l in &route_links[off..off + len] {
                link_ids[lcursor[l as usize] as usize] = i as u32;
                lcursor[l as usize] += 1;
            }
        }

        Ok(SimPlan {
            n,
            nsteps,
            num_links,
            msgs,
            route_links,
            inject_off,
            inject_ids,
            expected,
            link_off,
            link_ids,
            link_bw_scale: (0..num_links).map(|l| model.bw_scale(l)).collect(),
            link_lat_scale: (0..num_links).map(|l| model.lat_scale(l)).collect(),
            link_proc_scale: (0..num_links).map(|l| model.proc_scale(l)).collect(),
            // The class model decides uniformity: build_faulted only changes
            // *routes* (scale columns stay all-1.0 on a uniform base), and
            // the engines' uniform fast paths assume equal capacities and
            // latencies, not any particular routing.
            uniform: model.is_uniform(),
        })
    }

    /// Was this plan built against the uniform (paper §6) network model?
    pub fn is_uniform(&self) -> bool {
        self.uniform
    }

    /// Bandwidth multiplier of dense link `link`.
    pub fn link_bw_scale(&self, link: usize) -> f64 {
        self.link_bw_scale[link]
    }

    /// Per-link capacities in bytes/s under `params` (each exactly the
    /// scalar `link_bw_bps / 8` on a uniform plan: `cap * 1.0 == cap`).
    pub fn link_caps(&self, params: &NetParams) -> Vec<f64> {
        let cap = params.link_bw_bps / 8.0;
        self.link_bw_scale.iter().map(|&s| cap * s).collect()
    }

    /// Per-link forwarding latency (scaled propagation + processing) under
    /// `params`; exactly `per_hop_s()` everywhere on a uniform plan.
    pub fn link_hop_lat(&self, params: &NetParams) -> Vec<f64> {
        self.link_lat_scale
            .iter()
            .zip(&self.link_proc_scale)
            .map(|(&ls, &ps)| ls * params.link_latency_s + ps * params.hop_latency_s)
            .collect()
    }

    /// Total route forwarding latency per message. Uniform plans keep the
    /// historical `hops * per_hop` product so flow results stay
    /// bit-identical; heterogeneous plans sum the per-link latencies.
    pub fn msg_hop_lat(&self, params: &NetParams) -> Vec<f64> {
        if self.uniform {
            let per_hop = params.per_hop_s();
            return self.msgs.iter().map(|m| m.route_len as f64 * per_hop).collect();
        }
        let hop = self.link_hop_lat(params);
        (0..self.msgs.len())
            .map(|i| self.route(i).iter().map(|&l| hop[l as usize]).sum())
            .collect()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn num_steps(&self) -> usize {
        self.nsteps
    }

    pub fn num_links(&self) -> usize {
        self.num_links
    }

    pub fn num_msgs(&self) -> usize {
        self.msgs.len()
    }

    /// Total route length summed over all messages (scratch sizing).
    pub fn total_hops(&self) -> usize {
        self.route_links.len()
    }

    pub fn msg(&self, i: usize) -> &PlanMsg {
        &self.msgs[i]
    }

    /// The dense directed-link indices of message `i`'s route.
    pub fn route(&self, i: usize) -> &[u32] {
        let m = &self.msgs[i];
        &self.route_links[m.route_off as usize..(m.route_off + m.route_len) as usize]
    }

    /// Absolute payload of message `i` for an `m_bytes` collective.
    pub fn bytes(&self, i: usize, m_bytes: u64) -> f64 {
        self.msgs[i].rel_bytes * m_bytes as f64
    }

    /// Message ids node `node` injects when it enters `step`.
    pub fn injections(&self, node: usize, step: usize) -> &[u32] {
        let slot = node * self.nsteps + step;
        &self.inject_ids[self.inject_off[slot] as usize..self.inject_off[slot + 1] as usize]
    }

    /// Number of messages `node` must receive in `step` before advancing.
    pub fn expected(&self, node: usize, step: usize) -> u32 {
        self.expected[node * self.nsteps + step]
    }

    /// Message ids whose route crosses dense link `link`.
    pub fn msgs_on_link(&self, link: usize) -> &[u32] {
        &self.link_ids[self.link_off[link] as usize..self.link_off[link + 1] as usize]
    }

    /// Does any message have an empty route (a co-located src/dst pair)?
    /// Registry-built schedules never produce these; the flow simulator's
    /// symmetric-step fast path is gated on their absence.
    pub fn has_zero_hop_routes(&self) -> bool {
        self.msgs.iter().any(|m| m.route_len == 0)
    }

    /// Serialization lower bound (seconds) of the whole collective at
    /// `m_bytes` under `params`: the most time-expensive link's total
    /// payload at its own line rate (`load / bw_scale` at the base β). A
    /// cheap sanity anchor for both simulator modes.
    pub fn bottleneck_serialization_s(&self, m_bytes: u64, params: &NetParams) -> f64 {
        let mut load = vec![0f64; self.num_links];
        for (i, m) in self.msgs.iter().enumerate() {
            let b = m.rel_bytes * m_bytes as f64;
            for &l in self.route(i) {
                load[l as usize] += b;
            }
        }
        load.into_iter()
            .enumerate()
            .map(|(l, ld)| ld / self.link_bw_scale[l])
            .fold(0f64, f64::max)
            * params.beta_per_byte()
    }
}

/// Precomputed per-`(plan, params)` simulator scratch: the per-link
/// capacity/latency columns and per-message route latencies that both
/// engines previously rebuilt on every `simulate_plan` call. Sweeps and
/// trace replays build one `SimScratch` per `(plan, params)` pair and reuse
/// it across every message size ([`crate::sim::simulate_plan_scratch`]).
/// The columns are exactly what the per-call path computes
/// ([`SimPlan::link_caps`] / [`SimPlan::link_hop_lat`] /
/// [`SimPlan::msg_hop_lat`]), so scratch-based runs are **bit-identical**
/// to scratch-less ones. All three columns are built eagerly even though
/// each engine reads only two — the spare column is `O(links)` /
/// `O(messages)`, dominated by the simulation that follows on the only
/// paths that build scratch per call (one-off CLI runs); sweeps and
/// replays amortize it across the whole ladder.
#[derive(Clone, Debug)]
pub struct SimScratch {
    /// Per-link capacity in bytes/s.
    pub(crate) caps: Vec<f64>,
    /// Per-link forwarding latency (scaled propagation + processing).
    pub(crate) link_hop_lat: Vec<f64>,
    /// Per-message total route forwarding latency.
    pub(crate) msg_hop_lat: Vec<f64>,
}

impl SimScratch {
    /// Precompute the columns for one `(plan, params)` pair.
    pub fn new(plan: &SimPlan, params: &NetParams) -> SimScratch {
        SimScratch {
            caps: plan.link_caps(params),
            link_hop_lat: plan.link_hop_lat(params),
            msg_hop_lat: plan.msg_hop_lat(params),
        }
    }

    /// Does this scratch's shape match `plan`? (A mismatched pair would
    /// silently price the wrong links — asserted by the engines.)
    pub(crate) fn matches(&self, plan: &SimPlan) -> bool {
        self.caps.len() == plan.num_links()
            && self.link_hop_lat.len() == plan.num_links()
            && self.msg_hop_lat.len() == plan.num_msgs()
    }
}

/// Exclusive prefix sum; returns (offsets with trailing total, a working
/// copy of the offsets to use as fill cursors).
fn prefix_sum(counts: &[u32]) -> (Vec<u32>, Vec<u32>) {
    let mut off = Vec::with_capacity(counts.len() + 1);
    let mut acc = 0u32;
    off.push(0);
    for &c in counts {
        acc += c;
        off.push(acc);
    }
    let cursor = off[..counts.len()].to_vec();
    (off, cursor)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};

    #[test]
    fn plan_flattens_trivance_ring9() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let p = SimPlan::build(&s, &t);
        assert_eq!(p.num_steps(), 2);
        assert_eq!(p.n(), 9);
        // step 0: 18 messages at distance 1, full vector
        let step0: Vec<usize> = (0..p.num_msgs()).filter(|&i| p.msg(i).step == 0).collect();
        assert_eq!(step0.len(), 18);
        for &i in &step0 {
            assert_eq!(p.route(i).len(), 1);
            assert!((p.bytes(i, 900) - 900.0).abs() < 1e-9);
        }
        // step 1: distance 3
        for i in 0..p.num_msgs() {
            if p.msg(i).step == 1 {
                assert_eq!(p.route(i).len(), 3);
            }
        }
    }

    #[test]
    fn injection_and_expected_counts_are_consistent() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let p = SimPlan::build(&s, &t);
        let mut total = 0usize;
        for node in 0..p.n() {
            for step in 0..p.num_steps() {
                for &mi in p.injections(node, step) {
                    let m = p.msg(mi as usize);
                    assert_eq!(m.src as usize, node);
                    assert_eq!(m.step as usize, step);
                    total += 1;
                }
            }
        }
        assert_eq!(total, p.num_msgs());
        let expected_total: u32 =
            (0..p.n()).flat_map(|r| (0..p.num_steps()).map(move |k| (r, k)))
                .map(|(r, k)| p.expected(r, k))
                .sum();
        assert_eq!(expected_total as usize, p.num_msgs());
    }

    #[test]
    fn model_plan_carries_scales_and_detours() {
        use crate::net::{LinkClass, NetModel};
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let l = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 });
        let mut model = NetModel::uniform(&t);
        model.set_class(l, LinkClass::slowdown(4.0));
        let p = SimPlan::try_build_with_model(&s, &model).unwrap();
        assert!(!p.is_uniform());
        assert_eq!(p.link_bw_scale(l), 0.25);
        // uniform model produces the identical plan surface as build()
        let u = SimPlan::try_build_with_model(&s, &NetModel::uniform(&t)).unwrap();
        let b = SimPlan::build(&s, &t);
        assert!(u.is_uniform() && b.is_uniform());
        assert_eq!(u.num_msgs(), b.num_msgs());
        for i in 0..u.num_msgs() {
            assert_eq!(u.route(i), b.route(i));
        }
        // a down link never appears in any route
        let mut faulty = NetModel::uniform(&t);
        faulty.set_down(l, true);
        let pf = SimPlan::try_build_with_model(&s, &faulty).unwrap();
        for i in 0..pf.num_msgs() {
            assert!(!pf.route(i).contains(&(l as u32)), "msg {i} crosses the down link");
        }
    }

    #[test]
    fn faulted_plan_routes_pre_and_post_steps_differently() {
        use crate::net::NetModel;
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let l = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 });
        let mut post = NetModel::uniform(&t);
        post.set_down(l, true);
        // fault before step 1: step-0 routes may still cross the link,
        // step-1 routes must not
        let p = SimPlan::build_faulted(&s, &base, &post, 1).unwrap();
        assert!(p.is_uniform(), "scale columns stay uniform across a fault");
        let nominal = SimPlan::build(&s, &t);
        let mut post_crossings = 0usize;
        for i in 0..p.num_msgs() {
            let m = p.msg(i);
            if m.step < 1 {
                assert_eq!(p.route(i), nominal.route(i), "pre-fault step rerouted");
            } else {
                assert!(!p.route(i).contains(&(l as u32)), "post-fault msg {i} crosses the dead link");
                if nominal.route(i).contains(&(l as u32)) {
                    post_crossings += 1;
                }
            }
        }
        assert!(post_crossings > 0, "the dead link carried step-1 traffic nominally");
        // fault after the last step is exactly the plain build
        let noop = SimPlan::build_faulted(&s, &base, &post, s.steps.len() as u32).unwrap();
        for i in 0..noop.num_msgs() {
            assert_eq!(noop.route(i), nominal.route(i));
        }
    }

    #[test]
    fn staged_plan_generalizes_faulted_and_routes_per_range() {
        use crate::net::NetModel;
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let base = NetModel::uniform(&t);
        let l0 = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 });
        let l3 = t.link_index(crate::topology::Link { node: 3, dim: 0, dir: 1 });
        let mut post1 = NetModel::uniform(&t);
        post1.set_down(l0, true);
        let mut post2 = post1.clone();
        post2.set_down(l3, true);
        // one stage == build_faulted, route for route
        let faulted = SimPlan::build_faulted(&s, &base, &post1, 1).unwrap();
        let staged = SimPlan::build_staged(&s, &base, &[(1, &post1)]).unwrap();
        assert_eq!(faulted.num_msgs(), staged.num_msgs());
        for i in 0..faulted.num_msgs() {
            assert_eq!(faulted.route(i), staged.route(i));
        }
        // a two-stage stack routes each step range on its own fabric
        // (bandwidth variant: 4 steps, so every range carries messages)
        let sb = crate::agpattern::bandwidth_allreduce(&trivance(9, Order::Dec));
        let two = SimPlan::build_staged(&sb, &base, &[(1, &post1), (2, &post2)]).unwrap();
        assert!(two.is_uniform(), "scale columns stay on the class model");
        let mut saw = [false; 3];
        for i in 0..two.num_msgs() {
            let step = two.msg(i).step;
            saw[(step as usize).min(2)] = true;
            if step >= 1 {
                assert!(!two.route(i).contains(&(l0 as u32)));
            }
            if step >= 2 {
                assert!(!two.route(i).contains(&(l3 as u32)));
            }
        }
        assert_eq!(saw, [true; 3], "every stage range carried traffic");
        // empty stack == the plain model build
        let empty = SimPlan::build_staged(&s, &base, &[]).unwrap();
        let plain = SimPlan::build(&s, &t);
        for i in 0..empty.num_msgs() {
            assert_eq!(empty.route(i), plain.route(i));
        }
    }

    #[test]
    fn partitioned_model_surfaces_unreachable_from_try_build() {
        use crate::net::NetModel;
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let mut m = NetModel::uniform(&t);
        // isolate node 1's inbound links
        m.set_down(t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 }), true);
        m.set_down(t.link_index(crate::topology::Link { node: 2, dim: 0, dir: -1 }), true);
        let err = SimPlan::try_build_with_model(&s, &m).unwrap_err();
        assert_eq!(err.dst, 1, "some sender cannot reach the isolated node: {err}");
    }

    #[test]
    fn link_adjacency_covers_every_hop() {
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let p = SimPlan::build(&s, &t);
        let mut hops = 0usize;
        for l in 0..p.num_links() {
            for &mi in p.msgs_on_link(l) {
                assert!(p.route(mi as usize).contains(&(l as u32)));
                hops += 1;
            }
        }
        assert_eq!(hops, p.total_hops());
    }
}
