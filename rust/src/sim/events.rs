//! Pluggable event queues for the discrete-event engines.
//!
//! The packet engine's hot loop is `push`/`pop` on a priority queue ordered
//! by `(time, push seq)`. A [`std::collections::BinaryHeap`] pays
//! `O(log n)` comparisons per operation; a **calendar queue** (Brown 1988)
//! buckets events by "day" (`⌊t / width⌋`) into a circular array of days
//! and pays amortized `O(1)` per operation when the day width tracks the
//! event density — which the self-resizing rule below keeps it doing.
//!
//! Correctness contract: **every pop returns the global `(t, seq)` minimum**,
//! exactly as the heap does, so the two implementations are *bit-identical*
//! — not approximately equal — for any simulation driven through
//! [`EventQueue`]. The argument:
//!
//! * every event whose day is `d` lives in bucket `d % nbuckets` (both
//!   `push` and the resize rebuild place it there, computing the day with
//!   the **same float expression** `(t / width) as u64`);
//! * `cur_day` never exceeds the day of the earliest pending event: `push`
//!   lowers it when an earlier event arrives, `pop` only advances past a
//!   day after scanning its bucket and finding no event *of that day*, and
//!   the direct-search fallback resets it to the day of the true minimum;
//! * therefore the first day whose bucket holds a matching event is the
//!   globally earliest day, and the scan picks the `(t, seq)`-least event
//!   of that day — which is the global minimum, since a smaller `t` implies
//!   a smaller-or-equal day.
//!
//! Same-instant events (e.g. a `Batch` landing exactly when a `StepStart`
//! fires) are ordered by the push sequence number, the same FIFO tiebreak
//! [`Timed`]'s heap ordering uses; `tools/pysim/eval_core.py` proves the
//! bit-identity across the full registry, timelines included, and the
//! tests below pin the day-rollover ordering directly.

use super::Timed;
use std::collections::BinaryHeap;
use std::sync::atomic::{AtomicU8, Ordering};

/// Which event-queue implementation the packet engine schedules on.
/// Selectable per call ([`crate::sim::packet::simulate_packet_plan_queue`])
/// or process-wide via [`set_default_kind`] (the CLI's `--event-queue`
/// knob). The default is [`QueueKind::Calendar`] — safe because the two are
/// bit-identical; `--event-queue heap` restores the seed data structure.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueueKind {
    /// `BinaryHeap<Timed<E>>` — the seed scheduler, `O(log n)` per op.
    Heap,
    /// Bucketed calendar queue — amortized `O(1)` per op.
    Calendar,
}

impl QueueKind {
    /// Parse a `--event-queue` value.
    pub fn parse(s: &str) -> Option<QueueKind> {
        match s {
            "heap" => Some(QueueKind::Heap),
            "calendar" => Some(QueueKind::Calendar),
            _ => None,
        }
    }
}

impl std::fmt::Display for QueueKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            QueueKind::Heap => write!(f, "heap"),
            QueueKind::Calendar => write!(f, "calendar"),
        }
    }
}

static DEFAULT_KIND: AtomicU8 = AtomicU8::new(1); // 0 = heap, 1 = calendar

/// Set the process-wide default queue (the CLI's `--event-queue` flag).
pub fn set_default_kind(kind: QueueKind) {
    DEFAULT_KIND.store(
        match kind {
            QueueKind::Heap => 0,
            QueueKind::Calendar => 1,
        },
        Ordering::Relaxed,
    );
}

/// The process-wide default queue kind.
pub fn default_kind() -> QueueKind {
    match DEFAULT_KIND.load(Ordering::Relaxed) {
        0 => QueueKind::Heap,
        _ => QueueKind::Calendar,
    }
}

/// Operation counters for one simulation's event queue — the raw material
/// of the heap-vs-calendar comparison `bench-sweep` reports. `pushes` and
/// `pops` are implementation-independent (the bit-identity makes them equal
/// across kinds); `resizes` and `scanned` are calendar-only (`scanned` is
/// the total entries examined during pops — the calendar's analogue of the
/// heap's sift comparisons, and the number that stays `O(1)` per pop when
/// the day width is healthy).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct QueueStats {
    /// Events pushed.
    pub pushes: u64,
    /// Events popped.
    pub pops: u64,
    /// Peak queue length.
    pub peak_len: u64,
    /// Calendar rebuilds (bucket-count doublings/halvings). 0 for the heap.
    pub resizes: u64,
    /// Entries examined while scanning for minima. 0 for the heap.
    pub scanned: u64,
}

/// The engines' event queue: one of the two [`QueueKind`]s behind a common
/// `push`/`pop` face. Owns the FIFO-tiebreak sequence counter, so call
/// sites just push `(t, ev)`.
pub(crate) struct EventQueue<E> {
    seq: u64,
    stats: QueueStats,
    imp: Imp<E>,
}

enum Imp<E> {
    Heap(BinaryHeap<Timed<E>>),
    Calendar(CalendarQueue<E>),
}

impl<E: Copy> EventQueue<E> {
    pub(crate) fn new(kind: QueueKind) -> EventQueue<E> {
        EventQueue {
            seq: 0,
            stats: QueueStats::default(),
            imp: match kind {
                QueueKind::Heap => Imp::Heap(BinaryHeap::new()),
                QueueKind::Calendar => Imp::Calendar(CalendarQueue::new()),
            },
        }
    }

    pub(crate) fn push(&mut self, t: f64, ev: E) {
        self.seq += 1;
        let e = Timed { t, seq: self.seq, ev };
        match &mut self.imp {
            Imp::Heap(h) => h.push(e),
            Imp::Calendar(c) => c.push(e),
        }
        self.stats.pushes += 1;
        self.stats.peak_len = self.stats.peak_len.max(self.len() as u64);
    }

    pub(crate) fn pop(&mut self) -> Option<Timed<E>> {
        let e = match &mut self.imp {
            Imp::Heap(h) => h.pop(),
            Imp::Calendar(c) => c.pop(),
        };
        if e.is_some() {
            self.stats.pops += 1;
        }
        e
    }

    pub(crate) fn len(&self) -> usize {
        match &self.imp {
            Imp::Heap(h) => h.len(),
            Imp::Calendar(c) => c.len,
        }
    }

    pub(crate) fn stats(&self) -> QueueStats {
        let mut s = self.stats;
        if let Imp::Calendar(c) = &self.imp {
            s.resizes = c.resizes;
            s.scanned = c.scanned;
        }
        s
    }
}

const MIN_BUCKETS: usize = 4;
const INIT_WIDTH: f64 = 1e-6; // one day ≈ 1 µs — the engines' natural scale
const MIN_WIDTH: f64 = 1e-12;

/// The calendar proper: `buckets[d % nbuckets]` holds every pending event
/// whose day is `d`, unsorted. Grows (doubles) when occupancy exceeds two
/// events per bucket, shrinks (halves, floor [`MIN_BUCKETS`]) below half an
/// event per bucket — the factor-4 hysteresis keeps resizes amortized away.
/// Each rebuild re-derives the day width from the pending events' span so
/// that a day holds ~2 events on average, which is what makes `pop`'s scan
/// `O(1)` amortized.
struct CalendarQueue<E> {
    buckets: Vec<Vec<Timed<E>>>,
    len: usize,
    width: f64,
    cur_day: u64,
    resizes: u64,
    scanned: u64,
}

impl<E: Copy> CalendarQueue<E> {
    fn new() -> CalendarQueue<E> {
        CalendarQueue {
            buckets: vec![Vec::new(); MIN_BUCKETS],
            len: 0,
            width: INIT_WIDTH,
            cur_day: 0,
            resizes: 0,
            scanned: 0,
        }
    }

    /// The day of time `t` at the current width. The cast saturates for
    /// astronomically large `t / width`, which only flattens those events
    /// into one far-future day — ordering is still exact because the scan
    /// compares `(t, seq)` directly.
    #[inline]
    fn day(&self, t: f64) -> u64 {
        debug_assert!(!t.is_nan(), "NaN event time in the calendar queue");
        (t / self.width) as u64
    }

    fn push(&mut self, e: Timed<E>) {
        let d = self.day(e.t);
        // an event earlier than the cursor (pushed at the current sim time
        // while the cursor sits on a later day) rewinds the cursor — pops
        // re-scan forward from it, so nothing is ever skipped
        if self.len == 0 || d < self.cur_day {
            self.cur_day = d;
        }
        let nb = self.buckets.len() as u64;
        self.buckets[(d % nb) as usize].push(e);
        self.len += 1;
        if self.len > 2 * self.buckets.len() {
            self.rebuild(self.buckets.len() * 2);
        }
    }

    fn pop(&mut self) -> Option<Timed<E>> {
        if self.len == 0 {
            return None;
        }
        let nb = self.buckets.len();
        for _ in 0..nb {
            let b = (self.cur_day % nb as u64) as usize;
            if let Some(i) = self.min_of_day_in(b, self.cur_day) {
                return Some(self.take(b, i));
            }
            self.cur_day += 1;
        }
        // a full lap found nothing: the earliest event is > nbuckets days
        // out (a latency gap wider than the calendar). Find it directly and
        // jump the cursor to its day.
        let (b, i, t) = self.global_min();
        self.cur_day = self.day(t);
        Some(self.take(b, i))
    }

    /// Index of the `(t, seq)`-least entry of day `d` in bucket `b`, if any.
    fn min_of_day_in(&mut self, b: usize, d: u64) -> Option<usize> {
        let mut best: Option<(f64, u64, usize)> = None;
        let width = self.width;
        let mut scanned = 0u64;
        for (i, e) in self.buckets[b].iter().enumerate() {
            scanned += 1;
            if (e.t / width) as u64 != d {
                continue;
            }
            let better = match best {
                None => true,
                Some((bt, bs, _)) => e.t.total_cmp(&bt).then(e.seq.cmp(&bs)).is_lt(),
            };
            if better {
                best = Some((e.t, e.seq, i));
            }
        }
        self.scanned += scanned;
        best.map(|(_, _, i)| i)
    }

    /// Locate the globally `(t, seq)`-least entry (the fallback path).
    fn global_min(&mut self) -> (usize, usize, f64) {
        let mut best: Option<(usize, usize)> = None;
        let mut bt = 0.0f64;
        let mut bs = 0u64;
        let mut scanned = 0u64;
        for (b, bucket) in self.buckets.iter().enumerate() {
            for (i, e) in bucket.iter().enumerate() {
                scanned += 1;
                if best.is_none() || e.t.total_cmp(&bt).then(e.seq.cmp(&bs)).is_lt() {
                    best = Some((b, i));
                    bt = e.t;
                    bs = e.seq;
                }
            }
        }
        self.scanned += scanned;
        let (b, i) = best.expect("global_min on a non-empty queue");
        (b, i, bt)
    }

    /// Remove entry `i` of bucket `b` (order within a bucket is irrelevant:
    /// the scans select by key, so `swap_remove` is safe) and shrink the
    /// calendar if occupancy fell far enough.
    fn take(&mut self, b: usize, i: usize) -> Timed<E> {
        let e = self.buckets[b].swap_remove(i);
        self.len -= 1;
        if self.buckets.len() > MIN_BUCKETS && self.len * 2 < self.buckets.len() {
            self.rebuild(self.buckets.len() / 2);
        }
        e
    }

    /// Redistribute into `nb` buckets, re-deriving the day width from the
    /// pending span (target: ~2 events per day) and the cursor from the
    /// earliest pending event. Deterministic: width and cursor depend only
    /// on the pending set.
    fn rebuild(&mut self, nb: usize) {
        let nb = nb.max(MIN_BUCKETS);
        self.resizes += 1;
        let mut all: Vec<Timed<E>> = Vec::with_capacity(self.len);
        for b in &mut self.buckets {
            all.append(b);
        }
        if !all.is_empty() {
            let mut min_t = f64::INFINITY;
            let mut max_t = f64::NEG_INFINITY;
            for e in &all {
                min_t = min_t.min(e.t);
                max_t = max_t.max(e.t);
            }
            let span = max_t - min_t;
            if span > 0.0 {
                self.width = (span * 2.0 / all.len() as f64).max(MIN_WIDTH);
            }
            self.cur_day = (min_t / self.width) as u64;
        }
        self.buckets.resize(nb, Vec::new());
        let nb64 = nb as u64;
        for e in all {
            let d = self.day(e.t);
            self.buckets[(d % nb64) as usize].push(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drain(q: &mut EventQueue<u32>) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        while let Some(Timed { t, seq, .. }) = q.pop() {
            out.push((t, seq));
        }
        out
    }

    fn assert_sorted(popped: &[(f64, u64)]) {
        for w in popped.windows(2) {
            let ord = w[0].0.total_cmp(&w[1].0).then(w[0].1.cmp(&w[1].1));
            assert!(ord.is_lt(), "out of order: {:?} then {:?}", w[0], w[1]);
        }
    }

    #[test]
    fn both_kinds_pop_the_same_sequence() {
        // interleaved pushes and pops with clustered, duplicate, and
        // far-apart times — the two kinds must agree event for event
        let times: Vec<f64> = (0..400)
            .map(|i| {
                let i = i as f64;
                match i as u64 % 4 {
                    0 => 1e-6 * i,            // dense cluster
                    1 => 1e-6 * (i % 7.0),    // duplicates
                    2 => 0.5 + 1e-3 * i,      // far block
                    _ => 1e-9 * i * i,        // quadratic spread
                }
            })
            .collect();
        let mut h = EventQueue::<u32>::new(QueueKind::Heap);
        let mut c = EventQueue::<u32>::new(QueueKind::Calendar);
        let mut popped_h = Vec::new();
        let mut popped_c = Vec::new();
        for (i, &t) in times.iter().enumerate() {
            h.push(t, i as u32);
            c.push(t, i as u32);
            if i % 3 == 2 {
                let a = h.pop().unwrap();
                let b = c.pop().unwrap();
                assert_eq!(a.t.to_bits(), b.t.to_bits());
                assert_eq!(a.seq, b.seq);
                assert_eq!(a.ev, b.ev);
                popped_h.push((a.t, a.seq));
                popped_c.push((b.t, b.seq));
            }
        }
        popped_h.extend(drain(&mut h));
        popped_c.extend(drain(&mut c));
        assert_eq!(popped_h, popped_c);
        assert_eq!(popped_h.len(), times.len());
        let sh = h.stats();
        let sc = c.stats();
        assert_eq!(sh.pushes, sc.pushes);
        assert_eq!(sh.pops, sc.pops);
        assert_eq!(sh.peak_len, sc.peak_len);
        assert_eq!(sh.resizes, 0, "heap never resizes");
        assert!(sc.resizes > 0, "400 events must outgrow 4 buckets");
    }

    #[test]
    fn day_rollover_preserves_t_seq_total_order() {
        // the satellite's targeted witness: same-instant events pushed
        // around day boundaries and resizes, plus a far-future event that
        // forces the direct-search fallback — pops must follow (t, seq)
        // exactly, FIFO within each instant
        let mut q = EventQueue::<u32>::new(QueueKind::Calendar);
        // 12 events at one instant near a day boundary (seq FIFO within t),
        // 12 at the exactly-next representable instant
        let t0 = 64.0 * INIT_WIDTH; // an exact day boundary at initial width
        let t1 = f64::from_bits(t0.to_bits() + 1);
        for i in 0..12u32 {
            q.push(t0, i);
            q.push(t1, 100 + i);
        }
        // far-future straggler: > MIN_BUCKETS days out after any resize
        q.push(1e3, 999);
        // and a pre-boundary event pushed late (rewinds the cursor)
        q.push(0.5 * t0, 1000);
        let mut popped = Vec::new();
        let mut evs = Vec::new();
        while let Some(Timed { t, seq, ev }) = q.pop() {
            popped.push((t, seq));
            evs.push(ev);
        }
        assert_sorted(&popped);
        assert_eq!(evs[0], 1000, "rewound event pops first");
        assert_eq!(&evs[1..13], &(0..12).collect::<Vec<u32>>()[..], "FIFO within t0");
        assert_eq!(
            &evs[13..25],
            &(100..112).collect::<Vec<u32>>()[..],
            "t0's next ulp pops after every t0 event"
        );
        assert_eq!(*evs.last().unwrap(), 999, "fallback finds the straggler");
    }

    #[test]
    fn grow_shrink_cycles_stay_exact() {
        // pump the queue up past several doublings, drain through the
        // halvings, repeat — every drain is sorted and complete
        let mut q = EventQueue::<u32>::new(QueueKind::Calendar);
        for round in 0..3u32 {
            let n = 257; // odd, > 2 * any bucket count reached
            for i in 0..n {
                let t = (i as f64 * 31.0 % 97.0) * 1e-5 + round as f64;
                q.push(t, i);
            }
            let popped = drain(&mut q);
            assert_eq!(popped.len(), n as usize, "round {round}");
            assert_sorted(&popped);
        }
        assert!(q.stats().resizes >= 6, "grow and shrink both exercised");
    }

    #[test]
    fn zero_span_same_instant_burst_is_fifo() {
        // every event at exactly one time (span 0: resize keeps the width):
        // pops are pure FIFO by seq
        let mut q = EventQueue::<u32>::new(QueueKind::Calendar);
        for i in 0..100u32 {
            q.push(2.5e-6, i);
        }
        let popped = drain(&mut q);
        assert_eq!(popped.len(), 100);
        assert_sorted(&popped);
    }

    #[test]
    fn same_instant_bursts_pin_the_scanned_per_pop_degradation() {
        // PR 8's honest finding, pinned: same-instant bursts defeat the
        // calendar's ~2-events-per-day sizing (span 0 means every rebuild
        // keeps the old width, so the whole burst lands in one day and each
        // pop rescans the remaining burst). The identical workload with
        // distinct timestamps stays O(1) per pop. The exact workload and
        // ratios are mirrored in tools/pysim (eval_core.py §4): burst
        // 16640/512 = 32.5, spread 776/512 ≈ 1.52.
        let ratio = |rounds: &[Vec<f64>]| {
            let mut q = EventQueue::<u32>::new(QueueKind::Calendar);
            for times in rounds {
                for (i, &t) in times.iter().enumerate() {
                    q.push(t, i as u32);
                }
                let popped = drain(&mut q);
                assert_eq!(popped.len(), times.len());
                assert_sorted(&popped);
            }
            let s = q.stats();
            assert_eq!(s.pops, s.pushes);
            s.scanned as f64 / s.pops as f64
        };
        let burst: Vec<Vec<f64>> = (0..8).map(|r| vec![r as f64 * 1e-3; 64]).collect();
        let spread: Vec<Vec<f64>> = (0..8)
            .map(|r| (0..64).map(|i| (r * 64 + i) as f64 * 1e-6).collect())
            .collect();
        let rb = ratio(&burst);
        let rs = ratio(&spread);
        assert!(rb > 16.0, "burst scanned/pop collapsed to {rb} — sizing fixed?");
        assert!(rs < 4.0, "spread scanned/pop degraded to {rs}");
        assert!(rb > 4.0 * rs, "burst ({rb}) no longer dominates spread ({rs})");
    }

    #[test]
    fn default_kind_round_trips() {
        assert_eq!(QueueKind::parse("heap"), Some(QueueKind::Heap));
        assert_eq!(QueueKind::parse("calendar"), Some(QueueKind::Calendar));
        assert_eq!(QueueKind::parse("cal"), None);
        let prev = default_kind();
        set_default_kind(QueueKind::Heap);
        assert_eq!(default_kind(), QueueKind::Heap);
        set_default_kind(prev);
        assert_eq!(default_kind(), prev);
    }
}
