//! Flow-level discrete-event simulation with max-min fair link sharing.
//!
//! Each message is a fluid flow over its route. Whenever the set of active
//! flows changes (injection or drain), rates are recomputed by progressive
//! water-filling: repeatedly freeze the flows crossing the currently most
//! contended link at its fair share. Deliveries complete `hops · per_hop`
//! after the last byte is serialized (cut-through pipelining).
//!
//! Events at equal timestamps are batch-processed so the symmetric,
//! step-synchronized traffic of these collectives triggers only a handful
//! of rate recomputations per step.

use super::{materialize, SimMsg, SimResult};
use crate::cost::NetParams;
use crate::schedule::Schedule;
use crate::topology::Torus;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

const TIME_EPS: f64 = 1e-15;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Event {
    /// Node enters step `k`: inject its step-`k` sends.
    StepStart { node: u32, step: u32 },
    /// A message has fully arrived at its destination.
    Delivery { node: u32, step: u32 },
}

#[derive(Clone, Copy, PartialEq)]
struct Timed {
    t: f64,
    seq: u64,
    ev: Event,
}

impl Eq for Timed {}
impl Ord for Timed {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap by time (reverse), tie-broken by insertion order
        other
            .t
            .partial_cmp(&self.t)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}
impl PartialOrd for Timed {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

struct ActiveFlow {
    msg_idx: u32,
    remaining: f64,
    rate: f64,
}

pub fn simulate_flow(
    schedule: &Schedule,
    torus: &Torus,
    m_bytes: u64,
    params: &NetParams,
) -> SimResult {
    let steps = materialize(schedule, torus, m_bytes);
    let n = schedule.n as usize;
    let nsteps = steps.len();
    if nsteps == 0 {
        return SimResult { completion_s: 0.0, messages: 0, events: 0 };
    }
    let cap = params.link_bw_bps / 8.0; // bytes per second per link
    let per_hop = params.per_hop_s();

    // Expected receive counts per (node, step).
    let mut expected = vec![0u32; n * nsteps];
    for (k, msgs) in steps.iter().enumerate() {
        for m in msgs {
            expected[m.dst as usize * nsteps + k] += 1;
        }
    }
    let mut received = vec![0u32; n * nsteps];
    // Per node: the step it has entered (sends injected); none = about to
    // enter step 0.
    let mut entered = vec![-1i64; n];

    let msgs_flat: Vec<&SimMsg> = steps.iter().flatten().collect();
    // index of messages per (step, src) for injection
    let mut by_step_src: Vec<Vec<u32>> = vec![Vec::new(); n * nsteps];
    for (i, m) in msgs_flat.iter().enumerate() {
        by_step_src[m.src as usize * nsteps + m.step].push(i as u32);
    }

    let mut heap: BinaryHeap<Timed> = BinaryHeap::new();
    let mut seq = 0u64;
    let mut push = |heap: &mut BinaryHeap<Timed>, t: f64, ev: Event| {
        seq += 1;
        heap.push(Timed { t, seq, ev });
    };
    // Every node enters step 0 after the initial α.
    for r in 0..n {
        push(&mut heap, params.alpha_s, Event::StepStart { node: r as u32, step: 0 });
    }

    let mut active: Vec<ActiveFlow> = Vec::new();
    let mut link_count = vec![0u32; torus.num_links()];
    let mut now = 0.0f64;
    let mut completion = 0.0f64;
    let mut events = 0u64;
    // scratch buffers for water-filling
    let mut link_cap = vec![0f64; torus.num_links()];

    // Water-filling rate assignment over `active`.
    let recompute = |active: &mut Vec<ActiveFlow>,
                     link_count: &mut [u32],
                     link_cap: &mut [f64],
                     frozen: &mut Vec<bool>| {
        frozen.clear();
        frozen.resize(active.len(), false);
        // initialize per-link state for links actually used
        for f in active.iter() {
            for &l in &msgs_flat[f.msg_idx as usize].route {
                link_cap[l as usize] = cap;
                link_count[l as usize] = 0;
            }
        }
        for f in active.iter() {
            for &l in &msgs_flat[f.msg_idx as usize].route {
                link_count[l as usize] += 1;
            }
        }
        let mut left = active.len();
        while left > 0 {
            // find the most contended link's fair share
            let mut min_share = f64::INFINITY;
            for (i, f) in active.iter().enumerate() {
                if frozen[i] {
                    continue;
                }
                for &l in &msgs_flat[f.msg_idx as usize].route {
                    let c = link_count[l as usize];
                    if c > 0 {
                        let share = link_cap[l as usize] / c as f64;
                        if share < min_share {
                            min_share = share;
                        }
                    }
                }
            }
            if !min_share.is_finite() {
                // remaining flows cross no contended links (shouldn't
                // happen: every flow has ≥1 hop)
                for (i, f) in active.iter_mut().enumerate() {
                    if !frozen[i] {
                        f.rate = cap;
                        frozen[i] = true;
                        left -= 1;
                    }
                }
                break;
            }
            // freeze every unfrozen flow whose bottleneck share equals min
            let mut progressed = false;
            for i in 0..active.len() {
                if frozen[i] {
                    continue;
                }
                let route = &msgs_flat[active[i].msg_idx as usize].route;
                let share = route
                    .iter()
                    .map(|&l| link_cap[l as usize] / link_count[l as usize].max(1) as f64)
                    .fold(f64::INFINITY, f64::min);
                if share <= min_share * (1.0 + 1e-12) {
                    active[i].rate = min_share;
                    frozen[i] = true;
                    left -= 1;
                    progressed = true;
                    for &l in route {
                        link_cap[l as usize] -= min_share;
                        link_count[l as usize] -= 1;
                    }
                }
            }
            debug_assert!(progressed, "water-filling stalled");
            if !progressed {
                break;
            }
        }
    };

    let mut frozen_buf: Vec<bool> = Vec::new();
    let mut need_recompute = false;

    loop {
        // Next discrete event vs. next flow drain.
        let t_event = heap.peek().map(|e| e.t).unwrap_or(f64::INFINITY);
        let mut t_drain = f64::INFINITY;
        for f in &active {
            if f.rate > 0.0 {
                let t = now + f.remaining / f.rate;
                if t < t_drain {
                    t_drain = t;
                }
            }
        }
        let t_next = t_event.min(t_drain);
        if !t_next.is_finite() {
            break;
        }
        // advance fluid state
        let dt = t_next - now;
        if dt > 0.0 {
            for f in active.iter_mut() {
                f.remaining -= f.rate * dt;
            }
        }
        now = t_next;

        // Collect drained flows at this instant.
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= active[i].rate * TIME_EPS + 1e-9 * TIME_EPS
                || active[i].remaining <= 1e-7
            {
                let f = active.swap_remove(i);
                let m = msgs_flat[f.msg_idx as usize];
                let arrive = now + m.route.len() as f64 * per_hop;
                push(&mut heap, arrive, Event::Delivery { node: m.dst, step: m.step as u32 });
                need_recompute = true;
            } else {
                i += 1;
            }
        }

        // Process all heap events at this instant.
        while let Some(top) = heap.peek() {
            if top.t > now + TIME_EPS.max(now * 1e-12) {
                break;
            }
            let Timed { ev, .. } = heap.pop().unwrap();
            events += 1;
            match ev {
                Event::StepStart { node, step } => {
                    entered[node as usize] = step as i64;
                    for &mi in &by_step_src[node as usize * nsteps + step as usize] {
                        let m = msgs_flat[mi as usize];
                        active.push(ActiveFlow { msg_idx: mi, remaining: m.bytes, rate: 0.0 });
                        need_recompute = true;
                    }
                    // A step with no expected receives chains immediately.
                    let k = step as usize;
                    if expected[node as usize * nsteps + k] == received[node as usize * nsteps + k]
                        && k + 1 < nsteps
                    {
                        push(
                            &mut heap,
                            now + params.alpha_s,
                            Event::StepStart { node, step: step + 1 },
                        );
                    }
                }
                Event::Delivery { node, step } => {
                    completion = completion.max(now);
                    let k = step as usize;
                    received[node as usize * nsteps + k] += 1;
                    // barrier: all step-k receives done AND node entered k
                    if received[node as usize * nsteps + k] == expected[node as usize * nsteps + k]
                        && entered[node as usize] == k as i64
                        && k + 1 < nsteps
                    {
                        push(
                            &mut heap,
                            now + params.alpha_s,
                            Event::StepStart { node, step: step as u32 + 1 },
                        );
                    }
                }
            }
        }

        if need_recompute {
            recompute(&mut active, &mut link_count, &mut link_cap, &mut frozen_buf);
            need_recompute = false;
        }
    }

    SimResult { completion_s: completion, messages: msgs_flat.len(), events }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};

    fn params() -> NetParams {
        NetParams::default()
    }

    #[test]
    fn single_message_time() {
        // one neighbor message: α + bytes/rate + per_hop
        let n = 4u32;
        let t = Torus::ring(n);
        let mut s = Schedule::new("one", n, n);
        let st = s.push_step();
        st.push(
            0,
            crate::schedule::Send {
                to: 1,
                pieces: vec![crate::schedule::Piece {
                    blocks: crate::blockset::BlockSet::full(n),
                    contrib: crate::blockset::BlockSet::singleton(0, n),
                    kind: crate::schedule::Kind::Reduce,
                }],
                route: crate::schedule::RouteHint::Minimal,
            },
        );
        let p = params();
        let m = 1u64 << 20;
        let r = simulate_flow(&s, &t, m, &p);
        let expect = p.alpha_s + m as f64 * 8.0 / p.link_bw_bps + p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < 1e-12,
            "got {}, expect {expect}",
            r.completion_s
        );
    }

    #[test]
    fn trivance_ring9_latency_time() {
        // 2 steps; step k: full vector at distance 3^k with uniform
        // congestion 3^k (each link carries 3^k flows in each direction) →
        // serialization 3^k·m·β each step (shared fairly), plus pipelining.
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let p = params();
        let m = 1u64 << 20;
        let r = simulate_flow(&s, &t, m, &p);
        let beta = 8.0 / p.link_bw_bps;
        let expect = 2.0 * p.alpha_s
            + (1.0 + 3.0) * m as f64 * beta
            + (1.0 + 3.0) * p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-9,
            "got {}, expect {expect}",
            r.completion_s
        );
    }

    #[test]
    fn alpha_dominates_small_messages() {
        let t = Torus::ring(27);
        let s = latency_allreduce(&trivance(27, Order::Inc));
        let p = params();
        let r = simulate_flow(&s, &t, 32, &p);
        // 3 steps × 1.5 µs = 4.5 µs dominates; plus (1+3+9) hops × 200 ns
        // = 2.6 µs of propagation and negligible serialization.
        assert!(r.completion_s > 4.5e-6 && r.completion_s < 7.5e-6, "{}", r.completion_s);
    }

    #[test]
    fn more_bandwidth_is_faster() {
        let t = Torus::ring(27);
        let s = latency_allreduce(&trivance(27, Order::Inc));
        let m = 8 << 20;
        let slow = simulate_flow(&s, &t, m, &NetParams::default().with_bandwidth_gbps(200.0));
        let fast = simulate_flow(&s, &t, m, &NetParams::default().with_bandwidth_gbps(3200.0));
        assert!(fast.completion_s < slow.completion_s / 8.0);
    }
}
