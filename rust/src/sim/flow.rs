//! Flow-level discrete-event simulation with max-min fair link sharing.
//!
//! Each message is a fluid flow over its route. Whenever the set of active
//! flows changes (injection or drain), rates are recomputed by progressive
//! water-filling: repeatedly freeze the flows bottlenecked on the currently
//! most contended link at its fair share. Deliveries complete `hops ·
//! per_hop` after the last byte is serialized (cut-through pipelining).
//!
//! Events at equal timestamps are batch-processed so the symmetric,
//! step-synchronized traffic of these collectives triggers only a handful
//! of rate recomputations per step.
//!
//! ## Incremental water-filling
//!
//! The rate solver keeps **persistent per-link state** ([`WaterFill`]):
//! active-flow counts per link are maintained incrementally (±1 per route
//! hop at injection/drain), and the set of links touched by any active flow
//! is tracked as a compact list. A recomputation therefore initializes
//! residual capacity only for the touched links, finds each round's minimum
//! fair share by scanning links (not flows × hops), and freezes from a
//! shrinking unfrozen-flow list — instead of re-initializing every link and
//! rescanning all active flows (frozen ones included) on every round, as
//! the previous implementation did. Combined with [`SimPlan`] reuse this is
//! what makes full-registry message-size ladders cheap.
//!
//! ## Symmetric-step fast path
//!
//! The steady state of these step-synchronized collectives is uniform
//! congestion: every contended link carries the same number of flows. The
//! recomputation detects that case up front and assigns the closed-form
//! equal split `cap / c` to every active flow — no water-filling rounds, no
//! per-flow route scans — falling back to progressive filling whenever link
//! loads diverge (padded configurations, drain transients). The fast path
//! computes the identical f64 division the generic first round would, so
//! flow results are bit-identical either way.
//!
//! ## Allocation-free hot path
//!
//! Both engines run out of a thread-local [`FlowWs`] workspace: the event
//! heap, the per-node receive/entered columns, the active-flow list, the
//! water-filler, and the timeline engine's mutable per-link columns are
//! allocated once per thread and re-initialized — never re-allocated — per
//! collective. The workspace is thread-local rather than part of
//! [`SimScratch`] because the scratch is shared *immutably* across sweep
//! threads. Every buffer is fully re-initialized per call, so results are
//! bit-identical to the former allocate-per-call engines
//! (`sim_crosscheck.rs` pins this).
//!
//! ## Heterogeneous links
//!
//! Under a non-uniform [`crate::net::NetModel`] each link has its own
//! capacity (`cap · bw_scale`, from the plan's scale columns) and the
//! water-filling fills against those per-link residuals; deliveries pay the
//! route's *summed* per-link forwarding latencies. The symmetric fast path
//! is gated on the plan actually being uniform — equal flow counts on
//! unequal links are not an equal split. Uniform plans run the exact
//! legacy arithmetic (`cap · 1.0 == cap`), so results are bit-identical to
//! the pre-NetModel simulator.

use super::plan::{SimPlan, SimScratch};
use super::{SimError, SimResult, Timed};
use crate::cost::NetParams;
use crate::net::{Mutation, Timeline};
use crate::obs;
use crate::schedule::Schedule;
use crate::topology::Torus;
use std::cell::RefCell;
use std::collections::BinaryHeap;

const TIME_EPS: f64 = 1e-15;
/// Relative slack when matching a flow's bottleneck share against the
/// round's minimum (absorbs float drift in `residual / count`).
const SHARE_EPS: f64 = 1e-12;

#[derive(Clone, Copy, Debug, PartialEq)]
enum Event {
    /// Node enters step `k`: inject its step-`k` sends.
    StepStart { node: u32, step: u32 },
    /// A message has fully arrived at its destination.
    Delivery { node: u32, step: u32 },
    /// A [`Timeline`] epoch fires: apply its mutations and re-water-fill.
    /// Never pushed by the static engine.
    Epoch { idx: u32 },
}

struct ActiveFlow {
    msg: u32,
    remaining: f64,
    rate: f64,
}

/// Persistent max-min water-filling state (see module docs). Sized once per
/// plan ([`WaterFill::reset`]); all per-recomputation work is proportional
/// to the *touched* links and the still-unfrozen flows.
#[derive(Default)]
struct WaterFill {
    /// Active flows crossing each link — incrementally maintained.
    nactive: Vec<u32>,
    /// Links with `nactive > 0` (compacted lazily at recompute).
    touched: Vec<u32>,
    in_touched: Vec<bool>,
    /// Scratch, valid for touched links during one recomputation.
    residual: Vec<f64>,
    unfrozen: Vec<u32>,
    /// Scratch: indices into the active-flow list.
    unfrozen_flows: Vec<u32>,
    freeze_buf: Vec<u32>,
    /// Whether the symmetric-step fast path may fire: the plan must be
    /// uniform (equal flow counts on *unequal* links are not an equal
    /// split) and every message must cross at least one link (a zero-hop
    /// flow is never link-bound and must take the generic infinite-share
    /// branch).
    symmetric_ok: bool,
    /// Observability counters, zeroed per collective by [`WaterFill::reset`]
    /// and flushed to `flow.waterfill.*` after the run. Integer bookkeeping
    /// only — the fill arithmetic never reads them.
    recomputes: u64,
    rounds: u64,
}

impl WaterFill {
    #[cfg(test)]
    fn new(plan: &SimPlan) -> Self {
        let mut wf = WaterFill::default();
        wf.reset(plan);
        wf
    }

    /// Re-size and re-zero the per-link state for `plan`, reusing the
    /// buffers' allocations. After a reset the state is indistinguishable
    /// from a freshly constructed one — the engines call this once per
    /// collective from the thread-local [`FlowWs`].
    fn reset(&mut self, plan: &SimPlan) {
        let num_links = plan.num_links();
        self.nactive.clear();
        self.nactive.resize(num_links, 0);
        self.touched.clear();
        self.in_touched.clear();
        self.in_touched.resize(num_links, false);
        self.residual.clear();
        self.residual.resize(num_links, 0.0);
        self.unfrozen.clear();
        self.unfrozen.resize(num_links, 0);
        self.unfrozen_flows.clear();
        self.freeze_buf.clear();
        self.symmetric_ok = plan.is_uniform() && !plan.has_zero_hop_routes();
        self.recomputes = 0;
        self.rounds = 0;
    }

    fn inject(&mut self, route: &[u32]) {
        for &l in route {
            let li = l as usize;
            if !self.in_touched[li] {
                self.in_touched[li] = true;
                self.touched.push(l);
            }
            self.nactive[li] += 1;
        }
    }

    fn drain(&mut self, route: &[u32]) {
        for &l in route {
            self.nactive[l as usize] -= 1;
        }
        // links that reached zero are dropped at the next recompute
    }

    /// Assign max-min fair rates to `active`. Progressive filling: each
    /// round computes the global minimum fair share over the touched links,
    /// freezes every flow whose bottleneck equals it (two-phase, so the
    /// round's selection is order-independent), and subtracts the frozen
    /// bandwidth from the links crossed. `cap` is the base (uniform)
    /// capacity, `caps` the per-link capacities (`== cap` on uniform plans).
    fn recompute(&mut self, active: &mut [ActiveFlow], plan: &SimPlan, cap: f64, caps: &[f64]) {
        self.recomputes += 1;
        // Compact the touched list and (re)initialize per-link state for
        // links still carrying active flows.
        let mut touched = std::mem::take(&mut self.touched);
        touched.retain(|&l| {
            let li = l as usize;
            if self.nactive[li] == 0 {
                self.in_touched[li] = false;
                false
            } else {
                self.residual[li] = caps[li];
                self.unfrozen[li] = self.nactive[li];
                true
            }
        });
        self.touched = touched;

        // Symmetric-step fast path: the steady state of these collectives
        // is *uniform* congestion — every contended link carries the same
        // number of flows. Max-min fairness then degenerates to an equal
        // split (every flow is bottlenecked at `cap / c` on every link it
        // crosses), so rates are assigned in closed form without any
        // water-filling rounds. The assigned rate is the same f64 division
        // the generic first round would compute, so results stay
        // bit-identical (see symmetric_fast_path_is_bit_identical_to_
        // water_filling below).
        if self.symmetric_ok {
            if let Some(&l0) = self.touched.first() {
                let c = self.nactive[l0 as usize];
                if self.touched.iter().all(|&l| self.nactive[l as usize] == c) {
                    let share = cap / c as f64;
                    for f in active.iter_mut() {
                        f.rate = share;
                    }
                    return;
                }
            }
        }

        self.unfrozen_flows.clear();
        self.unfrozen_flows.extend(0..active.len() as u32);
        while !self.unfrozen_flows.is_empty() {
            self.rounds += 1;
            // The most contended link's fair share.
            let mut min_share = f64::INFINITY;
            for &l in &self.touched {
                let li = l as usize;
                if self.unfrozen[li] > 0 {
                    let share = self.residual[li] / self.unfrozen[li] as f64;
                    if share < min_share {
                        min_share = share;
                    }
                }
            }
            if !min_share.is_finite() {
                // Remaining flows cross no contended link (possible only
                // for zero-hop routes, which schedules never produce).
                for &fi in &self.unfrozen_flows {
                    active[fi as usize].rate = cap;
                }
                self.unfrozen_flows.clear();
                break;
            }
            // Phase 1: select the flows bottlenecked at min_share.
            self.freeze_buf.clear();
            let mut i = 0;
            while i < self.unfrozen_flows.len() {
                let fi = self.unfrozen_flows[i] as usize;
                let share = plan
                    .route(active[fi].msg as usize)
                    .iter()
                    .map(|&l| {
                        let li = l as usize;
                        self.residual[li] / self.unfrozen[li].max(1) as f64
                    })
                    .fold(f64::INFINITY, f64::min);
                if share <= min_share * (1.0 + SHARE_EPS) {
                    self.freeze_buf.push(self.unfrozen_flows[i]);
                    self.unfrozen_flows.swap_remove(i);
                } else {
                    i += 1;
                }
            }
            debug_assert!(!self.freeze_buf.is_empty(), "water-filling stalled");
            if self.freeze_buf.is_empty() {
                // Float-drift safety net: never loop forever.
                for &fi in &self.unfrozen_flows {
                    active[fi as usize].rate = min_share;
                }
                self.unfrozen_flows.clear();
                break;
            }
            // Phase 2: apply.
            for &fi in &self.freeze_buf {
                let fi = fi as usize;
                active[fi].rate = min_share;
                for &l in plan.route(active[fi].msg as usize) {
                    let li = l as usize;
                    self.residual[li] -= min_share;
                    if self.residual[li] < 0.0 {
                        self.residual[li] = 0.0;
                    }
                    self.unfrozen[li] -= 1;
                }
            }
        }
    }
}

/// Per-thread reusable engine state (see "Allocation-free hot path" in the
/// module docs). Thread-local rather than part of [`SimScratch`] because
/// the scratch is shared immutably across sweep threads; every field is
/// fully re-initialized per collective, so reuse is invisible to results.
#[derive(Default)]
struct FlowWs {
    received: Vec<u32>,
    entered: Vec<i64>,
    heap: BinaryHeap<Timed<Event>>,
    active: Vec<ActiveFlow>,
    wf: WaterFill,
    /// Timeline-engine mutable per-link columns (unused by the static path).
    caps_up: Vec<f64>,
    caps_eff: Vec<f64>,
    down: Vec<bool>,
    link_hop: Vec<f64>,
}

thread_local! {
    static WS: RefCell<FlowWs> = RefCell::new(FlowWs::default());
}

/// One integer-only metrics flush per flow simulation (a single registry
/// lock). `epochs` is the number of timeline epochs applied (0 static).
fn flush_flow_metrics(events: u64, wf: &WaterFill, epochs: u64) {
    obs::metrics::counters_add(&[
        ("flow.sims", 1),
        ("flow.events", events),
        ("flow.waterfill.recomputes", wf.recomputes),
        ("flow.waterfill.rounds", wf.rounds),
        ("flow.epochs", epochs),
    ]);
}

/// Convenience wrapper: build the plan and simulate. Ladder-style callers
/// should build one [`SimPlan`] and call [`simulate_flow_plan`] per size.
pub fn simulate_flow(
    schedule: &Schedule,
    torus: &Torus,
    m_bytes: u64,
    params: &NetParams,
) -> SimResult {
    simulate_flow_plan(&SimPlan::build(schedule, torus), m_bytes, params)
}

/// Flow-level simulation of an `m_bytes` collective against a precompiled
/// plan. Builds the per-`(plan, params)` scratch internally — ladder/replay
/// callers should build one [`SimScratch`] and use
/// [`simulate_flow_plan_scratch`] (bit-identical).
pub fn simulate_flow_plan(plan: &SimPlan, m_bytes: u64, params: &NetParams) -> SimResult {
    simulate_flow_plan_scratch(plan, m_bytes, params, &SimScratch::new(plan, params))
}

/// [`simulate_flow_plan`] against a precomputed [`SimScratch`]. Runs out
/// of the thread-local [`FlowWs`] workspace — no per-call allocations on
/// the hot path.
pub fn simulate_flow_plan_scratch(
    plan: &SimPlan,
    m_bytes: u64,
    params: &NetParams,
    scratch: &SimScratch,
) -> SimResult {
    debug_assert!(scratch.matches(plan), "scratch built for a different plan");
    if plan.num_steps() == 0 {
        return SimResult { completion_s: 0.0, messages: 0, events: 0 };
    }
    WS.with(|ws| run_static(plan, m_bytes, params, scratch, &mut ws.borrow_mut()))
}

fn run_static(
    plan: &SimPlan,
    m_bytes: u64,
    params: &NetParams,
    scratch: &SimScratch,
    ws: &mut FlowWs,
) -> SimResult {
    let n = plan.n();
    let nsteps = plan.num_steps();
    let cap = params.link_bw_bps / 8.0; // base bytes per second per link
    let caps = &scratch.caps; // per-link (== cap when uniform)
    let msg_hop_lat = &scratch.msg_hop_lat;

    let FlowWs { received, entered, heap, active, wf, .. } = ws;
    received.clear();
    received.resize(n * nsteps, 0);
    // Per node: the step it has entered (sends injected); -1 = about to
    // enter step 0.
    entered.clear();
    entered.resize(n, -1);
    heap.clear();
    active.clear();
    wf.reset(plan);

    let mut seq = 0u64;
    macro_rules! push {
        ($t:expr, $ev:expr) => {{
            seq += 1;
            heap.push(Timed { t: $t, seq, ev: $ev });
        }};
    }
    // Every node enters step 0 after the initial α.
    for r in 0..n {
        push!(params.alpha_s, Event::StepStart { node: r as u32, step: 0 });
    }
    if obs::tracing() {
        obs::with_sink(|s| s.span_begin(obs::PID_FLOW, obs::cur_tid(), "flow_run", 0.0));
    }

    let mut now = 0.0f64;
    let mut completion = 0.0f64;
    let mut events = 0u64;
    let mut need_recompute = false;

    loop {
        // Next discrete event vs. next flow drain.
        let t_event = heap.peek().map(|e| e.t).unwrap_or(f64::INFINITY);
        let mut t_drain = f64::INFINITY;
        for f in active.iter() {
            if f.rate > 0.0 {
                let t = now + f.remaining / f.rate;
                if t < t_drain {
                    t_drain = t;
                }
            }
        }
        let t_next = t_event.min(t_drain);
        if !t_next.is_finite() {
            break;
        }
        // advance fluid state
        let dt = t_next - now;
        if dt > 0.0 {
            for f in active.iter_mut() {
                f.remaining -= f.rate * dt;
            }
        }
        now = t_next;

        // Collect drained flows at this instant.
        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= active[i].rate * TIME_EPS + 1e-9 * TIME_EPS
                || active[i].remaining <= 1e-7
            {
                let f = active.swap_remove(i);
                let route = plan.route(f.msg as usize);
                wf.drain(route);
                let m = plan.msg(f.msg as usize);
                let arrive = now + msg_hop_lat[f.msg as usize];
                push!(arrive, Event::Delivery { node: m.dst, step: m.step });
                need_recompute = true;
            } else {
                i += 1;
            }
        }

        // Process all heap events at this instant.
        while let Some(top) = heap.peek() {
            if top.t > now + TIME_EPS.max(now * 1e-12) {
                break;
            }
            let Timed { ev, .. } = heap.pop().unwrap();
            events += 1;
            match ev {
                Event::StepStart { node, step } => {
                    entered[node as usize] = step as i64;
                    for &mi in plan.injections(node as usize, step as usize) {
                        active.push(ActiveFlow {
                            msg: mi,
                            remaining: plan.bytes(mi as usize, m_bytes),
                            rate: 0.0,
                        });
                        wf.inject(plan.route(mi as usize));
                        need_recompute = true;
                    }
                    // A step with no expected receives chains immediately.
                    let k = step as usize;
                    if plan.expected(node as usize, k) == received[node as usize * nsteps + k]
                        && k + 1 < nsteps
                    {
                        push!(now + params.alpha_s, Event::StepStart { node, step: step + 1 });
                    }
                }
                Event::Delivery { node, step } => {
                    completion = completion.max(now);
                    let k = step as usize;
                    received[node as usize * nsteps + k] += 1;
                    // barrier: all step-k receives done AND node entered k
                    if received[node as usize * nsteps + k] == plan.expected(node as usize, k)
                        && entered[node as usize] == k as i64
                        && k + 1 < nsteps
                    {
                        push!(now + params.alpha_s, Event::StepStart { node, step: step + 1 });
                    }
                }
                Event::Epoch { .. } => unreachable!("static flow engine pushes no epochs"),
            }
        }

        if need_recompute {
            wf.recompute(active, plan, cap, caps);
            need_recompute = false;
        }
    }

    if obs::tracing() {
        obs::with_sink(|s| s.span_end(obs::PID_FLOW, obs::cur_tid(), "flow_run", completion));
    }
    flush_flow_metrics(events, wf, 0);
    SimResult { completion_s: completion, messages: plan.num_msgs(), events }
}

/// [`simulate_flow_plan_scratch`] under a [`Timeline`] of fabric mutations:
/// one [`Event::Epoch`] per timeline epoch switches the per-link capacities
/// and forwarding latencies and triggers a max-min **re-water-fill**, so
/// every active flow's rate reflects the fabric in force right now. A link
/// taken down has capacity 0 — its flows stall at rate 0 and resume on
/// recovery. With an empty timeline this *is* the static engine (same code
/// path, bit-identical).
///
/// Returns [`SimError::Stranded`] (carrying the blocked link and step) if
/// the timeline leaves flows stranded on a permanently-down link: a
/// completion time that silently dropped undelivered messages would be
/// wrong, and permanent faults belong to [`crate::schedule::rewrite`] /
/// [`crate::schedule::online`].
pub fn simulate_flow_plan_timeline(
    plan: &SimPlan,
    m_bytes: u64,
    params: &NetParams,
    scratch: &SimScratch,
    timeline: &Timeline,
) -> Result<SimResult, SimError> {
    if timeline.is_empty() {
        return Ok(simulate_flow_plan_scratch(plan, m_bytes, params, scratch));
    }
    debug_assert!(scratch.matches(plan), "scratch built for a different plan");
    if plan.num_steps() == 0 {
        return Ok(SimResult { completion_s: 0.0, messages: 0, events: 0 });
    }
    WS.with(|ws| run_timeline(plan, m_bytes, params, scratch, timeline, &mut ws.borrow_mut()))
}

fn run_timeline(
    plan: &SimPlan,
    m_bytes: u64,
    params: &NetParams,
    scratch: &SimScratch,
    timeline: &Timeline,
    ws: &mut FlowWs,
) -> Result<SimResult, SimError> {
    let n = plan.n();
    let nsteps = plan.num_steps();
    let cap = params.link_bw_bps / 8.0;

    let FlowWs { received, entered, heap, active, wf, caps_up, caps_eff, down, link_hop } = ws;
    // Mutable per-link state seeded from the scratch columns: the class
    // value (`caps_up`), the down flag, and the effective capacity the
    // water-filling sees (`caps_eff` — 0 while down).
    caps_up.clear();
    caps_up.extend_from_slice(&scratch.caps);
    caps_eff.clear();
    caps_eff.extend_from_slice(&scratch.caps);
    down.clear();
    down.resize(plan.num_links(), false);
    link_hop.clear();
    link_hop.extend_from_slice(&scratch.link_hop_lat);

    received.clear();
    received.resize(n * nsteps, 0);
    entered.clear();
    entered.resize(n, -1);
    heap.clear();
    active.clear();
    wf.reset(plan);

    let mut seq = 0u64;
    macro_rules! push {
        ($t:expr, $ev:expr) => {{
            seq += 1;
            heap.push(Timed { t: $t, seq, ev: $ev });
        }};
    }
    for r in 0..n {
        push!(params.alpha_s, Event::StepStart { node: r as u32, step: 0 });
    }
    for (ei, e) in timeline.epochs().iter().enumerate() {
        push!(e.t, Event::Epoch { idx: ei as u32 });
    }
    if obs::tracing() {
        obs::with_sink(|s| s.span_begin(obs::PID_FLOW, obs::cur_tid(), "flow_run", 0.0));
    }

    // Rates change mid-flight and capacities diverge per link: the
    // closed-form symmetric shortcut no longer applies.
    wf.symmetric_ok = false;
    let mut now = 0.0f64;
    let mut completion = 0.0f64;
    let mut events = 0u64;
    let mut need_recompute = false;

    loop {
        let t_event = heap.peek().map(|e| e.t).unwrap_or(f64::INFINITY);
        let mut t_drain = f64::INFINITY;
        for f in active.iter() {
            if f.rate > 0.0 {
                let t = now + f.remaining / f.rate;
                if t < t_drain {
                    t_drain = t;
                }
            }
        }
        let t_next = t_event.min(t_drain);
        if !t_next.is_finite() {
            break;
        }
        let dt = t_next - now;
        if dt > 0.0 {
            for f in active.iter_mut() {
                f.remaining -= f.rate * dt;
            }
        }
        now = t_next;

        let mut i = 0;
        while i < active.len() {
            if active[i].remaining <= active[i].rate * TIME_EPS + 1e-9 * TIME_EPS
                || active[i].remaining <= 1e-7
            {
                let f = active.swap_remove(i);
                let route = plan.route(f.msg as usize);
                wf.drain(route);
                let m = plan.msg(f.msg as usize);
                // per-link forwarding latencies in force at drain time
                let lat: f64 = route.iter().map(|&l| link_hop[l as usize]).sum();
                push!(now + lat, Event::Delivery { node: m.dst, step: m.step });
                need_recompute = true;
            } else {
                i += 1;
            }
        }

        while let Some(top) = heap.peek() {
            if top.t > now + TIME_EPS.max(now * 1e-12) {
                break;
            }
            let Timed { ev, .. } = heap.pop().unwrap();
            events += 1;
            match ev {
                Event::StepStart { node, step } => {
                    entered[node as usize] = step as i64;
                    for &mi in plan.injections(node as usize, step as usize) {
                        active.push(ActiveFlow {
                            msg: mi,
                            remaining: plan.bytes(mi as usize, m_bytes),
                            rate: 0.0,
                        });
                        wf.inject(plan.route(mi as usize));
                        need_recompute = true;
                    }
                    let k = step as usize;
                    if plan.expected(node as usize, k) == received[node as usize * nsteps + k]
                        && k + 1 < nsteps
                    {
                        push!(now + params.alpha_s, Event::StepStart { node, step: step + 1 });
                    }
                }
                Event::Delivery { node, step } => {
                    completion = completion.max(now);
                    let k = step as usize;
                    received[node as usize * nsteps + k] += 1;
                    if received[node as usize * nsteps + k] == plan.expected(node as usize, k)
                        && entered[node as usize] == k as i64
                        && k + 1 < nsteps
                    {
                        push!(now + params.alpha_s, Event::StepStart { node, step: step + 1 });
                    }
                }
                Event::Epoch { idx } => {
                    if obs::tracing() {
                        let muts = timeline.epochs()[idx as usize].mutations.len();
                        obs::with_sink(|s| {
                            s.instant(
                                obs::PID_FLOW,
                                obs::cur_tid(),
                                "flow_epoch",
                                now,
                                &[("idx", idx as f64), ("mutations", muts as f64)],
                            );
                        });
                    }
                    for m in &timeline.epochs()[idx as usize].mutations {
                        match *m {
                            Mutation::SetClass { link, class } => {
                                let l = link as usize;
                                caps_up[l] = cap * class.bw_scale;
                                link_hop[l] = class.lat_scale * params.link_latency_s
                                    + class.proc_scale * params.hop_latency_s;
                                caps_eff[l] = if down[l] { 0.0 } else { caps_up[l] };
                            }
                            Mutation::SetDown { link, down: d } => {
                                let l = link as usize;
                                down[l] = d;
                                caps_eff[l] = if d { 0.0 } else { caps_up[l] };
                            }
                        }
                    }
                    need_recompute = true;
                }
            }
        }

        if need_recompute {
            wf.recompute(active, plan, cap, caps_eff);
            need_recompute = false;
        }
    }

    if !active.is_empty() {
        // Deterministic diagnostic: the lowest-id stranded message, and the
        // first zero-capacity link on its route (the link its bytes are
        // blocked on for good).
        let f = active.iter().min_by_key(|f| f.msg).unwrap();
        let route = plan.route(f.msg as usize);
        let link = route
            .iter()
            .map(|&l| l as usize)
            .find(|&l| caps_eff[l] == 0.0)
            .unwrap_or_else(|| route.first().map(|&l| l as usize).unwrap_or(0));
        if obs::tracing() {
            // Close the run span so error traces still validate.
            obs::with_sink(|s| s.span_end(obs::PID_FLOW, obs::cur_tid(), "flow_run", now));
        }
        return Err(SimError::Stranded { link, step: plan.msg(f.msg as usize).step });
    }
    if obs::tracing() {
        obs::with_sink(|s| s.span_end(obs::PID_FLOW, obs::cur_tid(), "flow_run", completion));
    }
    flush_flow_metrics(events, wf, timeline.epochs().len() as u64);
    Ok(SimResult { completion_s: completion, messages: plan.num_msgs(), events })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::agpattern::latency_allreduce;
    use crate::algo::rings::{trivance, Order};

    fn params() -> NetParams {
        NetParams::default()
    }

    #[test]
    fn single_message_time() {
        // one neighbor message: α + bytes/rate + per_hop
        let n = 4u32;
        let t = Torus::ring(n);
        let mut s = Schedule::new("one", n, n);
        let st = s.push_step();
        st.push(
            0,
            crate::schedule::Send {
                to: 1,
                pieces: vec![crate::schedule::Piece {
                    blocks: crate::blockset::BlockSet::full(n),
                    contrib: crate::blockset::BlockSet::singleton(0, n),
                    kind: crate::schedule::Kind::Reduce,
                }],
                route: crate::schedule::RouteHint::Minimal,
            },
        );
        let p = params();
        let m = 1u64 << 20;
        let r = simulate_flow(&s, &t, m, &p);
        let expect = p.alpha_s + m as f64 * 8.0 / p.link_bw_bps + p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < 1e-12,
            "got {}, expect {expect}",
            r.completion_s
        );
    }

    #[test]
    fn trivance_ring9_latency_time() {
        // 2 steps; step k: full vector at distance 3^k with uniform
        // congestion 3^k (each link carries 3^k flows in each direction) →
        // serialization 3^k·m·β each step (shared fairly), plus pipelining.
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let p = params();
        let m = 1u64 << 20;
        let r = simulate_flow(&s, &t, m, &p);
        let beta = 8.0 / p.link_bw_bps;
        let expect = 2.0 * p.alpha_s
            + (1.0 + 3.0) * m as f64 * beta
            + (1.0 + 3.0) * p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-9,
            "got {}, expect {expect}",
            r.completion_s
        );
    }

    #[test]
    fn alpha_dominates_small_messages() {
        let t = Torus::ring(27);
        let s = latency_allreduce(&trivance(27, Order::Inc));
        let p = params();
        let r = simulate_flow(&s, &t, 32, &p);
        // 3 steps × 1.5 µs = 4.5 µs dominates; plus (1+3+9) hops × 200 ns
        // = 2.6 µs of propagation and negligible serialization.
        assert!(r.completion_s > 4.5e-6 && r.completion_s < 7.5e-6, "{}", r.completion_s);
    }

    #[test]
    fn more_bandwidth_is_faster() {
        let t = Torus::ring(27);
        let s = latency_allreduce(&trivance(27, Order::Inc));
        let m = 8 << 20;
        let slow = simulate_flow(&s, &t, m, &NetParams::default().with_bandwidth_gbps(200.0));
        let fast = simulate_flow(&s, &t, m, &NetParams::default().with_bandwidth_gbps(3200.0));
        assert!(fast.completion_s < slow.completion_s / 8.0);
    }

    #[test]
    fn symmetric_fast_path_is_bit_identical_to_water_filling() {
        // Same injected flow set, recomputed with and without the fast
        // path: rates must match bit for bit (the fast path is only a
        // short-circuit of the uniform first round).
        let t = Torus::ring(9);
        let s = latency_allreduce(&trivance(9, Order::Inc));
        let plan = SimPlan::build(&s, &t);
        let p = params();
        let cap = p.link_bw_bps / 8.0;
        let caps = plan.link_caps(&p);
        for step in 0..plan.num_steps() {
            let mut fast = WaterFill::new(&plan);
            let mut slow = WaterFill::new(&plan);
            slow.symmetric_ok = false;
            assert!(fast.symmetric_ok);
            let mut active_f: Vec<ActiveFlow> = Vec::new();
            let mut active_s: Vec<ActiveFlow> = Vec::new();
            for node in 0..plan.n() {
                for &mi in plan.injections(node, step) {
                    for (wf, active) in
                        [(&mut fast, &mut active_f), (&mut slow, &mut active_s)]
                    {
                        active.push(ActiveFlow {
                            msg: mi,
                            remaining: plan.bytes(mi as usize, 1 << 20),
                            rate: 0.0,
                        });
                        wf.inject(plan.route(mi as usize));
                    }
                }
            }
            fast.recompute(&mut active_f, &plan, cap, &caps);
            slow.recompute(&mut active_s, &plan, cap, &caps);
            for (a, b) in active_f.iter().zip(&active_s) {
                assert_eq!(a.msg, b.msg);
                assert_eq!(a.rate.to_bits(), b.rate.to_bits(), "step {step}");
            }
        }
    }

    #[test]
    fn thread_local_workspace_reuse_is_invisible() {
        // Interleave two differently-shaped plans on one thread: the
        // workspace (heap, water-filler, per-link columns) is resized and
        // re-zeroed between calls, so the repeat run must be bit-identical
        // to the first — any stale state would show up here.
        let p = params();
        let t9 = Torus::ring(9);
        let s9 = latency_allreduce(&trivance(9, Order::Inc));
        let plan9 = SimPlan::build(&s9, &t9);
        let sc9 = SimScratch::new(&plan9, &p);
        let t27 = Torus::ring(27);
        let s27 = latency_allreduce(&trivance(27, Order::Inc));
        let plan27 = SimPlan::build(&s27, &t27);
        let sc27 = SimScratch::new(&plan27, &p);
        for m in [0u64, 4096, 1 << 20] {
            let first = simulate_flow_plan_scratch(&plan9, m, &p, &sc9);
            let _ = simulate_flow_plan_scratch(&plan27, m, &p, &sc27);
            let again = simulate_flow_plan_scratch(&plan9, m, &p, &sc9);
            assert_eq!(first.completion_s.to_bits(), again.completion_s.to_bits(), "m={m}");
            assert_eq!(first.events, again.events);
            assert_eq!(first.messages, again.messages);
        }
    }

    #[test]
    fn plan_reuse_across_sizes_matches_rebuild() {
        // The plan/execute split must be observationally identical to
        // per-size materialization — bit-for-bit.
        let t = Torus::ring(27);
        let s = latency_allreduce(&trivance(27, Order::Inc));
        let p = params();
        let plan = SimPlan::build(&s, &t);
        for m in [32u64, 4096, 1 << 20, 8 << 20] {
            let via_plan = simulate_flow_plan(&plan, m, &p);
            let direct = simulate_flow(&s, &t, m, &p);
            assert_eq!(
                via_plan.completion_s.to_bits(),
                direct.completion_s.to_bits(),
                "m={m}"
            );
            assert_eq!(via_plan.messages, direct.messages);
            assert_eq!(via_plan.events, direct.events);
        }
    }

    #[test]
    fn straggled_link_slows_its_flow_by_the_factor() {
        // one neighbor message over a 4x-slowed link: α + 4·bytes/cap +
        // per_hop, exactly
        use crate::net::{LinkClass, NetModel};
        let n = 4u32;
        let t = Torus::ring(n);
        let mut s = Schedule::new("one", n, n);
        let st = s.push_step();
        st.push(
            0,
            crate::schedule::Send {
                to: 1,
                pieces: vec![crate::schedule::Piece {
                    blocks: crate::blockset::BlockSet::full(n),
                    contrib: crate::blockset::BlockSet::singleton(0, n),
                    kind: crate::schedule::Kind::Reduce,
                }],
                route: crate::schedule::RouteHint::Minimal,
            },
        );
        let mut model = NetModel::uniform(&t);
        let l = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 });
        model.set_class(l, LinkClass::slowdown(4.0));
        let p = params();
        let m = 1u64 << 20;
        let plan = SimPlan::try_build_with_model(&s, &model).unwrap();
        let r = simulate_flow_plan(&plan, m, &p);
        let expect = p.alpha_s + 4.0 * m as f64 * 8.0 / p.link_bw_bps + p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-9,
            "got {} expect {expect}",
            r.completion_s
        );
        // scaled per-link latencies are paid too
        let mut lat = NetModel::uniform(&t);
        lat.set_class(l, LinkClass::new(1.0, 3.0, 2.0));
        let rl = simulate_flow_plan(&SimPlan::try_build_with_model(&s, &lat).unwrap(), m, &p);
        let expect_lat = p.alpha_s
            + m as f64 * 8.0 / p.link_bw_bps
            + 3.0 * p.link_latency_s
            + 2.0 * p.hop_latency_s;
        assert!(
            (rl.completion_s - expect_lat).abs() < expect_lat * 1e-9,
            "got {} expect {expect_lat}",
            rl.completion_s
        );
    }

    fn one_msg_schedule(n: u32, to: u32) -> Schedule {
        let mut s = Schedule::new("one", n, n);
        let st = s.push_step();
        st.push(
            0,
            crate::schedule::Send {
                to,
                pieces: vec![crate::schedule::Piece {
                    blocks: crate::blockset::BlockSet::full(n),
                    contrib: crate::blockset::BlockSet::singleton(0, n),
                    kind: crate::schedule::Kind::Reduce,
                }],
                route: crate::schedule::RouteHint::Minimal,
            },
        );
        s
    }

    #[test]
    fn flap_outage_adds_exactly_the_window() {
        // one neighbor flow; its link goes down for a window inside the
        // serialization: completion = α + ser + window + per_hop, exactly
        use crate::net::{Epoch, Mutation, Timeline};
        let t = Torus::ring(4);
        let s = one_msg_schedule(4, 1);
        let p = params();
        let m = 1u64 << 20;
        let plan = SimPlan::build(&s, &t);
        let scratch = SimScratch::new(&plan, &p);
        let cap = p.link_bw_bps / 8.0;
        let ser = m as f64 / cap;
        let l = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 }) as u32;
        let (t0, t1) = (p.alpha_s + 0.25 * ser, p.alpha_s + 0.5 * ser);
        let tl = Timeline::new(vec![
            Epoch { t: t0, mutations: vec![Mutation::SetDown { link: l, down: true }] },
            Epoch { t: t1, mutations: vec![Mutation::SetDown { link: l, down: false }] },
        ]);
        let r = simulate_flow_plan_timeline(&plan, m, &p, &scratch, &tl).unwrap();
        let expect = p.alpha_s + ser + (t1 - t0) + p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-9,
            "got {} expect {expect}",
            r.completion_s
        );
        // and a timeline that never recovers strands the flow: a typed
        // error naming the blocked link and step, never a panic
        let dead = Timeline::new(vec![Epoch {
            t: t0,
            mutations: vec![Mutation::SetDown { link: l, down: true }],
        }]);
        let err = simulate_flow_plan_timeline(&plan, m, &p, &scratch, &dead).unwrap_err();
        assert_eq!(err, SimError::Stranded { link: l as usize, step: 0 });
        assert!(err.to_string().contains("stranded"), "{err}");
    }

    #[test]
    fn brownout_slows_exactly_by_the_window_deficit() {
        // 2x slowdown over a window of length w inside the serialization
        // phase costs exactly w extra (half the bytes of the window are
        // deferred): completion = α + ser + w + per_hop
        use crate::net::{Epoch, LinkClass, Mutation, Timeline};
        let t = Torus::ring(4);
        let s = one_msg_schedule(4, 1);
        let p = params();
        let m = 1u64 << 20;
        let plan = SimPlan::build(&s, &t);
        let scratch = SimScratch::new(&plan, &p);
        let cap = p.link_bw_bps / 8.0;
        let ser = m as f64 / cap;
        let l = t.link_index(crate::topology::Link { node: 0, dim: 0, dir: 1 }) as u32;
        let w = 0.25 * ser;
        let tl = Timeline::new(vec![
            Epoch {
                t: p.alpha_s + 0.25 * ser,
                mutations: vec![Mutation::SetClass { link: l, class: LinkClass::slowdown(2.0) }],
            },
            Epoch {
                t: p.alpha_s + 0.25 * ser + w,
                mutations: vec![Mutation::SetClass { link: l, class: LinkClass::UNIFORM }],
            },
        ]);
        let r = simulate_flow_plan_timeline(&plan, m, &p, &scratch, &tl).unwrap();
        // during the window the flow drains at cap/2, deferring 0.5·cap·w
        // bytes — recovered at full rate afterwards: exactly 0.5·w extra
        let expect = p.alpha_s + ser + 0.5 * w + p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-9,
            "got {} expect {expect}",
            r.completion_s
        );
        // empty timeline delegates to the static engine bit for bit
        let stat = simulate_flow_plan_scratch(&plan, m, &p, &scratch);
        let empt =
            simulate_flow_plan_timeline(&plan, m, &p, &scratch, &Timeline::empty()).unwrap();
        assert_eq!(stat.completion_s.to_bits(), empt.completion_s.to_bits());
        assert_eq!(stat.events, empt.events);
    }

    #[test]
    fn incremental_state_survives_asymmetric_load() {
        // Two flows share a link, a third does not: rates must settle at
        // cap/2, cap/2, cap — and completion must reflect the shared pair
        // finishing last.
        let n = 6u32;
        let t = Torus::ring(n);
        let mut s = Schedule::new("asym", n, n);
        let st = s.push_step();
        for (src, to) in [(0u32, 2u32), (1, 2), (4, 5)] {
            st.push(
                src,
                crate::schedule::Send {
                    to,
                    pieces: vec![crate::schedule::Piece {
                        blocks: crate::blockset::BlockSet::full(n),
                        contrib: crate::blockset::BlockSet::singleton(src, n),
                        kind: crate::schedule::Kind::Reduce,
                    }],
                    route: crate::schedule::RouteHint::Minimal,
                },
            );
        }
        let p = params();
        let m = 1u64 << 20;
        let r = simulate_flow(&s, &t, m, &p);
        // 0→2 and 1→2 share link 1→2 (both route forward): the later of the
        // two is bottlenecked at cap/2 on that link. 0→2 serializes first on
        // 0→1 at full rate … the completion is dominated by the shared pair:
        // total bytes through link 1→2 is 2m at cap.
        let beta = 8.0 / p.link_bw_bps;
        let expect = p.alpha_s + 2.0 * m as f64 * beta + 2.0 * p.per_hop_s();
        assert!(
            (r.completion_s - expect).abs() < expect * 1e-6,
            "got {} expect {expect}",
            r.completion_s
        );
    }
}
